"""Per-architecture smoke tests (reduced variants, CPU): one forward + one
train step, output shapes + no NaNs; decode ≡ forward consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.config import OptimConfig
from repro.configs import ARCH_IDS, ASSIGNED, get_config, get_smoke_config, lora_targets
from repro.models import transformer as T
from repro.optim.adamw import adamw_init
from repro.peft.lora import init_lora
from repro.train.step import loss_fn, make_train_step

B, S = 2, 32


def _batch(cfg, rng, seq=S):
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, seq))),
             "loss_mask": jnp.ones((B, seq), jnp.float32)}
    if cfg.frontend == "vision":
        batch["patch_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.num_patches, cfg.frontend_dim)), jnp.float32)
    if cfg.frontend == "audio":
        batch = {"frame_embeds": jnp.asarray(rng.normal(size=(B, seq, cfg.frontend_dim)),
                                             jnp.float32),
                 "labels": batch["tokens"], "loss_mask": batch["loss_mask"]}
    return batch


@pytest.fixture(params=ARCH_IDS)
def arch(request):
    return request.param


# exotic families whose train-step compile dominates the suite: their
# forward smoke stays in the default tier, the backward pass runs in the
# slow tier
_HEAVY_ARCHS = {"deepseek_v3_671b", "zamba2_1p2b", "granite_moe_1b_a400m",
                "phi3_vision_4p2b"}


@pytest.fixture(params=[
    pytest.param(a, marks=pytest.mark.slow) if a in _HEAVY_ARCHS else a
    for a in ARCH_IDS])
def train_arch(request):
    return request.param


class TestSmoke:
    def test_forward_shapes_and_finite(self, arch, rng):
        cfg = get_smoke_config(arch)
        params = T.init(cfg, jax.random.PRNGKey(0))
        batch = _batch(cfg, rng)
        hidden, aux = T.forward(cfg, params, batch)
        S_total = S + (cfg.num_patches if cfg.frontend == "vision" else 0)
        assert hidden.shape == (B, S_total, cfg.d_model)
        assert np.isfinite(np.asarray(hidden, np.float32)).all()
        lg = T.logits(cfg, params, hidden)
        assert lg.shape[-1] == cfg.vocab_size

    def test_one_train_step_no_nans(self, train_arch, rng):
        cfg = get_smoke_config(train_arch)
        key = jax.random.PRNGKey(1)
        params = T.init(cfg, key)
        adapters = init_lora(params, lora_targets(cfg), 4, 4.0, key)
        opt = adamw_init(adapters)
        step = make_train_step(cfg, OptimConfig(lr=1e-3), remat=False,
                               loss_chunk=16)
        batch = _batch(cfg, rng)
        new_ad, opt, metrics = step(params, adapters, opt, batch)
        assert np.isfinite(float(metrics["loss"]))
        # adapters actually moved (B starts at 0, grads flow)
        moved = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()),
                             adapters, new_ad)
        assert max(jax.tree.leaves(moved)) > 0

    def test_grad_accum_matches_single_batch(self, rng):
        """grad_accum=2 must match grad_accum=1 (same global batch)."""
        cfg = get_smoke_config("qwen2-0.5b")
        key = jax.random.PRNGKey(2)
        params = T.init(cfg, key)
        adapters = init_lora(params, lora_targets(cfg), 4, 4.0, key)
        batch = _batch(cfg, rng)
        opt = OptimConfig(lr=1e-3)
        s1 = make_train_step(cfg, opt, remat=False, loss_chunk=16, grad_accum=1)
        s2 = make_train_step(cfg, opt, remat=False, loss_chunk=16, grad_accum=2)
        a1, _, _ = s1(params, adapters, adamw_init(adapters), batch)
        a2, _, _ = s2(params, adapters, adamw_init(adapters), batch)
        diff = max(jax.tree.leaves(jax.tree.map(
            lambda x, y: float(jnp.abs(x - y).max()), a1, a2)))
        assert diff < 1e-4


@pytest.mark.slow
class TestDecodeConsistency:
    """Token-by-token decode ≡ full forward — end-to-end serving-path
    checks (sequential decode loops, compile-heavy): slow tier."""
    @pytest.mark.parametrize("arch", ["qwen3-4b", "qwen2-0.5b", "rwkv6-1.6b",
                                      "zamba2-1.2b", "deepseek-v3-671b",
                                      "musicgen-medium"])
    def test_decode_matches_forward(self, arch, rng):
        cfg = get_smoke_config(arch)
        if cfg.num_experts:
            cfg = cfg.replace(moe_capacity_factor=8.0)   # disable cap drops
        key = jax.random.PRNGKey(1)
        params = T.init(cfg, key)
        adapters = init_lora(params, lora_targets(cfg), 4, 8.0, key, sigma=0.05)
        adapters = jax.tree.map(lambda x: x + 0.01 if x.ndim >= 2 else x, adapters)
        toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, 16)))
        hidden, _ = T.forward(cfg, params, {"tokens": toks}, adapters)
        full = T.logits(cfg, params, hidden)
        cache = T.init_cache(cfg, B, capacity=16, kv_dtype=jnp.float32)
        outs = []
        for t in range(16):
            lg, cache = T.decode(cfg, params, cache,
                                 {"tokens": toks[:, t:t + 1]}, adapters)
            outs.append(lg[:, 0])
        dec = jnp.stack(outs, 1)
        rel = float(jnp.max(jnp.abs(dec - full))) / float(jnp.max(jnp.abs(full)))
        assert rel < 2e-4

    def test_sliding_window_decode_matches_windowed_forward(self, rng):
        cfg = get_smoke_config("qwen3-4b").replace(sliding_window=8)
        key = jax.random.PRNGKey(3)
        params = T.init(cfg, key)
        toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, 24)))
        hidden, _ = T.forward(cfg, params, {"tokens": toks})
        full = T.logits(cfg, params, hidden)
        cache = T.init_cache(cfg, B, capacity=24, kv_dtype=jnp.float32)
        assert cache[0]["k"].shape[2] == 8   # ring buffer is window-sized
        outs = []
        for t in range(24):
            lg, cache = T.decode(cfg, params, cache,
                                 {"tokens": toks[:, t:t + 1]})
            outs.append(lg[:, 0])
        dec = jnp.stack(outs, 1)
        rel = float(jnp.max(jnp.abs(dec - full))) / float(jnp.max(jnp.abs(full)))
        assert rel < 2e-4

    def test_int8_cache_close_to_fp(self, rng):
        cfg = get_smoke_config("qwen2.5-14b")
        key = jax.random.PRNGKey(4)
        params = T.init(cfg, key)
        toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, 16)))
        caches = {dt: T.init_cache(cfg, B, 16, kv_dtype=dt)
                  for dt in (jnp.float32, jnp.int8)}
        outs = {}
        for dt, cache in caches.items():
            o = []
            for t in range(16):
                lg, cache = T.decode(cfg, params, cache,
                                     {"tokens": toks[:, t:t + 1]})
                o.append(lg[:, 0])
            outs[dt] = jnp.stack(o, 1)
        rel = (float(jnp.max(jnp.abs(outs[jnp.int8] - outs[jnp.float32])))
               / float(jnp.max(jnp.abs(outs[jnp.float32]))))
        assert rel < 0.05   # int8 absmax quantization error bound


class TestConfigs:
    def test_assigned_configs_match_assignment(self):
        """The exact dims from the assignment block."""
        expect = {
            "phi-3-vision-4.2b": (32, 3072, 32, 32, 8192, 32064),
            "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
            "rwkv6-1.6b": (24, 2048, 32, 32, 7168, 65536),
            "qwen1.5-32b": (64, 5120, 40, 40, 27392, 152064),
            "granite-moe-1b-a400m": (24, 1024, 16, 8, 512, 49155),
            "qwen3-4b": (36, 2560, 32, 8, 9728, 151936),
            "qwen2.5-14b": (48, 5120, 40, 8, 13824, 152064),
            "qwen2-0.5b": (24, 896, 14, 2, 4864, 151936),
            "deepseek-v3-671b": (61, 7168, 128, 128, 2048, 129280),
            "musicgen-medium": (48, 1536, 24, 24, 6144, 2048),
        }
        for name, (L, d, H, K, ff, V) in expect.items():
            cfg = get_config(name)
            assert (cfg.num_layers, cfg.d_model, cfg.num_heads,
                    cfg.num_kv_heads, cfg.d_ff, cfg.vocab_size) == (L, d, H, K, ff, V), name
            assert cfg.source, f"{name} missing citation"

    def test_moe_configs(self):
        g = get_config("granite-moe-1b-a400m")
        assert (g.num_experts, g.experts_per_token) == (32, 8)
        d = get_config("deepseek-v3-671b")
        assert (d.num_experts, d.experts_per_token, d.num_shared_experts) == (256, 8, 1)
        assert d.use_mla and d.kv_lora_rank == 512
        z = get_config("zamba2-1.2b")
        assert z.ssm_state == 64

    def test_param_counts_in_expected_range(self):
        """Analytic param counts should land near the advertised sizes."""
        approx = {"qwen2-0.5b": (0.3e9, 0.7e9),
                  "tinyllama-1.1b": (0.9e9, 1.3e9),
                  "qwen2.5-14b": (12e9, 16e9),
                  "qwen1.5-32b": (28e9, 36e9),
                  "deepseek-v3-671b": (600e9, 720e9),
                  "granite-moe-1b-a400m": (0.8e9, 1.6e9)}
        for name, (lo, hi) in approx.items():
            n = get_config(name).param_count()
            assert lo <= n <= hi, (name, n)

    def test_active_params_moe(self):
        d = get_config("deepseek-v3-671b")
        assert d.active_param_count() < 0.1 * d.param_count()

    def test_smoke_configs_reduced(self):
        for a in ASSIGNED:
            c = get_smoke_config(a)
            assert c.num_layers <= 4 and c.d_model <= 512
            assert c.num_experts <= 4
