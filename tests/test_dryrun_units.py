"""Unit tests for dry-run helpers that don't need 512 devices."""
import os

import jax
import pytest

# dryrun sets XLA_FLAGS (512 host devices) at import.  This module is
# imported during pytest *collection*, i.e. before the JAX backend
# initializes — restore the env immediately so the rest of the suite keeps
# seeing the single real device.
_prev_flags = os.environ.get("XLA_FLAGS")
from repro.launch import dryrun as D  # noqa: E402

if _prev_flags is None:
    os.environ.pop("XLA_FLAGS", None)
else:
    os.environ["XLA_FLAGS"] = _prev_flags
from repro.common.config import INPUT_SHAPES
from repro.configs import get_config


class TestCollectiveParser:
    HLO = """
  %ar = bf16[16,4096,896]{2,1,0} all-reduce(%x), replica_groups={}
  %ag.1 = f32[32,128]{1,0} all-gather(%y), dimensions={0}
  ROOT %a2a = (f32[2,4]{1,0}, f32[2,4]{1,0}) all-to-all(%z, %w), channel_id=3
  %rs = bf16[8,8]{1,0} reduce-scatter(%q), dimensions={0}
  %cp = u8[100]{0} collective-permute(%p), source_target_pairs={{0,1}}
  %notacoll = f32[7]{0} add(%a, %b)
  %fused_all-reduce_like = f32[9]{0} fusion(%c), kind=kLoop
"""

    def test_counts_each_type(self):
        out = D.collective_bytes(self.HLO)
        assert out["all-reduce"] == 16 * 4096 * 896 * 2
        assert out["all-gather"] == 32 * 128 * 4
        assert out["all-to-all"] == 2 * (2 * 4 * 4)
        assert out["reduce-scatter"] == 8 * 8 * 2
        assert out["collective-permute"] == 100

    def test_ignores_non_collectives(self):
        out = D.collective_bytes("%x = f32[4]{0} add(%a, %b)")
        assert sum(out.values()) == 0


class TestShapeBytes:
    @pytest.mark.parametrize("ty,expect", [
        ("bf16[10,10]", 200),
        ("f32[2,3,4]", 96),
        ("s8[1024]", 1024),
        ("(f32[2]{0}, bf16[4]{0})", 16),
        ("pred[8]", 8),
    ])
    def test_sizes(self, ty, expect):
        assert D._shape_bytes(ty) == expect


class TestHelpers:
    def test_reduced_pair_dense(self):
        cfg = get_config("qwen3-4b")
        c1, c2, l1, l2 = D._reduced_pair(cfg)
        assert (c1.num_layers, c2.num_layers) == (2, 4)

    def test_reduced_pair_hybrid_respects_attn_every(self):
        cfg = get_config("zamba2-1.2b")
        c1, c2, l1, l2 = D._reduced_pair(cfg)
        assert l1 == cfg.attn_every and l2 == 2 * cfg.attn_every

    def test_reduced_pair_deepseek_keeps_one_dense(self):
        cfg = get_config("deepseek-v3-671b")
        c1, c2, _, _ = D._reduced_pair(cfg)
        assert c1.first_dense_layers == 1 and c2.first_dense_layers == 1

    def test_kv_dtype_policy(self):
        assert D.pick_kv_dtype(get_config("qwen1.5-32b"),
                               INPUT_SHAPES["decode_32k"]) == "int8"
        assert D.pick_kv_dtype(get_config("qwen3-4b"),
                               INPUT_SHAPES["decode_32k"]) == "bfloat16"
        assert D.pick_kv_dtype(get_config("qwen1.5-32b"),
                               INPUT_SHAPES["train_4k"]) == "bfloat16"

    def test_long_context_variant(self):
        from repro.configs import long_context_variant
        dense = long_context_variant(get_config("qwen3-4b"))
        assert dense.sliding_window == 8192
        ssm = long_context_variant(get_config("rwkv6-1.6b"))
        assert ssm.sliding_window == 0   # native sub-quadratic
