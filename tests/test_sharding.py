"""Partition rules + distributed paths on a small host mesh."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.common.config import INPUT_SHAPES, LoRAConfig
from repro.configs import get_config, get_smoke_config, lora_targets
from repro.topology import (batch_pspecs, cache_pspecs,
                            make_production_mesh, params_pspecs)
from repro.launch.specs import cache_specs, input_specs, state_specs
from repro.models import transformer as T


@pytest.fixture(scope="module")
def mesh512():
    """Production mesh needs 512 devices — only valid inside dryrun.py.
    Here we only test the *pspec rules*, which need a Mesh object's axis
    sizes, so build a light stand-in via mock axis sizing."""
    return None


class TestPspecRules:
    def _mesh(self):
        # single-device mesh with production axis names (axis size 1 → every
        # axis 'fits'); rule structure is what we verify
        return jax.make_mesh((1, 1), ("data", "model"))

    def test_params_specs_structure(self):
        mesh = self._mesh()
        cfg = get_smoke_config("qwen3-4b")
        params = jax.eval_shape(lambda k: T.init(cfg, k), jax.random.PRNGKey(0))
        specs = params_pspecs(mesh, cfg, params)
        blk = specs["blocks"][0]
        assert blk["attn"]["wq"] == P(None, None, "model")
        assert blk["attn"]["wo"] == P(None, "model", None)
        assert blk["mlp"]["w_gate"] == P(None, None, "model")
        assert blk["mlp"]["w_down"] == P(None, "model", None)
        assert specs["embed"] == P("model", None)
        # norms replicated
        assert blk["ln1"] == P(None, None)

    def test_moe_expert_parallel_spec(self):
        mesh = self._mesh()
        cfg = get_smoke_config("granite-moe-1b-a400m")
        params = jax.eval_shape(lambda k: T.init(cfg, k), jax.random.PRNGKey(0))
        specs = params_pspecs(mesh, cfg, params)
        wg = specs["blocks"][0]["moe"]["w_gate"]
        # (L, E, d, ff): expert dim sharded
        assert wg[1] in ("model", ("data", "model"))

    def test_nondivisible_axes_dropped(self):
        """49155-vocab (granite) must not be vocab-sharded on a 16-wide axis."""
        try:
            mesh = make_production_mesh()   # needs 256 devices
        except Exception:
            pytest.skip("production mesh needs 256 host devices (dryrun only)")
        cfg = get_config("granite-moe-1b-a400m")
        params = jax.eval_shape(lambda k: T.init(cfg, k), jax.random.PRNGKey(0))
        specs = params_pspecs(mesh, cfg, params)
        assert specs["embed"] == P(None, None)

    def test_batch_specs(self):
        mesh = self._mesh()
        cfg = get_smoke_config("qwen2-0.5b")
        batch = input_specs(cfg, INPUT_SHAPES["train_4k"])
        specs = batch_pspecs(mesh, cfg, batch)
        assert specs["tokens"][0] == "data"

    def test_cache_specs_shard_batch_and_seq(self):
        mesh = self._mesh()
        cfg = get_smoke_config("qwen2-0.5b")
        cache = cache_specs(cfg, INPUT_SHAPES["decode_32k"], jnp.bfloat16)
        specs = cache_pspecs(mesh, cfg, cache)
        k_spec = specs[0]["k"]
        assert k_spec[1] == "data"       # batch after layer-stack axis
        assert k_spec[2] == "model"      # cache sequence


pytestmark_skip_one_dev = pytest.mark.skipif(
    len(jax.devices()) < 2, reason="needs >1 device")


class TestDistributedAggregation:
    def test_sharded_florist_matches_host(self, rng):
        if len(jax.devices()) < 2:
            pytest.skip("single device")
        from repro.core.distributed import make_sharded_florist
        from repro.core.svd import florist_core_padded
        ndev = min(len(jax.devices()), 8)
        mesh = jax.make_mesh((1, ndev), ("data", "model"),
                             devices=jax.devices()[:ndev])
        L, m, n, r = 8, 32, 24, 12
        B = jnp.asarray(rng.normal(size=(L, m, r)), jnp.float32)
        A = jnp.asarray(rng.normal(size=(L, r, n)), jnp.float32)
        fn = make_sharded_florist(mesh, tau=0.9, svd_method="gram")
        bg, ag, sp, p = fn(B, A)
        for l in range(L):
            bg_h, ag_h, sp_h, p_h = florist_core_padded(B[l], A[l], 0.9, "gram")
            np.testing.assert_allclose(np.asarray(bg[l] @ ag[l]),
                                       np.asarray(bg_h @ ag_h),
                                       rtol=5e-3, atol=5e-3)
