"""Federated round runtime: legacy-loop equivalence, cohort parity,
scheduler determinism, measured wire transport."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.config import FedConfig, LoRAConfig, ModelConfig, OptimConfig
from repro.core.aggregators import (AggResult, Aggregator, adapter_leaf_paths,
                                    fold_scale, get_path, set_path)
from repro.core.federated import FederatedTrainer
from repro.core.runtime import make_codec
from repro.core.runtime.transport import AdapterPayload
from repro.optim.adamw import adamw_init

CFG = ModelConfig(name="rt-tiny", family="dense", num_layers=2, d_model=64,
                  num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
                  vocab_size=256, dtype="float32")
LORA = LoRAConfig(rank=8, alpha=8.0)
OPT = OptimConfig(lr=3e-3)


def make_trainer(method, heter=False, **kw):
    fed = FedConfig(num_clients=12, clients_per_round=4, method=method,
                    tau=0.9, homogeneous_rank=8, heterogeneous=heter,
                    rank_distribution=((4, 4), (8, 4), (16, 4)),
                    zero_padding=heter, seed=0)
    kw.setdefault("local_steps", 2)
    return FederatedTrainer(CFG, fed, LORA, OPT, batch_size=8, seq_len=32,
                            **kw)


# ---------------------------------------------------------------------------
# the pre-redesign run_round, verbatim, as the equivalence oracle
# ---------------------------------------------------------------------------


def legacy_run_round(self, rnd):
    """The pre-runtime ``FederatedTrainer.run_round`` body (hard-coded
    synchronous loop, no wire), kept as the bit-for-bit oracle."""
    from repro.core.federated import RoundRecord
    from repro.peft.lora import merge_lora

    fed = self.fed
    sampled = list(self.rng.choice(fed.num_clients, fed.clients_per_round,
                                   replace=False))
    n_total = sum(self.clients[k].num_samples for k in sampled)
    ranks = [self.client_ranks[k] for k in sampled]
    self.aggregator.begin_round()
    for k in sampled:
        rk = self.client_ranks[k]
        adapters = self._client_init(k)
        init_adapters = adapters
        opt_state = adamw_init(adapters)
        step = self._train_step()
        data = self.clients[k]
        brng = np.random.default_rng(1000 * rnd + k)
        steps_done = 0
        while steps_done < self.local_steps:
            for batch in data.batches(min(self.batch_size, data.num_samples),
                                      brng):
                jb = {kk: jnp.asarray(v) for kk, v in batch.items()}
                adapters, opt_state, _ = step(self.params, adapters,
                                              opt_state, jb)
                steps_done += 1
                if steps_done >= self.local_steps:
                    break
        if self.dp_clip:
            from repro.core.privacy import clip_client_adapters
            adapters = clip_client_adapters(adapters, init_adapters,
                                            self.dp_clip)
        self.aggregator.add_client(
            adapters, self.clients[k].num_samples / n_total, rank=rk)

    agg = self.aggregator.finalize()
    if self.dp_sigma and agg.global_adapters is not None:
        from repro.core.privacy import add_gaussian_noise
        key = jax.random.PRNGKey(10_000 + rnd)
        agg.global_adapters = add_gaussian_noise(
            agg.global_adapters, self.dp_sigma, self.dp_clip or 1.0,
            fed.clients_per_round, key)
    dims = self.aggregator.dims
    up = self.aggregator.round_upload_params
    down = self.aggregator.download_params(agg, dims, fed.clients_per_round,
                                           ranks)
    if agg.merge_into_base:
        self.params = merge_lora(self.params, agg.global_adapters)
        eval_params = self.params
    else:
        eval_params = merge_lora(self.params, agg.global_adapters)
    self.global_state = agg
    m = self._eval(eval_params, None, self.eval_batch)
    rec = RoundRecord(
        round=rnd, eval_loss=float(m["loss"]), eval_acc=float(m["accuracy"]),
        upload_params=up, download_params=down,
        download_rank=agg.total_download_rank()
        * self.aggregator.download_rank_factor,
        global_rank_total=agg.total_download_rank())
    self.history.append(rec)
    return rec


def tree_arrays(tree):
    return {path: np.asarray(leaf)
            for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]}


def assert_trees_bitwise_equal(a, b):
    fa, fb = tree_arrays(a), tree_arrays(b)
    assert fa.keys() == fb.keys()
    for path in fa:
        np.testing.assert_array_equal(fa[path], fb[path], err_msg=str(path))


METHODS = ["florist", "fedit", "ffa", "flora", "flexlora"]


@pytest.mark.parametrize("method", METHODS)
def test_sync_sequential_bit_exact_vs_legacy(method):
    """The default runtime (sync scheduler + sequential runner + fp32 wire)
    reproduces the pre-redesign loop bit-for-bit, homogeneous ranks."""
    new, old = make_trainer(method), make_trainer(method)
    for rnd in range(2):
        rn = new.run_round(rnd)
        ro = legacy_run_round(old, rnd)
        assert rn.eval_loss == ro.eval_loss
        assert rn.eval_acc == ro.eval_acc
        assert rn.upload_params == ro.upload_params
        assert rn.download_params == ro.download_params
        assert rn.download_rank == ro.download_rank
        assert rn.global_rank_total == ro.global_rank_total
    assert_trees_bitwise_equal(new.global_state.global_adapters,
                               old.global_state.global_adapters)


@pytest.mark.parametrize("method", ["florist", "flexlora", "flora"])
def test_sync_sequential_bit_exact_vs_legacy_heterogeneous(method):
    new, old = make_trainer(method, heter=True), make_trainer(method,
                                                              heter=True)
    for rnd in range(2):
        rn = new.run_round(rnd)
        ro = legacy_run_round(old, rnd)
        assert rn.eval_loss == ro.eval_loss
        assert rn.download_params == ro.download_params
    assert_trees_bitwise_equal(new.global_state.global_adapters,
                               old.global_state.global_adapters)


# ---------------------------------------------------------------------------
# cohort runner parity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("heter", [False, True])
def test_cohort_matches_sequential(heter):
    seq = make_trainer("florist", heter=heter, runner="sequential")
    coh = make_trainer("florist", heter=heter, runner="cohort")
    for rnd in range(2):
        rs, rc = seq.run_round(rnd), coh.run_round(rnd)
        assert rc.eval_loss == pytest.approx(rs.eval_loss, abs=1e-4)
        assert rc.upload_params == rs.upload_params
    fa = tree_arrays(seq.global_state.global_adapters)
    fb = tree_arrays(coh.global_state.global_adapters)
    assert fa.keys() == fb.keys()
    for path in fa:
        np.testing.assert_allclose(fa[path], fb[path], atol=5e-4,
                                   err_msg=str(path))


def test_cohort_runs_all_methods():
    for method in METHODS:
        hist = make_trainer(method, runner="cohort").run(1)
        assert np.isfinite(hist[-1].eval_loss)


# ---------------------------------------------------------------------------
# schedulers
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("scheduler", ["partial", "async"])
def test_scheduler_deterministic_given_seed(scheduler):
    h1 = make_trainer("florist", scheduler=scheduler).run(3)
    h2 = make_trainer("florist", scheduler=scheduler).run(3)
    for a, b in zip(h1, h2):
        assert a.eval_loss == b.eval_loss
        assert a.upload_params == b.upload_params
        assert a.upload_bytes == b.upload_bytes


def test_partial_scheduler_budgets():
    """Dropouts shrink participation; stragglers shrink step budgets."""
    tr = make_trainer("florist", scheduler="partial", local_steps=8)
    plans = [tr.scheduler.plan(rnd, tr) for rnd in range(8)]
    sizes = [len(p.tasks) for p in plans]
    steps = [t.steps for p in plans for t in p.tasks]
    assert all(1 <= s <= tr.fed.clients_per_round for s in sizes)
    assert any(s < tr.fed.clients_per_round for s in sizes)  # dropouts hit
    assert any(st < 8 for st in steps)                       # stragglers hit
    assert all(st >= 1 for st in steps)
    for p in plans:
        assert sum(t.weight for t in p.tasks) == pytest.approx(1.0)


def test_async_scheduler_staleness_and_snapshots():
    tr = make_trainer("florist", scheduler="async")
    plans = [tr.scheduler.plan(rnd, tr) for rnd in range(6)]
    tasks = [t for p in plans for t in p.tasks]
    assert all(t.init_adapters is not None for t in tasks)
    assert any(t.staleness > 0 for t in tasks)
    for p in plans:
        assert p.tasks                                        # never empty
        assert sum(t.weight for t in p.tasks) == pytest.approx(1.0)


def test_async_end_to_end_trains():
    hist = make_trainer("florist", scheduler="async").run(3)
    assert all(np.isfinite(h.eval_loss) for h in hist)
    assert all(h.upload_bytes > 0 for h in hist)


# ---------------------------------------------------------------------------
# transport / codecs
# ---------------------------------------------------------------------------


def test_fp32_codec_roundtrip_exact():
    c = make_codec("fp32")
    assert c.bytes_per_param == 4
    x = np.random.default_rng(0).normal(size=(3, 5)).astype(np.float32)
    enc = c.encode(x)
    assert enc.num_bytes == c.bytes_per_param * x.size
    np.testing.assert_array_equal(c.decode(enc), x)


def test_bf16_codec_halves_bytes():
    c = make_codec("bf16")
    assert c.bytes_per_param == 2
    x = np.random.default_rng(0).normal(size=(4, 8)).astype(np.float32)
    enc = c.encode(x)
    assert enc.num_bytes == c.bytes_per_param * x.size
    np.testing.assert_allclose(c.decode(enc), x, rtol=1e-2)


def test_int8_codec_quantizes():
    c = make_codec("int8")
    x = np.random.default_rng(0).normal(size=(16, 16)).astype(np.float32)
    enc = c.encode(x)
    # payload at bytes_per_param + the fp32 scale header
    assert enc.num_bytes == c.bytes_per_param * x.size + 4
    np.testing.assert_allclose(c.decode(enc), x, atol=2 * np.abs(x).max() / 127)


def test_payload_ragged_ranks_skip_padding():
    """Per-layer ranks: only the first r_l columns travel, zero padding is
    reconstructed for free on the receiving side."""
    A = np.zeros((2, 4, 6), np.float32)
    B = np.zeros((2, 5, 4), np.float32)
    A[0, :2], A[1, :3] = 1.0, 2.0
    B[0, :, :2], B[1, :, :3] = 3.0, 4.0
    tree = {"leaf": {"A": A, "B": B, "scale": np.ones((2,), np.float32)}}
    codec = make_codec("fp32")
    payload = AdapterPayload.pack(tree, codec,
                                  ranks={("leaf",): [2, 3]})
    assert payload.num_bytes == 4 * (2 * 6 + 3 * 6 + 5 * 2 + 5 * 3)
    out = payload.unpack_into(tree, codec)
    np.testing.assert_array_equal(out["leaf"]["A"], A)
    np.testing.assert_array_equal(out["leaf"]["B"], B)


@pytest.mark.parametrize("method", METHODS)
def test_measured_bytes_match_analytic(method):
    """fp32 wire bytes are exactly 4 × the analytic parameter counts —
    the cross-check between costs.py and the measured transport."""
    hist = make_trainer(method).run(2)
    for rec in hist:
        assert rec.upload_bytes == 4 * rec.upload_params
        assert rec.download_bytes == 4 * rec.download_params
        assert rec.wall_secs > 0


def test_lossy_codec_still_trains():
    hist = make_trainer("florist", transport="int8").run(2)
    assert all(np.isfinite(h.eval_loss) for h in hist)
    assert hist[-1].upload_bytes < 4 * hist[-1].upload_params


# ---------------------------------------------------------------------------
# aggregator A_init contract (regression for the getattr probe)
# ---------------------------------------------------------------------------


class MeanAggregator(Aggregator):
    """Minimal custom strategy with no A_init attribute at all."""

    name = "custom-mean"

    def _accumulate(self, update, weight, rank):
        for path in adapter_leaf_paths(update):
            Bk, Ak = fold_scale(get_path(update, path))
            acc = self._state.get(path)
            if acc is None:
                self._state[path] = {"A": weight * Ak, "B": weight * Bk}
            else:
                acc["A"] = acc["A"] + weight * Ak
                acc["B"] = acc["B"] + weight * Bk

    def _finalize(self):
        out, rank_rec = {}, {}
        for path, acc in self._state.items():
            set_path(out, path, {"A": acc["A"], "B": acc["B"],
                                 "scale": self._ref_scales[path]})
            L = acc["A"].shape[0] if acc["A"].ndim == 3 else 1
            rank_rec[path] = [acc["A"].shape[-2]] * L
        return AggResult(self.name, out, None, rank_rec, {})


def test_custom_aggregator_without_a_init_runs():
    """A strategy that never heard of A_init must run untouched: the
    trainer keys the injection on the explicit ``needs_a_init`` flag
    instead of probing for an ``A_init`` attribute."""
    agg = MeanAggregator()
    tr = make_trainer("florist", aggregator=agg)
    hist = tr.run(2)
    assert all(np.isfinite(h.eval_loss) for h in hist)
    assert not hasattr(agg, "A_init")


def test_needs_a_init_flags():
    from repro.core.aggregators.ffa import FfaAggregator
    assert FfaAggregator.needs_a_init
    assert not Aggregator.needs_a_init
    # the trainer injects the shared init exactly for ffa
    tr = make_trainer("ffa")
    assert tr.aggregator.A_init is tr.A_init_full
