"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _arr(rng, shape, dtype, scale=1.0):
    x = rng.normal(size=shape) * scale
    return jnp.asarray(x, dtype)


class TestLoraMatmul:
    @pytest.mark.parametrize("M,din,dout,r", [
        (64, 64, 64, 4), (128, 192, 160, 8), (100, 96, 224, 16), (256, 128, 128, 32),
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, rng, M, din, dout, r, dtype):
        x = _arr(rng, (M, din), dtype)
        w = _arr(rng, (din, dout), dtype, 0.1)
        a = _arr(rng, (r, din), dtype, 0.1)
        b = _arr(rng, (dout, r), dtype, 0.1)
        y = ops.lora_matmul(x, w, a, b, 0.5, bm=64, bn=64)
        yr = ref.lora_matmul_ref(x, w, a, b, jnp.asarray(0.5, dtype))
        tol = 2e-5 if dtype == jnp.float32 else 3e-2
        np.testing.assert_allclose(np.asarray(y, np.float32),
                                   np.asarray(yr, np.float32),
                                   rtol=tol, atol=tol * 10)

    def test_batched_input(self, rng):
        x = _arr(rng, (2, 50, 64), jnp.float32)
        w = _arr(rng, (64, 96), jnp.float32, 0.1)
        a = _arr(rng, (4, 64), jnp.float32, 0.1)
        b = _arr(rng, (96, 4), jnp.float32, 0.1)
        y = ops.lora_matmul(x, w, a, b, 2.0, bm=32, bn=32)
        yr = ref.lora_matmul_ref(x.reshape(-1, 64), w, a, b, 2.0).reshape(2, 50, 96)
        np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=1e-5, atol=1e-4)

    def test_grad_matches_ref(self, rng):
        """custom_vjp: kernel forward, reference-math backward — gradients
        w.r.t. every operand (including scale) match the pure-jnp path."""
        x = _arr(rng, (50, 64), jnp.float32)
        w = _arr(rng, (64, 96), jnp.float32, 0.1)
        a = _arr(rng, (8, 64), jnp.float32, 0.1)
        b = _arr(rng, (96, 8), jnp.float32, 0.1)
        sc = jnp.asarray(0.5)
        co = _arr(rng, (50, 96), jnp.float32)     # non-trivial cotangent
        gk = jax.grad(lambda *t: (ops.lora_matmul(*t, bm=32, bn=32) * co).sum(),
                      argnums=(0, 1, 2, 3, 4))(x, w, a, b, sc)
        gr = jax.grad(lambda *t: (ref.lora_matmul_ref(*t) * co).sum(),
                      argnums=(0, 1, 2, 3, 4))(x, w, a, b, sc)
        for i, (p, q) in enumerate(zip(gk, gr)):
            np.testing.assert_allclose(np.asarray(p), np.asarray(q),
                                       rtol=1e-5, atol=1e-4, err_msg=f"arg{i}")

    def test_train_step_grad_parity(self, rng):
        """A full LoRA train step with the fused kernel routed through
        ``lora_proj`` produces the same adapter update as the reference
        path — ``use_kernels=True`` training differentiates correctly."""
        from repro.configs import get_smoke_config, lora_targets
        from repro.models import transformer as T
        from repro.peft import lora
        from repro.peft.lora import init_lora
        from repro.common.config import OptimConfig
        from repro.optim.adamw import adamw_init
        from repro.train.step import make_train_step

        cfg = get_smoke_config("qwen2-0.5b")
        params = T.init(cfg, jax.random.PRNGKey(0))
        adapters = init_lora(params, lora_targets(cfg), 4, 8.0,
                             jax.random.PRNGKey(1))
        batch = {"tokens": jnp.asarray(rng.integers(1, cfg.vocab_size,
                                                    (2, 32)))}
        step = make_train_step(cfg, OptimConfig(lr=1e-2), remat=False)
        outs = {}
        for use_kernel in (False, True):
            old = lora.USE_KERNEL
            lora.USE_KERNEL = use_kernel
            try:
                new_a, _, m = step(params, adapters, adamw_init(adapters),
                                   batch)
            finally:
                lora.USE_KERNEL = old
            outs[use_kernel] = (new_a, float(m["loss"]))
        assert outs[True][1] == pytest.approx(outs[False][1], rel=1e-5)
        for p, q in zip(jax.tree.leaves(outs[True][0]),
                        jax.tree.leaves(outs[False][0])):
            np.testing.assert_allclose(np.asarray(p), np.asarray(q),
                                       rtol=1e-4, atol=1e-5)


class TestFlashAttention:
    @pytest.mark.parametrize("S,H,K,hd", [
        (128, 4, 4, 32),     # MHA
        (256, 8, 2, 64),     # GQA 4x
        (128, 8, 1, 32),     # MQA
    ])
    def test_causal(self, rng, S, H, K, hd):
        q = _arr(rng, (2, S, H, hd), jnp.float32)
        k = _arr(rng, (2, S, K, hd), jnp.float32)
        v = _arr(rng, (2, S, K, hd), jnp.float32)
        o = ops.flash_attention(q, k, v, causal=True, bq=64, bk=64)
        orf = ref.flash_attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(o), np.asarray(orf),
                                   rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize("window", [32, 96, 128])
    def test_sliding_window(self, rng, window):
        q = _arr(rng, (1, 256, 4, 32), jnp.float32)
        k = _arr(rng, (1, 256, 4, 32), jnp.float32)
        v = _arr(rng, (1, 256, 4, 32), jnp.float32)
        o = ops.flash_attention(q, k, v, causal=True, window=window, bq=64, bk=64)
        orf = ref.flash_attention_ref(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(np.asarray(o), np.asarray(orf),
                                   rtol=1e-5, atol=1e-5)

    def test_bf16(self, rng):
        q = _arr(rng, (1, 128, 4, 64), jnp.bfloat16)
        k = _arr(rng, (1, 128, 2, 64), jnp.bfloat16)
        v = _arr(rng, (1, 128, 2, 64), jnp.bfloat16)
        o = ops.flash_attention(q, k, v, bq=64, bk=64)
        orf = ref.flash_attention_ref(q, k, v)
        np.testing.assert_allclose(np.asarray(o, np.float32),
                                   np.asarray(orf, np.float32),
                                   rtol=3e-2, atol=3e-2)

    @pytest.mark.parametrize("S,window", [(100, 0), (300, 0), (300, 50)])
    def test_odd_lengths_run_kernel_not_fallback(self, rng, monkeypatch,
                                                 S, window):
        """S/T not block multiples: the wrapper pads to block multiples and
        masks the padded KV columns in-kernel — the KERNEL runs (the old
        silent reference fallback is gone; a poisoned ref proves it)."""
        q = _arr(rng, (2, S, 4, 32), jnp.float32)
        k = _arr(rng, (2, S, 2, 32), jnp.float32)
        v = _arr(rng, (2, S, 2, 32), jnp.float32)
        orf = ref.flash_attention_ref(q, k, v, causal=True, window=window)

        def boom(*a, **kw):
            raise AssertionError("fell back to the reference path")
        monkeypatch.setattr(ops.ref, "flash_attention_ref", boom)
        o = ops.flash_attention(q, k, v, causal=True, window=window,
                                bq=128, bk=128)
        assert o.shape == q.shape
        np.testing.assert_allclose(np.asarray(o), np.asarray(orf),
                                   rtol=1e-5, atol=1e-5)

    def test_grad_flows_through_kernel(self, rng):
        """custom_vjp (reference-math backward) lets use_kernels training
        differentiate through the attention kernel."""
        q = _arr(rng, (1, 64, 4, 16), jnp.float32)
        k = _arr(rng, (1, 64, 2, 16), jnp.float32)
        v = _arr(rng, (1, 64, 2, 16), jnp.float32)
        gk = jax.grad(lambda q_: ops.flash_attention(q_, k, v, bq=32,
                                                     bk=32).sum())(q)
        gr = jax.grad(lambda q_: ref.flash_attention_ref(q_, k, v).sum())(q)
        np.testing.assert_allclose(np.asarray(gk), np.asarray(gr),
                                   rtol=1e-5, atol=1e-5)


class TestWkv6:
    @pytest.mark.parametrize("S,H,hd,chunk", [
        (64, 2, 16, 32), (128, 4, 32, 64), (96, 1, 16, 32),
    ])
    def test_matches_scan(self, rng, S, H, hd, chunk):
        r = _arr(rng, (2, S, H, hd), jnp.float32)
        k = _arr(rng, (2, S, H, hd), jnp.float32)
        v = _arr(rng, (2, S, H, hd), jnp.float32)
        w = -jnp.exp(_arr(rng, (2, S, H, hd), jnp.float32))
        u = _arr(rng, (H, hd), jnp.float32)
        y = ops.wkv6(r, k, v, w, u, chunk=chunk)
        yr = ref.wkv6_ref(r, k, v, w, u)
        np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                                   rtol=1e-5, atol=1e-5)

    def test_state_persists_across_chunks(self, rng):
        """Chunked and unchunked must agree exactly — the VMEM state scratch
        carries across sequential grid steps."""
        args = [_arr(rng, (1, 64, 2, 16), jnp.float32) for _ in range(3)]
        w = -jnp.exp(_arr(rng, (1, 64, 2, 16), jnp.float32))
        u = _arr(rng, (2, 16), jnp.float32)
        y1 = ops.wkv6(*args, w, u, chunk=64)
        y2 = ops.wkv6(*args, w, u, chunk=16)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                                   rtol=1e-6, atol=1e-6)


class TestAdapterGram:
    @pytest.mark.parametrize("m,r", [(256, 16), (1000, 48), (512, 160)])
    def test_matches_ref(self, rng, m, r):
        x = _arr(rng, (m, r), jnp.float32)
        g = ops.adapter_gram(x, bm=128)
        gr = ref.adapter_gram_ref(x)
        np.testing.assert_allclose(np.asarray(g), np.asarray(gr),
                                   rtol=1e-4, atol=1e-3)

    @pytest.mark.parametrize("m", [100, 129, 257])
    def test_tail_panel_masked(self, rng, m):
        """m not a multiple of bm: the kernel masks the tail panel instead
        of requiring a host-side padding copy."""
        x = _arr(rng, (m, 24), jnp.float32)
        g = ops.adapter_gram(x, bm=128)
        gr = ref.adapter_gram_ref(x)
        np.testing.assert_allclose(np.asarray(g), np.asarray(gr),
                                   rtol=1e-4, atol=1e-3)

    def test_bf16_input_fp32_accum(self, rng):
        x = _arr(rng, (512, 32), jnp.bfloat16)
        g = ops.adapter_gram(x, bm=128)
        assert g.dtype == jnp.float32
        gr = ref.adapter_gram_ref(x)
        np.testing.assert_allclose(np.asarray(g), np.asarray(gr),
                                   rtol=2e-2, atol=2e-1)


class TestFlashJax:
    """The XLA-flash lowering path used by every dry-run."""

    def test_matches_ref_gqa(self, rng):
        from repro.models.attention_core import flash_jax
        q = _arr(rng, (2, 256, 8, 32), jnp.float32)
        k = _arr(rng, (2, 256, 2, 32), jnp.float32)
        v = _arr(rng, (2, 256, 2, 32), jnp.float32)
        o = flash_jax(q, k, v, causal=True, q_chunk=64, kv_chunk=64)
        orf = ref.flash_attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(o), np.asarray(orf),
                                   rtol=1e-5, atol=1e-5)

    def test_grad_flows(self, rng):
        from repro.models.attention_core import flash_jax
        q = _arr(rng, (1, 64, 2, 16), jnp.float32)
        k = _arr(rng, (1, 64, 2, 16), jnp.float32)
        v = _arr(rng, (1, 64, 2, 16), jnp.float32)
        g = jax.grad(lambda q_: flash_jax(q_, k, v, q_chunk=32, kv_chunk=32).sum())(q)
        assert np.isfinite(np.asarray(g)).all()
        assert float(jnp.abs(g).max()) > 0
