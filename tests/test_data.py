"""Synthetic corpus + Dirichlet federated partitioning."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.data.synthetic import (BOS, SEP, dirichlet_partition, make_eval_data,
                                  make_federated_data)


class TestPartition:
    @given(st.integers(2, 30), st.integers(2, 10),
           st.floats(0.05, 10.0))
    @settings(max_examples=30, deadline=None)
    def test_mixtures_are_distributions(self, clients, tasks, alpha):
        rng = np.random.default_rng(0)
        mix = dirichlet_partition(clients, tasks, alpha, rng)
        assert mix.shape == (clients, tasks)
        np.testing.assert_allclose(mix.sum(1), 1.0, atol=1e-9)
        assert (mix >= 0).all()

    def test_low_alpha_more_skewed(self):
        rng = np.random.default_rng(0)
        sharp = dirichlet_partition(200, 8, 0.1, rng).max(1).mean()
        rng = np.random.default_rng(0)
        flat = dirichlet_partition(200, 8, 100.0, rng).max(1).mean()
        assert sharp > flat  # non-IID skew increases as alpha drops


class TestCorpus:
    def test_shapes_and_structure(self):
        data = make_federated_data(num_clients=5, mean_samples=8, seq_len=64,
                                   vocab=256, seed=1)
        assert len(data) == 5
        for c in data:
            assert c.tokens.shape[1] == 64
            assert c.tokens[:, 0].tolist() == [BOS] * c.num_samples
            assert (c.tokens == SEP).any(axis=1).all()
            # loss only on response region
            assert (c.loss_mask.sum(1) > 0).all()

    def test_deterministic(self):
        a = make_federated_data(num_clients=3, seed=7)
        b = make_federated_data(num_clients=3, seed=7)
        np.testing.assert_array_equal(a[0].tokens, b[0].tokens)

    def test_task_is_learnable_mapping(self):
        """Same instruction token under same task -> same response token."""
        data = make_eval_data(num_samples=64, seq_len=32, vocab=128,
                              num_tasks=1, seed=3)
        toks = data["tokens"]
        m = (32 - 3) // 2
        instr = toks[:, 1: 1 + m]
        resp = toks[:, 2 + m: 2 + 2 * m]
        # deterministic affine map for task 0: resp = (instr*1 + 3) mod 124 + 4
        expect = (instr * 1 + 3) % (128 - 4) + 4
        np.testing.assert_array_equal(resp, expect)

    def test_batches_cover_dataset(self):
        data = make_federated_data(num_clients=1, mean_samples=20, seed=0)[0]
        rng = np.random.default_rng(0)
        seen = sum(b["tokens"].shape[0] for b in data.batches(4, rng))
        assert seen >= data.num_samples - 4
