"""Fault-tolerant federated rounds: deterministic fault injection,
checksummed/retrying transport, the validation/quarantine gate, and
crash-exact checkpoint/resume."""
import dataclasses
import os

import jax
import numpy as np
import pytest

from repro.checkpoint import io as ckpt_io
from repro.common.config import FedConfig, LoRAConfig, ModelConfig, OptimConfig
from repro.core.aggregators import (adapter_leaf_paths, fold_scale, get_path,
                                    make_aggregator)
from repro.core.federated import FederatedTrainer
from repro.core.runtime import (DeadClientError, FaultPlan, PayloadCorrupted,
                                PayloadError, ServerCrash, Transport,
                                ValidationGate, make_codec)
from repro.core.runtime.transport import AdapterPayload

CFG = ModelConfig(name="ft-tiny", family="dense", num_layers=2, d_model=64,
                  num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
                  vocab_size=256, dtype="float32")
LORA = LoRAConfig(rank=8, alpha=8.0)
OPT = OptimConfig(lr=3e-3)


def make_trainer(method="florist", **kw):
    fed = FedConfig(num_clients=12, clients_per_round=4, method=method,
                    tau=0.9, homogeneous_rank=8, seed=0)
    kw.setdefault("local_steps", 1)
    kw.setdefault("batch_size", 4)
    kw.setdefault("seq_len", 16)
    return FederatedTrainer(CFG, fed, LORA, OPT, **kw)


def adapter_products(tree):
    out = {}
    for path in adapter_leaf_paths(tree):
        B, A = fold_scale(get_path(tree, path))
        out[path] = np.asarray(B, np.float64) @ np.asarray(A, np.float64)
    return out


def rand_client_tree(rng, L=2, m=32, n=24, r=4, b_scale=1.0):
    return {"blk": {"A": rng.normal(size=(L, r, n)).astype(np.float32),
                    "B": (b_scale * rng.normal(size=(L, m, r))
                          ).astype(np.float32),
                    "scale": np.ones((L,), np.float32)}}


class _RecAgg:
    """Minimal aggregator stand-in recording every fold."""

    def __init__(self):
        self.calls = []

    def add_client(self, update, weight, rank=None):
        self.calls.append((update, float(weight), rank))


# ---------------------------------------------------------------------------
# FaultPlan: pure function of (seed, round, client)
# ---------------------------------------------------------------------------


def test_fault_plan_is_pure_and_deterministic():
    mk = lambda: FaultPlan(seed=5, drop=0.2, duplicate=0.1, corrupt=0.2,
                           nan=0.1, scale=0.1, slow=0.1)
    p1, p2 = mk(), mk()
    for rnd in range(4):
        for cid in range(30):
            f1, f2 = p1.client_fault(rnd, cid), p2.client_fault(rnd, cid)
            assert f1 == f2
            # re-querying never changes the answer (no mutable state)
            assert p1.client_fault(rnd, cid) == f1
    kinds = {p1.client_fault(r, c).kind for r in range(4) for c in range(30)}
    assert {"drop", "corrupt", None} <= kinds       # taxonomy actually fires
    # fault assignments vary by round for a fixed client
    assert len({p1.client_fault(r, 3).kind for r in range(20)}) > 1


def test_fault_plan_validates_rates_and_crash_points():
    with pytest.raises(ValueError):
        FaultPlan(drop=0.8, corrupt=0.5)             # sums > 1
    with pytest.raises(ValueError):
        FaultPlan(drop=-0.1)
    with pytest.raises(ValueError):
        FaultPlan(crashes=((0, "nonsense"),))
    plan = FaultPlan(seed=1, crashes=((2, "mid_round"),))
    assert plan.should_crash(2, "mid_round")
    assert not plan.should_crash(2, "begin")
    assert not plan.without_crashes().should_crash(2, "mid_round")
    # clearing crashes must not change the client-fault assignment
    faulty = FaultPlan(seed=1, drop=0.5, crashes=((2, "mid_round"),))
    clone = faulty.without_crashes()
    for cid in range(20):
        assert faulty.client_fault(0, cid) == clone.client_fault(0, cid)


# ---------------------------------------------------------------------------
# transport hardening: checksums, structural validation, retry
# ---------------------------------------------------------------------------


def test_checksum_catches_bit_flip():
    tree = rand_client_tree(np.random.default_rng(0))
    codec = make_codec("fp32")
    payload = AdapterPayload.pack(tree, codec)
    plan = FaultPlan(seed=0, corrupt=1.0)
    bad = plan.corrupt_payload(payload, 0, 0, attempt=0)
    with pytest.raises(PayloadCorrupted):
        bad.unpack_into(tree, codec)
    # the pristine payload still verifies and round-trips bit-exactly
    out = payload.unpack_into(tree, codec)
    np.testing.assert_array_equal(out["blk"]["A"], tree["blk"]["A"])


def test_checksum_excluded_from_wire_bytes():
    tree = rand_client_tree(np.random.default_rng(1))
    codec = make_codec("fp32")
    with_crc = AdapterPayload.pack(tree, codec, checksum=True)
    without = AdapterPayload.pack(tree, codec, checksum=False)
    assert with_crc.num_bytes == without.num_bytes   # integrity is out-of-band


def test_unpack_rejects_wrong_shape_block():
    tree = rand_client_tree(np.random.default_rng(2))
    codec = make_codec("fp32")
    payload = AdapterPayload.pack(tree, codec)
    enc = payload.blocks[("blk",)]["A"][0]
    # truncated bytes with a matching (stale) checksum: structural error
    import zlib
    cut = enc.data[:-8]
    payload.blocks[("blk",)]["A"][0] = dataclasses.replace(
        enc, data=cut, crc=zlib.crc32(cut))
    with pytest.raises(PayloadError):
        payload.unpack_into(tree, codec)


def test_unpack_rejects_bad_ragged_blocks():
    tree = rand_client_tree(np.random.default_rng(3), L=2, r=4)
    codec = make_codec("fp32")
    ranks = {("blk",): [2, 3]}
    payload = AdapterPayload.pack(tree, codec, ranks=ranks)
    ok = payload.unpack_into(tree, codec)
    assert ok["blk"]["A"].shape == tree["blk"]["A"].shape
    # missing layer block -> layer-count contract violation
    short = AdapterPayload.pack(tree, codec, ranks=ranks)
    short.blocks[("blk",)]["A"].pop()
    with pytest.raises(PayloadError):
        short.unpack_into(tree, codec)
    # a ragged rank larger than the reference rank dim -> rank bound
    wide = AdapterPayload.pack(tree, codec, ranks={("blk",): [4, 4]})
    small = rand_client_tree(np.random.default_rng(3), L=2, r=2)
    with pytest.raises(PayloadError):
        wide.unpack_into(small, codec)


def test_uplink_retry_then_success_and_dead_client():
    tree = rand_client_tree(np.random.default_rng(4))
    agg = _RecAgg()
    plan = FaultPlan(seed=0, corrupt=1.0)            # every client corrupts
    n_bad = plan.client_fault(0, 0).n_bad
    tp = Transport("fp32", fault_plan=plan, max_retries=n_bad)
    decoded, nbytes = tp.client_to_server(tree, agg, rnd=0, client_id=0)
    np.testing.assert_array_equal(decoded["blk"]["A"], tree["blk"]["A"])
    assert tp.stats.retries == n_bad
    assert tp.stats.crc_failures == n_bad
    assert tp.stats.dead_clients == 0
    # retransmissions cost wire bytes; backoff advanced the simulated clock
    one = AdapterPayload.pack(tree, tp.codec).num_bytes
    assert nbytes == one * (n_bad + 1)
    assert plan.clock.now > 0.0
    # one fewer allowed attempt -> the client is declared dead
    tp2 = Transport("fp32", fault_plan=plan, max_retries=n_bad - 1)
    with pytest.raises(DeadClientError):
        tp2.client_to_server(tree, agg, rnd=0, client_id=0)
    assert tp2.stats.dead_clients == 1


def test_backoff_is_deterministic():
    tree = rand_client_tree(np.random.default_rng(5))
    times = []
    for _ in range(2):
        plan = FaultPlan(seed=2, corrupt=1.0)
        tp = Transport("fp32", fault_plan=plan,
                       max_retries=plan.client_fault(0, 7).n_bad)
        tp.client_to_server(tree, _RecAgg(), rnd=0, client_id=7)
        times.append(plan.clock.now)
    assert times[0] == times[1] > 0.0


def test_dp_clip_applied_exactly_once_across_retries(monkeypatch):
    import repro.core.privacy as P
    calls = {"clip": 0, "noise": 0}
    real_clip, real_noise = P.clip_update, P.local_gaussian_noise
    monkeypatch.setattr(P, "clip_update", lambda *a, **k: (
        calls.__setitem__("clip", calls["clip"] + 1), real_clip(*a, **k))[1])
    monkeypatch.setattr(P, "local_gaussian_noise", lambda *a, **k: (
        calls.__setitem__("noise", calls["noise"] + 1),
        real_noise(*a, **k))[1])
    rng = np.random.default_rng(6)
    tree = rand_client_tree(rng)
    init = rand_client_tree(np.random.default_rng(7))
    plan = FaultPlan(seed=0, corrupt=1.0)
    n_bad = plan.client_fault(0, 0).n_bad
    tp = Transport("fp32", dp_clip=1.0, dp_sigma=0.5, fault_plan=plan,
                   max_retries=n_bad)
    tp.client_to_server(tree, _RecAgg(), init_adapters=init, rnd=0,
                        client_id=0)
    assert tp.stats.retries == n_bad
    assert calls == {"clip": 1, "noise": 1}   # retries re-encode, never re-DP


# ---------------------------------------------------------------------------
# validation gate
# ---------------------------------------------------------------------------


def test_gate_screen_rejects_nonfinite_and_folds_clean():
    rng = np.random.default_rng(8)
    gate = ValidationGate("screen")
    agg = _RecAgg()
    gate.begin_round(agg)
    clean = rand_client_tree(rng)
    assert gate.submit(object(), clean, 0.5, rank=4)
    bad = rand_client_tree(rng)
    bad["blk"]["B"][0, 0, 0] = np.nan
    assert not gate.submit(object(), bad, 0.5, rank=4)
    inf = rand_client_tree(rng)
    inf["blk"]["A"][1, 2, 3] = np.inf
    assert not gate.submit(object(), inf, 0.5, rank=4)
    stats = gate.finish()
    assert len(agg.calls) == 1
    assert stats.rejected_nonfinite == 2 and stats.accepted == 1


def test_gate_rejects_shape_and_rank_violations():
    rng = np.random.default_rng(9)
    gate = ValidationGate("screen")
    agg = _RecAgg()
    gate.begin_round(agg)
    assert gate.submit(object(), rand_client_tree(rng), 0.5, rank=4)
    # wrong model dims vs the round's reference
    assert not gate.submit(object(), rand_client_tree(rng, n=99), 0.5, rank=4)
    # A/B rank dims disagree
    torn = rand_client_tree(rng)
    torn["blk"]["B"] = torn["blk"]["B"][:, :, :2]
    assert not gate.submit(object(), torn, 0.5, rank=4)
    # declared task rank does not match the uploaded tensors
    assert not gate.submit(object(), rand_client_tree(rng), 0.5, rank=6)
    assert gate.finish().rejected_shape == 3


def test_gate_deduplicates_at_least_once_delivery():
    rng = np.random.default_rng(10)
    gate = ValidationGate("screen")
    agg = _RecAgg()
    gate.begin_round(agg)
    task = object()
    tree = rand_client_tree(rng)
    assert gate.submit(task, tree, 0.5, rank=4)
    assert not gate.submit(task, tree, 0.5, rank=4)   # same delivery re-sent
    stats = gate.finish()
    assert len(agg.calls) == 1 and stats.rejected_duplicate == 1


def test_gate_full_quarantines_norm_outliers_and_renormalizes():
    rng = np.random.default_rng(11)
    gate = ValidationGate("full", mad_threshold=6.0)
    agg = _RecAgg()
    gate.begin_round(agg)
    w = 1.0 / 6.0
    for _ in range(5):
        assert gate.submit(object(), rand_client_tree(rng), w, rank=4)
    assert gate.submit(object(), rand_client_tree(rng, b_scale=100.0), w,
                       rank=4)                        # held, not yet judged
    assert not agg.calls                              # full mode buffers
    stats = gate.finish()
    assert stats.quarantined == 1 and stats.accepted == 5
    # surviving weights renormalize to the round's total mass
    assert sum(wt for _, wt, _ in agg.calls) == pytest.approx(6 * w)


def test_gate_full_tight_honest_cluster_never_self_rejects():
    """All-identical norms (e.g. every update clipped to the same DP bound)
    must not quarantine anyone on numerically-tiny spread."""
    gate = ValidationGate("full")
    agg = _RecAgg()
    gate.begin_round(agg)
    for i in range(6):
        tree = rand_client_tree(np.random.default_rng(100 + i))
        norm = np.sqrt(sum(float(np.sum(np.asarray(v, np.float64) ** 2))
                           for v in (tree["blk"]["A"], tree["blk"]["B"])))
        tree["blk"]["A"] /= norm                      # exact unit L2
        tree["blk"]["B"] = np.zeros_like(tree["blk"]["B"])
        gate.submit(object(), tree, 1 / 6, rank=4)
    stats = gate.finish()
    assert stats.quarantined == 0 and stats.accepted == 6


def test_gate_quorum():
    gate = ValidationGate("screen", min_clients=3)
    agg = _RecAgg()
    gate.begin_round(agg)
    gate.submit(object(), rand_client_tree(np.random.default_rng(12)), 1.0,
                rank=4)
    assert not gate.finish().quorum_met
    gate.begin_round(agg)
    for i in range(3):
        gate.submit(object(),
                    rand_client_tree(np.random.default_rng(13 + i)), 1 / 3,
                    rank=4)
    assert gate.finish().quorum_met


def test_gate_off_mode_bypasses_checks():
    gate = ValidationGate("off")
    agg = _RecAgg()
    gate.begin_round(agg)
    bad = rand_client_tree(np.random.default_rng(14))
    bad["blk"]["A"][0, 0, 0] = np.nan
    assert gate.submit(object(), bad, 1.0, rank=4)
    assert len(agg.calls) == 1


# ---------------------------------------------------------------------------
# end-to-end: poison containment
# ---------------------------------------------------------------------------


def test_nan_poison_contained_exactly_as_if_dropped():
    """Screen-gate rejection of NaN uploads must equal the same clients
    never arriving: FaultPlan draws one uniform per (round, client), so
    nan=p and drop=p poison the *same* client set."""
    nan_plan = FaultPlan(seed=21, nan=0.4)
    drop_plan = FaultPlan(seed=21, drop=0.4)
    poisoned = [c for c in range(12)
                if nan_plan.client_fault(0, c).kind == "nan"]
    assert poisoned, "seed must poison someone for the test to bite"
    t_nan = make_trainer(faults=nan_plan)
    t_drop = make_trainer(faults=drop_plan)
    h_nan, h_drop = t_nan.run(2), t_drop.run(2)
    for a, b in zip(h_nan, h_drop):
        assert a.eval_loss == b.eval_loss
        assert a.rejected > 0 or a.dead_clients == b.dead_clients
    pn = adapter_products(t_nan.global_state.global_adapters)
    pd = adapter_products(t_drop.global_state.global_adapters)
    for path in pn:
        np.testing.assert_array_equal(pn[path], pd[path])
    # counters surfaced in the history
    assert sum(r.rejected for r in h_nan) == \
        sum(r.dead_clients for r in h_drop)


def test_scale_poison_quarantined_matches_clean_only_aggregation():
    """100×-scaled updates are finite, so only the full gate's MAD
    quarantine catches them; the finalized global adapters must match
    folding the clean clients alone (weights renormalized)."""
    plan = FaultPlan(seed=4, scale=0.3)
    fed_sample = 4
    # capture each clean client's decoded update via a recording gate
    captured = []

    class _CapturingGate(ValidationGate):
        def submit(self, task, update, weight, rank=None,
                   init_adapters=None):
            captured.append((task.client_id, update, weight, rank))
            return super().submit(task, update, weight, rank=rank,
                                  init_adapters=init_adapters)

    t_clean = make_trainer(validation=_CapturingGate("full"))
    t_clean.run(1)
    poisoned = {cid for cid, *_ in captured
                if plan.client_fault(0, cid).kind == "scale"}
    assert poisoned and len(poisoned) < fed_sample

    t_poison = make_trainer(faults=plan, validation=ValidationGate("full"))
    h = t_poison.run(1)
    assert h[0].quarantined == len(poisoned)
    assert h[0].quorum_met

    # reference: clean clients only, weights renormalized to full mass
    agg = make_aggregator("florist", tau=0.9)
    agg.begin_round()
    w_all = sum(w for _, _, w, _ in captured)
    w_acc = sum(w for cid, _, w, _ in captured if cid not in poisoned)
    for cid, update, w, rank in captured:
        if cid not in poisoned:
            agg.add_client(update, w * (w_all / w_acc), rank=rank)
    ref = agg.finalize()
    pr = adapter_products(ref.global_adapters)
    pp = adapter_products(t_poison.global_state.global_adapters)
    for path in pr:
        np.testing.assert_allclose(pr[path], pp[path], atol=1e-5,
                                   err_msg=str(path))


def test_duplicate_uploads_fold_once():
    t_dup = make_trainer(faults=FaultPlan(seed=0, duplicate=1.0))
    t_ref = make_trainer()
    h_dup, h_ref = t_dup.run(2), t_ref.run(2)
    for a, b in zip(h_dup, h_ref):
        assert a.eval_loss == b.eval_loss
        assert a.rejected == 4                 # every re-send deduplicated
    pd = adapter_products(t_dup.global_state.global_adapters)
    pr = adapter_products(t_ref.global_state.global_adapters)
    for path in pd:
        np.testing.assert_array_equal(pd[path], pr[path])


def test_slow_clients_only_cost_simulated_time():
    t_slow = make_trainer(faults=FaultPlan(seed=0, slow=1.0, slow_secs=3.0))
    t_ref = make_trainer()
    h_slow, h_ref = t_slow.run(1), t_ref.run(1)
    assert h_slow[0].eval_loss == h_ref[0].eval_loss
    assert h_slow[0].sim_secs > 0.0 and h_ref[0].sim_secs == 0.0


def test_all_dropped_round_degrades_gracefully():
    tr = make_trainer(faults=FaultPlan(seed=0, drop=1.0))
    h = tr.run(2)
    assert all(not r.quorum_met for r in h)
    assert all(r.dead_clients == 4 for r in h)
    assert tr.global_state is None             # nothing was ever folded
    assert np.isfinite(h[-1].eval_loss)        # still evaluates the base


def test_honest_dp_clients_pass_full_gate():
    tr = make_trainer(dp_clip=1.0, dp_sigma=0.7,
                      validation=ValidationGate("full"))
    h = tr.run(2)
    assert all(r.quarantined == 0 and r.rejected == 0 for r in h)
    assert all(r.quorum_met for r in h)


# ---------------------------------------------------------------------------
# checkpoint/resume: crash-exactness
# ---------------------------------------------------------------------------


def _strip(rec):
    d = dataclasses.asdict(rec)
    for k in ("wall_secs", "sim_secs", "resumes"):
        d.pop(k)
    return d


def _assert_resume_bit_exact(tmp_path, crash_round, crash_point, rounds=3,
                             **kw):
    ref_tr = make_trainer(**kw)
    ref = ref_tr.run(rounds)
    ck = os.path.join(str(tmp_path), "fed.ckpt")
    plan = FaultPlan(seed=0, crashes=((crash_round, crash_point),))
    t1 = make_trainer(faults=plan, **kw)
    with pytest.raises(ServerCrash):
        t1.run(rounds, checkpoint=ck, checkpoint_every=1)
    t2 = make_trainer(faults=plan.without_crashes(), **kw)
    hist = t2.run(rounds, checkpoint=ck, checkpoint_every=1, resume=True)
    assert [_strip(r) for r in hist] == [_strip(r) for r in ref]
    assert any(r.resumes for r in hist) or crash_round == 0
    pr = adapter_products(ref_tr.global_state.global_adapters)
    pt = adapter_products(t2.global_state.global_adapters)
    for path in pr:
        np.testing.assert_array_equal(pr[path], pt[path])
    eq = jax.tree.map(
        lambda a, b: bool(np.array_equal(np.asarray(a), np.asarray(b))),
        jax.device_get(ref_tr.global_state.global_adapters),
        jax.device_get(t2.global_state.global_adapters))
    assert all(jax.tree.leaves(eq))


@pytest.mark.parametrize("point",
                         ["begin", "mid_round", "pre_finalize", "post_round"])
def test_crash_resume_bit_exact_sequential(tmp_path, point):
    _assert_resume_bit_exact(tmp_path, 1, point)


@pytest.mark.parametrize("runner", ["cohort", "sharded_cohort"])
def test_crash_resume_bit_exact_batched_runners(tmp_path, runner):
    _assert_resume_bit_exact(tmp_path, 1, "mid_round", runner=runner)


def test_crash_resume_round_zero_before_any_checkpoint(tmp_path):
    _assert_resume_bit_exact(tmp_path, 0, "mid_round", rounds=2)


def test_crash_resume_with_async_scheduler_state(tmp_path):
    # spec string -> each trainer builds its OWN AsyncScheduler (the
    # scheduler is stateful; sharing an instance would leak in-flight
    # dispatches across runs), and resume restores its state_dict
    _assert_resume_bit_exact(tmp_path, 1, "post_round", scheduler="async")


def test_resume_skips_completed_rounds(tmp_path):
    ck = os.path.join(str(tmp_path), "fed.ckpt")
    t1 = make_trainer()
    t1.run(3, checkpoint=ck)
    runs = []
    t2 = make_trainer()
    orig = t2.run_round
    t2.run_round = lambda rnd: runs.append(rnd) or orig(rnd)
    hist = t2.run(3, checkpoint=ck, resume=True)
    assert runs == []                          # nothing left to replay
    assert len(hist) == 3
    assert [_strip(r) for r in hist] == [_strip(r) for r in t1.history]


# ---------------------------------------------------------------------------
# aggregator mid-round state round-trip
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("method",
                         ["fedit", "ffa", "flora", "flexlora", "florist"])
def test_aggregator_state_roundtrip_mid_round(method, tmp_path):
    rng = np.random.default_rng(30)
    clients = [rand_client_tree(rng) for _ in range(4)]
    mk = lambda: make_aggregator(method, **({"tau": 0.9}
                                           if method == "florist" else {}))
    a_init = {"blk": {"A": clients[0]["blk"]["A"],
                      "B": np.zeros_like(clients[0]["blk"]["B"]),
                      "scale": np.ones((2,), np.float32)}}
    agg1 = mk()
    if method == "ffa":
        agg1.A_init = a_init
    agg1.begin_round()
    for c in clients[:2]:
        agg1.add_client(c, 0.25, rank=4)
    # snapshot through the atomic pickle path, restore into a FRESH instance
    blob = os.path.join(str(tmp_path), "agg.state")
    ckpt_io.save_state(blob, agg1.state_dict())
    agg2 = mk()
    if method == "ffa":
        agg2.A_init = a_init
    agg2.begin_round()
    agg2.load_state_dict(ckpt_io.restore_state(blob))
    for agg in (agg1, agg2):
        for c in clients[2:]:
            agg.add_client(c, 0.25, rank=4)
    r1, r2 = agg1.finalize(), agg2.finalize()
    assert r1.ranks == r2.ranks
    if r1.global_adapters is not None:
        eq = jax.tree.map(
            lambda a, b: bool(np.array_equal(np.asarray(a), np.asarray(b))),
            jax.device_get(r1.global_adapters),
            jax.device_get(r2.global_adapters))
        assert all(jax.tree.leaves(eq))
    if r1.per_client is not None:
        for t1, t2 in zip(r1.per_client, r2.per_client):
            eq = jax.tree.map(
                lambda a, b: bool(np.array_equal(np.asarray(a),
                                                 np.asarray(b))),
                jax.device_get(t1), jax.device_get(t2))
            assert all(jax.tree.leaves(eq))


# ---------------------------------------------------------------------------
# atomic checkpoint io
# ---------------------------------------------------------------------------


def test_npz_save_extensionless_path_round_trips(tmp_path):
    tree = {"a": np.arange(6, dtype=np.float32).reshape(2, 3),
            "b": {"c": np.ones((4,), np.float32)}}
    path = os.path.join(str(tmp_path), "ckpt")       # no .npz
    ckpt_io.save(path, tree, step=7)
    assert os.path.exists(path)                      # no silent suffix-append
    back = ckpt_io.restore(path, tree)
    assert ckpt_io.restore_step(path) == 7
    np.testing.assert_array_equal(np.asarray(back["a"]), tree["a"])
    # legacy suffixed checkpoints still restore
    path2 = os.path.join(str(tmp_path), "ckpt2.npz")
    ckpt_io.save(path2, tree)
    np.testing.assert_array_equal(
        np.asarray(ckpt_io.restore(path2, tree)["b"]["c"]), tree["b"]["c"])


def test_atomic_writes_leave_no_temp_files(tmp_path):
    d = str(tmp_path)
    ckpt_io.save(os.path.join(d, "x"), {"a": np.ones((2,), np.float32)})
    ckpt_io.save_state(os.path.join(d, "y"), {"k": 1})
    # interrupted write (serializer throws) leaves no partial/temp file
    class Boom(Exception):
        pass

    with pytest.raises(Boom):
        ckpt_io._atomic_write(os.path.join(d, "z"),
                              lambda f: (_ for _ in ()).throw(Boom()))
    assert sorted(os.listdir(d)) == ["x", "y"]


def test_save_overwrite_is_all_or_nothing(tmp_path):
    path = os.path.join(str(tmp_path), "state")
    ckpt_io.save_state(path, {"v": 1})
    ckpt_io.save_state(path, {"v": 2})
    assert ckpt_io.restore_state(path) == {"v": 2}


def test_state_blob_round_trips_tuple_keys_and_arrays(tmp_path):
    path = os.path.join(str(tmp_path), "blob")
    state = {("layer", "q"): {"M": np.random.default_rng(0).normal(
        size=(2, 3)).astype(np.float32)},
             "seen": {4, 8}, "n": 3}
    ckpt_io.save_state(path, ckpt_io.to_host(state))
    back = ckpt_io.restore_state(path)
    np.testing.assert_array_equal(back[("layer", "q")]["M"],
                                  state[("layer", "q")]["M"])
    assert back["seen"] == {4, 8} and back["n"] == 3


def test_to_host_to_device_round_trip():
    import jax.numpy as jnp
    tree = {"a": jnp.ones((2, 2)), "l": [jnp.zeros((3,)), 5, None],
            "t": (jnp.arange(4), "tag")}
    host = ckpt_io.to_host(tree)
    assert isinstance(host["a"], np.ndarray)
    dev = ckpt_io.to_device(host)
    assert isinstance(dev["a"], jax.Array)
    assert dev["l"][1] == 5 and dev["l"][2] is None and dev["t"][1] == "tag"
    np.testing.assert_array_equal(np.asarray(dev["t"][0]), np.arange(4))
