"""XLA_FLAGS management: append-never-clobber semantics of
``repro.common.xla_env`` (jax-free, so these run without backend init)."""
import pytest

from repro.common.xla_env import (append_xla_flags, force_host_devices,
                                  merge_flags, render_flags)


class TestMergeFlags:
    def test_append_to_empty(self):
        assert merge_flags("", "--a=1") == "--a=1"

    def test_append_new_flag(self):
        assert merge_flags("--a=1", "--b=2") == "--a=1 --b=2"

    def test_existing_name_wins(self):
        """A flag whose NAME is already set is left alone — the user's
        value wins even when ours differs."""
        assert merge_flags("--a=1", "--a=2") == "--a=1"

    def test_duplicate_among_new_flags(self):
        assert merge_flags("--a=1", "--b=2", "--b=3") == "--a=1 --b=2 --b=3"
        # first-wins precedence applies against base, not within additions:
        # XLA itself takes the last occurrence, so callers pass one value

    def test_valueless_flag(self):
        assert merge_flags("--xla_dump_to=/tmp/x", "--xla_dump_to=/y") \
            == "--xla_dump_to=/tmp/x"

    def test_multiple_base_flags(self):
        base = "--a=1 --b=2"
        assert merge_flags(base, "--b=9", "--c=3") == "--a=1 --b=2 --c=3"


class TestAppendXlaFlags:
    def test_sets_env(self, monkeypatch):
        monkeypatch.delenv("XLA_FLAGS", raising=False)
        import os
        assert append_xla_flags("--a=1") == "--a=1"
        assert os.environ["XLA_FLAGS"] == "--a=1"

    def test_idempotent(self, monkeypatch):
        monkeypatch.setenv("XLA_FLAGS", "")
        first = append_xla_flags("--a=1")
        second = append_xla_flags("--a=1")
        assert first == second == "--a=1"

    def test_preserves_user_flags(self, monkeypatch):
        monkeypatch.setenv("XLA_FLAGS", "--user=yes")
        assert append_xla_flags("--mine=1") == "--user=yes --mine=1"


class TestForceHostDevices:
    def test_sets_count(self, monkeypatch):
        monkeypatch.delenv("XLA_FLAGS", raising=False)
        out = force_host_devices(8)
        assert out == "--xla_force_host_platform_device_count=8"

    def test_user_count_wins(self, monkeypatch):
        """The clobbering bug class this module exists to fix: a user-set
        device count must survive our request."""
        monkeypatch.setenv(
            "XLA_FLAGS", "--xla_force_host_platform_device_count=2")
        out = force_host_devices(512)
        assert out == "--xla_force_host_platform_device_count=2"

    def test_unrelated_user_flags_survive(self, monkeypatch):
        monkeypatch.setenv("XLA_FLAGS", "--xla_cpu_use_thunk_runtime=false")
        out = force_host_devices(4)
        assert out == ("--xla_cpu_use_thunk_runtime=false "
                       "--xla_force_host_platform_device_count=4")


class TestRenderFlags:
    def test_renders_values_and_booleans(self):
        assert render_flags({"a": 1, "b": True, "c": False, "d": "x"}) \
            == "--a=1 --b=true --c=false --d=x"

    def test_roundtrip_through_merge(self):
        frag = render_flags({"xla_foo": 7})
        assert merge_flags(frag, "--xla_foo=9") == frag
