"""Population-scale federated runtime: sampled-participation determinism,
sharded-cohort parity, streaming-aggregation memory bounds, DP-on-the-wire
and participation-aware round accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core.costs as C
from repro.common.config import FedConfig, LoRAConfig, ModelConfig, OptimConfig
from repro.core.aggregators import (METHODS, adapter_leaf_paths, fold_scale,
                                    get_path, make_aggregator)
from repro.core.aggregators.florist import FloristAggregator
from repro.core.federated import FederatedTrainer
from repro.core.privacy import (clip_update, global_l2, local_gaussian_noise,
                                tree_add, tree_sub)
from repro.core.runtime import (AsyncScheduler, ResourceRankPolicy,
                                SampledScheduler, ShardedCohortRunner,
                                Transport)
from repro.data.synthetic import make_eval_data, make_federated_data

CFG = ModelConfig(name="fs-tiny", family="dense", num_layers=2, d_model=64,
                  num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
                  vocab_size=256, dtype="float32")
LORA = LoRAConfig(rank=8, alpha=8.0)
OPT = OptimConfig(lr=3e-3)


def make_trainer(method, heter=False, **kw):
    fed = FedConfig(num_clients=12, clients_per_round=4, method=method,
                    tau=0.9, homogeneous_rank=8, heterogeneous=heter,
                    rank_distribution=((4, 4), (8, 4), (16, 4)),
                    zero_padding=heter, seed=0)
    kw.setdefault("local_steps", 2)
    return FederatedTrainer(CFG, fed, LORA, OPT, batch_size=8, seq_len=32,
                            **kw)


def adapter_products(tree):
    """Per-leaf ΔW = scale·B@A — the permutation/rotation-invariant object
    (cohort delivery order can rotate near-degenerate SVD factors while
    leaving the product unchanged)."""
    out = {}
    for path in adapter_leaf_paths(tree):
        B, A = fold_scale(get_path(tree, path))
        B, A = np.asarray(B, np.float64), np.asarray(A, np.float64)
        out[path] = B @ A if B.ndim == 3 else B @ A
    return out


def assert_same_products(t1, t2, atol):
    p1, p2 = adapter_products(t1), adapter_products(t2)
    assert p1.keys() == p2.keys()
    for path in p1:
        np.testing.assert_allclose(p1[path], p2[path], atol=atol,
                                   err_msg=str(path))


def rand_client_tree(rng, L=2, m=32, n=24, r=4):
    return {"blk": {"A": rng.normal(size=(L, r, n)).astype(np.float32),
                    "B": rng.normal(size=(L, m, r)).astype(np.float32),
                    "scale": np.ones((L,), np.float32)}}


# ---------------------------------------------------------------------------
# sampled scheduler: seed-deterministic participation
# ---------------------------------------------------------------------------


def test_sampled_participants_pure_function_of_seed_and_round():
    """The participant set must not depend on what else consumed the
    trainer's shared rng stream — only on (seed, round)."""
    t1 = make_trainer("florist", scheduler=SampledScheduler(fraction=0.5))
    t2 = make_trainer("florist", scheduler=SampledScheduler(fraction=0.5))
    t2.rng.integers(1000, size=7)        # perturb the shared stream
    for rnd in range(5):
        p1 = t1.scheduler.plan(rnd, t1)
        p2 = t2.scheduler.plan(rnd, t2)
        assert [t.client_id for t in p1.tasks] == \
            [t.client_id for t in p2.tasks]
        assert [t.steps for t in p1.tasks] == [t.steps for t in p2.tasks]
        assert sum(t.weight for t in p1.tasks) == pytest.approx(1.0)
    # ... and the sets actually vary across rounds
    sets = {tuple(t.client_id for t in t1.scheduler.plan(r, t1).tasks)
            for r in range(6)}
    assert len(sets) > 1


def test_sampled_fraction_and_floor():
    tr = make_trainer("florist")
    plan = SampledScheduler(fraction=0.5).plan(0, tr)
    assert len(plan.tasks) == 6           # 0.5 · 12
    plan = SampledScheduler(fraction=1e-6, min_clients=2).plan(0, tr)
    assert len(plan.tasks) == 2           # min_clients floor
    with pytest.raises(ValueError):
        SampledScheduler(fraction=0.0)


def test_sampled_composes_partial_semantics():
    tr = make_trainer("florist", local_steps=8)
    sched = SampledScheduler(fraction=1.0, drop_rate=0.3, straggler_rate=0.3)
    plans = [sched.plan(r, tr) for r in range(8)]
    sizes = [len(p.tasks) for p in plans]
    steps = [t.steps for p in plans for t in p.tasks]
    assert any(s < tr.fed.num_clients for s in sizes)   # dropouts hit
    assert all(s >= 1 for s in sizes)                   # never empty
    assert any(st < 8 for st in steps)                  # stragglers hit
    assert all(st >= 1 for st in steps)
    for p in plans:
        assert sum(t.weight for t in p.tasks) == pytest.approx(1.0)


def test_sampled_end_to_end_deterministic():
    h1 = make_trainer("florist",
                      scheduler=SampledScheduler(fraction=0.5)).run(2)
    h2 = make_trainer("florist",
                      scheduler=SampledScheduler(fraction=0.5)).run(2)
    for a, b in zip(h1, h2):
        assert a.eval_loss == b.eval_loss
        assert a.upload_bytes == b.upload_bytes


# ---------------------------------------------------------------------------
# sharded cohort parity (acceptance: all five methods, hom + heter)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("heter", [False, True])
@pytest.mark.parametrize("method", METHODS)
def test_sharded_cohort_matches_sequential(method, heter):
    """sharded_cohort ≡ cohort ≡ sequential at the level that is invariant
    to delivery order: eval loss, analytic counts, and the aggregated
    product ΔW = B@A per leaf (streamed blocks permute the stack columns,
    which can rotate near-degenerate SVD *factors* without changing ΔW)."""
    rounds = 2 if method == "florist" else 1
    seq = make_trainer(method, heter=heter)
    sh = make_trainer(method, heter=heter,
                      runner=ShardedCohortRunner(block=8))
    for rnd in range(rounds):
        rs, rh = seq.run_round(rnd), sh.run_round(rnd)
        assert rh.eval_loss == pytest.approx(rs.eval_loss, abs=2e-4)
        assert rh.upload_params == rs.upload_params
        assert rh.download_params == rs.download_params
        assert rh.global_rank_total == rs.global_rank_total
    assert_same_products(seq.global_state.global_adapters,
                         sh.global_state.global_adapters, atol=2e-3)


def test_sharded_cohort_matches_cohort():
    seq = make_trainer("florist", heter=True, runner="cohort")
    sh = make_trainer("florist", heter=True, runner="sharded_cohort")
    for rnd in range(2):
        rs, rh = seq.run_round(rnd), sh.run_round(rnd)
        assert rh.eval_loss == pytest.approx(rs.eval_loss, abs=2e-4)
    assert_same_products(seq.global_state.global_adapters,
                         sh.global_state.global_adapters, atol=2e-3)


def test_sharded_cohort_streams_blocks():
    """Block size caps the number of clients alive on host/device at once."""
    runner = ShardedCohortRunner(block=2)
    tr = make_trainer("florist", runner=runner)
    tr.run_round(0)
    assert 0 < runner.peak_live_clients <= max(
        2, runner._pad(2, tr))            # one block, mesh-padded


# ---------------------------------------------------------------------------
# streaming aggregation: O(cohort) server memory
# ---------------------------------------------------------------------------


def test_streaming_florist_bounds_pending_blocks_and_matches_stacked():
    rng = np.random.default_rng(0)
    trees = [rand_client_tree(rng) for _ in range(24)]
    ref = FloristAggregator(tau=0.9, stream="stacked")
    agg = FloristAggregator(tau=0.9, stream="delta", flush_every=4)
    for a in (ref, agg):
        a.begin_round()
    w = 1.0 / len(trees)
    for t in trees:
        ref.add_client(t, w, rank=4)
        agg.add_client(t, w, rank=4)
    assert agg.peak_pending_blocks <= 4           # never K=24 trees live
    assert ref.peak_pending_blocks == len(trees)  # the O(K) baseline
    r_ref, r_agg = ref.finalize(), agg.finalize()
    assert_same_products(r_ref.global_adapters, r_agg.global_adapters,
                         atol=1e-4)


def test_streaming_auto_converts_past_crossover():
    """auto keeps the stacked factors while Σ r_k ≤ min(m, n) (bit-exact
    legacy path) and contracts into the dense delta once past it."""
    rng = np.random.default_rng(1)
    agg = FloristAggregator(tau=0.9, stream="auto", flush_every=4)
    agg.begin_round()
    # m=32, n=24 → crossover at Σr > 24; 24 rank-4 clients cross at k=7
    for k in range(24):
        agg.add_client(rand_client_tree(rng), 1 / 24, rank=4)
    assert agg.peak_pending_blocks <= 4
    inter = agg._settle()
    assert all(kind == "delta" for kind, *_ in inter.values())


def test_trainer_streaming_memory_bound():
    """End-to-end: a sampled 6-client round with flush_every=2 never holds
    more than 2 un-compacted uploads server-side."""
    agg = FloristAggregator(tau=0.9, svd_method="svd", stream="delta",
                            flush_every=2)
    tr = make_trainer("florist", aggregator=agg,
                      scheduler=SampledScheduler(fraction=0.5),
                      runner="sharded_cohort")
    hist = tr.run(2)
    assert all(np.isfinite(h.eval_loss) for h in hist)
    assert agg.peak_pending_blocks <= 2


# ---------------------------------------------------------------------------
# DP-on-the-wire: clip + noise exactly once, before encoding
# ---------------------------------------------------------------------------


def _init_and_trained(rng, seed_delta=0.1):
    init = rand_client_tree(rng)
    trained = jax.tree.map(
        lambda x: x + seed_delta * rng.normal(size=x.shape).astype(x.dtype)
        if x.ndim >= 2 else x, init)
    return init, trained


def test_dp_transport_matches_manual_mechanism():
    """The uplink is exactly clip(Δ) → noise(σ·C) → re-anchor → encode,
    keyed on (dp_seed, round, client): bitwise vs a manual replication."""
    rng = np.random.default_rng(2)
    init, trained = _init_and_trained(rng)
    agg = make_aggregator("fedit")
    tp = Transport("fp32", dp_clip=0.5, dp_sigma=0.3, dp_seed=7)
    out, nbytes = tp.client_to_server(trained, agg, init_adapters=init,
                                      rnd=3, client_id=5)

    delta, _ = clip_update(tree_sub(trained, init), 0.5)
    key = jax.random.fold_in(jax.random.fold_in(jax.random.PRNGKey(7), 3), 5)
    expected = tree_add(init, local_gaussian_noise(delta, 0.3, 0.5, key))

    for path in adapter_leaf_paths(expected):
        exp, got = get_path(expected, path), get_path(out, path)
        for name in ("A", "B"):
            np.testing.assert_array_equal(np.asarray(exp[name]),
                                          np.asarray(got[name]),
                                          err_msg=f"{path}/{name}")
    # privatization never changes the byte accounting
    plain, pbytes = Transport("fp32").client_to_server(trained, agg)
    assert nbytes == pbytes


def test_dp_clip_only_bounds_update_norm():
    rng = np.random.default_rng(3)
    init, trained = _init_and_trained(rng, seed_delta=2.0)
    tp = Transport("fp32", dp_clip=0.25, dp_sigma=0.0)
    out, _ = tp.client_to_server(trained, make_aggregator("fedit"),
                                 init_adapters=init)
    # scale never travels — compare the wire arrays only
    d = global_l2(tree_sub(
        {p: {k: get_path(out, p)[k] for k in ("A", "B")}
         for p in adapter_leaf_paths(out)},
        {p: {k: get_path(init, p)[k] for k in ("A", "B")}
         for p in adapter_leaf_paths(init)}))
    assert float(d) <= 0.25 * (1 + 1e-5)


def test_dp_noise_keys_unique_per_round_and_client():
    rng = np.random.default_rng(4)
    init, trained = _init_and_trained(rng)
    agg = make_aggregator("fedit")
    tp = Transport("fp32", dp_clip=1.0, dp_sigma=0.5, dp_seed=0)

    def upload(rnd, cid):
        out, _ = tp.client_to_server(trained, agg, init_adapters=init,
                                     rnd=rnd, client_id=cid)
        return np.concatenate([np.asarray(get_path(out, p)[n]).ravel()
                               for p in adapter_leaf_paths(out)
                               for n in ("A", "B")])

    base = upload(0, 0)
    np.testing.assert_array_equal(base, upload(0, 0))    # deterministic
    assert not np.array_equal(base, upload(0, 1))        # per-client key
    assert not np.array_equal(base, upload(1, 0))        # per-round key


def test_dp_requires_init_adapters():
    tp = Transport("fp32", dp_clip=1.0)
    with pytest.raises(ValueError, match="init adapters"):
        tp.client_to_server(rand_client_tree(np.random.default_rng(5)),
                            make_aggregator("fedit"))


def test_dp_applied_exactly_once_per_upload(monkeypatch):
    """One clip and one noise call per delivered client — no server-side
    second application (the old sidecar is gone)."""
    import repro.core.privacy as priv
    clips, noises = [], []
    orig_clip, orig_noise = priv.clip_update, priv.local_gaussian_noise
    monkeypatch.setattr(priv, "clip_update",
                        lambda *a: clips.append(1) or orig_clip(*a))
    monkeypatch.setattr(priv, "local_gaussian_noise",
                        lambda *a: noises.append(1) or orig_noise(*a))
    tr = make_trainer("florist", dp_clip=1.0, dp_sigma=0.1)
    rec = tr.run_round(0)
    assert len(clips) == tr.fed.clients_per_round
    assert len(noises) == tr.fed.clients_per_round
    assert np.isfinite(rec.eval_loss)
    # byte identity survives the DP stage (fp32 wire)
    assert rec.upload_bytes == 4 * rec.upload_params


def test_dp_end_to_end_deterministic_and_trains():
    kw = dict(dp_clip=1.0, dp_sigma=0.1,
              scheduler=SampledScheduler(fraction=0.5),
              runner="sharded_cohort")
    h1 = make_trainer("florist", **kw).run(2)
    h2 = make_trainer("florist", **kw).run(2)
    for a, b in zip(h1, h2):
        assert a.eval_loss == b.eval_loss
        assert np.isfinite(a.eval_loss)


# ---------------------------------------------------------------------------
# round accounting under sampling / async (participants only)
# ---------------------------------------------------------------------------


def test_sampled_round_accounting_matches_analytics():
    """RoundRecord counts cover exactly the participating clients, and the
    measured fp32 wire cross-checks the table-3 analytic model."""
    sched = SampledScheduler(fraction=0.5, drop_rate=0.3)
    tr = make_trainer("florist", scheduler=sched)
    rec = tr.run_round(0)
    # replay the (pure-function) plan to learn who participated
    plan = SampledScheduler(fraction=0.5, drop_rate=0.3).plan(0, tr)
    n_part = len(plan.tasks)
    assert n_part < tr.fed.num_clients
    trees = [tr._client_init(t.client_id, t.rank) for t in plan.tasks]
    assert rec.upload_params == C.upload_params("florist", trees)
    assert rec.upload_bytes == C.wire_upload_bytes("florist", trees,
                                                   codec="fp32")
    agg = tr.global_state
    assert rec.download_params == C.download_params(
        "florist", agg, tr.aggregator.dims, n_part,
        [t.rank for t in plan.tasks])
    assert rec.download_bytes == C.wire_download_bytes("florist", agg,
                                                       n_part, codec="fp32")
    assert rec.upload_bytes == 4 * rec.upload_params
    assert rec.download_bytes == 4 * rec.download_params


def test_async_download_accounting_counts_dispatches():
    """Async downlink bytes follow model *dispatches* (snapshot handed out),
    not arrivals — round 0 fills the whole in-flight pool while only the
    soonest cohort delivers."""
    sched = AsyncScheduler()
    plans = []
    orig_plan = sched.plan

    def spy(rnd, ctx):
        p = orig_plan(rnd, ctx)
        plans.append(p)
        return p

    sched.plan = spy
    tr = make_trainer("florist", scheduler=sched)
    recs = [tr.run_round(r) for r in range(4)]
    cap = tr.fed.clients_per_round
    assert plans[0].downloads == cap          # initial pool fill
    for p_prev, p in zip(plans, plans[1:]):
        assert p.downloads == len(p_prev.tasks)   # refill = last arrivals
    assert any(p.downloads != len(p.tasks) for p in plans)
    for rec in recs:
        # wire consistency under the dispatch-based count
        assert rec.download_bytes == 4 * rec.download_params


def test_partial_round_accounting_counts_survivors():
    tr = make_trainer("florist", scheduler="partial")
    recs = tr.run(4)
    for rec in recs:
        assert rec.upload_bytes == 4 * rec.upload_params
        assert rec.download_bytes == 4 * rec.download_params


# ---------------------------------------------------------------------------
# resource-aware rank policy (AFLoRA-style)
# ---------------------------------------------------------------------------


def test_resource_rank_policy_caps_and_pow2():
    tr = make_trainer("florist", heter=True)
    policy = ResourceRankPolicy()
    plan = tr.scheduler.plan(0, tr)
    policy.assign(0, plan, tr)
    for t in plan.tasks:
        cap = tr.client_ranks[t.client_id]
        budget = policy.budgets[t.client_id % len(policy.budgets)]
        assert 1 <= t.rank <= cap
        assert t.rank & (t.rank - 1) == 0            # power of two
        r = max(1, int(cap * budget))
        assert t.rank == min(cap, 1 << (r.bit_length() - 1))


def test_resource_rank_policy_warmup_ramps():
    tr = make_trainer("florist", heter=True)
    policy = ResourceRankPolicy(budgets=(1.0,), warmup=4)
    plan = tr.scheduler.plan(0, tr)
    early = {t.client_id: None for t in plan.tasks}
    for rnd, frac in ((0, 0.25), (3, 1.0)):
        policy.assign(rnd, plan, tr)
        for t in plan.tasks:
            cap = tr.client_ranks[t.client_id]
            r = max(1, int(cap * frac))
            assert t.rank == min(cap, 1 << (r.bit_length() - 1))
            if rnd == 0:
                early[t.client_id] = t.rank
            else:
                assert t.rank >= early[t.client_id]  # monotone ramp


def test_resource_rank_policy_end_to_end():
    hist = make_trainer("florist", heter=True, rank_policy="resource",
                        runner="sharded_cohort").run(2)
    assert all(np.isfinite(h.eval_loss) for h in hist)


# ---------------------------------------------------------------------------
# 1024-client smoke: the scaled round completes with bounded memory
# ---------------------------------------------------------------------------


def test_1024_clients_sampled_sharded_round():
    cfg = ModelConfig(name="fs-nano", family="dense", num_layers=1,
                      d_model=32, num_heads=2, num_kv_heads=1, head_dim=16,
                      d_ff=64, vocab_size=128, dtype="float32")
    fed = FedConfig(num_clients=1024, clients_per_round=16, method="florist",
                    tau=0.9, homogeneous_rank=4, seed=0)
    clients = make_federated_data(num_clients=1024, mean_samples=6,
                                  seq_len=16, vocab=128, seed=0)
    runner = ShardedCohortRunner(block=16)
    agg = FloristAggregator(tau=0.9, svd_method="svd", stream="auto",
                            flush_every=16)
    tr = FederatedTrainer(cfg, fed, LORA, OPT, clients=clients,
                          eval_data=make_eval_data(num_samples=32,
                                                   seq_len=16, vocab=128),
                          batch_size=2, local_steps=1, seq_len=16,
                          aggregator=agg, runner=runner,
                          scheduler=SampledScheduler(fraction=16 / 1024))
    rec = tr.run_round(0)
    assert np.isfinite(rec.eval_loss)
    # 16 participants out of 1024; memory stays O(cohort) on both sides
    plan = SampledScheduler(fraction=16 / 1024).plan(0, tr)
    assert len(plan.tasks) == 16
    assert runner.peak_live_clients <= runner._pad(16, tr)
    assert agg.peak_pending_blocks <= 16
    assert rec.upload_bytes == 4 * rec.upload_params
