"""Multi-tenant adapter serving: registry paging, per-row adapters through
the jitted hot loop, bgmv kernel parity, and the zero-retrace / hot-swap /
bit-identity invariants of ``repro.serve.adapters``."""
import jax
import jax.core as jcore
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config, lora_targets
from repro.models import transformer as T
from repro.peft.lora import PagedLoRA, init_lora, lora_proj, paged_lora_delta
from repro.serve.adapters import AdapterRegistry, attach, is_device_state
from repro.serve.engine import SamplingParams, ServeEngine, _build_engine_step

ARCH = "qwen2-0.5b"
REG_KW = dict(page_rank=4, num_pages=64, max_adapters=16, max_rank=8)


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config(ARCH)
    key = jax.random.PRNGKey(0)
    params = T.init(cfg, key)
    template = init_lora(params, lora_targets(cfg), 4, 8.0, key)
    return cfg, params, template


def _rand_adapter(cfg, params, rank, seed, alpha=8.0):
    """init_lora shape with non-zero B so the adapter changes outputs."""
    k = jax.random.PRNGKey(seed)
    ad = init_lora(params, lora_targets(cfg), rank, alpha, k)

    def fix(path, leaf):
        if getattr(path[-1], "key", None) == "B":
            kk = jax.random.fold_in(k, abs(hash(str(path))) % 2**30)
            return jax.random.normal(kk, leaf.shape) * 0.05
        return leaf

    return jax.tree_util.tree_map_with_path(fix, ad)


def _registry(template):
    return AdapterRegistry(template, **REG_KW)


def _engine(cfg, params, reg, **kw):
    kw.setdefault("batch_slots", 4)
    kw.setdefault("capacity", 64)
    return ServeEngine(cfg, params, registry=reg, seed=0, **kw)


def _count_dots(jaxpr):
    """dot_general count, recursive through scan/cond/pjit sub-jaxprs."""
    n = 0
    for eq in jaxpr.eqns:
        if eq.primitive.name == "dot_general":
            n += 1
        for v in eq.params.values():
            vs = v if isinstance(v, (tuple, list)) else (v,)
            for s in vs:
                if isinstance(s, jcore.ClosedJaxpr):
                    n += _count_dots(s.jaxpr)
                elif isinstance(s, jcore.Jaxpr):
                    n += _count_dots(s)
    return n


class TestRegistry:
    def test_register_assigns_pages_and_ids(self, setup):
        cfg, params, template = setup
        reg = _registry(template)
        i1 = reg.register("a", _rand_adapter(cfg, params, 4, 1))
        i2 = reg.register("b", _rand_adapter(cfg, params, 7, 2))
        assert (i1, i2) == (1, 2)           # id 0 reserved for base
        assert reg.metadata(i1)["rank"] == 4 and len(reg.metadata(i1)["pages"]) == 1
        assert reg.metadata(i2)["rank"] == 7 and len(reg.metadata(i2)["pages"]) == 2
        assert reg.num_free_pages == REG_KW["num_pages"] - 3
        assert reg.is_live(0) and reg.is_live(i1) and not reg.is_live(99)

    def test_register_evict_register_is_deterministic(self, setup):
        """Page/id reuse after evict is exact: same id, same pages, same
        device pool bytes."""
        cfg, params, template = setup
        reg = _registry(template)
        reg.register("keep", _rand_adapter(cfg, params, 4, 1))
        ad = _rand_adapter(cfg, params, 7, 2)
        i_a = reg.register("x", ad)
        pages_a = reg.metadata(i_a)["pages"]
        pools_a = jax.device_get(reg.device_state["pools"])
        table_a = np.asarray(reg.device_state["table"])
        reg.evict("x")
        assert not reg.is_live(i_a)
        i_b = reg.register("x", ad)
        assert i_b == i_a
        assert reg.metadata(i_b)["pages"] == pages_a
        np.testing.assert_array_equal(np.asarray(reg.device_state["table"]),
                                      table_a)
        for la, lb in zip(jax.tree_util.tree_leaves(pools_a),
                          jax.tree_util.tree_leaves(
                              jax.device_get(reg.device_state["pools"]))):
            np.testing.assert_array_equal(la, lb)

    def test_capacity_and_validation_errors(self, setup):
        cfg, params, template = setup
        reg = AdapterRegistry(template, page_rank=4, num_pages=2,
                              max_adapters=4, max_rank=8)
        with pytest.raises(ValueError, match="max_rank"):
            reg.register("big", _rand_adapter(cfg, params, 9, 1))
        reg.register("a", _rand_adapter(cfg, params, 8, 1))    # 2 pages
        with pytest.raises(RuntimeError, match="out of adapter pages"):
            reg.register("b", _rand_adapter(cfg, params, 4, 2))
        with pytest.raises(ValueError, match="already registered"):
            reg.register("a", _rand_adapter(cfg, params, 4, 3))
        with pytest.raises(KeyError):
            reg.swap("nope", _rand_adapter(cfg, params, 4, 4))
        with pytest.raises(ValueError, match="structure"):
            bad = {"not": {"the": {"template": {
                "A": jnp.zeros((4, 8)), "B": jnp.zeros((8, 4)),
                "scale": jnp.float32(1.0)}}}}
            _registry(template).register("bad", bad)

    def test_swap_is_atomic_version_bump(self, setup):
        cfg, params, template = setup
        reg = _registry(template)
        i_old = reg.register("svc", _rand_adapter(cfg, params, 4, 1))
        i_new = reg.swap("svc", _rand_adapter(cfg, params, 6, 2))
        assert i_new != i_old
        assert reg.resolve("svc") == i_new
        # the old version keeps serving in-flight rows until evicted
        assert reg.is_live(i_old) and reg.metadata(i_old)["retired"]
        assert reg.metadata(i_new)["version"] == 2
        reg.evict(i_old)
        assert not reg.is_live(i_old) and reg.is_live(i_new)

    def test_attach_builds_paged_leaves(self, setup):
        cfg, params, template = setup
        reg = _registry(template)
        i1 = reg.register("a", _rand_adapter(cfg, params, 4, 1))
        assert is_device_state(reg.device_state)
        tree = attach(reg.device_state, jnp.asarray([i1, 0], jnp.int32))
        leaves = [l for l in jax.tree_util.tree_leaves(
            tree, is_leaf=lambda x: isinstance(x, PagedLoRA))
            if isinstance(l, PagedLoRA)]
        assert leaves, "attach produced no PagedLoRA leaves"
        # stacked leaves carry the broadcast layer axis on every child
        for l in leaves:
            if l.a_pages.ndim == 4:
                L = l.a_pages.shape[0]
                assert l.table.shape[0] == L and l.ids.shape == (L, 2)


def _first_paged_leaf(tree):
    """First PagedLoRA of an attached tree, layer-0 slice if stacked."""
    for l in jax.tree_util.tree_leaves(
            tree, is_leaf=lambda x: isinstance(x, PagedLoRA)):
        if isinstance(l, PagedLoRA):
            return (jax.tree_util.tree_map(lambda p: p[0], l)
                    if l.a_pages.ndim == 4 else l)
    raise AssertionError("attach produced no PagedLoRA leaves")


class TestPagedMath:
    def test_paged_xla_rows_independent_and_rank_masked(self, setup):
        """Row math is row-local: a row's delta is bitwise invariant to what
        the other rows' adapters are, and a base (id-0) row's delta is an
        exact zero."""
        cfg, params, template = setup
        reg = _registry(template)
        i1 = reg.register("a", _rand_adapter(cfg, params, 4, 1))
        i2 = reg.register("b", _rand_adapter(cfg, params, 7, 2))
        rng = np.random.default_rng(0)
        paged = _first_paged_leaf(
            attach(reg.device_state, jnp.asarray([i1, i2, 0], jnp.int32)))
        x = jnp.asarray(rng.normal(size=(3, 1, paged.a_pages.shape[-1])),
                        jnp.float32)
        d = paged_lora_delta(x, paged)
        assert (np.asarray(d[2]) == 0).all()          # base row: exact zero
        # permuting OTHER rows' ids leaves row 0 bitwise unchanged
        paged2 = _first_paged_leaf(
            attach(reg.device_state, jnp.asarray([i1, 0, i2], jnp.int32)))
        d2 = paged_lora_delta(x, paged2)
        np.testing.assert_array_equal(np.asarray(d[0]), np.asarray(d2[0]))

    def test_bgmv_kernel_matches_xla_twin(self, setup):
        cfg, params, template = setup
        reg = _registry(template)
        i1 = reg.register("a", _rand_adapter(cfg, params, 4, 1))
        i2 = reg.register("b", _rand_adapter(cfg, params, 7, 2))
        ids = jnp.asarray([i1, i2, 0, i2], jnp.int32)
        rng = np.random.default_rng(1)
        lx = _first_paged_leaf(attach(reg.device_state, ids, impl="xla"))
        lk = _first_paged_leaf(attach(reg.device_state, ids, impl="kernel"))
        assert lx.impl == "xla" and lk.impl == "kernel"
        x = jnp.asarray(rng.normal(size=(4, 2, lx.a_pages.shape[-1])),
                        jnp.float32)
        dx = paged_lora_delta(x, lx)
        dk = paged_lora_delta(x, lk)
        np.testing.assert_allclose(np.asarray(dx), np.asarray(dk),
                                   atol=1e-4, rtol=1e-4)
        assert (np.asarray(dk[2]) == 0).all()         # base row exact zero


class TestEngine:
    def test_multi_matches_solo_engines_heterogeneous_ranks(self, setup):
        """One engine, >=8 live adapters with mixed ranks in one continuous
        batch: every request's tokens are identical to a solo engine serving
        only that adapter (both through the paged path, so the comparison is
        of bit-identical programs)."""
        cfg, params, template = setup
        reg = _registry(template)
        ranks = [4, 7, 3, 8, 5, 2, 6, 4]
        ads = {f"t{j}": _rand_adapter(cfg, params, r, 10 + j)
               for j, r in enumerate(ranks)}
        ids = {n: reg.register(n, a) for n, a in ads.items()}
        assert len(reg.live_ids) >= 8

        gp = SamplingParams(max_tokens=4)
        prompts = {n: [3 + j, 17 + j] for j, n in enumerate(ads)}
        eng = _engine(cfg, params, reg)
        uids = {n: eng.submit(prompts[n], gp, adapter_id=ids[n]) for n in ads}
        ub = eng.submit([29, 31], gp)                  # base row rides along
        multi = eng.run()

        for n in ads:
            solo_reg = _registry(template)
            aid = solo_reg.register(n, ads[n])
            solo = _engine(cfg, params, solo_reg)
            su = solo.submit(prompts[n], gp, adapter_id=aid)
            assert solo.run()[su] == multi[uids[n]], f"row for {n} diverged"
        base = ServeEngine(cfg, params, batch_slots=4, capacity=64, seed=0)
        bu = base.submit([29, 31], gp)
        assert base.run()[bu] == multi[ub]

    def test_zero_retraces_under_churn(self, setup):
        cfg, params, template = setup
        reg = _registry(template)
        i1 = reg.register("a", _rand_adapter(cfg, params, 4, 1))
        eng = _engine(cfg, params, reg, batch_slots=2)
        gp = SamplingParams(max_tokens=4)
        eng.submit([5, 6, 7], gp, adapter_id=i1)
        eng.run()
        baseline = dict(eng.trace_counts)
        assert baseline, "trace counter never fired"
        for s in range(5):
            reg.register(f"x{s}", _rand_adapter(cfg, params, 3 + s % 5, 20 + s))
        reg.swap("x0", _rand_adapter(cfg, params, 6, 30))
        reg.evict("x1")
        eng.submit([5, 6, 7], gp, adapter_id=reg.resolve("x2"))
        eng.run()
        assert dict(eng.trace_counts) == baseline, (
            f"adapter churn retraced: {baseline} -> {dict(eng.trace_counts)}")

    def test_hot_swap_mid_flight_leaves_tokens_unchanged(self, setup):
        cfg, params, template = setup

        def serve(do_swap):
            reg = _registry(template)
            i_old = reg.register("svc", _rand_adapter(cfg, params, 4, 42))
            eng = _engine(cfg, params, reg, batch_slots=2)
            uid = eng.submit([9, 10, 11], SamplingParams(max_tokens=10),
                             adapter_id=i_old)
            assert not eng.run_steps(4)               # still in flight
            if do_swap:
                i_new = reg.swap("svc", _rand_adapter(cfg, params, 6, 43))
                eng.submit([1, 2], SamplingParams(max_tokens=3),
                           adapter_id=i_new)          # new version serves too
            return eng.run()[uid]

        assert serve(False) == serve(True)

    def test_submit_validates_adapter_id(self, setup):
        cfg, params, template = setup
        reg = _registry(template)
        i1 = reg.register("a", _rand_adapter(cfg, params, 4, 1))
        eng = _engine(cfg, params, reg)
        with pytest.raises(KeyError, match="unknown or evicted"):
            eng.submit([1], adapter_id=7)
        reg.evict(i1)
        with pytest.raises(KeyError, match="unknown or evicted"):
            eng.submit([1], adapter_id=i1)
        no_reg = ServeEngine(cfg, params, batch_slots=2, capacity=64)
        with pytest.raises(ValueError, match="requires an engine"):
            no_reg.submit([1], adapter_id=1)
        with pytest.raises(ValueError, match="not both"):
            ServeEngine(cfg, params, adapters=template, registry=reg,
                        batch_slots=2, capacity=64)

    def test_reset_slot_clears_adapter_entry(self, setup):
        cfg, params, template = setup
        reg = _registry(template)
        i1 = reg.register("a", _rand_adapter(cfg, params, 4, 1))
        eng = _engine(cfg, params, reg, batch_slots=2)
        eng.submit([5, 6], SamplingParams(max_tokens=8), adapter_id=i1)
        eng.run_steps(2)
        assert int(eng._state["adapter_ids"][0]) == i1
        eng.reset_slot(0)
        assert int(eng._state["adapter_ids"][0]) == 0
        assert eng.slots[0] is None
        assert not bool(eng._state["active"][0])
        # cache row wiped alongside (length leaves may carry a layer axis)
        assert (np.asarray(eng.cache[0]["length"])[..., 0] == 0).all()
        with pytest.raises(ValueError, match="not occupied"):
            eng.reset_slot(0)


class TestBaseOnlyPath:
    def test_base_only_step_compiles_no_lora_dots(self, setup):
        """adapters=None must not pay ANY adapter math: the compiled step
        contains no ``lora_delta``-scoped ops, and its jaxpr has strictly
        fewer dots than the single-tenant adapter step."""
        cfg, params, template = setup
        eng = ServeEngine(cfg, params, batch_slots=2, capacity=32)
        step = _build_engine_step(cfg, 1, False)
        hlo_none = jax.jit(step).lower(
            params, None, eng.cache, eng._state).compile().as_text()
        assert "lora_delta" not in hlo_none
        hlo_ad = jax.jit(step).lower(
            params, template, eng.cache, eng._state).compile().as_text()
        assert "lora_delta" in hlo_ad                 # marker is detectable
        dots_none = _count_dots(jax.make_jaxpr(step)(
            params, None, eng.cache, eng._state).jaxpr)
        dots_ad = _count_dots(jax.make_jaxpr(step)(
            params, template, eng.cache, eng._state).jaxpr)
        assert dots_none < dots_ad
