"""Serving engine, Trainer (resume), and DP mechanism."""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.config import FedConfig, LoRAConfig, ModelConfig, OptimConfig
from repro.configs import get_smoke_config, lora_targets
from repro.models import transformer as T


class TestServeEngine:
    @pytest.fixture(scope="class")
    def engine_setup(self):
        from repro.serve.engine import ServeEngine
        cfg = get_smoke_config("qwen2-0.5b")
        params = T.init(cfg, jax.random.PRNGKey(0))
        return cfg, params

    def test_greedy_completion(self, engine_setup):
        from repro.serve.engine import SamplingParams, ServeEngine
        cfg, params = engine_setup
        eng = ServeEngine(cfg, params, batch_slots=2, capacity=64)
        uid = eng.submit([5, 6, 7], SamplingParams(max_tokens=8))
        out = eng.run()
        assert len(out[uid]) == 8
        assert all(0 <= t < cfg.vocab_size for t in out[uid])

    def test_more_requests_than_slots(self, engine_setup):
        from repro.serve.engine import SamplingParams, ServeEngine
        cfg, params = engine_setup
        eng = ServeEngine(cfg, params, batch_slots=2, capacity=64)
        uids = [eng.submit([3 + i], SamplingParams(max_tokens=4))
                for i in range(5)]
        out = eng.run()
        assert set(out) == set(uids)
        assert all(len(v) == 4 for v in out.values())

    def test_greedy_deterministic(self, engine_setup):
        from repro.serve.engine import SamplingParams, ServeEngine
        cfg, params = engine_setup
        outs = []
        for _ in range(2):
            eng = ServeEngine(cfg, params, batch_slots=1, capacity=64)
            uid = eng.submit([9, 10], SamplingParams(max_tokens=6))
            outs.append(tuple(eng.run()[uid]))
        assert outs[0] == outs[1]

    def test_empty_prompt_seeds_token_zero(self, engine_setup):
        """An empty-prompt request must not sample its first token from the
        stale last-token slot value of a previous occupant — defined
        behavior is to seed generation from token 0."""
        from repro.serve.engine import SamplingParams, ServeEngine
        cfg, params = engine_setup
        eng = ServeEngine(cfg, params, batch_slots=1, capacity=64)
        # first request leaves a stale last-token behind in slot 0
        first = eng.submit([5, 6], SamplingParams(max_tokens=3))
        out1 = eng.run()
        assert int(eng._state["last_token"][0]) == out1[first][-1]
        uid = eng.submit([], SamplingParams(max_tokens=4))
        # admission re-seeds the slot's feed token to 0
        eng._admit()
        assert int(eng._state["last_token"][0]) == 0
        out2 = eng.run()
        assert len(out2[uid]) == 4
        assert all(0 <= t < cfg.vocab_size for t in out2[uid])
        # and the output equals an empty-prompt request on a fresh engine
        fresh = ServeEngine(cfg, params, batch_slots=1, capacity=64)
        fu = fresh.submit([], SamplingParams(max_tokens=4))
        assert out2[uid] == fresh.run()[fu]

    def test_stop_token_excluded_from_output(self, engine_setup):
        """The stop token completes the request but is NOT emitted."""
        from repro.serve.engine import SamplingParams, ServeEngine
        cfg, params = engine_setup
        eng = ServeEngine(cfg, params, batch_slots=1, capacity=64)
        u = eng.submit([7, 8], SamplingParams(max_tokens=10))
        ref = eng.run()[u]
        stop = ref[3]
        eng2 = ServeEngine(cfg, params, batch_slots=1, capacity=64)
        u2 = eng2.submit([7, 8], SamplingParams(max_tokens=10, stop_token=stop))
        got = eng2.run()[u2]
        assert got == ref[:3]
        assert stop not in got

    def test_straggler_drain_frees_slots(self, engine_setup):
        """A request cut off by max_steps is reported truncated, marked
        done, and its slot freed — a second run() neither double-reports
        nor re-decodes it."""
        from repro.serve.engine import SamplingParams, ServeEngine
        cfg, params = engine_setup
        eng = ServeEngine(cfg, params, batch_slots=1, capacity=64)
        u1 = eng.submit([3, 4], SamplingParams(max_tokens=30))
        r1 = eng.run(max_steps=4)
        assert 0 < len(r1[u1]) < 30          # truncated partial output
        assert eng.slots[0] is None          # slot freed
        u2 = eng.submit([5], SamplingParams(max_tokens=3))
        r2 = eng.run()
        assert u1 not in r2                  # no double-report
        assert len(r2[u2]) == 3

    def test_sampling_invariant_to_slot_placement(self, engine_setup):
        """Per-request PRNG streams are keyed by (seed, uid): the same
        submissions produce bit-identical outputs whatever batch_slots (and
        hence slot placement / batching interleave) the engine runs."""
        from repro.serve.engine import SamplingParams, ServeEngine
        cfg, params = engine_setup
        prompts = [[11, 12], [13, 14, 15], [16]]
        sp = SamplingParams(temperature=0.9, top_k=20, max_tokens=5)
        outs = []
        for bs in (1, 3):
            eng = ServeEngine(cfg, params, batch_slots=bs, capacity=64, seed=7)
            uids = [eng.submit(list(p), sp) for p in prompts]
            out = eng.run()
            outs.append([out[u] for u in uids])
        assert outs[0] == outs[1]

    def test_greedy_rows_unaffected_by_sampled_neighbors(self, engine_setup):
        """Greedy and sampled requests may share a batch (the stochastic
        step variant handles both); a greedy request's tokens must match a
        greedy-only engine, and a sampled request's tokens must not depend
        on the greedy neighbor."""
        from repro.serve.engine import SamplingParams, ServeEngine
        cfg, params = engine_setup
        gsp = SamplingParams(max_tokens=5)
        ssp = SamplingParams(temperature=0.9, top_k=20, max_tokens=5)
        eng = ServeEngine(cfg, params, batch_slots=2, capacity=64, seed=3)
        g = eng.submit([5, 6], gsp)
        s = eng.submit([7, 8], ssp)
        mixed = eng.run()
        solo = ServeEngine(cfg, params, batch_slots=2, capacity=64, seed=3)
        g2 = solo.submit([5, 6], gsp)
        assert mixed[g] == solo.run()[g2]
        # greedy-only engines compile the argmax-only variant
        assert all(k[1] == "greedy" for k in solo.trace_counts)
        assert all(k[1] == "sampled" for k in eng.trace_counts)

    def test_jitted_step_no_retrace(self, engine_setup):
        """After warmup the engine reuses a fixed set of compiled
        executables (chunked prefill width, decode width 1, scanned decode
        burst) across admissions, slot churn, and repeated runs — every
        executable compiles exactly once."""
        from repro.serve.engine import SamplingParams, ServeEngine
        cfg, params = engine_setup
        eng = ServeEngine(cfg, params, batch_slots=2, capacity=64)
        for p in ([1, 2, 3], [4], [5, 6]):
            eng.submit(p, SamplingParams(max_tokens=4))
        eng.run()
        counts = dict(eng.trace_counts)
        assert all(v == 1 for v in counts.values())
        assert len(counts) <= 3
        for p in ([7, 8], [9]):
            eng.submit(p, SamplingParams(max_tokens=6))
        eng.run()
        assert eng.trace_counts == counts       # zero retraces

    def test_sampling_respects_top_k(self):
        from repro.serve.engine import SamplingParams, sample_logits
        logits = jnp.asarray([10.0, 9.0, -5.0, -5.0])
        for seed in range(10):
            t = int(sample_logits(logits, SamplingParams(temperature=1.0, top_k=2),
                                  jax.random.PRNGKey(seed)))
            assert t in (0, 1)

    def test_top_p_filters_tail(self):
        from repro.serve.engine import SamplingParams, sample_logits
        logits = jnp.asarray([10.0, 0.0, 0.0, 0.0])
        for seed in range(10):
            t = int(sample_logits(logits,
                                  SamplingParams(temperature=1.0, top_p=0.9),
                                  jax.random.PRNGKey(seed)))
            assert t == 0


class TestTrainer:
    def _mk(self, tmp_path):
        from repro.train.trainer import Trainer, TrainerConfig
        cfg = get_smoke_config("qwen2-0.5b")
        tcfg = TrainerConfig(steps=6, eval_every=3, ckpt_every=3,
                             ckpt_path=str(tmp_path / "ck.npz"), loss_chunk=8)
        return Trainer(cfg, LoRAConfig(rank=4, alpha=4.0), OptimConfig(lr=1e-3),
                       tcfg, targets=lora_targets(cfg)), cfg

    def _batches(self, cfg, n=100):
        rng = np.random.default_rng(0)
        for _ in range(n):
            yield {"tokens": rng.integers(0, cfg.vocab_size, (2, 16)),
                   "loss_mask": np.ones((2, 16), np.float32)}

    def test_fit_and_history(self, tmp_path):
        tr, cfg = self._mk(tmp_path)
        hist = tr.fit(self._batches(cfg), steps=4)
        assert len(hist) == 4 and np.isfinite(hist[-1]["loss"])

    def test_checkpoint_resume(self, tmp_path):
        tr, cfg = self._mk(tmp_path)
        tr.fit(self._batches(cfg), steps=3)   # ckpt at step 3
        tr2, _ = self._mk(tmp_path)
        step = tr2.restore_ckpt()
        assert step == 3
        a1 = jax.tree.leaves(tr.adapters)
        a2 = jax.tree.leaves(tr2.adapters)
        for x, y in zip(a1, a2):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


class TestPrivacy:
    def test_clip_bounds_norm(self, rng):
        from repro.core.privacy import clip_update, global_l2
        tree = {"a": jnp.asarray(rng.normal(size=(8, 8)) * 10, jnp.float32)}
        clipped, n = clip_update(tree, 1.0)
        assert float(global_l2(clipped)) <= 1.0 + 1e-5
        small = {"a": jnp.asarray(rng.normal(size=(8, 8)) * 1e-3, jnp.float32)}
        same, _ = clip_update(small, 1.0)
        np.testing.assert_array_equal(np.asarray(same["a"]), np.asarray(small["a"]))

    def test_clip_anchored_at_init(self, rng):
        from repro.core.privacy import clip_client_adapters, global_l2, tree_sub
        init = {"x": {"A": jnp.zeros((4, 8)), "B": jnp.ones((8, 4)),
                      "scale": jnp.asarray(1.0)}}
        trained = {"x": {"A": jnp.full((4, 8), 5.0), "B": jnp.ones((8, 4)),
                         "scale": jnp.asarray(1.0)}}
        out = clip_client_adapters(trained, init, clip_norm=1.0)
        delta = tree_sub(out, init)
        assert float(global_l2(delta)) <= 1.0 + 1e-5

    def test_noise_zero_sigma_identity(self, rng):
        from repro.core.privacy import add_gaussian_noise
        tree = {"A": jnp.asarray(rng.normal(size=(4, 4)), jnp.float32)}
        out = add_gaussian_noise(tree, 0.0, 1.0, 10, jax.random.PRNGKey(0))
        np.testing.assert_array_equal(np.asarray(out["A"]), np.asarray(tree["A"]))

    def test_dp_federated_round_runs(self):
        from repro.core.federated import FederatedTrainer
        cfg = ModelConfig(name="dp-tiny", family="dense", num_layers=2,
                          d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
                          d_ff=128, vocab_size=256, dtype="float32")
        fed = FedConfig(num_clients=8, clients_per_round=3, method="florist",
                        tau=0.9, homogeneous_rank=8, seed=0)
        tr = FederatedTrainer(cfg, fed, LoRAConfig(rank=8, alpha=8.0),
                              OptimConfig(lr=3e-3), batch_size=8,
                              local_steps=2, seq_len=32,
                              dp_clip=1.0, dp_sigma=0.1)
        hist = tr.run(2)
        assert all(np.isfinite(h.eval_loss) for h in hist)

    def test_sigma_calibration(self):
        from repro.core.privacy import noise_multiplier_for_epsilon
        assert noise_multiplier_for_epsilon(1.0) > noise_multiplier_for_epsilon(8.0)
