"""The declarative HLO audit layer: text parsing, check builders, and the
``serve.decode_step`` audit — including a genuine compiled regression
(a dense-score-buffer lowering must fail the streamed-decode audit)."""
import jax
import pytest

from repro.analysis.hlo_audit import (audit_names, collective_bytes,
                                      collective_budget, forbid_collective,
                                      forbid_shapes, get_audit, iter_ops,
                                      require_collective, run_audit,
                                      shape_bytes)
from repro.common.config import ModelConfig

CANNED = """\
HloModule step

ENTRY %main (p0: f32[4,64]) -> f32[4,64] {
  %p0 = f32[4,64] parameter(0)
  %ar = f32[4,64] all-reduce(%p0), replica_groups={}
  %ag.1 = f32[8,64] all-gather-start(%p0), dimensions={0}
  %ag.2 = f32[8,64] all-gather-done(%ag.1)
  ROOT %out = f32[4,64] add(%ar, %p0)
}
"""


def test_shape_bytes():
    assert shape_bytes("f32[4,64]") == 4 * 64 * 4
    assert shape_bytes("(f32[2,2], s8[8])") == 16 + 8
    assert shape_bytes("bf16[3]") == 6
    assert shape_bytes("token[]") == 0


def test_collective_bytes_folds_async_halves():
    totals = collective_bytes(CANNED)
    assert totals["all-reduce"] == 4 * 64 * 4
    # -start and -done both parse onto the base op
    assert totals["all-gather"] == 2 * 8 * 64 * 4
    assert totals["all-to-all"] == 0


def test_iter_ops():
    ops = [op for op, _, _ in iter_ops(CANNED)]
    assert "all-reduce" in ops and "add" in ops


def test_check_builders():
    assert forbid_collective("all-to-all")(CANNED, {}) == []
    assert forbid_collective("all-reduce")(CANNED, {}) != []
    assert require_collective("all-reduce")(CANNED, {}) == []
    assert require_collective("reduce-scatter")(CANNED, {}) != []
    gated = require_collective("reduce-scatter",
                               when=lambda ctx: ctx["mesh"] > 1)
    assert gated(CANNED, {"mesh": 1}) == []
    assert gated(CANNED, {"mesh": 8}) != []
    assert collective_budget(lambda ctx: 10 ** 9)(CANNED, {}) == []
    over = collective_budget(lambda ctx: 1, "tiny")(CANNED, {})
    assert over and "exceed" in over[0]
    hit = forbid_shapes(lambda ctx: ["f32[8,64]"], "test")(CANNED, {})
    assert hit and "f32[8,64]" in hit[0]
    assert forbid_shapes(lambda ctx: ["f32[9,9]"])(CANNED, {}) == []


def test_registry():
    assert "serve.decode_step" in audit_names()
    with pytest.raises(KeyError):
        get_audit("no.such.audit")


# -- the serve.decode_step audit on real compiled artifacts -------------------

TINY = ModelConfig(name="hlo-audit-tiny", family="dense", num_layers=2,
                   d_model=64, num_heads=8, num_kv_heads=8, head_dim=16,
                   d_ff=128, vocab_size=256, dtype="float32")


def _compiled_step_text(decode_impl, B=4, cap=512):
    from repro.models import transformer as T
    from repro.serve.engine import ServeEngine
    params = T.init(TINY, jax.random.PRNGKey(0))
    eng = ServeEngine(TINY, params, batch_slots=B, capacity=cap,
                      prefill_chunk=8, decode_impl=decode_impl)
    return eng.lower_step(width=1, stochastic=False).compile().as_text()


def _ctx(decode_impl, mesh=1, B=4, cap=512):
    return {"cfg": TINY, "mesh": mesh, "batch": B, "capacity": cap,
            "width": 1, "decode_impl": decode_impl}


def test_streamed_step_passes_audit():
    txt = _compiled_step_text("streamed")
    assert run_audit("serve.decode_step", txt, _ctx("streamed")) == []


def test_dense_score_buffer_regression_fails_audit():
    """The regression CI must catch: if the streamed interior ever
    rematerializes a dense (B,H,C,cap) score buffer, the audit fails.
    The dense oracle genuinely materializes one, so auditing its lowering
    under the streamed claim must flag exactly that."""
    txt = _compiled_step_text("dense")
    failures = run_audit("serve.decode_step", txt, _ctx("dense"))
    assert failures == [], "dense impl makes no streaming claim"
    failures = run_audit("serve.decode_step", txt, _ctx("streamed"))
    assert failures, "dense score buffers must fail the streamed audit"
    assert any("forbidden buffers" in f for f in failures), failures


def test_meshless_step_schedules_no_collectives():
    txt = _compiled_step_text("streamed")
    assert sum(collective_bytes(txt).values()) == 0
