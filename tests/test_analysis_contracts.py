"""The abstract contract checker: full-matrix run on the repo's own
registrations (zero FLOPs, bounded wall-clock), fixture fidelity, and
fail-loud detection of seeded violations (fp64 upcast, host callback,
kernel/twin drift, non-divisible pspec)."""
import time

import jax
import jax.numpy as jnp
import pytest

from repro.analysis import fixtures as FX
from repro.analysis.contracts import (jaxpr_violations, pspec_violations,
                                      run_all, run_case)
from repro.analysis.registry import (Case, ContractCase, _Entry,
                                     contract_entries, load_registrations)


# -- the repo's own contracts -------------------------------------------------

def test_registry_covers_major_entrypoints():
    names = set(load_registrations())
    expected = {"train.step", "serve.step", "serve.engine_step",
                "serve.decode_burst", "agg.florist_finalize", "agg.thin_svd",
                "agg.sharded_florist", "kernel.ring_decode",
                "kernel.mla_ring_decode", "kernel.bgmv", "kernel.wkv6",
                "kernel.flash_attention", "kernel.lora_matmul",
                "kernel.adapter_gram"}
    assert expected <= names, expected - names
    assert len(names) >= 8


def test_full_matrix_passes_within_budget():
    """Every registered contract across {dense,streamed,kernel} x mesh
    {1,2} passes abstractly in well under a minute of CPU."""
    t0 = time.perf_counter()
    results = run_all()
    elapsed = time.perf_counter() - t0
    failed = [r for r in results if r.status == "fail"]
    assert not failed, "\n".join(
        f"{r.contract} {r.case}: {r.errors}" for r in failed)
    ran = [r for r in results if r.status == "ok"]
    assert len(ran) >= 60, len(ran)
    impls = {r.case.split("/")[1] for r in ran}
    meshes = {r.case.split("/")[2] for r in ran}
    assert impls == {"dense", "streamed", "kernel"}
    assert meshes == {"mesh1", "mesh2"}
    assert elapsed < 60, f"contract matrix took {elapsed:.1f}s"


def test_engine_state_fixture_matches_engine():
    """The aval mirror in fixtures must stay in lockstep with
    ``ServeEngine.__init__`` — drift would silently weaken the engine
    fixed-point contracts."""
    from repro.models import transformer as T
    from repro.serve.engine import ServeEngine
    cfg = FX.tiny_config("gqa")
    params = T.init(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, batch_slots=FX.BATCH_SLOTS,
                      capacity=FX.CAPACITY, max_tokens_cap=FX.OUT_CAP,
                      prefill_chunk=FX.CHUNK)
    assert FX.avals_equal(eng._state, FX.engine_state()), \
        "fixtures.engine_state drifted from ServeEngine.__init__"


# -- seeded violations --------------------------------------------------------

def _entry(name, build, **axes):
    axes.setdefault("families", ("gqa",))
    axes.setdefault("decode_impls", ("dense",))
    axes.setdefault("mesh_sizes", (1,))
    return _Entry(name, build, axes["families"], axes["decode_impls"],
                  axes["mesh_sizes"])


_SEEDED = iter(range(10 ** 6))


def _run_one(build):
    # unique name per seeded contract: the checker memoizes traces by
    # (contract, family, impl), exactly like real registrations
    return run_case(_entry(f"seeded-{next(_SEEDED)}", build),
                    Case("gqa", "dense", 1))


def test_detects_fp64_upcast():
    def build(case):
        def bad(x):
            return x.astype(jnp.float64) + 1.0
        return ContractCase(bad, (FX.sds((4,), "float32"),))

    res = _run_one(build)
    assert res.status == "fail"
    assert any("float64" in e for e in res.errors), res.errors


def test_detects_host_callback():
    import numpy as np

    def build(case):
        def bad(x):
            return jax.pure_callback(
                lambda v: np.asarray(v), jax.ShapeDtypeStruct(x.shape, x.dtype), x)
        return ContractCase(bad, (FX.sds((4,), "float32"),))

    res = _run_one(build)
    assert res.status == "fail"
    assert any("callback" in e for e in res.errors), res.errors


def test_detects_twin_aval_drift():
    def build(case):
        args = (FX.sds((4, 8), "float32"),)
        return ContractCase(lambda x: x.sum(0), args,
                            twin=(lambda x: x.sum(1), args))

    res = _run_one(build)
    assert res.status == "fail"
    assert any("twin" in e for e in res.errors), res.errors


def test_detects_retrace_hazard_via_out_check():
    """A step whose output avals drift from its inputs retraces every
    call — the fixed-point out_check is the abstract retrace detector."""
    def build(case):
        state = FX.sds((4,), "float32")

        def grows(s):
            return jnp.concatenate([s, s])      # aval drift: (4,) -> (8,)

        def out_check(out, _case):
            assert FX.avals_equal(out, state), "state avals drift"

        return ContractCase(grows, (state,), out_check=out_check)

    res = _run_one(build)
    assert res.status == "fail"
    assert any("drift" in e for e in res.errors), res.errors


def test_detects_nondivisible_pspec():
    from jax.sharding import PartitionSpec as P
    mesh = FX.abstract_mesh(2)
    # 7 does not divide by the model axis (2)
    errs = pspec_violations({"w": FX.sds((4, 7), "float32")},
                            {"w": P(None, "model")}, mesh)
    assert errs and "not divisible" in errs[0]
    # divisible shard + replicated leaf are clean
    assert pspec_violations({"w": FX.sds((4, 8), "float32")},
                            {"w": P(None, "model")}, mesh) == []
    assert pspec_violations({"w": FX.sds((4, 7), "float32")},
                            {"w": P()}, mesh) == []


def test_pspec_unknown_axis_and_rank_overflow():
    from jax.sharding import PartitionSpec as P
    mesh = FX.abstract_mesh(2)
    errs = pspec_violations({"w": FX.sds((4,), "float32")},
                            {"w": P("bogus")}, mesh)
    assert errs and "unknown mesh axis" in errs[0]
    errs = pspec_violations({"w": FX.sds((4,), "float32")},
                            {"w": P("data", "model")}, mesh)
    assert errs and "more axes than array rank" in errs[0]


def test_clean_jaxpr_has_no_violations():
    def fine(x):
        return jnp.sin(x) * 2.0

    with jax.experimental.enable_x64():
        closed = jax.make_jaxpr(fine)(FX.sds((4,), "float32"))
    assert jaxpr_violations(closed) == []


def test_f64_ban_sees_through_nesting():
    """The jaxpr walker must reach pjit/scan sub-jaxprs."""
    def bad(x):
        def body(c, v):
            return c, v.astype(jnp.float64)
        return jax.lax.scan(body, 0.0, x)[1]

    with jax.experimental.enable_x64():
        closed = jax.make_jaxpr(bad)(FX.sds((4,), "float32"))
    assert any("float64" in v for v in jaxpr_violations(closed))


def test_build_exception_is_a_failure_not_a_crash():
    def build(case):
        raise RuntimeError("boom")

    res = _run_one(build)
    assert res.status == "fail"
    assert "RuntimeError" in res.errors[0]


def test_case_skip_when_build_returns_none():
    res = _run_one(lambda case: None)
    assert res.status == "skip" and res.errors == []


# -- CLI ----------------------------------------------------------------------

def test_cli_select_and_exit_code():
    from repro.analysis.contracts import main
    assert main(["--select", "agg.thin_svd"]) == 0
    with pytest.raises(SystemExit):
        main(["--no-such-flag"])


def test_abstract_mesh_axis_size():
    """axis_size reads name->size off ``mesh.shape``, so device-free
    AbstractMesh widths validate on a 1-device host."""
    from repro.topology import axis_size
    mesh = FX.abstract_mesh(4)
    assert axis_size(mesh, "model") == 4
    assert axis_size(mesh, "data") == 1
    assert axis_size(mesh, "absent") == 1
    real = jax.make_mesh((1, 1), ("data", "model"))
    assert axis_size(real, "model") == 1


def test_contract_entries_respect_matrix_slices():
    load_registrations()
    entries = contract_entries()
    kernel_cases = entries["kernel.ring_decode"].cases()
    assert all(c.mesh == 1 for c in kernel_cases)
    engine_cases = entries["serve.engine_step"].cases()
    assert {c.decode_impl for c in engine_cases} == \
        {"dense", "streamed", "kernel"}
    assert {c.mesh for c in engine_cases} == {1, 2}
