"""LoRA adapter tree machinery."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config, lora_targets
from repro.models import transformer as T
from repro.peft.lora import (adapter_num_params, init_lora, lora_proj,
                             match_rank, merge_lora, target_leaves)


@pytest.fixture
def setup():
    cfg = get_smoke_config("qwen2-0.5b")
    params = T.init(cfg, jax.random.PRNGKey(0))
    adapters = init_lora(params, lora_targets(cfg), 8, 16.0,
                         jax.random.PRNGKey(1))
    return cfg, params, adapters


def test_targets_found(setup):
    cfg, params, adapters = setup
    leaves = target_leaves(params, lora_targets(cfg))
    assert len(leaves) == 4          # wq, wk, wv, wo (stacked over layers)
    paths = {l[0][-1] for l in leaves}
    assert paths == {"wq", "wk", "wv", "wo"}


def test_b_zero_init_means_identity(setup):
    """Fresh adapters must not change the model (B = 0)."""
    cfg, params, adapters = setup
    toks = jnp.arange(2 * 16).reshape(2, 16) % cfg.vocab_size
    h0, _ = T.forward(cfg, params, {"tokens": toks})
    h1, _ = T.forward(cfg, params, {"tokens": toks}, adapters)
    np.testing.assert_allclose(np.asarray(h0), np.asarray(h1), atol=1e-6)


def test_merge_equals_adapter_forward(setup):
    cfg, params, adapters = setup
    adapters = jax.tree.map(
        lambda x: x + 0.02 if x.ndim >= 2 else x, adapters)
    toks = jnp.arange(2 * 16).reshape(2, 16) % cfg.vocab_size
    h_ad, _ = T.forward(cfg, params, {"tokens": toks}, adapters)
    merged = merge_lora(params, adapters)
    h_merged, _ = T.forward(cfg, merged, {"tokens": toks})
    np.testing.assert_allclose(np.asarray(h_ad), np.asarray(h_merged),
                               rtol=1e-4, atol=1e-4)


def test_lora_proj_math(rng):
    x = jnp.asarray(rng.normal(size=(3, 10, 16)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(16, 8)), jnp.float32)
    ad = {"A": jnp.asarray(rng.normal(size=(4, 16)), jnp.float32),
          "B": jnp.asarray(rng.normal(size=(8, 4)), jnp.float32),
          "scale": jnp.asarray(0.5)}
    y = lora_proj(x, w, ad)
    expect = x @ w + 0.5 * (x @ ad["A"].T) @ ad["B"].T
    np.testing.assert_allclose(np.asarray(y), np.asarray(expect),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("r_from,r_to", [(8, 4), (8, 16), (8, 8)])
def test_match_rank_shapes(setup, r_from, r_to):
    cfg, params, adapters = setup
    out = match_rank(adapters, r_to)
    for path, leaf in jax.tree_util.tree_flatten_with_path(out)[0]:
        last = getattr(path[-1], "key", None)
        if last == "A":
            assert leaf.shape[-2] == r_to
        if last == "B":
            assert leaf.shape[-1] == r_to


def test_match_rank_truncation_preserves_top_directions(rng):
    """After truncation, B·A equals the top-r submatrix product."""
    ad = {"x": {"A": jnp.asarray(rng.normal(size=(8, 16)), jnp.float32),
                "B": jnp.asarray(rng.normal(size=(12, 8)), jnp.float32),
                "scale": jnp.asarray(1.0)}}
    tr = match_rank(ad, 4)
    expect = ad["x"]["B"][:, :4] @ ad["x"]["A"][:4]
    got = tr["x"]["B"] @ tr["x"]["A"]
    np.testing.assert_allclose(np.asarray(got), np.asarray(expect))


def test_match_rank_padding_keeps_product(rng):
    ad = {"x": {"A": jnp.asarray(rng.normal(size=(4, 16)), jnp.float32),
                "B": jnp.asarray(rng.normal(size=(12, 4)), jnp.float32),
                "scale": jnp.asarray(1.0)}}
    pd = match_rank(ad, 8)
    np.testing.assert_allclose(np.asarray(pd["x"]["B"] @ pd["x"]["A"]),
                               np.asarray(ad["x"]["B"] @ ad["x"]["A"]),
                               atol=1e-6)


def test_adapter_num_params(setup):
    cfg, params, adapters = setup
    n = adapter_num_params(adapters)
    # 4 targets × L layers × r × (in + out)
    L, d, r = cfg.num_layers, cfg.d_model, 8
    H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    expect = L * r * ((d + H * hd) + 2 * (d + K * hd) + (H * hd + d))
    assert n == expect
