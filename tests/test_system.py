"""System-level integration: the full paper pipeline end to end, plus the
headline comparative claims on one shared run.

Everything here is marked ``slow`` (multi-method multi-round federated
loops) and excluded from the default tier-1 run; select with
``pytest -m slow``."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.config import FedConfig, LoRAConfig, ModelConfig, OptimConfig
from repro.core import costs as C
from repro.core.federated import FederatedTrainer

pytestmark = pytest.mark.slow

CFG = ModelConfig(name="sys-tiny", family="dense", num_layers=2, d_model=64,
                  num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
                  vocab_size=256, dtype="float32")


@pytest.fixture(scope="module")
def runs():
    """One 4-round run per method on identical data/seed."""
    out = {}
    for method in ("florist", "fedit", "ffa", "flora", "flexlora"):
        fed = FedConfig(num_clients=16, clients_per_round=5, method=method,
                        tau=0.9, homogeneous_rank=8, seed=1)
        tr = FederatedTrainer(CFG, fed, LoRAConfig(rank=8, alpha=8.0),
                              OptimConfig(lr=3e-3), batch_size=8,
                              local_steps=3, seq_len=32)
        out[method] = (tr.run(4), tr)
    return out


def test_all_methods_learn(runs):
    for method, (hist, _) in runs.items():
        assert hist[-1].eval_loss < hist[0].eval_loss + 0.02, method


def test_florist_most_download_efficient(runs):
    """Headline claim: FLoRIST has the best download communication
    efficiency among the two-adapter methods (FFA halves params by
    construction but fell behind in accuracy in the paper)."""
    down = {m: h[-1].download_params for m, (h, _) in runs.items()}
    assert down["florist"] < down["fedit"]
    assert down["florist"] < down["flora"]
    assert down["florist"] < down["flexlora"]


def test_florist_accuracy_competitive(runs):
    """FLoRIST loss within a small margin of the best method.  (4 rounds on
    a tiny model — differences are ~1e-2; the paper's ±1% claim is over 75
    rounds, exercised in benchmarks/table2.)"""
    losses = {m: h[-1].eval_loss for m, (h, _) in runs.items()}
    best = min(losses.values())
    assert losses["florist"] <= best + 0.1


def test_rank_ordering_on_live_run(runs):
    r = {m: h[-1].download_rank for m, (h, _) in runs.items()}
    # Rank: FLoRIST < FlexLoRA <= FedIT < FLoRA (paper §3)
    assert r["florist"] < r["fedit"] < r["flora"]


def test_comm_accounting_consistency(runs):
    """upload == K clients × adapter params; download scales with rank."""
    hist, tr = runs["florist"]
    rec = hist[-1]
    assert rec.upload_params > 0
    assert rec.download_params < rec.upload_params * tr.fed.clients_per_round


def test_gram_svd_backend_end_to_end():
    """The TPU (Gram/eigh) SVD route drives the same pipeline."""
    fed = FedConfig(num_clients=8, clients_per_round=3, method="florist",
                    tau=0.9, homogeneous_rank=8, seed=0)
    tr = FederatedTrainer(CFG, fed, LoRAConfig(rank=8, alpha=8.0),
                          OptimConfig(lr=3e-3), batch_size=8, local_steps=2,
                          seq_len=32, svd_method="gram")
    hist = tr.run(2)
    assert np.isfinite(hist[-1].eval_loss)
