"""The pluggable Aggregator API: registry, streaming lifecycle equivalence
with the legacy one-shot ``aggregate()`` shim, client-init semantics, and
the per-class cost model."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import costs as C
from repro.core.aggregation import aggregate
from repro.core.aggregators import (AggResult, Aggregator, METHODS,
                                    adapter_leaf_paths, available_aggregators,
                                    get_path, leaf_dims, make_aggregator,
                                    register_aggregator)

HOMOG = [8, 8, 8]
HETER = [4, 8, 16]


def _client_tree(rng, L, m, n, r, scale=1.0):
    return {"blocks": {0: {"attn": {"wq": {
        "A": jnp.asarray(rng.normal(size=(L, r, n)), jnp.float32),
        "B": jnp.asarray(rng.normal(size=(L, m, r)), jnp.float32),
        "scale": jnp.full((L,), scale, jnp.float32),
    }}}}}


def _make_clients(rng, ranks):
    trees = [_client_tree(rng, L=2, m=40, n=32, r=r) for r in ranks]
    weights = [0.5, 0.3, 0.2]
    return trees, weights


def _shim_kwargs(method, trees, ranks):
    kw = {"zero_padding": True}
    if method == "ffa":
        kw["A_init"] = trees[0]
    if method == "florist":
        kw["tau"] = 0.9
    return kw


def _cfg_kwargs(method, trees):
    if method == "ffa":
        return {"A_init": trees[0], "zero_padding": True}
    if method == "fedit":
        return {"zero_padding": True}
    if method == "florist":
        return {"tau": 0.9}
    return {}


def _assert_trees_equal(t1, t2):
    assert (t1 is None) == (t2 is None)
    if t1 is None:
        return
    paths1, paths2 = adapter_leaf_paths(t1), adapter_leaf_paths(t2)
    assert paths1 == paths2
    for p in paths1:
        l1, l2 = get_path(t1, p), get_path(t2, p)
        for k in ("A", "B", "scale"):
            np.testing.assert_array_equal(np.asarray(l1[k]),
                                          np.asarray(l2[k]), err_msg=str((p, k)))


def _assert_results_equal(r1: AggResult, r2: AggResult):
    assert r1.method == r2.method
    assert r1.ranks == r2.ranks
    assert r1.merge_into_base == r2.merge_into_base
    assert set(r1.spectra) == set(r2.spectra)
    for p in r1.spectra:
        for s1, s2 in zip(r1.spectra[p], r2.spectra[p]):
            np.testing.assert_array_equal(s1, s2)
    _assert_trees_equal(r1.global_adapters, r2.global_adapters)
    assert (r1.per_client is None) == (r2.per_client is None)
    if r1.per_client is not None:
        assert len(r1.per_client) == len(r2.per_client)
        for c1, c2 in zip(r1.per_client, r2.per_client):
            _assert_trees_equal(c1, c2)


class TestStreamingEquivalence:
    """Incremental add_client/finalize must match the one-shot shim
    bit-for-bit, homogeneous and heterogeneous."""

    @pytest.mark.parametrize("method", METHODS)
    @pytest.mark.parametrize("ranks", [HOMOG, HETER],
                             ids=["homogeneous", "heterogeneous"])
    def test_matches_one_shot_shim(self, rng, method, ranks):
        trees, w = _make_clients(rng, ranks)
        legacy = aggregate(method, trees, w, client_ranks=ranks,
                           **_shim_kwargs(method, trees, ranks))
        strat = make_aggregator(method, **_cfg_kwargs(method, trees))
        strat.begin_round()
        for t, wk, rk in zip(trees, w, ranks):
            strat.add_client(t, wk, rank=rk)
        streamed = strat.finalize()
        _assert_results_equal(legacy, streamed)

    @pytest.mark.parametrize("method", METHODS)
    def test_aggregator_is_reusable_across_rounds(self, rng, method):
        """begin_round must fully reset per-round state."""
        trees, w = _make_clients(rng, HETER)
        strat = make_aggregator(method, **_cfg_kwargs(method, trees))
        first = strat.aggregate(trees, w, client_ranks=HETER)
        second = strat.aggregate(trees, w, client_ranks=HETER)
        _assert_results_equal(first, second)

    def test_upload_accounting_accumulates_per_client(self, rng):
        trees, w = _make_clients(rng, HETER)
        for method in ("florist", "ffa"):
            strat = make_aggregator(method, **_cfg_kwargs(method, trees))
            strat.aggregate(trees, w, client_ranks=HETER)
            assert strat.round_upload_params == C.upload_params(method, trees)

    def test_finalize_without_clients_raises(self):
        strat = make_aggregator("florist")
        strat.begin_round()
        with pytest.raises(ValueError):
            strat.finalize()

    def test_dims_captured_from_first_client(self, rng):
        trees, w = _make_clients(rng, HOMOG)
        strat = make_aggregator("fedit")
        strat.begin_round()
        strat.add_client(trees[0], w[0])
        assert strat.dims == leaf_dims(trees[0])


class TestRegistry:
    def test_paper_methods_registered(self):
        assert set(METHODS) <= set(available_aggregators())

    def test_unknown_method_raises(self):
        with pytest.raises(ValueError):
            make_aggregator("nope")

    def test_custom_aggregator_plugs_in(self, rng):
        """A third-party method is a single registered class — no edits to
        trainer / costs / dispatcher."""

        @register_aggregator("unit-test-sum")
        class SumAggregator(Aggregator):
            def _accumulate(self, update, weight, rank):
                for path in adapter_leaf_paths(update):
                    leaf = get_path(update, path)
                    acc = self._state.setdefault(
                        path, {"A": jnp.zeros_like(leaf["A"]),
                               "B": jnp.zeros_like(leaf["B"])})
                    acc["A"] = acc["A"] + weight * leaf["A"]
                    acc["B"] = acc["B"] + weight * leaf["B"]

            def _finalize(self):
                from repro.core.aggregators import set_path
                out = {}
                ranks = {}
                for path, acc in self._state.items():
                    set_path(out, path, {"A": acc["A"], "B": acc["B"],
                                         "scale": self._ref_scales[path]})
                    ranks[path] = [acc["A"].shape[-2]] * acc["A"].shape[0]
                return AggResult(self.name, out, None, ranks, {})

            def server_flops(self, dims, client_ranks, agg_ranks=None):
                return 0

        trees, w = _make_clients(rng, HOMOG)
        agg = make_aggregator("unit-test-sum").aggregate(trees, w)
        assert agg.method == "unit-test-sum"
        assert agg.total_download_rank() > 0


class TestClientInitSemantics:
    def _a_init(self, rng, L=2, m=40, n=32, r=16):
        t = _client_tree(rng, L, m, n, r)
        leaf = get_path(t, adapter_leaf_paths(t)[0])
        leaf["B"] = jnp.zeros_like(leaf["B"])
        return t

    def test_round_one_starts_at_base(self, rng):
        a_init = self._a_init(rng)
        init = make_aggregator("florist").client_init(None, 8, a_init)
        leaf = get_path(init, adapter_leaf_paths(init)[0])
        assert leaf["A"].shape[-2] == 8
        np.testing.assert_array_equal(np.asarray(leaf["B"]), 0.0)

    def test_flora_reinits_every_round(self, rng):
        trees, w = _make_clients(rng, HOMOG)
        strat = make_aggregator("flora")
        agg = strat.aggregate(trees, w)
        init = strat.client_init(agg, 8, self._a_init(rng))
        leaf = get_path(init, adapter_leaf_paths(init)[0])
        np.testing.assert_array_equal(np.asarray(leaf["B"]), 0.0)

    def test_ffa_keeps_frozen_a(self, rng):
        a_init = self._a_init(rng)
        trees, w = _make_clients(rng, HOMOG)
        strat = make_aggregator("ffa", A_init=a_init)
        agg = strat.aggregate(trees, w)
        init = strat.client_init(agg, 8, a_init)
        got = get_path(init, adapter_leaf_paths(init)[0])["A"]
        want = get_path(a_init, adapter_leaf_paths(a_init)[0])["A"][..., :8, :]
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_default_resumes_from_truncated_global(self, rng):
        trees, w = _make_clients(rng, HOMOG)
        strat = make_aggregator("florist", tau=1.0)
        agg = strat.aggregate(trees, w)
        init = strat.client_init(agg, 4, self._a_init(rng))
        leaf = get_path(init, adapter_leaf_paths(init)[0])
        assert leaf["A"].shape[-2] == 4
        g = get_path(agg.global_adapters,
                     adapter_leaf_paths(agg.global_adapters)[0])
        np.testing.assert_array_equal(np.asarray(leaf["A"]),
                                      np.asarray(g["A"][..., :4, :]))


class TestCostModelParity:
    """The registry-dispatched costs.* wrappers must match the per-class
    methods (the formulas moved, the numbers must not)."""

    def test_download_and_flops_dispatch(self, rng):
        trees, w = _make_clients(rng, HETER)
        dims = leaf_dims(trees[0])
        for method in METHODS:
            strat = make_aggregator(method, **_cfg_kwargs(method, trees))
            agg = strat.aggregate(trees, w, client_ranks=HETER)
            assert C.download_params(method, agg, dims, 3, HETER) == \
                strat.download_params(agg, dims, 3, HETER)
            assert C.server_flops(method, dims, HETER, agg.ranks) == \
                strat.server_flops(dims, HETER, agg.ranks)

    def test_ffa_half_rank_factor(self, rng):
        trees, w = _make_clients(rng, HOMOG)
        agg = make_aggregator("ffa", A_init=trees[0]).aggregate(trees, w)
        assert C.total_download_rank(agg) == agg.total_download_rank() / 2.0


class TestBatchedPipeline:
    """The batched finalize (one compiled vmapped call per bucket of
    equal-shaped leaves, one device→host transfer) must match the legacy
    per-(leaf, layer) loop."""

    def _hetero_trees(self, rng, spread=2.0):
        """Heterogeneous ranks with a per-layer energy spread so layers of
        the same leaf select different p_l."""
        trees = []
        for r in HETER:
            t = _client_tree(rng, L=3, m=40, n=32, r=r, scale=1.0)
            leaf = get_path(t, adapter_leaf_paths(t)[0])
            sc = jnp.asarray(spread ** np.arange(3), jnp.float32)
            leaf["B"] = leaf["B"] * sc[:, None, None]
            trees.append(t)
        return trees, [0.5, 0.3, 0.2]

    @pytest.mark.parametrize("svd_method", ["svd", "gram"])
    @pytest.mark.parametrize("tau,max_rank", [(0.9, 0), (0.9, 5), ("auto", 0)])
    def test_matches_loop(self, rng, svd_method, tau, max_rank):
        trees, w = self._hetero_trees(rng)
        loop = make_aggregator("florist", tau=tau, svd_method=svd_method,
                               max_rank=max_rank, pipeline="loop"
                               ).aggregate(trees, w, client_ranks=HETER)
        bat = make_aggregator("florist", tau=tau, svd_method=svd_method,
                              max_rank=max_rank
                              ).aggregate(trees, w, client_ranks=HETER)
        assert bat.ranks == loop.ranks
        for p in loop.spectra:
            for s1, s2 in zip(loop.spectra[p], bat.spectra[p]):
                np.testing.assert_allclose(s1, s2, rtol=1e-4, atol=1e-4)
        for p in adapter_leaf_paths(loop.global_adapters):
            l, b = (get_path(loop.global_adapters, p),
                    get_path(bat.global_adapters, p))
            assert l["B"].shape == b["B"].shape
            for layer in range(3):
                np.testing.assert_allclose(
                    np.asarray(l["B"][layer] @ l["A"][layer]),
                    np.asarray(b["B"][layer] @ b["A"][layer]),
                    rtol=1e-4, atol=1e-4)

    def test_layers_pick_different_ranks(self, rng):
        trees, w = self._hetero_trees(rng, spread=4.0)
        bat = make_aggregator("florist", tau=0.9).aggregate(
            trees, w, client_ranks=HETER)
        ps = next(iter(bat.ranks.values()))
        assert len(set(ps)) > 1        # the vmapped threshold is per-layer

    def test_equal_shaped_leaves_bucketed_one_call(self, rng, monkeypatch):
        """All equal-shaped leaves must go through a single compiled call."""
        import repro.core.aggregators.florist as F
        trees = []
        for r in HETER:
            t = _client_tree(rng, L=2, m=40, n=32, r=r)
            blk = t["blocks"][0]["attn"]
            blk["wk"] = {k: jnp.array(v) for k, v in blk["wq"].items()}
            trees.append(t)
        calls = []
        real = F.florist_core_batched

        def spy(*a, **kw):
            calls.append(a[0].shape)
            return real(*a, **kw)

        monkeypatch.setattr(F, "florist_core_batched", spy)
        res = make_aggregator("florist", tau=0.9).aggregate(
            trees, [0.5, 0.3, 0.2], client_ranks=HETER)
        assert len(calls) == 1                 # 2 leaves × 2 layers batched
        assert calls[0][0] == 4
        assert len(res.ranks) == 2

    def test_invalid_pipeline_rejected(self):
        with pytest.raises(ValueError):
            make_aggregator("florist", pipeline="nope")


def test_sharded_florist_max_rank_matches_host(rng):
    """Satellite regression: florist_sharded must produce the same ΔW as
    host florist under a rank cap (the padded core used to ignore it)."""
    from repro.core.distributed import ShardedFloristAggregator  # registers

    trees, w = _make_clients(rng, HETER)
    for tau, cap in ((0.95, 4), ("auto", 3)):
        host = make_aggregator("florist", tau=tau,
                               max_rank=cap).aggregate(trees, w)
        shard = make_aggregator("florist_sharded", tau=tau, svd_method="svd",
                                max_rank=cap).aggregate(trees, w)
        assert shard.ranks == host.ranks
        assert all(r <= cap for ps in shard.ranks.values() for r in ps)
        path = adapter_leaf_paths(trees[0])[0]
        h = get_path(host.global_adapters, path)
        s = get_path(shard.global_adapters, path)
        for l in range(2):
            np.testing.assert_allclose(
                np.asarray(h["B"][l] @ h["A"][l]),
                np.asarray(s["B"][l] @ s["A"][l]), rtol=1e-3, atol=1e-3)


def test_sharded_florist_backend_matches_host_deltaw(rng):
    """The registered multi-pod backend (florist_sharded) reconstructs the
    same ΔW as the host-side strategy at τ=1 on a single-device mesh."""
    from repro.core.distributed import ShardedFloristAggregator  # registers

    trees, w = _make_clients(rng, HETER)
    host = make_aggregator("florist", tau=1.0).aggregate(trees, w)
    sharded = make_aggregator("florist_sharded", tau=1.0,
                              svd_method="svd").aggregate(trees, w)
    path = adapter_leaf_paths(trees[0])[0]
    for l in range(2):
        h = get_path(host.global_adapters, path)
        s = get_path(sharded.global_adapters, path)
        np.testing.assert_allclose(
            np.asarray(h["B"][l] @ h["A"][l]),
            np.asarray(s["B"][l] @ s["A"][l]), rtol=1e-3, atol=1e-3)
