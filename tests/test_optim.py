"""Pure-JAX optimizers."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.config import OptimConfig
from repro.optim.adamw import (adamw_init, adamw_update, clip_by_global_norm,
                               schedule, sgd_init, sgd_update)


def test_adamw_converges_on_quadratic():
    cfg = OptimConfig(lr=0.1, grad_clip=0.0)
    target = {"w": jnp.asarray([3.0, -2.0, 0.5])}
    params = {"w": jnp.zeros(3)}
    state = adamw_init(params)
    for _ in range(300):
        grads = jax.tree.map(lambda p, t: p - t, params, target)
        params, state = adamw_update(cfg, grads, state, params)
    np.testing.assert_allclose(np.asarray(params["w"]),
                               np.asarray(target["w"]), atol=1e-2)


def test_weight_decay_shrinks():
    cfg = OptimConfig(lr=0.1, weight_decay=0.5, grad_clip=0.0)
    params = {"w": jnp.ones(4)}
    state = adamw_init(params)
    zeros = {"w": jnp.zeros(4)}
    params, _ = adamw_update(cfg, zeros, state, params)
    assert float(params["w"][0]) < 1.0


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert norm == pytest.approx(20.0)
    total = float(jnp.sqrt(sum(jnp.sum(x ** 2) for x in jax.tree.leaves(clipped))))
    assert total == pytest.approx(1.0, rel=1e-5)


@pytest.mark.parametrize("sched,last_smaller", [("cosine", True),
                                                ("linear", True),
                                                ("constant", False)])
def test_schedules(sched, last_smaller):
    cfg = OptimConfig(lr=1e-2, schedule=sched, warmup_steps=10, total_steps=100)
    lr0 = float(schedule(cfg, jnp.asarray(0)))
    lr_mid = float(schedule(cfg, jnp.asarray(50)))
    lr_end = float(schedule(cfg, jnp.asarray(99)))
    assert lr0 < lr_mid                      # warmup
    assert (lr_end < lr_mid) == last_smaller


def test_sgd_momentum_converges():
    cfg = OptimConfig(lr=0.05, grad_clip=0.0)
    params = {"w": jnp.zeros(2)}
    state = sgd_init(params)
    for _ in range(200):
        grads = jax.tree.map(lambda p: p - 1.0, params)
        params, state = sgd_update(cfg, grads, state, params)
    np.testing.assert_allclose(np.asarray(params["w"]), [1.0, 1.0], atol=1e-2)


def test_bf16_params_supported():
    cfg = OptimConfig(lr=0.1)
    params = {"w": jnp.ones(4, jnp.bfloat16)}
    state = adamw_init(params)
    grads = {"w": jnp.ones(4, jnp.bfloat16)}
    params, state = adamw_update(cfg, grads, state, params)
    assert params["w"].dtype == jnp.bfloat16
    assert state["mu"]["w"].dtype == jnp.float32   # fp32 master moments
