"""The five aggregation methods: correctness, rank ordering, cross-term
noise (the paper's comparative claims)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.aggregation import (adapter_leaf_paths, aggregate, get_path)
from repro.core import costs as C


def _client_tree(rng, L, m, n, r, scale=1.0):
    return {"blocks": {0: {"attn": {"wq": {
        "A": jnp.asarray(rng.normal(size=(L, r, n)), jnp.float32),
        "B": jnp.asarray(rng.normal(size=(L, m, r)), jnp.float32),
        "scale": jnp.full((L,), scale, jnp.float32),
    }}}}}


def _delta_w(tree, l=0):
    leaf = get_path(tree, adapter_leaf_paths(tree)[0])
    s = leaf["scale"][l] if leaf["scale"].ndim else leaf["scale"]
    return s * (leaf["B"][l] @ leaf["A"][l])


@pytest.fixture
def clients3(rng):
    trees = [_client_tree(rng, L=2, m=48, n=40, r=r) for r in (4, 8, 16)]
    weights = [0.5, 0.3, 0.2]
    return trees, weights


def _true_dw(trees, weights, l=0):
    return sum(w * _delta_w(t, l) for w, t in zip(weights, trees))


class TestFlorist:
    def test_exact_at_tau_one(self, clients3):
        trees, w = clients3
        agg = aggregate("florist", trees, w, tau=1.0)
        for l in range(2):
            got = _delta_w(agg.global_adapters, l)
            np.testing.assert_allclose(np.asarray(got),
                                       np.asarray(_true_dw(trees, w, l)),
                                       rtol=1e-4, atol=1e-4)

    def test_per_layer_ranks_recorded(self, clients3):
        trees, w = clients3
        agg = aggregate("florist", trees, w, tau=0.9)
        path = adapter_leaf_paths(trees[0])[0]
        assert len(agg.ranks[path]) == 2
        assert all(1 <= p <= 28 for p in agg.ranks[path])

    def test_heterogeneous_scales_folded(self, rng):
        """Clients with different alpha/r scalings must aggregate the same
        effective ΔW."""
        t1 = _client_tree(rng, 1, 32, 24, 4, scale=2.0)
        t2 = _client_tree(rng, 1, 32, 24, 8, scale=0.5)
        agg = aggregate("florist", [t1, t2], [0.6, 0.4], tau=1.0)
        true = 0.6 * _delta_w(t1) + 0.4 * _delta_w(t2)
        np.testing.assert_allclose(np.asarray(_delta_w(agg.global_adapters)),
                                   np.asarray(true), rtol=1e-4, atol=1e-4)


class TestBaselines:
    def test_fedit_has_cross_term_noise(self, rng):
        """(Σw B)(Σw A) ≠ Σw BA — the paper's motivating inaccuracy."""
        trees = [_client_tree(rng, 1, 32, 24, 8) for _ in range(3)]
        w = [1 / 3] * 3
        agg = aggregate("fedit", trees, w)
        err = np.linalg.norm(np.asarray(_delta_w(agg.global_adapters)
                                        - _true_dw(trees, w)))
        assert err > 1.0   # materially wrong, not rounding noise

    def test_fedit_rejects_heterogeneous_without_padding(self, clients3):
        trees, w = clients3
        with pytest.raises(ValueError):
            aggregate("fedit", trees, w)
        agg = aggregate("fedit", trees, w, zero_padding=True)   # HetLoRA
        assert agg.global_adapters is not None

    def test_ffa_exact_with_shared_frozen_a(self, rng):
        """When all clients share frozen A, averaging B is noise-free:
        Σw B_k A = (Σw B_k) A."""
        A_shared = jnp.asarray(rng.normal(size=(1, 8, 24)), jnp.float32)
        trees = []
        for _ in range(3):
            t = _client_tree(rng, 1, 32, 24, 8)
            t["blocks"][0]["attn"]["wq"]["A"] = A_shared
            trees.append(t)
        w = [0.2, 0.3, 0.5]
        agg = aggregate("ffa", trees, w, A_init=trees[0])
        np.testing.assert_allclose(np.asarray(_delta_w(agg.global_adapters)),
                                   np.asarray(_true_dw(trees, w)),
                                   rtol=1e-4, atol=1e-4)

    def test_flora_stack_is_exact_and_max_rank(self, clients3):
        trees, w = clients3
        agg = aggregate("flora", trees, w)
        np.testing.assert_allclose(np.asarray(_delta_w(agg.global_adapters)),
                                   np.asarray(_true_dw(trees, w)),
                                   rtol=1e-4, atol=1e-4)
        assert agg.merge_into_base
        path = adapter_leaf_paths(trees[0])[0]
        assert agg.ranks[path][0] == 4 + 8 + 16

    def test_flexlora_global_is_exact(self, clients3):
        trees, w = clients3
        agg = aggregate("flexlora", trees, w, client_ranks=[4, 8, 16])
        np.testing.assert_allclose(np.asarray(_delta_w(agg.global_adapters)),
                                   np.asarray(_true_dw(trees, w)),
                                   rtol=1e-4, atol=1e-4)
        assert agg.per_client is not None and len(agg.per_client) == 3

    def test_flexlora_equals_florist_at_same_rank(self, clients3):
        """Both are truncated SVDs of the same ΔW — at equal rank the
        reconstructions must coincide (paper: FLoRIST computes FlexLoRA's
        decomposition without forming ΔW)."""
        trees, w = clients3
        fl = aggregate("florist", trees, w, tau=1.0, max_rank=8)
        fx = aggregate("flexlora", trees, w, client_ranks=[8, 8, 8])
        dw_fl = _delta_w(fl.global_adapters)
        cl = fx.per_client[0]
        dw_fx = _delta_w(cl)
        np.testing.assert_allclose(np.asarray(dw_fl), np.asarray(dw_fx),
                                   rtol=1e-3, atol=1e-3)


class TestRankOrdering:
    def test_paper_rank_inequality(self, clients3):
        """Rank: FLoRIST < FlexLoRA ≤ FedIT < FLoRA (paper §3)."""
        trees, w = clients3
        ranks = [4, 8, 16]
        fl = aggregate("florist", trees, w, tau=0.9)
        fx = aggregate("flexlora", trees, w, client_ranks=ranks)
        fi = aggregate("fedit", trees, w, zero_padding=True)
        fo = aggregate("flora", trees, w)
        path = adapter_leaf_paths(trees[0])[0]
        p_fl = max(fl.ranks[path])
        p_fx = max(fx.ranks[path])          # ≤ max client rank
        p_fi = fi.ranks[path][0]            # = max client rank
        p_fo = fo.ranks[path][0]            # = Σ ranks
        assert p_fl < p_fi < p_fo
        assert p_fx <= p_fi


class TestCommAccounting:
    def test_download_ordering(self, clients3):
        """florist < ffa(half) <= fedit = flexlora-ish < flora (Table 2/3)."""
        trees, w = clients3
        ranks = [4, 8, 16]
        dims = C.leaf_dims(trees[0])
        res = {}
        for m, kw in [("florist", dict(tau=0.9)),
                      ("fedit", dict(zero_padding=True)),
                      ("flora", {}),
                      ("flexlora", dict(client_ranks=ranks)),
                      ("ffa", dict(A_init=trees[0], zero_padding=True))]:
            agg = aggregate(m, trees, w, **kw)
            res[m] = C.download_params(m, agg, dims, num_clients=3,
                                       client_ranks=ranks)
        assert res["florist"] < res["fedit"]
        assert res["fedit"] < res["flora"]
        assert res["ffa"] < res["fedit"]

    def test_upload_ffa_half(self, clients3):
        trees, w = clients3
        up_full = C.upload_params("florist", trees)
        up_ffa = C.upload_params("ffa", trees)
        assert up_ffa < up_full

    def test_efficiency_proxy_tinyllama_shape(self, rng):
        """Reproduce the paper's FedIT homogeneous efficiency on TinyLlama
        geometry: 22 layers × 2 proj × rank16 → 14.2e-4."""
        trees = [{"blocks": {0: {"attn": {
            "wq": {"A": jnp.zeros((22, 16, 2048)), "B": jnp.zeros((22, 2048, 16)),
                   "scale": jnp.ones((22,))},
            "wv": {"A": jnp.zeros((22, 16, 2048)), "B": jnp.zeros((22, 2048, 16)),
                   "scale": jnp.ones((22,))},
        }}}} for _ in range(2)]
        agg = aggregate("fedit", trees, [0.5, 0.5])
        eff = C.efficiency(agg)
        assert eff == pytest.approx(1 / (22 * 2 * 16), rel=1e-6)
        assert eff == pytest.approx(14.2e-4, rel=0.01)

    def test_server_flops_florist_much_cheaper_than_flexlora(self, clients3):
        """Table 4: FLoRIST ≪ FlexLoRA server cost (~7.5× there)."""
        trees, w = clients3
        dims = C.leaf_dims(trees[0])
        fl = aggregate("florist", trees, w, tau=0.9)
        f_fl = C.server_flops("florist", dims, [4, 8, 16], fl.ranks)
        f_fx = C.server_flops("flexlora", dims, [4, 8, 16])
        assert f_fl < f_fx
