"""repro-lint: one seeded violation per rule must flag, idiomatic clean
code must not, suppressions silence, and the repo's own src/ tree is
lint-clean (the CI ``analysis`` job enforces the same)."""
import os
import subprocess
import sys
import textwrap

import pytest

from repro.analysis.lint import Finding, lint_paths, lint_source

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def lint(src, **kw):
    return lint_source(textwrap.dedent(src), **kw)


def rules_of(findings):
    return sorted({f.rule for f in findings})


# -- seeded violations: every rule catches its canonical bug ------------------

def test_host_branch_on_traced_param():
    found = lint("""
        import jax

        @jax.jit
        def step(x):
            if x > 0:          # ConcretizationTypeError at trace time
                return x
            return -x
    """)
    assert "host-branch-on-traced" in rules_of(found)
    assert any(f.line == 6 for f in found)


def test_host_branch_via_builder_convention():
    """Functions returned by make_*/build_* builders are traced even
    without a visible jit decorator."""
    found = lint("""
        def make_train_step(cfg):
            def train_step(params, batch):
                if params["w"].sum() > 0:
                    return batch
                return params["w"].item()
            return train_step
    """)
    assert "host-branch-on-traced" in rules_of(found)
    # both the `if` and the `.item()` host sync flag
    assert len([f for f in found if f.rule == "host-branch-on-traced"]) == 2


def test_host_sync_in_hot_loop():
    found = lint("""
        import jax

        def _log(x):
            return jax.device_get(x)

        @jax.jit
        def step(x):
            _log(x)
            return x + 1
    """)
    assert "host-sync-in-hot-loop" in rules_of(found)


def test_import_time_jax_compute():
    found = lint("""
        import jax.numpy as jnp

        TABLE = jnp.arange(1024)    # compiles + allocates at import
    """)
    assert "import-time-jax-compute" in rules_of(found)


def test_jit_in_loop():
    found = lint("""
        import jax

        def sweep(fns, x):
            outs = []
            for fn in fns:
                outs.append(jax.jit(fn)(x))   # retraces every iteration
            return outs
    """)
    assert "jit-in-loop" in rules_of(found)


def test_nonhashable_static_arg():
    found = lint("""
        import jax
        from functools import partial

        @partial(jax.jit, static_argnames=("dims",))
        def f(x, dims):
            return x.sum(dims)

        def call(x):
            return f(x, dims=[0, 1])    # unhashable -> TypeError
    """)
    assert "nonhashable-static-arg" in rules_of(found)


def test_mutable_default_pytree():
    found = lint("""
        import jax.numpy as jnp

        def init(state={}, w=jnp.zeros(3)):
            return state, w
    """)
    assert rules_of(found) == ["mutable-default-pytree"]
    assert len(found) == 2


def test_topology_shim_bypass():
    found = lint("""
        from repro.launch.mesh import axis_size
        from repro.launch import sharding
    """, relpath="src/repro/train/trainer.py")
    assert len([f for f in found if f.rule == "topology-shim-bypass"]) == 2


# -- false-positive guards ----------------------------------------------------

def test_clean_traced_code_no_findings():
    """Idiomatic traced code: lax control flow, shape/dtype host reads,
    hashable statics — zero findings."""
    found = lint("""
        from functools import partial

        import jax
        import jax.numpy as jnp

        @partial(jax.jit, static_argnames=("k",))
        def topk_mask(x, k):
            if k <= 0:                      # static arg: host branch fine
                return x
            if x.ndim == 2:                 # shape read: host-safe
                x = x[None]
            return jax.lax.cond(jnp.all(x > 0), lambda v: v,
                                lambda v: -v, x)

        def make_step(cfg):
            def step(params, batch):
                del cfg
                return jax.tree.map(lambda p: p + batch["lr"], params)
            return step
    """)
    assert found == []


def test_shim_files_exempt_from_bypass_rule():
    """The shims re-export themselves; the rule must not flag them."""
    found = lint("from repro.topology.mesh import axis_size\n",
                 relpath="src/repro/launch/mesh.py",
                 select=["topology-shim-bypass"])
    assert found == []


# -- suppression --------------------------------------------------------------

def test_inline_suppression_with_justification():
    src = """
        import jax.numpy as jnp

        T = jnp.zeros(3)  # repro-lint: disable=import-time-jax-compute -- tiny
    """
    assert lint(src) == []


def test_disable_all_suppresses_everything():
    src = """
        import jax.numpy as jnp

        T = jnp.zeros(3)  # repro-lint: disable=all
    """
    assert lint(src) == []


def test_unrelated_suppression_does_not_silence():
    src = """
        import jax.numpy as jnp

        T = jnp.zeros(3)  # repro-lint: disable=jit-in-loop
    """
    assert rules_of(lint(src)) == ["import-time-jax-compute"]


# -- the repo itself ----------------------------------------------------------

def test_src_tree_is_lint_clean():
    findings = lint_paths([os.path.join(REPO, "src")])
    assert findings == [], "\n".join(f.render() for f in findings)


def test_cli_exit_codes(tmp_path):
    bad = tmp_path / "bad.py"
    bad.write_text("import jax.numpy as jnp\nT = jnp.zeros(3)\n")
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    ok = subprocess.run([sys.executable, "-m", "repro.analysis.lint",
                         str(tmp_path)], env=env, capture_output=True,
                        text=True)
    assert ok.returncode == 1
    assert "import-time-jax-compute" in ok.stdout
    bad.write_text("x = 1\n")
    clean = subprocess.run([sys.executable, "-m", "repro.analysis.lint",
                            str(tmp_path)], env=env, capture_output=True,
                           text=True)
    assert clean.returncode == 0, clean.stdout + clean.stderr


def test_unknown_rule_select_raises():
    with pytest.raises(ValueError, match="unknown lint rule"):
        lint_source("x = 1\n", select=["no-such-rule"])


def test_finding_render_clickable():
    f = Finding(rule="r", path="a/b.py", line=3, col=0, message="m")
    assert f.render() == "a/b.py:3:1: [r] m"
