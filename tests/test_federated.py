"""End-to-end federated simulation: all five methods on a tiny model."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.config import FedConfig, LoRAConfig, ModelConfig, OptimConfig
from repro.core.federated import FederatedTrainer

CFG = ModelConfig(name="fed-tiny", family="dense", num_layers=2, d_model=64,
                  num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
                  vocab_size=256, dtype="float32")
LORA = LoRAConfig(rank=8, alpha=8.0)
OPT = OptimConfig(lr=3e-3)


def _run(method, rounds=2, heter=False, **kw):
    fed = FedConfig(num_clients=12, clients_per_round=4, method=method,
                    tau=0.9, homogeneous_rank=8, heterogeneous=heter,
                    rank_distribution=((4, 4), (8, 4), (16, 4)),
                    zero_padding=heter, seed=0, **kw)
    tr = FederatedTrainer(CFG, fed, LORA, OPT, batch_size=8, local_steps=2,
                          seq_len=32)
    return tr.run(rounds), tr


@pytest.mark.parametrize("method", ["florist", "fedit", "ffa", "flora", "flexlora"])
def test_method_runs_and_is_finite(method):
    hist, _ = _run(method)
    assert all(np.isfinite(h.eval_loss) for h in hist)
    assert all(h.upload_params > 0 and h.download_params > 0 for h in hist)


@pytest.mark.parametrize("method", ["florist", "flexlora", "flora"])
def test_heterogeneous_ranks(method):
    hist, tr = _run(method, heter=True)
    assert len(set(tr.client_ranks)) == 3
    assert all(np.isfinite(h.eval_loss) for h in hist)


@pytest.mark.slow
def test_florist_download_rank_below_fedit_and_flora():
    """Rank: FLoRIST < FedIT < FLoRA on the same run (paper §3)."""
    res = {}
    for m in ("florist", "fedit", "flora"):
        hist, _ = _run(m)
        res[m] = hist[-1].download_rank
    assert res["florist"] < res["fedit"] < res["flora"]


@pytest.mark.slow
def test_florist_loss_improves_over_rounds():
    hist, _ = _run("florist", rounds=4)
    assert hist[-1].eval_loss < hist[0].eval_loss + 1e-3


@pytest.mark.slow
def test_tau_controls_rank():
    """Fig. 5: lower τ -> lower total rank."""
    ranks = {}
    for tau in (0.8, 0.99):
        fed = FedConfig(num_clients=12, clients_per_round=4, method="florist",
                        tau=tau, homogeneous_rank=8, seed=0)
        tr = FederatedTrainer(CFG, fed, LORA, OPT, batch_size=8,
                              local_steps=2, seq_len=32)
        hist = tr.run(2)
        ranks[tau] = hist[-1].global_rank_total
    assert ranks[0.8] <= ranks[0.99]


def test_ffa_a_frozen():
    """FFA clients must never change A."""
    hist, tr = _run("ffa", rounds=2)
    from repro.core.aggregation import adapter_leaf_paths, get_path
    g = tr.global_state.global_adapters
    a_init = tr.A_init_full
    for path in adapter_leaf_paths(g):
        a_g = np.asarray(get_path(g, path)["A"])
        a_0 = np.asarray(get_path(a_init, path)["A"])[..., : a_g.shape[-2], :]
        np.testing.assert_allclose(a_g, a_0, rtol=1e-6)


@pytest.mark.slow
def test_deterministic_given_seed():
    h1, _ = _run("florist", rounds=2)
    h2, _ = _run("florist", rounds=2)
    assert h1[-1].eval_loss == pytest.approx(h2[-1].eval_loss, abs=1e-6)
