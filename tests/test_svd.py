"""FLoRIST SVD pipeline: the paper's central mathematical claims."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.svd import (eckart_young_bound, energy_rank,
                            energy_rank_traced, florist_core,
                            florist_core_batched, florist_core_padded,
                            florist_core_stacked, gram_svd,
                            reconstruction_error, stack_adapters, thin_svd,
                            thin_svd_batched)


def _clients(rng, m, n, ranks):
    Bs = [jnp.asarray(rng.normal(size=(m, r)), jnp.float32) for r in ranks]
    As = [jnp.asarray(rng.normal(size=(r, n)), jnp.float32) for r in ranks]
    w = rng.dirichlet([1.0] * len(ranks)).tolist()
    return Bs, As, w


class TestExactness:
    """Claim: (B_g, A_g) is the exact truncated SVD of ΔW = Σ w_k B_k A_k
    computed without forming ΔW (paper §3, Eq. 4)."""

    def test_tau_one_reconstructs_exactly(self, rng):
        Bs, As, w = _clients(rng, 96, 80, [4, 8, 16])
        out = florist_core(Bs, As, w, tau=1.0)
        dw = sum(wi * (B @ A) for wi, B, A in zip(w, Bs, As))
        rel = float(jnp.linalg.norm(dw - out.B_g @ out.A_g) / jnp.linalg.norm(dw))
        assert rel < 1e-5

    def test_spectrum_matches_direct_svd(self, rng):
        """S_P are the singular values of ΔW (paper: 'without explicitly
        forming ΔW')."""
        Bs, As, w = _clients(rng, 64, 96, [4, 4, 8])
        out = florist_core(Bs, As, w, tau=1.0)
        dw = sum(wi * (B @ A) for wi, B, A in zip(w, Bs, As))
        s_direct = jnp.linalg.svd(dw, compute_uv=False)
        r = sum([4, 4, 8])
        np.testing.assert_allclose(np.asarray(out.spectrum[:r]),
                                   np.asarray(s_direct[:r]),
                                   rtol=1e-4, atol=1e-4)

    def test_error_equals_eckart_young_bound(self, rng):
        """Truncated SVD achieves the Eckart–Young optimum, so the paper's
        Eq. 5 bound is met with equality."""
        Bs, As, w = _clients(rng, 96, 80, [8, 8])
        out = florist_core(Bs, As, w, tau=0.85)
        err = reconstruction_error(Bs, As, w, out.B_g, out.A_g)
        bound = eckart_young_bound(out.spectrum, out.p)
        assert err == pytest.approx(bound, rel=1e-3)

    def test_truncation_beats_any_other_rank_p_factorization(self, rng):
        """Eckart–Young: no rank-p pair (e.g. FedIT-averaged) does better."""
        Bs, As, w = _clients(rng, 64, 64, [8, 8])
        out = florist_core(Bs, As, w, tau=0.8)
        dw = sum(wi * (B @ A) for wi, B, A in zip(w, Bs, As))
        err_fl = float(jnp.linalg.norm(dw - out.B_g @ out.A_g))
        # a same-rank alternative: truncated FedAvg of the factors
        B_avg = sum(wi * B for wi, B in zip(w, Bs))[:, : out.p]
        A_avg = sum(wi * A for wi, A in zip(w, As))[: out.p]
        err_avg = float(jnp.linalg.norm(dw - B_avg @ A_avg))
        assert err_fl <= err_avg + 1e-5


class TestEnergyRank:
    @given(st.lists(st.floats(0.01, 100.0), min_size=1, max_size=64),
           st.floats(0.05, 1.0))
    @settings(max_examples=60, deadline=None)
    def test_energy_rank_is_minimal_and_sufficient(self, sigmas, tau):
        s = jnp.asarray(sorted(sigmas, reverse=True), jnp.float32)
        p = energy_rank(s, tau)
        e = np.cumsum(np.asarray(s, np.float64) ** 2)
        frac = e / e[-1]
        assert frac[p - 1] >= tau - 1e-6          # sufficient
        if p > 1:
            assert frac[p - 2] < tau + 1e-6        # minimal

    def test_tau_monotone(self, rng):
        s = jnp.asarray(np.sort(rng.gamma(2, 2, size=32))[::-1].copy(), jnp.float32)
        ps = [energy_rank(s, t) for t in (0.5, 0.8, 0.9, 0.99, 1.0)]
        assert ps == sorted(ps)
        assert ps[-1] <= 32

    def test_host_matches_traced_at_tau_boundaries(self, rng):
        """Regression: the host path used to take a float64 branch that
        could pick a different p than the traced float32 path exactly at a
        cumulative-energy boundary.  Both must share fp32 semantics."""
        # equal singular values put τ = k/r exactly on a boundary
        s_eq = jnp.ones((8,), jnp.float32)
        for tau in (0.125, 0.25, 0.5, 0.625, 0.875, 1.0):
            assert energy_rank(s_eq, tau) == int(energy_rank_traced(s_eq, tau))
        # τ values that are not fp32-representable (0.9, 0.99, ...) on a
        # spectrum whose cumulative fractions land arbitrarily close
        for _ in range(20):
            s = jnp.asarray(np.sort(rng.gamma(2, 2, size=17))[::-1].copy(),
                            jnp.float32)
            frac = np.cumsum(np.asarray(s, np.float32) ** 2)
            frac = frac / frac[-1]
            for tau in (0.9, 0.99, float(frac[3]), float(frac[9])):
                assert energy_rank(s, tau) == int(energy_rank_traced(s, tau))

    @given(st.lists(st.floats(0.01, 100.0), min_size=2, max_size=64),
           st.floats(0.05, 1.0))
    @settings(max_examples=60, deadline=None)
    def test_host_traced_parity_property(self, sigmas, tau):
        s = jnp.asarray(sorted(sigmas, reverse=True), jnp.float32)
        assert energy_rank(s, tau) == int(energy_rank_traced(s, tau))


class TestBackends:
    @pytest.mark.parametrize("shape", [(128, 16), (16, 128), (64, 64)])
    def test_gram_svd_matches_lapack(self, rng, shape):
        x = jnp.asarray(rng.normal(size=shape), jnp.float32)
        a = thin_svd(x, "svd")
        g = gram_svd(x)
        np.testing.assert_allclose(np.asarray(g.s), np.asarray(a.s),
                                   rtol=2e-3, atol=2e-3)
        # U S Vt must reconstruct x
        np.testing.assert_allclose(np.asarray(g.u @ jnp.diag(g.s) @ g.vt),
                                   np.asarray(x), rtol=2e-2, atol=2e-3)

    def test_padded_variant_same_delta_w(self, rng):
        Bs, As, w = _clients(rng, 48, 40, [4, 8])
        B_stack, A_stack = stack_adapters(Bs, As, w)
        bg, ag, sp, p = florist_core_padded(B_stack, A_stack, tau=0.9)
        out = florist_core(Bs, As, w, tau=0.9)
        assert int(p) == out.p
        np.testing.assert_allclose(np.asarray(bg @ ag),
                                   np.asarray(out.B_g @ out.A_g),
                                   rtol=1e-4, atol=1e-4)

    @pytest.mark.parametrize("tau,max_rank", [(0.9, 3), (1.0, 5),
                                              ("auto", 0), ("auto", 2)])
    def test_padded_honors_max_rank_and_auto(self, rng, tau, max_rank):
        """Regression: the jit-safe padded variant used to ignore max_rank
        and reject tau='auto', diverging from the host path (and hence
        florist_sharded from florist)."""
        Bs, As, w = _clients(rng, 48, 40, [4, 8, 8])
        B_stack, A_stack = stack_adapters(Bs, As, w)
        bg, ag, sp, p = florist_core_padded(B_stack, A_stack, tau=tau,
                                            max_rank=max_rank)
        out = florist_core(Bs, As, w, tau=tau, max_rank=max_rank)
        assert int(p) == out.p
        if max_rank:
            assert int(p) <= max_rank
        np.testing.assert_allclose(np.asarray(bg @ ag),
                                   np.asarray(out.B_g @ out.A_g),
                                   rtol=1e-4, atol=1e-4)

    def test_gram_svd_rank_deficient_duplicated_clients(self, rng):
        """Two identical clients stacked → the stack's true rank is half its
        columns.  The Gram route must not emit garbage-magnitude U columns
        in the null directions (old behavior: x·v ≈ 0 divided by s ≈ 0)."""
        b = jnp.asarray(rng.normal(size=(96, 8)), jnp.float32)
        x = jnp.concatenate([b, b], axis=1)            # (96, 16), rank 8
        g = gram_svd(x)
        u = np.asarray(g.u)
        assert np.isfinite(u).all()
        # every column is either (near-)unit or exactly zeroed — nothing huge
        norms = np.linalg.norm(u, axis=0)
        assert norms.max() < 1.0 + 1e-3
        assert (norms[8:] < 1e-2).all()                # null directions zeroed
        # reconstruction still matches on the true range
        np.testing.assert_allclose(np.asarray(g.u @ jnp.diag(g.s) @ g.vt),
                                   np.asarray(x), rtol=2e-2, atol=2e-2)

    def test_gram_svd_rank_deficient_wide(self, rng):
        a = jnp.asarray(rng.normal(size=(6, 64)), jnp.float32)
        x = jnp.concatenate([a, 2.0 * a], axis=0)      # (12, 64), rank 6
        g = gram_svd(x)
        assert np.isfinite(np.asarray(g.u)).all()
        assert np.isfinite(np.asarray(g.vt)).all()
        assert np.linalg.norm(np.asarray(g.vt), axis=1).max() < 1.0 + 1e-3
        np.testing.assert_allclose(np.asarray(g.u @ jnp.diag(g.s) @ g.vt),
                                   np.asarray(x), rtol=2e-2, atol=2e-2)


class TestBatchedCore:
    """The batched (vmapped, single-compile) server pipeline must agree
    with the per-layer host loop."""

    def _layer_stacks(self, rng, L, m, n, ranks, spread=1.0):
        Bs = [jnp.asarray(rng.normal(size=(L, m, r)), jnp.float32)
              for r in ranks]
        As = [jnp.asarray(rng.normal(size=(L, r, n)), jnp.float32)
              for r in ranks]
        if spread != 1.0:   # make layers select different p_l
            scale = jnp.asarray(spread ** np.arange(L), jnp.float32)
            Bs = [B * scale[:, None, None] for B in Bs]
        w = rng.dirichlet([1.0] * len(ranks)).tolist()
        B_stacks = jnp.concatenate(Bs, axis=-1)
        A_stacks = jnp.concatenate([wi * A for wi, A in zip(w, As)], axis=-2)
        return B_stacks, A_stacks

    @pytest.mark.parametrize("svd_method", ["svd", "gram"])
    @pytest.mark.parametrize("tau,max_rank", [(0.9, 0), (0.9, 4), ("auto", 0)])
    def test_matches_per_layer_loop(self, rng, svd_method, tau, max_rank):
        L = 4
        B_stacks, A_stacks = self._layer_stacks(rng, L, 48, 40, [4, 8, 8])
        bg, ag, sp, p = florist_core_batched(B_stacks, A_stacks, tau,
                                             svd_method, max_rank)
        for l in range(L):
            ref = florist_core_stacked(B_stacks[l], A_stacks[l], tau,
                                       svd_method, max_rank)
            assert int(p[l]) == ref.p
            np.testing.assert_allclose(np.asarray(sp[l]),
                                       np.asarray(ref.spectrum),
                                       rtol=1e-4, atol=1e-4)
            np.testing.assert_allclose(
                np.asarray(bg[l] @ ag[l]),
                np.asarray(ref.B_g @ ref.A_g), rtol=1e-4, atol=1e-4)

    def test_layers_select_different_ranks(self, rng):
        B_stacks, A_stacks = self._layer_stacks(rng, 6, 48, 40, [2, 4],
                                                spread=3.0)
        # per-layer spectra differ in shape → the traced threshold must be
        # applied per layer, not shared across the vmap axis
        _, _, _, p = florist_core_batched(B_stacks, A_stacks, 0.9)
        ps = [int(x) for x in np.asarray(p)]
        for l, pl in enumerate(ps):
            ref = florist_core_stacked(B_stacks[l], A_stacks[l], 0.9)
            assert pl == ref.p

    def test_thin_svd_batched_matches_loop(self, rng):
        x = jnp.asarray(rng.normal(size=(5, 32, 24)), jnp.float32)
        u, s, vt = thin_svd_batched(x, "svd")
        for l in range(5):
            ref = thin_svd(x[l], "svd")
            np.testing.assert_allclose(np.asarray(s[l]), np.asarray(ref.s),
                                       rtol=1e-5, atol=1e-5)
            np.testing.assert_allclose(
                np.asarray(u[l] * s[l][None, :] @ vt[l]),
                np.asarray(ref.u * ref.s[None, :] @ ref.vt),
                rtol=1e-4, atol=1e-4)


class TestKneeRank:
    """Beyond-paper: automatic rank selection (paper §5 future work (i))."""

    def test_sharp_spectrum_small_rank(self):
        from repro.core.svd import knee_rank
        s = jnp.asarray([10.0, 9.0, 8.0] + [0.01] * 29, jnp.float32)
        p = knee_rank(s)
        assert 1 <= p <= 4

    def test_flat_spectrum_larger_rank(self):
        from repro.core.svd import knee_rank
        sharp = knee_rank(jnp.asarray([10.0] * 2 + [0.01] * 30, jnp.float32))
        flat = knee_rank(jnp.asarray(np.linspace(10, 9, 32), jnp.float32))
        assert flat > sharp

    def test_auto_in_florist_core(self, rng):
        Bs, As, w = _clients(rng, 64, 48, [8, 8])
        out = florist_core(Bs, As, w, tau="auto")
        assert 1 <= out.p <= 16
        # reconstruction still bounded by Eckart–Young at the chosen rank
        err = reconstruction_error(Bs, As, w, out.B_g, out.A_g)
        assert err == pytest.approx(eckart_young_bound(out.spectrum, out.p),
                                    rel=1e-3)


class TestProperties:
    @given(st.integers(1, 4), st.floats(0.3, 0.999))
    @settings(max_examples=20, deadline=None)
    def test_rank_never_exceeds_stack_rank(self, k, tau):
        rng = np.random.default_rng(k)
        ranks = [int(r) for r in rng.integers(2, 8, size=k)]
        Bs, As, w = _clients(rng, 32, 24, ranks)
        out = florist_core(Bs, As, w, tau=tau)
        assert 1 <= out.p <= sum(ranks)

    def test_scaling_invariance_of_product(self, rng):
        """ΔW depends only on w_k·B_k A_k — folding weights into A_stack
        (the paper's choice) must equal folding into B_stack."""
        Bs, As, w = _clients(rng, 40, 32, [4, 4])
        out_a = florist_core(Bs, As, w, tau=1.0)
        Bs2 = [wi * B for wi, B in zip(w, Bs)]
        out_b = florist_core(Bs2, As, [1.0, 1.0], tau=1.0)
        np.testing.assert_allclose(np.asarray(out_a.B_g @ out_a.A_g),
                                   np.asarray(out_b.B_g @ out_b.A_g),
                                   rtol=1e-4, atol=1e-4)
