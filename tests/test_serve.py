"""Serving: KV caches (full / sliding / int8), loss chunking, checkpoint."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.config import ModelConfig
from repro.configs import get_smoke_config
from repro.models import transformer as T
from repro.serve.kvcache import attn_cache, cache_kv, cache_update, dequant, quant
from repro.train.loss import chunked_ce


class TestQuant:
    def test_roundtrip_error_bounded(self, rng):
        x = jnp.asarray(rng.normal(size=(2, 8, 4, 16)) * 3, jnp.float32)
        q, s = quant(x)
        err = np.abs(np.asarray(dequant(q, s) - x))
        # absmax int8: error <= scale/2 per element
        assert (err <= np.asarray(s) * 0.5 + 1e-6).all()

    def test_quant_preserves_zero(self):
        q, s = quant(jnp.zeros((2, 4)))
        assert (np.asarray(dequant(q, s)) == 0).all()


class TestRingBuffer:
    def test_wraparound(self):
        cfg = get_smoke_config("qwen2-0.5b")
        c = attn_cache(cfg, batch=1, capacity=4, dtype=jnp.float32)
        K, hd = cfg.num_kv_heads, cfg.head_dim
        for t in range(6):
            k = jnp.full((1, 1, K, hd), float(t))
            c = cache_update(cfg, c, k, k)
        assert int(c["pos"]) == 6
        assert int(c["length"]) == 4
        kc, _ = cache_kv(cfg, c)
        # slots hold tokens 4,5,2,3 (ring)
        got = sorted(float(kc[0, i, 0, 0]) for i in range(4))
        assert got == [2.0, 3.0, 4.0, 5.0]


class TestChunkedCE:
    @pytest.mark.parametrize("chunk", [4, 8, 32, 31])
    def test_matches_direct(self, rng, chunk):
        cfg = get_smoke_config("qwen2-0.5b")
        params = T.init(cfg, jax.random.PRNGKey(0))
        B, S = 2, 32
        hidden = jnp.asarray(rng.normal(size=(B, S, cfg.d_model)), jnp.float32)
        toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)))
        mask = jnp.asarray(rng.integers(0, 2, (B, S)), jnp.float32)
        loss, m = chunked_ce(cfg, params, hidden, toks, mask, chunk=chunk)
        # direct
        head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        logits = (hidden[:, :-1] @ head).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, -1)
        tgt = jnp.take_along_axis(logits, toks[:, 1:, None], -1)[..., 0]
        direct = float(((lse - tgt) * mask[:, 1:]).sum() / mask[:, 1:].sum())
        assert float(loss) == pytest.approx(direct, rel=1e-5)


class TestCheckpoint:
    def test_roundtrip(self, tmp_path, rng):
        from repro.checkpoint.io import restore, restore_step, save
        cfg = get_smoke_config("qwen3-4b")
        params = T.init(cfg, jax.random.PRNGKey(0))
        p = str(tmp_path / "ckpt.npz")
        save(p, params, step=42)
        like = jax.tree.map(lambda x: jnp.zeros_like(x), params)
        back = restore(p, like)
        flat_a = jax.tree.leaves(params)
        flat_b = jax.tree.leaves(back)
        for a, b in zip(flat_a, flat_b):
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))
        assert restore_step(p) == 42

    def test_shape_mismatch_raises(self, tmp_path):
        from repro.checkpoint.io import restore, save
        save(str(tmp_path / "c.npz"), {"w": jnp.ones(4)})
        with pytest.raises(ValueError):
            restore(str(tmp_path / "c.npz"), {"w": jnp.ones(5)})
