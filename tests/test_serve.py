"""Serving: KV caches (full / sliding / int8), loss chunking, checkpoint."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.config import ModelConfig
from repro.configs import get_smoke_config
from repro.models import transformer as T
from repro.serve.kvcache import (attn_cache, cache_kv, cache_update, dequant,
                                 quant, reset_slot, reset_slots)
from repro.train.loss import chunked_ce


class TestQuant:
    def test_roundtrip_error_bounded(self, rng):
        x = jnp.asarray(rng.normal(size=(2, 8, 4, 16)) * 3, jnp.float32)
        q, s = quant(x)
        err = np.abs(np.asarray(dequant(q, s) - x))
        # absmax int8: error <= scale/2 per element
        assert (err <= np.asarray(s) * 0.5 + 1e-6).all()

    def test_quant_preserves_zero(self):
        q, s = quant(jnp.zeros((2, 4)))
        assert (np.asarray(dequant(q, s)) == 0).all()


class TestRingBuffer:
    def test_wraparound(self):
        cfg = get_smoke_config("qwen2-0.5b")
        c = attn_cache(cfg, batch=1, capacity=4, dtype=jnp.float32)
        K, hd = cfg.num_kv_heads, cfg.head_dim
        for t in range(6):
            k = jnp.full((1, 1, K, hd), float(t))
            c = cache_update(cfg, c, k, k)
        assert int(c["pos"][0]) == 6
        assert int(c["length"][0]) == 4
        kc, _ = cache_kv(cfg, c)
        # slots hold tokens 4,5,2,3 (ring)
        got = sorted(float(kc[0, i, 0, 0]) for i in range(4))
        assert got == [2.0, 3.0, 4.0, 5.0]

    def test_per_slot_positions_advance_independently(self):
        """Rows write at their OWN ring offset: resetting one slot restarts
        its ring at 0 while the other row keeps wrapping."""
        cfg = get_smoke_config("qwen2-0.5b")
        K, hd = cfg.num_kv_heads, cfg.head_dim
        c = attn_cache(cfg, batch=2, capacity=4, dtype=jnp.float32)
        for t in range(3):
            k = jnp.full((2, 1, K, hd), float(t))
            c = cache_update(cfg, c, k, k)
        c = reset_slot(c, 1)
        np.testing.assert_array_equal(np.asarray(c["pos"]), [3, 0])
        np.testing.assert_array_equal(np.asarray(c["length"]), [3, 0])
        for t in range(3, 5):
            k = jnp.full((2, 1, K, hd), float(t))
            c = cache_update(cfg, c, k, k)
        np.testing.assert_array_equal(np.asarray(c["pos"]), [5, 2])
        np.testing.assert_array_equal(np.asarray(c["length"]), [4, 2])
        kc, _ = cache_kv(cfg, c)
        # row 0 wrapped (slot 0 overwritten by token 4); row 1 restarted at 0
        got0 = [float(kc[0, i, 0, 0]) for i in range(4)]
        assert got0 == [4.0, 1.0, 2.0, 3.0]
        got1 = [float(kc[1, i, 0, 0]) for i in range(4)]
        assert got1[:2] == [3.0, 4.0]

    def test_chunk_write_matches_sequential(self, rng):
        """One (B,C) chunk write == C single-token writes, incl. ragged
        n_tokens rows and int8 quantized storage."""
        cfg = get_smoke_config("qwen2-0.5b")
        K, hd = cfg.num_kv_heads, cfg.head_dim
        for dtype in (jnp.float32, jnp.int8):
            kv = jnp.asarray(rng.normal(size=(2, 3, K, hd)), jnp.float32)
            n = jnp.asarray([2, 3])
            chunked = cache_update(cfg, attn_cache(cfg, 2, 8, dtype),
                                   kv, kv, n_tokens=n)
            seq = attn_cache(cfg, 2, 8, dtype)
            for t in range(3):
                mask = (t < n).astype(jnp.int32)
                seq = cache_update(cfg, seq, kv[:, t:t+1], kv[:, t:t+1],
                                   n_tokens=mask)
            for key in chunked:
                np.testing.assert_array_equal(np.asarray(chunked[key]),
                                              np.asarray(seq[key]), err_msg=key)

    def test_chunk_longer_than_capacity_keeps_last_tokens(self):
        """Writing C > cap tokens keeps only the newest cap (last write
        wins), matching sequential ring eviction."""
        cfg = get_smoke_config("qwen2-0.5b")
        K, hd = cfg.num_kv_heads, cfg.head_dim
        kv = jnp.arange(6, dtype=jnp.float32)[None, :, None, None] \
            * jnp.ones((1, 6, K, hd))
        c = cache_update(cfg, attn_cache(cfg, 1, 4, jnp.float32), kv, kv)
        seq = attn_cache(cfg, 1, 4, jnp.float32)
        for t in range(6):
            seq = cache_update(cfg, seq, kv[:, t:t+1], kv[:, t:t+1])
        np.testing.assert_array_equal(np.asarray(c["k"]), np.asarray(seq["k"]))
        np.testing.assert_array_equal(np.asarray(c["pos"]), np.asarray(seq["pos"]))

    def test_reset_slots_wipes_recurrent_state(self):
        """reset_slots on a whole init_cache tuple zeroes the masked rows of
        attention rings AND SSM/RWKV recurrent states, leaving others."""
        cfg = get_smoke_config("rwkv6-1.6b")
        B = 5                      # unambiguous batch-axis size
        cache = T.init_cache(cfg, B, 8, kv_dtype=jnp.float32)
        dirty = jax.tree.map(lambda l: l + 1, cache)
        mask = np.zeros(B, bool)
        mask[3] = True
        wiped = T.reset_cache_slots(dirty, jnp.asarray(mask))
        for leaf in jax.tree.leaves(wiped):
            arr = np.asarray(leaf, np.float32)
            bax = [i for i, s in enumerate(leaf.shape) if s == B][0]
            moved = np.moveaxis(arr, bax, 0)
            assert (moved[3] == 0).all()
            assert (moved[0] != 0).any()


class TestContinuousBatching:
    """Per-slot isolation of the serving engine: a request must decode the
    same tokens no matter which slot it lands in, who occupied that slot
    before, or how the prompt is chunked."""

    @pytest.fixture(scope="class")
    def setup(self):
        cfg = get_smoke_config("qwen2-0.5b")
        params = T.init(cfg, jax.random.PRNGKey(0))
        return cfg, params

    def _engine(self, cfg, params, **kw):
        from repro.serve.engine import ServeEngine
        kw.setdefault("batch_slots", 2)
        kw.setdefault("capacity", 64)
        return ServeEngine(cfg, params, seed=0, **kw)

    @pytest.mark.parametrize("variant", ["full", "window", "int8"])
    def test_slot_reuse_no_contamination(self, setup, variant):
        """The ISSUE 4 repro: serve {A, B, C} on 2 slots so C reuses A's
        freed slot — C's greedy tokens must be bit-identical to serving C
        alone on a fresh engine."""
        from repro.serve.engine import SamplingParams
        cfg, params = setup
        kw = {}
        if variant == "window":
            cfg = cfg.replace(sliding_window=8)
        if variant == "int8":
            kw["kv_dtype"] = jnp.int8
        eng = self._engine(cfg, params, **kw)
        eng.submit([5, 6], SamplingParams(max_tokens=3))          # A: finishes first
        eng.submit([9, 10, 11, 12], SamplingParams(max_tokens=12))  # B: keeps going
        c = eng.submit([42, 43, 44], SamplingParams(max_tokens=6))  # C -> A's slot
        batched = eng.run()[c]
        fresh = self._engine(cfg, params, **kw)
        alone = fresh.submit([42, 43, 44], SamplingParams(max_tokens=6))
        assert batched == fresh.run()[alone]

    def test_readmit_with_ring_wraparound(self, setup):
        """Re-admitted slot with a capacity small enough that the ring wraps
        during generation: per-slot pos restarts at 0 and wrap behaves as on
        a fresh engine."""
        from repro.serve.engine import SamplingParams
        cfg, params = setup
        eng = self._engine(cfg, params, capacity=8)
        eng.submit([5, 6, 7], SamplingParams(max_tokens=4))
        second = eng.submit([21, 22], SamplingParams(max_tokens=12))  # wraps
        got = eng.run()[second]
        fresh = self._engine(cfg, params, capacity=8)
        alone = fresh.submit([21, 22], SamplingParams(max_tokens=12))
        assert got == fresh.run()[alone]

    def test_chunked_prefill_matches_tokenwise(self, setup):
        from repro.serve.engine import SamplingParams
        cfg, params = setup
        outs = []
        for chunk in (1, 4):
            eng = self._engine(cfg, params, prefill_chunk=chunk)
            uids = [eng.submit(p, SamplingParams(max_tokens=5))
                    for p in ([3, 4, 5, 6, 7], [8, 9], [])]
            out = eng.run()
            outs.append([out[u] for u in uids])
        assert outs[0] == outs[1]

    def test_batched_equals_solo_decode(self, setup):
        """Two requests decoded concurrently in one batch == each decoded
        alone: per-row masking keeps rows fully independent."""
        from repro.serve.engine import SamplingParams
        cfg, params = setup
        eng = self._engine(cfg, params)
        u1 = eng.submit([3, 4, 5], SamplingParams(max_tokens=6))
        u2 = eng.submit([6, 7], SamplingParams(max_tokens=6))
        both = eng.run()
        for uid, prompt in ((u1, [3, 4, 5]), (u2, [6, 7])):
            solo = self._engine(cfg, params, batch_slots=1)
            su = solo.submit(prompt, SamplingParams(max_tokens=6))
            assert both[uid] == solo.run()[su]


class TestChunkedCE:
    @pytest.mark.parametrize("chunk", [4, 8, 32, 31])
    def test_matches_direct(self, rng, chunk):
        cfg = get_smoke_config("qwen2-0.5b")
        params = T.init(cfg, jax.random.PRNGKey(0))
        B, S = 2, 32
        hidden = jnp.asarray(rng.normal(size=(B, S, cfg.d_model)), jnp.float32)
        toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)))
        mask = jnp.asarray(rng.integers(0, 2, (B, S)), jnp.float32)
        loss, m = chunked_ce(cfg, params, hidden, toks, mask, chunk=chunk)
        # direct
        head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        logits = (hidden[:, :-1] @ head).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, -1)
        tgt = jnp.take_along_axis(logits, toks[:, 1:, None], -1)[..., 0]
        direct = float(((lse - tgt) * mask[:, 1:]).sum() / mask[:, 1:].sum())
        assert float(loss) == pytest.approx(direct, rel=1e-5)


class TestCheckpoint:
    def test_roundtrip(self, tmp_path, rng):
        from repro.checkpoint.io import restore, restore_step, save
        cfg = get_smoke_config("qwen3-4b")
        params = T.init(cfg, jax.random.PRNGKey(0))
        p = str(tmp_path / "ckpt.npz")
        save(p, params, step=42)
        like = jax.tree.map(lambda x: jnp.zeros_like(x), params)
        back = restore(p, like)
        flat_a = jax.tree.leaves(params)
        flat_b = jax.tree.leaves(back)
        for a, b in zip(flat_a, flat_b):
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))
        assert restore_step(p) == 42

    def test_shape_mismatch_raises(self, tmp_path):
        from repro.checkpoint.io import restore, save
        save(str(tmp_path / "c.npz"), {"w": jnp.ones(4)})
        with pytest.raises(ValueError):
            restore(str(tmp_path / "c.npz"), {"w": jnp.ones(5)})
