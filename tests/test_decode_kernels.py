"""Ring-flash-decode parity suite.

The streamed (XLA online-softmax) and Pallas kernel decode paths must match
the dense oracle — full / sliding-window / int8 caches, ring wraparound,
ragged ``n_tokens`` chunks, batched-vs-solo invariance — and the in-loop
ring masking must reproduce ``ring_attend_mask`` exactly (hypothesis
property test).  The agreement contract covers every VALID query position
(``t < n_tokens[b]``); invalid positions hold unspecified values and are
discarded by every caller (the serve step gathers each row's last valid
token).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.kernels import ops, ref
from repro.models import transformer as T
from repro.models.attention_core import (mla_ring_flash_decode,
                                         ring_attend_mask, ring_block_mask,
                                         ring_flash_decode)
from repro.serve.kvcache import quant

IMPLS = ("streamed", "kernel")


def _states():
    """(pos, length) rows: mid-prefill, exactly-full, wrapped ring,
    never-written slot — all in one batch."""
    pos = jnp.asarray([3, 20, 33, 0], jnp.int32)
    length = jnp.asarray([3, 20, 20, 0], jnp.int32)
    return pos, length


def _gqa_case(rng, B=4, C=3, H=8, K=2, hd=16, cap=20):
    q = jnp.asarray(rng.normal(size=(B, C, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, cap, K, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, cap, K, hd)), jnp.float32)
    return q, k, v


def _run(impl, q, k, v, pos, length, n, window=0, k_scale=None, v_scale=None,
         block=8):
    if impl == "streamed":
        return ring_flash_decode(q, k, v, pos, length, n, window=window,
                                 k_scale=k_scale, v_scale=v_scale,
                                 block=block)
    return ops.ring_decode(q, k, v, pos, length, n, window=window,
                           k_scale=k_scale, v_scale=v_scale, bk=block)


def _run_mla(impl, q_eff, c_kv, k_rope, pos, length, n, scale, window=0,
             c_kv_scale=None, k_rope_scale=None, block=8):
    if impl == "streamed":
        return mla_ring_flash_decode(q_eff, c_kv, k_rope, pos, length, n,
                                     scale=scale, window=window,
                                     c_kv_scale=c_kv_scale,
                                     k_rope_scale=k_rope_scale, block=block)
    return ops.mla_ring_decode(q_eff, c_kv, k_rope, pos, length, n,
                               scale=scale, window=window,
                               c_kv_scale=c_kv_scale,
                               k_rope_scale=k_rope_scale, bk=block)


class TestRingBlockMaskProperty:
    """In-loop (streamed / in-kernel) ring masking ≡ ``ring_attend_mask``:
    concatenating per-block masks over the slot axis reproduces the dense
    mask for ANY (pos, length, window, cap) — wraparound, partially filled
    and never-written slots included."""

    def test_hypothesis_equivalence(self):
        pytest.importorskip("hypothesis")
        from hypothesis import given, settings, strategies as st

        @settings(max_examples=200, deadline=None)
        @given(st.data())
        def run(data):
            cap = data.draw(st.integers(1, 48), label="cap")
            bk = data.draw(st.integers(1, 48), label="bk")
            C = data.draw(st.integers(1, 4), label="C")
            window = data.draw(st.sampled_from([0, 1, 3, cap, 2 * cap]),
                               label="window")
            B = data.draw(st.integers(1, 3), label="B")
            pos_l, len_l, n_l = [], [], []
            for _ in range(B):
                p = data.draw(st.integers(0, 3 * cap), label="pos")
                pos_l.append(p)
                len_l.append(data.draw(st.integers(0, min(p, cap)),
                                       label="length"))
                n_l.append(data.draw(st.integers(0, min(p, C)), label="n"))
            pos = jnp.asarray(pos_l, jnp.int32)
            length = jnp.asarray(len_l, jnp.int32)
            n = jnp.asarray(n_l, jnp.int32)
            qpos = (pos - n)[:, None] + jnp.arange(C)[None, :]
            dense = np.asarray(ring_attend_mask(pos, length, cap, qpos,
                                                window))
            nb = -(-cap // bk)
            blocks = [np.asarray(ring_block_mask(pos, length, n, cap,
                                                 ib * bk, bk, C, window))
                      for ib in range(nb)]
            tiled = np.concatenate(blocks, axis=-1)[..., :cap]
            np.testing.assert_array_equal(tiled, dense)
            # the Pallas kernels' per-row copy of the same math
            from repro.kernels.ring_decode import ring_mask_tile
            for b in range(B):
                kern = np.concatenate(
                    [np.asarray(ring_mask_tile(
                        pos[b], length[b], n[b], ib, bk=bk, cap=cap, C=C,
                        window=window)) for ib in range(nb)],
                    axis=-1)[..., :cap]
                np.testing.assert_array_equal(kern, dense[b])

        run()

    def test_padded_slots_masked(self):
        """Block-padding slots (s >= cap) are never attendable, whatever the
        ring state claims."""
        pos = jnp.asarray([37], jnp.int32)
        length = jnp.asarray([5], jnp.int32)
        n = jnp.asarray([1], jnp.int32)
        m = np.asarray(ring_block_mask(pos, length, n, 5, 0, 8, 1))
        assert not m[..., 5:].any()


class TestRingDecodeParity:
    @pytest.mark.parametrize("impl", IMPLS)
    @pytest.mark.parametrize("window", [0, 5])
    def test_matches_dense_oracle(self, rng, impl, window):
        """All four ring states (prefill / full / wrapped / never-written)
        in one batch; block (8) smaller than — and not dividing — cap (20)."""
        q, k, v = _gqa_case(rng)
        pos, length = _states()
        n = jnp.full((4,), q.shape[1], jnp.int32)
        want = ref.ring_decode_ref(q, k, v, pos, length, n, window=window)
        got = _run(impl, q, k, v, pos, length, n, window=window)
        # never-written rows (length 0) hold degenerate softmax values that
        # differ between dense and online forms — exclude row 3 (discarded
        # by every caller) and compare the three live rows everywhere
        np.testing.assert_allclose(np.asarray(got)[:3], np.asarray(want)[:3],
                                   rtol=2e-5, atol=2e-5)

    @pytest.mark.parametrize("impl", IMPLS)
    def test_int8_fused_dequant(self, rng, impl):
        q, k, v = _gqa_case(rng)
        pos, length = _states()
        n = jnp.full((4,), q.shape[1], jnp.int32)
        kq, ks = quant(k)
        vq, vs = quant(v)
        want = ref.ring_decode_ref(q, kq, vq, pos, length, n,
                                   k_scale=ks, v_scale=vs)
        got = _run(impl, q, kq, vq, pos, length, n, k_scale=ks, v_scale=vs)
        np.testing.assert_allclose(np.asarray(got)[:3], np.asarray(want)[:3],
                                   rtol=2e-5, atol=2e-5)

    @pytest.mark.parametrize("impl", IMPLS)
    def test_ragged_chunk_valid_positions(self, rng, impl):
        """Ragged n_tokens: every VALID query position matches the oracle
        (invalid tails are unspecified and discarded by callers)."""
        q, k, v = _gqa_case(rng)
        pos, length = _states()
        n = jnp.asarray([3, 1, 2, 0], jnp.int32)
        want = np.asarray(ref.ring_decode_ref(q, k, v, pos, length, n))
        got = np.asarray(_run(impl, q, k, v, pos, length, n))
        valid = np.arange(q.shape[1])[None, :] < np.asarray(n)[:, None]
        np.testing.assert_allclose(got[valid], want[valid],
                                   rtol=2e-5, atol=2e-5)

    @pytest.mark.parametrize("impl", IMPLS)
    def test_slot_placement_invariance(self, rng, impl):
        """A row computes the same output whether it rides alone or inside
        a batch of unrelated ring states."""
        q, k, v = _gqa_case(rng)
        pos, length = _states()
        n = jnp.full((4,), q.shape[1], jnp.int32)
        batched = np.asarray(_run(impl, q, k, v, pos, length, n, window=5))
        for b in range(3):
            solo = _run(impl, q[b:b + 1], k[b:b + 1], v[b:b + 1],
                        pos[b:b + 1], length[b:b + 1], n[b:b + 1], window=5)
            np.testing.assert_allclose(np.asarray(solo)[0], batched[b],
                                       rtol=1e-6, atol=1e-6)

    @pytest.mark.parametrize("impl", IMPLS)
    def test_gqa_and_mqa_grouping(self, rng, impl):
        for K in (1, 4, 8):
            q, k, v = _gqa_case(rng, K=K)
            pos, length = _states()
            n = jnp.full((4,), q.shape[1], jnp.int32)
            want = ref.ring_decode_ref(q, k, v, pos, length, n)
            got = _run(impl, q, k, v, pos, length, n)
            np.testing.assert_allclose(np.asarray(got)[:3],
                                       np.asarray(want)[:3],
                                       rtol=2e-5, atol=2e-5, err_msg=f"K={K}")


class TestMlaRingDecodeParity:
    def _case(self, rng, B=4, C=3, H=6, kvr=12, rope=6, cap=20):
        q_eff = jnp.asarray(rng.normal(size=(B, C, H, kvr + rope)), jnp.float32)
        c_kv = jnp.asarray(rng.normal(size=(B, cap, kvr)), jnp.float32)
        k_rope = jnp.asarray(rng.normal(size=(B, cap, rope)), jnp.float32)
        return q_eff, c_kv, k_rope, 1.0 / np.sqrt(48.0)

    @pytest.mark.parametrize("impl", IMPLS)
    @pytest.mark.parametrize("window", [0, 5])
    def test_matches_dense_oracle(self, rng, impl, window):
        q_eff, c_kv, k_rope, sc = self._case(rng)
        pos, length = _states()
        n = jnp.full((4,), q_eff.shape[1], jnp.int32)
        want = ref.mla_ring_decode_ref(q_eff, c_kv, k_rope, pos, length, n,
                                       sc, window=window)
        got = _run_mla(impl, q_eff, c_kv, k_rope, pos, length, n, sc,
                       window=window)
        np.testing.assert_allclose(np.asarray(got)[:3], np.asarray(want)[:3],
                                   rtol=2e-5, atol=2e-5)

    @pytest.mark.parametrize("impl", IMPLS)
    def test_int8_per_half_scales(self, rng, impl):
        """int8 latent caches carry SEPARATE per-token scales for the c_kv
        and k_rope halves; both are fused per block."""
        q_eff, c_kv, k_rope, sc = self._case(rng)
        pos, length = _states()
        n = jnp.full((4,), q_eff.shape[1], jnp.int32)
        cq, cs = quant(c_kv)
        rq, rs = quant(k_rope)
        want = ref.mla_ring_decode_ref(q_eff, cq, rq, pos, length, n, sc,
                                       c_kv_scale=cs, k_rope_scale=rs)
        got = _run_mla(impl, q_eff, cq, rq, pos, length, n, sc,
                       c_kv_scale=cs, k_rope_scale=rs)
        np.testing.assert_allclose(np.asarray(got)[:3], np.asarray(want)[:3],
                                   rtol=2e-5, atol=2e-5)


class TestDecodeImplRouting:
    """decode_impl through transformer.decode: streamed / kernel logits
    match the dense path through real cache_update flow — chunked prefill,
    ring wraparound, sliding window, int8 — on the serve-relevant gather
    positions (every row's last valid token)."""

    def _logits_trace(self, cfg, params, impl, kv_dtype=jnp.float32,
                      capacity=8):
        # jit the two step shapes once each (the interpret-mode kernel is
        # expensive to trace; this is also how the engine runs it)
        step = jax.jit(lambda p, c, t, n: T.decode(
            cfg, p, c, {"tokens": t}, n_tokens=n, decode_impl=impl))
        cache = T.init_cache(cfg, 2, capacity, kv_dtype, prefill_chunk=4)
        toks = jnp.asarray([[3, 4, 5, 6], [7, 8, 9, 1]])
        n = jnp.asarray([4, 2], jnp.int32)
        out = []
        lg, cache = step(params, cache, toks, n)
        out.append(np.asarray(jnp.take_along_axis(
            lg, (n - 1)[:, None, None], axis=1)[:, 0]))
        ones = jnp.asarray([1, 1], jnp.int32)
        for t in range(10):                     # wraps an 8-slot ring
            tok = jnp.asarray([[10 + t], [20 + t]])
            lg, cache = step(params, cache, tok, ones)
            out.append(np.asarray(lg[:, -1]))
        return np.stack(out)

    @pytest.mark.parametrize("variant", ["full", "window", "int8"])
    def test_transformer_decode_parity(self, variant):
        cfg = get_smoke_config("qwen2-0.5b")
        kv = jnp.float32
        if variant == "window":
            cfg = cfg.replace(sliding_window=4)
        if variant == "int8":
            kv = jnp.int8
        params = T.init(cfg, jax.random.PRNGKey(0))
        dense = self._logits_trace(cfg, params, "dense", kv)
        tol = 3e-2 if variant == "int8" else 1e-4   # dense int8 dequantizes
        for impl in IMPLS:                          # to bf16, streamed to f32
            got = self._logits_trace(cfg, params, impl, kv)
            np.testing.assert_allclose(got, dense, rtol=tol, atol=tol,
                                       err_msg=f"{variant}/{impl}")

    @pytest.mark.parametrize("kv_dtype", [jnp.float32, jnp.int8])
    def test_mla_decode_parity(self, kv_dtype):
        cfg = get_smoke_config("deepseek-v3-671b")
        params = T.init(cfg, jax.random.PRNGKey(1))
        dense = self._logits_trace(cfg, params, "dense", kv_dtype)
        for impl in IMPLS:
            got = self._logits_trace(cfg, params, impl, kv_dtype)
            np.testing.assert_allclose(got, dense, rtol=1e-4, atol=1e-4,
                                       err_msg=impl)


class TestEngineDecodeImpl:
    @pytest.fixture(scope="class")
    def setup(self):
        cfg = get_smoke_config("qwen2-0.5b")
        params = T.init(cfg, jax.random.PRNGKey(0))
        return cfg, params

    def _engine(self, cfg, params, **kw):
        from repro.serve.engine import ServeEngine
        kw.setdefault("batch_slots", 2)
        kw.setdefault("capacity", 32)
        kw.setdefault("prefill_chunk", 4)
        return ServeEngine(cfg, params, seed=0, **kw)

    @pytest.mark.parametrize("variant", ["full", "window", "int8"])
    def test_greedy_tokens_match_dense(self, setup, variant):
        """The whole serve stack (chunked prefill, ring wraparound, decode
        bursts, sampling gather) emits the same greedy tokens under every
        decode_impl.  For int8 caches the dense oracle dequantizes to bf16
        while streamed/kernel dequantize to fp32 (strictly MORE precise), so
        dense token-exactness is only required for fp caches; streamed and
        kernel must always agree with each other (dense int8 agreement is
        asserted at logits level in TestDecodeImplRouting)."""
        from repro.serve.engine import SamplingParams
        cfg, params = setup
        kw = {"capacity": 16}                        # generation wraps
        if variant == "window":
            cfg = cfg.replace(sliding_window=8)
        if variant == "int8":
            kw["kv_dtype"] = jnp.int8
        outs = {}
        for impl in ("dense",) + IMPLS:
            eng = self._engine(cfg, params, decode_impl=impl, **kw)
            u1 = eng.submit([5, 6, 7, 8, 9], SamplingParams(max_tokens=8))
            u2 = eng.submit([11, 12], SamplingParams(max_tokens=8))
            res = eng.run()
            outs[impl] = (res[u1], res[u2])
        assert outs["streamed"] == outs["kernel"], outs
        if variant != "int8":
            assert outs["dense"] == outs["streamed"], outs

    def test_batched_equals_solo_streamed(self, setup):
        from repro.serve.engine import SamplingParams
        cfg, params = setup
        eng = self._engine(cfg, params, decode_impl="streamed")
        u1 = eng.submit([3, 4, 5], SamplingParams(max_tokens=6))
        u2 = eng.submit([6, 7], SamplingParams(max_tokens=6))
        both = eng.run()
        for uid, prompt in ((u1, [3, 4, 5]), (u2, [6, 7])):
            solo = self._engine(cfg, params, batch_slots=1,
                                decode_impl="streamed")
            su = solo.submit(prompt, SamplingParams(max_tokens=6))
            assert both[uid] == solo.run()[su]

    @pytest.mark.parametrize("impl", IMPLS)
    def test_zero_retrace_with_kernels(self, setup, impl):
        """The engine keeps its fixed-executable-set guarantee with the
        streamed/kernel decode paths enabled: a second identical workload
        triggers no new traces."""
        from repro.serve.engine import SamplingParams
        cfg, params = setup
        eng = self._engine(cfg, params, decode_impl=impl)

        def workload():
            uids = [eng.submit([3, 4, 5, 6, 7], SamplingParams(max_tokens=6)),
                    eng.submit([9, 8], SamplingParams(max_tokens=4))]
            eng.run()
        workload()
        before = dict(eng.trace_counts)
        assert before
        workload()
        assert eng.trace_counts == before, (before, eng.trace_counts)

    def test_rejects_unknown_impl(self, setup):
        cfg, params = setup
        with pytest.raises(ValueError):
            self._engine(cfg, params, decode_impl="magic")
