import os

# NOTE: do NOT set xla_force_host_platform_device_count here — smoke tests
# and benches must see the single real device; only launch/dryrun.py forces
# 512 placeholder devices (and does so before importing jax).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
