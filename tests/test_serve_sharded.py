"""Mesh-sharded serving decode: single-device parity and the topology layer.

The sharded engine's contract is *bit-identity*: a ``ServeEngine`` built
with a ``(data=1, model=N)`` mesh must produce exactly the tokens of the
mesh-less engine — greedy decode bit-identical, sampled decode seed-stable
— with the SAME trace counts (the shardings install at init, so the hot
loop never retraces).

The device-parametrized tests need forced host devices, which must be in
``XLA_FLAGS`` before backend init and therefore cannot be set by
``tests/conftest.py`` (smoke tests need the single real device).  They
skip on a 1-device host; ``test_eight_device_driver`` re-runs this file in
a subprocess with ``--xla_force_host_platform_device_count=8`` so the
default suite still exercises them.  The topology-shim import-surface
tests run everywhere.
"""
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.common.config import ModelConfig
from repro.models import transformer as T
from repro.serve.engine import SamplingParams, ServeEngine
from repro.topology import make_serve_mesh

NDEV = len(jax.devices())
multidevice = pytest.mark.skipif(
    NDEV < 8, reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")

# head counts divide every mesh size in {1, 2, 4, 8}
TINY = ModelConfig(name="shard-tiny", family="dense", num_layers=2,
                   d_model=64, num_heads=8, num_kv_heads=4, head_dim=8,
                   d_ff=128, vocab_size=128, dtype="float32")

GREEDY = SamplingParams(max_tokens=5)
SAMPLED = SamplingParams(temperature=0.8, top_k=20, max_tokens=5)


@pytest.fixture(scope="module")
def tiny_params():
    return T.init(TINY, jax.random.PRNGKey(0))


def _run(cfg, params, mesh_size, sp, decode_impl="streamed", registry=None,
         adapter_ids=None, steps=12, kv_dtype=None):
    """Build an engine (mesh-less when ``mesh_size`` is None), serve one
    4-slot workload with run_steps, return (uid->tokens, trace_counts)."""
    mesh = None if mesh_size is None else make_serve_mesh(mesh_size)
    eng = ServeEngine(cfg, params, batch_slots=4, capacity=32,
                      prefill_chunk=4, decode_impl=decode_impl,
                      registry=registry, seed=0, mesh=mesh,
                      kv_dtype=kv_dtype)
    rng = np.random.default_rng(3)
    for r in range(4):
        prompt = rng.integers(1, cfg.vocab_size, 4).tolist()
        kw = {"adapter_id": adapter_ids[r]} if adapter_ids else {}
        eng.submit(prompt, sp, **kw)
    out = eng.run_steps(steps)
    assert len(out) == 4, f"requests incomplete after {steps} steps: {out}"
    return out, dict(eng.trace_counts)


@multidevice
@pytest.mark.parametrize("impl", ["dense", "streamed"])
@pytest.mark.parametrize("mesh_size", [1, 2, 4, 8])
def test_greedy_parity_and_zero_retrace(tiny_params, mesh_size, impl):
    ref, ref_traces = _run(TINY, tiny_params, None, GREEDY, impl)
    got, traces = _run(TINY, tiny_params, mesh_size, GREEDY, impl)
    assert got == ref
    # same executables, no extra compiles from the sharded lowering
    assert traces == ref_traces


@multidevice
@pytest.mark.parametrize("mesh_size", [2, 8])
def test_sampled_seed_stable(tiny_params, mesh_size):
    ref, _ = _run(TINY, tiny_params, None, SAMPLED)
    got, _ = _run(TINY, tiny_params, mesh_size, SAMPLED)
    assert got == ref


@multidevice
@pytest.mark.parametrize("mesh_size", [2, 8])
def test_multitenant_mixed_ranks_parity(tiny_params, mesh_size):
    """Heterogeneous-rank adapters through the paged registry: the pool
    shardings must reproduce per-slot outputs bit-for-bit."""
    from repro.configs import lora_targets
    from repro.peft.lora import init_lora
    from repro.serve.adapters import AdapterRegistry

    key = jax.random.PRNGKey(7)

    def rand_adapter(rank, seed):
        ad = init_lora(tiny_params, lora_targets(TINY), rank, 8.0,
                       jax.random.fold_in(key, seed))
        return jax.tree_util.tree_map_with_path(
            lambda p, x: (jax.random.normal(
                jax.random.fold_in(key, abs(hash(str(p))) % 2**30), x.shape)
                * 0.05 if getattr(p[-1], "key", None) == "B" else x), ad)

    def build():
        template = init_lora(tiny_params, lora_targets(TINY), 4, 8.0, key)
        reg = AdapterRegistry(template, page_rank=4, num_pages=16,
                              max_adapters=8, max_rank=8)
        ids = [reg.register(f"t{r}", rand_adapter(r, r)) for r in (4, 7, 3)]
        return reg, [0] + ids            # base id 0 + three live adapters

    reg0, ids0 = build()
    ref, _ = _run(TINY, tiny_params, None, GREEDY, registry=reg0,
                  adapter_ids=ids0)
    reg1, ids1 = build()
    got, _ = _run(TINY, tiny_params, mesh_size, GREEDY, registry=reg1,
                  adapter_ids=ids1)
    assert got == ref


@multidevice
@pytest.mark.parametrize("mesh_size", [2, 8])
def test_int8_cache_parity(tiny_params, mesh_size):
    """Quantized ring caches add per-token scale leaves (k_scale/v_scale)
    that shard with their heads; parity must hold bit-for-bit too."""
    import jax.numpy as jnp
    ref, _ = _run(TINY, tiny_params, None, GREEDY, kv_dtype=jnp.int8)
    got, _ = _run(TINY, tiny_params, mesh_size, GREEDY, kv_dtype=jnp.int8)
    assert got == ref


@multidevice
def test_kernel_impl_parity(tiny_params):
    """Pallas ring-decode (interpret mode off-TPU) under shard_map over the
    kv-head axis matches the mesh-less kernel engine."""
    ref, _ = _run(TINY, tiny_params, None, GREEDY, decode_impl="kernel")
    got, _ = _run(TINY, tiny_params, 2, GREEDY, decode_impl="kernel")
    assert got == ref


@multidevice
@pytest.mark.parametrize("impl", ["dense", "streamed"])
def test_mla_parity(impl):
    """MLA decode (compressed latents replicated, query heads sharded)
    through the deepseek smoke config — MoE layers included."""
    from repro.configs import get_smoke_config
    cfg = get_smoke_config("deepseek-v3-671b")
    params = T.init(cfg, jax.random.PRNGKey(0))
    ref, _ = _run(cfg, params, None, GREEDY, impl)
    got, _ = _run(cfg, params, 2, GREEDY, impl)
    assert got == ref


@pytest.mark.skipif(NDEV >= 8, reason="already on a multi-device host")
def test_eight_device_driver():
    """Re-run this file on 8 forced host devices in a subprocess (the only
    way to get them: XLA reads the flag once, at backend init)."""
    from repro.common.xla_env import merge_flags
    env = dict(os.environ)
    env["XLA_FLAGS"] = merge_flags(
        os.environ.get("XLA_FLAGS", ""),
        "--xla_force_host_platform_device_count=8")
    env.setdefault("JAX_PLATFORMS", "cpu")
    proc = subprocess.run(
        [sys.executable, "-m", "pytest", "-x", "-q",
         "-p", "no:cacheprovider", os.path.abspath(__file__)],
        env=env, capture_output=True, text=True, timeout=1500)
    if proc.returncode != 0:
        pytest.fail("sharded suite failed under 8 forced devices:\n"
                    + proc.stdout[-4000:] + proc.stderr[-2000:])


# -- topology layer import surface (device-count independent) ----------------

def test_launch_shims_reexport_topology():
    # the shims are deprecated (DeprecationWarning on import) but their
    # re-export surface must stay intact for external callers
    import warnings
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        import repro.launch.mesh as lm
        import repro.launch.sharding as ls
    from repro import topology as topo
    assert lm.make_production_mesh is topo.make_production_mesh
    assert lm.make_host_mesh is topo.make_host_mesh
    assert lm.axis_size is topo.axis_size
    assert ls.param_pspec is topo.param_pspec
    assert ls.params_pspecs is topo.params_pspecs
    assert ls.batch_pspecs is topo.batch_pspecs
    assert ls.cache_pspecs is topo.cache_pspecs
    assert ls.to_shardings is topo.to_shardings
    assert ls.ZERO3_THRESHOLD == topo.ZERO3_THRESHOLD


def test_launch_shims_warn_deprecation():
    import importlib

    import repro.launch.mesh as lm
    import repro.launch.sharding as ls
    with pytest.warns(DeprecationWarning, match="repro.launch.mesh"):
        importlib.reload(lm)
    with pytest.warns(DeprecationWarning, match="repro.launch.sharding"):
        importlib.reload(ls)


def test_cache_leaf_ranks_single_table():
    from repro import topology as topo
    from repro.serve import kvcache
    assert kvcache.CACHE_LEAF_RANKS is topo.CACHE_LEAF_RANKS


def test_shard_map_single_definition():
    """The version-portable shard_map wrapper has ONE definition; every
    consumer (federated aggregation + model layers + serve decode) binds
    the same object."""
    from repro.common import pjit_utils
    from repro.core import distributed
    from repro.models import attention_core, layers, moe
    assert distributed._shard_map is pjit_utils.shard_map
    assert layers._pjit_shard_map is pjit_utils.shard_map
    assert attention_core._pjit_shard_map is pjit_utils.shard_map
    assert moe._pjit_shard_map is pjit_utils.shard_map


def test_make_serve_mesh_shapes():
    from repro import topology as topo
    m = topo.make_serve_mesh(1)
    assert m.devices.shape == (1, 1) and m.axis_names == ("data", "model")
    with pytest.raises(ValueError):
        topo.make_serve_mesh(len(jax.devices()) + 1)
