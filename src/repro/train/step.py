"""Train / eval / serve step builders.

``make_train_step`` builds the LoRA fine-tuning step: gradients flow through
the frozen base into the *adapter tree only* — no base-model grads, no
base-model optimizer state (this is what makes the 671B config trainable on
v5e pods).
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.common.config import ModelConfig, OptimConfig, RunConfig
from repro.models import transformer as T
from repro.optim.adamw import adamw_init, adamw_update
from repro.train.loss import chunked_ce


def loss_fn(cfg: ModelConfig, params, adapters, batch: Dict,
            remat: bool = False, loss_chunk: int = 512,
            use_kernels: bool = False):
    hidden, aux = T.forward(cfg, params, batch, adapters, remat=remat,
                            use_kernels=use_kernels)
    tokens = batch.get("labels", batch.get("tokens"))
    mask = batch.get("loss_mask", jnp.ones_like(tokens, jnp.float32))
    if cfg.frontend == "vision" and "patch_embeds" in batch:
        # hidden includes patch positions; loss only over the text tail
        P = batch["patch_embeds"].shape[1]
        hidden = hidden[:, P:]
    loss, metrics = chunked_ce(cfg, params, hidden, tokens, mask, loss_chunk)
    if cfg.router_aux_coef:
        loss = loss + cfg.router_aux_coef * aux
    metrics["aux"] = aux
    return loss, metrics


def _mask_a_grads(grads):
    """Zero gradients on A leaves (FFA-LoRA trains B only)."""
    def fix(path, g):
        last = getattr(path[-1], "key", None)
        return jnp.zeros_like(g) if last == "A" else g
    return jax.tree_util.tree_map_with_path(fix, grads)


def make_train_step(cfg: ModelConfig, optim: OptimConfig, remat: bool = True,
                    loss_chunk: int = 512, use_kernels: bool = False,
                    b_only: bool = False, grad_accum: int = 1):
    """Returns train_step(params, adapters, opt_state, batch) ->
    (adapters, opt_state, metrics).

    ``b_only`` freezes A (FFA-LoRA).  ``grad_accum`` splits the global batch
    into microbatches processed sequentially (lax.scan): live activation
    memory scales with batch/grad_accum while LoRA grads (tiny) accumulate —
    this is what fits the deep archs' residual stream in v5e HBM.
    """

    def train_step(params, adapters, opt_state, batch):
        def grad_fn(a, b):
            return jax.value_and_grad(
                lambda a_: loss_fn(cfg, params, a_, b, remat, loss_chunk,
                                   use_kernels), has_aux=True)(a)

        if grad_accum == 1:
            (loss, metrics), grads = grad_fn(adapters, batch)
        else:
            mb = jax.tree.map(
                lambda t: t.reshape(grad_accum, t.shape[0] // grad_accum,
                                    *t.shape[1:]), batch)
            g0 = jax.tree.map(lambda a: jnp.zeros(a.shape, jnp.float32), adapters)

            def body(g_acc, b):
                (_, m), g = grad_fn(adapters, b)
                g_acc = jax.tree.map(lambda x, y: x + y.astype(jnp.float32),
                                     g_acc, g)
                return g_acc, m

            grads, ms = jax.lax.scan(body, g0, mb)
            grads = jax.tree.map(lambda g: g / grad_accum, grads)
            metrics = jax.tree.map(lambda m: m.mean(0), ms)
        if b_only:
            grads = _mask_a_grads(grads)
        adapters, opt_state = adamw_update(optim, grads, opt_state, adapters)
        return adapters, opt_state, metrics

    return train_step


def make_eval_step(cfg: ModelConfig, loss_chunk: int = 512):
    def eval_step(params, adapters, batch):
        _, metrics = loss_fn(cfg, params, adapters, batch, remat=False,
                             loss_chunk=loss_chunk)
        return metrics
    return eval_step


def make_prefill_step(cfg: ModelConfig, use_kernels: bool = False):
    """Full-sequence forward returning last-position logits (B, V)."""
    def prefill_step(params, adapters, batch):
        hidden, _ = T.forward(cfg, params, batch, adapters, remat=False,
                              use_kernels=use_kernels)
        return T.logits(cfg, params, hidden[:, -1:])[:, 0]
    return prefill_step


def make_serve_step(cfg: ModelConfig, decode_impl: str = "dense",
                    lora_impl: str = "xla"):
    """Chunked decode against a per-slot KV cache: (params, adapters, cache,
    batch) -> (next_token_logits (B,V), cache).

    batch: {"tokens": (B,C)} plus optional {"n_tokens": (B,)} giving the
    real token count per row (chunked prefill with ragged prompt tails).
    Returns the logits at each row's LAST real token — the position the
    next token is sampled from.  ``decode_impl`` picks the attention
    interior (dense | streamed | kernel, see ``transformer.decode``).

    ``adapters`` may also be an :class:`repro.serve.adapters.AdapterRegistry`
    device state; then ``batch["adapter_ids"]: (B,)`` selects each row's
    adapter from the paged pools (id 0 = base) and ``lora_impl`` picks the
    bgmv Pallas kernel or its XLA gather/einsum twin."""
    def serve_step(params, adapters, cache, batch):
        from repro.serve.adapters import attach, is_device_state
        if is_device_state(adapters):
            ids = batch.get("adapter_ids")
            if ids is None:
                ids = jnp.zeros((batch["tokens"].shape[0],), jnp.int32)
            adapters = attach(adapters, ids, impl=lora_impl)
        n = batch.get("n_tokens")
        lg, cache = T.decode(cfg, params, cache,
                             {k: v for k, v in batch.items()
                              if k not in ("n_tokens", "adapter_ids")},
                             adapters, n_tokens=n, decode_impl=decode_impl)
        if n is None:
            return lg[:, -1], cache
        idx = jnp.clip(n - 1, 0, lg.shape[1] - 1).astype(jnp.int32)
        return jnp.take_along_axis(lg, idx[:, None, None], axis=1)[:, 0], cache
    return serve_step


def init_train_state(cfg: ModelConfig, run: RunConfig, key) -> Tuple:
    from repro.peft.lora import init_lora
    kp, ka = jax.random.split(key)
    params = T.init(cfg, kp)
    adapters = init_lora(params, run.lora.targets, run.lora.rank,
                         run.lora.alpha, ka)
    opt_state = adamw_init(adapters)
    return params, adapters, opt_state


# -- abstract contracts (checked by repro.analysis.contracts) -----------------

from repro.analysis.registry import ContractCase, check_contract  # noqa: E402


@check_contract("train.step", families=("gqa", "mla", "moe", "ssm"))
def _contract_train_step(case):
    """Adapter/opt-state avals are a fixed point of the train step (else the
    trainer retraces every round), and params shard under the Megatron
    rules at the case's mesh width."""
    from repro.analysis import fixtures as FX
    from repro.topology import params_pspecs
    cfg = FX.tiny_config(case.family)
    params = FX.abstract_params(cfg)
    adapters = FX.abstract_adapters(cfg, params)
    opt_state = jax.eval_shape(adamw_init, adapters)
    batch = FX.train_batch(cfg)
    step = make_train_step(cfg, OptimConfig(), remat=False)

    def out_check(out, _case):
        a2, o2, metrics = out
        assert FX.avals_equal(a2, adapters), "adapter avals drift"
        assert FX.avals_equal(o2, opt_state), "opt_state avals drift"
        assert all(v.shape == () for v in jax.tree.leaves(metrics)), \
            "metrics must be scalars"

    mesh = FX.abstract_mesh(case.mesh)
    return ContractCase(step, (params, adapters, opt_state, batch),
                        out_check=out_check,
                        pspec_tree=(params, params_pspecs(mesh, cfg, params)),
                        mesh=mesh)


@check_contract("serve.step", families=("gqa", "mla", "moe", "ssm"),
                decode_impls=("dense", "streamed", "kernel"))
def _contract_serve_step(case):
    """Chunked decode returns (B, V) next-token logits and preserves cache
    avals exactly — the zero-retrace property of the serving hot path."""
    from repro.analysis import fixtures as FX
    cfg = FX.tiny_config(case.family)
    if cfg.family == "ssm" and case.decode_impl != "dense":
        return None          # recurrences have no attention interior to swap
    params = FX.abstract_params(cfg)
    cache = FX.abstract_cache(cfg)
    width = FX.chunk_width(cfg)
    batch = {"tokens": FX.sds((FX.BATCH_SLOTS, width), jnp.int32)}
    step = make_serve_step(cfg, decode_impl=case.decode_impl)

    def out_check(out, _case):
        logits, c2 = out
        assert logits.shape == (FX.BATCH_SLOTS, cfg.vocab_size), logits.shape
        assert logits.dtype == jnp.float32, logits.dtype
        assert FX.avals_equal(c2, cache), "cache avals drift across decode"

    return ContractCase(step, (params, None, cache, batch),
                        out_check=out_check)
