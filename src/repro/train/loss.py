"""Losses. Chunked cross-entropy: logits are materialized only for a
sequence chunk at a time (scan), bounding peak memory to
(B, chunk, vocab) instead of (B, S, vocab) — essential for the 150k-vocab
archs at seq 4096 on 16 GB chips.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.common.config import ModelConfig
from repro.models import transformer as T


def _ce_chunk(head: jnp.ndarray, hidden, targets, mask):
    """hidden: (B,c,d), targets: (B,c), mask: (B,c). Returns (sum_loss, sum_cnt, sum_correct)."""
    logits = (hidden @ head).astype(jnp.float32)           # (B,c,V)
    lse = jax.nn.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = (lse - tgt) * mask
    correct = (jnp.argmax(logits, -1) == targets).astype(jnp.float32) * mask
    return nll.sum(), mask.sum(), correct.sum()


def chunked_ce(cfg: ModelConfig, params, hidden: jnp.ndarray, tokens: jnp.ndarray,
               loss_mask: jnp.ndarray, chunk: int = 512) -> Tuple[jnp.ndarray, Dict]:
    """Next-token CE over `tokens`, masked by `loss_mask` on *target*
    positions. hidden: (B,S,d) aligned with tokens (B,S)."""
    from repro.common import flags
    if flags.scan_unroll():
        chunk = max(chunk, (tokens.shape[1] - 1) // 2)   # analysis lowering
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    B, S, d = hidden.shape
    # predict token t+1 from hidden t
    h = hidden[:, :-1]
    tgt = tokens[:, 1:]
    msk = loss_mask[:, 1:]
    Sm = h.shape[1]
    c = min(chunk, Sm)
    nc = Sm // c
    rem = Sm - nc * c

    # remat: logits for a chunk are recomputed in backward instead of living
    # across the whole loss scan (8 × (B,c,V) fp32 otherwise)
    ce_chunk = jax.checkpoint(_ce_chunk,
                              policy=jax.checkpoint_policies.nothing_saveable)

    def body(carry, xs):
        s_l, n_l, a_l = carry
        hh, tt, mm = xs
        s, n, a = ce_chunk(head, hh, tt, mm)
        return (s_l + s, n_l + n, a_l + a), None

    from repro.common import flags
    xs = (h[:, : nc * c].reshape(B, nc, c, d).swapaxes(0, 1),
          tgt[:, : nc * c].reshape(B, nc, c).swapaxes(0, 1),
          msk[:, : nc * c].reshape(B, nc, c).swapaxes(0, 1))
    (s, n, acc), _ = jax.lax.scan(body, (0.0, 0.0, 0.0), xs,
                                  unroll=flags.scan_unroll())
    if rem:
        s2, n2, a2 = _ce_chunk(head, h[:, nc * c:], tgt[:, nc * c:], msk[:, nc * c:])
        s, n, acc = s + s2, n + n2, acc + a2
    n = jnp.maximum(n, 1.0)
    return s / n, {"loss": s / n, "tokens": n, "accuracy": acc / n}
