"""Trainer: the single-tenant training loop as a resumable object —
checkpointing (adapters + optimizer state + step), periodic eval, metric
history.  Wraps the same jitted train step the dry-run lowers.
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Any, Callable, Dict, Iterator, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.io import restore, restore_step, save
from repro.common.config import LoRAConfig, ModelConfig, OptimConfig
from repro.models import transformer as T
from repro.optim.adamw import adamw_init
from repro.peft.lora import init_lora
from repro.train.step import make_eval_step, make_train_step


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    eval_every: int = 25
    ckpt_every: int = 50
    ckpt_path: str = ""
    loss_chunk: int = 64
    grad_accum: int = 1
    use_kernels: bool = False
    log_every: int = 10


class Trainer:
    def __init__(self, cfg: ModelConfig, lora: LoRAConfig, optim: OptimConfig,
                 tcfg: TrainerConfig, targets: tuple, seed: int = 0,
                 params: Optional[Any] = None):
        self.cfg, self.lora, self.optim, self.tcfg = cfg, lora, optim, tcfg
        key = jax.random.PRNGKey(seed)
        kp, ka = jax.random.split(key)
        self.params = params if params is not None else T.init(cfg, kp)
        self.adapters = init_lora(self.params, targets, lora.rank, lora.alpha, ka)
        self.opt_state = adamw_init(self.adapters)
        self.step_no = 0
        self._train = jax.jit(make_train_step(
            cfg, optim, remat=False, loss_chunk=tcfg.loss_chunk,
            use_kernels=tcfg.use_kernels, grad_accum=tcfg.grad_accum))
        self._eval = jax.jit(make_eval_step(cfg, loss_chunk=tcfg.loss_chunk))
        self.history: List[Dict] = []

    # -- checkpointing ---------------------------------------------------------
    def save_ckpt(self, path: Optional[str] = None) -> str:
        path = path or self.tcfg.ckpt_path
        assert path, "no checkpoint path configured"
        save(path, {"adapters": self.adapters, "opt": self.opt_state},
             step=self.step_no)
        return path

    def restore_ckpt(self, path: Optional[str] = None) -> int:
        path = path or self.tcfg.ckpt_path
        like = {"adapters": self.adapters, "opt": self.opt_state}
        tree = restore(path, like)
        self.adapters, self.opt_state = tree["adapters"], tree["opt"]
        self.step_no = restore_step(path) or 0
        return self.step_no

    # -- loop --------------------------------------------------------------------
    def fit(self, batches: Iterator[Dict], eval_batch: Optional[Dict] = None,
            steps: Optional[int] = None, verbose: bool = False) -> List[Dict]:
        steps = steps or self.tcfg.steps
        t0 = time.time()
        for batch in batches:
            if self.step_no >= steps:
                break
            jb = {k: jnp.asarray(v) for k, v in batch.items()}
            self.adapters, self.opt_state, metrics = self._train(
                self.params, self.adapters, self.opt_state, jb)
            self.step_no += 1
            rec = {"step": self.step_no, "loss": float(metrics["loss"]),
                   "accuracy": float(metrics["accuracy"]),
                   "wall_s": time.time() - t0}
            if eval_batch is not None and self.step_no % self.tcfg.eval_every == 0:
                em = self._eval(self.params, self.adapters,
                                {k: jnp.asarray(v) for k, v in eval_batch.items()})
                rec["eval_loss"] = float(em["loss"])
                rec["eval_accuracy"] = float(em["accuracy"])
            if self.tcfg.ckpt_path and self.step_no % self.tcfg.ckpt_every == 0:
                self.save_ckpt()
            self.history.append(rec)
            if verbose and self.step_no % self.tcfg.log_every == 0:
                print(f"step {rec['step']:5d} loss={rec['loss']:.4f} "
                      f"acc={rec['accuracy']:.3f}")
        return self.history
