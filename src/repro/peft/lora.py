"""LoRA adapters over arbitrary model parameter trees.

Convention (matches the paper): a target weight ``W`` used as ``y = x @ W``
with ``W: (in, out)`` carries an adapter ``{"A": (r, in), "B": (out, r)}``
so that the effective update is ``ΔWᵀ = (B A)ᵀ``:

    y = x @ W + scale * (x @ Aᵀ) @ Bᵀ ,   scale = alpha / r.

``B`` is zero-initialized and ``A`` is Gaussian (Hu et al. 2022), so training
starts at the base model.  When model layers are stacked for
``lax.scan`` (leading ``L`` axis), adapters carry the same leading axis.

Multi-tenant serving (``repro.serve.adapters``) replaces the per-leaf
``{"A", "B", "scale"}`` dict with a :class:`PagedLoRA` leaf — fixed-shape
paged pools plus per-batch-row adapter ids — so one jitted decode step
applies every row's OWN adapter at its own effective rank.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

# set by launchers to route LoRA matmuls through the fused Pallas kernel
USE_KERNEL: bool = False


@jax.tree_util.register_pytree_node_class
class PagedLoRA:
    """One LoRA-bearing leaf of a multi-tenant *paged* adapter store.

    Built per serve step by :func:`repro.serve.adapters.attach`; consumed by
    :func:`lora_proj`, which applies each batch row's own adapter at its own
    effective rank with branch-free gathered math.

    Array children (scanned leaves carry a leading layer axis ``L`` added by
    ``attach`` so ``lax.scan`` over layers unstacks every child):

    ==========  ==========================  =====================================
    child       shape                       meaning
    ==========  ==========================  =====================================
    a_pages     (P, page_rank, din)         paged A rows, page p = ranks
                                            [j·pr, (j+1)·pr) of its owner
    b_pages     (P, dout, page_rank)        paged B columns, same layout
    scale       (maxA,)                     per-adapter alpha/r
    table       (maxA, Pmax)                page indirection per adapter
    rank        (maxA,)                     effective rank (0 = base / masked)
    ids         (B,)                        per-batch-row adapter id (0 = base)
    ==========  ==========================  =====================================

    Static aux data: ``impl`` — ``"xla"`` (gather/einsum twin, the dense
    oracle, bit-identical to the classic single-tenant math) or ``"kernel"``
    (the Pallas bgmv kernel, ``repro.kernels.bgmv``).
    """

    def __init__(self, a_pages, b_pages, scale, table, rank, ids,
                 impl: str = "xla"):
        self.a_pages = a_pages
        self.b_pages = b_pages
        self.scale = scale
        self.table = table
        self.rank = rank
        self.ids = ids
        self.impl = impl

    def tree_flatten(self):
        return ((self.a_pages, self.b_pages, self.scale, self.table,
                 self.rank, self.ids), self.impl)

    @classmethod
    def tree_unflatten(cls, impl, children):
        return cls(*children, impl=impl)

    def __repr__(self):  # pragma: no cover - debugging aid
        return (f"PagedLoRA(P={self.a_pages.shape[-3]}, "
                f"pr={self.a_pages.shape[-2]}, impl={self.impl!r})")


def _paged_gather(ad: PagedLoRA):
    """Gather each row's pages into dense per-row panels.

    Returns (Ag (B, R, din), Bg (B, dout, R), rank_b (B,), scale_b (B,))
    with R = Pmax·page_rank; lane ordering matches page order so lane
    ``l`` is rank index ``l`` of the row's adapter."""
    pt = ad.table[ad.ids]                               # (B, Pmax)
    B_, Pmax = pt.shape
    P, pr, din = ad.a_pages.shape
    dout = ad.b_pages.shape[1]
    R = Pmax * pr
    Ag = ad.a_pages[pt].reshape(B_, R, din)
    Bg = jnp.moveaxis(ad.b_pages[pt], 2, 1).reshape(B_, dout, R)
    return Ag, Bg, ad.rank[ad.ids], ad.scale[ad.ids]


def paged_lora_delta(x: jnp.ndarray, ad: PagedLoRA) -> jnp.ndarray:
    """Per-row LoRA delta  Δy_b = scale_b · (x_b A_bᵀ) B_bᵀ.

    x: (B, C, din) — one continuous-batching token chunk; row ``b`` applies
    adapter ``ids[b]`` at its own effective rank (lanes ≥ rank are masked,
    so stale page contents from evicted adapters can never leak).  The
    ``"xla"`` twin is bit-identical to the classic single-tenant
    ``lora_proj`` math (masked lanes contribute exact zeros); ``"kernel"``
    runs the Pallas bgmv kernel (fp32 accumulation, within tolerance).
    """
    if x.ndim != 3:
        raise ValueError("paged multi-tenant adapters are a decode-path "
                         f"feature: expected x of rank 3 (B, C, din), got "
                         f"shape {x.shape}")
    if ad.impl == "kernel":
        from repro.kernels import ops as kops
        return kops.bgmv(x, ad.a_pages, ad.b_pages, ad.table, ad.rank,
                         ad.scale, ad.ids).astype(x.dtype)
    Ag, Bg, rank_b, scale_b = _paged_gather(ad)
    R = Ag.shape[1]
    z = jnp.einsum("bcd,brd->bcr", x, Ag.astype(x.dtype))
    z = jnp.where(jnp.arange(R)[None, None, :] < rank_b[:, None, None],
                  z, jnp.zeros((), x.dtype))
    return (jnp.einsum("bcr,bor->bco", z, Bg.astype(x.dtype))
            * scale_b[:, None, None].astype(x.dtype))


def paged_delta_weight(ad: PagedLoRA) -> jnp.ndarray:
    """Per-row dense ΔW_b = scale_b · (B_b A_b)ᵀ: (B, din, dout).

    The paged counterpart of folding a LoRA delta into a base weight — used
    by the MLA absorbed-decode path, where the ``wkv_b`` adapter must merge
    into the absorbed projection per batch row.  Materializes per-row
    weights (B · din · dout), so it is the dense fallback, not a fast path.
    """
    Ag, Bg, rank_b, scale_b = _paged_gather(ad)
    R = Ag.shape[1]
    lane = jnp.arange(R)[None, :, None]
    Ag = jnp.where(lane < rank_b[:, None, None], Ag, 0.0)
    delta = jnp.einsum("bor,brd->bdo", Bg.astype(jnp.float32),
                       Ag.astype(jnp.float32))
    return delta * scale_b[:, None, None]


def lora_proj(x: jnp.ndarray, w: jnp.ndarray, adapter: Optional[Any] = None) -> jnp.ndarray:
    """y = x @ w (+ LoRA delta). x: (..., in), w: (in, out).

    ``adapter`` is ``None`` (base model — NO adapter math is traced, the
    compiled step contains no LoRA dots), a classic ``{"A", "B", "scale"}``
    leaf, or a :class:`PagedLoRA` multi-tenant leaf (per-row adapters).
    """
    if adapter is None:
        return x @ w
    if isinstance(adapter, PagedLoRA):
        with jax.named_scope("lora_delta"):
            return x @ w + paged_lora_delta(x, adapter)
    if USE_KERNEL and x.ndim == 3:
        from repro.kernels import ops as kops
        return kops.lora_matmul(x, w, adapter["A"], adapter["B"], adapter["scale"])
    y = x @ w
    with jax.named_scope("lora_delta"):
        z = x @ adapter["A"].T.astype(x.dtype)
        y = y + (z @ adapter["B"].T.astype(x.dtype)) * adapter["scale"].astype(x.dtype)
    return y


# ---------------------------------------------------------------------------
# adapter-tree construction
# ---------------------------------------------------------------------------

def target_leaves(params: Any, targets: Sequence[str]) -> List[Tuple[Tuple, jnp.ndarray]]:
    """All (path, leaf) pairs whose final key is in `targets` and that look
    like 2-D weights (possibly with a leading scan axis)."""
    out = []
    for path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
        keys = tuple(getattr(k, "key", getattr(k, "idx", None)) for k in path)
        if keys[-1] in targets and leaf.ndim in (2, 3):
            out.append((keys, leaf))
    return out


def _set_path(tree: Dict, keys: Tuple, value: Any) -> None:
    node = tree
    for k in keys[:-1]:
        node = node.setdefault(k, {})
    node[keys[-1]] = value


def init_lora(params: Any, targets: Sequence[str], rank: int, alpha: float,
              key: jax.Array, dtype=jnp.float32, sigma: float = 0.02) -> Dict:
    """Build an adapter tree mirroring `params` at the target leaves.

    For a scanned leaf ``(L, in, out)`` the adapter is ``A: (L, r, in)``,
    ``B: (L, out, r)``; for a plain ``(in, out)`` leaf it is ``(r, in)`` /
    ``(out, r)``.
    """
    tree: Dict = {}
    leaves = target_leaves(params, targets)
    ks = jax.random.split(key, max(len(leaves), 1))
    for (keys, leaf), k in zip(leaves, ks):
        if leaf.ndim == 3:
            L, din, dout = leaf.shape
            a = jax.random.normal(k, (L, rank, din)) * sigma
            b = jnp.zeros((L, dout, rank))
            # per-layer scale so the stacked tree is scan-compatible
            scale = jnp.full((L,), alpha / rank, jnp.float32)
        else:
            din, dout = leaf.shape
            a = jax.random.normal(k, (rank, din)) * sigma
            b = jnp.zeros((dout, rank))
            scale = jnp.asarray(alpha / rank, dtype=jnp.float32)
        _set_path(tree, keys, {
            "A": a.astype(dtype),
            "B": b.astype(dtype),
            "scale": scale,
        })
    return tree


def adapter_num_params(adapters: Any) -> int:
    n = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(adapters)[0]:
        last = getattr(path[-1], "key", None)
        if last in ("A", "B"):
            n += leaf.size
    return n


def merge_lora(params: Any, adapters: Dict) -> Any:
    """Return params with ΔW = scale·(BA)ᵀ folded into the target weights."""
    flat = dict(jax.tree_util.tree_flatten_with_path(adapters)[0])

    def keys_of(path):
        return tuple(getattr(k, "key", getattr(k, "idx", None)) for k in path)

    adapter_map: Dict[Tuple, Dict] = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(adapters)[0]:
        keys = keys_of(path)
        adapter_map.setdefault(keys[:-1], {})[keys[-1]] = leaf

    def merge(path, w):
        keys = keys_of(path)
        ad = adapter_map.get(keys)
        if ad is None:
            return w
        A, B, s = ad["A"], ad["B"], ad["scale"]
        if w.ndim == 3:
            sl = s[:, None, None] if getattr(s, "ndim", 0) == 1 else s
            delta = jnp.einsum("lor,lri->lio", B, A) * sl
        else:
            delta = (B @ A).T * s
        return (w.astype(jnp.float32) + delta.astype(jnp.float32)).astype(w.dtype)

    return jax.tree_util.tree_map_with_path(merge, params)


def match_rank(adapters: Dict, rank: int) -> Dict:
    """Algorithm 1 client-side rank matching: truncate (p > r_k) or zero-pad
    (p < r_k) the global adapters to the client's local rank.

    Host (numpy) leaves — e.g. a decoded wire payload — stay on the host:
    ``np.pad``/slicing produce the identical values without dispatching
    eager device ops, whose shapes change with the global rank every round
    and would otherwise trigger a fresh XLA compile per round."""
    import numpy as np

    def fix(path, leaf):
        xp = np if isinstance(leaf, np.ndarray) else jnp
        last = getattr(path[-1], "key", None)
        if last == "A":                       # (..., p, in)
            p = leaf.shape[-2]
            if p == rank:
                return leaf
            if p > rank:
                return leaf[..., :rank, :]
            pad = [(0, 0)] * leaf.ndim
            pad[-2] = (0, rank - p)
            return xp.pad(leaf, pad)
        if last == "B":                       # (..., out, p)
            p = leaf.shape[-1]
            if p == rank:
                return leaf
            if p > rank:
                return leaf[..., :rank]
            pad = [(0, 0)] * leaf.ndim
            pad[-1] = (0, rank - p)
            return xp.pad(leaf, pad)
        if last == "scale":
            # local training resumes at the client's own alpha/r scaling of
            # the *downloaded* update; keep scale consistent with stored B·A
            return leaf
        return leaf

    return jax.tree_util.tree_map_with_path(fix, adapters)
