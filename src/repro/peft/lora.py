"""LoRA adapters over arbitrary model parameter trees.

Convention (matches the paper): a target weight ``W`` used as ``y = x @ W``
with ``W: (in, out)`` carries an adapter ``{"A": (r, in), "B": (out, r)}``
so that the effective update is ``ΔWᵀ = (B A)ᵀ``:

    y = x @ W + scale * (x @ Aᵀ) @ Bᵀ ,   scale = alpha / r.

``B`` is zero-initialized and ``A`` is Gaussian (Hu et al. 2022), so training
starts at the base model.  When model layers are stacked for
``lax.scan`` (leading ``L`` axis), adapters carry the same leading axis.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

# set by launchers to route LoRA matmuls through the fused Pallas kernel
USE_KERNEL: bool = False


def lora_proj(x: jnp.ndarray, w: jnp.ndarray, adapter: Optional[Dict] = None) -> jnp.ndarray:
    """y = x @ w (+ LoRA delta). x: (..., in), w: (in, out)."""
    if adapter is None:
        return x @ w
    if USE_KERNEL and x.ndim == 3:
        from repro.kernels import ops as kops
        return kops.lora_matmul(x, w, adapter["A"], adapter["B"], adapter["scale"])
    y = x @ w
    z = x @ adapter["A"].T.astype(x.dtype)
    y = y + (z @ adapter["B"].T.astype(x.dtype)) * adapter["scale"].astype(x.dtype)
    return y


# ---------------------------------------------------------------------------
# adapter-tree construction
# ---------------------------------------------------------------------------

def target_leaves(params: Any, targets: Sequence[str]) -> List[Tuple[Tuple, jnp.ndarray]]:
    """All (path, leaf) pairs whose final key is in `targets` and that look
    like 2-D weights (possibly with a leading scan axis)."""
    out = []
    for path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
        keys = tuple(getattr(k, "key", getattr(k, "idx", None)) for k in path)
        if keys[-1] in targets and leaf.ndim in (2, 3):
            out.append((keys, leaf))
    return out


def _set_path(tree: Dict, keys: Tuple, value: Any) -> None:
    node = tree
    for k in keys[:-1]:
        node = node.setdefault(k, {})
    node[keys[-1]] = value


def init_lora(params: Any, targets: Sequence[str], rank: int, alpha: float,
              key: jax.Array, dtype=jnp.float32, sigma: float = 0.02) -> Dict:
    """Build an adapter tree mirroring `params` at the target leaves.

    For a scanned leaf ``(L, in, out)`` the adapter is ``A: (L, r, in)``,
    ``B: (L, out, r)``; for a plain ``(in, out)`` leaf it is ``(r, in)`` /
    ``(out, r)``.
    """
    tree: Dict = {}
    leaves = target_leaves(params, targets)
    ks = jax.random.split(key, max(len(leaves), 1))
    for (keys, leaf), k in zip(leaves, ks):
        if leaf.ndim == 3:
            L, din, dout = leaf.shape
            a = jax.random.normal(k, (L, rank, din)) * sigma
            b = jnp.zeros((L, dout, rank))
            # per-layer scale so the stacked tree is scan-compatible
            scale = jnp.full((L,), alpha / rank, jnp.float32)
        else:
            din, dout = leaf.shape
            a = jax.random.normal(k, (rank, din)) * sigma
            b = jnp.zeros((dout, rank))
            scale = jnp.asarray(alpha / rank, dtype=jnp.float32)
        _set_path(tree, keys, {
            "A": a.astype(dtype),
            "B": b.astype(dtype),
            "scale": scale,
        })
    return tree


def adapter_num_params(adapters: Any) -> int:
    n = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(adapters)[0]:
        last = getattr(path[-1], "key", None)
        if last in ("A", "B"):
            n += leaf.size
    return n


def merge_lora(params: Any, adapters: Dict) -> Any:
    """Return params with ΔW = scale·(BA)ᵀ folded into the target weights."""
    flat = dict(jax.tree_util.tree_flatten_with_path(adapters)[0])

    def keys_of(path):
        return tuple(getattr(k, "key", getattr(k, "idx", None)) for k in path)

    adapter_map: Dict[Tuple, Dict] = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(adapters)[0]:
        keys = keys_of(path)
        adapter_map.setdefault(keys[:-1], {})[keys[-1]] = leaf

    def merge(path, w):
        keys = keys_of(path)
        ad = adapter_map.get(keys)
        if ad is None:
            return w
        A, B, s = ad["A"], ad["B"], ad["scale"]
        if w.ndim == 3:
            sl = s[:, None, None] if getattr(s, "ndim", 0) == 1 else s
            delta = jnp.einsum("lor,lri->lio", B, A) * sl
        else:
            delta = (B @ A).T * s
        return (w.astype(jnp.float32) + delta.astype(jnp.float32)).astype(w.dtype)

    return jax.tree_util.tree_map_with_path(merge, params)


def match_rank(adapters: Dict, rank: int) -> Dict:
    """Algorithm 1 client-side rank matching: truncate (p > r_k) or zero-pad
    (p < r_k) the global adapters to the client's local rank.

    Host (numpy) leaves — e.g. a decoded wire payload — stay on the host:
    ``np.pad``/slicing produce the identical values without dispatching
    eager device ops, whose shapes change with the global rank every round
    and would otherwise trigger a fresh XLA compile per round."""
    import numpy as np

    def fix(path, leaf):
        xp = np if isinstance(leaf, np.ndarray) else jnp
        last = getattr(path[-1], "key", None)
        if last == "A":                       # (..., p, in)
            p = leaf.shape[-2]
            if p == rank:
                return leaf
            if p > rank:
                return leaf[..., :rank, :]
            pad = [(0, 0)] * leaf.ndim
            pad[-2] = (0, rank - p)
            return xp.pad(leaf, pad)
        if last == "B":                       # (..., out, p)
            p = leaf.shape[-1]
            if p == rank:
                return leaf
            if p > rank:
                return leaf[..., :rank]
            pad = [(0, 0)] * leaf.ndim
            pad[-1] = (0, rank - p)
            return xp.pad(leaf, pad)
        if last == "scale":
            # local training resumes at the client's own alpha/r scaling of
            # the *downloaded* update; keep scale consistent with stored B·A
            return leaf
        return leaf

    return jax.tree_util.tree_map_with_path(fix, adapters)
