from repro.peft.lora import (
    adapter_num_params,
    init_lora,
    lora_proj,
    match_rank,
    merge_lora,
    target_leaves,
)
