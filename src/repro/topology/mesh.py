"""Mesh construction.

Production single pod: v5e-256 as (data=16, model=16).
Multi-pod:  2 pods = 512 chips as (pod=2, data=16, model=16) — the ``pod``
axis carries only data parallelism + the federated upload/download
collectives (DCN-friendly), never tensor parallelism.
Serving: (data=1, model=N) — decode is latency-bound, so every device goes
to tensor parallelism; scale-out replicas are separate engine processes.

Functions, not module constants: importing this module must never touch JAX
device state (the dry-run sets XLA_FLAGS *before* the first jax import).
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1) -> Mesh:
    """Tiny mesh over however many real devices exist (tests / examples)."""
    n = len(jax.devices())
    return jax.make_mesh((n // model, model), ("data", "model"))


def make_serve_mesh(model: int = 0, *, devices: Optional[Sequence] = None) -> Mesh:
    """Serving mesh (data=1, model=N) over the first N devices.

    ``model=0`` takes every device.  Parity tests build subset meshes of a
    forced 8-device host platform with ``model`` in {1, 2, 4, 8}.
    """
    devs = list(devices) if devices is not None else list(jax.devices())
    n = model or len(devs)
    if n > len(devs):
        raise ValueError(f"requested model={n} but only {len(devs)} devices")
    return jax.make_mesh((1, n), ("data", "model"), devices=devs[:n])


def make_fed_mesh(data: int = 0, *, devices: Optional[Sequence] = None) -> Mesh:
    """Federated simulation mesh (data=N, model=1) over the first N devices.

    The data axis carries the cohort's client dimension (see
    :mod:`repro.topology.fed`); ``model`` is kept (size 1) so fed specs and
    training specs share the same axis vocabulary.  ``data=0`` takes every
    device.
    """
    devs = list(devices) if devices is not None else list(jax.devices())
    n = data or len(devs)
    if n > len(devs):
        raise ValueError(f"requested data={n} but only {len(devs)} devices")
    return jax.make_mesh((n, 1), ("data", "model"), devices=devs[:n])


def data_axes(mesh: Mesh):
    """Axes carrying the batch dimension."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def axis_size(mesh, name: str) -> int:
    """Axis size by name (1 for absent axes).

    Reads ``mesh.shape`` — the name→size mapping shared by ``Mesh`` and
    ``jax.sharding.AbstractMesh`` — so partition rules can be validated
    abstractly (the contract checker builds device-free meshes).
    """
    return dict(mesh.shape).get(name, 1)
