"""Shared topology layer: mesh construction + partition rules.

One home for everything that decides *where tensors live*, consumed by both
the trainer (``launch/``) and the serving stack (``serve/``):

  * :mod:`repro.topology.mesh` — production / host / serve mesh builders and
    axis helpers;
  * :mod:`repro.topology.partitioning` — training-side PartitionSpec rules
    (params, batches, KV caches, optimizer state) plus the
    ``CACHE_LEAF_RANKS`` table and ``to_shardings``;
  * :mod:`repro.topology.serve` — serving-side specs: ring KV caches with a
    head-sharded (not sequence-sharded) layout, per-slot engine state, and
    the paged multi-tenant adapter pools;
  * :mod:`repro.topology.fed` — federated-side specs: client-parallel
    cohort layouts for the sharded cohort runner, plus ``make_fed_mesh``.

``launch/mesh.py`` and ``launch/sharding.py`` remain as thin re-export shims
so existing imports keep working.
"""
from repro.topology.fed import (
    fed_client_pspecs,
    fed_pspecs,
)
from repro.topology.mesh import (
    axis_size,
    data_axes,
    make_fed_mesh,
    make_host_mesh,
    make_production_mesh,
    make_serve_mesh,
)
from repro.topology.partitioning import (
    CACHE_LEAF_RANKS,
    ZERO3_THRESHOLD,
    batch_pspecs,
    cache_pspecs,
    param_pspec,
    params_pspecs,
    replicated_pspecs,
    to_shardings,
)
from repro.topology.serve import (
    serve_adapter_pspecs,
    serve_cache_pspecs,
    serve_pspecs,
    serve_state_pspecs,
)

__all__ = [
    "CACHE_LEAF_RANKS",
    "ZERO3_THRESHOLD",
    "axis_size",
    "batch_pspecs",
    "cache_pspecs",
    "data_axes",
    "fed_client_pspecs",
    "fed_pspecs",
    "make_fed_mesh",
    "make_host_mesh",
    "make_production_mesh",
    "make_serve_mesh",
    "param_pspec",
    "params_pspecs",
    "replicated_pspecs",
    "serve_adapter_pspecs",
    "serve_cache_pspecs",
    "serve_pspecs",
    "serve_state_pspecs",
    "to_shardings",
]
