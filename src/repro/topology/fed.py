"""Federated-side partition specs: client-parallel cohort layouts.

The sharded cohort runner stacks equal-(rank, steps) clients along a
leading client axis — adapters ``(C, L, r, d)``, optimizer state, and batch
schedules ``(C, steps, B, T)`` — and vmaps one local-training step over it.
On a fed mesh ``(data=N, model=1)`` that client axis shards over ``data``:
each device trains ``C/N`` clients and the only collective is the implicit
gather when the server pulls the cohort's results.  Base params replicate
(every simulated client fine-tunes the same frozen base, and smoke-scale
models don't need tensor parallelism — the ``model`` axis is kept at 1 so
the same rule set extends to larger bases later).

Consumed exactly like ``serve_pspecs``: build the bundle once per (config,
mesh) and hand the specs to ``jit`` as pytree-prefix in/out shardings.
Every rule degrades to replicated when the client axis does not divide the
``data`` axis (the runner pads cohorts to a multiple of the axis size, so
this only triggers for hand-built shapes).
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
from jax.sharding import Mesh, PartitionSpec as P

from repro.common.config import ModelConfig
from repro.topology.mesh import data_axes
from repro.topology.partitioning import _fits, params_pspecs


def _client_axis(mesh: Mesh):
    dax = data_axes(mesh)
    return dax if len(dax) > 1 else dax[0]


def fed_client_pspecs(mesh: Mesh, tree: Optional[Any] = None) -> Any:
    """Specs for a client-stacked pytree (leading axis = cohort clients).

    With ``tree=None`` returns the single pytree-*prefix* spec ``P(data)``
    — leading axis over ``data``, trailing dims replicated — which is what
    the runner feeds ``jit``'s in/out shardings (no concrete cohort tree
    needed at trace-cache time).  With a concrete/abstract ``tree``,
    returns a matching tree of full specs, degrading to replicated where
    the leading dim does not divide the axis.
    """
    ax = _client_axis(mesh)
    if tree is None:
        return P(ax)

    def fix(leaf):
        if leaf.ndim == 0 or not _fits(mesh, leaf.shape[0], ax):
            return P(*([None] * leaf.ndim))
        return P(*((ax,) + (None,) * (leaf.ndim - 1)))

    return jax.tree.map(fix, tree)


def fed_pspecs(mesh: Mesh, cfg: Optional[ModelConfig] = None,
               params: Optional[Any] = None, cohort: Optional[Any] = None,
               batch: Optional[Any] = None) -> Dict[str, Any]:
    """The spec bundle for one sharded cohort step.

    * ``params`` — the frozen base: replicated (prefix ``P()``) unless a
      concrete tree + config is supplied, in which case the training
      Megatron rules apply on the mesh's ``model`` axis (=1 on fed meshes,
      so they reduce to replicated anyway);
    * ``cohort`` — client-stacked adapters / optimizer state: client axis
      over ``data``;
    * ``batch`` — the per-client batch schedule ``(C, steps, B, T)``:
      client axis over ``data``.
    """
    if cfg is not None and params is not None:
        pspec = params_pspecs(mesh, cfg, params)
    else:
        pspec = P()
    return {
        "params": pspec,
        "cohort": fed_client_pspecs(mesh, cohort),
        "batch": fed_client_pspecs(mesh, batch),
    }
