"""Serving-side partition specs: the decode hot loop's tensor layouts.

Training shards the KV cache's *sequence* axis over ``model`` (prefill is
throughput-bound and GSPMD's flash-decoding partial-softmax merge is fine
there).  Decode is latency-bound: one token per step means the sequence axis
no longer amortizes the merge collectives, so serving shards the *head*
axis instead — Megatron-style tensor parallelism where attention is
collective-free per shard and the only communication is the all-reduce at
each row-parallel output projection (``wo`` / ``w_down``):

  * GQA ring caches ``(B, cap, K, hd)``: KV-head axis ``K`` → ``model``
    (query heads follow their group: ``H = g·K`` shards with them);
  * MLA compressed latents ``(B, cap, kvr)``: replicated — the latent
    stream is tiny by construction and the absorbed-decode query heads
    carry the parallelism instead (latent-attention head sharding);
  * SSM / RWKV recurrent state: head/state axis → ``model`` as in training;
  * per-slot engine state (``(B,)``-leading leaves): batch → ``data``;
  * paged adapter pools: follow the base weight's Megatron layout —
    column-parallel targets shard the B-pool's ``dout``, row-parallel
    targets shard the A-pool's ``din``; indirection/rank tables replicated.
    The Pallas bgmv path keeps pools replicated (the kernel is opaque to
    GSPMD; only the XLA twin participates in tensor parallelism).

Every rule degrades to ``None`` when an axis does not divide, so any model
shape lowers on any mesh — an axis that does not fit is simply replicated.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
from jax.sharding import Mesh, PartitionSpec as P

from repro.common.config import ModelConfig
from repro.topology.mesh import data_axes
from repro.topology.partitioning import (
    CACHE_LEAF_RANKS,
    _COL_MODEL,
    _ROW_MODEL,
    _fits,
    params_pspecs,
)

# serving shards these GQA ring-cache leaves on the KV-head axis
_HEADED_CACHE = ("k", "v", "k_scale", "v_scale")
# recurrent-state leaves keep their training-side head/state sharding
_STATE_CACHE = ("ssm", "wkv")


def _batch_axis(mesh: Mesh, dim: int):
    dax = data_axes(mesh)
    if _fits(mesh, dim, dax):
        return dax if len(dax) > 1 else dax[0]
    if _fits(mesh, dim, dax[-1]):
        return dax[-1]
    return None


def serve_cache_pspecs(mesh: Mesh, cfg: ModelConfig, cache: Any) -> Any:
    """Head-sharded ring-cache specs (see module docstring)."""
    ranks = CACHE_LEAF_RANKS

    def fix(path, leaf):
        keys = tuple(getattr(k, "key", getattr(k, "idx", None)) for k in path)
        last = keys[-1]
        nd = leaf.ndim
        base = ranks.get(last, nd)
        lead = max(0, nd - base)          # leading layer-stack axes
        spec = [None] * nd
        if last in ("pos", "length") or nd == lead:
            return P(*spec)
        spec[lead] = _batch_axis(mesh, leaf.shape[lead])
        if last in _HEADED_CACHE and nd > lead + 2:
            if _fits(mesh, leaf.shape[lead + 2], "model"):
                spec[lead + 2] = "model"
        elif last in _STATE_CACHE and nd > lead + 1:
            if _fits(mesh, leaf.shape[lead + 1], "model"):
                spec[lead + 1] = "model"
        return P(*spec)

    return jax.tree_util.tree_map_with_path(fix, cache)


def serve_state_pspecs(mesh: Mesh, state: Any) -> Any:
    """Per-slot engine state: every ``(B, ...)`` leaf shards batch → data."""

    def fix(leaf):
        if leaf.ndim == 0:
            return P()
        spec = [None] * leaf.ndim
        spec[0] = _batch_axis(mesh, leaf.shape[0])
        return P(*spec)

    return jax.tree.map(fix, state)


def _pool_leaf_spec(mesh: Mesh, keys, leaf) -> P:
    """Spec for one array inside a paged-pool / classic adapter leaf-dict.

    ``keys`` ends with (..., target_name, {"A"|"B"|"scale"}).
    Layouts: pool A ``(L?, P, pr, din)`` / B ``(L?, P, dout, pr)``;
    classic A ``(L?, r, din)`` / B ``(L?, dout, r)``.
    """
    part = keys[-1]
    target = keys[-2] if len(keys) >= 2 else None
    nd = leaf.ndim
    spec = [None] * nd
    if part == "A" and target in _ROW_MODEL and nd >= 2:
        if _fits(mesh, leaf.shape[-1], "model"):
            spec[-1] = "model"                      # din follows row-parallel in
    elif part == "B" and target in _COL_MODEL and nd >= 2:
        if _fits(mesh, leaf.shape[-2], "model"):
            spec[-2] = "model"                      # dout follows col-parallel out
    return P(*spec)


def serve_adapter_pspecs(mesh: Mesh, adapters: Any,
                         lora_impl: str = "xla") -> Any:
    """Specs for the engine's ``adapters`` argument: a registry device-state
    dict, a classic single-tenant adapter tree, or ``None``."""
    if adapters is None:
        return None

    def replicated(tree):
        return jax.tree.map(lambda l: P(*([None] * l.ndim)), tree)

    from repro.serve.adapters import is_device_state

    if is_device_state(adapters):
        if lora_impl == "kernel":
            return replicated(adapters)
        def fix(path, leaf):
            keys = tuple(getattr(k, "key", getattr(k, "idx", None))
                         for k in path)
            if keys[0] != "pools":
                return P(*([None] * leaf.ndim))     # table / rank: replicated
            return _pool_leaf_spec(mesh, keys, leaf)
        return jax.tree_util.tree_map_with_path(fix, adapters)

    if lora_impl == "kernel":
        return replicated(adapters)

    def fix(path, leaf):
        keys = tuple(getattr(k, "key", getattr(k, "idx", None)) for k in path)
        return _pool_leaf_spec(mesh, keys, leaf)

    return jax.tree_util.tree_map_with_path(fix, adapters)


def serve_pspecs(mesh: Mesh, cfg: ModelConfig, params: Any, cache: Any,
                 state: Any, adapters: Any = None,
                 lora_impl: str = "xla") -> Dict[str, Any]:
    """The full spec bundle for one engine: params reuse the training
    Megatron rules (``params_pspecs``); cache/state/adapters get the
    serving-specific rules above."""
    return {
        "params": params_pspecs(mesh, cfg, params),
        "cache": serve_cache_pspecs(mesh, cfg, cache),
        "state": serve_state_pspecs(mesh, state),
        "adapters": serve_adapter_pspecs(mesh, adapters, lora_impl),
    }
