"""Checkpointing: pytree <-> npz with path-string keys, plus pickled
round-state blobs for the federated trainer.

Restores into an existing tree structure (dtype/shape validated), so a
checkpoint written on host can be restored under a mesh by sharding the
loaded arrays with ``jax.device_put`` against the target shardings.

All writes are **atomic**: bytes go to a temp file in the destination
directory first and land via ``os.replace``, so a crash mid-write leaves
either the previous checkpoint or none — never a torn file.  ``np.savez``
silently appends ``.npz`` to extensionless paths; :func:`save` writes
through an open file object instead, so ``save(p)`` / ``restore(p)``
round-trip for any ``p`` (the legacy suffix-append lookup is kept on the
read side for old checkpoints).

:func:`save_state` / :func:`restore_state` persist an arbitrary picklable
object (the federated round state: rng states, per-leaf accumulator dicts
keyed by tuple paths, RoundRecord history) with the same atomicity;
:func:`to_host` / :func:`to_device` convert the array leaves of nested
containers so device trees pickle portably and come back as jnp arrays.
"""
from __future__ import annotations

import os
import pickle
import tempfile
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

_SEP = "/"


def _key_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return _SEP.join(parts)


def _npz_path(path: str) -> str:
    """Where :func:`save` actually wrote ``path``: exact path if present,
    else the legacy ``np.savez`` suffix-append location."""
    if os.path.exists(path) or path.endswith(".npz"):
        return path
    return path + ".npz"


def _atomic_write(path: str, write_fn) -> None:
    """Write via ``write_fn(file_object)`` to a temp file in ``path``'s
    directory, fsync, then ``os.replace`` into place — a crash leaves the
    previous file (or nothing), never a torn one."""
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=d, prefix=os.path.basename(path) + ".tmp.")
    try:
        with os.fdopen(fd, "wb") as f:
            write_fn(f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise


def save(path: str, tree: Any, step: Optional[int] = None) -> None:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    arrays = {_key_str(p): np.asarray(v) for p, v in flat}
    if step is not None:
        arrays["__step__"] = np.asarray(step)
    # writing through the file object (not a path string) stops np.savez
    # appending ".npz", so the atomic replace lands on the requested name
    _atomic_write(path, lambda f: np.savez(f, **arrays))


def restore(path: str, like: Any) -> Any:
    """Restore into the structure of `like` (shape/dtype checked)."""
    data = np.load(_npz_path(path))
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    out = []
    for p, ref in flat:
        k = _key_str(p)
        if k not in data:
            raise KeyError(f"checkpoint missing key {k}")
        arr = data[k]
        if tuple(arr.shape) != tuple(ref.shape):
            raise ValueError(f"shape mismatch at {k}: {arr.shape} vs {ref.shape}")
        out.append(jnp.asarray(arr, dtype=ref.dtype))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), out)


def restore_step(path: str) -> Optional[int]:
    data = np.load(_npz_path(path))
    return int(data["__step__"]) if "__step__" in data else None


# ---------------------------------------------------------------------------
# pickled state blobs (federated round state)
# ---------------------------------------------------------------------------


def save_state(path: str, state: Any) -> None:
    """Atomically pickle an arbitrary state object (pass array leaves
    through :func:`to_host` first so the blob is device-independent)."""
    _atomic_write(path, lambda f: pickle.dump(state, f,
                                              protocol=pickle.HIGHEST_PROTOCOL))


def restore_state(path: str) -> Any:
    with open(path, "rb") as f:
        return pickle.load(f)


def to_host(obj: Any) -> Any:
    """Recursively convert array leaves of nested dict/list/tuple/set
    containers to host numpy (scalars, strings, None pass through) —
    makes device trees picklable and portable."""
    if isinstance(obj, dict):
        return {k: to_host(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return type(obj)(to_host(v) for v in obj)
    if isinstance(obj, (set, frozenset)):
        return type(obj)(to_host(v) for v in obj)
    if isinstance(obj, jax.Array):
        return np.asarray(jax.device_get(obj))
    return obj


def to_device(obj: Any) -> Any:
    """Inverse of :func:`to_host`: numpy array leaves come back as jnp
    arrays (containers recursed, everything else untouched)."""
    if isinstance(obj, dict):
        return {k: to_device(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return type(obj)(to_device(v) for v in obj)
    if isinstance(obj, (set, frozenset)):
        return type(obj)(to_device(v) for v in obj)
    if isinstance(obj, np.ndarray) and obj.dtype != object:
        return jnp.asarray(obj)
    return obj
