"""Checkpointing: pytree <-> npz with path-string keys.

Restores into an existing tree structure (dtype/shape validated), so a
checkpoint written on host can be restored under a mesh by sharding the
loaded arrays with ``jax.device_put`` against the target shardings.
"""
from __future__ import annotations

import os
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

_SEP = "/"


def _key_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return _SEP.join(parts)


def save(path: str, tree: Any, step: Optional[int] = None) -> None:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    arrays = {_key_str(p): np.asarray(v) for p, v in flat}
    if step is not None:
        arrays["__step__"] = np.asarray(step)
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    np.savez(path, **arrays)


def restore(path: str, like: Any) -> Any:
    """Restore into the structure of `like` (shape/dtype checked)."""
    data = np.load(path if path.endswith(".npz") else path + ".npz")
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    out = []
    for p, ref in flat:
        k = _key_str(p)
        if k not in data:
            raise KeyError(f"checkpoint missing key {k}")
        arr = data[k]
        if tuple(arr.shape) != tuple(ref.shape):
            raise ValueError(f"shape mismatch at {k}: {arr.shape} vs {ref.shape}")
        out.append(jnp.asarray(arr, dtype=ref.dtype))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), out)


def restore_step(path: str) -> Optional[int]:
    data = np.load(path if path.endswith(".npz") else path + ".npz")
    return int(data["__step__"]) if "__step__" in data else None
