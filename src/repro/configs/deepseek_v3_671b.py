"""deepseek-v3-671b — MLA, 1 shared + 256 routed experts top-8, MTP.
[arXiv:2412.19437]

61L d_model=7168 128H (GQA kv=128 → MLA) d_ff=2048 vocab=129280,
MoE 256e top-8.  d_ff=2048 is the per-expert (and, per the assignment,
dense-layer) intermediate size; the first 3 layers are dense, the remainder
MoE with one shared expert; sigmoid router scoring (V3 style); MLA caches
only the compressed latent (kv_lora_rank 512 + 64 RoPE dims) at decode.
"""
from repro.common.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,
    head_dim=128,
    d_ff=2048,
    vocab_size=129280,
    num_experts=256,
    experts_per_token=8,
    num_shared_experts=1,
    moe_d_ff=2048,
    first_dense_layers=3,
    router_sigmoid=True,
    router_aux_coef=0.001,
    use_mla=True,
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_head_dim=128,
    qk_rope_head_dim=64,
    v_head_dim=128,
    mtp_depth=1,
    source="arXiv:2412.19437",
)

SMOKE = CONFIG.replace(
    name="deepseek-v3-smoke", num_layers=3, d_model=256, num_heads=4,
    num_kv_heads=4, head_dim=64, d_ff=256, vocab_size=512,
    num_experts=4, experts_per_token=2, moe_d_ff=128, first_dense_layers=1,
    q_lora_rank=64, kv_lora_rank=32, qk_nope_head_dim=32, qk_rope_head_dim=16,
    v_head_dim=32, dtype="float32",
)
