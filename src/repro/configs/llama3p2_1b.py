"""LLaMA-3.2-1B — the paper's second experimental model. [ai.meta.com Llama 3.2]

16L d_model=2048 32H (GQA kv=8) d_ff=8192 vocab=128256.
"""
from repro.common.config import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-1b",
    family="dense",
    num_layers=16,
    d_model=2048,
    num_heads=32,
    num_kv_heads=8,
    head_dim=64,
    d_ff=8192,
    vocab_size=128256,
    rope_theta=500_000.0,
    tie_embeddings=True,
    source="Llama 3.2 model card (paper §4.1)",
)

SMOKE = CONFIG.replace(
    name="llama3.2-smoke", num_layers=2, d_model=256, num_heads=4,
    num_kv_heads=2, head_dim=64, d_ff=512, vocab_size=512, dtype="float32",
)
