"""zamba2-1.2b — Mamba2 backbone + shared attention blocks. [arXiv:2411.15242]

38L d_model=2048 32H (GQA kv=32) d_ff=8192 vocab=32000, ssm_state=64.
The single shared attention(+MLP) block is stored once and applied after
every 6th Mamba2 layer (params shared across applications, Zamba-style).
"""
from repro.common.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    num_layers=38,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=32000,
    ssm_state=64,
    ssm_conv=4,
    ssm_expand=2,
    ssm_head_dim=64,
    attn_every=6,
    source="arXiv:2411.15242",
)

SMOKE = CONFIG.replace(
    name="zamba2-smoke", num_layers=4, d_model=256, num_heads=4,
    num_kv_heads=4, head_dim=64, d_ff=512, vocab_size=512, attn_every=2,
    ssm_state=16, ssm_head_dim=32, dtype="float32",
)
