"""Architecture registry: the 10 assigned architectures + the paper's own
models (TinyLlama-1.1B, LLaMA-3.2-1B), each in its own module, plus reduced
smoke variants and per-family LoRA target defaults.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.common.config import ModelConfig

ARCH_IDS = [
    "phi3_vision_4p2b",
    "zamba2_1p2b",
    "rwkv6_1p6b",
    "qwen1p5_32b",
    "granite_moe_1b_a400m",
    "qwen3_4b",
    "qwen2p5_14b",
    "qwen2_0p5b",
    "deepseek_v3_671b",
    "musicgen_medium",
    # paper's own models
    "tinyllama_1p1b",
    "llama3p2_1b",
]

ASSIGNED = ARCH_IDS[:10]

_ALIAS = {
    "phi-3-vision-4.2b": "phi3_vision_4p2b",
    "zamba2-1.2b": "zamba2_1p2b",
    "rwkv6-1.6b": "rwkv6_1p6b",
    "qwen1.5-32b": "qwen1p5_32b",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "qwen3-4b": "qwen3_4b",
    "qwen2.5-14b": "qwen2p5_14b",
    "qwen2-0.5b": "qwen2_0p5b",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "musicgen-medium": "musicgen_medium",
    "tinyllama-1.1b": "tinyllama_1p1b",
    "llama-3.2-1b": "llama3p2_1b",
}


def get_config(name: str) -> ModelConfig:
    mod_name = _ALIAS.get(name, name)
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def get_smoke_config(name: str) -> ModelConfig:
    mod_name = _ALIAS.get(name, name)
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.SMOKE


def lora_targets(cfg: ModelConfig) -> tuple:
    """Default LoRA target modules per family (paper: attention q/k/v/o;
    adapted for attention-free / hybrid / MLA families — DESIGN.md §4)."""
    if cfg.use_mla:
        return ("wq_a", "wq_b", "wkv_a", "wkv_b", "wo")
    if cfg.family == "ssm":
        return ("wr", "wk", "wv", "wg", "wo")
    if cfg.family == "hybrid":
        return ("wq", "wk", "wv", "wo", "in_proj", "out_proj")
    return ("wq", "wk", "wv", "wo")


def long_context_variant(cfg: ModelConfig, window: int = 8192) -> ModelConfig:
    """Sub-quadratic variant for long_500k: SSM/hybrid-native archs are
    already O(1)-state; full-attention archs get a sliding window (documented
    in DESIGN.md §Shape-skips)."""
    if cfg.family == "ssm":
        return cfg
    return cfg.replace(sliding_window=window)
