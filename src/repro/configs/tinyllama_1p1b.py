"""TinyLlama-1.1B — the paper's primary experimental model. [arXiv:2401.02385]

22L d_model=2048 32H (GQA kv=4) d_ff=5632 vocab=32000.
"""
from repro.common.config import ModelConfig

CONFIG = ModelConfig(
    name="tinyllama-1.1b",
    family="dense",
    num_layers=22,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    head_dim=64,
    d_ff=5632,
    vocab_size=32000,
    source="arXiv:2401.02385 (paper §4.1)",
)

SMOKE = CONFIG.replace(
    name="tinyllama-smoke", num_layers=2, d_model=256, num_heads=4,
    num_kv_heads=2, head_dim=64, d_ff=512, vocab_size=512, dtype="float32",
)
