"""phi-3-vision-4.2b — phi3-mini LM backbone + CLIP vision frontend (stub).

[hf:microsoft/Phi-3-vision-128k-instruct]  32L d_model=3072 32H (GQA kv=32)
d_ff=8192 vocab=32064.  The ViT/CLIP encoder + projector front-end is a STUB:
``input_specs()`` provides precomputed patch embeddings (576 patches of
dim 1024, CLIP ViT-L/14-336 penultimate features); the framework implements
the language decoder that consumes them plus a linear projector.
"""
from repro.common.config import ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    num_layers=32,
    d_model=3072,
    num_heads=32,
    num_kv_heads=32,
    head_dim=96,
    d_ff=8192,
    vocab_size=32064,
    rope_theta=10_000.0,
    frontend="vision",
    frontend_dim=1024,
    num_patches=576,
    source="hf:microsoft/Phi-3-vision-128k-instruct",
)

SMOKE = CONFIG.replace(
    name="phi-3-vision-smoke", num_layers=2, d_model=256, num_heads=4,
    num_kv_heads=4, head_dim=64, d_ff=512, vocab_size=512,
    frontend_dim=64, num_patches=8, dtype="float32",
)
