"""qwen1.5-32b — dense, QKV bias. [hf:Qwen/Qwen1.5-0.5B family scaling]

64L d_model=5120 40H (GQA kv=40) d_ff=27392 vocab=152064.
decode_32k uses the int8 KV cache (bf16 cache would be 21.5 GB/device on a
v5e-256 — over HBM; see DESIGN.md §Shape-skips).
"""
from repro.common.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-32b",
    family="dense",
    num_layers=64,
    d_model=5120,
    num_heads=40,
    num_kv_heads=40,
    head_dim=128,
    d_ff=27392,
    vocab_size=152064,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    source="hf:Qwen/Qwen1.5-0.5B",
)

SMOKE = CONFIG.replace(
    name="qwen1.5-smoke", num_layers=2, d_model=256, num_heads=4,
    num_kv_heads=4, head_dim=64, d_ff=512, vocab_size=512, dtype="float32",
)
