"""musicgen-medium — decoder-only over EnCodec tokens. [arXiv:2306.05284]

48L d_model=1536 24H (GQA kv=24) d_ff=6144 vocab=2048.  The EnCodec
conv-codec frontend is a STUB: ``input_specs()`` provides precomputed frame
embeddings (sum of the 4 codebook embeddings, delay-pattern applied) of dim
1024; decode emits codebook-token logits (vocab 2048).
"""
from repro.common.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="audio",
    num_layers=48,
    d_model=1536,
    num_heads=24,
    num_kv_heads=24,
    head_dim=64,
    d_ff=6144,
    vocab_size=2048,
    frontend="audio",
    frontend_dim=1024,
    num_codebooks=4,
    source="arXiv:2306.05284",
)

SMOKE = CONFIG.replace(
    name="musicgen-smoke", num_layers=2, d_model=256, num_heads=4,
    num_kv_heads=4, head_dim=64, d_ff=512, vocab_size=256,
    frontend_dim=64, dtype="float32",
)
