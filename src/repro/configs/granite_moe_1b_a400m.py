"""granite-moe-1b-a400m — 32 experts, top-8. [hf:ibm-granite/granite-3.0-1b-a400m-base]

24L d_model=1024 16H (GQA kv=8) d_ff=512 vocab=49155, MoE 32e top-8.
(d_ff=512 is the per-expert intermediate size.)
"""
from repro.common.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab_size=49155,
    num_experts=32,
    experts_per_token=8,
    moe_d_ff=512,
    router_aux_coef=0.01,
    tie_embeddings=True,
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
)

SMOKE = CONFIG.replace(
    name="granite-moe-smoke", num_layers=2, d_model=256, num_heads=4,
    num_kv_heads=2, head_dim=64, d_ff=128, vocab_size=512,
    num_experts=4, experts_per_token=2, moe_d_ff=128, dtype="float32",
)
