"""Sharding-constraint helpers usable from model code.

``constrain(x, spec)`` applies ``with_sharding_constraint`` against the
*ambient* mesh (the ``with mesh:`` context the launcher establishes) and is
a no-op when there is no mesh (unit tests, host examples) or when a named
axis does not divide the corresponding dim.  This keeps model code
mesh-agnostic while letting us pin down activation layouts where GSPMD's
propagation picks pathological strategies (e.g. partially-sharded attention
contractions when head counts don't divide the model axis).
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple, Union

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

Axis = Union[None, str, Tuple[str, ...]]

# The launcher-registered mesh (``with mesh:`` does not populate JAX's
# abstract-mesh context in this version, so we carry our own).
_ACTIVE_MESH = None


def set_active_mesh(mesh) -> None:
    global _ACTIVE_MESH
    _ACTIVE_MESH = mesh


class active_mesh:
    """Context manager: ``with active_mesh(mesh): fn.lower(...)``"""

    def __init__(self, mesh):
        self.mesh = mesh

    def __enter__(self):
        self.prev = _ACTIVE_MESH
        set_active_mesh(self.mesh)
        return self.mesh

    def __exit__(self, *exc):
        set_active_mesh(self.prev)
        return False


def _ambient_mesh():
    if _ACTIVE_MESH is not None:
        return _ACTIVE_MESH
    try:
        mesh = jax.sharding.get_abstract_mesh()
    except Exception:
        return None
    if mesh is None or not getattr(mesh, "axis_names", ()):
        return None
    return mesh


def mesh_axis_sizes() -> dict:
    mesh = _ambient_mesh()
    if mesh is None:
        return {}
    return dict(zip(mesh.axis_names, mesh.shape.values() if hasattr(mesh.shape, "values")
                    else mesh.shape))


def _axis_size(sizes: dict, axis: Axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        n = 1
        for a in axis:
            n *= sizes.get(a, 1)
        return n
    return sizes.get(axis, 1)


def constrain(x, spec: Sequence[Axis]):
    """with_sharding_constraint(x, P(*spec)) with divisibility guards."""
    mesh = _ambient_mesh()
    if mesh is None:
        return x
    sizes = dict(zip(mesh.axis_names,
                     mesh.shape.values() if hasattr(mesh.shape, "values") else mesh.shape))
    fixed = []
    for axis, dim in zip(spec, x.shape):
        if axis is None:
            fixed.append(None)
            continue
        names = axis if isinstance(axis, tuple) else (axis,)
        if not all(n in sizes for n in names):
            fixed.append(None)
            continue
        fixed.append(axis if dim % _axis_size(sizes, axis) == 0 else None)
    fixed += [None] * (x.ndim - len(fixed))
    try:
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, P(*fixed)))
    except Exception:
        return x


def batch_axes() -> Axis:
    sizes = mesh_axis_sizes()
    if "pod" in sizes:
        return ("pod", "data")
    if "data" in sizes:
        return "data"
    return None


def shard_map(f, mesh, in_specs, out_specs, check_vma: bool = False):
    """Version-portable ``shard_map``: jax >= 0.5 exposes ``jax.shard_map``
    with ``check_vma``; 0.4.x only has the experimental one with
    ``check_rep`` (same semantics: replication/varying-manual-axes check)."""
    try:
        sm = jax.shard_map
    except AttributeError:
        from jax.experimental.shard_map import shard_map as esm
        return esm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                   check_rep=check_vma)
    return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
              check_vma=check_vma)
