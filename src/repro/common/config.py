"""Config system for the repro framework.

Dataclass-based, immutable, serializable.  One ``ModelConfig`` per
architecture (see ``repro/configs``), plus federated / training / serving
configs consumed by the launchers.
"""
from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass, field
from typing import Any, Optional, Sequence


@dataclass(frozen=True)
class ModelConfig:
    """Architecture description.

    All assigned architectures (dense / moe / ssm / hybrid / vlm / audio)
    are expressible with this one config; family-specific fields default
    to "off".
    """

    name: str
    family: str                       # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                 # 0 -> d_model // num_heads
    # ---- attention options -------------------------------------------------
    qkv_bias: bool = False            # Qwen1.5/2/2.5 style
    qk_norm: bool = False             # Qwen3 style per-head RMSNorm on q,k
    rope_theta: float = 10_000.0
    sliding_window: int = 0           # 0 = full attention; >0 = window size
    tie_embeddings: bool = False
    # ---- MoE ---------------------------------------------------------------
    num_experts: int = 0
    experts_per_token: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0                 # expert FF dim (granite: 512, dsv3: 2048)
    first_dense_layers: int = 0       # deepseek-v3: first k layers dense
    router_aux_coef: float = 0.0      # load-balance loss coefficient
    router_sigmoid: bool = False      # deepseek-v3 sigmoid scoring
    moe_capacity_factor: float = 1.25 # per-expert capacity factor
    # ---- MLA (DeepSeek-V3) ---------------------------------------------------
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0
    mtp_depth: int = 0                # multi-token-prediction extra streams
    # ---- SSM (Mamba2 / Zamba2) ----------------------------------------------
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    # ---- hybrid (Zamba2) -----------------------------------------------------
    attn_every: int = 0               # shared attn block every k ssm layers
    # ---- RWKV6 ----------------------------------------------------------------
    rwkv_head_dim: int = 64
    rwkv_decay_lora: int = 64         # rank of data-dependent decay MLP
    # ---- modality stub frontends ----------------------------------------------
    frontend: str = ""                # "" | "vision" | "audio"
    frontend_dim: int = 0             # stub modality embedding dim
    num_patches: int = 0              # vision: patches prepended to text
    num_codebooks: int = 0            # audio: EnCodec codebooks
    # ---- numerics ---------------------------------------------------------------
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    # ---- provenance ----------------------------------------------------------
    source: str = ""                  # citation for the config

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    # -- derived ---------------------------------------------------------------
    @property
    def attn_free(self) -> bool:
        return self.family == "ssm"

    @property
    def q_dim(self) -> int:
        if self.use_mla:
            return self.num_heads * (self.qk_nope_head_dim + self.qk_rope_head_dim)
        return self.num_heads * self.head_dim

    @property
    def kv_head_dim(self) -> int:
        return self.head_dim

    @property
    def d_inner(self) -> int:          # mamba2 inner width
        return self.ssm_expand * self.d_model

    @property
    def num_ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def num_rwkv_heads(self) -> int:
        return self.d_model // self.rwkv_head_dim

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), indent=2)

    def param_count(self) -> int:
        """Analytic total parameter count (embedding + blocks + head)."""
        d, ff, V, L = self.d_model, self.d_ff, self.vocab_size, self.num_layers
        n = V * d                      # embedding
        if not self.tie_embeddings:
            n += d * V                 # lm head
        n += d                         # final norm
        per_layer = 2 * d              # ln1, ln2
        if self.family == "ssm":       # rwkv6 block
            hd = self.rwkv_head_dim
            per_layer += 5 * d * d + d * d          # r,k,v,g,o + w proj
            per_layer += 2 * self.rwkv_decay_lora * d * 5   # ddlerp loras
            per_layer += 2 * (d // hd) * hd          # time_first/decay base
            per_layer += d * ff + ff * d + d * d     # channel mix
        else:
            per_layer += self._attn_params()
            per_layer += self._mlp_params()
        n += L * per_layer
        if self.family == "hybrid":
            # shared attention block counted once, not per layer
            n -= L * self._attn_params()
            n += self._attn_params() + 2 * self.d_model
        return n

    def _attn_params(self) -> int:
        d = self.d_model
        if self.use_mla:
            qr, kvr = self.q_lora_rank, self.kv_lora_rank
            nope, rope, vd = self.qk_nope_head_dim, self.qk_rope_head_dim, self.v_head_dim
            H = self.num_heads
            return (d * qr + qr * H * (nope + rope)
                    + d * (kvr + rope) + kvr * H * (nope + vd)
                    + H * vd * d + qr + kvr)
        H, K, hd = self.num_heads, self.num_kv_heads, self.head_dim
        n = d * H * hd + 2 * d * K * hd + H * hd * d
        if self.qkv_bias:
            n += H * hd + 2 * K * hd
        if self.qk_norm:
            n += 2 * hd
        return n

    def _mlp_params(self) -> int:
        d, ff = self.d_model, self.d_ff
        dense = 3 * d * ff            # swiglu gate/up/down
        if self.num_experts:
            e_ff = self.moe_d_ff or ff
            moe = self.num_experts * 3 * d * e_ff + d * self.num_experts
            moe += self.num_shared_experts * 3 * d * e_ff
            # deepseek: first_dense_layers use the dense MLP; average it in
            if self.first_dense_layers:
                frac = self.first_dense_layers / self.num_layers
                return int(frac * dense + (1 - frac) * moe)
            return moe
        return dense

    def active_param_count(self) -> int:
        """Activated parameters per token (MoE: only routed top-k experts)."""
        if not self.num_experts:
            return self.param_count()
        d, L = self.d_model, self.num_layers
        e_ff = self.moe_d_ff or self.d_ff
        full = self.param_count()
        all_expert = L * self.num_experts * 3 * d * e_ff
        if self.first_dense_layers:
            moe_layers = L - self.first_dense_layers
            all_expert = moe_layers * self.num_experts * 3 * d * e_ff
        active_expert = (all_expert // self.num_experts) * self.experts_per_token
        return full - all_expert + active_expert


@dataclass(frozen=True)
class ShapeConfig:
    """An assigned input shape (mode + global dims)."""
    name: str
    seq_len: int
    global_batch: int
    mode: str                          # "train" | "prefill" | "decode"

    @property
    def is_decode(self) -> bool:
        return self.mode == "decode"


@dataclass(frozen=True)
class LoRAConfig:
    rank: int = 16
    alpha: float = 16.0
    targets: Sequence[str] = ("wq", "wk", "wv", "wo")
    dropout: float = 0.0


@dataclass(frozen=True)
class OptimConfig:
    name: str = "adamw"
    lr: float = 3e-4                  # paper: 0.0003
    betas: tuple = (0.9, 0.999)
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip: float = 1.0
    schedule: str = "constant"        # constant | cosine | linear
    warmup_steps: int = 0
    total_steps: int = 1000


@dataclass(frozen=True)
class FedConfig:
    """Federated fine-tuning setup (paper §4.1)."""
    num_clients: int = 100
    clients_per_round: int = 10
    num_rounds: int = 75
    local_epochs: int = 1
    local_steps: int = 0              # if >0, overrides epochs
    dirichlet_alpha: float = 0.5
    method: str = "florist"           # florist|fedit|ffa|flora|flexlora
    tau: float = 0.9                  # energy threshold
    heterogeneous: bool = False
    # paper's heavy-tail rank distribution: 40x4, 20x8, 20x16, 10x32, 10x64
    rank_distribution: Sequence[tuple] = ((4, 40), (8, 20), (16, 20), (32, 10), (64, 10))
    homogeneous_rank: int = 16
    zero_padding: bool = False        # HetLoRA zero-pad for fedit/ffa
    seed: int = 0

    def client_ranks(self) -> list:
        if not self.heterogeneous:
            return [self.homogeneous_rank] * self.num_clients
        ranks = []
        for r, count in self.rank_distribution:
            ranks += [r] * count
        assert len(ranks) == self.num_clients, (len(ranks), self.num_clients)
        return ranks


@dataclass(frozen=True)
class MeshConfig:
    shape: tuple = (16, 16)
    axes: tuple = ("data", "model")
    multi_pod: bool = False


@dataclass
class RunConfig:
    """Top-level launcher config."""
    model: ModelConfig = None
    shape: ShapeConfig = None
    lora: LoRAConfig = field(default_factory=LoRAConfig)
    optim: OptimConfig = field(default_factory=OptimConfig)
    fed: FedConfig = field(default_factory=FedConfig)
    mesh: MeshConfig = field(default_factory=MeshConfig)
    remat: bool = True
    loss_chunk: int = 512             # chunked CE over sequence
    kv_cache_dtype: str = "bfloat16"  # or "int8"
    seed: int = 0


# ---------------------------------------------------------------------------
# Input shapes assigned to this paper.
# ---------------------------------------------------------------------------
INPUT_SHAPES = {
    "train_4k":    ShapeConfig("train_4k",    seq_len=4_096,   global_batch=256, mode="train"),
    "prefill_32k": ShapeConfig("prefill_32k", seq_len=32_768,  global_batch=32,  mode="prefill"),
    "decode_32k":  ShapeConfig("decode_32k",  seq_len=32_768,  global_batch=128, mode="decode"),
    "long_500k":   ShapeConfig("long_500k",   seq_len=524_288, global_batch=1,   mode="decode"),
}
