from repro.common.config import (
    FedConfig,
    INPUT_SHAPES,
    LoRAConfig,
    MeshConfig,
    ModelConfig,
    OptimConfig,
    RunConfig,
    ShapeConfig,
)

__all__ = [
    "FedConfig", "INPUT_SHAPES", "LoRAConfig", "MeshConfig", "ModelConfig",
    "OptimConfig", "RunConfig", "ShapeConfig",
]
