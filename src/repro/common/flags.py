"""Trace-time flags.

ANALYSIS_UNROLL: when True, every internal lax.scan (layer stack, flash
attention chunks, chunked CE, grad-accum) is fully unrolled at lowering.
Used ONLY by the roofline analysis lowering (reduced layer counts): XLA's
cost_analysis counts a while-loop body once, so unrolling is what makes
HLO_FLOPs/HLO_bytes exact.  Never enabled for the fit-proof compile or real
execution.
"""
ANALYSIS_UNROLL = False


def set_analysis_unroll(v: bool) -> None:
    global ANALYSIS_UNROLL
    ANALYSIS_UNROLL = bool(v)


def scan_unroll() -> bool:
    return ANALYSIS_UNROLL
