"""``XLA_FLAGS`` management for tools that tune the backend.

XLA parses ``XLA_FLAGS`` once, when the backend initializes (lazily, at the
first device lookup — not at ``import jax``), so these helpers work as long
as they run before any device use.  They APPEND to a user-set value instead
of clobbering it, and a flag whose *name* is already present is left alone
(the user's choice wins) — the clobbering bug class this module exists to
fix.  Shared by the dry-run, ``benchmarks/hlo_collectives.py`` and
``benchmarks/xla_flags_tune.py``.

No jax import here: the module must be importable before flag setup.
"""
from __future__ import annotations

import os
from typing import Mapping, Union


def _flag_name(flag: str) -> str:
    return flag.split("=", 1)[0]


def merge_flags(base: str, *flags: str) -> str:
    """Merge ``flags`` (full ``--name=value`` strings) into the flag string
    ``base``, skipping any whose name ``base`` already sets."""
    have = {_flag_name(f) for f in base.split()}
    add = [f for f in flags if _flag_name(f) not in have]
    return " ".join(([base] if base else []) + add)


def append_xla_flags(*flags: str) -> str:
    """Append ``flags`` (full ``--name=value`` strings) to ``XLA_FLAGS``,
    skipping any whose name is already set.  Returns the merged value."""
    merged = merge_flags(os.environ.get("XLA_FLAGS", ""), *flags)
    os.environ["XLA_FLAGS"] = merged
    return merged


def force_host_devices(n: int) -> str:
    """Request ``n`` virtual host devices — unless the caller's environment
    already chose a count."""
    return append_xla_flags(f"--xla_force_host_platform_device_count={n}")


def render_flags(flag_dict: Mapping[str, Union[str, int, bool]]) -> str:
    """Render a ``{name: value}`` flag set as an ``XLA_FLAGS`` fragment
    (for a child process env; booleans lower-case as XLA expects)."""
    out = []
    for k, v in flag_dict.items():
        if isinstance(v, bool):
            v = "true" if v else "false"
        out.append(f"--{k}={v}")
    return " ".join(out)
