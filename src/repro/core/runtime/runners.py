"""Client runners: who executes a round's local fine-tuning, and how.

A :class:`ClientRunner` consumes a :class:`~repro.core.runtime.schedulers.
RoundPlan` and trains every task against the round context ``ctx`` (the
:class:`~repro.core.federated.FederatedTrainer`: frozen ``params``,
``clients``, ``batch_size``, ``dp_clip``, ``_client_init``), calling
``deliver(task, trained_adapters)`` once per finished client so the server
can stream each update into the aggregator and drop it.

* ``sequential`` — one client at a time, exactly the legacy ``run_round``
  loop (same batch rng ``default_rng(1000·rnd + k)``, same step order):
  bit-for-bit reproducible.
* ``cohort`` — the client-side analogue of the batched server pipeline:
  tasks are grouped into equal-(rank, steps) cohorts, their init adapters
  and pre-drawn batch schedules are stacked along a client axis, and each
  cohort trains in ONE jitted ``vmap``-of-``scan`` train-step call.  Ragged
  batch sizes are padded with zero-masked rows (mathematically inert under
  the masked CE), so cohort training is numerically equivalent to the
  sequential loop up to batched-matmul reassociation.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, List, Tuple, Type

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim.adamw import adamw_init
from repro.train.step import make_train_step


class ClientRunner:
    """Local-training executor.  Subclasses implement :meth:`run`."""

    name: str = "?"

    def run(self, ctx, plan, deliver: Callable) -> None:
        """Train every task in ``plan``; call ``deliver(task, adapters)``
        once per completed client, in a deterministic order."""
        raise NotImplementedError


_REGISTRY: Dict[str, Type[ClientRunner]] = {}


def register_runner(name: str):
    def deco(cls: Type[ClientRunner]) -> Type[ClientRunner]:
        _REGISTRY[name] = cls
        cls.name = name
        return cls
    return deco


def make_runner(spec: Any, **cfg) -> ClientRunner:
    if isinstance(spec, ClientRunner):
        return spec
    try:
        return _REGISTRY[spec](**cfg)
    except KeyError:
        raise ValueError(f"unknown runner {spec!r} "
                         f"(registered: {sorted(_REGISTRY)})") from None


def available_runners() -> List[str]:
    return sorted(_REGISTRY)


# ---------------------------------------------------------------------------
# shared plumbing
# ---------------------------------------------------------------------------


def _init_getter(ctx):
    """Per-plan client-init resolver: a task resumes from its dispatch-time
    snapshot (async) or the aggregator's client-init for the current global
    state.  ``Aggregator.client_init(global_state, rank, a_init)`` depends
    only on the client's *rank*, so equal-rank clients share one computed
    tree instead of re-running the eager truncate/pad per client."""
    cache: Dict[int, Dict] = {}

    def get(task) -> Dict:
        if task.init_adapters is not None:
            return task.init_adapters
        rank = ctx.client_ranks[task.client_id]
        if rank not in cache:
            cache[rank] = ctx._client_init(task.client_id)
        return cache[rank]

    return get


def _batch_schedule(ctx, rnd: int, task) -> List[Dict[str, np.ndarray]]:
    """The exact batch sequence the legacy loop would draw for this task
    (same rng stream, same epoch re-permutation)."""
    data = ctx.clients[task.client_id]
    bs = min(ctx.batch_size, data.num_samples)
    brng = np.random.default_rng(1000 * rnd + task.client_id)
    batches: List[Dict[str, np.ndarray]] = []
    while len(batches) < task.steps:
        for batch in data.batches(bs, brng):
            batches.append(batch)
            if len(batches) >= task.steps:
                break
    return batches


def _maybe_clip(ctx, adapters: Dict, init_adapters: Dict) -> Dict:
    if ctx.dp_clip:
        from repro.core.privacy import clip_client_adapters
        return clip_client_adapters(adapters, init_adapters, ctx.dp_clip)
    return adapters


# ---------------------------------------------------------------------------
# sequential (legacy-equivalent)
# ---------------------------------------------------------------------------


@register_runner("sequential")
class SequentialRunner(ClientRunner):
    """One jitted train-step call per (client, batch) — the legacy loop."""

    def run(self, ctx, plan, deliver: Callable) -> None:
        step = ctx._train_step()
        task_init = _init_getter(ctx)
        for task in plan.tasks:
            adapters = task_init(task)
            init_adapters = adapters
            opt_state = adamw_init(adapters)
            for batch in _batch_schedule(ctx, plan.round, task):
                jb = {kk: jnp.asarray(v) for kk, v in batch.items()}
                adapters, opt_state, _ = step(ctx.params, adapters,
                                              opt_state, jb)
            deliver(task, _maybe_clip(ctx, adapters, init_adapters))


# ---------------------------------------------------------------------------
# cohort (vmapped)
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _cached_cohort_train(cfg, optim, loss_chunk: int, b_only: bool):
    """Jitted cohort trainer: vmap over the client axis of a scan over the
    local step axis.  jax.jit re-specializes per (cohort, rank, batch)
    shape, so every equal-shaped cohort reuses one compiled program."""
    step = make_train_step(cfg, optim, remat=False, loss_chunk=loss_chunk,
                           b_only=b_only)

    def one_client(params, adapters, batches):
        opt_state = adamw_init(adapters)

        def body(carry, batch):
            ad, opt = carry
            ad, opt, _ = step(params, ad, opt, batch)
            return (ad, opt), None

        (adapters, _), _ = jax.lax.scan(body, (adapters, opt_state), batches)
        return adapters

    return jax.jit(jax.vmap(one_client, in_axes=(None, 0, 0)))


@register_runner("cohort")
class CohortRunner(ClientRunner):
    """Equal-rank cohorts train in one compiled vmapped call each.

    Host-side prep replays the sequential batch draws, zero-pads ragged
    batch sizes up to ``ctx.batch_size`` (padded rows carry
    ``loss_mask = 0`` and contribute nothing to loss, gradient, or metric
    denominators), stacks adapters/batches along a new client axis, and
    dispatches one device call per (rank, steps) cohort instead of
    K·steps calls.  The client axis is padded to the next power of two
    with inert replicas (zero mask ⇒ zero gradients), so schedulers with
    varying arrival counts (``async``/``partial``) hit at most
    O(log K) compiled shapes instead of one per count.
    """

    def run(self, ctx, plan, deliver: Callable) -> None:
        task_init = _init_getter(ctx)
        prepared = [(task, task_init(task),
                     _batch_schedule(ctx, plan.round, task))
                    for task in plan.tasks]
        cohorts: Dict[Tuple[int, int], List[int]] = {}
        for i, (task, _, _) in enumerate(prepared):
            cohorts.setdefault((task.rank, task.steps), []).append(i)
        train = _cached_cohort_train(ctx.cfg, ctx.optim, 64,
                                     ctx.aggregator.trains_b_only)
        results: List[Dict] = [None] * len(prepared)
        for (_, steps), idxs in cohorts.items():
            k_c = len(idxs)
            pad_c = 1 << (k_c - 1).bit_length()      # next power of two
            seq_len = prepared[idxs[0]][2][0]["tokens"].shape[1]
            bs = ctx.batch_size              # fixed batch axis: stable shape
            toks = np.zeros((pad_c, steps, bs, seq_len), np.int32)
            mask = np.zeros((pad_c, steps, bs, seq_len), np.float32)
            for ci, i in enumerate(idxs):
                for si, b in enumerate(prepared[i][2]):
                    toks[ci, si, : b["tokens"].shape[0]] = b["tokens"]
                    mask[ci, si, : b["tokens"].shape[0]] = b["loss_mask"]
            inits = [prepared[i][1] for i in idxs]
            inits += [inits[0]] * (pad_c - k_c)      # inert pad replicas
            stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *inits)
            out = train(ctx.params, stacked,
                        {"tokens": jnp.asarray(toks),
                         "loss_mask": jnp.asarray(mask)})
            # ONE device→host transfer for the whole cohort; per-client
            # unstacking is then free numpy views (eager per-leaf device
            # slicing would cost a dispatch per (client, leaf))
            host_out = jax.device_get(out)
            for ci, i in enumerate(idxs):
                adapters = jax.tree.map(lambda x: x[ci], host_out)
                results[i] = _maybe_clip(ctx, adapters, prepared[i][1])
        for (task, _, _), adapters in zip(prepared, results):
            deliver(task, adapters)
