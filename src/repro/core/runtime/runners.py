"""Client runners: who executes a round's local fine-tuning, and how.

A :class:`ClientRunner` consumes a :class:`~repro.core.runtime.schedulers.
RoundPlan` and trains every task against the round context ``ctx`` (the
:class:`~repro.core.federated.FederatedTrainer`: frozen ``params``,
``clients``, ``batch_size``, ``_client_init``), calling ``deliver(task,
trained_adapters, init_adapters)`` once per finished client so the server
can stream each update through the transport (where DP clipping/noising
happens against ``init_adapters``) into the aggregator and drop it.

* ``sequential`` — one client at a time, exactly the legacy ``run_round``
  loop (same batch rng ``default_rng(1000·rnd + k)``, same step order):
  bit-for-bit reproducible.
* ``cohort`` — the client-side analogue of the batched server pipeline:
  tasks are grouped into equal-(rank, steps) cohorts, their init adapters
  and pre-drawn batch schedules are stacked along a client axis, and each
  cohort trains in ONE jitted ``vmap``-of-``scan`` train-step call.  Ragged
  batch sizes are padded with zero-masked rows (mathematically inert under
  the masked CE), so cohort training is numerically equivalent to the
  sequential loop up to batched-matmul reassociation.
* ``sharded_cohort`` — ``cohort`` with the client axis additionally sharded
  over the fed mesh's ``data`` axis (specs from
  :func:`repro.topology.fed_pspecs`, consumed the same way the serving
  stack consumes ``serve_pspecs``): a 1024-client round becomes a handful
  of compiled sharded calls, each training ``block/N`` clients per device.

Runners *stream*: each cohort block is prepared, trained, and delivered
before the next is staged, so peak host memory is one cohort of client
state — not the whole round's (``peak_live_clients`` records the
high-water mark for the O(cohort) memory tests).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Iterator, List, Optional, Tuple, Type

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim.adamw import adamw_init
from repro.train.step import make_train_step


class ClientRunner:
    """Local-training executor.  Subclasses implement :meth:`run`."""

    name: str = "?"

    def run(self, ctx, plan, deliver: Callable) -> None:
        """Train every task in ``plan``; call ``deliver(task, adapters,
        init_adapters)`` once per completed client, in a deterministic
        order."""
        raise NotImplementedError


_REGISTRY: Dict[str, Type[ClientRunner]] = {}


def register_runner(name: str):
    def deco(cls: Type[ClientRunner]) -> Type[ClientRunner]:
        _REGISTRY[name] = cls
        cls.name = name
        return cls
    return deco


def make_runner(spec: Any, **cfg) -> ClientRunner:
    if isinstance(spec, ClientRunner):
        return spec
    try:
        return _REGISTRY[spec](**cfg)
    except KeyError:
        raise ValueError(f"unknown runner {spec!r} "
                         f"(registered: {sorted(_REGISTRY)})") from None


def available_runners() -> List[str]:
    return sorted(_REGISTRY)


# ---------------------------------------------------------------------------
# shared plumbing
# ---------------------------------------------------------------------------


def _init_getter(ctx):
    """Per-plan client-init resolver: a task resumes from its dispatch-time
    snapshot (async) or the aggregator's client-init for the current global
    state.  ``Aggregator.client_init(global_state, rank, a_init)`` depends
    only on the task's *rank* (which a rank policy may have adapted away
    from the client's static profile), so equal-rank tasks share one
    computed tree instead of re-running the eager truncate/pad per client —
    at 1024 clients this is the difference between 4 and 1024 host-side
    tree builds."""
    cache: Dict[int, Dict] = {}

    def get(task) -> Dict:
        if task.init_adapters is not None:
            return task.init_adapters
        if task.rank not in cache:
            cache[task.rank] = ctx._client_init(task.client_id, task.rank)
        return cache[task.rank]

    return get


def _batch_schedule(ctx, rnd: int, task) -> List[Dict[str, np.ndarray]]:
    """The exact batch sequence the legacy loop would draw for this task
    (same rng stream, same epoch re-permutation)."""
    data = ctx.clients[task.client_id]
    bs = min(ctx.batch_size, data.num_samples)
    brng = np.random.default_rng(1000 * rnd + task.client_id)
    batches: List[Dict[str, np.ndarray]] = []
    while len(batches) < task.steps:
        for batch in data.batches(bs, brng):
            batches.append(batch)
            if len(batches) >= task.steps:
                break
    return batches


# ---------------------------------------------------------------------------
# sequential (legacy-equivalent)
# ---------------------------------------------------------------------------


@register_runner("sequential")
class SequentialRunner(ClientRunner):
    """One jitted train-step call per (client, batch) — the legacy loop."""

    def run(self, ctx, plan, deliver: Callable) -> None:
        step = ctx._train_step()
        task_init = _init_getter(ctx)
        for task in plan.tasks:
            init_adapters = task_init(task)
            adapters = init_adapters
            opt_state = adamw_init(adapters)
            for batch in _batch_schedule(ctx, plan.round, task):
                jb = {kk: jnp.asarray(v) for kk, v in batch.items()}
                adapters, opt_state, _ = step(ctx.params, adapters,
                                              opt_state, jb)
            deliver(task, adapters, init_adapters)


# ---------------------------------------------------------------------------
# cohort (vmapped) + sharded cohort (vmapped, client axis over the mesh)
# ---------------------------------------------------------------------------


def _cohort_train_fn(cfg, optim, loss_chunk: int, b_only: bool):
    """The un-jitted cohort trainer: vmap over the client axis of a scan
    over the local step axis.  ``fn(params, stacked_adapters, batches)``
    with batches ``{"tokens": (C, steps, B, T), "loss_mask": ...}`` returns
    the trained stacked adapters (an aval fixed point — asserted by the
    ``fed.cohort_step`` contract)."""
    step = make_train_step(cfg, optim, remat=False, loss_chunk=loss_chunk,
                           b_only=b_only)

    def one_client(params, adapters, batches):
        opt_state = adamw_init(adapters)

        def body(carry, batch):
            ad, opt = carry
            ad, opt, _ = step(params, ad, opt, batch)
            return (ad, opt), None

        (adapters, _), _ = jax.lax.scan(body, (adapters, opt_state), batches)
        return adapters

    return jax.vmap(one_client, in_axes=(None, 0, 0))


@functools.lru_cache(maxsize=None)
def _cached_cohort_train(cfg, optim, loss_chunk: int, b_only: bool):
    """Jitted cohort trainer.  jax.jit re-specializes per (cohort, rank,
    batch) shape, so every equal-shaped cohort reuses one compiled
    program."""
    return jax.jit(_cohort_train_fn(cfg, optim, loss_chunk, b_only))


@functools.lru_cache(maxsize=None)
def _cached_sharded_cohort_train(cfg, optim, loss_chunk: int, b_only: bool,
                                 mesh):
    """Jitted cohort trainer with the client axis sharded over ``data``.

    The fed specs are pytree *prefixes* (one spec per argument subtree,
    trailing dims replicated — see :func:`repro.topology.fed_pspecs`), so
    the wrapper is built once per (config, mesh) without concrete cohort
    trees; GSPMD then partitions every client-stacked leaf the same way.
    """
    from jax.sharding import NamedSharding

    from repro.topology import fed_pspecs

    specs = fed_pspecs(mesh)
    param_s = NamedSharding(mesh, specs["params"])
    cohort_s = NamedSharding(mesh, specs["cohort"])
    batch_s = NamedSharding(mesh, specs["batch"])
    return jax.jit(_cohort_train_fn(cfg, optim, loss_chunk, b_only),
                   in_shardings=(param_s, cohort_s, batch_s),
                   out_shardings=cohort_s)


def _group_cohorts(plan) -> Dict[Tuple[int, int], List]:
    """Tasks bucketed by (rank, steps) — each bucket trains in one
    compiled call (or a few fixed-size blocks of one)."""
    cohorts: Dict[Tuple[int, int], List] = {}
    for task in plan.tasks:
        cohorts.setdefault((task.rank, task.steps), []).append(task)
    return cohorts


def _stack_cohort(ctx, rnd: int, tasks: List, task_init, pad_c: int):
    """Host-side prep for one cohort block: replay the sequential batch
    draws, zero-pad ragged batch sizes (padded rows carry ``loss_mask = 0``
    and contribute nothing to loss, gradient, or metric denominators),
    stack inits/batches along a new client axis, and pad the client axis to
    ``pad_c`` with inert replicas (zero mask ⇒ zero gradients).

    Returns ``(stacked_adapters, {"tokens", "loss_mask"}, inits)`` with
    ``inits`` the unpadded per-task init trees (deliver needs them for the
    DP stage)."""
    steps = tasks[0].steps
    scheds = [_batch_schedule(ctx, rnd, t) for t in tasks]
    seq_len = scheds[0][0]["tokens"].shape[1]
    bs = ctx.batch_size                  # fixed batch axis: stable shape
    toks = np.zeros((pad_c, steps, bs, seq_len), np.int32)
    mask = np.zeros((pad_c, steps, bs, seq_len), np.float32)
    for ci, sched in enumerate(scheds):
        for si, b in enumerate(sched):
            toks[ci, si, : b["tokens"].shape[0]] = b["tokens"]
            mask[ci, si, : b["tokens"].shape[0]] = b["loss_mask"]
    inits = [task_init(t) for t in tasks]
    padded = inits + [inits[0]] * (pad_c - len(tasks))
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *padded)
    return stacked, {"tokens": toks, "loss_mask": mask}, inits


@register_runner("cohort")
class CohortRunner(ClientRunner):
    """Equal-rank cohorts train in one compiled vmapped call each.

    Host-side prep (see :func:`_stack_cohort`) stages ONE cohort block at a
    time: stack → train → one device→host transfer per block.  The client
    axis is padded to the next power of two, so schedulers with varying
    arrival counts (``async``/``partial``) hit at most O(log K) compiled
    shapes instead of one per count.

    Delivery order is a subclass policy (``stream``): the plain cohort
    runner buffers trained results and delivers in *plan order*, keeping
    the aggregator's stack column order identical to ``sequential`` (for
    SVD-based methods a permuted stack yields the same ΔW but can rotate
    near-degenerate singular vectors, which factor-level equivalence tests
    would see); ``sharded_cohort`` streams cohort-grouped, delivering each
    block as it finishes so host memory stays O(block) at 1000+ clients.
    """

    #: deliver per finished block (True) or buffered in plan order (False)
    stream = False

    def __init__(self):
        self.peak_live_clients = 0

    def _pad(self, k_c: int, ctx) -> int:
        return 1 << (k_c - 1).bit_length()       # next power of two

    def _train_fn(self, ctx):
        return _cached_cohort_train(ctx.cfg, ctx.optim, 64,
                                    ctx.aggregator.trains_b_only)

    def _params(self, ctx):
        return ctx.params

    def _blocks(self, tasks: List) -> Iterator[List]:
        yield tasks

    def run(self, ctx, plan, deliver: Callable) -> None:
        task_init = _init_getter(ctx)
        train = self._train_fn(ctx)
        params = self._params(ctx)
        order = {id(t): i for i, t in enumerate(plan.tasks)}
        buffered: Dict[int, Tuple] = {}
        for _, tasks in _group_cohorts(plan).items():
            for block in self._blocks(tasks):
                pad_c = self._pad(len(block), ctx)
                stacked, batch, inits = _stack_cohort(
                    ctx, plan.round, block, task_init, pad_c)
                self.peak_live_clients = max(self.peak_live_clients, pad_c)
                out = train(params, stacked,
                            {"tokens": jnp.asarray(batch["tokens"]),
                             "loss_mask": jnp.asarray(batch["loss_mask"])})
                # ONE device→host transfer for the whole block; per-client
                # unstacking is then free numpy views (eager per-leaf
                # device slicing would cost a dispatch per (client, leaf))
                host_out = jax.device_get(out)
                for ci, task in enumerate(block):
                    adapters = jax.tree.map(lambda x: x[ci], host_out)
                    if self.stream:
                        deliver(task, adapters, inits[ci])
                    else:
                        buffered[order[id(task)]] = (task, adapters,
                                                     inits[ci])
        for i in sorted(buffered):
            deliver(*buffered[i])


@register_runner("sharded_cohort")
class ShardedCohortRunner(CohortRunner):
    """Cohort training with the client axis sharded over the fed mesh.

    Each (rank, steps) cohort is cut into blocks of ≤ ``block`` clients,
    the block's client axis is padded to a multiple of the ``data`` axis
    (on top of the power-of-two rounding that bounds compiled-shape count),
    and one sharded jitted call trains ``pad_c / N`` clients per device.
    Blocks *stream*: each is delivered (cohort-grouped order) and dropped
    before the next is staged, so a 1024-client round never holds more
    than ``block`` trained trees on the host.  Base params are replicated
    once per round via a cached ``device_put`` (flora merges swap
    ``ctx.params`` between rounds, hence the id key).
    """

    stream = True

    def __init__(self, mesh=None, block: int = 256):
        super().__init__()
        self._mesh = mesh
        self.block = int(block)
        self._params_cache: Dict[int, Any] = {}

    @property
    def mesh(self):
        if self._mesh is None:
            from repro.topology import make_fed_mesh
            self._mesh = make_fed_mesh()
        return self._mesh

    def _pad(self, k_c: int, ctx) -> int:
        from repro.topology import axis_size
        data = axis_size(self.mesh, "data")
        pow2 = 1 << (k_c - 1).bit_length()
        return -(-pow2 // data) * data

    def _train_fn(self, ctx):
        return _cached_sharded_cohort_train(ctx.cfg, ctx.optim, 64,
                                            ctx.aggregator.trains_b_only,
                                            self.mesh)

    def _params(self, ctx):
        from jax.sharding import NamedSharding, PartitionSpec as P
        key = id(ctx.params)
        if key not in self._params_cache:
            self._params_cache.clear()   # params swapped (flora merge)
            self._params_cache[key] = jax.device_put(
                ctx.params, NamedSharding(self.mesh, P()))
        return self._params_cache[key]

    def _blocks(self, tasks: List) -> Iterator[List]:
        for i in range(0, len(tasks), self.block):
            yield tasks[i: i + self.block]


# ---------------------------------------------------------------------------
# contract: the sharded cohort step's aval fixed point + fed partitioning
# ---------------------------------------------------------------------------

from repro.analysis.registry import ContractCase, check_contract  # noqa: E402


@check_contract("fed.cohort_step")
def _contract_cohort_step(case):
    """Stacked adapter avals are a fixed point of the cohort train step
    (else the round loop retraces every cohort), and the client-stacked
    trees partition under the fed rules at the case's mesh width."""
    from repro.analysis import fixtures as FX
    from repro.common.config import OptimConfig
    from repro.topology import fed_client_pspecs
    from jax.sharding import PartitionSpec as P

    cfg = FX.tiny_config(case.family)
    params = FX.abstract_params(cfg)
    adapters = FX.abstract_adapters(cfg, params)
    C, steps, bs, seq = 4, 2, 2, 16
    stacked = jax.tree.map(
        lambda l: FX.sds((C,) + tuple(l.shape), l.dtype), adapters)
    batch = {"tokens": FX.sds((C, steps, bs, seq), jnp.int32),
             "loss_mask": FX.sds((C, steps, bs, seq), jnp.float32)}
    fn = _cohort_train_fn(cfg, OptimConfig(), 64, False)

    def out_check(out, _case):
        assert FX.avals_equal(out, stacked), "cohort adapter avals drift"

    mesh = FX.abstract_fed_mesh(case.mesh)
    specs = ({"params": params, "cohort": stacked, "batch": batch},
             {"params": jax.tree.map(lambda l: P(*([None] * l.ndim)), params),
              "cohort": fed_client_pspecs(mesh, stacked),
              "batch": fed_client_pspecs(mesh, batch)})
    return ContractCase(fn, (params, stacked, batch), out_check=out_check,
                        pspec_tree=specs, mesh=mesh)
