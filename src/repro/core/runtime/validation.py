"""Server-side update validation / quarantine gate.

PR 9's streaming aggregation made the server fold irreversible: once
``Aggregator.add_client`` has folded an update into the delta-mode
accumulator there is no way to subtract it back out, so one NaN, one
corrupted leaf, or one adversarially scaled client poisons the global
adapters for everyone.  The :class:`ValidationGate` sits in front of
every fold and screens each arriving update against three contracts:

* **finiteness** — every wire tensor (A, B) and the scale header must be
  free of NaN/Inf (a single NaN in the FLoRIST accumulator propagates to
  every singular value at finalize);
* **structure** — leaf paths, layer counts and (n_in, m_out) dims must
  match the round's reference dims, and the update's A/B rank dims must
  agree with each other and with the client's assigned task rank;
* **at-most-once** — duplicate deliveries of the same task (an
  at-least-once wire re-send) fold only once.

Norm-outlier quarantine needs to see the whole round before judging any
one client, which conflicts with streaming; the gate therefore has three
modes trading robustness against server memory:

``off``
    bypass — every submit folds immediately, exactly the pre-gate path.
``screen`` (default)
    streaming: finiteness/structure/duplicate checks per update, then an
    immediate fold.  O(1) extra memory, numerically identical to ``off``
    when nothing is rejected (same folds, same order, same weights).
``full``
    buffered: updates are held until :meth:`finish`, which computes a
    robust z-score on each update's delta L2 norm (median/MAD across the
    round, with a relative floor so a tight honest cluster — e.g. every
    client clipped to the same DP bound C — never self-rejects),
    quarantines outliers, renormalizes the surviving weights to the
    round's total mass (only when something was rejected, preserving
    bit-exactness for clean rounds), and folds survivors in arrival
    order.  Costs O(participants) held updates — the PR 9 streaming
    memory bound is deliberately given up for robustness.

Either way :meth:`finish` enforces the round quorum: fewer than
``min_clients`` accepted updates marks the round failed
(``quorum_met=False``) and the trainer keeps the previous global state
instead of finalizing a half-empty accumulator.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Optional

import numpy as np

from repro.core.aggregators.base import (adapter_leaf_paths, get_path,
                                         leaf_dims)

#: robust-σ consistency constant: σ ≈ 1.4826 · MAD for a normal sample
_MAD_SIGMA = 1.4826
#: MAD floor, relative to the median norm — an honest cluster tighter
#: than this (e.g. all updates clipped to the same DP bound) never
#: self-rejects on numerically-tiny spread
_REL_FLOOR = 0.05


@dataclasses.dataclass
class GateStats:
    """One round's validation outcome (returned by
    :meth:`ValidationGate.finish`)."""
    submitted: int = 0
    accepted: int = 0
    rejected_nonfinite: int = 0
    rejected_shape: int = 0
    rejected_duplicate: int = 0
    quarantined: int = 0
    quorum_met: bool = True

    @property
    def rejected(self) -> int:
        return (self.rejected_nonfinite + self.rejected_shape
                + self.rejected_duplicate)


@dataclasses.dataclass
class _Held:
    """One buffered submission awaiting the full-mode round verdict."""
    update: Dict
    weight: float
    rank: Optional[int]
    norm: float


class ValidationGate:
    """Validates client updates before they reach ``add_client``.

    Lifecycle mirrors the aggregator: ``begin_round(aggregator)`` →
    ``submit(...)`` per arriving update → ``finish()`` → read the
    returned :class:`GateStats` (including the quorum verdict).
    """

    def __init__(self, mode: str = "screen", mad_threshold: float = 6.0,
                 min_clients: int = 1, min_mad_samples: int = 4):
        if mode not in ("off", "screen", "full"):
            raise ValueError(f"unknown validation mode {mode!r} "
                             f"(valid: off, screen, full)")
        self.mode = mode
        self.mad_threshold = float(mad_threshold)
        self.min_clients = int(min_clients)
        self.min_mad_samples = int(min_mad_samples)
        self._agg = None
        self._dims: Optional[Dict] = None
        # id(task) -> task; holding the task pins its id for the round, so
        # a garbage-collected delivery can never alias a later one
        self._seen: Dict[int, Any] = {}
        self._held: List[_Held] = []
        self.stats = GateStats()

    # -- lifecycle ------------------------------------------------------------

    def begin_round(self, aggregator, dims: Optional[Dict] = None) -> None:
        self._agg = aggregator
        self._dims = dims
        self._seen = {}
        self._held = []
        self.stats = GateStats()

    def submit(self, task: Any, update: Dict, weight: float,
               rank: Optional[int] = None,
               init_adapters: Optional[Dict] = None) -> bool:
        """Screen one arriving update; fold it (``screen``/``off``) or
        hold it for the round verdict (``full``).  Returns False iff the
        update was rejected outright."""
        self.stats.submitted += 1
        if self.mode == "off":
            self._agg.add_client(update, weight, rank=rank)
            self.stats.accepted += 1
            return True
        if task is not None:
            key = id(task)
            if key in self._seen:
                self.stats.rejected_duplicate += 1
                return False
            self._seen[key] = task
        if not self._check_structure(update, rank):
            self.stats.rejected_shape += 1
            return False
        if not self._check_finite(update):
            self.stats.rejected_nonfinite += 1
            return False
        if self.mode == "screen":
            self._agg.add_client(update, weight, rank=rank)
            self.stats.accepted += 1
            return True
        self._held.append(_Held(update, float(weight), rank,
                                _delta_norm(update, init_adapters)))
        return True

    def finish(self) -> GateStats:
        """Close the round: full-mode quarantine + fold, then the quorum
        verdict.  Idempotent per ``begin_round``."""
        if self.mode == "full" and self._held:
            self._fold_held()
        self.stats.quorum_met = self.stats.accepted >= self.min_clients
        return self.stats

    # -- checks ---------------------------------------------------------------

    def _check_structure(self, update: Dict, rank: Optional[int]) -> bool:
        try:
            dims = leaf_dims(update)
        except (KeyError, AttributeError, IndexError):
            return False
        if self._dims is None:
            self._dims = dims
        elif dims != self._dims:
            return False
        for path in adapter_leaf_paths(update):
            leaf = get_path(update, path)
            r_a, r_b = leaf["A"].shape[-2], leaf["B"].shape[-1]
            if r_a != r_b or (rank is not None and r_a != rank):
                return False
        return True

    def _check_finite(self, update: Dict) -> bool:
        for path in adapter_leaf_paths(update):
            leaf = get_path(update, path)
            for name in ("A", "B", "scale"):
                if name in leaf and not bool(
                        np.all(np.isfinite(np.asarray(leaf[name])))):
                    return False
        return True

    # -- full-mode round verdict ----------------------------------------------

    def _fold_held(self) -> None:
        held = self._held
        reject: set = set()
        if len(held) >= self.min_mad_samples:
            norms = np.array([h.norm for h in held], np.float64)
            med = float(np.median(norms))
            mad = float(np.median(np.abs(norms - med)))
            denom = max(_MAD_SIGMA * mad, _REL_FLOOR * abs(med), 1e-12)
            for i, n in enumerate(norms):
                if abs(float(n) - med) / denom > self.mad_threshold:
                    reject.add(i)
        accepted = [h for i, h in enumerate(held) if i not in reject]
        self.stats.quarantined = len(reject)
        factor = 1.0
        if reject and accepted:
            w_all = sum(h.weight for h in held)
            w_acc = sum(h.weight for h in accepted)
            if w_acc > 0:
                factor = w_all / w_acc
        for h in accepted:
            self._agg.add_client(h.update, h.weight * factor, rank=h.rank)
            self.stats.accepted += 1
        self._held = []


def _delta_norm(update: Dict, init: Optional[Dict]) -> float:
    """Global L2 norm of the update's wire-tensor delta vs the round init
    (or of the raw tensors when no init is known), in float64 — the
    statistic the full-mode MAD quarantine judges."""
    total = 0.0
    for path in adapter_leaf_paths(update):
        leaf = get_path(update, path)
        ref = get_path(init, path) if init is not None else None
        for name in ("A", "B"):
            arr = np.asarray(leaf[name], np.float64)
            if ref is not None:
                arr = arr - np.asarray(ref[name], np.float64)
            total += float(np.sum(arr * arr))
    return math.sqrt(total)


def make_validator(spec: Any = "screen", **cfg) -> ValidationGate:
    """Coerce a gate spec (instance | mode name | None) into a
    :class:`ValidationGate`; an instance is returned as-is."""
    if isinstance(spec, ValidationGate):
        return spec
    return ValidationGate(mode=spec or "off", **cfg)
