"""Measured wire transport for federated adapter exchange.

The analytic cost model in :mod:`repro.core.costs` counts *parameters*; this
module puts actual **bytes** on a (simulated) wire so the two can be
cross-checked per round.  Three pieces:

* :class:`Codec` — pluggable array serialization (``fp32`` exact cast,
  ``bf16`` half-precision cast, ``int8`` symmetric per-tensor quantization),
  registered via :func:`register_codec` / built via :func:`make_codec`;
* :class:`AdapterPayload` — one serialized adapter tree: per-leaf encoded
  blocks plus the measured total byte size.  Packing honours the
  aggregator's *wire set* (``wire_arrays``: FFA sends only ``B``) and, for
  downlinks, the recorded per-layer ranks (rank-``p_l`` layers ship only
  their first ``p_l`` columns — zero padding never travels);
* :class:`Transport` — the round-trip used by the trainer: encode → count
  bytes → decode.  With the default ``fp32`` codec the round-trip is
  bit-exact, so the runtime reproduces the legacy loop; lossy codecs
  degrade exactly what a real deployment would (the wire tensors): clients
  resume from the decoded broadcast, and merge-into-base methods (FLoRA)
  fold the decoded stack into the base, while pure-broadcast methods still
  evaluate the server's exact aggregate.

``scale`` never travels: it is an O(L) header re-derived locally, and the
analytic model ignores it too, which keeps ``bytes == bytes_per_param ×
params`` an exact identity for the cast codecs.

**DP-on-the-wire**: with ``dp_clip``/``dp_sigma`` set, the uplink runs the
local Gaussian mechanism as a codec *stage* — the client's update delta
(trained − init) is clipped to L2 ≤ C and noised with std σ·C *before*
encoding, so the bytes on the wire are already privatized and the byte
accounting is unchanged (clip/noise don't alter shapes).  The noise key is
derived deterministically from ``(dp_seed, round, client_id)``, so runs
reproduce and no two uploads share a key.  This replaces the old
server-side noising sidecar in ``federated.py`` — privacy composes with
any codec, per-method byte accounting intact.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple, Type

import jax
import numpy as np

from repro.core.aggregators.base import (adapter_leaf_paths,
                                         default_wire_arrays, get_path,
                                         set_path)

try:  # ships with jax
    import ml_dtypes
    _BF16 = np.dtype(ml_dtypes.bfloat16)
except ImportError:  # pragma: no cover - jax always depends on ml_dtypes
    _BF16 = None

#: rank axis of each wire tensor (A: rows are rank, B: columns are rank)
_RANK_AXIS = {"A": -2, "B": -1}


# ---------------------------------------------------------------------------
# codecs
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class EncodedArray:
    """One serialized tensor: raw payload + the header needed to decode."""
    data: bytes
    shape: Tuple[int, ...]
    meta: Tuple[float, ...] = ()

    @property
    def num_bytes(self) -> int:
        # meta entries (e.g. a quantization scale) travel as fp32 headers
        return len(self.data) + 4 * len(self.meta)


class Codec:
    """Array serializer.  ``decode(encode(x))`` returns fp32 numpy."""

    name: str = "?"
    bytes_per_param: float = 4.0

    def encode(self, arr: Any) -> EncodedArray:
        raise NotImplementedError

    def decode(self, enc: EncodedArray) -> np.ndarray:
        raise NotImplementedError


_CODECS: Dict[str, Type[Codec]] = {}


def register_codec(name: str):
    def deco(cls: Type[Codec]) -> Type[Codec]:
        _CODECS[name] = cls
        cls.name = name
        return cls
    return deco


def make_codec(name: str) -> Codec:
    try:
        return _CODECS[name]()
    except KeyError:
        raise ValueError(f"unknown codec {name!r} "
                         f"(registered: {sorted(_CODECS)})") from None


def available_codecs() -> List[str]:
    return sorted(_CODECS)


@register_codec("fp32")
class Fp32Codec(Codec):
    """Exact for fp32 inputs — the round-trip is the identity."""
    bytes_per_param = 4.0

    def encode(self, arr) -> EncodedArray:
        a = np.asarray(arr, np.float32)
        return EncodedArray(a.tobytes(), a.shape)

    def decode(self, enc: EncodedArray) -> np.ndarray:
        return np.frombuffer(enc.data, np.float32).reshape(enc.shape)


@register_codec("bf16")
class Bf16Codec(Codec):
    """Truncate-to-bfloat16 cast (the paper's 2-byte accounting)."""
    bytes_per_param = 2.0

    def encode(self, arr) -> EncodedArray:
        if _BF16 is None:
            raise RuntimeError("bf16 codec requires ml_dtypes")
        a = np.asarray(arr, np.float32).astype(_BF16)
        return EncodedArray(a.tobytes(), a.shape)

    def decode(self, enc: EncodedArray) -> np.ndarray:
        return np.frombuffer(enc.data, _BF16).reshape(enc.shape) \
            .astype(np.float32)


@register_codec("int8")
class Int8Codec(Codec):
    """Symmetric per-tensor int8 quantization with an fp32 scale header."""
    bytes_per_param = 1.0

    def encode(self, arr) -> EncodedArray:
        a = np.asarray(arr, np.float32)
        amax = float(np.max(np.abs(a))) if a.size else 0.0
        scale = amax / 127.0 if amax > 0 else 1.0
        q = np.clip(np.rint(a / scale), -127, 127).astype(np.int8)
        return EncodedArray(q.tobytes(), a.shape, (scale,))

    def decode(self, enc: EncodedArray) -> np.ndarray:
        q = np.frombuffer(enc.data, np.int8).reshape(enc.shape)
        return q.astype(np.float32) * np.float32(enc.meta[0])


# ---------------------------------------------------------------------------
# payloads
# ---------------------------------------------------------------------------


def _wire_fn(aggregator) -> Any:
    return getattr(aggregator, "wire_arrays", None) or default_wire_arrays


@dataclasses.dataclass
class AdapterPayload:
    """One adapter tree as it travels: per-leaf encoded blocks + size.

    ``blocks`` maps leaf path → wire-array name → per-layer
    :class:`EncodedArray` list (a single whole-array block when no ragged
    per-layer ranks were given).
    """

    codec: str
    blocks: Dict[Tuple, Dict[str, List[EncodedArray]]]
    num_bytes: int

    @classmethod
    def pack(cls, tree: Dict, codec: Codec, wire_fn=default_wire_arrays,
             ranks: Optional[Dict[Tuple, Sequence[int]]] = None
             ) -> "AdapterPayload":
        """Serialize ``tree``'s wire arrays.  With ``ranks`` (per-leaf,
        per-layer, as recorded in an :class:`AggResult`), layer ``l`` of a
        leaf ships only its first ``r_l`` rank rows/columns.

        All wire arrays leave the device in ONE ``jax.device_get`` (ragged
        per-layer slicing happens host-side on the fetched buffers), so
        packing costs one sync per payload, not one per tensor."""
        items: List[Tuple[Tuple, str, Any]] = []
        for path in adapter_leaf_paths(tree):
            leaf = get_path(tree, path)
            for name, arr in wire_fn(leaf).items():
                items.append((path, name, arr))
        host = jax.device_get([arr for (_, _, arr) in items])
        blocks: Dict[Tuple, Dict[str, List[EncodedArray]]] = {}
        total = 0
        for (path, name, _), arr in zip(items, host):
            axis = _RANK_AXIS.get(name)
            rs = ranks.get(path) if ranks else None
            if rs is None or axis is None:
                encs = [codec.encode(arr)]
            else:
                layers = arr if arr.ndim == 3 else arr[None]
                encs = []
                for l, r_l in enumerate(rs):
                    lay = layers[l]
                    cut = lay[:r_l, :] if axis == -2 else lay[:, :r_l]
                    encs.append(codec.encode(cut))
            blocks.setdefault(path, {})[name] = encs
            total += sum(e.num_bytes for e in encs)
        return cls(codec.name, blocks, total)

    def unpack_into(self, tree: Dict, codec: Codec) -> Dict:
        """Rebuild a tree shaped like ``tree`` with every wire array
        replaced by its decoded bytes (non-wire entries, e.g. ``scale`` or a
        frozen ``A``, pass through from ``tree`` — they were never sent).
        Decoded leaves are host (numpy) arrays; downstream jnp ops move
        them to device on first use."""
        out: Dict = {}
        for path in adapter_leaf_paths(tree):
            leaf = dict(get_path(tree, path))
            for name, encs in self.blocks[path].items():
                ref = leaf[name]
                if len(encs) == 1 and encs[0].shape == tuple(ref.shape):
                    leaf[name] = codec.decode(encs[0])
                else:  # ragged per-layer blocks: zero-fill past each r_l
                    layers = np.zeros(ref.shape if ref.ndim == 3
                                      else (1,) + tuple(ref.shape), np.float32)
                    axis = _RANK_AXIS[name]
                    for l, enc in enumerate(encs):
                        dec = codec.decode(enc)
                        if axis == -2:
                            layers[l, :dec.shape[0], :] = dec
                        else:
                            layers[l, :, :dec.shape[1]] = dec
                    if ref.ndim != 3:
                        layers = layers[0]
                    leaf[name] = layers
            set_path(out, path, leaf)
        return out


# ---------------------------------------------------------------------------
# the transport
# ---------------------------------------------------------------------------


class Transport:
    """Measured client↔server wire: every exchanged adapter tree is
    serialized with the configured codec, its bytes are counted, and the
    *decoded* tree is what the receiving side actually uses."""

    def __init__(self, codec: Any = "fp32", dp_clip: float = 0.0,
                 dp_sigma: float = 0.0, dp_seed: int = 0):
        self.codec = codec if isinstance(codec, Codec) else make_codec(codec)
        self.dp_clip = float(dp_clip)
        self.dp_sigma = float(dp_sigma)
        self.dp_seed = int(dp_seed)

    def _dp_stage(self, adapters: Dict, init_adapters: Optional[Dict],
                  rnd: int, client_id: int) -> Dict:
        """Local DP on one upload: clip the update delta to L2 ≤ C, noise
        with std σ·C, re-anchor on the init.  Applied exactly once, before
        encoding."""
        if not (self.dp_clip or self.dp_sigma):
            return adapters
        from repro.core.privacy import (clip_update, local_gaussian_noise,
                                        tree_add, tree_sub)
        if init_adapters is None:
            raise ValueError("DP transport needs the round's init adapters "
                             "to form the update delta")
        clip = self.dp_clip or 1.0
        delta = tree_sub(adapters, init_adapters)
        delta, _ = clip_update(delta, clip)
        if self.dp_sigma:
            key = jax.random.fold_in(
                jax.random.fold_in(jax.random.PRNGKey(self.dp_seed), rnd),
                client_id)
            delta = local_gaussian_noise(delta, self.dp_sigma, clip, key)
        return tree_add(init_adapters, delta)

    def client_to_server(self, adapters: Dict, aggregator, *,
                         init_adapters: Optional[Dict] = None,
                         rnd: int = 0, client_id: int = 0
                         ) -> Tuple[Dict, int]:
        """Uplink one trained client tree (through the DP stage when
        configured).  Returns (decoded tree, bytes)."""
        wire = _wire_fn(aggregator)
        adapters = self._dp_stage(adapters, init_adapters, rnd, client_id)
        payload = AdapterPayload.pack(adapters, self.codec, wire)
        return payload.unpack_into(adapters, self.codec), payload.num_bytes

    def server_to_clients(self, agg, aggregator, num_receivers: int
                          ) -> Tuple[Optional[Dict], int]:
        """Downlink one round's result to ``num_receivers`` clients.

        Broadcast methods ship the global tree (ragged per-layer ranks —
        zero padding stays home) once per receiver; per-client methods
        (FlexLoRA) ship each tailored tree once.  Returns the decoded
        global tree (what clients resume from) and total downlink bytes.
        """
        wire = _wire_fn(aggregator)
        if agg.per_client is not None:
            nbytes = sum(
                AdapterPayload.pack(t, self.codec, wire).num_bytes
                for t in agg.per_client)
            if agg.global_adapters is None:
                return None, nbytes
            payload = AdapterPayload.pack(agg.global_adapters, self.codec,
                                          wire)
            return payload.unpack_into(agg.global_adapters, self.codec), nbytes
        if agg.global_adapters is None:
            return None, 0
        payload = AdapterPayload.pack(agg.global_adapters, self.codec, wire,
                                      ranks=agg.ranks)
        decoded = payload.unpack_into(agg.global_adapters, self.codec)
        return decoded, payload.num_bytes * num_receivers


def make_transport(spec: Any, **dp) -> Transport:
    """Coerce a transport spec (instance | codec name | Codec) into a
    :class:`Transport`.  ``dp`` kwargs (``dp_clip``/``dp_sigma``/
    ``dp_seed``) configure the uplink's DP stage; an already-built
    instance is returned as-is (its own DP config wins)."""
    if isinstance(spec, Transport):
        return spec
    return Transport(spec or "fp32", **dp)
