"""Measured wire transport for federated adapter exchange.

The analytic cost model in :mod:`repro.core.costs` counts *parameters*; this
module puts actual **bytes** on a (simulated) wire so the two can be
cross-checked per round.  Three pieces:

* :class:`Codec` — pluggable array serialization (``fp32`` exact cast,
  ``bf16`` half-precision cast, ``int8`` symmetric per-tensor quantization),
  registered via :func:`register_codec` / built via :func:`make_codec`;
* :class:`AdapterPayload` — one serialized adapter tree: per-leaf encoded
  blocks plus the measured total byte size.  Packing honours the
  aggregator's *wire set* (``wire_arrays``: FFA sends only ``B``) and, for
  downlinks, the recorded per-layer ranks (rank-``p_l`` layers ship only
  their first ``p_l`` columns — zero padding never travels);
* :class:`Transport` — the round-trip used by the trainer: encode → count
  bytes → decode.  With the default ``fp32`` codec the round-trip is
  bit-exact, so the runtime reproduces the legacy loop; lossy codecs
  degrade exactly what a real deployment would (the wire tensors): clients
  resume from the decoded broadcast, and merge-into-base methods (FLoRA)
  fold the decoded stack into the base, while pure-broadcast methods still
  evaluate the server's exact aggregate.

``scale`` never travels: it is an O(L) header re-derived locally, and the
analytic model ignores it too, which keeps ``bytes == bytes_per_param ×
params`` an exact identity for the cast codecs.

**DP-on-the-wire**: with ``dp_clip``/``dp_sigma`` set, the uplink runs the
local Gaussian mechanism as a codec *stage* — the client's update delta
(trained − init) is clipped to L2 ≤ C and noised with std σ·C *before*
encoding, so the bytes on the wire are already privatized and the byte
accounting is unchanged (clip/noise don't alter shapes).  The noise key is
derived deterministically from ``(dp_seed, round, client_id)``, so runs
reproduce and no two uploads share a key.  This replaces the old
server-side noising sidecar in ``federated.py`` — privacy composes with
any codec, per-method byte accounting intact.

**Hardening** (PR 10): every :class:`EncodedArray` carries a CRC-32 of its
payload bytes (out-of-band — checksums don't count against the measured
wire bytes, keeping the ``bytes == bytes_per_param × params`` identity),
verified at :meth:`AdapterPayload.unpack_into` along with shape/layer/rank
contract checks against the receiving tree; structural violations raise
:class:`PayloadError` (or :class:`PayloadCorrupted` for checksum
mismatches) host-side instead of silently broadcasting a corrupted leaf.
The uplink retries corrupted payloads with deterministic exponential
backoff + jitter on the simulated clock and declares the client dead
(:class:`DeadClientError`) after ``max_retries`` re-sends; the DP stage
runs exactly once per upload, *before* the retry loop, so a re-encode
never re-clips or re-noises.
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Any, Dict, List, Optional, Sequence, Tuple, Type

import jax
import numpy as np

from repro.core.aggregators.base import (adapter_leaf_paths,
                                         default_wire_arrays, get_path,
                                         set_path)

try:  # ships with jax
    import ml_dtypes
    _BF16 = np.dtype(ml_dtypes.bfloat16)
except ImportError:  # pragma: no cover - jax always depends on ml_dtypes
    _BF16 = None

#: rank axis of each wire tensor (A: rows are rank, B: columns are rank)
_RANK_AXIS = {"A": -2, "B": -1}


class PayloadError(ValueError):
    """A received payload violates the structural contract (shape, layer
    count, rank bound, or undecodable bytes) for the tree it targets."""


class PayloadCorrupted(PayloadError):
    """A received block's bytes do not match its CRC-32 checksum."""


class DeadClientError(RuntimeError):
    """A client's upload failed verification on every retry attempt."""

    def __init__(self, client_id: int, attempts: int, last: Exception):
        self.client_id, self.attempts, self.last = client_id, attempts, last
        super().__init__(f"client {client_id} declared dead after "
                         f"{attempts} failed upload attempts: {last}")


# ---------------------------------------------------------------------------
# codecs
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class EncodedArray:
    """One serialized tensor: raw payload + the header needed to decode.

    ``crc`` is an optional CRC-32 of ``data``, attached at pack time and
    verified at unpack.  It is integrity metadata, not wire payload: the
    analytic cost model counts parameters, and checksums (like TCP/IP
    framing) live below that accounting, so ``num_bytes`` excludes them —
    the ``bytes == bytes_per_param × params`` identity is untouched.
    """
    data: bytes
    shape: Tuple[int, ...]
    meta: Tuple[float, ...] = ()
    crc: Optional[int] = None

    @property
    def num_bytes(self) -> int:
        # meta entries (e.g. a quantization scale) travel as fp32 headers
        return len(self.data) + 4 * len(self.meta)

    def verify(self) -> None:
        """Raise :class:`PayloadCorrupted` if the bytes don't match the
        checksum (no-op for unchecksummed blocks)."""
        if self.crc is not None and zlib.crc32(self.data) != self.crc:
            raise PayloadCorrupted(
                f"checksum mismatch on block shape={self.shape}: "
                f"crc32={zlib.crc32(self.data):#010x} != {self.crc:#010x}")


class Codec:
    """Array serializer.  ``decode(encode(x))`` returns fp32 numpy."""

    name: str = "?"
    bytes_per_param: float = 4.0

    def encode(self, arr: Any) -> EncodedArray:
        raise NotImplementedError

    def decode(self, enc: EncodedArray) -> np.ndarray:
        raise NotImplementedError


_CODECS: Dict[str, Type[Codec]] = {}


def register_codec(name: str):
    def deco(cls: Type[Codec]) -> Type[Codec]:
        _CODECS[name] = cls
        cls.name = name
        return cls
    return deco


def make_codec(name: str) -> Codec:
    try:
        return _CODECS[name]()
    except KeyError:
        raise ValueError(f"unknown codec {name!r} "
                         f"(registered: {sorted(_CODECS)})") from None


def available_codecs() -> List[str]:
    return sorted(_CODECS)


@register_codec("fp32")
class Fp32Codec(Codec):
    """Exact for fp32 inputs — the round-trip is the identity."""
    bytes_per_param = 4.0

    def encode(self, arr) -> EncodedArray:
        a = np.asarray(arr, np.float32)
        return EncodedArray(a.tobytes(), a.shape)

    def decode(self, enc: EncodedArray) -> np.ndarray:
        return np.frombuffer(enc.data, np.float32).reshape(enc.shape)


@register_codec("bf16")
class Bf16Codec(Codec):
    """Truncate-to-bfloat16 cast (the paper's 2-byte accounting)."""
    bytes_per_param = 2.0

    def encode(self, arr) -> EncodedArray:
        if _BF16 is None:
            raise RuntimeError("bf16 codec requires ml_dtypes")
        a = np.asarray(arr, np.float32).astype(_BF16)
        return EncodedArray(a.tobytes(), a.shape)

    def decode(self, enc: EncodedArray) -> np.ndarray:
        return np.frombuffer(enc.data, _BF16).reshape(enc.shape) \
            .astype(np.float32)


@register_codec("int8")
class Int8Codec(Codec):
    """Symmetric per-tensor int8 quantization with an fp32 scale header."""
    bytes_per_param = 1.0

    def encode(self, arr) -> EncodedArray:
        a = np.asarray(arr, np.float32)
        amax = float(np.max(np.abs(a))) if a.size else 0.0
        scale = amax / 127.0 if amax > 0 else 1.0
        q = np.clip(np.rint(a / scale), -127, 127).astype(np.int8)
        return EncodedArray(q.tobytes(), a.shape, (scale,))

    def decode(self, enc: EncodedArray) -> np.ndarray:
        q = np.frombuffer(enc.data, np.int8).reshape(enc.shape)
        return q.astype(np.float32) * np.float32(enc.meta[0])


# ---------------------------------------------------------------------------
# payloads
# ---------------------------------------------------------------------------


def _wire_fn(aggregator) -> Any:
    return getattr(aggregator, "wire_arrays", None) or default_wire_arrays


@dataclasses.dataclass
class AdapterPayload:
    """One adapter tree as it travels: per-leaf encoded blocks + size.

    ``blocks`` maps leaf path → wire-array name → per-layer
    :class:`EncodedArray` list (a single whole-array block when no ragged
    per-layer ranks were given).
    """

    codec: str
    blocks: Dict[Tuple, Dict[str, List[EncodedArray]]]
    num_bytes: int

    @classmethod
    def pack(cls, tree: Dict, codec: Codec, wire_fn=default_wire_arrays,
             ranks: Optional[Dict[Tuple, Sequence[int]]] = None,
             checksum: bool = True) -> "AdapterPayload":
        """Serialize ``tree``'s wire arrays.  With ``ranks`` (per-leaf,
        per-layer, as recorded in an :class:`AggResult`), layer ``l`` of a
        leaf ships only its first ``r_l`` rank rows/columns.

        All wire arrays leave the device in ONE ``jax.device_get`` (ragged
        per-layer slicing happens host-side on the fetched buffers), so
        packing costs one sync per payload, not one per tensor.  With
        ``checksum`` (default) every block carries a CRC-32 verified at
        :meth:`unpack_into`."""
        items: List[Tuple[Tuple, str, Any]] = []
        for path in adapter_leaf_paths(tree):
            leaf = get_path(tree, path)
            for name, arr in wire_fn(leaf).items():
                items.append((path, name, arr))
        host = jax.device_get([arr for (_, _, arr) in items])
        blocks: Dict[Tuple, Dict[str, List[EncodedArray]]] = {}
        total = 0
        for (path, name, _), arr in zip(items, host):
            axis = _RANK_AXIS.get(name)
            rs = ranks.get(path) if ranks else None
            if rs is None or axis is None:
                encs = [codec.encode(arr)]
            else:
                layers = arr if arr.ndim == 3 else arr[None]
                encs = []
                for l, r_l in enumerate(rs):
                    lay = layers[l]
                    cut = lay[:r_l, :] if axis == -2 else lay[:, :r_l]
                    encs.append(codec.encode(cut))
            if checksum:
                encs = [dataclasses.replace(e, crc=zlib.crc32(e.data))
                        for e in encs]
            blocks.setdefault(path, {})[name] = encs
            total += sum(e.num_bytes for e in encs)
        return cls(codec.name, blocks, total)

    def unpack_into(self, tree: Dict, codec: Codec,
                    verify: bool = True) -> Dict:
        """Rebuild a tree shaped like ``tree`` with every wire array
        replaced by its decoded bytes (non-wire entries, e.g. ``scale`` or a
        frozen ``A``, pass through from ``tree`` — they were never sent).
        Decoded leaves are host (numpy) arrays; downstream jnp ops move
        them to device on first use.

        With ``verify`` (default) every block's CRC-32 is checked before
        decoding and the decoded shapes are validated against the contract
        implied by ``tree``: a whole-array block must match the reference
        shape exactly; ragged per-layer blocks must cover exactly the
        reference layer count with per-layer ranks within the reference
        rank dimension.  Violations raise :class:`PayloadCorrupted` /
        :class:`PayloadError` host-side — a corrupted leaf is never
        silently broadcast into the aggregator."""
        out: Dict = {}
        for path in adapter_leaf_paths(tree):
            leaf = dict(get_path(tree, path))
            for name, encs in self.blocks[path].items():
                ref = leaf[name]
                if verify:
                    for enc in encs:
                        enc.verify()
                if len(encs) == 1 and encs[0].shape == tuple(ref.shape):
                    leaf[name] = _checked_decode(codec, encs[0], path, name)
                else:  # ragged per-layer blocks: zero-fill past each r_l
                    axis = _RANK_AXIS.get(name)
                    if verify and axis is None:
                        raise PayloadError(
                            f"{'/'.join(map(str, path))}:{name}: ragged "
                            f"blocks for a non-rank wire array")
                    ref_shape = (tuple(ref.shape) if ref.ndim == 3
                                 else (1,) + tuple(ref.shape))
                    if verify and len(encs) != ref_shape[0]:
                        raise PayloadError(
                            f"{'/'.join(map(str, path))}:{name}: "
                            f"{len(encs)} ragged layer blocks for "
                            f"{ref_shape[0]} layers")
                    layers = np.zeros(ref_shape, np.float32)
                    for l, enc in enumerate(encs):
                        dec = _checked_decode(codec, enc, path, name)
                        if verify:
                            _check_ragged(dec, ref_shape[1:], axis, path,
                                          name, l)
                        if axis == -2:
                            layers[l, :dec.shape[0], :] = dec
                        else:
                            layers[l, :, :dec.shape[1]] = dec
                    if ref.ndim != 3:
                        layers = layers[0]
                    leaf[name] = layers
            set_path(out, path, leaf)
        return out


def _checked_decode(codec: Codec, enc: EncodedArray, path: Tuple,
                    name: str) -> np.ndarray:
    """Decode one block, converting low-level buffer/reshape failures
    (truncated bytes, inconsistent header) into :class:`PayloadError`."""
    try:
        dec = codec.decode(enc)
    except (ValueError, TypeError) as e:
        raise PayloadError(f"{'/'.join(map(str, path))}:{name}: "
                           f"undecodable block: {e}") from e
    if tuple(dec.shape) != tuple(enc.shape):
        raise PayloadError(f"{'/'.join(map(str, path))}:{name}: decoded "
                           f"shape {dec.shape} != header {enc.shape}")
    return dec


def _check_ragged(dec: np.ndarray, layer_shape: Tuple[int, ...], axis: int,
                  path: Tuple, name: str, layer: int) -> None:
    """One ragged layer block must be the reference layer shape with the
    rank axis shortened to r_l ≤ full rank."""
    full = list(layer_shape)
    rank_dim = full[axis]
    got = list(dec.shape)
    ok = (len(got) == len(full) and got[axis] <= rank_dim
          and all(g == f for i, (g, f) in enumerate(zip(got, full))
                  if i != len(full) + axis))
    if not ok:
        raise PayloadError(
            f"{'/'.join(map(str, path))}:{name}[{layer}]: ragged block "
            f"shape {tuple(dec.shape)} violates layer contract "
            f"{tuple(layer_shape)} (rank axis {axis} ≤ {rank_dim})")


# ---------------------------------------------------------------------------
# the transport
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class TransportStats:
    """Per-round uplink reliability counters (reset by the trainer)."""
    attempts: int = 0
    retries: int = 0
    crc_failures: int = 0
    dead_clients: int = 0
    backoff_secs: float = 0.0


#: rng stream tag for retry-backoff jitter
_JITTER_TAG = 0xBACF


class Transport:
    """Measured client↔server wire: every exchanged adapter tree is
    serialized with the configured codec, its bytes are counted, and the
    *decoded* tree is what the receiving side actually uses.

    The uplink is an at-least-once channel: payloads are checksummed
    (``checksums``, default on), verification failures are retried up to
    ``max_retries`` times with deterministic exponential backoff —
    ``backoff_base · 2^attempt · (1 + backoff_jitter · u)`` with ``u``
    drawn from a pure function of ``(round, client, attempt)`` — advancing
    the simulated ``clock``, and a client whose every attempt fails is
    declared dead (:class:`DeadClientError`; the trainer treats it as a
    drop).  A ``fault_plan`` (see :mod:`.faults`) can corrupt attempts
    deterministically for testing.  Retransmissions count against the
    measured wire bytes (a real wire pays for them); checksums do not.
    """

    def __init__(self, codec: Any = "fp32", dp_clip: float = 0.0,
                 dp_sigma: float = 0.0, dp_seed: int = 0,
                 checksums: bool = True, max_retries: int = 3,
                 backoff_base: float = 0.1, backoff_jitter: float = 0.5,
                 fault_plan: Any = None, clock: Any = None):
        self.codec = codec if isinstance(codec, Codec) else make_codec(codec)
        self.dp_clip = float(dp_clip)
        self.dp_sigma = float(dp_sigma)
        self.dp_seed = int(dp_seed)
        self.checksums = bool(checksums)
        self.max_retries = int(max_retries)
        self.backoff_base = float(backoff_base)
        self.backoff_jitter = float(backoff_jitter)
        self.fault_plan = fault_plan
        self.clock = clock if clock is not None else (
            fault_plan.clock if fault_plan is not None else None)
        self.stats = TransportStats()

    def reset_stats(self) -> TransportStats:
        """Swap in fresh counters, returning the old ones."""
        old, self.stats = self.stats, TransportStats()
        return old

    def _dp_stage(self, adapters: Dict, init_adapters: Optional[Dict],
                  rnd: int, client_id: int) -> Dict:
        """Local DP on one upload: clip the update delta to L2 ≤ C, noise
        with std σ·C, re-anchor on the init.  Applied exactly once, before
        encoding."""
        if not (self.dp_clip or self.dp_sigma):
            return adapters
        from repro.core.privacy import (clip_update, local_gaussian_noise,
                                        tree_add, tree_sub)
        if init_adapters is None:
            raise ValueError("DP transport needs the round's init adapters "
                             "to form the update delta")
        clip = self.dp_clip or 1.0
        delta = tree_sub(adapters, init_adapters)
        delta, _ = clip_update(delta, clip)
        if self.dp_sigma:
            key = jax.random.fold_in(
                jax.random.fold_in(jax.random.PRNGKey(self.dp_seed), rnd),
                client_id)
            delta = local_gaussian_noise(delta, self.dp_sigma, clip, key)
        return tree_add(init_adapters, delta)

    def client_to_server(self, adapters: Dict, aggregator, *,
                         init_adapters: Optional[Dict] = None,
                         rnd: int = 0, client_id: int = 0
                         ) -> Tuple[Dict, int]:
        """Uplink one trained client tree (through the DP stage when
        configured).  Returns (decoded tree, bytes across all attempts).

        Verification failures retry with deterministic backoff; raises
        :class:`DeadClientError` once ``max_retries`` re-sends have failed.
        The DP stage runs exactly once, before the first pack — a retry
        re-encodes the already-privatized tree, never re-clips/re-noises.
        """
        wire = _wire_fn(aggregator)
        adapters = self._dp_stage(adapters, init_adapters, rnd, client_id)
        total_bytes, last_err = 0, None
        for attempt in range(self.max_retries + 1):
            payload = AdapterPayload.pack(adapters, self.codec, wire,
                                          checksum=self.checksums)
            if self.fault_plan is not None and self.fault_plan.is_corrupt(
                    rnd, client_id, attempt):
                payload = self.fault_plan.corrupt_payload(
                    payload, rnd, client_id, attempt)
            self.stats.attempts += 1
            total_bytes += payload.num_bytes
            try:
                decoded = payload.unpack_into(adapters, self.codec,
                                              verify=self.checksums)
                return decoded, total_bytes
            except PayloadError as e:
                last_err = e
                if isinstance(e, PayloadCorrupted):
                    self.stats.crc_failures += 1
                if attempt < self.max_retries:
                    self.stats.retries += 1
                    u = float(np.random.default_rng(
                        [_JITTER_TAG, rnd, client_id, attempt]).random())
                    delay = (self.backoff_base * 2 ** attempt
                             * (1.0 + self.backoff_jitter * u))
                    self.stats.backoff_secs += delay
                    if self.clock is not None:
                        self.clock.advance(delay)
        self.stats.dead_clients += 1
        raise DeadClientError(client_id, self.max_retries + 1, last_err)

    def server_to_clients(self, agg, aggregator, num_receivers: int
                          ) -> Tuple[Optional[Dict], int]:
        """Downlink one round's result to ``num_receivers`` clients.

        Broadcast methods ship the global tree (ragged per-layer ranks —
        zero padding stays home) once per receiver; per-client methods
        (FlexLoRA) ship each tailored tree once.  Returns the decoded
        global tree (what clients resume from) and total downlink bytes.
        """
        wire = _wire_fn(aggregator)
        if agg.per_client is not None:
            nbytes = sum(
                AdapterPayload.pack(t, self.codec, wire).num_bytes
                for t in agg.per_client)
            if agg.global_adapters is None:
                return None, nbytes
            payload = AdapterPayload.pack(agg.global_adapters, self.codec,
                                          wire)
            return payload.unpack_into(agg.global_adapters, self.codec), nbytes
        if agg.global_adapters is None:
            return None, 0
        payload = AdapterPayload.pack(agg.global_adapters, self.codec, wire,
                                      ranks=agg.ranks)
        decoded = payload.unpack_into(agg.global_adapters, self.codec)
        return decoded, payload.num_bytes * num_receivers


def make_transport(spec: Any, **kw) -> Transport:
    """Coerce a transport spec (instance | codec name | Codec) into a
    :class:`Transport`.  ``kw`` kwargs (``dp_clip``/``dp_sigma``/
    ``dp_seed``, plus the hardening knobs ``checksums``/``max_retries``/
    ``backoff_base``/``backoff_jitter``/``fault_plan``/``clock``)
    configure the built transport; an already-built instance is returned
    as-is (its own config wins)."""
    if isinstance(spec, Transport):
        return spec
    return Transport(spec or "fp32", **kw)
