"""Round schedulers: who participates in a federated round, and how.

A :class:`RoundScheduler` turns "round ``rnd`` is starting" into a
:class:`RoundPlan` — the list of :class:`ClientTask`\\ s that will deliver an
update this round, each with its local step budget, (normalized)
aggregation weight and, for buffered/async semantics, the staleness and the
adapter snapshot the client was dispatched with.  The scheduler only reads
the trainer (the *round context*: ``rng``, ``fed``, ``clients``,
``client_ranks``, ``local_steps``, ``_client_init``); training itself is
the :class:`~repro.core.runtime.runners.ClientRunner`'s job.

Registered schedulers:

* ``sync`` — the paper's loop: sample K clients, wait for all of them,
  weight by sample counts.  Reproduces the legacy ``run_round`` bit-for-bit.
* ``partial`` — sample K, then drop a fraction (dropouts) and cut some
  survivors' step budgets (stragglers); weights renormalize over survivors.
  Deterministic given the federated seed.
* ``async`` — FedBuff/AFLoRA-style buffered aggregation: a pool of
  in-flight clients dispatched with a *snapshot* of the global state;
  arrivals are aggregated with staleness-discounted weights
  ``n_k · (1 + s)^(-α)`` feeding the streaming ``add_client``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple, Type

import numpy as np


@dataclasses.dataclass
class ClientTask:
    """One client's assignment for a round."""
    client_id: int
    rank: int
    steps: int                      # local fine-tuning step budget
    weight: float                   # normalized aggregation weight
    staleness: int = 0              # rounds between dispatch and arrival
    init_adapters: Optional[Dict] = None   # dispatch-time snapshot (async)


@dataclasses.dataclass
class RoundPlan:
    round: int
    tasks: List[ClientTask]


class RoundScheduler:
    """Participation policy.  Subclasses implement :meth:`plan`."""

    name: str = "?"

    def plan(self, rnd: int, ctx) -> RoundPlan:
        raise NotImplementedError


_REGISTRY: Dict[str, Type[RoundScheduler]] = {}


def register_scheduler(name: str):
    def deco(cls: Type[RoundScheduler]) -> Type[RoundScheduler]:
        _REGISTRY[name] = cls
        cls.name = name
        return cls
    return deco


def make_scheduler(spec: Any, **cfg) -> RoundScheduler:
    if isinstance(spec, RoundScheduler):
        return spec
    try:
        return _REGISTRY[spec](**cfg)
    except KeyError:
        raise ValueError(f"unknown scheduler {spec!r} "
                         f"(registered: {sorted(_REGISTRY)})") from None


def available_schedulers() -> List[str]:
    return sorted(_REGISTRY)


# ---------------------------------------------------------------------------
# implementations
# ---------------------------------------------------------------------------


@register_scheduler("sync")
class SyncScheduler(RoundScheduler):
    """Sample K, wait for all K — the legacy semantics, bit-for-bit (same
    rng call, same weight arithmetic)."""

    def plan(self, rnd: int, ctx) -> RoundPlan:
        fed = ctx.fed
        sampled = list(ctx.rng.choice(fed.num_clients, fed.clients_per_round,
                                      replace=False))
        n_total = sum(ctx.clients[k].num_samples for k in sampled)
        tasks = [ClientTask(int(k), ctx.client_ranks[k], ctx.local_steps,
                            ctx.clients[k].num_samples / n_total)
                 for k in sampled]
        return RoundPlan(rnd, tasks)


@register_scheduler("partial")
class PartialScheduler(RoundScheduler):
    """Dropouts + stragglers over the sync sample.

    Each sampled client independently drops out with ``drop_rate``;
    surviving clients become stragglers with ``straggler_rate`` and then
    finish only a uniform fraction of the step budget (≥ ``min_steps``).
    The per-round decisions come from a rng derived from ``(seed, rnd)``,
    so a fixed federated seed gives an identical dropout pattern.
    """

    def __init__(self, drop_rate: float = 0.25, straggler_rate: float = 0.25,
                 min_steps: int = 1):
        self.drop_rate = drop_rate
        self.straggler_rate = straggler_rate
        self.min_steps = min_steps

    def plan(self, rnd: int, ctx) -> RoundPlan:
        fed = ctx.fed
        sampled = list(ctx.rng.choice(fed.num_clients, fed.clients_per_round,
                                      replace=False))
        prng = np.random.default_rng([fed.seed, 104729, rnd])
        survivors: List[Tuple[int, int]] = []
        for k in sampled:
            if prng.random() < self.drop_rate:
                continue
            steps = ctx.local_steps
            if prng.random() < self.straggler_rate:
                steps = max(self.min_steps,
                            int(round(ctx.local_steps * prng.uniform(0.25, 1.0))))
            survivors.append((int(k), steps))
        if not survivors:            # never an empty round
            survivors = [(int(sampled[0]), ctx.local_steps)]
        n_total = sum(ctx.clients[k].num_samples for k, _ in survivors)
        tasks = [ClientTask(k, ctx.client_ranks[k], steps,
                            ctx.clients[k].num_samples / n_total)
                 for k, steps in survivors]
        return RoundPlan(rnd, tasks)


@register_scheduler("async")
class AsyncScheduler(RoundScheduler):
    """Buffered asynchronous aggregation with staleness discounting.

    A pool of ``buffer_size`` (default: ``clients_per_round``) clients is
    kept in flight; each is dispatched with a snapshot of the global
    adapters *at dispatch time* and a completion delay of 1..``max_delay``
    rounds.  Arrivals whose delay has elapsed deliver this round, weighted
    ``n_k · (1 + staleness)^(-staleness_power)`` and renormalized; the pool
    is refilled at the start of every round with the then-current state.
    If nothing is due (e.g. round 0), the soonest cohort arrives early so
    every round aggregates at least one update.
    """

    def __init__(self, max_delay: int = 3, staleness_power: float = 0.5,
                 buffer_size: int = 0):
        self.max_delay = max(1, int(max_delay))
        self.staleness_power = staleness_power
        self.buffer_size = buffer_size
        self._in_flight: List[Dict] = []

    def _dispatch(self, rnd: int, ctx) -> None:
        k = int(ctx.rng.integers(ctx.fed.num_clients))
        delay = int(ctx.rng.integers(1, self.max_delay + 1))
        self._in_flight.append({
            "client_id": k,
            "dispatched": rnd,
            "completes": rnd + delay,
            "init": ctx._client_init(k),
        })

    def plan(self, rnd: int, ctx) -> RoundPlan:
        cap = self.buffer_size or ctx.fed.clients_per_round
        while len(self._in_flight) < cap:
            self._dispatch(rnd, ctx)
        due = [f for f in self._in_flight if f["completes"] <= rnd]
        if not due:
            soonest = min(f["completes"] for f in self._in_flight)
            due = [f for f in self._in_flight if f["completes"] == soonest]
        # remove by identity: entries hold adapter trees, so equality
        # comparison (list.remove) would raise on array truthiness
        self._in_flight = [f for f in self._in_flight
                           if not any(f is d for d in due)]
        raw, tasks = [], []
        for f in due:
            stale = max(0, rnd - f["dispatched"])
            n_k = ctx.clients[f["client_id"]].num_samples
            raw.append(n_k * (1.0 + stale) ** (-self.staleness_power))
            tasks.append(ClientTask(f["client_id"],
                                    ctx.client_ranks[f["client_id"]],
                                    ctx.local_steps, 0.0, staleness=stale,
                                    init_adapters=f["init"]))
        total = sum(raw)
        for t, w in zip(tasks, raw):
            t.weight = w / total
        return RoundPlan(rnd, tasks)
