"""Round schedulers: who participates in a federated round, and how.

A :class:`RoundScheduler` turns "round ``rnd`` is starting" into a
:class:`RoundPlan` — the list of :class:`ClientTask`\\ s that will deliver an
update this round, each with its local step budget, (normalized)
aggregation weight and, for buffered/async semantics, the staleness and the
adapter snapshot the client was dispatched with.  The scheduler only reads
the trainer (the *round context*: ``rng``, ``fed``, ``clients``,
``client_ranks``, ``local_steps``, ``_client_init``); training itself is
the :class:`~repro.core.runtime.runners.ClientRunner`'s job.

Registered schedulers:

* ``sync`` — the paper's loop: sample K clients, wait for all of them,
  weight by sample counts.  Reproduces the legacy ``run_round`` bit-for-bit.
* ``partial`` — sample K, then drop a fraction (dropouts) and cut some
  survivors' step budgets (stragglers); weights renormalize over survivors.
  Deterministic given the federated seed.
* ``async`` — FedBuff/AFLoRA-style buffered aggregation: a pool of
  in-flight clients dispatched with a *snapshot* of the global state;
  arrivals are aggregated with staleness-discounted weights
  ``n_k · (1 + s)^(-α)`` feeding the streaming ``add_client``.
* ``sampled`` — population-scale participation: each round draws a
  ``fraction`` of the *full* client population from a rng keyed on
  ``(seed, rnd)`` only (same seed → identical participant sets, regardless
  of what else consumed ``ctx.rng``), optionally composed with the
  ``partial`` dropout/straggler semantics.

This module also hosts the **rank policies** (:class:`RankPolicy`): an
AFLoRA-style hook that adapts each task's LoRA rank to a declared per-client
resource profile after the scheduler builds the plan — ``static`` keeps the
config's heterogeneous ranks, ``resource`` scales them by budget tier with a
warmup ramp, snapping to powers of two so cohorts stay batchable.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple, Type

import numpy as np


@dataclasses.dataclass
class ClientTask:
    """One client's assignment for a round."""
    client_id: int
    rank: int
    steps: int                      # local fine-tuning step budget
    weight: float                   # normalized aggregation weight
    staleness: int = 0              # rounds between dispatch and arrival
    init_adapters: Optional[Dict] = None   # dispatch-time snapshot (async)


@dataclasses.dataclass
class RoundPlan:
    round: int
    tasks: List[ClientTask]
    #: server→client model dispatches this round (``None``: one per task).
    #: ``async`` sets it to the number of *new* dispatches — clients already
    #: in flight received their snapshot in an earlier round's downlink.
    downloads: Optional[int] = None


class RoundScheduler:
    """Participation policy.  Subclasses implement :meth:`plan`."""

    name: str = "?"

    def plan(self, rnd: int, ctx) -> RoundPlan:
        raise NotImplementedError

    # -- checkpoint hooks ---------------------------------------------------
    # Most schedulers are pure functions of (seed, round) and carry no
    # cross-round state; ``async`` overrides these to serialize its
    # in-flight pool so a resumed run replays identically.
    def state_dict(self) -> Dict:
        return {}

    def load_state_dict(self, state: Dict) -> None:
        return


_REGISTRY: Dict[str, Type[RoundScheduler]] = {}


def register_scheduler(name: str):
    def deco(cls: Type[RoundScheduler]) -> Type[RoundScheduler]:
        _REGISTRY[name] = cls
        cls.name = name
        return cls
    return deco


def make_scheduler(spec: Any, **cfg) -> RoundScheduler:
    if isinstance(spec, RoundScheduler):
        return spec
    try:
        return _REGISTRY[spec](**cfg)
    except KeyError:
        raise ValueError(f"unknown scheduler {spec!r} "
                         f"(registered: {sorted(_REGISTRY)})") from None


def available_schedulers() -> List[str]:
    return sorted(_REGISTRY)


# ---------------------------------------------------------------------------
# implementations
# ---------------------------------------------------------------------------


@register_scheduler("sync")
class SyncScheduler(RoundScheduler):
    """Sample K, wait for all K — the legacy semantics, bit-for-bit (same
    rng call, same weight arithmetic)."""

    def plan(self, rnd: int, ctx) -> RoundPlan:
        fed = ctx.fed
        sampled = list(ctx.rng.choice(fed.num_clients, fed.clients_per_round,
                                      replace=False))
        n_total = sum(ctx.clients[k].num_samples for k in sampled)
        tasks = [ClientTask(int(k), ctx.client_ranks[k], ctx.local_steps,
                            ctx.clients[k].num_samples / n_total)
                 for k in sampled]
        return RoundPlan(rnd, tasks)


@register_scheduler("partial")
class PartialScheduler(RoundScheduler):
    """Dropouts + stragglers over the sync sample.

    Each sampled client independently drops out with ``drop_rate``;
    surviving clients become stragglers with ``straggler_rate`` and then
    finish only a uniform fraction of the step budget (≥ ``min_steps``).
    The per-round decisions come from a rng derived from ``(seed, rnd)``,
    so a fixed federated seed gives an identical dropout pattern.
    """

    def __init__(self, drop_rate: float = 0.25, straggler_rate: float = 0.25,
                 min_steps: int = 1):
        self.drop_rate = drop_rate
        self.straggler_rate = straggler_rate
        self.min_steps = min_steps

    def plan(self, rnd: int, ctx) -> RoundPlan:
        fed = ctx.fed
        sampled = list(ctx.rng.choice(fed.num_clients, fed.clients_per_round,
                                      replace=False))
        prng = np.random.default_rng([fed.seed, 104729, rnd])
        survivors: List[Tuple[int, int]] = []
        for k in sampled:
            if prng.random() < self.drop_rate:
                continue
            steps = ctx.local_steps
            if prng.random() < self.straggler_rate:
                steps = max(self.min_steps,
                            int(round(ctx.local_steps * prng.uniform(0.25, 1.0))))
            survivors.append((int(k), steps))
        if not survivors:            # never an empty round
            survivors = [(int(sampled[0]), ctx.local_steps)]
        n_total = sum(ctx.clients[k].num_samples for k, _ in survivors)
        tasks = [ClientTask(k, ctx.client_ranks[k], steps,
                            ctx.clients[k].num_samples / n_total)
                 for k, steps in survivors]
        return RoundPlan(rnd, tasks)


@register_scheduler("async")
class AsyncScheduler(RoundScheduler):
    """Buffered asynchronous aggregation with staleness discounting.

    A pool of ``buffer_size`` (default: ``clients_per_round``) clients is
    kept in flight; each is dispatched with a snapshot of the global
    adapters *at dispatch time* and a completion delay of 1..``max_delay``
    rounds.  Arrivals whose delay has elapsed deliver this round, weighted
    ``n_k · (1 + staleness)^(-staleness_power)`` and renormalized; the pool
    is refilled at the start of every round with the then-current state.
    If nothing is due (e.g. round 0), the soonest cohort arrives early so
    every round aggregates at least one update.
    """

    def __init__(self, max_delay: int = 3, staleness_power: float = 0.5,
                 buffer_size: int = 0):
        self.max_delay = max(1, int(max_delay))
        self.staleness_power = staleness_power
        self.buffer_size = buffer_size
        self._in_flight: List[Dict] = []

    def _dispatch(self, rnd: int, ctx) -> None:
        k = int(ctx.rng.integers(ctx.fed.num_clients))
        delay = int(ctx.rng.integers(1, self.max_delay + 1))
        self._in_flight.append({
            "client_id": k,
            "dispatched": rnd,
            "completes": rnd + delay,
            "init": ctx._client_init(k),
        })

    def plan(self, rnd: int, ctx) -> RoundPlan:
        cap = self.buffer_size or ctx.fed.clients_per_round
        dispatched = 0
        while len(self._in_flight) < cap:
            self._dispatch(rnd, ctx)
            dispatched += 1
        due = [f for f in self._in_flight if f["completes"] <= rnd]
        if not due:
            soonest = min(f["completes"] for f in self._in_flight)
            due = [f for f in self._in_flight if f["completes"] == soonest]
        # remove by identity: entries hold adapter trees, so equality
        # comparison (list.remove) would raise on array truthiness
        self._in_flight = [f for f in self._in_flight
                           if not any(f is d for d in due)]
        raw, tasks = [], []
        for f in due:
            stale = max(0, rnd - f["dispatched"])
            n_k = ctx.clients[f["client_id"]].num_samples
            raw.append(n_k * (1.0 + stale) ** (-self.staleness_power))
            tasks.append(ClientTask(f["client_id"],
                                    ctx.client_ranks[f["client_id"]],
                                    ctx.local_steps, 0.0, staleness=stale,
                                    init_adapters=f["init"]))
        total = sum(raw)
        for t, w in zip(tasks, raw):
            t.weight = w / total
        # downlink happened at dispatch time (the snapshot), not arrival
        return RoundPlan(rnd, tasks, downloads=dispatched)

    def state_dict(self) -> Dict:
        from repro.checkpoint.io import to_host
        return {"in_flight": [dict(f, init=to_host(f["init"]))
                              for f in self._in_flight]}

    def load_state_dict(self, state: Dict) -> None:
        from repro.checkpoint.io import to_device
        self._in_flight = [dict(f, init=to_device(f["init"]))
                           for f in state.get("in_flight", [])]


@register_scheduler("sampled")
class SampledScheduler(RoundScheduler):
    """Per-round participation fraction over the full population.

    Draws ``max(min_clients, fraction · num_clients)`` participants from a
    rng keyed on ``(seed, rnd)`` *only* — unlike ``sync``, whose draw
    consumes the trainer's shared ``ctx.rng`` stream, the participant set
    is a pure function of (federated seed, round): two runs with the same
    seed pick identical sets even if other components consumed randomness
    in between.  ``drop_rate``/``straggler_rate`` compose the ``partial``
    semantics on top of the sample (a sampled client may still drop out or
    finish a cut step budget); weights renormalize over survivors.
    """

    def __init__(self, fraction: float = 0.1, min_clients: int = 1,
                 drop_rate: float = 0.0, straggler_rate: float = 0.0,
                 min_steps: int = 1):
        if not 0.0 < fraction <= 1.0:
            raise ValueError(f"fraction must be in (0, 1], got {fraction}")
        self.fraction = fraction
        self.min_clients = min_clients
        self.drop_rate = drop_rate
        self.straggler_rate = straggler_rate
        self.min_steps = min_steps

    def plan(self, rnd: int, ctx) -> RoundPlan:
        fed = ctx.fed
        srng = np.random.default_rng([fed.seed, 7919, rnd])
        k = min(fed.num_clients,
                max(self.min_clients,
                    int(round(self.fraction * fed.num_clients))))
        sampled = sorted(int(c) for c in
                         srng.choice(fed.num_clients, k, replace=False))
        survivors: List[Tuple[int, int]] = []
        for c in sampled:
            if self.drop_rate and srng.random() < self.drop_rate:
                continue
            steps = ctx.local_steps
            if self.straggler_rate and srng.random() < self.straggler_rate:
                steps = max(self.min_steps,
                            int(round(ctx.local_steps
                                      * srng.uniform(0.25, 1.0))))
            survivors.append((c, steps))
        if not survivors:            # never an empty round
            survivors = [(sampled[0], ctx.local_steps)]
        n_total = sum(ctx.clients[c].num_samples for c, _ in survivors)
        tasks = [ClientTask(c, ctx.client_ranks[c], steps,
                            ctx.clients[c].num_samples / n_total)
                 for c, steps in survivors]
        return RoundPlan(rnd, tasks)


# ---------------------------------------------------------------------------
# rank policies (AFLoRA-style resource-aware rank assignment)
# ---------------------------------------------------------------------------


class RankPolicy:
    """Post-plan hook adapting each task's LoRA rank to client resources.

    ``assign(rnd, plan, ctx)`` mutates ``task.rank`` in place (never above
    the client's configured rank — the shared A init only has that many
    rows).  Runs after the scheduler builds the plan and before the runner
    trains it, so policies see exactly the participating tasks.
    """

    name: str = "?"

    def assign(self, rnd: int, plan: RoundPlan, ctx) -> None:
        raise NotImplementedError


_RANK_POLICIES: Dict[str, Type[RankPolicy]] = {}


def register_rank_policy(name: str):
    def deco(cls: Type[RankPolicy]) -> Type[RankPolicy]:
        _RANK_POLICIES[name] = cls
        cls.name = name
        return cls
    return deco


def make_rank_policy(spec: Any, **cfg) -> RankPolicy:
    if isinstance(spec, RankPolicy):
        return spec
    try:
        return _RANK_POLICIES[spec](**cfg)
    except KeyError:
        raise ValueError(f"unknown rank policy {spec!r} "
                         f"(registered: {sorted(_RANK_POLICIES)})") from None


def available_rank_policies() -> List[str]:
    return sorted(_RANK_POLICIES)


@register_rank_policy("static")
class StaticRankPolicy(RankPolicy):
    """Keep the scheduler-assigned (config-profile) ranks untouched."""

    def assign(self, rnd: int, plan: RoundPlan, ctx) -> None:
        return


@register_rank_policy("resource")
class ResourceRankPolicy(RankPolicy):
    """AFLoRA-style resource-aware ranks (arXiv:2505.24773).

    Each client declares a compute budget in (0, 1] — by default a cyclic
    tier profile ``budgets[client_id % len(budgets)]``, or an explicit
    ``profile`` list.  A task's rank is its configured cap scaled by the
    budget and a linear ``warmup`` ramp (AFLoRA grows ranks as training
    stabilizes), snapped DOWN to a power of two so equal-rank cohorts stay
    batchable (at most O(log r) distinct compiled shapes per round).
    """

    def __init__(self, budgets: Tuple[float, ...] = (0.25, 0.5, 0.75, 1.0),
                 warmup: int = 0, profile: Optional[List[float]] = None):
        self.budgets = tuple(budgets)
        self.warmup = int(warmup)
        self.profile = profile

    def assign(self, rnd: int, plan: RoundPlan, ctx) -> None:
        ramp = min(1.0, (rnd + 1) / self.warmup) if self.warmup else 1.0
        for task in plan.tasks:
            cap = ctx.client_ranks[task.client_id]
            if self.profile is not None:
                budget = self.profile[task.client_id % len(self.profile)]
            else:
                budget = self.budgets[task.client_id % len(self.budgets)]
            r = max(1, int(cap * budget * ramp))
            task.rank = min(cap, 1 << (r.bit_length() - 1))   # pow2 floor
