"""Federated round runtime: pluggable client runners, round schedulers and
measured wire transport.

The :class:`~repro.core.federated.FederatedTrainer` is a thin composition
of four seams, each independently swappable:

====================  ====================================================
seam                  registry / built-ins
====================  ====================================================
``ClientRunner``      ``make_runner``: ``sequential`` (legacy loop,
                      bit-for-bit) · ``cohort`` (equal-rank cohorts in one
                      jitted vmapped train call)
``RoundScheduler``    ``make_scheduler``: ``sync`` · ``partial``
                      (dropouts/stragglers) · ``async`` (buffered,
                      staleness-discounted)
``Transport``         ``make_codec``: ``fp32`` · ``bf16`` · ``int8`` —
                      measured bytes per round, cross-checkable against the
                      analytic counts in :mod:`repro.core.costs`
``Aggregator``        :mod:`repro.core.aggregators` (PR 1/2)
====================  ====================================================
"""
from repro.core.runtime.runners import (ClientRunner, CohortRunner,
                                        SequentialRunner, available_runners,
                                        make_runner, register_runner)
from repro.core.runtime.schedulers import (AsyncScheduler, ClientTask,
                                           PartialScheduler, RoundPlan,
                                           RoundScheduler, SyncScheduler,
                                           available_schedulers,
                                           make_scheduler, register_scheduler)
from repro.core.runtime.transport import (AdapterPayload, Codec, Transport,
                                          available_codecs, make_codec,
                                          make_transport, register_codec)

__all__ = [
    "AdapterPayload", "AsyncScheduler", "ClientRunner", "ClientTask",
    "Codec", "CohortRunner", "PartialScheduler", "RoundPlan",
    "RoundScheduler", "SequentialRunner", "SyncScheduler", "Transport",
    "available_codecs", "available_runners", "available_schedulers",
    "make_codec", "make_runner", "make_scheduler", "make_transport",
    "register_codec", "register_runner", "register_scheduler",
]
