"""Federated round runtime: pluggable client runners, round schedulers,
rank policies and measured wire transport.

The :class:`~repro.core.federated.FederatedTrainer` is a thin composition
of five seams, each independently swappable:

====================  ====================================================
seam                  registry / built-ins
====================  ====================================================
``ClientRunner``      ``make_runner``: ``sequential`` (legacy loop,
                      bit-for-bit) · ``cohort`` (equal-rank cohorts in one
                      jitted vmapped train call) · ``sharded_cohort``
                      (cohort with the client axis sharded over the fed
                      mesh's ``data`` axis)
``RoundScheduler``    ``make_scheduler``: ``sync`` · ``partial``
                      (dropouts/stragglers) · ``async`` (buffered,
                      staleness-discounted) · ``sampled`` (population-
                      scale participation fraction, seed-deterministic)
``RankPolicy``        ``make_rank_policy``: ``static`` · ``resource``
                      (AFLoRA-style budget tiers with warmup ramp)
``Transport``         ``make_codec``: ``fp32`` · ``bf16`` · ``int8`` —
                      measured bytes per round, cross-checkable against the
                      analytic counts in :mod:`repro.core.costs`; DP
                      clip/noise composes as an uplink codec stage
``Aggregator``        :mod:`repro.core.aggregators` (PR 1/2)
====================  ====================================================

The fault-tolerance layer (PR 10) wraps the seams: a deterministic
:class:`FaultPlan` (:mod:`.faults`) injects client/transport/server
failures on a :class:`SimClock`; the :class:`Transport` retries
checksummed uplinks and declares dead clients; a :class:`ValidationGate`
(:mod:`.validation`) screens every update before the irreversible
``add_client`` fold and enforces a round quorum.
"""
from repro.core.runtime.faults import (CRASH_POINTS, Fault, FaultPlan,
                                       ServerCrash, SimClock)
from repro.core.runtime.runners import (ClientRunner, CohortRunner,
                                        SequentialRunner,
                                        ShardedCohortRunner,
                                        available_runners, make_runner,
                                        register_runner)
from repro.core.runtime.schedulers import (AsyncScheduler, ClientTask,
                                           PartialScheduler, RankPolicy,
                                           ResourceRankPolicy, RoundPlan,
                                           RoundScheduler, SampledScheduler,
                                           StaticRankPolicy, SyncScheduler,
                                           available_rank_policies,
                                           available_schedulers,
                                           make_rank_policy, make_scheduler,
                                           register_rank_policy,
                                           register_scheduler)
from repro.core.runtime.transport import (AdapterPayload, Codec,
                                          DeadClientError, EncodedArray,
                                          PayloadCorrupted, PayloadError,
                                          Transport, TransportStats,
                                          available_codecs, make_codec,
                                          make_transport, register_codec)
from repro.core.runtime.validation import (GateStats, ValidationGate,
                                           make_validator)

__all__ = [
    "AdapterPayload", "AsyncScheduler", "CRASH_POINTS", "ClientRunner",
    "ClientTask", "Codec", "CohortRunner", "DeadClientError", "EncodedArray",
    "Fault", "FaultPlan", "GateStats", "PartialScheduler", "PayloadCorrupted",
    "PayloadError", "RankPolicy", "ResourceRankPolicy", "RoundPlan",
    "RoundScheduler", "SampledScheduler", "SequentialRunner", "ServerCrash",
    "ShardedCohortRunner", "SimClock", "StaticRankPolicy", "SyncScheduler",
    "Transport", "TransportStats", "ValidationGate", "available_codecs",
    "available_rank_policies", "available_runners", "available_schedulers",
    "make_codec", "make_rank_policy", "make_runner", "make_scheduler",
    "make_transport", "make_validator", "register_codec",
    "register_rank_policy", "register_runner", "register_scheduler",
]
