"""Deterministic fault injection for the federated runtime.

At production scale (PR 9: 1000+ sampled clients per round) client failure
is the common case: uploads drop, payloads arrive bit-flipped, stragglers
stall, and a small fraction of clients is outright adversarial (NaN/Inf or
norm-scaled poison — the heterogeneous-client failure surface of Koo et
al. 2024 and AFLoRA).  This module makes every one of those failures a
*reproducible test vector*: a :class:`FaultPlan` is a *pure function* of
``(fault_seed, round, client_id)`` — two processes holding the same plan
agree on exactly which client fails, how, and at which retry attempt,
without sharing any mutable state.  That purity is what lets the
crash/resume tests replay an interrupted round bit-for-bit and lets the
benchmarks recompute (rather than log) which uploads were poisoned.

Fault taxonomy (one fault at most per ``(round, client)``; probabilities
are cumulative and must sum to ≤ 1):

==============  ===========================================================
kind            effect
==============  ===========================================================
``drop``        the upload never arrives (client trained for nothing)
``duplicate``   the same upload is delivered twice (at-least-once wire)
``corrupt``     the first ``n_bad`` encoded payload attempts arrive with a
                flipped bit — the transport's per-array checksums catch it
                and retry; ``n_bad`` > max_retries kills the client
``nan``         a poisoned adapter: random entries set to NaN/±Inf
``scale``       a poisoned adapter: the update delta scaled ×
                ``scale_factor`` (norm-outlier, numerically finite)
``slow``        a straggler: ``slow_secs`` on the simulated clock
==============  ===========================================================

Server crashes are injected separately via ``crashes=((round, point),
...)`` with ``point`` one of :data:`CRASH_POINTS`; the trainer raises
:class:`ServerCrash` at the matching hook so the checkpoint/resume tests
can kill a run at every stage of a round.

Time never comes from the host: retries, backoff and slow clients advance
a :class:`SimClock`, so fault schedules are machine-independent.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

#: stream tags: independent rng streams per decision family
_FAULT_TAG = 0x5F4A
_POISON_TAG = 0x901
_CORRUPT_TAG = 0xB17F

#: trainer hooks where an injected server crash can fire
CRASH_POINTS = ("begin", "mid_round", "pre_finalize", "post_round")


class ServerCrash(RuntimeError):
    """Injected server failure — simulates SIGKILL at a round stage."""

    def __init__(self, rnd: int, point: str):
        self.round, self.point = rnd, point
        super().__init__(f"injected server crash at round {rnd} ({point!r})")


class SimClock:
    """Simulated wall clock: backoff delays and slow clients advance it
    deterministically, so fault timelines reproduce across machines."""

    def __init__(self) -> None:
        self.now = 0.0

    def advance(self, secs: float) -> None:
        self.now += float(secs)


@dataclasses.dataclass(frozen=True)
class Fault:
    """One client's fault assignment for a round (``kind=None``: healthy)."""
    kind: Optional[str] = None
    #: corrupt: number of leading upload attempts that arrive bit-flipped
    n_bad: int = 0
    #: slow: simulated straggler latency in seconds
    delay: float = 0.0


NO_FAULT = Fault()


class FaultPlan:
    """Deterministic per-(round, client) fault assignment.

    Every query re-derives its rng from ``(seed, tag, round, client_id)``
    so the plan carries no mutable state: ``client_fault(r, k)`` returns
    the same :class:`Fault` no matter when, where, or how often it is
    asked — the property the resume tests and the benchmarks rely on.
    """

    def __init__(self, seed: int = 0, drop: float = 0.0,
                 duplicate: float = 0.0, corrupt: float = 0.0,
                 nan: float = 0.0, scale: float = 0.0, slow: float = 0.0,
                 scale_factor: float = 100.0, slow_secs: float = 1.0,
                 max_bad_attempts: int = 6,
                 crashes: Tuple[Tuple[int, str], ...] = ()):
        rates = dict(drop=drop, duplicate=duplicate, corrupt=corrupt,
                     nan=nan, scale=scale, slow=slow)
        for k, v in rates.items():
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"fault rate {k}={v} outside [0, 1]")
        if sum(rates.values()) > 1.0 + 1e-9:
            raise ValueError(f"fault rates sum to {sum(rates.values())} > 1")
        for rnd, point in crashes:
            if point not in CRASH_POINTS:
                raise ValueError(f"unknown crash point {point!r} "
                                 f"(valid: {CRASH_POINTS})")
        self.seed = int(seed)
        self.rates = rates
        self.scale_factor = float(scale_factor)
        self.slow_secs = float(slow_secs)
        self.max_bad_attempts = int(max_bad_attempts)
        self.crashes = tuple((int(r), str(p)) for r, p in crashes)
        self.clock = SimClock()

    # -- pure per-(round, client) draws --------------------------------------

    def _rng(self, tag: int, *key: int) -> np.random.Generator:
        return np.random.default_rng([self.seed, tag, *[int(k) for k in key]])

    def client_fault(self, rnd: int, client_id: int) -> Fault:
        """The (at most one) fault assigned to this client this round."""
        rng = self._rng(_FAULT_TAG, rnd, client_id)
        u = float(rng.random())
        for kind, p in self.rates.items():
            if u < p:
                if kind == "corrupt":
                    return Fault("corrupt", n_bad=int(
                        rng.integers(1, self.max_bad_attempts + 1)))
                if kind == "slow":
                    return Fault("slow", delay=self.slow_secs
                                 * float(rng.uniform(0.5, 1.5)))
                return Fault(kind)
            u -= p
        return NO_FAULT

    def poison(self, adapters: Dict, init_adapters: Optional[Dict],
               rnd: int, client_id: int) -> Dict:
        """Apply this client's poison fault to its trained adapters.

        ``nan``: a handful of random A/B entries become NaN / ±Inf.
        ``scale``: the update delta (vs the round's init) is scaled by
        ``scale_factor`` — finite, but a gross norm outlier.
        Healthy clients pass through untouched.
        """
        import jax

        fault = self.client_fault(rnd, client_id)
        if fault.kind not in ("nan", "scale"):
            return adapters
        rng = self._rng(_POISON_TAG, rnd, client_id)

        def poison_leaf(path, leaf):
            last = getattr(path[-1], "key", path[-1])
            if last not in ("A", "B") or getattr(leaf, "ndim", 0) < 2:
                return leaf
            arr = np.array(leaf, np.float32)
            if fault.kind == "nan":
                flat = arr.reshape(-1)
                idx = rng.integers(0, flat.size, size=min(4, flat.size))
                flat[idx] = rng.choice([np.nan, np.inf, -np.inf], size=idx.size)
                return arr
            return arr * self.scale_factor   # scale: blow up A and B alike

        poisoned = jax.tree_util.tree_map_with_path(poison_leaf, adapters)
        if fault.kind == "scale" and init_adapters is not None:
            # re-anchor so the *delta* (not the absolute tree) is 100×:
            # poisoned = init + factor · (trained − init)
            poisoned = jax.tree.map(
                lambda p, t, i: p if getattr(p, "ndim", 0) < 2
                else p - (self.scale_factor - 1.0) * np.array(i, np.float32),
                poisoned, adapters, init_adapters)
        return poisoned

    # -- transport-level corruption ------------------------------------------

    def is_corrupt(self, rnd: int, client_id: int, attempt: int) -> bool:
        """Does this client's upload attempt arrive bit-flipped?"""
        fault = self.client_fault(rnd, client_id)
        return fault.kind == "corrupt" and attempt < fault.n_bad

    def corrupt_payload(self, payload, rnd: int, client_id: int,
                        attempt: int):
        """Flip one bit in one encoded block (checksum left stale, so the
        receiver's verification catches it).  Returns a new payload; the
        input is not mutated."""
        import dataclasses as dc

        rng = self._rng(_CORRUPT_TAG, rnd, client_id, attempt)
        blocks = {}
        flat = [(path, name, i, enc)
                for path, by_name in payload.blocks.items()
                for name, encs in by_name.items()
                for i, enc in enumerate(encs)]
        victim = int(rng.integers(len(flat)))
        for j, (path, name, i, enc) in enumerate(flat):
            if j == victim and len(enc.data):
                data = bytearray(enc.data)
                bit = int(rng.integers(len(data) * 8))
                data[bit // 8] ^= 1 << (bit % 8)
                enc = dc.replace(enc, data=bytes(data))
            blocks.setdefault(path, {}).setdefault(name, []).append(enc)
        return dc.replace(payload, blocks=blocks)

    # -- server crash schedule ------------------------------------------------

    def should_crash(self, rnd: int, point: str) -> bool:
        return (rnd, point) in self.crashes

    def without_crashes(self) -> "FaultPlan":
        """The same client-fault plan with the crash schedule cleared —
        what a *resumed* server process observes (the injected crash
        already happened; the client population faults are unchanged)."""
        clone = FaultPlan(seed=self.seed, scale_factor=self.scale_factor,
                          slow_secs=self.slow_secs,
                          max_bad_attempts=self.max_bad_attempts,
                          **self.rates)
        return clone
