"""Aggregator strategy interface + registry (the pluggable aggregation API).

An :class:`Aggregator` owns everything the server needs to know about one
aggregation method:

* a **streaming lifecycle** — ``begin_round(dims)`` → ``add_client(update,
  weight)`` (once per arriving client, in arrival order) → ``finalize()``.
  The server accumulates running weighted sums or stacked blocks per LoRA
  leaf, so peak server memory is O(Σ r_k) per leaf (or O(1) in K for the
  averaging methods) instead of K full adapter trees held simultaneously;
* **client-init semantics** — ``client_init(global_state, rank, a_init)``
  builds the adapters a client resumes from each round (truncate/pad,
  frozen-A composition, re-init after base merge, ...);
* a **cost model** — ``upload_params`` / ``download_params`` /
  ``server_flops`` / ``efficiency``, replacing the per-method ``if`` chains
  that used to live in :mod:`repro.core.costs`.

Third-party methods plug in with::

    @register_aggregator("mymethod")
    class MyAggregator(Aggregator):
        ...

    agg = make_aggregator("mymethod", **cfg)

A client update is an adapter tree whose LoRA leaves are
``{"A": (L, r_k, n), "B": (L, m, r_k), "scale": (L,)}`` (or un-stacked 2-D
for shared blocks).  Aggregation is per-(leaf, layer).  Client ``scale`` is
folded into ``B`` on arrival so methods compare the same effective updates
``ΔW_k = scale_k · B_k A_k``; all global adapters carry scale 1.
"""
from __future__ import annotations

import dataclasses
import inspect
from typing import Any, Dict, List, Optional, Sequence, Tuple, Type

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# adapter-tree plumbing (shared by all methods and by costs.py)
# ---------------------------------------------------------------------------


def adapter_leaf_paths(tree: Dict) -> List[Tuple]:
    """Paths of LoRA leaves (subdicts holding A/B/scale)."""
    out = []

    def walk(node, path):
        if isinstance(node, dict) and "A" in node and "B" in node:
            out.append(path)
            return
        if isinstance(node, dict):
            for k, v in node.items():
                walk(v, path + (k,))

    walk(tree, ())
    return out


def get_path(tree, path):
    node = tree
    for k in path:
        node = node[k]
    return node


def set_path(tree, path, value):
    node = tree
    for k in path[:-1]:
        node = node.setdefault(k, {})
    node[path[-1]] = value


def fold_scale(leaf: Dict) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Return (B', A) with scale folded into B. Handles stacked + flat."""
    A, B, s = leaf["A"], leaf["B"], leaf["scale"]
    if B.ndim == 3:
        sl = s[:, None, None] if s.ndim == 1 else s
        return B * sl, A
    return B * s, A


def per_layer(mat: jnp.ndarray, l: int, stacked: bool):
    return mat[l] if stacked else mat


def ones_scale(ref_scale):
    return jnp.ones_like(ref_scale)


def default_wire_arrays(leaf: Dict) -> Dict[str, Any]:
    """The default wire set of one LoRA leaf: A and B travel, ``scale``
    stays home.  Single source of truth for both the aggregator hook
    (:meth:`Aggregator.wire_arrays`) and the transport's fallback for
    duck-typed strategies."""
    return {"A": leaf["A"], "B": leaf["B"]}


def bucket_by_shape(stacks: Dict[Tuple, Sequence[jnp.ndarray]]
                    ) -> List[List[Tuple]]:
    """Group leaf paths whose stacked blocks share shapes.

    Equal-shaped leaves (e.g. all the q/k/v/o projections of a layer stack)
    can be concatenated along the batch axis and pushed through ONE compiled
    vmapped call by the batched server pipelines; ``stacks`` maps each leaf
    path to its tuple of arrays and the result lists the path groups in
    insertion order.
    """
    buckets: Dict[Tuple, List[Tuple]] = {}
    for path, arrs in stacks.items():
        buckets.setdefault(tuple(a.shape for a in arrs), []).append(path)
    return list(buckets.values())


def leaf_dims(client_tree: Dict) -> Dict[Tuple, Tuple[int, int, int]]:
    """{leaf path: (L, n_in, m_out)} from one client's adapter tree.
    Note: A: (L, r, n_in), B: (L, m_out, r)."""
    dims = {}
    for path in adapter_leaf_paths(client_tree):
        leaf = get_path(client_tree, path)
        A, B = leaf["A"], leaf["B"]
        if A.ndim == 3:
            dims[path] = (A.shape[0], A.shape[2], B.shape[1])
        else:
            dims[path] = (1, A.shape[1], B.shape[0])
    return dims


def leaf_rank(tree: Dict) -> int:
    """Local LoRA rank of an adapter tree (from its first leaf)."""
    return get_path(tree, adapter_leaf_paths(tree)[0])["A"].shape[-2]


def fresh_client_adapters(a_init_full: Dict, rank: int) -> Dict:
    """Round-1 / re-init client state: A = shared init cut to ``rank``,
    B = 0 (training starts at the base model)."""
    from repro.peft.lora import match_rank

    a_init = match_rank(a_init_full, rank)

    def mk(path, leaf):
        last = getattr(path[-1], "key", None)
        return jnp.zeros_like(leaf) if last == "B" else leaf

    return jax.tree_util.tree_map_with_path(mk, a_init)


# ---------------------------------------------------------------------------
# result container
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class AggResult:
    method: str
    global_adapters: Optional[Dict]          # unified tree (None-able)
    per_client: Optional[List[Dict]]         # flexlora: tailored trees
    ranks: Dict[Tuple, List[int]]            # leaf path -> per-layer rank
    spectra: Dict[Tuple, List[np.ndarray]]   # leaf path -> per-layer σ (florist/flex)
    merge_into_base: bool = False            # flora semantics

    def total_download_rank(self) -> int:
        return int(sum(sum(v) for v in self.ranks.values()))


# ---------------------------------------------------------------------------
# the strategy interface
# ---------------------------------------------------------------------------


class Aggregator:
    """Base class for server-side aggregation strategies.

    Subclasses implement the streaming hooks ``_accumulate(update, weight,
    rank)`` and ``_finalize() -> AggResult`` plus whichever cost-model /
    client-init methods deviate from the defaults below.  Constructor kwargs
    are the method's own configuration (τ, SVD backend, frozen init, ...) —
    per-round state lives between ``begin_round`` and ``finalize``.
    """

    #: registry key, set by :func:`register_aggregator`.
    name: str = "?"
    #: FFA-style methods train only B locally (A frozen).
    trains_b_only: bool = False
    #: set True by strategies that must be handed the frozen shared init
    #: (``A_init``) before finalize — the trainer injects it explicitly
    #: instead of probing for an ``A_init`` attribute.
    needs_a_init: bool = False
    #: weight of this method's broadcast rank in the paper's efficiency
    #: denominator (FFA sends one of the two matrices → 0.5).
    download_rank_factor: float = 1.0

    def __init__(self):
        self._reset()

    # -- streaming lifecycle -------------------------------------------------
    def _reset(self) -> None:
        self.dims: Optional[Dict[Tuple, Tuple[int, int, int]]] = None
        self.num_clients: int = 0
        self.client_ranks: List[int] = []
        self.round_upload_params: int = 0
        self._ref_scales: Dict[Tuple, jnp.ndarray] = {}
        self._state: Dict[Tuple, Any] = {}

    def begin_round(self, dims: Optional[Dict] = None) -> None:
        """Reset per-round accumulators.  ``dims`` (as from
        :func:`leaf_dims`) is optional — it is captured from the first
        client update otherwise."""
        self._reset()
        self.dims = dims

    def add_client(self, update: Dict, weight: float,
                   rank: Optional[int] = None) -> None:
        """Fold one arriving client update into the running accumulators.

        ``weight`` is the client's (already normalised) aggregation weight
        ``n_k / N``; ``rank`` is the client's target local rank (defaults to
        the update's own LoRA rank).  The caller may drop ``update``
        immediately after this returns.
        """
        if self.dims is None:
            self.dims = leaf_dims(update)
        if rank is None:
            rank = leaf_rank(update)
        for path in adapter_leaf_paths(update):
            leaf = get_path(update, path)
            if path not in self._ref_scales:
                self._ref_scales[path] = ones_scale(leaf["scale"])
            self.round_upload_params += self.client_upload_params(leaf)
        self._accumulate(update, float(weight), int(rank))
        self.num_clients += 1
        self.client_ranks.append(int(rank))

    def finalize(self) -> AggResult:
        """Produce the round's :class:`AggResult` from the accumulators."""
        if self.num_clients == 0:
            raise ValueError(f"{self.name}: finalize() before any add_client()")
        return self._finalize()

    # -- subclass hooks ------------------------------------------------------
    def _accumulate(self, update: Dict, weight: float, rank: int) -> None:
        raise NotImplementedError

    def _finalize(self) -> AggResult:
        raise NotImplementedError

    # -- checkpoint hooks ----------------------------------------------------
    #: extra per-round attributes a subclass wants serialized alongside the
    #: base accumulators (e.g. fedit/ffa's ``_seen_ranks``).
    _STATE_FIELDS: Tuple[str, ...] = ()

    def state_dict(self) -> Dict[str, Any]:
        """Serializable snapshot of the mid-round streaming accumulators
        (running sums, pending FLoRIST stacks, the delta-mode ``M``) —
        device arrays are pulled to host so the blob pickles portably."""
        from repro.checkpoint.io import to_host
        state = {
            "dims": self.dims,
            "num_clients": self.num_clients,
            "client_ranks": list(self.client_ranks),
            "round_upload_params": self.round_upload_params,
            "_ref_scales": to_host(self._ref_scales),
            "_state": to_host(self._state),
        }
        for field in self._STATE_FIELDS:
            state[field] = to_host(getattr(self, field))
        return state

    def load_state_dict(self, state: Dict[str, Any]) -> None:
        """Restore a :meth:`state_dict` snapshot (arrays back to device);
        folding may resume exactly where the saved round left off."""
        from repro.checkpoint.io import to_device
        self.dims = state["dims"]
        self.num_clients = int(state["num_clients"])
        self.client_ranks = list(state["client_ranks"])
        self.round_upload_params = int(state["round_upload_params"])
        self._ref_scales = to_device(state["_ref_scales"])
        self._state = to_device(state["_state"])
        for field in self._STATE_FIELDS:
            setattr(self, field, to_device(state[field]))

    # -- one-shot convenience (the legacy call shape) ------------------------
    def aggregate(self, clients: Sequence[Dict], weights: Sequence[float],
                  client_ranks: Optional[Sequence[int]] = None) -> AggResult:
        """Run the full streaming lifecycle over an in-memory client list."""
        self.begin_round()
        for i, (c, w) in enumerate(zip(clients, weights)):
            self.add_client(c, w,
                            None if client_ranks is None else client_ranks[i])
        return self.finalize()

    # -- client-init semantics ----------------------------------------------
    def client_init(self, global_state: Optional[AggResult], rank: int,
                    a_init_full: Dict) -> Dict:
        """Adapters a rank-``rank`` client resumes from this round.

        Default (fedit / florist / flexlora): truncate-or-pad the global
        adapters to the client's rank (Alg. 1).  For FlexLoRA the global
        tree holds the full SVD sorted by σ, so rank matching == the
        paper's per-client cut.  Round 1: B = 0, A = shared init.
        """
        from repro.peft.lora import match_rank

        if global_state is None:
            return fresh_client_adapters(a_init_full, rank)
        return match_rank(global_state.global_adapters, rank)

    # -- wire semantics ------------------------------------------------------
    def wire_arrays(self, leaf: Dict) -> Dict[str, Any]:
        """The tensors of one LoRA leaf that actually travel on the wire
        (both directions) — the measured-bytes counterpart of the analytic
        cost model below.  Default: A and B (``scale`` is an O(L) header
        re-derived locally; FFA overrides to send only B)."""
        return default_wire_arrays(leaf)

    # -- cost model ----------------------------------------------------------
    # NOTE: cost methods must not depend on constructor config or per-round
    # accumulator state — costs.py calls them on an uninitialised instance
    # so accounting works for any registered method name.
    def client_upload_params(self, leaf: Dict) -> int:
        """Parameters one client sends for one LoRA leaf (default: A + B)."""
        return leaf["A"].size + leaf["B"].size

    def upload_params(self, client_trees: Sequence[Dict]) -> int:
        """Total parameters uploaded by the sampled clients this round."""
        total = 0
        for tree in client_trees:
            for path in adapter_leaf_paths(tree):
                total += self.client_upload_params(get_path(tree, path))
        return total

    def download_params(self, agg: AggResult, dims: Dict, num_clients: int,
                        client_ranks: Sequence[int]) -> int:
        """Total parameters sent server → clients this round (default:
        broadcast the rank-p_l global adapters to every client)."""
        total = 0
        for path, (L, n, m) in dims.items():
            for r_l in agg.ranks[path]:
                total += num_clients * r_l * (n + m)
        return total

    def server_flops(self, dims: Dict, client_ranks: Sequence[int],
                     agg_ranks: Optional[Dict[Tuple, List[int]]] = None) -> int:
        """Analytic per-round server cost (mult-add = 2 FLOPs)."""
        raise NotImplementedError

    def efficiency(self, agg: AggResult, client_ranks: Sequence[int] = (),
                   dims: Optional[Dict] = None) -> float:
        """1 / downloaded rank (paper §4, 'communication efficiency')."""
        tr = agg.total_download_rank() * self.download_rank_factor
        return 1.0 / max(1.0, tr)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Type[Aggregator]] = {}


def register_aggregator(name: str):
    """Class decorator: make ``name`` constructible via
    :func:`make_aggregator` (and visible to the CLI launchers)."""

    def deco(cls: Type[Aggregator]) -> Type[Aggregator]:
        if not (isinstance(cls, type) and issubclass(cls, Aggregator)):
            raise TypeError(f"{cls!r} must subclass Aggregator")
        _REGISTRY[name] = cls
        cls.name = name
        return cls

    return deco


def get_aggregator_class(name: str) -> Type[Aggregator]:
    """Registered class for ``name`` — lets callers read class-level
    attributes (``download_rank_factor``, ``trains_b_only``) or pure cost
    formulas without constructing an instance."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown aggregation method {name!r} "
            f"(registered: {sorted(_REGISTRY)})") from None


def make_aggregator(name: str, **cfg) -> Aggregator:
    """Instantiate a registered aggregation strategy by name."""
    return get_aggregator_class(name)(**cfg)


def available_aggregators() -> List[str]:
    return sorted(_REGISTRY)


def accepted_config(name: str, cfg: Dict[str, Any]) -> Dict[str, Any]:
    """Subset of ``cfg`` accepted by ``name``'s constructor — lets generic
    callers (the legacy ``aggregate()`` shim, sweep drivers) carry a union
    of per-method knobs without every method growing every kwarg."""
    cls = get_aggregator_class(name)
    sig = inspect.signature(cls.__init__)
    if any(p.kind is inspect.Parameter.VAR_KEYWORD
           for p in sig.parameters.values()):
        return dict(cfg)
    return {k: v for k, v in cfg.items() if k in sig.parameters}
