"""FedIT: FedAvg of A's and B's separately — mathematically inexact (cross
terms).  Heterogeneous ranks require HetLoRA zero-padding."""
from __future__ import annotations

from typing import Dict, List, Tuple

import jax.numpy as jnp

from repro.core.aggregators.base import (AggResult, Aggregator,
                                         adapter_leaf_paths, fold_scale,
                                         get_path, leaf_rank,
                                         register_aggregator, set_path)


def pad_rank(A: jnp.ndarray, B: jnp.ndarray, R: int):
    """Zero-pad an (A, B) pair from its own rank up to R (no-op if equal)."""
    r = A.shape[-2]
    if r < R:
        padA = [(0, 0)] * A.ndim
        padA[-2] = (0, R - r)
        padB = [(0, 0)] * B.ndim
        padB[-1] = (0, R - r)
        A, B = jnp.pad(A, padA), jnp.pad(B, padB)
    return A, B


@register_aggregator("fedit")
class FedItAggregator(Aggregator):
    """Streaming FedAvg: one running weighted sum of (A, B) per leaf, grown
    to the max rank seen so far — O(1) memory in the client count."""

    _STATE_FIELDS = ("_seen_ranks",)

    def __init__(self, zero_padding: bool = False):
        self.zero_padding = zero_padding
        super().__init__()

    def _reset(self) -> None:
        super()._reset()
        self._seen_ranks = set()

    def _accumulate(self, update: Dict, weight: float, rank: int) -> None:
        self._seen_ranks.add(leaf_rank(update))
        if len(self._seen_ranks) > 1 and not self.zero_padding:
            raise ValueError(
                "FedIT requires homogeneous ranks (or zero_padding=True)")
        for path in adapter_leaf_paths(update):
            Bk, Ak = fold_scale(get_path(update, path))
            acc = self._state.get(path)
            if acc is None:
                self._state[path] = {"A": weight * Ak, "B": weight * Bk}
                continue
            R = max(acc["A"].shape[-2], Ak.shape[-2])
            acc["A"], acc["B"] = pad_rank(acc["A"], acc["B"], R)
            Ak, Bk = pad_rank(Ak, Bk, R)
            acc["A"] = acc["A"] + weight * Ak
            acc["B"] = acc["B"] + weight * Bk

    def _finalize(self) -> AggResult:
        out: Dict = {}
        rank_rec: Dict[Tuple, List[int]] = {}
        for path, acc in self._state.items():
            A_avg, B_avg = acc["A"], acc["B"]
            set_path(out, path, {"A": A_avg, "B": B_avg,
                                 "scale": self._ref_scales[path]})
            L = A_avg.shape[0] if A_avg.ndim == 3 else 1
            rank_rec[path] = [A_avg.shape[-2]] * L
        return AggResult(self.name, out, None, rank_rec, {})

    def server_flops(self, dims, client_ranks, agg_ranks=None) -> int:
        K, R = len(client_ranks), max(client_ranks)
        return sum(L * 2 * K * R * (m + n) for (L, n, m) in dims.values())
