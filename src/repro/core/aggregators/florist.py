"""FLoRIST (Algorithm 1, server block): stacked thin-SVDs + r×r core SVD +
per-layer energy thresholding — the singular values of ΔW without ever
forming ΔW."""
from __future__ import annotations

from typing import Dict, List, Tuple

import jax.numpy as jnp
import numpy as np

import jax

from repro.core.aggregators.base import (AggResult, Aggregator,
                                         adapter_leaf_paths, bucket_by_shape,
                                         fold_scale, get_path,
                                         register_aggregator, set_path)
from repro.core.svd import (florist_core_batched, florist_core_delta_batched,
                            florist_core_stacked)


@register_aggregator("florist")
class FloristAggregator(Aggregator):
    """Streaming stacker + thresholded core SVD at finalize.

    ``add_client`` folds each arriving client into a *bounded* compact
    intermediate: scale-folded B blocks and weighted A blocks are appended
    to a per-leaf pending list and, every ``flush_every`` arrivals, the
    pending blocks are compacted on device —

    * **stacked mode** (small rounds): the pending blocks are concatenated
      into one (L, m, Σr) / (L, Σr, n) pair, the exact intermediate the
      paper's pipeline thin-SVDs at finalize;
    * **delta mode** (``stream="delta"``, or ``"auto"`` once the stack
      width Σ r_k would exceed ``min(m, n)``): the pending blocks are
      contracted into a running dense update ``M += B_pend A_pend`` —
      O(m·n) per leaf, *constant in the client count* — and finalize runs
      the thin SVD of ``M`` directly (the same SVD the stacked route
      computes implicitly, so the two modes agree up to fp error).

    Either way the server never holds more than ``flush_every`` client
    blocks plus one compact intermediate per leaf: peak live adapter
    memory is O(cohort), not O(K).  ``peak_pending_blocks`` records the
    high-water mark for the memory-bound tests.

    ``finalize`` buckets leaves with identical intermediate shapes so every
    layer of a bucket goes through ONE compiled vmapped call
    (:func:`~repro.core.svd.florist_core_batched` /
    :func:`~repro.core.svd.florist_core_delta_batched`); spectra and
    concrete per-layer ranks are materialized with a single device→host
    transfer at the end, where the zero-padded outputs are truncated.
    Ragged per-layer ranks are zero-padded to the per-leaf max so the
    global tree stays scan-compatible; the true ranks are recorded for
    communication accounting.

    ``pipeline="loop"`` keeps the legacy per-(leaf, layer) Python loop
    (one eager ``florist_core_stacked`` + host sync per layer) as a
    reference for equivalence tests and the ``agg_bench`` baseline; it
    forces stacked mode (the loop oracle predates the delta route).
    """

    def __init__(self, tau=0.9, svd_method: str = "svd", max_rank: int = 0,
                 pipeline: str = "batched", stream: str = "auto",
                 flush_every: int = 64):
        if pipeline not in ("batched", "loop"):
            raise ValueError(pipeline)
        if stream not in ("auto", "stacked", "delta"):
            raise ValueError(stream)
        self.tau = tau
        self.svd_method = svd_method
        self.max_rank = max_rank
        self.pipeline = pipeline
        # the loop oracle iterates the stacked lists directly
        self.stream = "stacked" if pipeline == "loop" else stream
        self.flush_every = max(1, int(flush_every))
        self.peak_pending_blocks = 0
        super().__init__()

    # -- streaming accumulation ----------------------------------------------

    def _accumulate(self, update: Dict, weight: float, rank: int) -> None:
        for path in adapter_leaf_paths(update):
            Bk, Ak = fold_scale(get_path(update, path))
            acc = self._state.setdefault(
                path, {"stacked": Ak.ndim == 3, "A": [], "B": [], "M": None})
            acc["B"].append(Bk)
            acc["A"].append(weight * Ak)
            self.peak_pending_blocks = max(self.peak_pending_blocks,
                                           len(acc["B"]))
            if len(acc["B"]) >= self.flush_every:
                self._compact(acc)

    def _delta_mode(self, acc: Dict) -> bool:
        if acc["M"] is not None or self.stream == "delta":
            return True
        if self.stream != "auto" or not acc["B"]:
            return False
        width = sum(b.shape[-1] for b in acc["B"])
        m, n = acc["B"][0].shape[-2], acc["A"][0].shape[-1]
        return width > min(m, n)

    def _compact(self, acc: Dict) -> None:
        """Fold the pending client blocks into the compact intermediate
        (running dense ΔW in delta mode, one consolidated stack otherwise),
        bounding the pending list at ``flush_every`` entries."""
        if not acc["B"]:
            return
        B = acc["B"][0] if len(acc["B"]) == 1 \
            else jnp.concatenate(acc["B"], axis=-1)
        A = acc["A"][0] if len(acc["A"]) == 1 \
            else jnp.concatenate(acc["A"], axis=-2)
        if self._delta_mode(acc):
            d = B @ A                       # (L, m, n) / (m, n): batched matmul
            acc["M"] = d if acc["M"] is None else acc["M"] + d
            acc["B"], acc["A"] = [], []
        else:
            acc["B"], acc["A"] = [B], [A]

    def _settle(self) -> Dict[Tuple, Tuple]:
        """Compact every leaf and return its finalize-ready intermediate:
        ``("stack", B (L,m,Σr), A (L,Σr,n))`` or ``("delta", M (L,m,n))``
        (un-stacked leaves get a singleton layer axis so every leaf is
        3-D)."""
        inter: Dict[Tuple, Tuple] = {}
        for path, acc in self._state.items():
            self._compact(acc)
            if acc["M"] is not None:
                M = acc["M"] if acc["stacked"] else acc["M"][None]
                inter[path] = ("delta", M)
            else:
                B, A = acc["B"][0], acc["A"][0]
                if not acc["stacked"]:
                    B, A = B[None], A[None]
                inter[path] = ("stack", B, A)
        return inter

    def _leaf_stacks(self) -> Dict[Tuple, Tuple[jnp.ndarray, jnp.ndarray]]:
        """{path: (B_stack (L,m,Σr), A_stack (L,Σr,n))} — stacked-mode
        leaves only (the loop oracle and stacked-only callers)."""
        stacks = {}
        for path, acc in self._state.items():
            B_stack = jnp.concatenate(acc["B"], axis=-1)
            A_stack = jnp.concatenate(acc["A"], axis=-2)
            if not acc["stacked"]:
                B_stack, A_stack = B_stack[None], A_stack[None]
            stacks[path] = (B_stack, A_stack)
        return stacks

    # -- finalize -------------------------------------------------------------

    def _materialize(self, device: Dict[Tuple, Tuple]) -> AggResult:
        """Shared finalize tail: ONE device→host transfer for all leaves'
        spectra + ranks, then truncate the zero-padded global factors to
        each leaf's max kept rank (exact: the dropped columns are zeros)."""
        out: Dict = {}
        rank_rec: Dict[Tuple, List[int]] = {}
        spectra: Dict[Tuple, List[np.ndarray]] = {}
        host = jax.device_get({p: (v[2], v[3]) for p, v in device.items()})
        for path, (Bg, Ag, _, _) in device.items():
            sp_h, p_h = host[path]
            ps = [int(x) for x in p_h]
            p_max = max(ps)
            Bg, Ag = Bg[:, :, :p_max], Ag[:, :p_max, :]
            if not self._state[path]["stacked"]:
                Bg, Ag = Bg[0], Ag[0]
            set_path(out, path, {"A": Ag, "B": Bg,
                                 "scale": self._ref_scales[path]})
            rank_rec[path] = ps
            spectra[path] = [np.asarray(s) for s in sp_h]
        return AggResult(self.name, out, None, rank_rec, spectra)

    def _finalize(self) -> AggResult:
        if self.pipeline == "loop":
            return self._finalize_loop()
        inter = self._settle()
        stacks = {p: v[1:] for p, v in inter.items() if v[0] == "stack"}
        deltas = {p: v[1:] for p, v in inter.items() if v[0] == "delta"}
        # bucket leaves by intermediate shape: equal-shaped leaves (e.g. all
        # the q/k/v/o projections) share one compiled call over G·L layers
        device: Dict[Tuple, Tuple] = {}
        for paths in bucket_by_shape(stacks):
            Bb = jnp.concatenate([stacks[p][0] for p in paths], axis=0)
            Ab = jnp.concatenate([stacks[p][1] for p in paths], axis=0)
            Bg, Ag, sp, pr = florist_core_batched(
                Bb, Ab, self.tau, self.svd_method, self.max_rank)
            L = stacks[paths[0]][0].shape[0]
            for i, path in enumerate(paths):
                sl = slice(i * L, (i + 1) * L)
                device[path] = (Bg[sl], Ag[sl], sp[sl], pr[sl])
        for paths in bucket_by_shape(deltas):
            Mb = jnp.concatenate([deltas[p][0] for p in paths], axis=0)
            Bg, Ag, sp, pr = florist_core_delta_batched(
                Mb, self.tau, self.svd_method, self.max_rank)
            L = deltas[paths[0]][0].shape[0]
            for i, path in enumerate(paths):
                sl = slice(i * L, (i + 1) * L)
                device[path] = (Bg[sl], Ag[sl], sp[sl], pr[sl])
        return self._materialize(device)

    def _finalize_loop(self) -> AggResult:
        """Legacy per-(leaf, layer) eager loop — kept verbatim as the
        equivalence oracle and benchmark baseline."""
        out: Dict = {}
        rank_rec: Dict[Tuple, List[int]] = {}
        spectra: Dict[Tuple, List[np.ndarray]] = {}
        for path, acc in self._state.items():
            stacked = acc["stacked"]
            B_stack = jnp.concatenate(acc["B"], axis=-1)   # (L, m, Σr)
            A_stack = jnp.concatenate(acc["A"], axis=-2)   # (L, Σr, n)
            L = B_stack.shape[0] if stacked else 1
            Bg_l, Ag_l, ps = [], [], []
            spectra[path] = []
            for l in range(L):
                res = florist_core_stacked(
                    B_stack[l] if stacked else B_stack,
                    A_stack[l] if stacked else A_stack,
                    self.tau, self.svd_method, self.max_rank)
                Bg_l.append(res.B_g)
                Ag_l.append(res.A_g)
                ps.append(res.p)
                spectra[path].append(np.asarray(res.spectrum))
            p_max = max(ps)
            if stacked:
                Bg = jnp.stack([jnp.pad(b, ((0, 0), (0, p_max - b.shape[1])))
                                for b in Bg_l])
                Ag = jnp.stack([jnp.pad(a, ((0, p_max - a.shape[0]), (0, 0)))
                                for a in Ag_l])
            else:
                Bg, Ag = Bg_l[0], Ag_l[0]
            set_path(out, path, {"A": Ag, "B": Bg,
                                 "scale": self._ref_scales[path]})
            rank_rec[path] = ps
        return AggResult(self.name, out, None, rank_rec, spectra)

    def server_flops(self, dims, client_ranks, agg_ranks=None) -> int:
        from repro.core.costs import SVD_CONST

        r = sum(client_ranks)                        # stacked rank
        total = 0
        for path, (L, n, m) in dims.items():
            for l in range(L):
                total += SVD_CONST * (m * r * r + n * r * r)  # thin SVDs
                total += 2 * r ** 3                            # Q = V_Bᵀ U_A
                total += 2 * r * r                             # P diag scaling
                total += SVD_CONST * r ** 3                    # SVD(P)
                p_l = agg_ranks[path][l] if agg_ranks else r
                total += 2 * (m * r * p_l + p_l * r * n)       # build B_g, A_g
        return total
