"""FLoRIST (Algorithm 1, server block): stacked thin-SVDs + r×r core SVD +
per-layer energy thresholding — the singular values of ΔW without ever
forming ΔW."""
from __future__ import annotations

from typing import Dict, List, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.aggregators.base import (AggResult, Aggregator,
                                         adapter_leaf_paths, fold_scale,
                                         get_path, register_aggregator,
                                         set_path)
from repro.core.svd import florist_core_stacked


@register_aggregator("florist")
class FloristAggregator(Aggregator):
    """Streaming stacker + thresholded core SVD at finalize.

    ``add_client`` appends each client's scale-folded B block and weighted A
    block per leaf — O(Σ r_k) columns per leaf, never K full trees — and
    ``finalize`` runs the per-layer stacked-SVD pipeline on the completed
    stacks.  Ragged per-layer ranks are zero-padded to the per-leaf max so
    the global tree stays scan-compatible; the true ranks are recorded for
    communication accounting.
    """

    def __init__(self, tau=0.9, svd_method: str = "svd", max_rank: int = 0):
        self.tau = tau
        self.svd_method = svd_method
        self.max_rank = max_rank
        super().__init__()

    def _accumulate(self, update: Dict, weight: float, rank: int) -> None:
        for path in adapter_leaf_paths(update):
            Bk, Ak = fold_scale(get_path(update, path))
            acc = self._state.setdefault(
                path, {"stacked": Ak.ndim == 3, "A": [], "B": []})
            acc["B"].append(Bk)
            acc["A"].append(weight * Ak)

    def _finalize(self) -> AggResult:
        out: Dict = {}
        rank_rec: Dict[Tuple, List[int]] = {}
        spectra: Dict[Tuple, List[np.ndarray]] = {}
        for path, acc in self._state.items():
            stacked = acc["stacked"]
            B_stack = jnp.concatenate(acc["B"], axis=-1)   # (L, m, Σr)
            A_stack = jnp.concatenate(acc["A"], axis=-2)   # (L, Σr, n)
            L = B_stack.shape[0] if stacked else 1
            Bg_l, Ag_l, ps = [], [], []
            spectra[path] = []
            for l in range(L):
                res = florist_core_stacked(
                    B_stack[l] if stacked else B_stack,
                    A_stack[l] if stacked else A_stack,
                    self.tau, self.svd_method, self.max_rank)
                Bg_l.append(res.B_g)
                Ag_l.append(res.A_g)
                ps.append(res.p)
                spectra[path].append(np.asarray(res.spectrum))
            p_max = max(ps)
            if stacked:
                Bg = jnp.stack([jnp.pad(b, ((0, 0), (0, p_max - b.shape[1])))
                                for b in Bg_l])
                Ag = jnp.stack([jnp.pad(a, ((0, p_max - a.shape[0]), (0, 0)))
                                for a in Ag_l])
            else:
                Bg, Ag = Bg_l[0], Ag_l[0]
            set_path(out, path, {"A": Ag, "B": Bg,
                                 "scale": self._ref_scales[path]})
            rank_rec[path] = ps
        return AggResult(self.name, out, None, rank_rec, spectra)

    def server_flops(self, dims, client_ranks, agg_ranks=None) -> int:
        from repro.core.costs import SVD_CONST

        r = sum(client_ranks)                        # stacked rank
        total = 0
        for path, (L, n, m) in dims.items():
            for l in range(L):
                total += SVD_CONST * (m * r * r + n * r * r)  # thin SVDs
                total += 2 * r ** 3                            # Q = V_Bᵀ U_A
                total += 2 * r * r                             # P diag scaling
                total += SVD_CONST * r ** 3                    # SVD(P)
                p_l = agg_ranks[path][l] if agg_ranks else r
                total += 2 * (m * r * p_l + p_l * r * n)       # build B_g, A_g
        return total
