"""FLoRIST (Algorithm 1, server block): stacked thin-SVDs + r×r core SVD +
per-layer energy thresholding — the singular values of ΔW without ever
forming ΔW."""
from __future__ import annotations

from typing import Dict, List, Tuple

import jax.numpy as jnp
import numpy as np

import jax

from repro.core.aggregators.base import (AggResult, Aggregator,
                                         adapter_leaf_paths, bucket_by_shape,
                                         fold_scale, get_path,
                                         register_aggregator, set_path)
from repro.core.svd import florist_core_batched, florist_core_stacked


@register_aggregator("florist")
class FloristAggregator(Aggregator):
    """Streaming stacker + thresholded core SVD at finalize.

    ``add_client`` appends each client's scale-folded B block and weighted A
    block per leaf — O(Σ r_k) columns per leaf, never K full trees — and
    ``finalize`` runs the batched server pipeline on the completed stacks:
    leaves with identical stack shapes are batched together and every layer
    of a bucket goes through ONE compiled vmapped call
    (:func:`~repro.core.svd.florist_core_batched`); spectra and concrete
    per-layer ranks are materialized with a single device→host transfer at
    the end, where the zero-padded outputs are truncated.  Ragged per-layer
    ranks are zero-padded to the per-leaf max so the global tree stays
    scan-compatible; the true ranks are recorded for communication
    accounting.

    ``pipeline="loop"`` keeps the legacy per-(leaf, layer) Python loop
    (one eager ``florist_core_stacked`` + host sync per layer) as a
    reference for equivalence tests and the ``agg_bench`` baseline.
    """

    def __init__(self, tau=0.9, svd_method: str = "svd", max_rank: int = 0,
                 pipeline: str = "batched"):
        if pipeline not in ("batched", "loop"):
            raise ValueError(pipeline)
        self.tau = tau
        self.svd_method = svd_method
        self.max_rank = max_rank
        self.pipeline = pipeline
        super().__init__()

    def _accumulate(self, update: Dict, weight: float, rank: int) -> None:
        for path in adapter_leaf_paths(update):
            Bk, Ak = fold_scale(get_path(update, path))
            acc = self._state.setdefault(
                path, {"stacked": Ak.ndim == 3, "A": [], "B": []})
            acc["B"].append(Bk)
            acc["A"].append(weight * Ak)

    def _leaf_stacks(self) -> Dict[Tuple, Tuple[jnp.ndarray, jnp.ndarray]]:
        """{path: (B_stack (L,m,Σr), A_stack (L,Σr,n))} — un-stacked leaves
        get a singleton layer axis so every leaf is 3-D."""
        stacks = {}
        for path, acc in self._state.items():
            B_stack = jnp.concatenate(acc["B"], axis=-1)
            A_stack = jnp.concatenate(acc["A"], axis=-2)
            if not acc["stacked"]:
                B_stack, A_stack = B_stack[None], A_stack[None]
            stacks[path] = (B_stack, A_stack)
        return stacks

    def _finalize(self) -> AggResult:
        if self.pipeline == "loop":
            return self._finalize_loop()
        out: Dict = {}
        rank_rec: Dict[Tuple, List[int]] = {}
        spectra: Dict[Tuple, List[np.ndarray]] = {}
        stacks = self._leaf_stacks()
        # bucket leaves by stack shape: equal-shaped leaves (e.g. all the
        # q/k/v/o projections) share one compiled call over G·L layers
        device: Dict[Tuple, Tuple] = {}
        for paths in bucket_by_shape(stacks):
            Bb = jnp.concatenate([stacks[p][0] for p in paths], axis=0)
            Ab = jnp.concatenate([stacks[p][1] for p in paths], axis=0)
            Bg, Ag, sp, pr = florist_core_batched(
                Bb, Ab, self.tau, self.svd_method, self.max_rank)
            L = stacks[paths[0]][0].shape[0]
            for i, path in enumerate(paths):
                sl = slice(i * L, (i + 1) * L)
                device[path] = (Bg[sl], Ag[sl], sp[sl], pr[sl])
        # exactly ONE device→host transfer: the spectra and concrete ranks
        # needed for truncation and accounting
        host = jax.device_get({p: (v[2], v[3]) for p, v in device.items()})
        for path, (Bg, Ag, _, _) in device.items():
            sp_h, p_h = host[path]
            ps = [int(x) for x in p_h]
            p_max = max(ps)
            # columns beyond each layer's p_l are zeroed on device, so
            # truncating to the per-leaf max is exact (same ΔW)
            Bg, Ag = Bg[:, :, :p_max], Ag[:, :p_max, :]
            if not self._state[path]["stacked"]:
                Bg, Ag = Bg[0], Ag[0]
            set_path(out, path, {"A": Ag, "B": Bg,
                                 "scale": self._ref_scales[path]})
            rank_rec[path] = ps
            spectra[path] = [np.asarray(s) for s in sp_h]
        return AggResult(self.name, out, None, rank_rec, spectra)

    def _finalize_loop(self) -> AggResult:
        """Legacy per-(leaf, layer) eager loop — kept verbatim as the
        equivalence oracle and benchmark baseline."""
        out: Dict = {}
        rank_rec: Dict[Tuple, List[int]] = {}
        spectra: Dict[Tuple, List[np.ndarray]] = {}
        for path, acc in self._state.items():
            stacked = acc["stacked"]
            B_stack = jnp.concatenate(acc["B"], axis=-1)   # (L, m, Σr)
            A_stack = jnp.concatenate(acc["A"], axis=-2)   # (L, Σr, n)
            L = B_stack.shape[0] if stacked else 1
            Bg_l, Ag_l, ps = [], [], []
            spectra[path] = []
            for l in range(L):
                res = florist_core_stacked(
                    B_stack[l] if stacked else B_stack,
                    A_stack[l] if stacked else A_stack,
                    self.tau, self.svd_method, self.max_rank)
                Bg_l.append(res.B_g)
                Ag_l.append(res.A_g)
                ps.append(res.p)
                spectra[path].append(np.asarray(res.spectrum))
            p_max = max(ps)
            if stacked:
                Bg = jnp.stack([jnp.pad(b, ((0, 0), (0, p_max - b.shape[1])))
                                for b in Bg_l])
                Ag = jnp.stack([jnp.pad(a, ((0, p_max - a.shape[0]), (0, 0)))
                                for a in Ag_l])
            else:
                Bg, Ag = Bg_l[0], Ag_l[0]
            set_path(out, path, {"A": Ag, "B": Bg,
                                 "scale": self._ref_scales[path]})
            rank_rec[path] = ps
        return AggResult(self.name, out, None, rank_rec, spectra)

    def server_flops(self, dims, client_ranks, agg_ranks=None) -> int:
        from repro.core.costs import SVD_CONST

        r = sum(client_ranks)                        # stacked rank
        total = 0
        for path, (L, n, m) in dims.items():
            for l in range(L):
                total += SVD_CONST * (m * r * r + n * r * r)  # thin SVDs
                total += 2 * r ** 3                            # Q = V_Bᵀ U_A
                total += 2 * r * r                             # P diag scaling
                total += SVD_CONST * r ** 3                    # SVD(P)
                p_l = agg_ranks[path][l] if agg_ranks else r
                total += 2 * (m * r * p_l + p_l * r * n)       # build B_g, A_g
        return total
