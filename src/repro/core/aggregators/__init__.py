"""Pluggable server-side aggregation strategies.

Importing this package registers the five paper methods (``florist``,
``fedit``, ``ffa``, ``flora``, ``flexlora``); additional strategies
register themselves with :func:`register_aggregator` (e.g. the sharded
multi-pod FLoRIST backend in :mod:`repro.core.distributed`).
"""
from repro.core.aggregators.base import (AggResult, Aggregator,
                                         accepted_config,
                                         adapter_leaf_paths,
                                         available_aggregators,
                                         bucket_by_shape, fold_scale,
                                         fresh_client_adapters,
                                         get_aggregator_class, get_path,
                                         leaf_dims, leaf_rank,
                                         make_aggregator, ones_scale,
                                         per_layer, register_aggregator,
                                         set_path)
from repro.core.aggregators.fedit import FedItAggregator
from repro.core.aggregators.ffa import FfaAggregator
from repro.core.aggregators.flexlora import FlexLoRAAggregator
from repro.core.aggregators.flora import FloraAggregator
from repro.core.aggregators.florist import FloristAggregator

#: the paper's five methods, in the paper's comparison order
METHODS = ("florist", "fedit", "ffa", "flora", "flexlora")

__all__ = [
    "AggResult", "Aggregator", "METHODS", "accepted_config",
    "adapter_leaf_paths", "available_aggregators", "bucket_by_shape",
    "fold_scale",
    "fresh_client_adapters", "get_aggregator_class", "get_path",
    "leaf_dims", "leaf_rank",
    "make_aggregator", "ones_scale", "per_layer", "register_aggregator",
    "set_path", "FedItAggregator", "FfaAggregator", "FlexLoRAAggregator",
    "FloraAggregator", "FloristAggregator",
]


# -- abstract contracts (checked by repro.analysis.contracts) -----------------

from repro.analysis.registry import ContractCase, check_contract

#: geometry for the aggregation-core contracts: L layer-stacked adapters of
#: rank r over an m x n base matrix
_L, _M, _R, _N = 4, 32, 12, 24


@check_contract("agg.florist_finalize", mesh_sizes=(1,))
def _contract_florist_finalize(case):
    """The jit-safe FLoRIST core: zero-padded global factors keep the
    client-rank shapes (no data-dependent widths inside jit), the spectrum
    carries all r singular values, and the kept rank is a traced scalar."""
    import jax
    import jax.numpy as jnp

    from repro.analysis import fixtures as FX
    from repro.core.svd import florist_core_batched

    def core(b, a):
        return florist_core_batched(b, a, 0.9, "gram")

    def out_check(out, _case):
        b_g, a_g, spectrum, p = out
        assert b_g.shape == (_L, _M, _R), b_g.shape
        assert a_g.shape == (_L, _R, _N), a_g.shape
        assert spectrum.shape == (_L, _R), spectrum.shape
        assert p.shape == (_L,) and p.dtype == jnp.int32, (p.shape, p.dtype)
        assert all(v.dtype == jnp.float32 for v in (b_g, a_g, spectrum))

    return ContractCase(core, (FX.sds((_L, _M, _R), "float32"),
                               FX.sds((_L, _R, _N), "float32")),
                        out_check=out_check)


@check_contract("agg.florist_stream", mesh_sizes=(1,))
def _contract_florist_stream(case):
    """The streaming ``add_client`` path's compact intermediate: folding a
    pending block into the running dense update preserves the O(m·n) aval
    (a fixed point — the accumulator never grows with the client count),
    and the delta-mode finalize keeps the padded-core output shapes."""
    import jax
    import jax.numpy as jnp

    from repro.analysis import fixtures as FX
    from repro.core.svd import florist_core_delta_batched

    def core(m, b, a):
        m2 = m + b @ a                      # one _compact() fold
        return (m2,) + tuple(florist_core_delta_batched(m2, 0.9, "gram"))

    def out_check(out, _case):
        m2, b_g, a_g, spectrum, p = out
        q = min(_M, _N)
        assert m2.shape == (_L, _M, _N), m2.shape    # accumulator fixed point
        assert b_g.shape == (_L, _M, q), b_g.shape
        assert a_g.shape == (_L, q, _N), a_g.shape
        assert spectrum.shape == (_L, q), spectrum.shape
        assert p.shape == (_L,) and p.dtype == jnp.int32, (p.shape, p.dtype)
        assert all(v.dtype == jnp.float32 for v in (m2, b_g, a_g, spectrum))

    return ContractCase(core, (FX.sds((_L, _M, _N), "float32"),
                               FX.sds((_L, _M, _R), "float32"),
                               FX.sds((_L, _R, _N), "float32")),
                        out_check=out_check)


@check_contract("agg.thin_svd", mesh_sizes=(1,))
def _contract_thin_svd(case):
    """Batched thin SVD (both the LAPACK path and the gram-trick path used
    on stacked client factors) keeps thin shapes and fp32."""
    import jax
    import jax.numpy as jnp

    from repro.analysis import fixtures as FX
    from repro.core.svd import thin_svd_batched

    x = FX.sds((_L, _M, _R), "float32")

    def core(v):
        return tuple(thin_svd_batched(v, "gram")) \
            + tuple(thin_svd_batched(v, "svd"))

    def out_check(out, _case):
        for (u, s, vt) in (out[:3], out[3:]):
            assert u.shape == (_L, _M, _R), u.shape
            assert s.shape == (_L, _R), s.shape
            assert vt.shape == (_L, _R, _R), vt.shape
            assert u.dtype == s.dtype == vt.dtype == jnp.float32

    return ContractCase(core, (x,), out_check=out_check)


@check_contract("agg.sharded_florist", mesh_sizes=(1,))
def _contract_sharded_florist(case):
    """The shard_map'd multi-pod FLoRIST backend matches the host core's
    output avals exactly (shard_map needs device-backed meshes, so this
    contract runs at mesh 1 only)."""
    import jax

    from repro.analysis import fixtures as FX
    from repro.core.distributed import make_sharded_florist
    from repro.core.svd import florist_core_batched

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    fn = make_sharded_florist(mesh, tau=0.9, svd_method="gram")
    args = (FX.sds((_L, _M, _R), "float32"), FX.sds((_L, _R, _N), "float32"))
    return ContractCase(lambda b, a: tuple(fn(b, a)), args,
                        twin=(lambda b, a: tuple(
                            florist_core_batched(b, a, 0.9, "gram")), args))
