"""Pluggable server-side aggregation strategies.

Importing this package registers the five paper methods (``florist``,
``fedit``, ``ffa``, ``flora``, ``flexlora``); additional strategies
register themselves with :func:`register_aggregator` (e.g. the sharded
multi-pod FLoRIST backend in :mod:`repro.core.distributed`).
"""
from repro.core.aggregators.base import (AggResult, Aggregator,
                                         accepted_config,
                                         adapter_leaf_paths,
                                         available_aggregators,
                                         bucket_by_shape, fold_scale,
                                         fresh_client_adapters,
                                         get_aggregator_class, get_path,
                                         leaf_dims, leaf_rank,
                                         make_aggregator, ones_scale,
                                         per_layer, register_aggregator,
                                         set_path)
from repro.core.aggregators.fedit import FedItAggregator
from repro.core.aggregators.ffa import FfaAggregator
from repro.core.aggregators.flexlora import FlexLoRAAggregator
from repro.core.aggregators.flora import FloraAggregator
from repro.core.aggregators.florist import FloristAggregator

#: the paper's five methods, in the paper's comparison order
METHODS = ("florist", "fedit", "ffa", "flora", "flexlora")

__all__ = [
    "AggResult", "Aggregator", "METHODS", "accepted_config",
    "adapter_leaf_paths", "available_aggregators", "bucket_by_shape",
    "fold_scale",
    "fresh_client_adapters", "get_aggregator_class", "get_path",
    "leaf_dims", "leaf_rank",
    "make_aggregator", "ones_scale", "per_layer", "register_aggregator",
    "set_path", "FedItAggregator", "FfaAggregator", "FlexLoRAAggregator",
    "FloraAggregator", "FloristAggregator",
]
