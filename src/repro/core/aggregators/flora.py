"""FLoRA: stack everything, broadcast the stack (rank = Σ r_k); clients
merge into the frozen base and re-init local adapters."""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp

from repro.core.aggregators.base import (AggResult, Aggregator,
                                         adapter_leaf_paths, fold_scale,
                                         fresh_client_adapters, get_path,
                                         register_aggregator, set_path)


@register_aggregator("flora")
class FloraAggregator(Aggregator):
    """Streaming stacker: per-leaf lists of (scale-folded B, weighted A)
    blocks, concatenated once at finalize — O(Σ r_k) per leaf, which is the
    size of the broadcast stack itself."""

    def _accumulate(self, update: Dict, weight: float, rank: int) -> None:
        for path in adapter_leaf_paths(update):
            Bk, Ak = fold_scale(get_path(update, path))
            acc = self._state.setdefault(path, {"A": [], "B": []})
            acc["B"].append(Bk)
            acc["A"].append(weight * Ak)

    def _finalize(self) -> AggResult:
        out: Dict = {}
        rank_rec: Dict[Tuple, List[int]] = {}
        for path, acc in self._state.items():
            B_stack = jnp.concatenate(acc["B"], axis=-1)
            A_stack = jnp.concatenate(acc["A"], axis=-2)
            set_path(out, path, {"A": A_stack, "B": B_stack,
                                 "scale": self._ref_scales[path]})
            L = A_stack.shape[0] if A_stack.ndim == 3 else 1
            rank_rec[path] = [A_stack.shape[-2]] * L
        return AggResult(self.name, out, None, rank_rec, {},
                         merge_into_base=True)

    def client_init(self, global_state: Optional[AggResult], rank: int,
                    a_init_full: Dict) -> Dict:
        # the stack was merged into the base; adapters restart every round
        return fresh_client_adapters(a_init_full, rank)

    def server_flops(self, dims, client_ranks, agg_ranks=None) -> int:
        return 0                          # pure concatenation
