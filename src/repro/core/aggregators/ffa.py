"""FFA-LoRA: A frozen at the shared init, only B trained/uploaded/averaged."""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.aggregators.base import (AggResult, Aggregator,
                                         adapter_leaf_paths, fold_scale,
                                         get_path, register_aggregator,
                                         set_path)


@register_aggregator("ffa")
class FfaAggregator(Aggregator):
    """Streaming B-average: one running weighted B sum per leaf (A never
    travels — the server re-reads it from the frozen shared init)."""

    trains_b_only = True
    needs_a_init = True
    # only one of the two matrices is broadcast -> rank counts half in the
    # paper's efficiency denominator
    download_rank_factor = 0.5
    _STATE_FIELDS = ("_seen_ranks",)

    def __init__(self, A_init: Optional[Dict] = None,
                 zero_padding: bool = False):
        self.A_init = A_init
        self.zero_padding = zero_padding
        super().__init__()

    def _reset(self) -> None:
        super()._reset()
        self._seen_ranks: Dict[Tuple, set] = {}

    def wire_arrays(self, leaf: Dict):
        return {"B": leaf["B"]}          # A frozen, never on the wire

    def client_upload_params(self, leaf: Dict) -> int:
        return leaf["B"].size            # A frozen, never sent

    def _accumulate(self, update: Dict, weight: float, rank: int) -> None:
        for path in adapter_leaf_paths(update):
            Bk, _ = fold_scale(get_path(update, path))
            seen = self._seen_ranks.setdefault(path, set())
            seen.add(Bk.shape[-1])
            if len(seen) > 1 and not self.zero_padding:
                raise ValueError(
                    "FFA-LoRA requires homogeneous ranks (or zero_padding=True)")
            acc = self._state.get(path)
            if acc is None:
                self._state[path] = weight * Bk
                continue
            R = max(acc.shape[-1], Bk.shape[-1])
            if acc.shape[-1] < R:
                pad = [(0, 0)] * acc.ndim
                pad[-1] = (0, R - acc.shape[-1])
                acc = jnp.pad(acc, pad)
            if Bk.shape[-1] < R:
                pad = [(0, 0)] * Bk.ndim
                pad[-1] = (0, R - Bk.shape[-1])
                Bk = jnp.pad(Bk, pad)
            self._state[path] = acc + weight * Bk

    def _finalize(self) -> AggResult:
        if self.A_init is None:
            raise ValueError("ffa aggregator needs A_init (the frozen shared "
                             "init) to rebuild global adapters")
        out: Dict = {}
        rank_rec: Dict[Tuple, List[int]] = {}
        for path, B_avg in self._state.items():
            R = B_avg.shape[-1]
            a0 = get_path(self.A_init, path)
            A = a0["A"]
            r0 = A.shape[-2]
            if r0 < R:
                pad = [(0, 0)] * A.ndim
                pad[-2] = (0, R - r0)
                A = jnp.pad(A, pad)
            elif r0 > R:
                A = A[..., :R, :]
            set_path(out, path, {"A": A, "B": B_avg,
                                 "scale": self._ref_scales[path]})
            L = B_avg.shape[0] if B_avg.ndim == 3 else 1
            # only B travels; rank-equivalent download is R/2 per the paper's
            # half-parameter accounting (download_rank_factor above)
            rank_rec[path] = [R] * L
        return AggResult(self.name, out, None, rank_rec, {})

    # -- client-init: A stays at the frozen init ----------------------------
    def client_init(self, global_state: Optional[AggResult], rank: int,
                    a_init_full: Dict) -> Dict:
        from repro.peft.lora import match_rank

        g = super().client_init(global_state, rank, a_init_full)
        if global_state is None:
            return g
        a_init = match_rank(a_init_full, rank)

        def fix(path, gl):
            last = getattr(path[-1], "key", None)
            if last == "A":
                node = a_init
                for kk in [getattr(k, "key", getattr(k, "idx", None))
                           for k in path]:
                    node = node[kk]
                return node
            return gl

        return jax.tree_util.tree_map_with_path(fix, g)

    # -- cost model ----------------------------------------------------------
    def download_params(self, agg: AggResult, dims: Dict, num_clients: int,
                        client_ranks) -> int:
        total = 0
        for path, (L, n, m) in dims.items():
            for r_l in agg.ranks[path]:
                total += num_clients * r_l * m        # only B broadcast
        return total

    def server_flops(self, dims, client_ranks, agg_ranks=None) -> int:
        K, R = len(client_ranks), max(client_ranks)
        return sum(L * 2 * K * R * m for (L, n, m) in dims.values())
