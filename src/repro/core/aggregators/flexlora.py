"""FlexLoRA: form the dense ΔW = Σ w_k B_k A_k per layer, full SVD, then
cut per-client adapters at each client's own rank."""
from __future__ import annotations

from typing import Dict, List, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.aggregators.base import (AggResult, Aggregator,
                                         adapter_leaf_paths, fold_scale,
                                         get_path, per_layer,
                                         register_aggregator, set_path)
from repro.core.svd import thin_svd


@register_aggregator("flexlora")
class FlexLoRAAggregator(Aggregator):
    """Streaming dense accumulation: one running ΔW sum per (leaf, layer) —
    O(L·m·n) per leaf but O(1) in the client count; the SVD + per-client
    truncation happen once at finalize."""

    def _accumulate(self, update: Dict, weight: float, rank: int) -> None:
        for path in adapter_leaf_paths(update):
            Bk, Ak = fold_scale(get_path(update, path))
            stacked = Ak.ndim == 3
            L = Ak.shape[0] if stacked else 1
            acc = self._state.setdefault(
                path, {"stacked": stacked, "dw": [None] * L})
            for l in range(L):
                Bl = per_layer(Bk, l, stacked)
                Al = per_layer(Ak, l, stacked)
                term = weight * (Bl.astype(jnp.float32) @ Al.astype(jnp.float32))
                acc["dw"][l] = term if acc["dw"][l] is None \
                    else acc["dw"][l] + term

    def _finalize(self) -> AggResult:
        per_client: List[Dict] = [{} for _ in range(self.num_clients)]
        glob: Dict = {}
        rank_rec: Dict[Tuple, List[int]] = {}
        spectra: Dict[Tuple, List[np.ndarray]] = {}
        Rmax = max(self.client_ranks)
        for path, acc in self._state.items():
            stacked = acc["stacked"]
            ub_l, sp_l, vt_l = [], [], []
            for dw in acc["dw"]:
                u, s, vt = thin_svd(dw, "svd")
                ub_l.append(u)
                sp_l.append(s)
                vt_l.append(vt)
            spectra[path] = [np.asarray(s) for s in sp_l]
            rank_rec[path] = [min(Rmax, int(s.shape[0])) for s in sp_l]
            # global (exact) adapters at full rank — used for server-side eval
            r_full = sp_l[0].shape[0]
            Bg = jnp.stack([u * s[None, :] for u, s in zip(ub_l, sp_l)]) \
                if stacked else ub_l[0] * sp_l[0][None, :]
            Ag = jnp.stack(vt_l) if stacked else vt_l[0]
            ref = self._ref_scales[path]
            set_path(glob, path, {"A": Ag, "B": Bg, "scale": ref})
            # per-client truncations
            for ci, rk in enumerate(self.client_ranks):
                rr = min(rk, r_full)
                if stacked:
                    Bc = jnp.stack([u[:, :rr] * s[None, :rr]
                                    for u, s in zip(ub_l, sp_l)])
                    Ac = jnp.stack([vt[:rr] for vt in vt_l])
                else:
                    Bc = ub_l[0][:, :rr] * sp_l[0][None, :rr]
                    Ac = vt_l[0][:rr]
                if rr < rk:   # pad up to the client's rank
                    padB = [(0, 0)] * Bc.ndim
                    padB[-1] = (0, rk - rr)
                    padA = [(0, 0)] * Ac.ndim
                    padA[-2] = (0, rk - rr)
                    Bc, Ac = jnp.pad(Bc, padB), jnp.pad(Ac, padA)
                set_path(per_client[ci], path,
                         {"A": Ac, "B": Bc, "scale": ref})
        return AggResult(self.name, glob, per_client, rank_rec, spectra)

    # -- cost model ----------------------------------------------------------
    def download_params(self, agg: AggResult, dims: Dict, num_clients: int,
                        client_ranks) -> int:
        # each client gets its own rank-r_k adapters
        total = 0
        for rk in client_ranks:
            for path, (L, n, m) in dims.items():
                total += L * rk * (n + m)
        return total

    def server_flops(self, dims, client_ranks, agg_ranks=None) -> int:
        from repro.core.costs import SVD_CONST

        r = sum(client_ranks)                       # stacked rank
        total = 0
        for path, (L, n, m) in dims.items():
            p = min(m, n)
            total += L * (2 * m * n * r               # form ΔW
                          + SVD_CONST * m * n * p     # dense SVD
                          + 2 * (m * p * p + p * p * n))  # partition/rescale
        return total

    def efficiency(self, agg: AggResult, client_ranks=(), dims=None) -> float:
        # each client downloads its own rank-r_k adapters -> mean over clients
        L_total = sum(L for (L, _, _) in dims.values()) if dims else 1
        return 1.0 / max(1.0, L_total * float(np.mean(client_ranks)))
