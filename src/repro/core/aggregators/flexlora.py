"""FlexLoRA: form the dense ΔW = Σ w_k B_k A_k per layer, full SVD, then
cut per-client adapters at each client's own rank."""
from __future__ import annotations

from typing import Dict, List, Tuple

import jax.numpy as jnp
import numpy as np

import jax

from repro.core.aggregators.base import (AggResult, Aggregator,
                                         adapter_leaf_paths, fold_scale,
                                         get_path, register_aggregator,
                                         set_path)
from repro.core.svd import thin_svd_batched


@register_aggregator("flexlora")
class FlexLoRAAggregator(Aggregator):
    """Streaming dense accumulation: one running ΔW sum per leaf, held as a
    single (L, m, n) array — O(L·m·n) per leaf but O(1) in the client
    count.  Finalize runs ONE compiled vmapped SVD over all layers of a
    leaf (no per-layer Python loop) and one device→host transfer for the
    spectra; the per-client truncation happens on the device arrays."""

    def _accumulate(self, update: Dict, weight: float, rank: int) -> None:
        for path in adapter_leaf_paths(update):
            Bk, Ak = fold_scale(get_path(update, path))
            stacked = Ak.ndim == 3
            if not stacked:
                Bk, Ak = Bk[None], Ak[None]
            term = weight * jnp.einsum("lmr,lrn->lmn",
                                       Bk.astype(jnp.float32),
                                       Ak.astype(jnp.float32))
            acc = self._state.setdefault(path, {"stacked": stacked,
                                                "dw": None})
            acc["dw"] = term if acc["dw"] is None else acc["dw"] + term

    def _finalize(self) -> AggResult:
        per_client: List[Dict] = [{} for _ in range(self.num_clients)]
        glob: Dict = {}
        rank_rec: Dict[Tuple, List[int]] = {}
        spectra: Dict[Tuple, List[np.ndarray]] = {}
        Rmax = max(self.client_ranks)
        device: Dict[Tuple, Tuple] = {}
        for path, acc in self._state.items():
            # all L layer SVDs of the leaf in one compiled call
            device[path] = thin_svd_batched(acc["dw"], "svd")   # (L,m,k) ...
        host = jax.device_get({p: v.s for p, v in device.items()})
        for path, (ub, sp, vt) in device.items():
            stacked = self._state[path]["stacked"]
            sp_host = host[path]                                # (L, k)
            spectra[path] = [np.asarray(s) for s in sp_host]
            r_full = int(sp_host.shape[1])
            rank_rec[path] = [min(Rmax, r_full)] * sp_host.shape[0]
            # global (exact) adapters at full rank — used for server-side eval
            Bg = ub * sp[:, None, :]
            Ag = vt
            if not stacked:
                Bg, Ag = Bg[0], Ag[0]
            ref = self._ref_scales[path]
            set_path(glob, path, {"A": Ag, "B": Bg, "scale": ref})
            # per-client truncations
            for ci, rk in enumerate(self.client_ranks):
                rr = min(rk, r_full)
                Bc = ub[:, :, :rr] * sp[:, None, :rr]
                Ac = vt[:, :rr, :]
                if not stacked:
                    Bc, Ac = Bc[0], Ac[0]
                if rr < rk:   # pad up to the client's rank
                    padB = [(0, 0)] * Bc.ndim
                    padB[-1] = (0, rk - rr)
                    padA = [(0, 0)] * Ac.ndim
                    padA[-2] = (0, rk - rr)
                    Bc, Ac = jnp.pad(Bc, padB), jnp.pad(Ac, padA)
                set_path(per_client[ci], path,
                         {"A": Ac, "B": Bc, "scale": ref})
        return AggResult(self.name, glob, per_client, rank_rec, spectra)

    # -- cost model ----------------------------------------------------------
    def download_params(self, agg: AggResult, dims: Dict, num_clients: int,
                        client_ranks) -> int:
        # each client gets its own rank-r_k adapters
        total = 0
        for rk in client_ranks:
            for path, (L, n, m) in dims.items():
                total += L * rk * (n + m)
        return total

    def server_flops(self, dims, client_ranks, agg_ranks=None) -> int:
        from repro.core.costs import SVD_CONST

        r = sum(client_ranks)                       # stacked rank
        total = 0
        for path, (L, n, m) in dims.items():
            p = min(m, n)
            total += L * (2 * m * n * r               # form ΔW
                          + SVD_CONST * m * n * p     # dense SVD
                          + 2 * (m * p * p + p * p * n))  # partition/rescale
        return total

    def efficiency(self, agg: AggResult, client_ranks=(), dims=None) -> float:
        # each client downloads its own rank-r_k adapters -> mean over clients
        L_total = sum(L for (L, _, _) in dims.values()) if dims else 1
        return 1.0 / max(1.0, L_total * float(np.mean(client_ranks)))
