"""Multi-pod / sharded server aggregation.

The FLoRIST server pipeline is embarrassingly parallel over (layer ×
projection).  This module maps it onto the production mesh with
``shard_map``: each device owns a slice of layers, runs the stacked-SVD +
core-SVD + threshold locally (jit-safe padded variant), and only the
per-layer kept-rank counters are exchanged (an ``all_gather`` of L int32s —
the *algorithm's* download traffic is the rank-p adapters themselves, which
stay sharded until broadcast).

This is the TPU-native replacement for the paper's single-server NumPy/Torch
aggregation loop (DESIGN.md §3): thin SVDs become Gram-matmuls + small eigh
per layer shard; no cross-device traffic during the math.
"""
from __future__ import annotations

from functools import partial
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.common.pjit_utils import shard_map as _shard_map

from repro.core.aggregators import AggResult, register_aggregator, set_path
from repro.core.aggregators.florist import FloristAggregator
from repro.core.svd import florist_core_delta_padded, florist_core_padded


def florist_aggregate_batched(B_stacks: jnp.ndarray, A_stacks: jnp.ndarray,
                              tau, svd_method: str = "svd",
                              max_rank: int = 0):
    """vmapped padded FLoRIST core over a layer axis (the same core the
    host-side batched pipeline jits via ``florist_core_batched``; un-jitted
    here because ``shard_map`` wraps it).

    B_stacks: (L, m, r), A_stacks: (L, r, n) — already weighted/stacked.
    Returns (B_g (L,m,r) zero-padded beyond p_l, A_g (L,r,n), spectra (L,r),
    ranks (L,) int32).
    """
    fn = partial(florist_core_padded, tau=tau, svd_method=svd_method,
                 max_rank=max_rank)
    return jax.vmap(lambda b, a: fn(b, a))(B_stacks, A_stacks)


def pad_layers(x: jnp.ndarray, mult: int) -> Tuple[jnp.ndarray, int]:
    L = x.shape[0]
    pad = (-L) % mult
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad,) + x.shape[1:], x.dtype)])
    return x, L


def make_sharded_florist(mesh: Mesh, tau, svd_method: str = "gram",
                         max_rank: int = 0):
    """jit'd sharded aggregation: layers sharded over the 'model' axis.

    Returns fn(B_stacks (L,m,r), A_stacks (L,r,n)) ->
    (B_g, A_g, spectra, ranks) with L padded to the axis size internally.
    ``tau`` / ``max_rank`` semantics match the host pipeline exactly
    (including ``tau="auto"`` and the rank cap, applied inside the traced
    core so the kept columns are the capped truncation, not a post-hoc
    clamp).
    """
    n_shard = dict(zip(mesh.axis_names, mesh.devices.shape))["model"]

    def local(bs, as_):
        # bs: (L/n, m, r) local slice
        bg, ag, sp, p = florist_aggregate_batched(bs, as_, tau, svd_method,
                                                  max_rank)
        return bg, ag, sp, p

    sharded = _shard_map(
        local, mesh=mesh,
        in_specs=(P("model"), P("model")),
        out_specs=(P("model"), P("model"), P("model"), P("model")),
    )

    @jax.jit
    def run(B_stacks, A_stacks):
        Bp, L = pad_layers(B_stacks, n_shard)
        Ap, _ = pad_layers(A_stacks, n_shard)
        # guard padded layers against singular zero matrices
        eye_bump = 1e-6
        Bp = Bp.at[L:].add(eye_bump) if Bp.shape[0] > L else Bp
        bg, ag, sp, p = sharded(Bp, Ap)
        return bg[:L], ag[:L], sp[:L], p[:L]

    return run


def make_sharded_florist_delta(mesh: Mesh, tau, svd_method: str = "gram",
                               max_rank: int = 0):
    """Layer-sharded delta-mode finalize: fn(M (L, m, n)) ->
    (B_g, A_g, spectra, ranks) — the streaming server's compact dense
    intermediate SVD'd in place, layers sharded over 'model'."""
    n_shard = dict(zip(mesh.axis_names, mesh.devices.shape))["model"]

    def local(ms):
        fn = partial(florist_core_delta_padded, tau=tau,
                     svd_method=svd_method, max_rank=max_rank)
        return jax.vmap(fn)(ms)

    sharded = _shard_map(
        local, mesh=mesh,
        in_specs=(P("model"),),
        out_specs=(P("model"), P("model"), P("model"), P("model")),
    )

    @jax.jit
    def run(M):
        Mp, L = pad_layers(M, n_shard)
        eye_bump = 1e-6
        Mp = Mp.at[L:].add(eye_bump) if Mp.shape[0] > L else Mp
        bg, ag, sp, p = sharded(Mp)
        return bg[:L], ag[:L], sp[:L], p[:L]

    return run


@register_aggregator("florist_sharded")
class ShardedFloristAggregator(FloristAggregator):
    """FLoRIST with the finalize step mapped onto a device mesh.

    Streaming accumulation (``add_client``) is identical to the host-side
    ``florist`` strategy; ``finalize`` runs the layer-sharded jit'd pipeline
    instead of the per-layer Python loop.  Registered as
    ``"florist_sharded"`` — an example of a backend variant plugging into
    the aggregation registry without touching the trainer or the cost
    accounting (both are inherited).
    """

    def __init__(self, tau=0.9, svd_method: str = "gram",
                 mesh: Optional[Mesh] = None, max_rank: int = 0,
                 stream: str = "auto", flush_every: int = 64):
        if mesh is None:
            mesh = Mesh(np.asarray(jax.devices()), ("model",))
        self.mesh = mesh
        self._fn_cache: Dict = {}
        super().__init__(tau=tau, svd_method=svd_method, max_rank=max_rank,
                         stream=stream, flush_every=flush_every)

    def _finalize(self) -> AggResult:
        if "fn" not in self._fn_cache:
            self._fn_cache["fn"] = make_sharded_florist(
                self.mesh, tau=self.tau, svd_method=self.svd_method,
                max_rank=self.max_rank)
            self._fn_cache["delta"] = make_sharded_florist_delta(
                self.mesh, tau=self.tau, svd_method=self.svd_method,
                max_rank=self.max_rank)
        device: Dict[Tuple, Tuple] = {}
        for path, inter in self._settle().items():
            if inter[0] == "stack":
                device[path] = self._fn_cache["fn"](inter[1], inter[2])
            else:
                device[path] = self._fn_cache["delta"](inter[1])
        # _materialize does the single device→host transfer + exact
        # truncation of the zero-padded columns
        return self._materialize(device)
