"""Multi-pod / sharded server aggregation.

The FLoRIST server pipeline is embarrassingly parallel over (layer ×
projection).  This module maps it onto the production mesh with
``shard_map``: each device owns a slice of layers, runs the stacked-SVD +
core-SVD + threshold locally (jit-safe padded variant), and only the
per-layer kept-rank counters are exchanged (an ``all_gather`` of L int32s —
the *algorithm's* download traffic is the rank-p adapters themselves, which
stay sharded until broadcast).

This is the TPU-native replacement for the paper's single-server NumPy/Torch
aggregation loop (DESIGN.md §3): thin SVDs become Gram-matmuls + small eigh
per layer shard; no cross-device traffic during the math.
"""
from __future__ import annotations

from functools import partial
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.common.pjit_utils import shard_map as _shard_map

from repro.core.aggregators import AggResult, register_aggregator, set_path
from repro.core.aggregators.florist import FloristAggregator
from repro.core.svd import florist_core_padded


def florist_aggregate_batched(B_stacks: jnp.ndarray, A_stacks: jnp.ndarray,
                              tau, svd_method: str = "svd",
                              max_rank: int = 0):
    """vmapped padded FLoRIST core over a layer axis (the same core the
    host-side batched pipeline jits via ``florist_core_batched``; un-jitted
    here because ``shard_map`` wraps it).

    B_stacks: (L, m, r), A_stacks: (L, r, n) — already weighted/stacked.
    Returns (B_g (L,m,r) zero-padded beyond p_l, A_g (L,r,n), spectra (L,r),
    ranks (L,) int32).
    """
    fn = partial(florist_core_padded, tau=tau, svd_method=svd_method,
                 max_rank=max_rank)
    return jax.vmap(lambda b, a: fn(b, a))(B_stacks, A_stacks)


def pad_layers(x: jnp.ndarray, mult: int) -> Tuple[jnp.ndarray, int]:
    L = x.shape[0]
    pad = (-L) % mult
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad,) + x.shape[1:], x.dtype)])
    return x, L


def make_sharded_florist(mesh: Mesh, tau, svd_method: str = "gram",
                         max_rank: int = 0):
    """jit'd sharded aggregation: layers sharded over the 'model' axis.

    Returns fn(B_stacks (L,m,r), A_stacks (L,r,n)) ->
    (B_g, A_g, spectra, ranks) with L padded to the axis size internally.
    ``tau`` / ``max_rank`` semantics match the host pipeline exactly
    (including ``tau="auto"`` and the rank cap, applied inside the traced
    core so the kept columns are the capped truncation, not a post-hoc
    clamp).
    """
    n_shard = dict(zip(mesh.axis_names, mesh.devices.shape))["model"]

    def local(bs, as_):
        # bs: (L/n, m, r) local slice
        bg, ag, sp, p = florist_aggregate_batched(bs, as_, tau, svd_method,
                                                  max_rank)
        return bg, ag, sp, p

    sharded = _shard_map(
        local, mesh=mesh,
        in_specs=(P("model"), P("model")),
        out_specs=(P("model"), P("model"), P("model"), P("model")),
    )

    @jax.jit
    def run(B_stacks, A_stacks):
        Bp, L = pad_layers(B_stacks, n_shard)
        Ap, _ = pad_layers(A_stacks, n_shard)
        # guard padded layers against singular zero matrices
        eye_bump = 1e-6
        Bp = Bp.at[L:].add(eye_bump) if Bp.shape[0] > L else Bp
        bg, ag, sp, p = sharded(Bp, Ap)
        return bg[:L], ag[:L], sp[:L], p[:L]

    return run


@register_aggregator("florist_sharded")
class ShardedFloristAggregator(FloristAggregator):
    """FLoRIST with the finalize step mapped onto a device mesh.

    Streaming accumulation (``add_client``) is identical to the host-side
    ``florist`` strategy; ``finalize`` runs the layer-sharded jit'd pipeline
    instead of the per-layer Python loop.  Registered as
    ``"florist_sharded"`` — an example of a backend variant plugging into
    the aggregation registry without touching the trainer or the cost
    accounting (both are inherited).
    """

    def __init__(self, tau=0.9, svd_method: str = "gram",
                 mesh: Optional[Mesh] = None, max_rank: int = 0):
        if mesh is None:
            mesh = Mesh(np.asarray(jax.devices()), ("model",))
        self.mesh = mesh
        self._fn_cache: Dict = {}
        super().__init__(tau=tau, svd_method=svd_method, max_rank=max_rank)

    def _finalize(self) -> AggResult:
        out: Dict = {}
        rank_rec: Dict[Tuple, List[int]] = {}
        spectra: Dict[Tuple, List[np.ndarray]] = {}
        if "fn" not in self._fn_cache:
            self._fn_cache["fn"] = make_sharded_florist(
                self.mesh, tau=self.tau, svd_method=self.svd_method,
                max_rank=self.max_rank)
        fn = self._fn_cache["fn"]
        device: Dict[Tuple, Tuple] = {}
        for path, (B_stack, A_stack) in self._leaf_stacks().items():
            device[path] = fn(B_stack, A_stack)
        # one device→host transfer for all leaves' spectra + ranks
        host = jax.device_get({p: (v[2], v[3]) for p, v in device.items()})
        for path, (Bg, Ag, _, _) in device.items():
            sp_h, p_h = host[path]
            ps = [int(x) for x in p_h]
            p_max = max(ps)
            # zeroed columns beyond each layer's p_l make truncation to the
            # per-leaf max exact (same ΔW, scan-compatible tree)
            Bg, Ag = Bg[:, :, :p_max], Ag[:, :p_max, :]
            if not self._state[path]["stacked"]:
                Bg, Ag = Bg[0], Ag[0]
            set_path(out, path, {"A": Ag, "B": Bg,
                                 "scale": self._ref_scales[path]})
            rank_rec[path] = ps
            spectra[path] = [np.asarray(s) for s in sp_h]
        return AggResult(self.name, out, None, rank_rec, spectra)
