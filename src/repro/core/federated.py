"""Federated fine-tuning orchestration (paper §4.1 setup).

Simulates the full loop: 100 clients with Dirichlet(0.5) non-IID data, 10
sampled per round, local LoRA fine-tuning, server aggregation and
global-model evaluation — composed from four pluggable seams
(:mod:`repro.core.runtime`):

* a **RoundScheduler** decides who participates (``scheduler=``: ``sync``
  reproduces the paper's sample-K-wait-for-all semantics bit-for-bit;
  ``partial`` injects dropouts/stragglers with per-client step budgets;
  ``async`` buffers staleness-discounted arrivals; ``sampled`` draws a
  seed-deterministic participation fraction of the full population);
* a **RankPolicy** (``rank_policy=``: ``static`` / ``resource``) may then
  adapt each task's LoRA rank to a declared client resource profile
  (AFLoRA-style) before training starts;
* a **ClientRunner** executes local fine-tuning (``runner=``:
  ``sequential`` is the legacy one-client-at-a-time loop; ``cohort``
  trains each equal-rank cohort in one jitted vmapped train-step call;
  ``sharded_cohort`` additionally shards the cohort's client axis over the
  fed mesh's ``data`` axis — 1024 clients in a handful of compiled calls);
* a **Transport** puts every exchanged adapter tree on a measured wire
  (``transport=`` codec: ``fp32`` exact / ``bf16`` / ``int8``), so each
  :class:`RoundRecord` carries real serialized ``upload_bytes`` /
  ``download_bytes`` next to the analytic parameter counts — with
  ``dp_clip``/``dp_sigma`` set, uploads are clipped/noised on the wire
  (local DP) before encoding, whatever the codec;
* an **Aggregator** owns the method semantics (client re-init, frozen-A
  composition, base merging, truncation, cost formulas) — pass
  ``aggregator=`` for a custom strategy, otherwise one is built from
  ``fed.method`` via the registry.

The server side is **streaming**: each delivered client update is folded
into the aggregator's running accumulators (``add_client``) and dropped
before the next arrives, so peak server memory per round is one client's
adapters plus the O(Σ r_k) per-leaf accumulators — never all K sampled
adapter trees at once.
"""
from __future__ import annotations

import dataclasses
import functools
import os
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import io as ckpt_io
from repro.common.config import FedConfig, LoRAConfig, ModelConfig, OptimConfig
from repro.core.aggregators import (AggResult, Aggregator, accepted_config,
                                    make_aggregator)
from repro.core.runtime import (ClientRunner, DeadClientError, RankPolicy,
                                RoundScheduler, ServerCrash, Transport,
                                ValidationGate, make_rank_policy, make_runner,
                                make_scheduler, make_transport,
                                make_validator)
from repro.data.synthetic import ClientDataset, make_eval_data, make_federated_data
from repro.models import transformer as T
from repro.peft.lora import init_lora, merge_lora
from repro.train.step import make_eval_step, make_train_step


# jit'd step factories shared across trainer instances: configs are frozen
# (hashable) dataclasses, and jax.jit re-specializes per input shape, so a
# sweep over τ / methods / seeds compiles each (config, shapes) program once
# instead of once per FederatedTrainer.
@functools.lru_cache(maxsize=None)
def _cached_train_step(cfg: ModelConfig, optim: OptimConfig, loss_chunk: int,
                       b_only: bool):
    return jax.jit(make_train_step(cfg, optim, remat=False,
                                   loss_chunk=loss_chunk, b_only=b_only))


@functools.lru_cache(maxsize=None)
def _cached_eval_step(cfg: ModelConfig, loss_chunk: int):
    return jax.jit(make_eval_step(cfg, loss_chunk=loss_chunk))


@dataclasses.dataclass
class RoundRecord:
    round: int
    eval_loss: float
    eval_acc: float
    upload_params: int
    download_params: int
    download_rank: float
    global_rank_total: int
    upload_bytes: int = 0        # measured serialized uplink (all clients)
    download_bytes: int = 0      # measured serialized downlink (all clients)
    wall_secs: float = 0.0       # wall-clock of the whole round
    # -- fault-tolerance counters (PR 10) -----------------------------------
    retries: int = 0             # uplink re-sends after verification failure
    dead_clients: int = 0        # dropped uploads + retry-exhausted clients
    rejected: int = 0            # gate rejections (non-finite/shape/dup)
    quarantined: int = 0         # norm-outlier quarantines (full mode)
    quorum_met: bool = True      # round reached min_clients accepted updates
    resumes: int = 0             # 1 on the first round after --resume
    sim_secs: float = 0.0        # simulated time (backoff + slow clients)


class FederatedTrainer:
    """Thin composition of runner + scheduler + aggregator + transport.

    ``runner`` / ``scheduler`` / ``transport`` accept either a registered
    name (``"sequential"``, ``"sync"``, codec ``"fp32"``, ...) or an
    instance, so behaviours can be configured or injected.  The defaults
    reproduce the pre-runtime ``run_round`` bit-for-bit.
    """

    def __init__(self, cfg: ModelConfig, fed: FedConfig, lora: LoRAConfig,
                 optim: OptimConfig, clients: Optional[List[ClientDataset]] = None,
                 eval_data: Optional[Dict] = None, batch_size: int = 8,
                 local_steps: int = 4, seq_len: int = 64, svd_method: str = "svd",
                 targets: Optional[tuple] = None,
                 dp_clip: float = 0.0, dp_sigma: float = 0.0,
                 aggregator: Optional[Aggregator] = None,
                 runner: Any = "sequential",
                 scheduler: Any = "sync",
                 rank_policy: Any = "static",
                 transport: Any = "fp32",
                 faults: Any = None,
                 validation: Any = "screen",
                 min_clients: int = 1):
        self.cfg, self.fed, self.lora, self.optim = cfg, fed, lora, optim
        self.batch_size, self.local_steps = batch_size, local_steps
        self.svd_method = svd_method
        # client-level differential privacy, applied on the wire by the
        # transport's uplink DP stage (see core/runtime/transport)
        self.dp_clip, self.dp_sigma = dp_clip, dp_sigma
        # deterministic fault injection (None: healthy world) and the
        # validation gate screening every fold (see core/runtime/faults,
        # core/runtime/validation)
        self.faults = faults
        self.gate: ValidationGate = make_validator(
            validation, min_clients=min_clients)
        self.rng = np.random.default_rng(fed.seed)
        key = jax.random.PRNGKey(fed.seed)
        kp, ka = jax.random.split(key)
        self.params = T.init(cfg, kp)
        self.targets = targets or lora.targets
        self.client_ranks = fed.client_ranks()
        self.max_rank = max(self.client_ranks)
        # one shared init at max rank; client k uses its first r_k rows
        self.A_init_full = init_lora(self.params, self.targets, self.max_rank,
                                     float(self.max_rank), ka)
        self.aggregator = aggregator if aggregator is not None else \
            make_aggregator(fed.method, **accepted_config(fed.method, dict(
                tau=fed.tau, svd_method=svd_method,
                zero_padding=fed.zero_padding)))
        # strategies that declare needs_a_init (FFA-style) are handed the
        # frozen shared init explicitly; everything else is left untouched
        if getattr(self.aggregator, "needs_a_init", False) \
                and getattr(self.aggregator, "A_init", None) is None:
            self.aggregator.A_init = self.A_init_full
        self.runner: ClientRunner = make_runner(runner)
        self.scheduler: RoundScheduler = make_scheduler(scheduler)
        self.rank_policy: RankPolicy = make_rank_policy(rank_policy)
        self.transport: Transport = make_transport(
            transport, dp_clip=dp_clip, dp_sigma=dp_sigma, dp_seed=fed.seed,
            fault_plan=faults)
        self.global_state: Optional[AggResult] = None
        self.clients = clients if clients is not None else make_federated_data(
            num_clients=fed.num_clients, seq_len=seq_len,
            vocab=cfg.vocab_size, alpha=fed.dirichlet_alpha, seed=fed.seed)
        ev = eval_data if eval_data is not None else make_eval_data(
            seq_len=seq_len, vocab=cfg.vocab_size)
        self.eval_batch = {k: jnp.asarray(v) for k, v in ev.items()}
        self._eval = _cached_eval_step(cfg, seq_len)
        self.history: List[RoundRecord] = []
        self._pending_resumes = 0    # stamped into the first post-resume record

    # -- helpers -------------------------------------------------------------
    def _train_step(self):
        # rank only affects adapter shapes; jit re-specializes on those, so
        # all ranks share one cached wrapper per (cfg, optim, b_only)
        return _cached_train_step(self.cfg, self.optim, 64,
                                  self.aggregator.trains_b_only)

    def _client_init(self, k: int, rank: Optional[int] = None) -> Dict:
        """Build client k's starting adapters for this round (delegated to
        the aggregation strategy's client-init semantics).  ``rank``
        overrides the client's configured rank when a rank policy adapted
        this round's task."""
        return self.aggregator.client_init(
            self.global_state,
            self.client_ranks[k] if rank is None else rank,
            self.A_init_full)

    def _maybe_crash(self, rnd: int, point: str) -> None:
        if self.faults is not None and self.faults.should_crash(rnd, point):
            raise ServerCrash(rnd, point)

    # -- main loop ------------------------------------------------------------
    def run_round(self, rnd: int) -> RoundRecord:
        t0 = time.perf_counter()
        self._maybe_crash(rnd, "begin")
        clock = self.transport.clock
        sim0 = clock.now if clock is not None else 0.0
        self.transport.reset_stats()
        plan = self.scheduler.plan(rnd, self)
        self.rank_policy.assign(rnd, plan, self)
        ranks = [t.rank for t in plan.tasks]
        self.aggregator.begin_round()
        self.gate.begin_round(self.aggregator)
        upload_bytes = 0
        delivered = 0
        dropped = 0
        mid_crash_at = max(1, len(plan.tasks) // 2)

        def deliver(task, adapters, init_adapters=None):
            # uplink through the measured wire (DP clip/noise happens there,
            # against the round's init), then through the validation gate
            # into the server accumulators; the trained adapters go out of
            # scope here (no K-tree round buffer)
            nonlocal upload_bytes, delivered, dropped
            delivered += 1
            fault = (self.faults.client_fault(rnd, task.client_id)
                     if self.faults is not None else None)
            try:
                if fault is not None:
                    if fault.kind == "drop":
                        dropped += 1
                        return
                    if fault.kind == "slow" and clock is not None:
                        clock.advance(fault.delay)
                    adapters = self.faults.poison(adapters, init_adapters,
                                                  rnd, task.client_id)
                adapters, nbytes = self.transport.client_to_server(
                    adapters, self.aggregator, init_adapters=init_adapters,
                    rnd=rnd, client_id=task.client_id)
                upload_bytes += nbytes
                self.gate.submit(task, adapters, task.weight, rank=task.rank,
                                 init_adapters=init_adapters)
                if fault is not None and fault.kind == "duplicate":
                    # at-least-once wire: the same upload arrives twice —
                    # the gate's dedup must fold it exactly once
                    self.gate.submit(task, adapters, task.weight,
                                     rank=task.rank,
                                     init_adapters=init_adapters)
            except DeadClientError:
                pass        # counted in transport stats; treated as a drop
            finally:
                if delivered == mid_crash_at:
                    self._maybe_crash(rnd, "mid_round")

        self.runner.run(self, plan, deliver)
        self._maybe_crash(rnd, "pre_finalize")
        gstats = self.gate.finish()
        tstats = self.transport.reset_stats()
        if not gstats.quorum_met or self.aggregator.num_clients == 0:
            return self._degraded_round(rnd, t0, sim0, gstats, tstats,
                                        upload_bytes, dropped)
        agg = self.aggregator.finalize()
        dims = self.aggregator.dims
        up = self.aggregator.round_upload_params
        # participation-aware downlink count: only clients actually handed
        # the model this round (async: dispatch-time snapshots)
        n_down = plan.downloads if plan.downloads is not None \
            else len(plan.tasks)
        down = self.aggregator.download_params(agg, dims, n_down, ranks)

        # downlink through the measured wire: what the clients resume from
        # next round is the decoded broadcast (identity under fp32)
        bcast, download_bytes = self.transport.server_to_clients(
            agg, self.aggregator, n_down)
        if agg.merge_into_base:
            # FLoRA: every *client* folds the broadcast stack into its base,
            # so the merge consumes the decoded wire tensors, codec included
            if bcast is not None:
                agg.global_adapters = bcast
            self.params = merge_lora(self.params, agg.global_adapters)
            eval_params = self.params
        else:
            # broadcast methods: the server evals its exact aggregate;
            # clients resume from the decoded broadcast
            eval_params = merge_lora(self.params, agg.global_adapters)
            if bcast is not None:
                agg.global_adapters = bcast
        self.global_state = agg

        m = self._eval(eval_params, None, self.eval_batch)
        rec = RoundRecord(
            round=rnd,
            eval_loss=float(m["loss"]),
            eval_acc=float(m["accuracy"]),
            upload_params=up,
            download_params=down,
            download_rank=agg.total_download_rank()
            * self.aggregator.download_rank_factor,
            global_rank_total=agg.total_download_rank(),
            upload_bytes=upload_bytes,
            download_bytes=download_bytes,
            wall_secs=time.perf_counter() - t0,
            retries=tstats.retries,
            dead_clients=tstats.dead_clients + dropped,
            rejected=gstats.rejected,
            quarantined=gstats.quarantined,
            quorum_met=True,
            resumes=self._pending_resumes,
            sim_secs=(clock.now - sim0) if clock is not None else 0.0,
        )
        self._pending_resumes = 0
        self.history.append(rec)
        self._maybe_crash(rnd, "post_round")
        return rec

    def _degraded_round(self, rnd: int, t0: float, sim0: float, gstats,
                        tstats, upload_bytes: int,
                        dropped: int = 0) -> RoundRecord:
        """Quorum failure: too few accepted updates to trust a fold.  The
        round degrades gracefully — the previous global state is kept (the
        half-filled accumulator is never finalized), clients will resume
        from the old broadcast, and the record carries the fault counters
        so the failure is visible in the history."""
        gs = self.global_state
        if gs is not None and gs.global_adapters is not None \
                and not gs.merge_into_base:
            eval_params = merge_lora(self.params, gs.global_adapters)
        else:
            eval_params = self.params
        m = self._eval(eval_params, None, self.eval_batch)
        clock = self.transport.clock
        rec = RoundRecord(
            round=rnd,
            eval_loss=float(m["loss"]),
            eval_acc=float(m["accuracy"]),
            upload_params=self.aggregator.round_upload_params,
            download_params=0,
            download_rank=0.0,
            global_rank_total=(gs.total_download_rank()
                               if gs is not None else 0),
            upload_bytes=upload_bytes,
            download_bytes=0,
            wall_secs=time.perf_counter() - t0,
            retries=tstats.retries,
            dead_clients=tstats.dead_clients + dropped,
            rejected=gstats.rejected,
            quarantined=gstats.quarantined,
            quorum_met=False,
            resumes=self._pending_resumes,
            sim_secs=(clock.now - sim0) if clock is not None else 0.0,
        )
        self._pending_resumes = 0
        self.history.append(rec)
        self._maybe_crash(rnd, "post_round")
        return rec

    # -- checkpoint / resume ---------------------------------------------------
    def state_dict(self, next_round: int) -> Dict[str, Any]:
        """Everything a fresh process needs to continue from ``next_round``
        bit-identically: base params, global state, the shared rng's exact
        bit-generator state, scheduler in-flight pools, the aggregator's
        streaming accumulators, and the full RoundRecord history."""
        gs = self.global_state
        return {
            "next_round": int(next_round),
            "rng": self.rng.bit_generator.state,
            "params": ckpt_io.to_host(self.params),
            "global_state": None if gs is None else {
                "method": gs.method,
                "global_adapters": ckpt_io.to_host(gs.global_adapters),
                "per_client": ckpt_io.to_host(gs.per_client),
                "ranks": gs.ranks,
                "spectra": ckpt_io.to_host(gs.spectra),
                "merge_into_base": gs.merge_into_base,
            },
            "scheduler": self.scheduler.state_dict(),
            "aggregator": self.aggregator.state_dict(),
            "history": [dataclasses.asdict(r) for r in self.history],
        }

    def load_state_dict(self, state: Dict[str, Any]) -> int:
        """Inverse of :meth:`state_dict`; returns the round to run next."""
        self.rng.bit_generator.state = state["rng"]
        self.params = ckpt_io.to_device(state["params"])
        gs = state["global_state"]
        self.global_state = None if gs is None else AggResult(
            method=gs["method"],
            global_adapters=ckpt_io.to_device(gs["global_adapters"]),
            per_client=ckpt_io.to_device(gs["per_client"]),
            ranks=gs["ranks"],
            spectra=ckpt_io.to_device(gs["spectra"]),
            merge_into_base=gs["merge_into_base"],
        )
        self.scheduler.load_state_dict(state["scheduler"])
        self.aggregator.load_state_dict(state["aggregator"])
        self.history = [RoundRecord(**r) for r in state["history"]]
        return int(state["next_round"])

    def save_checkpoint(self, path: str, next_round: int) -> None:
        """Atomically persist the round-boundary state (temp file +
        ``os.replace`` via :func:`repro.checkpoint.io.save_state`)."""
        ckpt_io.save_state(path, self.state_dict(next_round))

    def restore_checkpoint(self, path: str) -> int:
        """Restore a :meth:`save_checkpoint` blob; returns the next round.
        The first record produced afterwards carries ``resumes=1``."""
        start = self.load_state_dict(ckpt_io.restore_state(path))
        self._pending_resumes = 1
        return start

    def run(self, num_rounds: Optional[int] = None, verbose: bool = False,
            checkpoint: str = "", checkpoint_every: int = 0,
            resume: bool = False) -> List[RoundRecord]:
        """Run rounds ``[start, num_rounds)``.  With ``checkpoint`` set,
        the round-boundary state is saved atomically every
        ``checkpoint_every`` rounds (default 1); with ``resume``, a run
        killed at any point restarts from the last saved boundary and —
        because every in-round decision is a pure function of restored
        state — replays to a bit-identical history."""
        start = 0
        if resume and checkpoint and os.path.exists(checkpoint):
            start = self.restore_checkpoint(checkpoint)
        every = checkpoint_every or (1 if checkpoint else 0)
        for rnd in range(start, num_rounds or self.fed.num_rounds):
            rec = self.run_round(rnd)
            if verbose:
                print(f"[{self.aggregator.name:9s}] round {rnd:3d} "
                      f"loss={rec.eval_loss:.4f} acc={rec.eval_acc:.3f} "
                      f"down_rank={rec.download_rank:.0f} "
                      f"up={rec.upload_bytes / 2**20:.2f}MB "
                      f"down={rec.download_bytes / 2**20:.2f}MB "
                      f"{rec.wall_secs:.2f}s")
            if checkpoint and every and (rnd + 1) % every == 0:
                self.save_checkpoint(checkpoint, rnd + 1)
        return self.history
