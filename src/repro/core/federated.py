"""Federated fine-tuning orchestration (paper §4.1 setup).

Simulates the full loop: 100 clients with Dirichlet(0.5) non-IID data, 10
sampled per round, local LoRA fine-tuning, server aggregation and
global-model evaluation — composed from four pluggable seams
(:mod:`repro.core.runtime`):

* a **RoundScheduler** decides who participates (``scheduler=``: ``sync``
  reproduces the paper's sample-K-wait-for-all semantics bit-for-bit;
  ``partial`` injects dropouts/stragglers with per-client step budgets;
  ``async`` buffers staleness-discounted arrivals; ``sampled`` draws a
  seed-deterministic participation fraction of the full population);
* a **RankPolicy** (``rank_policy=``: ``static`` / ``resource``) may then
  adapt each task's LoRA rank to a declared client resource profile
  (AFLoRA-style) before training starts;
* a **ClientRunner** executes local fine-tuning (``runner=``:
  ``sequential`` is the legacy one-client-at-a-time loop; ``cohort``
  trains each equal-rank cohort in one jitted vmapped train-step call;
  ``sharded_cohort`` additionally shards the cohort's client axis over the
  fed mesh's ``data`` axis — 1024 clients in a handful of compiled calls);
* a **Transport** puts every exchanged adapter tree on a measured wire
  (``transport=`` codec: ``fp32`` exact / ``bf16`` / ``int8``), so each
  :class:`RoundRecord` carries real serialized ``upload_bytes`` /
  ``download_bytes`` next to the analytic parameter counts — with
  ``dp_clip``/``dp_sigma`` set, uploads are clipped/noised on the wire
  (local DP) before encoding, whatever the codec;
* an **Aggregator** owns the method semantics (client re-init, frozen-A
  composition, base merging, truncation, cost formulas) — pass
  ``aggregator=`` for a custom strategy, otherwise one is built from
  ``fed.method`` via the registry.

The server side is **streaming**: each delivered client update is folded
into the aggregator's running accumulators (``add_client``) and dropped
before the next arrives, so peak server memory per round is one client's
adapters plus the O(Σ r_k) per-leaf accumulators — never all K sampled
adapter trees at once.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.config import FedConfig, LoRAConfig, ModelConfig, OptimConfig
from repro.core.aggregators import (AggResult, Aggregator, accepted_config,
                                    make_aggregator)
from repro.core.runtime import (ClientRunner, RankPolicy, RoundScheduler,
                                Transport, make_rank_policy, make_runner,
                                make_scheduler, make_transport)
from repro.data.synthetic import ClientDataset, make_eval_data, make_federated_data
from repro.models import transformer as T
from repro.peft.lora import init_lora, merge_lora
from repro.train.step import make_eval_step, make_train_step


# jit'd step factories shared across trainer instances: configs are frozen
# (hashable) dataclasses, and jax.jit re-specializes per input shape, so a
# sweep over τ / methods / seeds compiles each (config, shapes) program once
# instead of once per FederatedTrainer.
@functools.lru_cache(maxsize=None)
def _cached_train_step(cfg: ModelConfig, optim: OptimConfig, loss_chunk: int,
                       b_only: bool):
    return jax.jit(make_train_step(cfg, optim, remat=False,
                                   loss_chunk=loss_chunk, b_only=b_only))


@functools.lru_cache(maxsize=None)
def _cached_eval_step(cfg: ModelConfig, loss_chunk: int):
    return jax.jit(make_eval_step(cfg, loss_chunk=loss_chunk))


@dataclasses.dataclass
class RoundRecord:
    round: int
    eval_loss: float
    eval_acc: float
    upload_params: int
    download_params: int
    download_rank: float
    global_rank_total: int
    upload_bytes: int = 0        # measured serialized uplink (all clients)
    download_bytes: int = 0      # measured serialized downlink (all clients)
    wall_secs: float = 0.0       # wall-clock of the whole round


class FederatedTrainer:
    """Thin composition of runner + scheduler + aggregator + transport.

    ``runner`` / ``scheduler`` / ``transport`` accept either a registered
    name (``"sequential"``, ``"sync"``, codec ``"fp32"``, ...) or an
    instance, so behaviours can be configured or injected.  The defaults
    reproduce the pre-runtime ``run_round`` bit-for-bit.
    """

    def __init__(self, cfg: ModelConfig, fed: FedConfig, lora: LoRAConfig,
                 optim: OptimConfig, clients: Optional[List[ClientDataset]] = None,
                 eval_data: Optional[Dict] = None, batch_size: int = 8,
                 local_steps: int = 4, seq_len: int = 64, svd_method: str = "svd",
                 targets: Optional[tuple] = None,
                 dp_clip: float = 0.0, dp_sigma: float = 0.0,
                 aggregator: Optional[Aggregator] = None,
                 runner: Any = "sequential",
                 scheduler: Any = "sync",
                 rank_policy: Any = "static",
                 transport: Any = "fp32"):
        self.cfg, self.fed, self.lora, self.optim = cfg, fed, lora, optim
        self.batch_size, self.local_steps = batch_size, local_steps
        self.svd_method = svd_method
        # client-level differential privacy, applied on the wire by the
        # transport's uplink DP stage (see core/runtime/transport)
        self.dp_clip, self.dp_sigma = dp_clip, dp_sigma
        self.rng = np.random.default_rng(fed.seed)
        key = jax.random.PRNGKey(fed.seed)
        kp, ka = jax.random.split(key)
        self.params = T.init(cfg, kp)
        self.targets = targets or lora.targets
        self.client_ranks = fed.client_ranks()
        self.max_rank = max(self.client_ranks)
        # one shared init at max rank; client k uses its first r_k rows
        self.A_init_full = init_lora(self.params, self.targets, self.max_rank,
                                     float(self.max_rank), ka)
        self.aggregator = aggregator if aggregator is not None else \
            make_aggregator(fed.method, **accepted_config(fed.method, dict(
                tau=fed.tau, svd_method=svd_method,
                zero_padding=fed.zero_padding)))
        # strategies that declare needs_a_init (FFA-style) are handed the
        # frozen shared init explicitly; everything else is left untouched
        if getattr(self.aggregator, "needs_a_init", False) \
                and getattr(self.aggregator, "A_init", None) is None:
            self.aggregator.A_init = self.A_init_full
        self.runner: ClientRunner = make_runner(runner)
        self.scheduler: RoundScheduler = make_scheduler(scheduler)
        self.rank_policy: RankPolicy = make_rank_policy(rank_policy)
        self.transport: Transport = make_transport(
            transport, dp_clip=dp_clip, dp_sigma=dp_sigma, dp_seed=fed.seed)
        self.global_state: Optional[AggResult] = None
        self.clients = clients if clients is not None else make_federated_data(
            num_clients=fed.num_clients, seq_len=seq_len,
            vocab=cfg.vocab_size, alpha=fed.dirichlet_alpha, seed=fed.seed)
        ev = eval_data if eval_data is not None else make_eval_data(
            seq_len=seq_len, vocab=cfg.vocab_size)
        self.eval_batch = {k: jnp.asarray(v) for k, v in ev.items()}
        self._eval = _cached_eval_step(cfg, seq_len)
        self.history: List[RoundRecord] = []

    # -- helpers -------------------------------------------------------------
    def _train_step(self):
        # rank only affects adapter shapes; jit re-specializes on those, so
        # all ranks share one cached wrapper per (cfg, optim, b_only)
        return _cached_train_step(self.cfg, self.optim, 64,
                                  self.aggregator.trains_b_only)

    def _client_init(self, k: int, rank: Optional[int] = None) -> Dict:
        """Build client k's starting adapters for this round (delegated to
        the aggregation strategy's client-init semantics).  ``rank``
        overrides the client's configured rank when a rank policy adapted
        this round's task."""
        return self.aggregator.client_init(
            self.global_state,
            self.client_ranks[k] if rank is None else rank,
            self.A_init_full)

    # -- main loop ------------------------------------------------------------
    def run_round(self, rnd: int) -> RoundRecord:
        t0 = time.perf_counter()
        plan = self.scheduler.plan(rnd, self)
        self.rank_policy.assign(rnd, plan, self)
        ranks = [t.rank for t in plan.tasks]
        self.aggregator.begin_round()
        upload_bytes = 0

        def deliver(task, adapters, init_adapters=None):
            # uplink through the measured wire (DP clip/noise happens there,
            # against the round's init), then stream into the server
            # accumulators; the trained adapters go out of scope here (no
            # K-tree round buffer)
            nonlocal upload_bytes
            adapters, nbytes = self.transport.client_to_server(
                adapters, self.aggregator, init_adapters=init_adapters,
                rnd=rnd, client_id=task.client_id)
            upload_bytes += nbytes
            self.aggregator.add_client(adapters, task.weight, rank=task.rank)

        self.runner.run(self, plan, deliver)
        agg = self.aggregator.finalize()
        dims = self.aggregator.dims
        up = self.aggregator.round_upload_params
        # participation-aware downlink count: only clients actually handed
        # the model this round (async: dispatch-time snapshots)
        n_down = plan.downloads if plan.downloads is not None \
            else len(plan.tasks)
        down = self.aggregator.download_params(agg, dims, n_down, ranks)

        # downlink through the measured wire: what the clients resume from
        # next round is the decoded broadcast (identity under fp32)
        bcast, download_bytes = self.transport.server_to_clients(
            agg, self.aggregator, n_down)
        if agg.merge_into_base:
            # FLoRA: every *client* folds the broadcast stack into its base,
            # so the merge consumes the decoded wire tensors, codec included
            if bcast is not None:
                agg.global_adapters = bcast
            self.params = merge_lora(self.params, agg.global_adapters)
            eval_params = self.params
        else:
            # broadcast methods: the server evals its exact aggregate;
            # clients resume from the decoded broadcast
            eval_params = merge_lora(self.params, agg.global_adapters)
            if bcast is not None:
                agg.global_adapters = bcast
        self.global_state = agg

        m = self._eval(eval_params, None, self.eval_batch)
        rec = RoundRecord(
            round=rnd,
            eval_loss=float(m["loss"]),
            eval_acc=float(m["accuracy"]),
            upload_params=up,
            download_params=down,
            download_rank=agg.total_download_rank()
            * self.aggregator.download_rank_factor,
            global_rank_total=agg.total_download_rank(),
            upload_bytes=upload_bytes,
            download_bytes=download_bytes,
            wall_secs=time.perf_counter() - t0,
        )
        self.history.append(rec)
        return rec

    def run(self, num_rounds: Optional[int] = None, verbose: bool = False
            ) -> List[RoundRecord]:
        for rnd in range(num_rounds or self.fed.num_rounds):
            rec = self.run_round(rnd)
            if verbose:
                print(f"[{self.aggregator.name:9s}] round {rnd:3d} "
                      f"loss={rec.eval_loss:.4f} acc={rec.eval_acc:.3f} "
                      f"down_rank={rec.download_rank:.0f} "
                      f"up={rec.upload_bytes / 2**20:.2f}MB "
                      f"down={rec.download_bytes / 2**20:.2f}MB "
                      f"{rec.wall_secs:.2f}s")
        return self.history
