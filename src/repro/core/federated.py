"""Federated fine-tuning orchestration (paper §4.1 setup).

Simulates the full loop: 100 clients with Dirichlet(0.5) non-IID data, 10
sampled per round, local LoRA fine-tuning, server aggregation by any of the
five methods, global-model evaluation and per-round communication accounting.

Per-method client/semantics (faithful to the paper):
  * fedit / florist : clients resume from the global adapters matched to
    their local rank (truncate / zero-pad, Alg. 1);
  * ffa             : A frozen at the shared init, only B trained/averaged;
  * flora           : the stacked global adapters are merged into the frozen
    base and clients re-init fresh adapters each round;
  * flexlora        : each client starts from its own rank-r_k SVD cut.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.config import FedConfig, LoRAConfig, ModelConfig, OptimConfig
from repro.core import costs as C
from repro.core.aggregation import AggResult, aggregate
from repro.data.synthetic import ClientDataset, make_eval_data, make_federated_data
from repro.models import transformer as T
from repro.optim.adamw import adamw_init
from repro.peft.lora import init_lora, match_rank, merge_lora
from repro.train.step import make_eval_step, make_train_step


@dataclasses.dataclass
class RoundRecord:
    round: int
    eval_loss: float
    eval_acc: float
    upload_params: int
    download_params: int
    download_rank: float
    global_rank_total: int


class FederatedTrainer:
    def __init__(self, cfg: ModelConfig, fed: FedConfig, lora: LoRAConfig,
                 optim: OptimConfig, clients: Optional[List[ClientDataset]] = None,
                 eval_data: Optional[Dict] = None, batch_size: int = 8,
                 local_steps: int = 4, seq_len: int = 64, svd_method: str = "svd",
                 targets: Optional[tuple] = None,
                 dp_clip: float = 0.0, dp_sigma: float = 0.0):
        self.cfg, self.fed, self.lora, self.optim = cfg, fed, lora, optim
        self.batch_size, self.local_steps = batch_size, local_steps
        self.svd_method = svd_method
        # client-level differential privacy (beyond-paper; see core/privacy)
        self.dp_clip, self.dp_sigma = dp_clip, dp_sigma
        self.rng = np.random.default_rng(fed.seed)
        key = jax.random.PRNGKey(fed.seed)
        kp, ka = jax.random.split(key)
        self.params = T.init(cfg, kp)
        self.targets = targets or lora.targets
        self.client_ranks = fed.client_ranks()
        self.max_rank = max(self.client_ranks)
        # one shared init at max rank; client k uses its first r_k rows
        self.A_init_full = init_lora(self.params, self.targets, self.max_rank,
                                     float(self.max_rank), ka)
        self.global_state: Optional[AggResult] = None
        self.clients = clients if clients is not None else make_federated_data(
            num_clients=fed.num_clients, seq_len=seq_len,
            vocab=cfg.vocab_size, alpha=fed.dirichlet_alpha, seed=fed.seed)
        ev = eval_data if eval_data is not None else make_eval_data(
            seq_len=seq_len, vocab=cfg.vocab_size)
        self.eval_batch = {k: jnp.asarray(v) for k, v in ev.items()}
        self._step_cache: Dict = {}
        self._eval = jax.jit(make_eval_step(cfg, loss_chunk=seq_len))
        self.history: List[RoundRecord] = []

    # -- helpers -------------------------------------------------------------
    def _train_step(self, rank: int):
        key = (rank, self.fed.method == "ffa")
        if key not in self._step_cache:
            self._step_cache[key] = jax.jit(make_train_step(
                self.cfg, self.optim, remat=False, loss_chunk=64,
                b_only=(self.fed.method == "ffa")))
        return self._step_cache[key]

    def _client_init(self, k: int) -> Dict:
        """Build client k's starting adapters for this round."""
        rk = self.client_ranks[k]
        a_init = match_rank(self.A_init_full, rk)

        if self.global_state is None or self.fed.method == "flora":
            # round 1 (all methods) / every round (flora — base was merged,
            # adapters restart): B = 0, A = shared init
            def mk(path, leaf):
                last = getattr(path[-1], "key", None)
                return jnp.zeros_like(leaf) if last == "B" else leaf
            return jax.tree_util.tree_map_with_path(mk, a_init)

        # fedit / florist / flexlora: truncate-or-pad the global adapters to
        # the client's rank (Alg. 1).  For FlexLoRA the global tree holds the
        # full SVD sorted by σ, so match_rank == the paper's per-client cut.
        g = match_rank(self.global_state.global_adapters, rk)
        if self.fed.method == "ffa":
            g = self._ffa_compose(g, a_init)   # A stays at the frozen init
        return g

    def _ffa_compose(self, g: Dict, a_init: Dict) -> Dict:
        def fix(path, gl):
            last = getattr(path[-1], "key", None)
            if last == "A":
                node = a_init
                for kk in [getattr(k, "key", getattr(k, "idx", None)) for k in path]:
                    node = node[kk]
                return node
            return gl
        return jax.tree_util.tree_map_with_path(fix, g)

    # -- main loop ------------------------------------------------------------
    def run_round(self, rnd: int) -> RoundRecord:
        fed = self.fed
        sampled = list(self.rng.choice(fed.num_clients, fed.clients_per_round,
                                       replace=False))
        updates, weights, ranks = [], [], []
        n_total = sum(self.clients[k].num_samples for k in sampled)
        for k in sampled:
            rk = self.client_ranks[k]
            adapters = self._client_init(k)
            init_adapters = adapters
            opt_state = adamw_init(adapters)
            step = self._train_step(rk)
            data = self.clients[k]
            brng = np.random.default_rng(1000 * rnd + k)
            steps_done = 0
            while steps_done < self.local_steps:
                for batch in data.batches(min(self.batch_size, data.num_samples), brng):
                    jb = {kk: jnp.asarray(v) for kk, v in batch.items()}
                    adapters, opt_state, _ = step(self.params, adapters, opt_state, jb)
                    steps_done += 1
                    if steps_done >= self.local_steps:
                        break
            if self.dp_clip:
                from repro.core.privacy import clip_client_adapters
                adapters = clip_client_adapters(adapters, init_adapters,
                                                self.dp_clip)
            updates.append(adapters)
            weights.append(self.clients[k].num_samples / n_total)
            ranks.append(rk)

        agg = aggregate(fed.method, updates, weights, tau=fed.tau,
                        A_init=self.A_init_full, client_ranks=ranks,
                        zero_padding=fed.zero_padding, svd_method=self.svd_method)
        if self.dp_sigma and agg.global_adapters is not None:
            from repro.core.privacy import add_gaussian_noise
            key = jax.random.PRNGKey(10_000 + rnd)
            agg.global_adapters = add_gaussian_noise(
                agg.global_adapters, self.dp_sigma, self.dp_clip or 1.0,
                fed.clients_per_round, key)
        dims = C.leaf_dims(updates[0])
        up = C.upload_params(fed.method, updates)
        down = C.download_params(fed.method, agg, dims, fed.clients_per_round, ranks)

        if agg.merge_into_base:      # FLoRA: fold stack into the base weights
            self.params = merge_lora(self.params, agg.global_adapters)
            eval_params = self.params
        else:
            eval_params = merge_lora(self.params, agg.global_adapters)
        self.global_state = agg

        m = self._eval(eval_params, None, self.eval_batch)
        rec = RoundRecord(
            round=rnd,
            eval_loss=float(m["loss"]),
            eval_acc=float(m["accuracy"]),
            upload_params=up,
            download_params=down,
            download_rank=C.total_download_rank(agg),
            global_rank_total=agg.total_download_rank(),
        )
        self.history.append(rec)
        return rec

    def run(self, num_rounds: Optional[int] = None, verbose: bool = False
            ) -> List[RoundRecord]:
        for rnd in range(num_rounds or self.fed.num_rounds):
            rec = self.run_round(rnd)
            if verbose:
                print(f"[{self.fed.method:9s}] round {rnd:3d} "
                      f"loss={rec.eval_loss:.4f} acc={rec.eval_acc:.3f} "
                      f"down_rank={rec.download_rank:.0f}")
        return self.history
