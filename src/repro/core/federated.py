"""Federated fine-tuning orchestration (paper §4.1 setup).

Simulates the full loop: 100 clients with Dirichlet(0.5) non-IID data, 10
sampled per round, local LoRA fine-tuning, server aggregation through a
pluggable :class:`~repro.core.aggregators.Aggregator` strategy, global-model
evaluation and per-round communication accounting.

The server side is **streaming**: each trained client update is folded into
the aggregator's running accumulators (``add_client``) and dropped before
the next client trains, so peak server memory per round is one client's
adapters plus the O(Σ r_k) per-leaf accumulators — never all K sampled
adapter trees at once.  Method semantics (client re-init, frozen-A
composition, base merging, per-client truncation, cost formulas) live on
the aggregator classes, not here; pass ``aggregator=`` to plug in a custom
strategy, otherwise one is built from ``fed.method`` via the registry.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.config import FedConfig, LoRAConfig, ModelConfig, OptimConfig
from repro.core import costs as C
from repro.core.aggregators import (AggResult, Aggregator, accepted_config,
                                    make_aggregator)
from repro.data.synthetic import ClientDataset, make_eval_data, make_federated_data
from repro.models import transformer as T
from repro.optim.adamw import adamw_init
from repro.peft.lora import init_lora, merge_lora
from repro.train.step import make_eval_step, make_train_step


# jit'd step factories shared across trainer instances: configs are frozen
# (hashable) dataclasses, and jax.jit re-specializes per input shape, so a
# sweep over τ / methods / seeds compiles each (config, shapes) program once
# instead of once per FederatedTrainer.
@functools.lru_cache(maxsize=None)
def _cached_train_step(cfg: ModelConfig, optim: OptimConfig, loss_chunk: int,
                       b_only: bool):
    return jax.jit(make_train_step(cfg, optim, remat=False,
                                   loss_chunk=loss_chunk, b_only=b_only))


@functools.lru_cache(maxsize=None)
def _cached_eval_step(cfg: ModelConfig, loss_chunk: int):
    return jax.jit(make_eval_step(cfg, loss_chunk=loss_chunk))


@dataclasses.dataclass
class RoundRecord:
    round: int
    eval_loss: float
    eval_acc: float
    upload_params: int
    download_params: int
    download_rank: float
    global_rank_total: int


class FederatedTrainer:
    def __init__(self, cfg: ModelConfig, fed: FedConfig, lora: LoRAConfig,
                 optim: OptimConfig, clients: Optional[List[ClientDataset]] = None,
                 eval_data: Optional[Dict] = None, batch_size: int = 8,
                 local_steps: int = 4, seq_len: int = 64, svd_method: str = "svd",
                 targets: Optional[tuple] = None,
                 dp_clip: float = 0.0, dp_sigma: float = 0.0,
                 aggregator: Optional[Aggregator] = None):
        self.cfg, self.fed, self.lora, self.optim = cfg, fed, lora, optim
        self.batch_size, self.local_steps = batch_size, local_steps
        self.svd_method = svd_method
        # client-level differential privacy (beyond-paper; see core/privacy)
        self.dp_clip, self.dp_sigma = dp_clip, dp_sigma
        self.rng = np.random.default_rng(fed.seed)
        key = jax.random.PRNGKey(fed.seed)
        kp, ka = jax.random.split(key)
        self.params = T.init(cfg, kp)
        self.targets = targets or lora.targets
        self.client_ranks = fed.client_ranks()
        self.max_rank = max(self.client_ranks)
        # one shared init at max rank; client k uses its first r_k rows
        self.A_init_full = init_lora(self.params, self.targets, self.max_rank,
                                     float(self.max_rank), ka)
        self.aggregator = aggregator if aggregator is not None else \
            make_aggregator(fed.method, **accepted_config(fed.method, dict(
                tau=fed.tau, svd_method=svd_method,
                zero_padding=fed.zero_padding)))
        # FFA-style strategies read the frozen shared init at finalize
        if getattr(self.aggregator, "A_init", False) is None:
            self.aggregator.A_init = self.A_init_full
        self.global_state: Optional[AggResult] = None
        self.clients = clients if clients is not None else make_federated_data(
            num_clients=fed.num_clients, seq_len=seq_len,
            vocab=cfg.vocab_size, alpha=fed.dirichlet_alpha, seed=fed.seed)
        ev = eval_data if eval_data is not None else make_eval_data(
            seq_len=seq_len, vocab=cfg.vocab_size)
        self.eval_batch = {k: jnp.asarray(v) for k, v in ev.items()}
        self._eval = _cached_eval_step(cfg, seq_len)
        self.history: List[RoundRecord] = []

    # -- helpers -------------------------------------------------------------
    def _train_step(self, rank: int):
        # rank only affects adapter shapes; jit re-specializes on those, so
        # all ranks share one cached wrapper per (cfg, optim, b_only)
        return _cached_train_step(self.cfg, self.optim, 64,
                                  self.aggregator.trains_b_only)

    def _client_init(self, k: int) -> Dict:
        """Build client k's starting adapters for this round (delegated to
        the aggregation strategy's client-init semantics)."""
        return self.aggregator.client_init(self.global_state,
                                           self.client_ranks[k],
                                           self.A_init_full)

    # -- main loop ------------------------------------------------------------
    def run_round(self, rnd: int) -> RoundRecord:
        fed = self.fed
        sampled = list(self.rng.choice(fed.num_clients, fed.clients_per_round,
                                       replace=False))
        n_total = sum(self.clients[k].num_samples for k in sampled)
        ranks = [self.client_ranks[k] for k in sampled]
        self.aggregator.begin_round()
        for k in sampled:
            rk = self.client_ranks[k]
            adapters = self._client_init(k)
            init_adapters = adapters
            opt_state = adamw_init(adapters)
            step = self._train_step(rk)
            data = self.clients[k]
            brng = np.random.default_rng(1000 * rnd + k)
            steps_done = 0
            while steps_done < self.local_steps:
                for batch in data.batches(min(self.batch_size, data.num_samples), brng):
                    jb = {kk: jnp.asarray(v) for kk, v in batch.items()}
                    adapters, opt_state, _ = step(self.params, adapters, opt_state, jb)
                    steps_done += 1
                    if steps_done >= self.local_steps:
                        break
            if self.dp_clip:
                from repro.core.privacy import clip_client_adapters
                adapters = clip_client_adapters(adapters, init_adapters,
                                                self.dp_clip)
            # stream the update into the server accumulators; the trained
            # adapters go out of scope here (no K-tree round buffer)
            self.aggregator.add_client(
                adapters, self.clients[k].num_samples / n_total, rank=rk)

        agg = self.aggregator.finalize()
        if self.dp_sigma and agg.global_adapters is not None:
            from repro.core.privacy import add_gaussian_noise
            key = jax.random.PRNGKey(10_000 + rnd)
            agg.global_adapters = add_gaussian_noise(
                agg.global_adapters, self.dp_sigma, self.dp_clip or 1.0,
                fed.clients_per_round, key)
        dims = self.aggregator.dims
        up = self.aggregator.round_upload_params
        down = self.aggregator.download_params(agg, dims,
                                               fed.clients_per_round, ranks)

        if agg.merge_into_base:      # FLoRA: fold stack into the base weights
            self.params = merge_lora(self.params, agg.global_adapters)
            eval_params = self.params
        else:
            eval_params = merge_lora(self.params, agg.global_adapters)
        self.global_state = agg

        m = self._eval(eval_params, None, self.eval_batch)
        rec = RoundRecord(
            round=rnd,
            eval_loss=float(m["loss"]),
            eval_acc=float(m["accuracy"]),
            upload_params=up,
            download_params=down,
            download_rank=agg.total_download_rank()
            * self.aggregator.download_rank_factor,
            global_rank_total=agg.total_download_rank(),
        )
        self.history.append(rec)
        return rec

    def run(self, num_rounds: Optional[int] = None, verbose: bool = False
            ) -> List[RoundRecord]:
        for rnd in range(num_rounds or self.fed.num_rounds):
            rec = self.run_round(rnd)
            if verbose:
                print(f"[{self.aggregator.name:9s}] round {rnd:3d} "
                      f"loss={rec.eval_loss:.4f} acc={rec.eval_acc:.3f} "
                      f"down_rank={rec.download_rank:.0f}")
        return self.history

