"""Communication / computation cost accounting (paper Tables 3–6).

Parameter counts are exact (derived from the actual adapter trees / recorded
per-layer ranks), MB figures use FP16 as in the paper (§F.2: cost(MB) =
params × 2 / 1024²).  ``efficiency`` is the paper's proxy
1 / total-download-rank.  Server FLOPs are computed analytically from the
linear-algebra op counts (mult-add = 2 FLOPs); the benchmark additionally
*measures* compiled FLOPs of each aggregation via XLA cost analysis.
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.core.aggregation import AggResult, adapter_leaf_paths, get_path

BYTES_FP16 = 2


def leaf_dims(client_tree: Dict) -> Dict[Tuple, Tuple[int, int, int]]:
    """{leaf path: (L, n_in, m_out)} from one client's adapter tree.
    Note: A: (L, r, n_in), B: (L, m_out, r)."""
    dims = {}
    for path in adapter_leaf_paths(client_tree):
        leaf = get_path(client_tree, path)
        A, B = leaf["A"], leaf["B"]
        if A.ndim == 3:
            dims[path] = (A.shape[0], A.shape[2], B.shape[1])
        else:
            dims[path] = (1, A.shape[1], B.shape[0])
    return dims


# ---------------------------------------------------------------------------
# communication
# ---------------------------------------------------------------------------

def upload_params(method: str, client_trees: Sequence[Dict]) -> int:
    """Total parameters uploaded by the sampled clients this round."""
    total = 0
    for tree in client_trees:
        for path in adapter_leaf_paths(tree):
            leaf = get_path(tree, path)
            if method == "ffa":
                total += leaf["B"].size            # A frozen, never sent
            else:
                total += leaf["A"].size + leaf["B"].size
    return total


def download_params(method: str, agg: AggResult, dims: Dict,
                    num_clients: int, client_ranks: Sequence[int]) -> int:
    """Total parameters sent server -> clients this round."""
    total = 0
    if method == "flexlora":
        # each client gets its own rank-r_k adapters
        for rk in client_ranks:
            for path, (L, n, m) in dims.items():
                total += L * rk * (n + m)
        return total
    for path, (L, n, m) in dims.items():
        ranks = agg.ranks[path]
        for r_l in ranks:
            if method == "ffa":
                total += num_clients * r_l * m      # only B broadcast
            else:
                total += num_clients * r_l * (n + m)
    return total


def total_download_rank(agg: AggResult, half_for_ffa: bool = True) -> float:
    """The paper's efficiency denominator: Σ over layers of the broadcast
    rank (FFA counts rank/2 — only one of the two matrices travels)."""
    tr = agg.total_download_rank()
    if agg.method == "ffa" and half_for_ffa:
        return tr / 2.0
    return float(tr)


def efficiency(agg: AggResult, client_ranks: Sequence[int] = (),
               dims: Dict = None) -> float:
    """1 / total_download_rank (paper §4, 'communication efficiency').

    The denominator is the per-client downloaded rank summed over all LoRA'd
    matrices (this reproduces the paper's homogeneous numbers, e.g. FedIT on
    TinyLlama: 22 layers × 2 proj × rank 16 = 704 → 14.2e-4).  FlexLoRA sends
    each client its own rank-r_k adapters → mean over clients.
    """
    if agg.method == "flexlora":
        L_total = sum(L for (L, _, _) in dims.values()) if dims else 1
        return 1.0 / max(1.0, L_total * float(np.mean(client_ranks)))
    return 1.0 / max(1.0, total_download_rank(agg))


def mb(params: int) -> float:
    return params * BYTES_FP16 / (1024 ** 2)


def full_ft_params(model_param_count: int, num_clients: int) -> int:
    return model_param_count * num_clients


# ---------------------------------------------------------------------------
# server FLOPs (analytic; Table 4 / Table 5)
# ---------------------------------------------------------------------------

SVD_CONST = 4  # FLOPs ≈ SVD_CONST · m · n · min(m,n) for dense SVD


def server_flops(method: str, dims: Dict, client_ranks: Sequence[int],
                 agg_ranks: Dict[Tuple, List[int]] = None) -> int:
    """Analytic per-round server cost. mult-add = 2 FLOPs."""
    K = len(client_ranks)
    r = sum(client_ranks)                  # stacked rank
    total = 0
    for path, (L, n, m) in dims.items():
        for l in range(L):
            if method == "fedit":
                total += 2 * K * max(client_ranks) * (m + n)
            elif method == "ffa":
                total += 2 * K * max(client_ranks) * m
            elif method == "flora":
                total += 0                  # pure concatenation
            elif method == "flexlora":
                total += 2 * m * n * r                       # form ΔW
                total += SVD_CONST * m * n * min(m, n)       # dense SVD
                p = min(m, n)
                total += 2 * (m * p * p + p * p * n)         # partition/rescale
            elif method == "florist":
                total += SVD_CONST * (m * r * r + n * r * r)  # thin SVDs
                total += 2 * r ** 3                            # Q = V_Bᵀ U_A
                total += 2 * r * r                             # P diag scaling
                total += SVD_CONST * r ** 3                    # SVD(P)
                p_l = agg_ranks[path][l] if agg_ranks else r
                total += 2 * (m * r * p_l + p_l * r * n)       # build B_g, A_g
    return total
