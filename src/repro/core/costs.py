"""Communication / computation cost accounting (paper Tables 3–6).

Parameter counts are exact (derived from the actual adapter trees / recorded
per-layer ranks), MB figures use FP16 as in the paper (§F.2: cost(MB) =
params × 2 / 1024²).  ``efficiency`` is the paper's proxy
1 / total-download-rank.  Server FLOPs are computed analytically from the
linear-algebra op counts (mult-add = 2 FLOPs); the benchmark additionally
*measures* compiled FLOPs of each aggregation via XLA cost analysis.

The per-method formulas live on the registered
:class:`~repro.core.aggregators.Aggregator` classes (``upload_params`` /
``download_params`` / ``server_flops`` / ``efficiency``); the module-level
functions here keep the original ``f(method, ...)`` call shape by
delegating to the registry.
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.core.aggregators import (AggResult, adapter_leaf_paths,
                                    get_aggregator_class, get_path, leaf_dims)

__all__ = ["AggResult", "BYTES_FP16", "SVD_CONST", "adapter_leaf_paths",
           "download_params", "efficiency", "full_ft_params", "get_path",
           "leaf_dims", "mb", "server_flops", "total_download_rank",
           "upload_params", "wire_download_bytes", "wire_mb",
           "wire_upload_bytes"]

BYTES_FP16 = 2

SVD_CONST = 4  # FLOPs ≈ SVD_CONST · m · n · min(m,n) for dense SVD


def _cost_model(method: str):
    """An instance of ``method``'s class usable for its (state-free) cost
    methods — constructed without config so this also works for strategies
    with required constructor args or expensive setup (meshes)."""
    cls = get_aggregator_class(method)
    return cls.__new__(cls)


# ---------------------------------------------------------------------------
# communication
# ---------------------------------------------------------------------------

def upload_params(method: str, client_trees: Sequence[Dict]) -> int:
    """Total parameters uploaded by the sampled clients this round."""
    return _cost_model(method).upload_params(client_trees)


def download_params(method: str, agg: AggResult, dims: Dict,
                    num_clients: int, client_ranks: Sequence[int]) -> int:
    """Total parameters sent server -> clients this round."""
    return _cost_model(method).download_params(agg, dims, num_clients,
                                               client_ranks)


def total_download_rank(agg: AggResult, half_for_ffa: bool = True) -> float:
    """The paper's efficiency denominator: Σ over layers of the broadcast
    rank, weighted by the method's ``download_rank_factor`` (FFA counts
    rank/2 — only one of the two matrices travels)."""
    factor = get_aggregator_class(agg.method).download_rank_factor \
        if half_for_ffa else 1.0
    return float(agg.total_download_rank()) * factor


def efficiency(agg: AggResult, client_ranks: Sequence[int] = (),
               dims: Dict = None) -> float:
    """1 / total_download_rank (paper §4, 'communication efficiency').

    The denominator is the per-client downloaded rank summed over all LoRA'd
    matrices (this reproduces the paper's homogeneous numbers, e.g. FedIT on
    TinyLlama: 22 layers × 2 proj × rank 16 = 704 → 14.2e-4).  FlexLoRA sends
    each client its own rank-r_k adapters → mean over clients.
    """
    return _cost_model(agg.method).efficiency(agg, client_ranks, dims)


def mb(params: int) -> float:
    return params * BYTES_FP16 / (1024 ** 2)


def wire_mb(num_bytes: int) -> float:
    """MB of a *measured* serialized payload (see :mod:`repro.core.runtime.
    transport`), for cross-checking the analytic FP16 figures above."""
    return num_bytes / (1024 ** 2)


def wire_upload_bytes(method: str, client_trees: Sequence[Dict],
                      codec: str = "bf16") -> int:
    """Measured serialized uplink bytes for the sampled client trees —
    the real-bytes counterpart of :func:`upload_params` (with the ``bf16``
    codec, exactly ``BYTES_FP16 × upload_params``)."""
    from repro.core.runtime.transport import AdapterPayload, make_codec
    model, c = _cost_model(method), make_codec(codec)
    return sum(AdapterPayload.pack(t, c, model.wire_arrays).num_bytes
               for t in client_trees)


def wire_download_bytes(method: str, agg: AggResult, num_clients: int,
                        codec: str = "bf16") -> int:
    """Measured serialized downlink bytes for one round's result — the
    real-bytes counterpart of :func:`download_params` (per-layer ranks are
    honoured: zero padding is never serialized)."""
    from repro.core.runtime.transport import Transport, make_codec
    _, nbytes = Transport(make_codec(codec)).server_to_clients(
        agg, _cost_model(method), num_clients)
    return nbytes


def full_ft_params(model_param_count: int, num_clients: int) -> int:
    return model_param_count * num_clients


# ---------------------------------------------------------------------------
# server FLOPs (analytic; Table 4 / Table 5)
# ---------------------------------------------------------------------------

def server_flops(method: str, dims: Dict, client_ranks: Sequence[int],
                 agg_ranks: Dict[Tuple, List[int]] = None) -> int:
    """Analytic per-round server cost. mult-add = 2 FLOPs."""
    return _cost_model(method).server_flops(dims, client_ranks, agg_ranks)
