"""Differential privacy for client updates (beyond-paper).

FFA-LoRA's motivating context (Sun et al. 2024, "Improving LoRA in
privacy-preserving federated learning") is DP-SGD-style training; the
FLoRIST paper inherits the privacy framing but does not implement noise.
We provide the standard client-level DP mechanisms:

  1. clip each client's adapter update to L2 norm ≤ C (flattened over the
     whole adapter tree, the update being the delta from the round's init),
  2. **local** (DP-on-the-wire, the runtime default): add Gaussian noise
     N(0, σ²C²) to each clipped update *before it leaves the client* — the
     transport's DP codec stage (:mod:`repro.core.runtime.transport`), so
     the server and the wire only ever see privatized bytes;
  3. **central** (legacy helper): add N(0, σ²C²/K) to the *aggregated*
     update server-side (sensitivity C/K under mean aggregation).

Interaction with SVT (documented): under the local mechanism the stacked
intermediate the server thresholds is already noisy — small singular values
are noise-floor-inflated, so a given τ keeps a slightly *higher* rank than
the noiseless run; the Eckart–Young bound holds for the noisy aggregate the
server actually sees.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


def global_l2(tree: Any) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in jax.tree.leaves(tree)))


def tree_sub(a: Any, b: Any) -> Any:
    return jax.tree.map(lambda x, y: x.astype(jnp.float32) - y.astype(jnp.float32), a, b)


def tree_add(a: Any, b: Any) -> Any:
    return jax.tree.map(lambda x, y: (x.astype(jnp.float32)
                                      + y.astype(jnp.float32)).astype(x.dtype), a, b)


def clip_update(update: Any, clip_norm: float) -> Tuple[Any, jnp.ndarray]:
    """Scale the whole update tree so its global L2 ≤ clip_norm."""
    n = global_l2(update)
    scale = jnp.minimum(1.0, clip_norm / jnp.maximum(n, 1e-12))
    return jax.tree.map(lambda l: (l * scale).astype(l.dtype), update), n


def clip_client_adapters(adapters: Any, init_adapters: Any,
                         clip_norm: float) -> Any:
    """Clip the *delta* from the round's starting adapters, re-anchor."""
    delta = tree_sub(adapters, init_adapters)
    clipped, _ = clip_update(delta, clip_norm)
    return tree_add(init_adapters, clipped)


def add_gaussian_noise(tree: Any, sigma: float, clip_norm: float,
                       num_clients: int, key: jax.Array) -> Any:
    """Server-side Gaussian mechanism: noise std = σ·C / K per coordinate
    (client-level DP with sensitivity C/K under mean aggregation)."""
    std = sigma * clip_norm / max(num_clients, 1)
    leaves, treedef = jax.tree.flatten(tree)
    keys = jax.random.split(key, len(leaves))
    noisy = [
        (l + std * jax.random.normal(k, l.shape)).astype(l.dtype)
        if l.ndim >= 2 else l           # don't noise scalars ("scale")
        for l, k in zip(leaves, keys)
    ]
    return jax.tree.unflatten(treedef, noisy)


def local_gaussian_noise(tree: Any, sigma: float, clip_norm: float,
                         key: jax.Array) -> Any:
    """Client-side (local) Gaussian mechanism: noise std = σ·C per
    coordinate, applied to one clipped update before upload."""
    std = sigma * clip_norm
    leaves, treedef = jax.tree.flatten(tree)
    keys = jax.random.split(key, len(leaves))
    noisy = [
        (l + std * jax.random.normal(k, l.shape)).astype(l.dtype)
        if l.ndim >= 2 else l           # don't noise scalars ("scale")
        for l, k in zip(leaves, keys)
    ]
    return jax.tree.unflatten(treedef, noisy)


def noise_multiplier_for_epsilon(epsilon: float, delta: float = 1e-5) -> float:
    """Loose classical Gaussian-mechanism calibration (one release):
    σ ≥ sqrt(2 ln(1.25/δ)) / ε.  (Per-round; composition is left to an
    accountant — this module provides the mechanism, not the bookkeeping.)"""
    import math
    return math.sqrt(2.0 * math.log(1.25 / delta)) / epsilon
