"""FLoRIST's efficient SVD pipeline (paper §3, Eqs. 1–4).

Given client adapters ``B_k ∈ R^{m×r_k}``, ``A_k ∈ R^{r_k×n}`` and weights
``w_k = n_k / N``:

    B_stack = [B_1 | ... | B_K]              (m × r),  r = Σ r_k
    A_stack = [w_1 A_1 ; ... ; w_K A_K]      (r × n)
    ΔW      = B_stack A_stack                 (never formed!)

    B_stack = U_B S_B V_Bᵀ,  A_stack = U_A S_A V_Aᵀ          (thin SVDs)
    Q = V_Bᵀ U_A,  P = S_B Q S_A ∈ R^{r×r}                    (Eq. 2)
    SVD(P) = U_P S_P V_Pᵀ  →  singular values of ΔW are S_P   (exact)
    B_g = (U_B U_P)[:, :p] S_P[:p,:p],  A_g = (V_Pᵀ V_Aᵀ)[:p, :]   (Eq. 3)

with ``p`` from the energy threshold (Eq. 6):
    p = min { p : Σ_{i≤p} σ_i² / Σ_i σ_i² ≥ τ }.

Two thin-SVD backends:
  * ``svd``  — LAPACK/XLA divide-and-conquer (default; exact),
  * ``gram`` — eigh of the r×r Gram matrix (TPU-idiomatic for tall-skinny
    stacks: two MXU matmuls + small eigh instead of an m×r Householder
    pipeline; see DESIGN.md §3).
"""
from __future__ import annotations

import functools

from typing import Dict, List, NamedTuple, Sequence, Tuple

import jax
import jax.numpy as jnp


class SVDResult(NamedTuple):
    u: jnp.ndarray
    s: jnp.ndarray
    vt: jnp.ndarray


def thin_svd(x: jnp.ndarray, method: str = "svd") -> SVDResult:
    """Thin SVD of x (m×n, any aspect). method: 'svd' | 'gram'."""
    if method == "svd":
        u, s, vt = jnp.linalg.svd(x, full_matrices=False)
        return SVDResult(u, s, vt)
    if method == "gram":
        return gram_svd(x)
    raise ValueError(method)


@functools.lru_cache(maxsize=None)
def _batched_thin_svd_fn(method: str):
    return jax.jit(jax.vmap(lambda x: tuple(thin_svd(x, method))))


def thin_svd_batched(x: jnp.ndarray, method: str = "svd") -> SVDResult:
    """Thin SVD over a stack of equal-shaped matrices x (L, m, n) in ONE
    compiled call — the building block of the batched server pipeline."""
    u, s, vt = _batched_thin_svd_fn(method)(x)
    return SVDResult(u, s, vt)


def _gram_matrix(x: jnp.ndarray) -> jnp.ndarray:
    """xᵀx in fp32.  On TPU this is the streaming Pallas ``adapter_gram``
    kernel (m-panels through VMEM, r×r accumulator resident); on CPU /
    under interpret the plain-XLA reference is both the oracle and the
    faster choice, so we fall back to it."""
    if jax.default_backend() == "tpu":
        from repro.kernels.ops import adapter_gram
        return adapter_gram(x)
    xf = x.astype(jnp.float32)
    return xf.T @ xf


def gram_svd(x: jnp.ndarray) -> SVDResult:
    """Thin SVD via the Gram trick (TPU route).

    For tall x (m ≥ n): eigh(xᵀx) = V diag(s²) Vᵀ; U = x V / s.
    For wide x: transpose, recurse, swap.  Numerically fine for LoRA-scale
    conditioning (σ_max/σ_min ≪ 1/√eps in fp32); exactness is asserted
    against the LAPACK route in tests.

    Rank-deficient stacks (e.g. duplicated clients) produce near-null
    eigenvalues whose U columns would otherwise be garbage-magnitude noise
    (x·v ≈ 0 divided by s ≈ 0): columns with σ below a scaled tolerance
    (σ_max·√(n·eps), the Gram route's resolution limit) are zeroed, which
    leaves U S Vᵀ unchanged to within the tolerance.
    """
    m, n = x.shape
    if m < n:
        r = gram_svd(x.T)
        return SVDResult(r.vt.T, r.s, r.u.T)
    g = _gram_matrix(x)                            # (n, n)
    w, v = jnp.linalg.eigh(g)                      # ascending
    w = w[::-1]
    v = v[:, ::-1]
    s = jnp.sqrt(jnp.clip(w, 0.0))
    eps = jnp.finfo(s.dtype).eps
    tol = s[0] * jnp.sqrt(eps * n)
    u = jnp.where(s[None, :] > tol,
                  (x @ v) / jnp.maximum(s, tol)[None, :], 0.0)
    return SVDResult(u, s, v.T)


def energy_rank_traced(s: jnp.ndarray, tau: float) -> jnp.ndarray:
    """Smallest p with Σ_{i≤p} σ_i² / Σ σ_i² ≥ τ, as a traced int32 scalar.

    This is the single source of truth for energy-rank semantics: fp32
    cumulative energy and an fp32 τ comparison, identical under jit and on
    host (``energy_rank`` is a thin ``int()`` wrapper), so the padded /
    batched / sharded paths pick the same p as the host loop at τ
    boundaries.
    """
    e = jnp.cumsum(s.astype(jnp.float32) ** 2)
    frac = e / jnp.maximum(e[-1], 1e-30)
    p = jnp.searchsorted(frac, jnp.float32(tau), side="left") + 1
    return jnp.minimum(p, s.shape[0]).astype(jnp.int32)


def energy_rank(s: jnp.ndarray, tau: float) -> int:
    """Host-side energy rank (concrete int) — same fp32 semantics as
    :func:`energy_rank_traced` by construction."""
    return int(energy_rank_traced(s, tau))


def knee_rank_traced(s: jnp.ndarray) -> jnp.ndarray:
    """Traced knee-point rank: max distance of the cumulative-energy curve
    from the chord between (0, 0) and (r, 1).  int32 scalar in [1, r]."""
    e = jnp.cumsum(s.astype(jnp.float32) ** 2)
    frac = e / jnp.maximum(e[-1], 1e-30)               # (r,)
    r = s.shape[0]
    x = (jnp.arange(1, r + 1, dtype=jnp.float32)) / r
    # distance from the chord y = x (both endpoints normalized)
    p = jnp.argmax(frac - x) + 1
    return jnp.clip(p, 1, r).astype(jnp.int32)


def knee_rank(s: jnp.ndarray) -> int:
    """BEYOND-PAPER (paper §5 future work (i)): automatic per-layer rank
    selection by knee-point detection on the cumulative-energy curve.
    No tunable τ; adapts to each layer's spectrum shape.  Host wrapper of
    :func:`knee_rank_traced` (same semantics traced and concrete)."""
    return int(knee_rank_traced(s))


def stack_adapters(Bs: Sequence[jnp.ndarray], As: Sequence[jnp.ndarray],
                   weights: Sequence[float]) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Weighted stacking (paper: weights fold into A_stack)."""
    B_stack = jnp.concatenate(list(Bs), axis=1)                      # (m, r)
    A_stack = jnp.concatenate([w * A for w, A in zip(weights, As)], axis=0)
    return B_stack, A_stack


class FloristOut(NamedTuple):
    B_g: jnp.ndarray          # (m, p)  — includes S_P scaling
    A_g: jnp.ndarray          # (p, n)
    spectrum: jnp.ndarray     # full S_P (r,)
    p: int


def florist_core_stacked(B_stack: jnp.ndarray, A_stack: jnp.ndarray, tau,
                         svd_method: str = "svd",
                         max_rank: int = 0) -> FloristOut:
    """FLoRIST server pipeline on pre-stacked blocks (B_stack (m, r),
    A_stack (r, n) with weights already folded into A_stack) — the entry
    point for the streaming aggregator, which accumulates the stacks
    incrementally as clients arrive."""
    f32 = jnp.float32
    B_stack, A_stack = B_stack.astype(f32), A_stack.astype(f32)
    ub, sb, vbt = thin_svd(B_stack, svd_method)
    ua, sa, vat = thin_svd(A_stack, svd_method)
    q = vbt @ ua                                   # (r, r)
    p_core = (sb[:, None] * q) * sa[None, :]       # P = S_B Q S_A
    up, sp, vpt = thin_svd(p_core, "svd")          # r×r — always LAPACK-size
    p = knee_rank(sp) if tau == "auto" else energy_rank(sp, tau)
    if max_rank:
        p = min(p, max_rank)
    B_g = (ub @ up)[:, :p] * sp[None, :p]
    A_g = (vpt @ vat)[:p, :]
    return FloristOut(B_g, A_g, sp, p)


def florist_core(Bs: Sequence[jnp.ndarray], As: Sequence[jnp.ndarray],
                 weights: Sequence[float], tau,
                 svd_method: str = "svd", max_rank: int = 0) -> FloristOut:
    """The full FLoRIST server pipeline for one weight matrix (Alg. 1,
    server block).  Host-side: returns concretely-truncated adapters.
    tau: float in (0,1], or "auto" for knee-point rank selection
    (beyond-paper; paper §5 future-work (i))."""
    B_stack, A_stack = stack_adapters(Bs, As, weights)
    return florist_core_stacked(B_stack, A_stack, tau, svd_method, max_rank)


def florist_core_padded(B_stack: jnp.ndarray, A_stack: jnp.ndarray, tau,
                        svd_method: str = "svd", max_rank: int = 0):
    """Jit-safe variant: full-rank outputs with columns ≥ p zeroed (same ΔW).

    Used by the sharded multi-pod aggregation and the batched (vmapped)
    server pipeline, where shapes must be static.  Honors the same knobs as
    the host path: ``tau`` is a float threshold or ``"auto"`` (knee-point),
    and ``max_rank`` caps the kept rank — so sharded/batched backends
    produce the same ΔW as host ``florist`` under any configuration.
    Returns (B_g_full (m,r), A_g_full (r,n), spectrum (r,), p int32).
    """
    f32 = jnp.float32
    B_stack, A_stack = B_stack.astype(f32), A_stack.astype(f32)
    ub, sb, vbt = thin_svd(B_stack, svd_method)
    ua, sa, vat = thin_svd(A_stack, svd_method)
    q = vbt @ ua
    p_core = (sb[:, None] * q) * sa[None, :]
    up, sp, vpt = thin_svd(p_core, "svd")
    p = knee_rank_traced(sp) if tau == "auto" else energy_rank_traced(sp, tau)
    if max_rank:
        p = jnp.minimum(p, max_rank)
    r = sp.shape[0]
    keep = (jnp.arange(r) < p)
    B_g = (ub @ up) * jnp.where(keep, sp, 0.0)[None, :]
    A_g = (vpt @ vat) * keep[:, None]
    return B_g, A_g, sp, p


@functools.lru_cache(maxsize=None)
def _batched_core_fn(tau, svd_method: str, max_rank: int):
    fn = functools.partial(florist_core_padded, tau=tau,
                           svd_method=svd_method, max_rank=max_rank)
    return jax.jit(jax.vmap(fn))


def florist_core_batched(B_stacks: jnp.ndarray, A_stacks: jnp.ndarray, tau,
                         svd_method: str = "svd", max_rank: int = 0):
    """Batched FLoRIST server pipeline: ONE compiled call for a whole stack
    of layers (or a bucket of equal-shaped leaves × layers).

    ``jax.vmap`` of :func:`florist_core_padded` over axis 0, jitted and
    cached per (τ, backend, cap) — all thin SVDs for all layers run in a
    single XLA computation with no per-layer retrace or host sync.  The
    caller materializes spectra/ranks with one device→host transfer at the
    end and truncates the zero-padded outputs there.

    B_stacks: (L, m, r), A_stacks: (L, r, n), weights already folded in.
    Returns (B_g (L,m,r) zero-padded beyond each layer's p_l, A_g (L,r,n),
    spectra (L,r), ranks (L,) int32).
    """
    return _batched_core_fn(tau, svd_method, int(max_rank))(B_stacks, A_stacks)


def florist_core_delta_padded(M: jnp.ndarray, tau, svd_method: str = "svd",
                              max_rank: int = 0):
    """Jit-safe FLoRIST core on an *accumulated* update ΔW = Σ_k w_k B_k A_k.

    The stacked pipeline (:func:`florist_core_padded`) computes the SVD of
    ``B_stack A_stack`` — exactly the SVD of ΔW — without forming ΔW, which
    is the compact route while the stack width Σ r_k stays below
    ``min(m, n)``.  Past that point (hundreds of clients per round) the
    dense ΔW itself is the *smaller* intermediate, so the streaming
    aggregator contracts arriving blocks into a running ``M`` and this core
    finishes the job: one thin SVD of ``M`` and the same energy threshold /
    knee selection / rank cap as the stacked path (identical ΔW up to fp).

    Returns (B_g (m, q), A_g (q, n), spectrum (q,), p int32) with
    q = min(m, n) and columns ≥ p zeroed, mirroring the padded stacked core.
    """
    M = M.astype(jnp.float32)
    u, s, vt = thin_svd(M, svd_method)
    p = knee_rank_traced(s) if tau == "auto" else energy_rank_traced(s, tau)
    if max_rank:
        p = jnp.minimum(p, max_rank)
    keep = (jnp.arange(s.shape[0]) < p)
    B_g = u * jnp.where(keep, s, 0.0)[None, :]
    A_g = vt * keep[:, None]
    return B_g, A_g, s, p


@functools.lru_cache(maxsize=None)
def _batched_delta_fn(tau, svd_method: str, max_rank: int):
    fn = functools.partial(florist_core_delta_padded, tau=tau,
                           svd_method=svd_method, max_rank=max_rank)
    return jax.jit(jax.vmap(fn))


def florist_core_delta_batched(Ms: jnp.ndarray, tau,
                               svd_method: str = "svd", max_rank: int = 0):
    """Batched delta core: ONE compiled call for a layer stack of
    accumulated updates.  Ms: (L, m, n).  Returns (B_g (L, m, q),
    A_g (L, q, n), spectra (L, q), ranks (L,) int32), q = min(m, n)."""
    return _batched_delta_fn(tau, svd_method, int(max_rank))(Ms)


def reconstruction_error(Bs, As, weights, B_g, A_g) -> float:
    """‖ΔW − B_g A_g‖_F computed without forming ΔW twice (small shapes in
    tests — forms it once)."""
    dw = sum(w * (B @ A) for w, B, A in zip(weights, Bs, As))
    return float(jnp.linalg.norm(dw - B_g @ A_g))


def eckart_young_bound(spectrum: jnp.ndarray, p: int) -> float:
    """(Σ_{i>p} σ_i²)^{1/2} — the paper's Eq. 5 bound."""
    tail = spectrum[p:]
    return float(jnp.sqrt(jnp.sum(tail.astype(jnp.float32) ** 2)))
