"""Server-side aggregation: FLoRIST + the four baselines (FedIT, FFA-LoRA,
FLoRA, FlexLoRA), operating on per-client adapter trees.

A client update is an adapter tree whose LoRA leaves are
``{"A": (L, r_k, n), "B": (L, m, r_k), "scale": (L,)}`` (or un-stacked 2-D
for shared blocks).  Aggregation is per-(leaf, layer).  Client ``scale`` is
folded into ``B`` before aggregation so methods compare the same effective
updates ``ΔW_k = scale_k · B_k A_k``; all global adapters carry scale 1.

Host-side code (concrete ragged ranks).  The jit/shard_map multi-pod path
lives in :mod:`repro.core.distributed`.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.svd import florist_core, thin_svd, energy_rank

METHODS = ("florist", "fedit", "ffa", "flora", "flexlora")


# ---------------------------------------------------------------------------
# tree plumbing
# ---------------------------------------------------------------------------

def adapter_leaf_paths(tree: Dict) -> List[Tuple]:
    """Paths of LoRA leaves (subdicts holding A/B/scale)."""
    out = []

    def walk(node, path):
        if isinstance(node, dict) and "A" in node and "B" in node:
            out.append(path)
            return
        if isinstance(node, dict):
            for k, v in node.items():
                walk(v, path + (k,))

    walk(tree, ())
    return out


def get_path(tree, path):
    node = tree
    for k in path:
        node = node[k]
    return node


def set_path(tree, path, value):
    node = tree
    for k in path[:-1]:
        node = node.setdefault(k, {})
    node[path[-1]] = value


def _fold_scale(leaf: Dict) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Return (B', A) with scale folded into B. Handles stacked + flat."""
    A, B, s = leaf["A"], leaf["B"], leaf["scale"]
    if B.ndim == 3:
        sl = s[:, None, None] if s.ndim == 1 else s
        return B * sl, A
    return B * s, A


def _per_layer(mat: jnp.ndarray, l: int, stacked: bool):
    return mat[l] if stacked else mat


def _ones_scale(ref_scale):
    return jnp.ones_like(ref_scale)


# ---------------------------------------------------------------------------
# result container
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class AggResult:
    method: str
    global_adapters: Optional[Dict]          # unified tree (None for flexlora)
    per_client: Optional[List[Dict]]         # flexlora: tailored trees
    ranks: Dict[Tuple, List[int]]            # leaf path -> per-layer rank
    spectra: Dict[Tuple, List[np.ndarray]]   # leaf path -> per-layer σ (florist/flex)
    merge_into_base: bool = False            # flora semantics

    def total_download_rank(self) -> int:
        return int(sum(sum(v) for v in self.ranks.values()))


# ---------------------------------------------------------------------------
# the five methods
# ---------------------------------------------------------------------------

def aggregate_fedit(clients: Sequence[Dict], weights: Sequence[float],
                    zero_padding: bool = False) -> AggResult:
    """FedAvg of A's and B's separately — mathematically inexact (cross
    terms).  Heterogeneous ranks require HetLoRA zero-padding."""
    ranks = [get_path(c, adapter_leaf_paths(c)[0])["A"].shape[-2] for c in clients]
    R = max(ranks)
    if len(set(ranks)) > 1 and not zero_padding:
        raise ValueError("FedIT requires homogeneous ranks (or zero_padding=True)")
    out: Dict = {}
    rank_rec: Dict[Tuple, List[int]] = {}
    for path in adapter_leaf_paths(clients[0]):
        As, Bs = [], []
        for c in clients:
            Bk, Ak = _fold_scale(get_path(c, path))
            r = Ak.shape[-2]
            if r < R:
                padA = [(0, 0)] * Ak.ndim
                padA[-2] = (0, R - r)
                padB = [(0, 0)] * Bk.ndim
                padB[-1] = (0, R - r)
                Ak, Bk = jnp.pad(Ak, padA), jnp.pad(Bk, padB)
            As.append(Ak)
            Bs.append(Bk)
        A_avg = sum(w * A for w, A in zip(weights, As))
        B_avg = sum(w * B for w, B in zip(weights, Bs))
        ref = get_path(clients[0], path)["scale"]
        set_path(out, path, {"A": A_avg, "B": B_avg, "scale": _ones_scale(ref)})
        L = A_avg.shape[0] if A_avg.ndim == 3 else 1
        rank_rec[path] = [R] * L
    return AggResult("fedit", out, None, rank_rec, {})


def aggregate_ffa(clients: Sequence[Dict], weights: Sequence[float],
                  A_init: Dict, zero_padding: bool = False) -> AggResult:
    """FFA-LoRA: A frozen at init (shared), only B averaged."""
    out: Dict = {}
    rank_rec: Dict[Tuple, List[int]] = {}
    for path in adapter_leaf_paths(clients[0]):
        Bs = []
        ranks = []
        for c in clients:
            Bk, _ = _fold_scale(get_path(c, path))
            ranks.append(Bk.shape[-1])
            Bs.append(Bk)
        R = max(ranks)
        if len(set(ranks)) > 1 and not zero_padding:
            raise ValueError("FFA-LoRA requires homogeneous ranks (or zero_padding=True)")
        padded = []
        for Bk in Bs:
            r = Bk.shape[-1]
            if r < R:
                pad = [(0, 0)] * Bk.ndim
                pad[-1] = (0, R - r)
                Bk = jnp.pad(Bk, pad)
            padded.append(Bk)
        B_avg = sum(w * B for w, B in zip(weights, padded))
        a0 = get_path(A_init, path)
        A = a0["A"]
        r0 = A.shape[-2]
        if r0 < R:
            pad = [(0, 0)] * A.ndim
            pad[-2] = (0, R - r0)
            A = jnp.pad(A, pad)
        elif r0 > R:
            A = A[..., :R, :]
        set_path(out, path, {"A": A, "B": B_avg, "scale": _ones_scale(a0["scale"])})
        L = B_avg.shape[0] if B_avg.ndim == 3 else 1
        # only B travels; rank-equivalent download is R/2 per the paper's
        # half-parameter accounting (handled in costs.py)
        rank_rec[path] = [R] * L
    return AggResult("ffa", out, None, rank_rec, {})


def aggregate_flora(clients: Sequence[Dict], weights: Sequence[float]) -> AggResult:
    """FLoRA: stack everything, broadcast the stack (rank = Σ r_k); clients
    merge into the frozen base and re-init local adapters."""
    out: Dict = {}
    rank_rec: Dict[Tuple, List[int]] = {}
    for path in adapter_leaf_paths(clients[0]):
        Bs, As = [], []
        for c, w in zip(clients, weights):
            Bk, Ak = _fold_scale(get_path(c, path))
            Bs.append(Bk)
            As.append(w * Ak)
        B_stack = jnp.concatenate(Bs, axis=-1)
        A_stack = jnp.concatenate(As, axis=-2)
        ref = get_path(clients[0], path)["scale"]
        set_path(out, path, {"A": A_stack, "B": B_stack, "scale": _ones_scale(ref)})
        L = A_stack.shape[0] if A_stack.ndim == 3 else 1
        rank_rec[path] = [A_stack.shape[-2]] * L
    return AggResult("flora", out, None, rank_rec, {}, merge_into_base=True)


def aggregate_flexlora(clients: Sequence[Dict], weights: Sequence[float],
                       client_ranks: Sequence[int]) -> AggResult:
    """FlexLoRA: form the dense ΔW = Σ w_k B_k A_k per layer, full SVD, then
    cut per-client adapters at each client's own rank."""
    paths = adapter_leaf_paths(clients[0])
    per_client: List[Dict] = [{} for _ in clients]
    glob: Dict = {}
    rank_rec: Dict[Tuple, List[int]] = {}
    spectra: Dict[Tuple, List[np.ndarray]] = {}
    for path in paths:
        leaf0 = get_path(clients[0], path)["A"]
        stacked = leaf0.ndim == 3
        L = leaf0.shape[0] if stacked else 1
        Rmax = max(client_ranks)
        ub_l, sp_l, vt_l = [], [], []
        for l in range(L):
            dw = None
            for c, w in zip(clients, weights):
                Bk, Ak = _fold_scale(get_path(c, path))
                Bl, Al = _per_layer(Bk, l, stacked), _per_layer(Ak, l, stacked)
                term = w * (Bl.astype(jnp.float32) @ Al.astype(jnp.float32))
                dw = term if dw is None else dw + term
            u, s, vt = thin_svd(dw, "svd")
            ub_l.append(u)
            sp_l.append(s)
            vt_l.append(vt)
        spectra[path] = [np.asarray(s) for s in sp_l]
        rank_rec[path] = [min(Rmax, int(s.shape[0])) for s in sp_l]
        # global (exact) adapters at full rank — used for server-side eval
        r_full = sp_l[0].shape[0]
        Bg = jnp.stack([u * s[None, :] for u, s in zip(ub_l, sp_l)]) if stacked \
            else ub_l[0] * sp_l[0][None, :]
        Ag = jnp.stack(vt_l) if stacked else vt_l[0]
        ref = get_path(clients[0], path)["scale"]
        set_path(glob, path, {"A": Ag, "B": Bg, "scale": _ones_scale(ref)})
        # per-client truncations
        for ci, rk in enumerate(client_ranks):
            rr = min(rk, r_full)
            if stacked:
                Bc = jnp.stack([u[:, :rr] * s[None, :rr] for u, s in zip(ub_l, sp_l)])
                Ac = jnp.stack([vt[:rr] for vt in vt_l])
            else:
                Bc = ub_l[0][:, :rr] * sp_l[0][None, :rr]
                Ac = vt_l[0][:rr]
            if rr < rk:   # pad up to the client's rank
                padB = [(0, 0)] * Bc.ndim
                padB[-1] = (0, rk - rr)
                padA = [(0, 0)] * Ac.ndim
                padA[-2] = (0, rk - rr)
                Bc, Ac = jnp.pad(Bc, padB), jnp.pad(Ac, padA)
            set_path(per_client[ci], path,
                     {"A": Ac, "B": Bc, "scale": _ones_scale(ref)})
    return AggResult("flexlora", glob, per_client, rank_rec, spectra)


def aggregate_florist(clients: Sequence[Dict], weights: Sequence[float],
                      tau: float, svd_method: str = "svd",
                      max_rank: int = 0) -> AggResult:
    """FLoRIST (Algorithm 1, server block): stacked thin-SVDs + r×r core SVD
    + per-layer energy thresholding.  Ragged per-layer ranks are zero-padded
    to the per-leaf max so the global tree stays scan-compatible; the true
    ranks are recorded for communication accounting."""
    paths = adapter_leaf_paths(clients[0])
    out: Dict = {}
    rank_rec: Dict[Tuple, List[int]] = {}
    spectra: Dict[Tuple, List[np.ndarray]] = {}
    for path in paths:
        leaf0 = get_path(clients[0], path)["A"]
        stacked = leaf0.ndim == 3
        L = leaf0.shape[0] if stacked else 1
        Bg_l, Ag_l, ps = [], [], []
        spectra[path] = []
        for l in range(L):
            Bs, As = [], []
            for c in clients:
                Bk, Ak = _fold_scale(get_path(c, path))
                Bs.append(_per_layer(Bk, l, stacked))
                As.append(_per_layer(Ak, l, stacked))
            res = florist_core(Bs, As, weights, tau, svd_method, max_rank)
            Bg_l.append(res.B_g)
            Ag_l.append(res.A_g)
            ps.append(res.p)
            spectra[path].append(np.asarray(res.spectrum))
        p_max = max(ps)
        if stacked:
            Bg = jnp.stack([jnp.pad(b, ((0, 0), (0, p_max - b.shape[1]))) for b in Bg_l])
            Ag = jnp.stack([jnp.pad(a, ((0, p_max - a.shape[0]), (0, 0))) for a in Ag_l])
        else:
            Bg, Ag = Bg_l[0], Ag_l[0]
        ref = get_path(clients[0], path)["scale"]
        set_path(out, path, {"A": Ag, "B": Bg, "scale": _ones_scale(ref)})
        rank_rec[path] = ps
    return AggResult("florist", out, None, rank_rec, spectra)


# ---------------------------------------------------------------------------
# dispatcher
# ---------------------------------------------------------------------------

def aggregate(method: str, clients: Sequence[Dict], weights: Sequence[float],
              *, tau: float = 0.9, A_init: Optional[Dict] = None,
              client_ranks: Optional[Sequence[int]] = None,
              zero_padding: bool = False, svd_method: str = "svd",
              max_rank: int = 0) -> AggResult:
    if method == "fedit":
        return aggregate_fedit(clients, weights, zero_padding)
    if method == "ffa":
        assert A_init is not None
        return aggregate_ffa(clients, weights, A_init, zero_padding)
    if method == "flora":
        return aggregate_flora(clients, weights)
    if method == "flexlora":
        assert client_ranks is not None
        return aggregate_flexlora(clients, weights, client_ranks)
    if method == "florist":
        return aggregate_florist(clients, weights, tau, svd_method, max_rank)
    raise ValueError(f"unknown method {method!r} (choose from {METHODS})")
