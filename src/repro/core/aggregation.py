"""Legacy one-shot aggregation entry point (compatibility shim).

The aggregation layer lives in :mod:`repro.core.aggregators`: each method is
a registered :class:`~repro.core.aggregators.Aggregator` strategy with a
streaming ``begin_round`` / ``add_client`` / ``finalize`` lifecycle, its own
client-init semantics and its own cost model.  This module keeps the
original call shape — ``aggregate(method, clients, weights, **kw)`` — as a
thin wrapper that builds the registered strategy and runs the streaming
lifecycle over the in-memory client list, so existing callers and tests
keep working unchanged.

A client update is an adapter tree whose LoRA leaves are
``{"A": (L, r_k, n), "B": (L, m, r_k), "scale": (L,)}`` (or un-stacked 2-D
for shared blocks).  Aggregation is per-(leaf, layer).  Client ``scale`` is
folded into ``B`` before aggregation so methods compare the same effective
updates ``ΔW_k = scale_k · B_k A_k``; all global adapters carry scale 1.

Host-side code (concrete ragged ranks).  The jit/shard_map multi-pod path
lives in :mod:`repro.core.distributed`.
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence

# re-exported for callers that still import the tree plumbing from here
from repro.core.aggregators import (AggResult, METHODS, accepted_config,
                                    adapter_leaf_paths, get_path,
                                    make_aggregator, set_path)

__all__ = ["AggResult", "METHODS", "adapter_leaf_paths", "aggregate",
           "get_path", "set_path"]


def aggregate(method: str, clients: Sequence[Dict], weights: Sequence[float],
              *, tau: float = 0.9, A_init: Optional[Dict] = None,
              client_ranks: Optional[Sequence[int]] = None,
              zero_padding: bool = False, svd_method: str = "svd",
              max_rank: int = 0) -> AggResult:
    """One-shot aggregation: build the registered strategy for ``method``
    and stream the client list through it.  Each method picks the knobs it
    understands from the shared kwarg union (τ, the frozen FFA init, ...)."""
    cfg = accepted_config(method, dict(
        tau=tau, A_init=A_init, zero_padding=zero_padding,
        svd_method=svd_method, max_rank=max_rank))
    agg = make_aggregator(method, **cfg)
    return agg.aggregate(clients, weights, client_ranks=client_ranks)
