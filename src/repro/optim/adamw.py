"""Optimizers in pure JAX (no optax): AdamW, SGD+momentum, schedules,
global-norm clipping.  Operated over the *adapter* tree only — the base model
is frozen in LoRA fine-tuning, so no optimizer state exists for it.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.common.config import OptimConfig


def schedule(cfg: OptimConfig, step: jnp.ndarray) -> jnp.ndarray:
    s = step.astype(jnp.float32)
    lr = jnp.asarray(cfg.lr, jnp.float32)
    if cfg.warmup_steps:
        warm = jnp.minimum(1.0, (s + 1) / cfg.warmup_steps)
    else:
        warm = 1.0
    if cfg.schedule == "cosine":
        t = jnp.clip((s - cfg.warmup_steps) /
                     max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
        decay = 0.5 * (1 + jnp.cos(jnp.pi * t))
    elif cfg.schedule == "linear":
        t = jnp.clip((s - cfg.warmup_steps) /
                     max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
        decay = 1.0 - t
    else:
        decay = 1.0
    return lr * warm * decay


def clip_by_global_norm(grads: Any, max_norm: float) -> Tuple[Any, jnp.ndarray]:
    leaves = jax.tree.leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), gnorm


def adamw_init(params: Any) -> Dict:
    zeros = lambda: jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
    return {"mu": zeros(), "nu": zeros(), "step": jnp.zeros((), jnp.int32)}


def adamw_update(cfg: OptimConfig, grads: Any, state: Dict, params: Any
                 ) -> Tuple[Any, Dict]:
    step = state["step"] + 1
    if cfg.grad_clip:
        grads, _ = clip_by_global_norm(grads, cfg.grad_clip)
    b1, b2 = cfg.betas
    lr = schedule(cfg, step)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mh = m / (1 - b1 ** step.astype(jnp.float32))
        vh = v / (1 - b2 ** step.astype(jnp.float32))
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if cfg.weight_decay:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_g, tdef = jax.tree.flatten(grads)
    flat_m = tdef.flatten_up_to(state["mu"])
    flat_v = tdef.flatten_up_to(state["nu"])
    flat_p = tdef.flatten_up_to(params)
    new_p, new_m, new_v = [], [], []
    for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p):
        np_, nm, nv = upd(g, m, v, p)
        new_p.append(np_); new_m.append(nm); new_v.append(nv)
    return (tdef.unflatten(new_p),
            {"mu": tdef.unflatten(new_m), "nu": tdef.unflatten(new_v), "step": step})


def sgd_init(params: Any) -> Dict:
    return {"mu": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params),
            "step": jnp.zeros((), jnp.int32)}


def sgd_update(cfg: OptimConfig, grads: Any, state: Dict, params: Any,
               momentum: float = 0.9) -> Tuple[Any, Dict]:
    step = state["step"] + 1
    if cfg.grad_clip:
        grads, _ = clip_by_global_norm(grads, cfg.grad_clip)
    lr = schedule(cfg, step)
    mu = jax.tree.map(lambda m, g: momentum * m + g.astype(jnp.float32),
                      state["mu"], grads)
    params = jax.tree.map(lambda p, m: (p.astype(jnp.float32) - lr * m).astype(p.dtype),
                          params, mu)
    return params, {"mu": mu, "step": step}
