"""Structural hazards: jit misuse (re-jit in loops, non-hashable static
args) and mutable default pytrees.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterator, Set

from repro.analysis.astutils import (ModuleInfo, resolve, JIT_NAMES,
                                     _partial_of_jit, _static_argnames)
from repro.analysis.lint import Finding
from repro.analysis.rules import register_rule


@register_rule(
    "jit-in-loop",
    "jax.jit called inside a Python loop body (re-traces every iteration)")
def jit_in_loop(mod: ModuleInfo) -> Iterator[Finding]:
    for loop in ast.walk(mod.tree):
        if not isinstance(loop, (ast.For, ast.While)):
            continue
        for node in ast.walk(loop):
            if node is loop:
                continue
            # nested defs inside the loop only *define*, not call
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if isinstance(node, ast.Call):
                fq = resolve(node.func, mod.imports)
                if fq in JIT_NAMES or _partial_of_jit(node, mod.imports):
                    yield Finding(
                        rule="jit-in-loop", path=mod.relpath,
                        line=node.lineno, col=node.col_offset,
                        message="jax.jit inside a loop body builds a fresh "
                                "jitted callable (and cache entry) every "
                                "iteration — hoist or memoize it")


_UNHASHABLE = (ast.List, ast.Dict, ast.Set, ast.ListComp, ast.DictComp,
               ast.SetComp)


def _jitted_static_params(mod: ModuleInfo) -> Dict[str, Set[str]]:
    """name -> static param names, for defs with a jit-like decorator."""
    out: Dict[str, Set[str]] = {}
    for fn in ast.walk(mod.tree):
        if not isinstance(fn, ast.FunctionDef):
            continue
        for dec in fn.decorator_list:
            call = dec if isinstance(dec, ast.Call) else None
            if call is None:
                continue
            if resolve(call.func, mod.imports) in JIT_NAMES:
                out[fn.name] = _static_argnames(call)
            else:
                p = _partial_of_jit(call, mod.imports)
                if p is not None:
                    out[fn.name] = _static_argnames(p)
    return out


@register_rule(
    "nonhashable-static-arg",
    "list/dict/set passed for a static jit argument (TypeError at call "
    "time, or silent retrace churn via unstable hashes)")
def nonhashable_static_arg(mod: ModuleInfo) -> Iterator[Finding]:
    static = _jitted_static_params(mod)
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Call):
            continue
        # direct call of a module-local jitted function
        if isinstance(node.func, ast.Name) and node.func.id in static:
            names = static[node.func.id]
            for kw in node.keywords:
                if kw.arg in names and isinstance(kw.value, _UNHASHABLE):
                    yield Finding(
                        rule="nonhashable-static-arg", path=mod.relpath,
                        line=kw.value.lineno, col=kw.value.col_offset,
                        message=f"static argument `{kw.arg}` of "
                                f"`{node.func.id}` gets a non-hashable "
                                f"literal — pass a tuple / frozen value")
        # jax.jit(f, static_argnames=...) with unhashable *bound* args via
        # functools.partial(f, cfg=[...])-style wrapping
        fq = resolve(node.func, mod.imports)
        if fq in ("functools.partial", "partial") and node.args:
            tgt = node.args[0]
            if isinstance(tgt, ast.Name) and tgt.id in static:
                names = static[tgt.id]
                for kw in node.keywords:
                    if kw.arg in names and isinstance(kw.value, _UNHASHABLE):
                        yield Finding(
                            rule="nonhashable-static-arg", path=mod.relpath,
                            line=kw.value.lineno, col=kw.value.col_offset,
                            message=f"static argument `{kw.arg}` of "
                                    f"`{tgt.id}` bound to a non-hashable "
                                    f"literal in functools.partial")


@register_rule(
    "mutable-default-pytree",
    "mutable (or device-array) default argument values")
def mutable_default_pytree(mod: ModuleInfo) -> Iterator[Finding]:
    for fn in ast.walk(mod.tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        defaults = (fn.args.defaults
                    + [d for d in fn.args.kw_defaults if d is not None])
        for d in defaults:
            what = None
            if isinstance(d, _UNHASHABLE):
                what = "mutable literal"
            elif isinstance(d, ast.Call):
                fq = resolve(d.func, mod.imports) or ""
                if fq.startswith(("jax.numpy.", "numpy.", "jax.")):
                    what = f"`{fq}` call (evaluated once, at import time)"
            if what:
                yield Finding(
                    rule="mutable-default-pytree", path=mod.relpath,
                    line=d.lineno, col=d.col_offset,
                    message=f"default value of `{fn.name}` is a {what}: "
                            f"shared across calls — default to None and "
                            f"build inside the function")
