"""Rules over *traced* function bodies: retrace / concretization hazards
and host synchronization in the jitted hot loops.

``host-branch-on-traced`` — Python control flow (``if`` / ``while`` /
``assert``) or explicit concretization (``bool()`` / ``int()`` / ``float()``
/ ``.item()`` / ``.tolist()``) on a value that flows from a traced function
parameter.  Under ``jit`` these either raise ``ConcretizationTypeError`` or
— worse — silently bake a host value into the compiled program and retrace
on every change.

``host-sync-in-hot-loop`` — ``jax.device_get`` / ``block_until_ready`` /
``np.asarray`` in a function reachable from a traced entrypoint: a device
round-trip in the decode burst serializes the dispatch pipeline.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Set

from repro.analysis.astutils import (ModuleInfo, TracedFn, assign_targets,
                                     direct_taint, param_names, resolve,
                                     taints_through, traced_functions)
from repro.analysis.lint import Finding
from repro.analysis.rules import register_rule

_CONCRETIZERS = ("bool", "int", "float", "complex")
_ITEM_METHODS = ("item", "tolist", "__bool__", "__int__", "__float__")

_SYNC_QUALNAMES = frozenset({
    "jax.device_get", "jax.block_until_ready",
    "numpy.asarray", "numpy.array", "numpy.copy",
})


class _TaintScan:
    """One pass over a traced function: propagate taint statement by
    statement, flag host branches / concretizations on tainted values."""

    def __init__(self, mod: ModuleInfo, traced: TracedFn, rule: str):
        self.mod = mod
        self.rule = rule
        self.findings: List[Finding] = []
        fn = traced.node
        self.tainted: Set[str] = (
            set(param_names(fn)) - traced.static_names - {"self"})
        self.reason = traced.reason
        self._scan(fn.body)

    def _flag(self, node: ast.AST, what: str) -> None:
        self.findings.append(Finding(
            rule=self.rule, path=self.mod.relpath, line=node.lineno,
            col=node.col_offset,
            message=f"{what} on a traced value inside a traced function "
                    f"({self.reason}): retrace / ConcretizationTypeError "
                    f"hazard"))

    def _taints(self, node: ast.expr) -> bool:
        return taints_through(node, self.tainted, self.mod.imports)

    def _direct(self, node: ast.expr) -> bool:
        return direct_taint(node, self.tainted, self.mod.imports)

    def _scan_expr(self, node: ast.expr) -> None:
        for call in (n for n in ast.walk(node) if isinstance(n, ast.Call)):
            f = call.func
            if (isinstance(f, ast.Name) and f.id in _CONCRETIZERS
                    and call.args and self._direct(call.args[0])):
                self._flag(call, f"{f.id}()")
            elif (isinstance(f, ast.Attribute) and f.attr in _ITEM_METHODS
                    and self._direct(f.value)):
                self._flag(call, f".{f.attr}()")

    def _scan(self, body: List[ast.stmt]) -> None:
        for stmt in body:
            if isinstance(stmt, (ast.If, ast.While)):
                if self._direct(stmt.test):
                    kw = "if" if isinstance(stmt, ast.If) else "while"
                    self._flag(stmt, f"Python `{kw}`")
                self._scan_expr(stmt.test)
                self._scan(stmt.body)
                self._scan(stmt.orelse)
            elif isinstance(stmt, ast.Assert):
                if self._direct(stmt.test):
                    self._flag(stmt, "`assert`")
                self._scan_expr(stmt.test)
            elif isinstance(stmt, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
                value = stmt.value
                if value is not None:
                    self._scan_expr(value)
                    if self._taints(value):
                        self.tainted |= set(assign_targets(stmt))
            elif isinstance(stmt, ast.For):
                self._scan_expr(stmt.iter)
                if self._taints(stmt.iter):
                    self.tainted |= {n.id for n in ast.walk(stmt.target)
                                     if isinstance(n, ast.Name)}
                self._scan(stmt.body)
                self._scan(stmt.orelse)
            elif isinstance(stmt, ast.FunctionDef):
                # nested defs trace too; their params carry traced values
                self.tainted |= set(param_names(stmt)) - {"self"}
                self._scan(stmt.body)
            elif isinstance(stmt, (ast.Return, ast.Expr)):
                if stmt.value is not None:
                    self._scan_expr(stmt.value)
            elif isinstance(stmt, (ast.With,)):
                for item in stmt.items:
                    self._scan_expr(item.context_expr)
                self._scan(stmt.body)
            elif isinstance(stmt, ast.Try):
                self._scan(stmt.body)
                for h in stmt.handlers:
                    self._scan(h.body)
                self._scan(stmt.orelse)
                self._scan(stmt.finalbody)


@register_rule(
    "host-branch-on-traced",
    "Python control flow / bool()/int()/float()/.item() on traced values")
def host_branch_on_traced(mod: ModuleInfo) -> Iterator[Finding]:
    seen = set()
    for traced in traced_functions(mod):
        for f in _TaintScan(mod, traced, "host-branch-on-traced").findings:
            key = (f.line, f.col, f.message)
            if key not in seen:       # nested defs are scanned once per parent
                seen.add(key)
                yield f


def _local_call_graph(mod: ModuleInfo) -> Dict[str, Set[str]]:
    """name -> called local names, approximated by bare-Name calls."""
    defs = {n.name: n for n in ast.walk(mod.tree)
            if isinstance(n, ast.FunctionDef)}
    graph: Dict[str, Set[str]] = {}
    for name, fn in defs.items():
        calls = set()
        for node in ast.walk(fn):
            if isinstance(node, ast.Call) and isinstance(node.func, ast.Name) \
                    and node.func.id in defs:
                calls.add(node.func.id)
        graph[name] = calls
    return graph


@register_rule(
    "host-sync-in-hot-loop",
    "device_get / block_until_ready / np.asarray reachable from a traced "
    "entrypoint")
def host_sync_in_hot_loop(mod: ModuleInfo) -> Iterator[Finding]:
    traced = traced_functions(mod)
    if not traced:
        return
    graph = _local_call_graph(mod)
    defs = {n.name: n for n in ast.walk(mod.tree)
            if isinstance(n, ast.FunctionDef)}
    reachable = {t.node.name for t in traced}
    frontier = list(reachable)
    while frontier:
        nxt = frontier.pop()
        for callee in graph.get(nxt, ()):
            if callee not in reachable:
                reachable.add(callee)
                frontier.append(callee)
    seen = set()
    for name in sorted(reachable):
        fn = defs.get(name)
        if fn is None:
            continue
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            fq = resolve(node.func, mod.imports)
            bad = None
            if fq in _SYNC_QUALNAMES:
                bad = fq
            elif (isinstance(node.func, ast.Attribute)
                    and node.func.attr == "block_until_ready"):
                bad = ".block_until_ready()"
            if bad and node.lineno not in seen:
                seen.add(node.lineno)
                yield Finding(
                    rule="host-sync-in-hot-loop", path=mod.relpath,
                    line=node.lineno, col=node.col_offset,
                    message=f"{bad} in `{name}`, reachable from a jitted "
                            f"hot loop: forces a host sync / device "
                            f"round-trip per step")
