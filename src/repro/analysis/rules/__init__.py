"""Lint rule registry.

A rule is a function ``(module: ModuleInfo) -> Iterator[Finding]``
registered under a stable kebab-case name (the name users suppress with
``# repro-lint: disable=<rule>``).  Importing this package loads every
built-in rule module.
"""
from __future__ import annotations

import importlib
from typing import Callable, Dict, Iterator, List

_RULES: Dict[str, Callable] = {}
_DOCS: Dict[str, str] = {}

_BUILTIN_MODULES = ("retrace", "imports", "structure")


def register_rule(name: str, doc: str = ""):
    """Decorator: register ``fn`` as lint rule ``name``."""

    def deco(fn):
        if name in _RULES:
            raise ValueError(f"duplicate lint rule {name!r}")
        _RULES[name] = fn
        _DOCS[name] = doc or (fn.__doc__ or "").strip().splitlines()[0]
        fn.rule_name = name
        return fn

    return deco


def all_rules() -> Dict[str, Callable]:
    _load()
    return dict(_RULES)


def rule_docs() -> Dict[str, str]:
    _load()
    return dict(_DOCS)


def _load() -> None:
    for m in _BUILTIN_MODULES:
        importlib.import_module(f"{__name__}.{m}")
