"""Import-surface rules: device compute at module import time, and internal
imports that bypass :mod:`repro.topology` via the ``launch/`` shims.
"""
from __future__ import annotations

import ast
from typing import Iterator

from repro.analysis.astutils import ModuleInfo, resolve
from repro.analysis.lint import Finding
from repro.analysis.rules import register_rule

#: jax namespaces whose *calls* allocate device buffers / build tracers —
#: at module scope they run at import time, before XLA_FLAGS management or
#: mesh setup, and pin arrays to the default device
_COMPUTE_PREFIXES = ("jax.numpy.", "jax.random.", "jax.nn.", "jax.lax.")
#: metadata-only callables that are safe at import time
_SAFE_SUFFIXES = (".dtype",)

_SHIM_MODULES = ("repro.launch.mesh", "repro.launch.sharding")


def _module_scope_calls(tree: ast.Module) -> Iterator[ast.Call]:
    """Calls executed at import: module-level statements, class bodies and
    default-argument expressions — but NOT function bodies or the
    ``if __name__ == "__main__"`` block."""

    def is_main_guard(node: ast.stmt) -> bool:
        return (isinstance(node, ast.If)
                and isinstance(node.test, ast.Compare)
                and isinstance(node.test.left, ast.Name)
                and node.test.left.id == "__name__")

    def scan(body) -> Iterator[ast.Call]:
        for stmt in body:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                # default-arg expressions also run at import, but the
                # mutable-default-pytree rule owns that report
                continue
            if is_main_guard(stmt):
                continue
            if isinstance(stmt, ast.ClassDef):
                yield from scan(stmt.body)
                continue
            for n in ast.walk(stmt):
                if isinstance(n, ast.Call):
                    yield n

    yield from scan(tree.body)


@register_rule(
    "import-time-jax-compute",
    "jnp./jax.random/jax.nn calls at module import time")
def import_time_jax_compute(mod: ModuleInfo) -> Iterator[Finding]:
    for call in _module_scope_calls(mod.tree):
        fq = resolve(call.func, mod.imports)
        if not fq:
            continue
        if fq.endswith(_SAFE_SUFFIXES):
            continue
        if any(fq.startswith(p) for p in _COMPUTE_PREFIXES) \
                or fq in ("jax.jit", "jax.device_put"):
            yield Finding(
                rule="import-time-jax-compute", path=mod.relpath,
                line=call.lineno, col=call.col_offset,
                message=f"`{fq}` runs at module import time: allocates on "
                        f"the default device before flag/mesh setup and "
                        f"breaks jax-free importability")


@register_rule(
    "topology-shim-bypass",
    "internal imports of repro.launch.mesh/sharding instead of "
    "repro.topology")
def topology_shim_bypass(mod: ModuleInfo) -> Iterator[Finding]:
    # the shims themselves (and this package) are exempt
    rel = mod.relpath.replace("\\", "/")
    if rel.endswith(("launch/mesh.py", "launch/sharding.py")):
        return
    for node in ast.walk(mod.tree):
        hit = None
        if isinstance(node, ast.Import):
            hit = next((a.name for a in node.names
                        if a.name in _SHIM_MODULES), None)
        elif isinstance(node, ast.ImportFrom) and node.module:
            if node.module in _SHIM_MODULES:
                hit = node.module
            elif node.module == "repro.launch" and any(
                    a.name in ("mesh", "sharding") for a in node.names):
                hit = "repro.launch"
        if hit:
            yield Finding(
                rule="topology-shim-bypass", path=mod.relpath,
                line=node.lineno, col=node.col_offset,
                message=f"import of deprecated shim `{hit}`: import from "
                        f"repro.topology instead")
