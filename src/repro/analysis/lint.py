"""``repro-lint`` — the AST lint driver.

Pure-``ast`` (no jax import), so it runs anywhere, instantly::

    repro-lint src/                      # lint a tree (exit 1 on findings)
    repro-lint --list-rules              # rule catalog
    repro-lint --select host-branch-on-traced src/repro/serve/engine.py

Suppression is inline, per line, with a justification comment::

    x = int(flag)  # repro-lint: disable=host-branch-on-traced -- host flag

``disable=all`` silences every rule on the line.  Unsuppressed findings
fail the build (this is wired as a tier-1 CI job).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
from typing import Iterable, List, Optional, Sequence

from repro.analysis.astutils import ModuleInfo


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str
    path: str
    line: int
    col: int
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line}:{self.col + 1}: " \
               f"[{self.rule}] {self.message}"


def _iter_files(paths: Sequence[str]) -> Iterable[str]:
    for p in paths:
        if os.path.isdir(p):
            for root, dirs, files in os.walk(p):
                dirs[:] = sorted(d for d in dirs
                                 if d not in ("__pycache__", ".git"))
                for f in sorted(files):
                    if f.endswith(".py"):
                        yield os.path.join(root, f)
        else:
            yield p


def lint_source(source: str, path: str = "<string>",
                select: Optional[Sequence[str]] = None,
                relpath: Optional[str] = None) -> List[Finding]:
    """Lint one source string; suppressions applied.  ``select`` limits to
    the named rules."""
    from repro.analysis.rules import all_rules
    mod = ModuleInfo.parse(path, source, relpath=relpath)
    rules = all_rules()
    if select:
        unknown = set(select) - set(rules)
        if unknown:
            raise ValueError(f"unknown lint rule(s): {sorted(unknown)}")
        rules = {k: v for k, v in rules.items() if k in select}
    findings = []
    for name, rule in sorted(rules.items()):
        for f in rule(mod):
            if not mod.suppressed(name, f.line):
                findings.append(f)
    return sorted(findings, key=lambda f: (f.path, f.line, f.col, f.rule))


def lint_paths(paths: Sequence[str],
               select: Optional[Sequence[str]] = None) -> List[Finding]:
    findings: List[Finding] = []
    root = os.path.commonpath([os.path.abspath(p) for p in paths]) \
        if paths else os.getcwd()
    for fp in _iter_files(paths):
        rel = os.path.relpath(os.path.abspath(fp), root) \
            if os.path.isdir(root) else fp
        with open(fp, "r", encoding="utf-8") as fh:
            src = fh.read()
        try:
            findings.extend(lint_source(src, path=fp, select=select,
                                        relpath=fp))
        except SyntaxError as e:
            findings.append(Finding(rule="syntax-error", path=fp,
                                    line=e.lineno or 0, col=e.offset or 0,
                                    message=str(e.msg)))
    return findings


def main(argv: Optional[Sequence[str]] = None) -> int:
    from repro.analysis.rules import rule_docs
    ap = argparse.ArgumentParser(
        prog="repro-lint",
        description="JAX-aware static lint for the repro codebase")
    ap.add_argument("paths", nargs="*", default=["src"],
                    help="files or directories to lint (default: src)")
    ap.add_argument("--select", action="append", default=None,
                    metavar="RULE", help="run only the named rule(s)")
    ap.add_argument("--list-rules", action="store_true")
    ap.add_argument("--json", action="store_true",
                    help="machine-readable findings on stdout")
    args = ap.parse_args(argv)

    if args.list_rules:
        for name, doc in sorted(rule_docs().items()):
            print(f"{name:28s} {doc}")
        return 0

    findings = lint_paths(args.paths or ["src"], select=args.select)
    if args.json:
        print(json.dumps([dataclasses.asdict(f) for f in findings],
                         indent=2))
    else:
        for f in findings:
            print(f.render())
        n = len(findings)
        print(f"repro-lint: {n} finding{'s' if n != 1 else ''}"
              if n else "repro-lint: clean")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
