"""Abstract contract checker: run every registered contract across its
config-matrix slice with ``jax.eval_shape`` / ``jax.make_jaxpr`` only.

Zero FLOPs execute — each case traces the entrypoint abstractly and then
asserts:

* the contract's declared output invariant (``out_check``), e.g. the
  engine step's fixed point: output cache/state avals identical to the
  inputs (the property that makes the decode hot loop retrace-free);
* the kernel ↔ XLA-twin aval identity (``twin``);
* partition specs fit their arrays and divide evenly at the case's mesh
  width, validated on a device-free ``AbstractMesh``;
* jaxpr-level bans: no float64 anywhere in the traced computation (the
  jaxpr is traced under ``enable_x64`` so silent canonicalization cannot
  mask an upcast) and no host callbacks in the hot path.

CLI (used by the CI ``analysis`` job)::

    python -m repro.analysis.contracts [--select SUBSTR] [--list] [--json]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import jax.tree_util as jtu
from jax.sharding import PartitionSpec

from repro.analysis.registry import (Case, ContractCase, _Entry,
                                     contract_entries, load_registrations)

#: callback primitives banned from jitted hot paths (each one is a host
#: round-trip per dispatch)
BANNED_CALLBACK_PRIMS = frozenset(
    {"pure_callback", "io_callback", "debug_callback", "callback"})


# -- jaxpr walking -----------------------------------------------------------

def iter_eqns(jaxpr):
    """Every eqn in ``jaxpr`` and its nested sub-jaxprs (pjit bodies, scan
    bodies, cond branches, custom_vjp calls, ...)."""
    for eqn in jaxpr.eqns:
        yield eqn
        for sub in _sub_jaxprs(eqn.params):
            yield from iter_eqns(sub)


def _sub_jaxprs(params: Dict[str, Any]):
    for val in params.values():
        for v in (val if isinstance(val, (list, tuple)) else (val,)):
            inner = getattr(v, "jaxpr", None)
            if inner is not None and hasattr(inner, "eqns"):
                yield inner          # ClosedJaxpr
            elif hasattr(v, "eqns"):
                yield v              # raw Jaxpr


def jaxpr_violations(closed, *, forbid_f64: bool = True,
                     forbid_callbacks: bool = True) -> List[str]:
    """Scan a ClosedJaxpr for banned float64 values and callback prims.

    f64 is judged on eqn *outputs* and consts only: weak-typed python
    float literals trace as scalar ``f64[]`` operands under x64 and get
    converted straight down to f32 — those are benign and ignored.
    """
    out: List[str] = []
    if forbid_f64:
        for cv in closed.consts:
            if getattr(jnp.asarray(cv), "dtype", None) == jnp.float64:
                out.append("float64 constant captured in jaxpr")
    for eqn in iter_eqns(closed.jaxpr):
        name = eqn.primitive.name
        if forbid_callbacks and name in BANNED_CALLBACK_PRIMS:
            out.append(f"banned callback primitive {name!r} in jaxpr")
        if forbid_f64:
            for var in eqn.outvars:
                aval = getattr(var, "aval", None)
                dt = getattr(aval, "dtype", None)
                if dt == jnp.float64:
                    out.append(
                        f"float64 value {aval.str_short()} produced by "
                        f"{name!r} (fp32-explicit repo: no f64 upcasts)")
    return out


# -- pspec validation --------------------------------------------------------

def _spec_axes(entry) -> Tuple[str, ...]:
    if entry is None:
        return ()
    return tuple(entry) if isinstance(entry, tuple) else (entry,)


def pspec_violations(tree: Any, specs: Any, mesh) -> List[str]:
    """Check a (arrays, PartitionSpecs) pair against a mesh's axis sizes.

    ``mesh`` only needs ``.shape`` (name -> size), so an ``AbstractMesh``
    works — specs validate at mesh widths the host cannot build."""
    sizes = dict(mesh.shape)
    out: List[str] = []

    def leaf_path(path) -> str:
        return jtu.keystr(path) or "<root>"

    def check(path, arr, spec):
        if spec is None:
            return
        if not isinstance(spec, PartitionSpec):
            out.append(f"{leaf_path(path)}: spec {spec!r} is not a "
                       "PartitionSpec")
            return
        shape = tuple(arr.shape)
        if len(spec) > len(shape):
            out.append(f"{leaf_path(path)}: spec {spec} has more axes than "
                       f"array rank {len(shape)}")
            return
        for dim, entry in enumerate(spec):
            prod = 1
            for name in _spec_axes(entry):
                if name not in sizes:
                    out.append(f"{leaf_path(path)}: unknown mesh axis "
                               f"{name!r} in {spec}")
                    continue
                prod *= sizes[name]
            if prod > 1 and shape[dim] % prod:
                out.append(
                    f"{leaf_path(path)}: dim {dim} of shape {shape} not "
                    f"divisible by mesh extent {prod} ({spec})")

    jtu.tree_map_with_path(check, tree, specs,
                           is_leaf=lambda x: x is None)
    return out


# -- the runner --------------------------------------------------------------

@dataclasses.dataclass
class CaseResult:
    contract: str
    case: str
    status: str                      # "ok" | "skip" | "fail"
    errors: List[str]
    seconds: float

    def line(self) -> str:
        mark = {"ok": "ok", "skip": "-", "fail": "FAIL"}[self.status]
        return f"{self.contract:28s} {self.case:22s} {mark:4s} " \
               f"{self.seconds:5.2f}s"


#: abstract-eval results shared across mesh sizes: tracing is independent
#: of the mesh (only pspec validation varies), so each (contract, family,
#: impl) traces once
_TRACE_CACHE: Dict[Tuple[str, str, str], Tuple[Any, List[str]]] = {}


def _trace(name: str, case: Case, cc: ContractCase):
    key = (name, case.family, case.decode_impl)
    hit = _TRACE_CACHE.get(key)
    if hit is not None:
        return hit
    out = jax.eval_shape(cc.fn, *cc.args)
    with jax.experimental.enable_x64():
        closed = jax.make_jaxpr(cc.fn)(*cc.args)
    bans = jaxpr_violations(closed, forbid_f64=cc.forbid_f64,
                            forbid_callbacks=cc.forbid_callbacks)
    if cc.twin is not None:
        twin_fn, twin_args = cc.twin
        twin_out = jax.eval_shape(twin_fn, *twin_args)
        from repro.analysis.fixtures import avals_equal
        if not avals_equal(out, twin_out):
            bans.append(
                "kernel/twin aval mismatch: "
                f"{jtu.tree_map(lambda x: (tuple(x.shape), str(x.dtype)), out)}"
                " vs "
                f"{jtu.tree_map(lambda x: (tuple(x.shape), str(x.dtype)), twin_out)}")
    _TRACE_CACHE[key] = (out, bans)
    return out, bans


def run_case(entry: _Entry, case: Case) -> CaseResult:
    t0 = time.perf_counter()
    try:
        cc = entry.build(case)
        if cc is None:
            return CaseResult(entry.name, case.label(), "skip", [],
                              time.perf_counter() - t0)
        out, bans = _trace(entry.name, case, cc)
        errors = list(bans)
        if cc.out_check is not None:
            try:
                cc.out_check(out, case)
            except AssertionError as e:
                errors.append(f"out_check failed: {e}")
        if cc.pspec_tree is not None:
            if cc.mesh is None:
                errors.append("pspec_tree given without a mesh")
            else:
                errors.extend(pspec_violations(*cc.pspec_tree, cc.mesh))
    except Exception as e:            # build/trace blew up — that IS a failure
        errors = [f"{type(e).__name__}: {e}"]
    status = "fail" if errors else "ok"
    return CaseResult(entry.name, case.label(), status, errors,
                      time.perf_counter() - t0)


def run_all(select: Optional[str] = None) -> List[CaseResult]:
    load_registrations()
    results = []
    for name, entry in sorted(contract_entries().items()):
        if select and select not in name:
            continue
        for case in entry.cases():
            results.append(run_case(entry, case))
    return results


def main(argv: Optional[List[str]] = None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis.contracts",
        description="Abstract (zero-FLOP) contract checker.")
    p.add_argument("--select", help="substring filter on contract names")
    p.add_argument("--list", action="store_true",
                   help="list registered contracts and exit")
    p.add_argument("--json", action="store_true", dest="as_json")
    args = p.parse_args(argv)

    if args.list:
        for name in load_registrations():
            print(name)
        return 0

    t0 = time.perf_counter()
    results = run_all(args.select)
    failed = [r for r in results if r.status == "fail"]
    if args.as_json:
        print(json.dumps([dataclasses.asdict(r) for r in results], indent=2))
    else:
        for r in results:
            print(r.line())
            for err in r.errors:
                print(f"    {err}")
        ok = sum(r.status == "ok" for r in results)
        skipped = sum(r.status == "skip" for r in results)
        print(f"{ok} ok, {skipped} skipped, {len(failed)} failed "
              f"in {time.perf_counter() - t0:.1f}s "
              f"({len(contract_entries())} contracts)")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
