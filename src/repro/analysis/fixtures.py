"""Abstract fixtures for the contract checker.

Everything here is built with ``jax.eval_shape`` or raw
``ShapeDtypeStruct``s — no device arrays are ever materialized, so the
checker stays zero-FLOP even for the full config matrix.

The per-family configs are the repo's own SMOKE variants (the same ones
the test suite traces), so a contract failure here reproduces with the
exact configs a developer already knows how to run.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax import ShapeDtypeStruct
from jax.sharding import AbstractMesh

from repro.common.config import ModelConfig
from repro.configs import get_smoke_config, lora_targets
from repro.models import transformer as T

#: config-matrix family -> smoke architecture exercising it
FAMILY_SMOKE = {
    "gqa": "qwen3-4b",            # dense, GQA + qk_norm
    "mla": "deepseek-v3-671b",    # MLA latent cache + MoE blocks
    "moe": "granite-moe-1b-a400m",
    "ssm": "rwkv6-1.6b",          # attention-free recurrence
}

#: engine geometry shared by every serving contract
BATCH_SLOTS = 4
CAPACITY = 32
CHUNK = 4
OUT_CAP = 64


def sds(shape, dtype) -> ShapeDtypeStruct:
    return ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def tiny_config(family: str) -> ModelConfig:
    return get_smoke_config(FAMILY_SMOKE[family])


def chunk_width(cfg: ModelConfig) -> int:
    """SSM/RWKV decode is a single-token recurrence; attention families
    take whole chunks (mirrors ``ServeEngine.__init__``)."""
    return 1 if cfg.family in ("ssm", "hybrid") else CHUNK


def abstract_mesh(model: int) -> AbstractMesh:
    """A device-free serve-shaped mesh: pspec rules only read axis sizes,
    so divisibility validates at any mesh width on a 1-device host."""
    return AbstractMesh((("data", 1), ("model", model)))


def abstract_fed_mesh(data: int) -> AbstractMesh:
    """A device-free fed-shaped mesh (data=N, model=1): the client-parallel
    cohort specs validate at any data width on a 1-device host."""
    return AbstractMesh((("data", data), ("model", 1)))


def abstract_params(cfg: ModelConfig):
    return jax.eval_shape(partial(T.init, cfg), sds((2,), jnp.uint32))


def abstract_cache(cfg: ModelConfig, batch: int = BATCH_SLOTS,
                   capacity: int = CAPACITY, kv_dtype=None):
    kv_dtype = kv_dtype or jnp.dtype(cfg.dtype)
    return jax.eval_shape(
        lambda: T.init_cache(cfg, batch, capacity, kv_dtype,
                             prefill_chunk=chunk_width(cfg)))


def abstract_adapters(cfg: ModelConfig, params: Any, rank: int = 4,
                      alpha: float = 8.0):
    from repro.peft.lora import init_lora
    return jax.eval_shape(
        lambda p, k: init_lora(p, lora_targets(cfg), rank, alpha, k),
        params, sds((2,), jnp.uint32))


def engine_state(batch: int = BATCH_SLOTS, capacity: int = CAPACITY,
                 out_cap: int = OUT_CAP) -> Dict[str, ShapeDtypeStruct]:
    """Aval mirror of the ``ServeEngine`` slot-state dict.

    Kept in lockstep with ``ServeEngine.__init__`` by
    ``test_analysis_contracts.py::test_engine_state_fixture_matches_engine``.
    """
    B = batch
    return {
        "active": sds((B,), jnp.bool_),
        "last_token": sds((B,), jnp.int32),
        "consumed": sds((B,), jnp.int32),
        "prompt_len": sds((B,), jnp.int32),
        "prompt_buf": sds((B, capacity), jnp.int32),
        "gen_count": sds((B,), jnp.int32),
        "out_buf": sds((B, out_cap), jnp.int32),
        "temperature": sds((B,), jnp.float32),
        "top_k": sds((B,), jnp.int32),
        "top_p": sds((B,), jnp.float32),
        "max_tokens": sds((B,), jnp.int32),
        "stop_token": sds((B,), jnp.int32),
        "keys": sds((B, 2), jnp.uint32),
        "adapter_ids": sds((B,), jnp.int32),
    }


def train_batch(cfg: ModelConfig, batch: int = 2, seq: int = 16):
    return {"tokens": sds((batch, seq), jnp.int32)}


def avals_equal(a: Any, b: Any) -> bool:
    """Same pytree structure AND identical shape/dtype at every leaf."""
    import jax.tree_util as jtu
    if jtu.tree_structure(a) != jtu.tree_structure(b):
        return False
    return jtu.tree_all(jtu.tree_map(
        lambda x, y: tuple(x.shape) == tuple(y.shape)
        and jnp.dtype(x.dtype) == jnp.dtype(y.dtype), a, b))
