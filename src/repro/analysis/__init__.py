"""Static analysis for the repro codebase (``repro-lint`` + contracts).

Three layers, all zero-execution:

* :mod:`repro.analysis.lint` — AST rule engine with JAX-specific rules
  (retrace hazards, host syncs in hot loops, import-time device compute,
  static-arg hazards, topology-shim bypasses).  CLI: ``repro-lint``.
* :mod:`repro.analysis.contracts` / :mod:`repro.analysis.registry` — the
  ``@check_contract`` registry every major entrypoint registers with; the
  checker runs ``jax.eval_shape`` / ``jax.make_jaxpr`` across the config
  matrix and asserts declared invariants plus jaxpr-level bans.
* :mod:`repro.analysis.hlo_audit` — declarative assertions over compiled
  artifacts (forbidden buffer shapes, collective byte bounds, donation),
  shared by ``benchmarks/hlo_collectives.py`` and CI.
"""
from repro.analysis.hlo_audit import (audit_names, collective_bytes,  # noqa: F401
                                      run_audit, shape_bytes)
from repro.analysis.lint import Finding, lint_paths, lint_source  # noqa: F401
from repro.analysis.registry import check_contract, contract_names  # noqa: F401

__all__ = ["Finding", "lint_paths", "lint_source", "check_contract",
           "contract_names", "run_audit", "audit_names", "collective_bytes",
           "shape_bytes"]
