"""Declarative audits over compiled HLO text.

The serving/training stacks make *compiled-artifact* promises that neither
unit tests (they check values) nor the abstract contract checker (it
checks avals/jaxprs) can see: which collectives a lowering schedules, how
many bytes they move, and which buffer shapes must never materialize
(e.g. a dense ``(B, H, C, cap)`` score tensor inside a streamed decode).

This module is pure text analysis — **no jax import** — so audits are
cheap to register and to unit-test against canned HLO.  Producers compile
a function (``.lower(...).compile().as_text()``) and hand the text to a
registered :class:`HloAudit`; the same audit object backs both
``benchmarks/hlo_collectives.py --serve`` and the CI regression tests.

An audit is a named list of checks, each ``check(hlo, ctx) -> [failures]``
where ``ctx`` is a plain dict of compile-time facts (mesh width, batch,
capacity, the ModelConfig, ...).  Declarative check builders:

* :func:`forbid_collective` — op must move zero bytes;
* :func:`require_collective` — op must appear (optionally gated on ctx);
* :func:`collective_budget` — total collective bytes under an
  analytic-bound function of ctx;
* :func:`forbid_shapes` — no listed buffer shape may appear anywhere in
  the optimized HLO (ctx-dependent list).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Callable, Dict, Iterable, List, Optional, Sequence

# -- HLO text parsing ---------------------------------------------------------

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
                "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8, "c64": 8, "c128": 16}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")

_OP_RE = re.compile(r"^(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.+?)\s+([a-z\-]+)\(")


def shape_bytes(type_str: str) -> int:
    """Total bytes of every ``dtype[dims]`` shape in an HLO type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def iter_ops(hlo_text: str):
    """Yield ``(op_name, result_type_str, stripped_line)`` per HLO op."""
    for line in hlo_text.splitlines():
        ls = line.strip()
        m = _OP_RE.match(ls)
        if m:
            yield m.group(2), m.group(1), ls


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum result-shape bytes of every collective op in optimized HLO
    (``-start``/``-done`` async halves fold onto their base op)."""
    out = {c: 0 for c in _COLLECTIVES}
    for op, type_str, _ in iter_ops(hlo_text):
        for c in _COLLECTIVES:
            if op.startswith(c):
                out[c] += shape_bytes(type_str)
                break
    return out


# -- declarative checks -------------------------------------------------------

Ctx = Dict[str, object]
Check = Callable[[str, Ctx], List[str]]


def forbid_collective(op: str) -> Check:
    def check(hlo: str, ctx: Ctx) -> List[str]:
        b = collective_bytes(hlo).get(op, 0)
        return [f"unexpected {op} ({b}B)"] if b else []

    check.__name__ = f"forbid_collective[{op}]"
    return check


def require_collective(op: str,
                       when: Optional[Callable[[Ctx], bool]] = None) -> Check:
    def check(hlo: str, ctx: Ctx) -> List[str]:
        if when is not None and not when(ctx):
            return []
        if collective_bytes(hlo).get(op, 0) == 0:
            return [f"expected {op}, found none"]
        return []

    check.__name__ = f"require_collective[{op}]"
    return check


def collective_budget(bound: Callable[[Ctx], int],
                      label: str = "") -> Check:
    """Total collective bytes must not exceed ``bound(ctx)``."""

    def check(hlo: str, ctx: Ctx) -> List[str]:
        total = sum(collective_bytes(hlo).values())
        limit = bound(ctx)
        if total > limit:
            what = f" ({label})" if label else ""
            return [f"collective bytes {total} exceed bound {limit}{what}"]
        return []

    check.__name__ = "collective_budget"
    return check


def forbid_shapes(shapes: Callable[[Ctx], Iterable[str]],
                  reason: str = "") -> Check:
    """No listed literal shape string (e.g. ``f32[4,8,1,512]``) may appear
    anywhere in the HLO text."""

    def check(hlo: str, ctx: Ctx) -> List[str]:
        found = sorted({s for s in shapes(ctx) if s in hlo})
        if found:
            why = f" ({reason})" if reason else ""
            return [f"forbidden buffers materialized{why}: {found}"]
        return []

    check.__name__ = "forbid_shapes"
    return check


# -- the registry -------------------------------------------------------------

@dataclasses.dataclass
class HloAudit:
    name: str
    doc: str
    checks: Sequence[Check]

    def run(self, hlo_text: str, ctx: Ctx) -> List[str]:
        failures: List[str] = []
        for check in self.checks:
            failures.extend(check(hlo_text, ctx))
        return failures


_AUDITS: Dict[str, HloAudit] = {}


def register_audit(name: str, doc: str, checks: Sequence[Check]) -> HloAudit:
    if name in _AUDITS:
        raise ValueError(f"duplicate audit {name!r}")
    audit = HloAudit(name, doc, tuple(checks))
    _AUDITS[name] = audit
    return audit


def get_audit(name: str) -> HloAudit:
    return _AUDITS[name]


def audit_names() -> List[str]:
    return sorted(_AUDITS)


def run_audit(name: str, hlo_text: str, ctx: Ctx) -> List[str]:
    return get_audit(name).run(hlo_text, ctx)


# -- built-in audits ----------------------------------------------------------

def _serve_analytic_bytes(ctx: Ctx) -> int:
    """Dominant per-step traffic: one (B,C,d) f32 all-reduce per
    row-parallel projection (wo + w_down per layer + the embed
    row-combine) plus the (B,C,V) vocab-sharded logit epilogue."""
    cfg = ctx["cfg"]
    B, C = ctx["batch"], ctx["width"]
    d, V, L = cfg.d_model, cfg.vocab_size, cfg.num_layers
    return 4 * B * C * ((2 * L + 1) * d + 2 * V)


def _serve_budget(ctx: Ctx) -> int:
    # 8x slack keeps the bound meaningful (a dense (B,H,C,cap) gather
    # would blow it by orders of magnitude) without tracking XLA's exact
    # fusion choices; a mesh-less lowering must schedule no collectives
    return 8 * _serve_analytic_bytes(ctx) if ctx["mesh"] > 1 else 0


def _serve_dense_score_shapes(ctx: Ctx) -> List[str]:
    """Per-shard dense score/mask buffer shapes a streamed/kernel decode
    must never rematerialize (buffers shrink by the shard factor, so every
    per-shard variant is forbidden)."""
    if ctx["decode_impl"] == "dense":
        return []
    cfg = ctx["cfg"]
    B, C, cap, msize = ctx["batch"], ctx["width"], ctx["capacity"], ctx["mesh"]
    H, K = cfg.num_heads, cfg.num_kv_heads
    out = []
    for s in {1, msize}:
        for b in range(1, B + 1):
            out += [f"f32[{b},{H // s},{C},{cap}]",
                    f"f32[{b},{K // s},{H // K},{C},{cap}]"]
    return out


register_audit(
    "serve.decode_step",
    "Sharded serving decode: head-parallel attention communicates only "
    "via all-reduce at row-parallel projections, within an analytic "
    "per-step byte budget, and the streamed/kernel interior never "
    "rematerializes a dense score buffer.",
    (
        forbid_collective("all-to-all"),
        require_collective("all-reduce", when=lambda ctx: ctx["mesh"] > 1),
        collective_budget(_serve_budget, "analytic serve-step bound"),
        forbid_shapes(_serve_dense_score_shapes,
                      "dense score buffers in a streamed decode"),
    ),
)
