"""Shared AST machinery for the lint rules.

Everything here is pure ``ast`` — no jax import, so ``repro-lint`` runs in
any environment (including pre-commit hooks with no accelerator stack).

The central abstractions:

* :class:`ModuleInfo` — one parsed file: source, tree, the import alias map
  (``jnp`` → ``jax.numpy``), and per-line suppressions.
* :func:`resolve` — dotted qualname of an expression through the alias map.
* :func:`traced_functions` — the functions whose bodies execute under a
  JAX trace.  Detection is evidence-based: a jit-like decorator, being
  passed to a jit/vmap/grad/``lax.scan``-style wrapper in the same scope,
  or being the function *returned by* a step builder (the repo convention:
  ``make_*`` / ``_build_*`` factories return the traced step).  Pallas
  kernel bodies (``pl.pallas_call`` targets) are deliberately excluded —
  branching on ``functools.partial``-bound static config is idiomatic
  there and value branches already go through ``pl.when``.
* the taint helpers — which expressions carry *traced values* (function
  params and anything derived from them), with the host-safe escapes
  (``.shape`` / ``.dtype`` / ``.ndim`` / ``len()`` / ``is None`` ...)
  considered untainted.
"""
from __future__ import annotations

import ast
import dataclasses
import re
from typing import Dict, Iterator, List, Optional, Set, Tuple

# -- suppression syntax -----------------------------------------------------

_SUPPRESS_RE = re.compile(r"#\s*repro-lint:\s*disable=([\w*-]+(?:\s*,\s*[\w*-]+)*)")

#: qualnames that put their callee under a JAX trace
JIT_NAMES = frozenset({
    "jax.jit", "jax.pjit", "jax.experimental.pjit.pjit",
})
TRACED_WRAPPERS = JIT_NAMES | frozenset({
    "jax.vmap", "jax.pmap", "jax.grad", "jax.value_and_grad",
    "jax.jacfwd", "jax.jacrev", "jax.hessian", "jax.jvp", "jax.vjp",
    "jax.linearize", "jax.checkpoint", "jax.remat",
    "jax.eval_shape", "jax.make_jaxpr",
    "jax.lax.scan", "jax.lax.while_loop", "jax.lax.cond", "jax.lax.switch",
    "jax.lax.map", "jax.lax.fori_loop", "jax.lax.associative_scan",
})
#: function name patterns of traced-step builders (repo convention:
#: the def a ``make_*`` / ``_build_*`` factory returns is jitted by callers)
BUILDER_RE = re.compile(r"^(make_|_?build_)")

#: attribute reads that yield host metadata, never a traced value
HOST_SAFE_ATTRS = frozenset({
    "shape", "ndim", "dtype", "size", "aval", "sharding", "itemsize",
    "weak_type", "nbytes",
})
#: calls whose result is host data regardless of argument taint
HOST_SAFE_CALLS = frozenset({
    "len", "isinstance", "type", "id", "repr", "str", "hash", "getattr",
    "hasattr", "callable",
})


@dataclasses.dataclass
class ModuleInfo:
    """One parsed source file plus the lookup tables rules need."""

    path: str                       # as given (display)
    relpath: str                    # path relative to the lint root
    source: str
    tree: ast.Module
    imports: Dict[str, str]         # local alias -> dotted qualname
    suppressions: Dict[int, Optional[Set[str]]]  # line -> rules (None = all)

    @classmethod
    def parse(cls, path: str, source: str, relpath: Optional[str] = None
              ) -> "ModuleInfo":
        tree = ast.parse(source, filename=path)
        return cls(path=path, relpath=relpath or path, source=source,
                   tree=tree, imports=_import_map(tree),
                   suppressions=_suppressions(source))

    def suppressed(self, rule: str, line: int) -> bool:
        rules = self.suppressions.get(line, ())
        return rules is None or rule in rules


def _import_map(tree: ast.Module) -> Dict[str, str]:
    imports: Dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                imports[a.asname or a.name.split(".", 1)[0]] = (
                    a.name if a.asname else a.name.split(".", 1)[0])
        elif isinstance(node, ast.ImportFrom) and node.module and not node.level:
            for a in node.names:
                if a.name != "*":
                    imports[a.asname or a.name] = f"{node.module}.{a.name}"
    return imports


def _suppressions(source: str) -> Dict[int, Optional[Set[str]]]:
    out: Dict[int, Optional[Set[str]]] = {}
    for i, line in enumerate(source.splitlines(), start=1):
        m = _SUPPRESS_RE.search(line)
        if not m:
            continue
        rules = {r.strip() for r in m.group(1).split(",") if r.strip()}
        out[i] = None if "all" in rules or "*" in rules else rules
    return out


def resolve(node: ast.expr, imports: Dict[str, str]) -> Optional[str]:
    """Dotted qualname of a Name/Attribute chain through the alias map
    (``jnp.zeros`` -> ``jax.numpy.zeros``); None for anything else."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(imports.get(node.id, node.id))
        return ".".join(reversed(parts))
    return None


# -- traced-function discovery ----------------------------------------------


@dataclasses.dataclass
class TracedFn:
    node: ast.FunctionDef
    reason: str                     # evidence ("jit decorator", ...)
    static_names: Set[str]          # params excluded from taint


def _static_argnames(call: ast.Call) -> Set[str]:
    """Parse ``static_argnames=("a", "b")`` (or a single string) from a
    jit-like call's keywords."""
    for kw in call.keywords:
        if kw.arg == "static_argnames":
            v = kw.value
            if isinstance(v, ast.Constant) and isinstance(v.value, str):
                return {v.value}
            if isinstance(v, (ast.Tuple, ast.List)):
                return {e.value for e in v.elts
                        if isinstance(e, ast.Constant) and isinstance(e.value, str)}
    return set()


def _jit_like(call: ast.Call, imports: Dict[str, str]) -> bool:
    return resolve(call.func, imports) in JIT_NAMES


def _partial_of_jit(node: ast.expr, imports: Dict[str, str]
                    ) -> Optional[ast.Call]:
    """``functools.partial(jax.jit, ...)`` -> the partial Call, else None."""
    if (isinstance(node, ast.Call)
            and resolve(node.func, imports) in ("functools.partial", "partial")
            and node.args and _is_jit_name(node.args[0], imports)):
        return node
    return None


def _is_jit_name(node: ast.expr, imports: Dict[str, str]) -> bool:
    return resolve(node, imports) in JIT_NAMES


def traced_functions(mod: ModuleInfo) -> List[TracedFn]:
    """Every function whose body runs under a JAX trace, with evidence."""
    out: Dict[ast.FunctionDef, TracedFn] = {}

    def add(fn: ast.FunctionDef, reason: str, static: Set[str]) -> None:
        if fn not in out:
            out[fn] = TracedFn(fn, reason, static)

    def local_defs(body) -> Dict[str, ast.FunctionDef]:
        return {n.name: n for n in body if isinstance(n, ast.FunctionDef)}

    def scan_scope(body, enclosing: Optional[ast.FunctionDef]) -> None:
        defs = local_defs(body)
        # (1) decorator evidence
        for fn in defs.values():
            for dec in fn.decorator_list:
                if _is_jit_name(dec, mod.imports):
                    add(fn, "jit decorator", set())
                elif isinstance(dec, ast.Call) and _jit_like(dec, mod.imports):
                    add(fn, "jit decorator", _static_argnames(dec))
                else:
                    p = _partial_of_jit(dec, mod.imports)
                    if p is not None:
                        add(fn, "partial(jit) decorator", _static_argnames(p))
        # (2) passed to a jit/vmap/grad/lax.* wrapper in this scope
        for node in body:
            for call in (n for n in ast.walk(node) if isinstance(n, ast.Call)):
                fq = resolve(call.func, mod.imports)
                if fq not in TRACED_WRAPPERS:
                    continue
                for arg in call.args:
                    if isinstance(arg, ast.Name) and arg.id in defs:
                        static = (_static_argnames(call)
                                  if fq in JIT_NAMES else set())
                        add(defs[arg.id], f"passed to {fq}", static)
        # (3) returned by a step builder
        if enclosing is not None and BUILDER_RE.match(enclosing.name):
            returned = {n.value.id for n in ast.walk(enclosing)
                        if isinstance(n, ast.Return)
                        and isinstance(n.value, ast.Name)}
            for name in returned & set(defs):
                add(defs[name], f"returned by builder {enclosing.name}", set())
        # recurse into nested scopes
        for fn in defs.values():
            scan_scope(fn.body, fn)

    scan_scope(mod.tree.body, None)
    return list(out.values())


def param_names(fn: ast.FunctionDef) -> List[str]:
    a = fn.args
    names = [p.arg for p in a.posonlyargs + a.args + a.kwonlyargs]
    if a.vararg:
        names.append(a.vararg.arg)
    if a.kwarg:
        names.append(a.kwarg.arg)
    return names


# -- taint ------------------------------------------------------------------


def _is_none_compare(node: ast.expr) -> bool:
    return (isinstance(node, ast.Compare)
            and all(isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops))


def direct_taint(node: ast.expr, tainted: Set[str],
                 imports: Dict[str, str]) -> bool:
    """Whether ``node`` *directly* carries a traced value: a tainted name,
    or arithmetic / boolean / comparison / subscript / non-metadata
    attribute chains over one.  Call results are opaque (a predicate like
    ``is_device_state(x)`` may legally return host data), and the host-safe
    metadata escapes (``x.shape``, ``len(x)``, ``x is None``) never taint.
    """
    if isinstance(node, ast.Name):
        return node.id in tainted
    if isinstance(node, ast.Call):
        # free-function results are opaque (a predicate may return host
        # data), but a METHOD call on a traced receiver (x.sum(), x.any())
        # yields a tracer
        if isinstance(node.func, ast.Attribute):
            return direct_taint(node.func.value, tainted, imports)
        return False
    if isinstance(node, ast.Attribute):
        if node.attr in HOST_SAFE_ATTRS:
            return False
        return direct_taint(node.value, tainted, imports)
    if isinstance(node, ast.Subscript):
        return direct_taint(node.value, tainted, imports)
    if isinstance(node, ast.Compare):
        if _is_none_compare(node):
            return False
        return any(direct_taint(n, tainted, imports)
                   for n in [node.left] + node.comparators)
    if isinstance(node, ast.BoolOp):
        return any(direct_taint(v, tainted, imports) for v in node.values)
    if isinstance(node, ast.UnaryOp):
        return direct_taint(node.operand, tainted, imports)
    if isinstance(node, ast.BinOp):
        return (direct_taint(node.left, tainted, imports)
                or direct_taint(node.right, tainted, imports))
    if isinstance(node, (ast.Tuple, ast.List)):
        return any(direct_taint(e, tainted, imports) for e in node.elts)
    if isinstance(node, ast.IfExp):
        return (direct_taint(node.body, tainted, imports)
                or direct_taint(node.orelse, tainted, imports))
    return False


def taints_through(node: ast.expr, tainted: Set[str],
                   imports: Dict[str, str]) -> bool:
    """Whether assigning ``node`` to a name should taint it.  Unlike
    :func:`direct_taint`, calls DO propagate (``y = f(x)`` with traced
    ``x`` almost always yields a tracer) unless the callee is a host-safe
    metadata call or the expression is an ``is None`` test."""
    if _is_none_compare(node):
        return False
    if isinstance(node, ast.Call):
        fq = resolve(node.func, imports)
        if fq in HOST_SAFE_CALLS:
            return False
        return any(taints_through(a, tainted, imports) for a in node.args) or \
            any(taints_through(kw.value, tainted, imports)
                for kw in node.keywords)
    if isinstance(node, ast.Attribute):
        if node.attr in HOST_SAFE_ATTRS:
            return False
        return taints_through(node.value, tainted, imports)
    for child in ast.iter_child_nodes(node):
        if isinstance(child, ast.expr) and taints_through(child, tainted,
                                                          imports):
            return True
    return isinstance(node, ast.Name) and node.id in tainted


def assign_targets(node: ast.stmt) -> Iterator[str]:
    """Names bound by an assignment statement (tuples flattened)."""
    targets: List[ast.expr] = []
    if isinstance(node, ast.Assign):
        targets = node.targets
    elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
        targets = [node.target]
    for t in targets:
        for n in ast.walk(t):
            if isinstance(n, ast.Name):
                yield n.id


def walk_scope(body: List[ast.stmt]) -> Iterator[Tuple[ast.AST, bool]]:
    """Yield ``(node, entering_nested_fn)`` over a function body in source
    order, descending into nested defs (their bodies trace too)."""
    for stmt in body:
        for node in ast.walk(stmt):
            yield node, isinstance(node, (ast.FunctionDef,
                                          ast.AsyncFunctionDef))
