"""The ``@check_contract`` registry.

Every major entrypoint (train step, serve step, engine step/burst,
aggregator finalize cores, each Pallas kernel and its XLA twin) registers a
*contract builder* here.  A builder receives one :class:`Case` from the
config matrix and returns a :class:`ContractCase` describing a function to
abstractly evaluate plus the invariants it must satisfy — or ``None`` when
the case does not apply (e.g. an SSM family for an attention kernel).

This module is deliberately lightweight (no jax import): registration
happens at import time of the subsystem modules, and the heavy lifting
(``jax.eval_shape`` / ``jax.make_jaxpr``) lives in
:mod:`repro.analysis.contracts`.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

#: config-matrix axes a contract can vary over
FAMILIES = ("gqa", "mla", "moe", "ssm")
DECODE_IMPLS = ("dense", "streamed", "kernel")
MESH_SIZES = (1, 2)


@dataclasses.dataclass(frozen=True)
class Case:
    """One point of the config matrix."""
    family: str = "gqa"
    decode_impl: str = "dense"
    mesh: int = 1

    def label(self) -> str:
        return f"{self.family}/{self.decode_impl}/mesh{self.mesh}"


@dataclasses.dataclass
class ContractCase:
    """What the checker abstractly evaluates for one (contract, case).

    ``fn(*args)`` must be traceable with ``jax.eval_shape`` — zero FLOPs.
    ``args`` are ShapeDtypeStructs (or concrete small arrays; they are
    never materialized on device by the checker).

    Invariants:

    * ``out_check(out_avals, case)`` — raise/assert on bad output
      shape/dtype structure (called with the eval_shape result);
    * ``pspec_tree`` — optional ``(pytree_of_arrays_or_structs,
      pytree_of_PartitionSpecs)`` pair; the checker asserts every spec fits
      its array's rank and that sharded axes divide evenly on ``mesh``
      (an ``AbstractMesh`` at the case's mesh size — no devices needed);
    * ``twin`` — optional second ``(fn, args)`` whose eval_shape output
      avals must be identical to the primary's (Pallas kernel ↔ XLA twin);
    * ``forbid_f64`` / ``forbid_callbacks`` — jaxpr-level bans (fp64
      upcasts; pure/io/debug callbacks in the hot path).
    """
    fn: Callable
    args: Tuple[Any, ...]
    out_check: Optional[Callable[[Any, Case], None]] = None
    pspec_tree: Optional[Tuple[Any, Any]] = None
    mesh: Any = None
    twin: Optional[Tuple[Callable, Tuple[Any, ...]]] = None
    forbid_f64: bool = True
    forbid_callbacks: bool = True


@dataclasses.dataclass
class _Entry:
    name: str
    build: Callable[[Case], Optional[ContractCase]]
    families: Sequence[str]
    decode_impls: Sequence[str]
    mesh_sizes: Sequence[int]

    def cases(self) -> List[Case]:
        return [Case(f, d, m) for f in self.families
                for d in self.decode_impls for m in self.mesh_sizes]


_CONTRACTS: Dict[str, _Entry] = {}


def check_contract(name: str, *, families: Sequence[str] = ("gqa",),
                   decode_impls: Sequence[str] = ("dense",),
                   mesh_sizes: Sequence[int] = MESH_SIZES):
    """Register ``build(case) -> ContractCase | None`` under ``name``.

    The axes keywords declare which slice of the global matrix the
    contract varies over; the checker enumerates their cross product.
    """

    def deco(build: Callable[[Case], Optional[ContractCase]]):
        if name in _CONTRACTS:
            raise ValueError(f"duplicate contract {name!r}")
        _CONTRACTS[name] = _Entry(name, build, tuple(families),
                                  tuple(decode_impls), tuple(mesh_sizes))
        return build

    return deco


def contract_entries() -> Dict[str, _Entry]:
    """All registered contracts (after :func:`load_registrations`)."""
    return dict(_CONTRACTS)


def contract_names() -> List[str]:
    return sorted(_CONTRACTS)


#: modules whose import registers the repo's built-in contracts
REGISTRATION_MODULES = (
    "repro.train.step",
    "repro.serve.engine",
    "repro.core.aggregators",
    "repro.core.runtime.runners",
    "repro.kernels.ops",
)


def load_registrations() -> List[str]:
    """Import every registration module; return the contract names."""
    import importlib
    for m in REGISTRATION_MODULES:
        importlib.import_module(m)
    return contract_names()
