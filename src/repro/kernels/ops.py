"""Jit'd public wrappers around the Pallas kernels.

On CPU (this container) the kernels execute in ``interpret=True`` mode —
the kernel body runs as traced Python, validating the exact TPU code path.
Shape padding to block multiples is handled here so callers can use
arbitrary sizes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.adapter_gram import adapter_gram_kernel
from repro.kernels.flash_attention import flash_attention_kernel
from repro.kernels.lora_matmul import lora_matmul_kernel
from repro.kernels.wkv6 import wkv6_kernel


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x, axis: int, mult: int):
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x, n
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), n


def lora_matmul(x, w, a, b, scale, bm: int = 128, bn: int = 128):
    """x: (..., din) -> (..., dout), fused base + adapter matmul."""
    lead = x.shape[:-1]
    din = x.shape[-1]
    dout = w.shape[1]
    xf = x.reshape(-1, din)
    xf, M = _pad_to(xf, 0, bm)
    b_scaled = (b * scale).astype(w.dtype)
    wp, _ = _pad_to(w, 1, bn)
    bp, _ = _pad_to(b_scaled, 0, bn)
    y = lora_matmul_kernel(xf, wp, a.astype(x.dtype), bp.astype(x.dtype),
                           bm=bm, bn=bn, interpret=_interpret())
    return y[:M, :dout].reshape(*lead, dout)


def flash_attention(q, k, v, causal: bool = True, window: int = 0,
                    bq: int = 128, bk: int = 128):
    """GQA flash attention; falls back to the reference for tiny shapes."""
    B, S, H, hd = q.shape
    T = k.shape[1]
    if S % min(bq, S) or T % min(bk, T):
        return ref.flash_attention_ref(q, k, v, causal, window).astype(q.dtype)
    out = flash_attention_kernel(q, k, v, causal=causal, window=window,
                                 bq=bq, bk=bk, interpret=_interpret())
    return out


def wkv6(r, k, v, w, u, chunk: int = 256):
    return wkv6_kernel(r, k, v, w, u, chunk=min(chunk, r.shape[1]),
                       interpret=_interpret())


def adapter_gram(x, bm: int = 512):
    """xᵀx (r, r) fp32 for any (m, r) — tail masking inside the kernel."""
    return adapter_gram_kernel(x, bm=min(bm, x.shape[0]),
                               interpret=_interpret())
