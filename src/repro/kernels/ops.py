"""Jit'd public wrappers around the Pallas kernels.

On CPU (this container) the kernels execute in ``interpret=True`` mode —
the kernel body runs as traced Python, validating the exact TPU code path.
Shape padding to block multiples is handled here so callers can use
arbitrary sizes.  ``lora_matmul`` carries a ``custom_vjp`` (backward via the
reference math) so ``use_kernels=True`` training differentiates through the
fused forward.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.adapter_gram import adapter_gram_kernel
from repro.kernels.bgmv import bgmv_kernel
from repro.kernels.flash_attention import flash_attention_kernel
from repro.kernels.lora_matmul import lora_matmul_kernel
from repro.kernels.mla_ring_decode import mla_ring_decode_kernel
from repro.kernels.ring_decode import ring_decode_kernel
from repro.kernels.wkv6 import wkv6_kernel


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x, axis: int, mult: int):
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x, n
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), n


def _lora_matmul_fwd(x, w, a, b, scale, bm, bn):
    dout = w.shape[1]
    xf, M = _pad_to(x, 0, bm)
    b_scaled = (b * scale).astype(w.dtype)
    wp, _ = _pad_to(w, 1, bn)
    bp, _ = _pad_to(b_scaled, 0, bn)
    y = lora_matmul_kernel(xf, wp, a.astype(x.dtype), bp.astype(x.dtype),
                           bm=bm, bn=bn, interpret=_interpret())
    return y[:M, :dout]


@functools.lru_cache(maxsize=None)
def _lora_matmul_vjp(bm: int, bn: int):
    """custom_vjp-wrapped fused LoRA matmul: forward runs the Pallas kernel,
    backward is the reference math (Pallas kernels have no autodiff rule, so
    without this the ``use_kernels=True`` train step cannot differentiate)."""

    @jax.custom_vjp
    def f(x, w, a, b, scale):
        return _lora_matmul_fwd(x, w, a, b, scale, bm, bn)

    def fwd(x, w, a, b, scale):
        return f(x, w, a, b, scale), (x, w, a, b, scale)

    def bwd(res, g):
        x, w, a, b, scale = res
        sc = jnp.asarray(scale, x.dtype)
        g = g.astype(x.dtype)
        z = x @ a.T.astype(x.dtype)                      # (M, r) recomputed
        gz = (g @ b.astype(x.dtype)) * sc                # (M, r)
        dx = g @ w.T + gz @ a.astype(x.dtype)
        dw = (x.T @ g).astype(w.dtype)
        da = (gz.T @ x).astype(a.dtype)
        db = (g.T @ z * sc).astype(b.dtype)
        dscale = jnp.sum(g * (z @ b.T.astype(x.dtype))).astype(
            jnp.result_type(scale))
        return dx, dw, da, db, jnp.reshape(dscale, jnp.shape(scale))

    f.defvjp(fwd, bwd)
    return f


def lora_matmul(x, w, a, b, scale, bm: int = 128, bn: int = 128):
    """x: (..., din) -> (..., dout), fused base + adapter matmul
    (differentiable: reference-math backward)."""
    lead = x.shape[:-1]
    din = x.shape[-1]
    y = _lora_matmul_vjp(bm, bn)(x.reshape(-1, din), w, a, b, scale)
    return y.reshape(*lead, w.shape[1])


def _flash_attention_fwd(q, k, v, causal, window, bq, bk):
    S, T = q.shape[1], k.shape[1]
    bq = min(bq, S)
    bk = min(bk, T)
    qp, S0 = _pad_to(q, 1, bq)
    kp, T0 = _pad_to(k, 1, bk)
    vp, _ = _pad_to(v, 1, bk)
    kv_len = T0 if kp.shape[1] != T0 else 0
    out = flash_attention_kernel(qp, kp, vp, causal=causal, window=window,
                                 bq=bq, bk=bk, kv_len=kv_len,
                                 interpret=_interpret())
    return out[:, :S0]


@functools.lru_cache(maxsize=None)
def _flash_attention_vjp(causal: bool, window: int, bq: int, bk: int):
    """custom_vjp: Pallas forward, oracle-math backward (Pallas kernels
    carry no autodiff rule — without this ``use_kernels=True`` training
    cannot differentiate through attention).  The backward differentiates
    ``flash_jax`` — the same masking semantics as the kernel (causal and
    window applied independently) with O(bq·bk) live score tiles, so the
    flash memory win holds in the backward pass too; non-block-multiple
    shapes fall back to single-chunk (dense-equivalent) tiles."""

    @jax.custom_vjp
    def f(q, k, v):
        return _flash_attention_fwd(q, k, v, causal, window, bq, bk)

    def fwd(q, k, v):
        return f(q, k, v), (q, k, v)

    def bwd(res, g):
        from repro.models.attention_core import flash_jax
        q, k, v = res
        S, T = q.shape[1], k.shape[1]
        qc = 512 if S % 512 == 0 else S
        kc = 1024 if T % 1024 == 0 else T
        _, pull = jax.vjp(
            lambda q_, k_, v_: flash_jax(
                q_, k_, v_, causal=causal, window=window, q_chunk=qc,
                kv_chunk=kc).astype(q.dtype), q, k, v)
        return pull(g)

    f.defvjp(fwd, bwd)
    return f


def flash_attention(q, k, v, causal: bool = True, window: int = 0,
                    bq: int = 128, bk: int = 128):
    """GQA flash attention.  S/T are padded to block multiples (padded KV
    columns are masked in-kernel via ``kv_len``, padded query rows are
    sliced off), so the kernel path runs at ANY sequence length — no silent
    reference fallback.  Differentiable (memory-bounded flash backward)."""
    return _flash_attention_vjp(causal, window, bq, bk)(q, k, v)


def ring_decode(q, k, v, pos, length, n_tokens=None, window: int = 0,
                k_scale=None, v_scale=None, bk: int = 128):
    """Flash-decoding over a GQA ring cache (Pallas).

    q: (B,C,H,hd); k/v: (B,cap,K,hd) raw cache storage (int8 with
    per-token (B,cap,K,1) scales fused in-kernel); pos/length/n_tokens:
    (B,) ring state AFTER the chunk write.  The slot axis is padded to a
    block multiple here (dtype-preserving — an int8 cache is never expanded
    to full precision); padded slots are masked in-kernel.  (B,C,H,hd) fp32.
    """
    B, C = q.shape[:2]
    cap = k.shape[1]
    if n_tokens is None:
        n_tokens = jnp.full((B,), C, jnp.int32)
    bk = min(bk, cap)
    k, _ = _pad_to(k, 1, bk)
    v, _ = _pad_to(v, 1, bk)
    if k_scale is not None:
        k_scale, _ = _pad_to(k_scale, 1, bk)
        v_scale, _ = _pad_to(v_scale, 1, bk)
    return ring_decode_kernel(q, k, v, pos, length, n_tokens, cap=cap,
                              k_scale=k_scale, v_scale=v_scale,
                              window=window, bk=bk, interpret=_interpret())


def mla_ring_decode(q_eff, c_kv, k_rope, pos, length, n_tokens=None, *,
                    scale: float, window: int = 0,
                    c_kv_scale=None, k_rope_scale=None, bk: int = 128):
    """Flash-decoding over the MLA compressed-latent ring cache (Pallas).

    q_eff: (B,C,H,kvr+rope) absorbed queries; c_kv/k_rope: (B,cap,·) raw
    cache storage (int8 with per-half (B,cap,1) scales fused in-kernel);
    ``scale`` is REQUIRED and must be the un-absorbed 1/√(nope+rope) — it
    is not derivable from q_eff's width.  Returns out_lat (B,C,H,kvr) fp32.
    """
    B, C = q_eff.shape[:2]
    cap = c_kv.shape[1]
    if n_tokens is None:
        n_tokens = jnp.full((B,), C, jnp.int32)
    bk = min(bk, cap)
    c_kv, _ = _pad_to(c_kv, 1, bk)
    k_rope, _ = _pad_to(k_rope, 1, bk)
    if c_kv_scale is not None:
        c_kv_scale, _ = _pad_to(c_kv_scale, 1, bk)
        k_rope_scale, _ = _pad_to(k_rope_scale, 1, bk)
    return mla_ring_decode_kernel(q_eff, c_kv, k_rope, pos, length, n_tokens,
                                  cap=cap, scale=scale,
                                  c_kv_scale=c_kv_scale,
                                  k_rope_scale=k_rope_scale,
                                  window=window, bk=bk,
                                  interpret=_interpret())


def bgmv(x, a_pages, b_pages, table, rank, scale, ids):
    """Batched-gather multi-tenant LoRA delta (Pallas): per-row
    y_b = scale_b · B_b(A_b x_b) gathered from the paged adapter pools at
    each row's own rank.

    x: (B, C, din); a_pages: (P, pr, din); b_pages: (P, dout, pr);
    table: (maxA, Pmax) adapter→pages indirection; rank/scale: (maxA,);
    ids: (B,) per-row adapter ids (0 = base, exact-zero delta).
    Returns (B, C, dout) f32.  Inference-only — no autodiff rule.
    """
    ids = ids.astype(jnp.int32)
    return bgmv_kernel(x, a_pages, b_pages, table[ids], rank[ids],
                       scale.astype(jnp.float32)[ids],
                       interpret=_interpret())


def wkv6(r, k, v, w, u, chunk: int = 256):
    return wkv6_kernel(r, k, v, w, u, chunk=min(chunk, r.shape[1]),
                       interpret=_interpret())


def adapter_gram(x, bm: int = 512):
    """xᵀx (r, r) fp32 for any (m, r) — tail masking inside the kernel."""
    return adapter_gram_kernel(x, bm=min(bm, x.shape[0]),
                               interpret=_interpret())


# -- abstract contracts (checked by repro.analysis.contracts) -----------------
#
# Every Pallas kernel must be aval-identical to its XLA twin / oracle —
# ``pallas_call`` abstract-evals on any backend, so these hold on CPU CI.

from repro.analysis.registry import ContractCase, check_contract  # noqa: E402


@check_contract("kernel.ring_decode", families=("gqa",), mesh_sizes=(1,))
def _contract_ring_decode(case):
    from repro.analysis import fixtures as FX
    from repro.models.attention_core import ring_flash_decode
    B, C, H, K, hd, cap = 2, 4, 8, 4, 16, 64
    args = (FX.sds((B, C, H, hd), "float32"),
            FX.sds((B, cap, K, hd), "float32"),
            FX.sds((B, cap, K, hd), "float32"),
            FX.sds((B,), "int32"), FX.sds((B,), "int32"))

    def out_check(out, _case):
        assert out.shape == (B, C, H, hd) and out.dtype == jnp.float32

    return ContractCase(ring_decode, args, out_check=out_check,
                        twin=(ring_flash_decode, args))


@check_contract("kernel.mla_ring_decode", families=("mla",), mesh_sizes=(1,))
def _contract_mla_ring_decode(case):
    from repro.analysis import fixtures as FX
    from repro.models.attention_core import mla_ring_flash_decode
    B, C, H, kvr, rope, cap = 2, 4, 4, 32, 16, 64
    scale = (kvr + rope) ** -0.5
    args = (FX.sds((B, C, H, kvr + rope), "float32"),
            FX.sds((B, cap, kvr), "float32"),
            FX.sds((B, cap, rope), "float32"),
            FX.sds((B,), "int32"), FX.sds((B,), "int32"))

    def out_check(out, _case):
        assert out.shape == (B, C, H, kvr) and out.dtype == jnp.float32

    return ContractCase(functools.partial(mla_ring_decode, scale=scale), args,
                        out_check=out_check,
                        twin=(functools.partial(mla_ring_flash_decode, scale=scale),
                              args))


@check_contract("kernel.flash_attention", families=("gqa",), mesh_sizes=(1,))
def _contract_flash_attention(case):
    from repro.analysis import fixtures as FX
    from repro.kernels.ref import flash_attention_ref
    B, S, H, K, hd = 2, 16, 8, 4, 16
    args = (FX.sds((B, S, H, hd), "float32"),
            FX.sds((B, S, K, hd), "float32"),
            FX.sds((B, S, K, hd), "float32"))
    return ContractCase(flash_attention, args,
                        twin=(flash_attention_ref, args))


@check_contract("kernel.lora_matmul", families=("gqa",), mesh_sizes=(1,))
def _contract_lora_matmul(case):
    from repro.analysis import fixtures as FX
    from repro.kernels.ref import lora_matmul_ref
    B, S, din, dout, r = 2, 8, 32, 24, 4
    args = (FX.sds((B, S, din), "float32"),
            FX.sds((din, dout), "float32"),
            FX.sds((r, din), "float32"),
            FX.sds((dout, r), "float32"), 2.0)
    return ContractCase(lora_matmul, args, twin=(lora_matmul_ref, args))


@check_contract("kernel.wkv6", families=("ssm",), mesh_sizes=(1,))
def _contract_wkv6(case):
    from repro.analysis import fixtures as FX
    from repro.kernels.ref import wkv6_ref
    B, S, H, hd = 2, 8, 4, 16
    args = tuple(FX.sds((B, S, H, hd), "float32") for _ in range(4)) \
        + (FX.sds((H, hd), "float32"),)
    return ContractCase(wkv6, args, twin=(wkv6_ref, args))


@check_contract("kernel.adapter_gram", families=("gqa",), mesh_sizes=(1,))
def _contract_adapter_gram(case):
    from repro.analysis import fixtures as FX
    from repro.kernels.ref import adapter_gram_ref
    args = (FX.sds((100, 12), "float32"),)
    return ContractCase(adapter_gram, args, twin=(adapter_gram_ref, args))


@check_contract("kernel.bgmv", families=("gqa",), mesh_sizes=(1,))
def _contract_bgmv(case):
    """The paged multi-tenant LoRA delta: the Pallas bgmv path and the XLA
    gather/einsum twin must agree on avals through ``paged_lora_delta``."""
    from repro.analysis import fixtures as FX
    from repro.peft.lora import PagedLoRA, paged_lora_delta
    B, C, din, dout = 4, 4, 32, 24
    P, pr, maxA, Pmax = 8, 4, 4, 2
    leaves = (FX.sds((P, pr, din), "float32"),      # a_pages
              FX.sds((P, dout, pr), "float32"),     # b_pages
              FX.sds((maxA,), "float32"),           # scale
              FX.sds((maxA, Pmax), "int32"),        # table
              FX.sds((maxA,), "int32"),             # rank
              FX.sds((B,), "int32"))                # ids
    x = FX.sds((B, C, din), "float32")

    def run(impl):
        def f(x, a, b, s, t, r, i):
            return paged_lora_delta(x, PagedLoRA(a, b, s, t, r, i, impl=impl))
        return f

    args = (x,) + leaves
    return ContractCase(run("kernel"), args, twin=(run("xla"), args))
