"""Blockwise online-softmax (flash) attention kernel with causal + sliding-
window masking and native GQA (no KV repetition in HBM).

Grid: (B·H, S/bq, T/bk) with the KV axis innermost/sequential — running
max / normalizer / accumulator live in VMEM scratch and persist across the
sequential axis (the standard TPU flash pattern).  KV blocks for grouped
queries are addressed by index_map arithmetic (kv head = q head // group),
so KV is streamed once per group from HBM, never repeated.

fp32 accumulation; bq = bk = 128 default (MXU-aligned).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, bq: int, bk: int, nk: int, causal: bool,
            window: int, kv_len: int):
    ik = pl.program_id(2)
    iq = pl.program_id(1)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0]                                     # (bq, hd)
    k = k_ref[0]                                     # (bk, hd)
    v = v_ref[0]
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale   # (bq,bk)

    qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > (qpos - window)
    if kv_len:                      # T was padded: mask the padded columns
        mask &= kpos < kv_len
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                               # (bq, 1)
    m_cur = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur)
    l_scr[...] = l_scr[...] * alpha + p.sum(axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jnp.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)
    m_scr[...] = m_cur

    @pl.when(ik == nk - 1)
    def _flush():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("causal", "window", "bq", "bk", "kv_len",
                                    "interpret"))
def flash_attention_kernel(q, k, v, causal: bool = True, window: int = 0,
                           bq: int = 128, bk: int = 128, kv_len: int = 0,
                           interpret: bool = False):
    """q: (B,S,H,hd), k/v: (B,T,K,hd) -> (B,S,H,hd).  ``kv_len`` marks the
    real KV length when T carries block padding (0 = no padding)."""
    B, S, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    g = H // K
    bq = min(bq, S)
    bk = min(bk, T)
    assert S % bq == 0 and T % bk == 0, (S, bq, T, bk)
    nq, nk = S // bq, T // bk
    scale = 1.0 / math.sqrt(hd)

    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * K, T, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * K, T, hd)

    def kv_index(bh, iq_, ik_):
        return (bh // H * K + (bh % H) // g, ik_, 0)

    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, bq=bq, bk=bk, nk=nk,
                          causal=causal, window=window, kv_len=kv_len),
        grid=(B * H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda bh, iq_, ik_: (bh, iq_, 0)),
            pl.BlockSpec((1, bk, hd), kv_index),
            pl.BlockSpec((1, bk, hd), kv_index),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda bh, iq_, ik_: (bh, iq_, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),    # running max
            pltpu.VMEM((bq, 1), jnp.float32),    # running normalizer
            pltpu.VMEM((bq, hd), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
