"""Tall-skinny Gram kernel: C = XᵀX for adapter stacks X (m × r), m ≫ r.

This is the MXU-friendly building block of the server-side stacked SVD
(Gram/eigh route, DESIGN.md §3): the m-dimension is streamed through VMEM in
row panels while the small r×r accumulator stays resident; one pass over X
instead of a Householder QR pipeline.

Grid: (m/bm,) sequential; fp32 accumulator in VMEM scratch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, o_ref, acc_scr, *, nm: int, m: int, bm: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    x = x_ref[...]
    if m % bm:
        # tail panel: rows past m are out-of-bounds garbage — zero them so
        # callers never pay a host-side padding copy on the hot path
        rows = i * bm + jax.lax.broadcasted_iota(jnp.int32, x.shape, 0)
        x = jnp.where(rows < m, x, jnp.zeros_like(x))
    acc_scr[...] += jnp.dot(x.T, x, preferred_element_type=jnp.float32)

    @pl.when(i == nm - 1)
    def _flush():
        o_ref[...] = acc_scr[...]


@functools.partial(jax.jit, static_argnames=("bm", "interpret"))
def adapter_gram_kernel(x, bm: int = 512, interpret: bool = False):
    """x: (m, r) -> xᵀx (r, r) fp32.  Any m — the last panel is masked."""
    m, r = x.shape
    bm = min(bm, m)
    nm = pl.cdiv(m, bm)
    return pl.pallas_call(
        functools.partial(_kernel, nm=nm, m=m, bm=bm),
        grid=(nm,),
        in_specs=[pl.BlockSpec((bm, r), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((r, r), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((r, r), jnp.float32),
        scratch_shapes=[pltpu.VMEM((r, r), jnp.float32)],
        interpret=interpret,
    )(x)
