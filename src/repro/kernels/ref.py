"""Pure-jnp oracles for every Pallas kernel (ground truth for allclose
tests and the CPU execution path)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def lora_matmul_ref(x, w, a, b, scale):
    """y = x @ w + scale * (x @ aᵀ) @ bᵀ.
    x: (M, din), w: (din, dout), a: (r, din), b: (dout, r)."""
    y = x @ w
    z = x @ a.T.astype(x.dtype)
    return y + (z @ b.T.astype(x.dtype)) * scale


def flash_attention_ref(q, k, v, causal: bool = True, window: int = 0):
    """q: (B,S,H,hd), k/v: (B,T,K,hd) grouped-query attention, fp32 softmax."""
    B, S, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    g = H // K
    qf = q.astype(jnp.float32).reshape(B, S, K, g, hd)
    s = jnp.einsum("bskgh,btkh->bkgst", qf, k.astype(jnp.float32))
    s = s * (1.0 / math.sqrt(hd))
    if causal or window:        # window applies independently of causal,
        qpos = jnp.arange(S)[:, None]   # matching the kernel's mask
        kpos = jnp.arange(T)[None, :]
        m = jnp.ones((S, T), jnp.bool_)
        if causal:
            m &= kpos <= qpos
        if window:
            m &= kpos > (qpos - window)
        s = jnp.where(m[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgst,btkh->bskgh", p, v.astype(jnp.float32))
    return o.reshape(B, S, H, hd)


def _ring_mask(pos, length, cap, qpos, window):
    from repro.models.attention_core import ring_attend_mask
    return ring_attend_mask(pos, length, cap, qpos, window)


def ring_decode_ref(q, k, v, pos, length, n_tokens, window: int = 0,
                    k_scale=None, v_scale=None):
    """Dense decode-attention oracle over a GQA ring cache.

    q: (B,C,H,hd); k/v: (B,cap,K,hd) raw cache storage (int8 with
    (B,cap,K,1) scales supported — dequantized WHOLE, in fp32);
    pos/length/n_tokens: (B,) ring state AFTER the chunk write.  This is
    the O(cap)-live-memory math the streamed/kernel paths are tested
    against: full (B,H,C,cap) scores + dense (B,C,cap) ring mask.
    """
    B, C, H, hd = q.shape
    cap, K = k.shape[1], k.shape[2]
    g = H // K
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    if k_scale is not None:
        kf = kf * k_scale
        vf = vf * v_scale
    qf = q.astype(jnp.float32).reshape(B, C, K, g, hd)
    s = jnp.einsum("bckgh,btkh->bkgct", qf, kf) / math.sqrt(hd)
    qpos = (pos - n_tokens)[:, None] + jnp.arange(C)[None, :]
    mask = _ring_mask(pos, length, cap, qpos, window)        # (B,C,cap)
    s = jnp.where(mask[:, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgct,btkh->bckgh", p, vf)
    return o.reshape(B, C, H, hd)


def mla_ring_decode_ref(q_eff, c_kv, k_rope, pos, length, n_tokens,
                        scale: float, window: int = 0,
                        c_kv_scale=None, k_rope_scale=None):
    """Dense absorbed-MLA decode oracle over the compressed-latent ring
    cache.  q_eff: (B,C,H,kvr+rope); c_kv: (B,cap,kvr); k_rope:
    (B,cap,rope); returns out_lat (B,C,H,kvr) fp32."""
    B, C, H, _ = q_eff.shape
    cap = c_kv.shape[1]
    ckv = c_kv.astype(jnp.float32)
    kr = k_rope.astype(jnp.float32)
    if c_kv_scale is not None:
        ckv = ckv * c_kv_scale
        kr = kr * k_rope_scale
    keff = jnp.concatenate([ckv, kr], axis=-1)
    s = jnp.einsum("bchd,btd->bhct", q_eff.astype(jnp.float32), keff) * scale
    qpos = (pos - n_tokens)[:, None] + jnp.arange(C)[None, :]
    mask = _ring_mask(pos, length, cap, qpos, window)
    s = jnp.where(mask[:, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhct,btk->bchk", p, ckv)


def wkv6_ref(r, k, v, w, u):
    """RWKV6 recurrence (see repro.models.rwkv.wkv_scan).
    r,k,v,w: (B,S,H,hd) with w = log-decay (<0); u: (H,hd). fp32 out."""
    B, S, H, hd = r.shape
    rf, kf, vf, wf = (t.astype(jnp.float32) for t in (r, k, v, w))

    def step(state, inp):
        rt, kt, vt, wt = inp
        kv = kt[..., :, None] * vt[..., None, :]
        y = jnp.einsum("bhk,bhkv->bhv", rt, state + u[..., None] * kv)
        state = jnp.exp(wt)[..., None] * state + kv
        return state, y

    s0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    xs = tuple(t.swapaxes(0, 1) for t in (rf, kf, vf, wf))
    _, ys = jax.lax.scan(step, s0, xs)
    return ys.swapaxes(0, 1)


def adapter_gram_ref(x):
    """Gram matrix xᵀ x in fp32. x: (m, r)."""
    xf = x.astype(jnp.float32)
    return xf.T @ xf
