"""Pure-jnp oracles for every Pallas kernel (ground truth for allclose
tests and the CPU execution path)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def lora_matmul_ref(x, w, a, b, scale):
    """y = x @ w + scale * (x @ aᵀ) @ bᵀ.
    x: (M, din), w: (din, dout), a: (r, din), b: (dout, r)."""
    y = x @ w
    z = x @ a.T.astype(x.dtype)
    return y + (z @ b.T.astype(x.dtype)) * scale


def flash_attention_ref(q, k, v, causal: bool = True, window: int = 0):
    """q: (B,S,H,hd), k/v: (B,T,K,hd) grouped-query attention, fp32 softmax."""
    B, S, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    g = H // K
    qf = q.astype(jnp.float32).reshape(B, S, K, g, hd)
    s = jnp.einsum("bskgh,btkh->bkgst", qf, k.astype(jnp.float32))
    s = s * (1.0 / math.sqrt(hd))
    if causal:
        qpos = jnp.arange(S)[:, None]
        kpos = jnp.arange(T)[None, :]
        m = kpos <= qpos
        if window:
            m &= kpos > (qpos - window)
        s = jnp.where(m[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgst,btkh->bskgh", p, v.astype(jnp.float32))
    return o.reshape(B, S, H, hd)


def wkv6_ref(r, k, v, w, u):
    """RWKV6 recurrence (see repro.models.rwkv.wkv_scan).
    r,k,v,w: (B,S,H,hd) with w = log-decay (<0); u: (H,hd). fp32 out."""
    B, S, H, hd = r.shape
    rf, kf, vf, wf = (t.astype(jnp.float32) for t in (r, k, v, w))

    def step(state, inp):
        rt, kt, vt, wt = inp
        kv = kt[..., :, None] * vt[..., None, :]
        y = jnp.einsum("bhk,bhkv->bhv", rt, state + u[..., None] * kv)
        state = jnp.exp(wt)[..., None] * state + kv
        return state, y

    s0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    xs = tuple(t.swapaxes(0, 1) for t in (rf, kf, vf, wf))
    _, ys = jax.lax.scan(step, s0, xs)
    return ys.swapaxes(0, 1)


def adapter_gram_ref(x):
    """Gram matrix xᵀ x in fp32. x: (m, r)."""
    xf = x.astype(jnp.float32)
    return xf.T @ xf
