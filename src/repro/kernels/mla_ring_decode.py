"""Flash-decoding kernel over the MLA compressed-latent ring cache.

The absorbed-MLA decode attends the ``(B, cap, kvr)`` latent stream
directly (DeepSeek-V3 weight absorption): the effective key of slot ``s``
is ``[c_kv | k_rope]`` and its value is ``c_kv`` itself, shared by every
query head (MQA over the latent).  Queries arrive already absorbed:
``q_eff = [q_nope · W_k | q_rope]`` of shape ``(B, C, H, kvr + rope)``.

Same streaming contract as :mod:`repro.kernels.ring_decode` — the ring
residency ∧ causal ∧ window mask is computed in-kernel from the ``(B,)``
``pos``/``length`` scalars, the latent cache is consumed in ``bk``-slot
blocks with online softmax, and int8 caches are dequantized per block with
their *separate* per-token scales for the ``c_kv`` and ``k_rope`` halves
(a single concatenated scale would be wrong: absmax is taken per half).

Grid: (B·H, cap/bk), KV axis innermost; scratch persists across it.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ring_decode import (NEG_INF, flush_flash_scratch,
                                       online_softmax_step,
                                       reset_flash_scratch, ring_mask_tile)


def _kernel(*refs, scale: float, bk: int, nk: int, cap: int, window: int,
            quantized: bool):
    if quantized:
        (pos_ref, len_ref, n_ref, q_ref, ckv_ref, kr_ref, s1_ref, s2_ref,
         o_ref, m_scr, l_scr, acc_scr) = refs
    else:
        (pos_ref, len_ref, n_ref, q_ref, ckv_ref, kr_ref,
         o_ref, m_scr, l_scr, acc_scr) = refs
    ik = pl.program_id(1)

    @pl.when(ik == 0)
    def _init():
        reset_flash_scratch(m_scr, l_scr, acc_scr)

    q = q_ref[0].astype(jnp.float32)                  # (C, kvr + rope)
    ckv = ckv_ref[0].astype(jnp.float32)              # (bk, kvr)
    kr = kr_ref[0].astype(jnp.float32)                # (bk, rope)
    if quantized:
        ckv = ckv * s1_ref[0]                         # per-half absmax scales
        kr = kr * s2_ref[0]
    k = jnp.concatenate([ckv, kr], axis=-1)           # (bk, kvr + rope)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # (C, bk)

    mask = ring_mask_tile(pos_ref[0, 0], len_ref[0, 0], n_ref[0, 0], ik,
                          bk=bk, cap=cap, C=q.shape[0], window=window)
    s = jnp.where(mask, s, NEG_INF)
    online_softmax_step(s, ckv, m_scr, l_scr, acc_scr)  # value = latent

    @pl.when(ik == nk - 1)
    def _flush():
        flush_flash_scratch(o_ref, m_scr, l_scr, acc_scr)


@functools.partial(jax.jit, static_argnames=("cap", "scale", "window", "bk",
                                             "interpret"))
def mla_ring_decode_kernel(q_eff, c_kv, k_rope, pos, length, n_tokens,
                           cap: int, scale: float,
                           c_kv_scale=None, k_rope_scale=None,
                           window: int = 0, bk: int = 128,
                           interpret: bool = False):
    """q_eff: (B,C,H,kvr+rope); c_kv: (B,capp,kvr), k_rope: (B,capp,rope)
    (capp = cap padded to a bk multiple); pos/length/n_tokens: (B,) ring
    state AFTER the chunk write; *_scale: (B,capp,1) when int8.  ``scale``
    is the softmax scale of the UN-absorbed head dim (1/√(nope+rope) — not
    derivable from q_eff's width).  Returns out_lat (B,C,H,kvr) fp32 — the
    caller applies the absorbed V-projection."""
    B, C, H, dq = q_eff.shape
    capp, kvr = c_kv.shape[1], c_kv.shape[2]
    assert capp % bk == 0, (capp, bk)
    nk = capp // bk
    quantized = c_kv_scale is not None

    qf = q_eff.transpose(0, 2, 1, 3).reshape(B * H, C, dq)
    scal = [x.astype(jnp.int32).reshape(B, 1)
            for x in (pos, length, n_tokens)]

    def row_index(bh, ik_):
        return (bh // H, 0)

    def q_index(bh, ik_):
        return (bh, 0, 0)

    def kv_index(bh, ik_):
        return (bh // H, ik_, 0)

    scalar_spec = pl.BlockSpec((1, 1), row_index, memory_space=pltpu.SMEM)
    in_specs = [scalar_spec] * 3 + [
        pl.BlockSpec((1, C, dq), q_index),
        pl.BlockSpec((1, bk, kvr), kv_index),
        pl.BlockSpec((1, bk, dq - kvr), kv_index),
    ]
    args = scal + [qf, c_kv, k_rope]
    if quantized:
        in_specs += [pl.BlockSpec((1, bk, 1), kv_index)] * 2
        args += [c_kv_scale, k_rope_scale]

    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, bk=bk, nk=nk, cap=cap,
                          window=window, quantized=quantized),
        grid=(B * H, nk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, C, kvr), q_index),
        out_shape=jax.ShapeDtypeStruct((B * H, C, kvr), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((C, 1), jnp.float32),
            pltpu.VMEM((C, 1), jnp.float32),
            pltpu.VMEM((C, kvr), jnp.float32),
        ],
        interpret=interpret,
    )(*args)
    return out.reshape(B, H, C, kvr).transpose(0, 2, 1, 3)
