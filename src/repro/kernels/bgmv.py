"""Batched-gather LoRA delta kernel (BGMV): y_b = scale_b · B_b (A_b x_b).

Multi-tenant decode applies a DIFFERENT adapter to every batch row.  The
adapters live in paged pools (``repro.serve.adapters``): A-pages
(n_pages, page_rank, din), B-pages (n_pages, dout, page_rank), and each
row's indirection row ``tbl[b]`` lists the pages holding its adapter.  The
kernel walks grid (B, Pmax) — row outer, page-slot inner — and for each
(b, j) gathers page ``tbl[b, j]`` via a scalar-prefetch index map, so the
page fetch is a data-dependent block DMA, not an XLA gather materializing
(B, R, din) copies of the pools in HBM.

Rank raggedness is handled in-kernel: lane ℓ of page-slot j is the global
lane j·page_rank + ℓ, masked unless it is < rank_b.  A rank-0 row (the
reserved base-model id 0, or an evicted id) contributes an exact zero —
its padded table entries point at page 0, whose gathered values are fully
masked.  The rank-r intermediate z never round-trips HBM.

Grid order note: the output block (b) is revisited across consecutive j
steps, which is the Pallas accumulation pattern; when Pmax == 1 (rank ≤
page_rank, the common case) consecutive rows serving the SAME adapter map
to the same A/B page blocks and Pallas skips the redundant DMAs.

Inference-only: no custom_vjp (serving never differentiates; training uses
``lora_matmul``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(tbl_ref, rnk_ref, scl_ref, x_ref, a_ref, b_ref, o_ref):
    b = pl.program_id(0)
    j = pl.program_id(1)
    pr = a_ref.shape[1]
    x = x_ref[0]                                           # (C, din)
    z = jnp.dot(x, a_ref[0].T, preferred_element_type=jnp.float32)  # (C, pr)
    lane = jax.lax.broadcasted_iota(jnp.int32, (1, pr), 1) + j * pr
    z = jnp.where(lane < rnk_ref[b], z, 0.0)
    acc = jnp.dot(z, b_ref[0].astype(jnp.float32).T,
                  preferred_element_type=jnp.float32)      # (C, dout)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[0] += acc * scl_ref[b]


@functools.partial(jax.jit, static_argnames=("interpret",))
def bgmv_kernel(x, a_pages, b_pages, row_tbl, row_rank, row_scale,
                interpret: bool = False):
    """x: (B, C, din); a_pages: (P, pr, din); b_pages: (P, dout, pr);
    row_tbl: (B, Pmax) i32 page indices; row_rank: (B,) i32 effective
    ranks; row_scale: (B,) f32.  Returns (B, C, dout) f32 deltas."""
    B, C, din = x.shape
    P, pr, _ = a_pages.shape
    dout = b_pages.shape[1]
    Pmax = row_tbl.shape[1]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B, Pmax),
        in_specs=[
            pl.BlockSpec((1, C, din), lambda b, j, tbl, rnk, scl: (b, 0, 0)),
            pl.BlockSpec((1, pr, din),
                         lambda b, j, tbl, rnk, scl: (tbl[b, j], 0, 0)),
            pl.BlockSpec((1, dout, pr),
                         lambda b, j, tbl, rnk, scl: (tbl[b, j], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, C, dout),
                               lambda b, j, tbl, rnk, scl: (b, 0, 0)),
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, C, dout), jnp.float32),
        interpret=interpret,
    )(row_tbl.astype(jnp.int32), row_rank.astype(jnp.int32),
      row_scale.astype(jnp.float32), x, a_pages, b_pages)
