"""Flash-decoding kernel over a per-slot ring-buffer KV cache (GQA).

Decode-side attention for the serving hot loop: queries are a token chunk
(C = 1..prefill_chunk) attending a ``(B, cap, K, hd)`` ring cache whose
per-row state is just ``pos``/``length`` of shape ``(B,)``.  The dense path
materializes a ``(B, H, C, cap)`` score tensor and a ``(B, C, cap)`` bool
mask per step; this kernel streams the cache in ``bk``-slot key blocks with
online softmax, so live memory is O(C·bk) score tiles — the split-K
("flash-decoding") regime where ``cap`` ≫ ``C``.

The ring mask is computed *inside* the kernel from slot indices (the math of
:func:`repro.models.attention_core.ring_slot_positions`): slot ``s`` holds
absolute position ``p_abs = last - (last - s) mod cap`` and is attendable
iff it is resident (``p_abs >= pos - length``), causally visible
(``p_abs <= qpos``), inside the sliding window when one is set, and a real
slot (``s < cap`` — block padding).  Query positions come from the same
scalars: ``qpos = pos - n_tokens + t`` (``pos`` is the ring state AFTER the
chunk write), so ragged ``n_tokens`` chunks mask correctly per row.

int8 caches are dequantized **per key block** inside the kernel (per-token
absmax scales ride along as a second operand) — no full-precision cache
copy is ever formed in HBM.

Grid: (B·H, cap/bk) with the KV axis innermost/sequential; running
max / normalizer / accumulator persist in VMEM scratch.  GQA KV blocks are
addressed by index_map arithmetic (kv head = q head // group) so the cache
is streamed once per group, never repeated.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def ring_mask_tile(pos, length, n, ik, *, bk: int, cap: int, C: int,
                   window: int):
    """(C, bk) residency ∧ causal ∧ window mask for kv block ``ik`` of one
    batch row, from its ring scalars — the in-kernel form of
    :func:`repro.models.attention_core.ring_block_mask` (shared by the GQA
    and MLA decode kernels)."""
    s_idx = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (C, bk), 1)
    last = pos - 1
    p_abs = last - jnp.mod(last - s_idx, cap)         # slot -> absolute pos
    qpos = pos - n + jax.lax.broadcasted_iota(jnp.int32, (C, bk), 0)
    mask = (p_abs >= pos - length) & (s_idx < cap) & (p_abs <= qpos)
    if window:
        mask &= p_abs > (qpos - window)
    return mask


def reset_flash_scratch(m_scr, l_scr, acc_scr):
    m_scr[...] = jnp.full_like(m_scr, NEG_INF)
    l_scr[...] = jnp.zeros_like(l_scr)
    acc_scr[...] = jnp.zeros_like(acc_scr)


def online_softmax_step(s, v, m_scr, l_scr, acc_scr):
    """Fold one masked (C, bk) score tile + its (bk, dv) values into the
    running max / normalizer / accumulator VMEM scratch."""
    m_prev = m_scr[...]                               # (C, 1)
    m_cur = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur)
    l_scr[...] = l_scr[...] * alpha + p.sum(axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_scr[...] = m_cur


def flush_flash_scratch(o_ref, m_scr, l_scr, acc_scr):
    del m_scr
    o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
                ).astype(o_ref.dtype)


def _kernel(*refs, scale: float, bk: int, nk: int, cap: int, window: int,
            quantized: bool):
    if quantized:
        (pos_ref, len_ref, n_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref,
         o_ref, m_scr, l_scr, acc_scr) = refs
    else:
        (pos_ref, len_ref, n_ref, q_ref, k_ref, v_ref,
         o_ref, m_scr, l_scr, acc_scr) = refs
    ik = pl.program_id(1)

    @pl.when(ik == 0)
    def _init():
        reset_flash_scratch(m_scr, l_scr, acc_scr)

    q = q_ref[0].astype(jnp.float32)                  # (C, hd)
    k = k_ref[0].astype(jnp.float32)                  # (bk, hd)
    v = v_ref[0].astype(jnp.float32)
    if quantized:
        k = k * ks_ref[0]                             # (bk, 1) per-token scale
        v = v * vs_ref[0]
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # (C, bk)

    mask = ring_mask_tile(pos_ref[0, 0], len_ref[0, 0], n_ref[0, 0], ik,
                          bk=bk, cap=cap, C=q.shape[0], window=window)
    s = jnp.where(mask, s, NEG_INF)
    online_softmax_step(s, v, m_scr, l_scr, acc_scr)

    @pl.when(ik == nk - 1)
    def _flush():
        flush_flash_scratch(o_ref, m_scr, l_scr, acc_scr)


@functools.partial(jax.jit, static_argnames=("cap", "window", "bk", "interpret"))
def ring_decode_kernel(q, k, v, pos, length, n_tokens, cap: int,
                       k_scale=None, v_scale=None, window: int = 0,
                       bk: int = 128, interpret: bool = False):
    """q: (B,C,H,hd); k/v: (B,capp,K,hd) ring caches (capp = cap padded to a
    bk multiple); pos/length/n_tokens: (B,) ring state AFTER the chunk
    write; k_scale/v_scale: (B,capp,K,1) per-token absmax scales when the
    cache is int8.  Returns (B,C,H,hd) fp32."""
    B, C, H, hd = q.shape
    capp, K = k.shape[1], k.shape[2]
    g = H // K
    assert capp % bk == 0, (capp, bk)
    nk = capp // bk
    scale = 1.0 / (hd ** 0.5)
    quantized = k_scale is not None

    qf = q.transpose(0, 2, 1, 3).reshape(B * H, C, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * K, capp, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * K, capp, hd)
    scal = [x.astype(jnp.int32).reshape(B, 1)
            for x in (pos, length, n_tokens)]

    def row_index(bh, ik_):
        return (bh // H, 0)

    def q_index(bh, ik_):
        return (bh, 0, 0)

    def kv_index(bh, ik_):
        return (bh // H * K + (bh % H) // g, ik_, 0)

    scalar_spec = pl.BlockSpec((1, 1), row_index, memory_space=pltpu.SMEM)
    in_specs = [scalar_spec] * 3 + [
        pl.BlockSpec((1, C, hd), q_index),
        pl.BlockSpec((1, bk, hd), kv_index),
        pl.BlockSpec((1, bk, hd), kv_index),
    ]
    args = scal + [qf, kf, vf]
    if quantized:
        in_specs += [pl.BlockSpec((1, bk, 1), kv_index)] * 2
        args += [k_scale.transpose(0, 2, 1, 3).reshape(B * K, capp, 1),
                 v_scale.transpose(0, 2, 1, 3).reshape(B * K, capp, 1)]

    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, bk=bk, nk=nk, cap=cap,
                          window=window, quantized=quantized),
        grid=(B * H, nk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, C, hd), q_index),
        out_shape=jax.ShapeDtypeStruct((B * H, C, hd), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((C, 1), jnp.float32),    # running max
            pltpu.VMEM((C, 1), jnp.float32),    # running normalizer
            pltpu.VMEM((C, hd), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(*args)
    return out.reshape(B, H, C, hd).transpose(0, 2, 1, 3)
