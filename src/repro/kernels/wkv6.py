"""RWKV6 WKV recurrence kernel.

The recurrence is sequential in time but embarrassingly parallel over
(batch × head).  Grid: (B·H, S/chunk) with the chunk axis sequential — the
(hd × hd) WKV state lives in VMEM scratch and persists across sequential
grid steps; inside a chunk, a fori_loop advances one token at a time with
rank-1 outer-product updates (VPU work: hd=64 → 64×64 tiles).

This is the TPU re-blocking of the original CUDA wkv kernel: instead of one
thread-block per (b,h) with warp-level state in registers, we keep the state
resident in VMEM and stream r/k/v/w chunks HBM→VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, state_scr, *, chunk: int):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    u = u_ref[0].astype(jnp.float32)                  # (hd,)

    def step(t, state):
        rt = r_ref[0, t].astype(jnp.float32)          # (hd,)
        kt = k_ref[0, t].astype(jnp.float32)
        vt = v_ref[0, t].astype(jnp.float32)
        wt = w_ref[0, t].astype(jnp.float32)
        kv = kt[:, None] * vt[None, :]                # (hd, hd)
        y = jnp.sum(rt[:, None] * (state + u[:, None] * kv), axis=0)
        o_ref[0, t] = y.astype(o_ref.dtype)
        return jnp.exp(wt)[:, None] * state + kv

    state = jax.lax.fori_loop(0, chunk, step, state_scr[...])
    state_scr[...] = state


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6_kernel(r, k, v, w, u, chunk: int = 256, interpret: bool = False):
    """r,k,v,w: (B,S,H,hd); u: (H,hd). Returns fp32 (B,S,H,hd)."""
    B, S, H, hd = r.shape
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk

    def flat(t):
        return t.transpose(0, 2, 1, 3).reshape(B * H, S, hd)

    rf, kf, vf, wf = flat(r), flat(k), flat(v), flat(w)
    uf = jnp.tile(u, (B, 1))                          # (B*H, hd)

    out = pl.pallas_call(
        functools.partial(_kernel, chunk=chunk),
        grid=(B * H, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, hd), lambda bh, ic: (bh, ic, 0)),
            pl.BlockSpec((1, chunk, hd), lambda bh, ic: (bh, ic, 0)),
            pl.BlockSpec((1, chunk, hd), lambda bh, ic: (bh, ic, 0)),
            pl.BlockSpec((1, chunk, hd), lambda bh, ic: (bh, ic, 0)),
            pl.BlockSpec((1, hd), lambda bh, ic: (bh, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, hd), lambda bh, ic: (bh, ic, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, hd), jnp.float32),
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        interpret=interpret,
    )(rf, kf, vf, wf, uf)
    return out.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
