"""Fused LoRA projection kernel: y = x W + (x Aᵀ) Bᵀ  (B pre-scaled).

The rank-r intermediate z = x Aᵀ is produced and consumed inside VMEM —
it never round-trips HBM, which is the point of fusing (XLA will otherwise
materialize z for the (M, r) panel).  Adapter panels A (r × din) and
B_block (bn × r) are small (r ≤ 128) and held resident.

Tiling: grid (M/bm, dout/bn); every block sees the full contraction dim
(din ≤ 8k → x-block ≤ 2 MB at bm=128, W-block ≤ 2 MB at bn=128).
MXU-aligned defaults bm = bn = 128.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w_ref, a_ref, b_ref, o_ref):
    x = x_ref[...]
    acc = jnp.dot(x, w_ref[...], preferred_element_type=jnp.float32)
    z = jnp.dot(x, a_ref[...].T, preferred_element_type=jnp.float32)
    acc = acc + jnp.dot(z.astype(x.dtype), b_ref[...].T,
                        preferred_element_type=jnp.float32)
    o_ref[...] = acc.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def lora_matmul_kernel(x, w, a, b_scaled, bm: int = 128, bn: int = 128,
                       interpret: bool = False):
    """x: (M, din), w: (din, dout), a: (r, din), b_scaled: (dout, r)."""
    M, din = x.shape
    dout = w.shape[1]
    bm = min(bm, M)
    bn = min(bn, dout)
    assert M % bm == 0 and dout % bn == 0, (M, bm, dout, bn)
    grid = (M // bm, dout // bn)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, din), lambda i, j: (i, 0)),
            pl.BlockSpec((din, bn), lambda i, j: (0, j)),
            pl.BlockSpec(a.shape, lambda i, j: (0, 0)),
            pl.BlockSpec((bn, a.shape[0]), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, dout), x.dtype),
        interpret=interpret,
    )(x, w, a, b_scaled)
