"""DEPRECATED re-export shim: partition rules moved to
:mod:`repro.topology.partitioning` (shared by the trainer and the serving
stack; serving-side specs live in :mod:`repro.topology.serve`).  Import
from :mod:`repro.topology` — importing this module warns, and the
``topology-shim-bypass`` lint rule rejects internal use."""
import warnings

from repro.topology.partitioning import (  # noqa: F401
    _COL_MODEL,
    _ROW_MODEL,
    CACHE_LEAF_RANKS,
    ZERO3_THRESHOLD,
    _fits,
    _sanitize,
    batch_pspecs,
    cache_pspecs,
    param_pspec,
    params_pspecs,
    replicated_pspecs,
    to_shardings,
)

warnings.warn(
    "repro.launch.sharding is a deprecated shim; import from repro.topology",
    DeprecationWarning, stacklevel=2)

__all__ = ["CACHE_LEAF_RANKS", "ZERO3_THRESHOLD", "batch_pspecs",
           "cache_pspecs", "param_pspec", "params_pspecs",
           "replicated_pspecs", "to_shardings"]
