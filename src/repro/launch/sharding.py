"""Re-export shim: partition rules moved to
:mod:`repro.topology.partitioning` (shared by the trainer and the serving
stack; serving-side specs live in :mod:`repro.topology.serve`).  Import
from there."""
from repro.topology.partitioning import (  # noqa: F401
    _COL_MODEL,
    _ROW_MODEL,
    CACHE_LEAF_RANKS,
    ZERO3_THRESHOLD,
    _fits,
    _sanitize,
    batch_pspecs,
    cache_pspecs,
    param_pspec,
    params_pspecs,
    replicated_pspecs,
    to_shardings,
)

__all__ = ["CACHE_LEAF_RANKS", "ZERO3_THRESHOLD", "batch_pspecs",
           "cache_pspecs", "param_pspec", "params_pspecs",
           "replicated_pspecs", "to_shardings"]
