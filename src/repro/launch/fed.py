"""Federated launcher: the paper's experimental loop (§4.1) as a CLI.

  PYTHONPATH=src python -m repro.launch.fed --method florist --rounds 10 \
      [--heter] [--tau 0.9] [--clients 100] [--sample 10] \
      [--runner cohort] [--scheduler async] [--codec bf16] \
      [--participation 0.1] [--rank-policy resource] \
      [--dp-clip 1.0] [--dp-epsilon 8]

``--method`` accepts any registered aggregation strategy (including
plugins registered via ``repro.core.aggregators.register_aggregator``);
``--runner`` / ``--scheduler`` / ``--codec`` select the round runtime
seams (see :mod:`repro.core.runtime`).  ``--participation`` switches to
the population-scale ``sampled`` scheduler at that fraction (pair with
``--runner sharded_cohort`` and ``--clients 1024`` for the scaled
simulation); ``--rank-policy resource`` adapts per-task LoRA ranks to
client budgets (AFLoRA-style); ``--dp-clip``/``--dp-sigma`` enable
DP-on-the-wire (``--dp-epsilon`` calibrates σ from a per-round ε and
overrides ``--dp-sigma``).

Fault tolerance (PR 10): ``--checkpoint PATH`` saves the round-boundary
state atomically every ``--checkpoint-every`` rounds and ``--resume``
restarts from it bit-identically; ``--validation {off,screen,full}`` /
``--min-clients`` configure the server's update gate; the ``--fault-*``
flags and ``--crash-at ROUND:POINT`` drive the deterministic fault
injector (testing/chaos runs).
"""
from __future__ import annotations

import argparse
import json

from repro.common.config import FedConfig, LoRAConfig, ModelConfig, OptimConfig
from repro.core.aggregators import available_aggregators
from repro.core.federated import FederatedTrainer
from repro.core.privacy import noise_multiplier_for_epsilon
from repro.core.runtime import (CRASH_POINTS, FaultPlan, SampledScheduler,
                                available_codecs, available_rank_policies,
                                available_runners, available_schedulers)


def main(argv=None):
    # importing repro.core.distributed registers the sharded backend too
    import repro.core.distributed  # noqa: F401

    ap = argparse.ArgumentParser()
    ap.add_argument("--method", default="florist",
                    choices=available_aggregators())
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--clients", type=int, default=100)
    ap.add_argument("--sample", type=int, default=10)
    ap.add_argument("--tau", type=float, default=0.9)
    ap.add_argument("--alpha", type=float, default=0.5,
                    help="Dirichlet concentration (paper: 0.5)")
    ap.add_argument("--heter", action="store_true")
    ap.add_argument("--local-steps", type=int, default=4)
    ap.add_argument("--svd", default="svd", choices=["svd", "gram"])
    ap.add_argument("--runner", default="sequential",
                    choices=available_runners())
    ap.add_argument("--scheduler", default="sync",
                    choices=available_schedulers())
    ap.add_argument("--codec", default="fp32", choices=available_codecs())
    ap.add_argument("--participation", type=float, default=0.0,
                    help="sampled-scheduler participation fraction "
                         "(overrides --scheduler)")
    ap.add_argument("--rank-policy", default="static",
                    choices=available_rank_policies())
    ap.add_argument("--dp-clip", type=float, default=0.0,
                    help="L2 clip C for each client's update delta")
    ap.add_argument("--dp-sigma", type=float, default=0.0,
                    help="noise multiplier (std = sigma * C on the wire)")
    ap.add_argument("--dp-epsilon", type=float, default=0.0,
                    help="per-round epsilon; calibrates sigma "
                         "(overrides --dp-sigma)")
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--out", default="")
    ap.add_argument("--checkpoint", default="",
                    help="round-boundary checkpoint path (atomic writes)")
    ap.add_argument("--checkpoint-every", type=int, default=1,
                    help="rounds between checkpoint saves")
    ap.add_argument("--resume", action="store_true",
                    help="resume from --checkpoint if it exists "
                         "(bit-identical replay)")
    ap.add_argument("--validation", default="screen",
                    choices=["off", "screen", "full"],
                    help="server-side update gate mode")
    ap.add_argument("--min-clients", type=int, default=1,
                    help="round quorum: accepted updates required to fold")
    ap.add_argument("--fault-seed", type=int, default=0)
    ap.add_argument("--fault-drop", type=float, default=0.0)
    ap.add_argument("--fault-corrupt", type=float, default=0.0)
    ap.add_argument("--fault-duplicate", type=float, default=0.0)
    ap.add_argument("--fault-nan", type=float, default=0.0)
    ap.add_argument("--fault-scale", type=float, default=0.0)
    ap.add_argument("--fault-slow", type=float, default=0.0)
    ap.add_argument("--crash-at", default="",
                    help=f"inject a server crash, e.g. '2:mid_round' "
                         f"(points: {', '.join(CRASH_POINTS)})")
    args = ap.parse_args(argv)

    scheduler = args.scheduler
    if args.participation:
        scheduler = SampledScheduler(fraction=args.participation)
    dp_sigma = args.dp_sigma
    if args.dp_epsilon:
        dp_sigma = noise_multiplier_for_epsilon(args.dp_epsilon)
    faults = None
    if (args.fault_drop or args.fault_corrupt or args.fault_duplicate
            or args.fault_nan or args.fault_scale or args.fault_slow
            or args.crash_at):
        crashes = ()
        if args.crash_at:
            rnd, point = args.crash_at.split(":", 1)
            crashes = ((int(rnd), point),)
        faults = FaultPlan(seed=args.fault_seed, drop=args.fault_drop,
                           corrupt=args.fault_corrupt,
                           duplicate=args.fault_duplicate,
                           nan=args.fault_nan, scale=args.fault_scale,
                           slow=args.fault_slow, crashes=crashes)

    cfg = ModelConfig(name="fed-cli", family="dense", num_layers=args.layers,
                      d_model=args.d_model, num_heads=4, num_kv_heads=2,
                      head_dim=args.d_model // 4, d_ff=2 * args.d_model,
                      vocab_size=512, dtype="float32")
    # paper's heavy-tail heterogeneous rank distribution, scaled to --clients
    c = args.clients
    dist = ((4, 4 * c // 10), (8, 2 * c // 10), (16, 2 * c // 10),
            (32, c // 10), (64, c - (4 * c // 10) - 2 * (2 * c // 10) - c // 10))
    fed = FedConfig(num_clients=c, clients_per_round=args.sample,
                    num_rounds=args.rounds, method=args.method, tau=args.tau,
                    dirichlet_alpha=args.alpha, heterogeneous=args.heter,
                    rank_distribution=dist,
                    zero_padding=args.heter and args.method in ("fedit", "ffa"))
    tr = FederatedTrainer(cfg, fed, LoRAConfig(rank=16, alpha=16.0),
                          OptimConfig(lr=3e-4),
                          local_steps=args.local_steps, svd_method=args.svd,
                          dp_clip=args.dp_clip, dp_sigma=dp_sigma,
                          runner=args.runner, scheduler=scheduler,
                          rank_policy=args.rank_policy,
                          transport=args.codec, faults=faults,
                          validation=args.validation,
                          min_clients=args.min_clients)
    hist = tr.run(args.rounds, verbose=True, checkpoint=args.checkpoint,
                  checkpoint_every=args.checkpoint_every,
                  resume=args.resume)
    if args.out:
        with open(args.out, "w") as f:
            json.dump([vars(h) for h in hist], f, indent=2)
        print(f"history written to {args.out}")


if __name__ == "__main__":
    main()
