"""Federated launcher: the paper's experimental loop (§4.1) as a CLI.

  PYTHONPATH=src python -m repro.launch.fed --method florist --rounds 10 \
      [--heter] [--tau 0.9] [--clients 100] [--sample 10] \
      [--runner cohort] [--scheduler async] [--codec bf16] \
      [--participation 0.1] [--rank-policy resource] \
      [--dp-clip 1.0] [--dp-epsilon 8]

``--method`` accepts any registered aggregation strategy (including
plugins registered via ``repro.core.aggregators.register_aggregator``);
``--runner`` / ``--scheduler`` / ``--codec`` select the round runtime
seams (see :mod:`repro.core.runtime`).  ``--participation`` switches to
the population-scale ``sampled`` scheduler at that fraction (pair with
``--runner sharded_cohort`` and ``--clients 1024`` for the scaled
simulation); ``--rank-policy resource`` adapts per-task LoRA ranks to
client budgets (AFLoRA-style); ``--dp-clip``/``--dp-sigma`` enable
DP-on-the-wire (``--dp-epsilon`` calibrates σ from a per-round ε and
overrides ``--dp-sigma``).
"""
from __future__ import annotations

import argparse
import json

from repro.common.config import FedConfig, LoRAConfig, ModelConfig, OptimConfig
from repro.core.aggregators import available_aggregators
from repro.core.federated import FederatedTrainer
from repro.core.privacy import noise_multiplier_for_epsilon
from repro.core.runtime import (SampledScheduler, available_codecs,
                                available_rank_policies, available_runners,
                                available_schedulers)


def main(argv=None):
    # importing repro.core.distributed registers the sharded backend too
    import repro.core.distributed  # noqa: F401

    ap = argparse.ArgumentParser()
    ap.add_argument("--method", default="florist",
                    choices=available_aggregators())
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--clients", type=int, default=100)
    ap.add_argument("--sample", type=int, default=10)
    ap.add_argument("--tau", type=float, default=0.9)
    ap.add_argument("--alpha", type=float, default=0.5,
                    help="Dirichlet concentration (paper: 0.5)")
    ap.add_argument("--heter", action="store_true")
    ap.add_argument("--local-steps", type=int, default=4)
    ap.add_argument("--svd", default="svd", choices=["svd", "gram"])
    ap.add_argument("--runner", default="sequential",
                    choices=available_runners())
    ap.add_argument("--scheduler", default="sync",
                    choices=available_schedulers())
    ap.add_argument("--codec", default="fp32", choices=available_codecs())
    ap.add_argument("--participation", type=float, default=0.0,
                    help="sampled-scheduler participation fraction "
                         "(overrides --scheduler)")
    ap.add_argument("--rank-policy", default="static",
                    choices=available_rank_policies())
    ap.add_argument("--dp-clip", type=float, default=0.0,
                    help="L2 clip C for each client's update delta")
    ap.add_argument("--dp-sigma", type=float, default=0.0,
                    help="noise multiplier (std = sigma * C on the wire)")
    ap.add_argument("--dp-epsilon", type=float, default=0.0,
                    help="per-round epsilon; calibrates sigma "
                         "(overrides --dp-sigma)")
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--out", default="")
    args = ap.parse_args(argv)

    scheduler = args.scheduler
    if args.participation:
        scheduler = SampledScheduler(fraction=args.participation)
    dp_sigma = args.dp_sigma
    if args.dp_epsilon:
        dp_sigma = noise_multiplier_for_epsilon(args.dp_epsilon)

    cfg = ModelConfig(name="fed-cli", family="dense", num_layers=args.layers,
                      d_model=args.d_model, num_heads=4, num_kv_heads=2,
                      head_dim=args.d_model // 4, d_ff=2 * args.d_model,
                      vocab_size=512, dtype="float32")
    # paper's heavy-tail heterogeneous rank distribution, scaled to --clients
    c = args.clients
    dist = ((4, 4 * c // 10), (8, 2 * c // 10), (16, 2 * c // 10),
            (32, c // 10), (64, c - (4 * c // 10) - 2 * (2 * c // 10) - c // 10))
    fed = FedConfig(num_clients=c, clients_per_round=args.sample,
                    num_rounds=args.rounds, method=args.method, tau=args.tau,
                    dirichlet_alpha=args.alpha, heterogeneous=args.heter,
                    rank_distribution=dist,
                    zero_padding=args.heter and args.method in ("fedit", "ffa"))
    tr = FederatedTrainer(cfg, fed, LoRAConfig(rank=16, alpha=16.0),
                          OptimConfig(lr=3e-4),
                          local_steps=args.local_steps, svd_method=args.svd,
                          dp_clip=args.dp_clip, dp_sigma=dp_sigma,
                          runner=args.runner, scheduler=scheduler,
                          rank_policy=args.rank_policy,
                          transport=args.codec)
    hist = tr.run(args.rounds, verbose=True)
    if args.out:
        with open(args.out, "w") as f:
            json.dump([vars(h) for h in hist], f, indent=2)
        print(f"history written to {args.out}")


if __name__ == "__main__":
    main()
