"""Re-export shim: mesh construction moved to :mod:`repro.topology.mesh`
(shared by the trainer and the serving stack).  Import from there."""
from repro.topology.mesh import (  # noqa: F401
    axis_size,
    data_axes,
    make_host_mesh,
    make_production_mesh,
    make_serve_mesh,
)

__all__ = ["axis_size", "data_axes", "make_host_mesh",
           "make_production_mesh", "make_serve_mesh"]
