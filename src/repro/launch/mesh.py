"""DEPRECATED re-export shim: mesh construction moved to
:mod:`repro.topology.mesh` (shared by the trainer and the serving stack).
Import from :mod:`repro.topology` — importing this module warns, and the
``topology-shim-bypass`` lint rule rejects internal use."""
import warnings

from repro.topology.mesh import (  # noqa: F401
    axis_size,
    data_axes,
    make_host_mesh,
    make_production_mesh,
    make_serve_mesh,
)

warnings.warn(
    "repro.launch.mesh is a deprecated shim; import from repro.topology",
    DeprecationWarning, stacklevel=2)

__all__ = ["axis_size", "data_axes", "make_host_mesh",
           "make_production_mesh", "make_serve_mesh"]
