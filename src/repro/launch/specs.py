"""ShapeDtypeStruct stand-ins for every model input and state object —
the dry-run lowers against these (no allocation ever happens).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.common.config import LoRAConfig, ModelConfig, OptimConfig, ShapeConfig
from repro.models import transformer as T
from repro.optim.adamw import adamw_init
from repro.peft.lora import init_lora


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Batch ShapeDtypeStructs for (cfg, shape)."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    bf16 = jnp.dtype(cfg.dtype)
    f32 = jnp.float32

    if shape.mode == "decode":
        return {"tokens": jax.ShapeDtypeStruct((B, 1), i32)}

    if cfg.frontend == "vision":
        P = cfg.num_patches
        batch = {
            "patch_embeds": jax.ShapeDtypeStruct((B, P, cfg.frontend_dim), bf16),
            "tokens": jax.ShapeDtypeStruct((B, S - P), i32),
        }
        if shape.mode == "train":
            batch["loss_mask"] = jax.ShapeDtypeStruct((B, S - P), f32)
        return batch

    if cfg.frontend == "audio":
        batch = {"frame_embeds": jax.ShapeDtypeStruct((B, S, cfg.frontend_dim), bf16)}
        if shape.mode == "train":
            batch["labels"] = jax.ShapeDtypeStruct((B, S), i32)
            batch["loss_mask"] = jax.ShapeDtypeStruct((B, S), f32)
        return batch

    batch = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
    if shape.mode == "train":
        batch["loss_mask"] = jax.ShapeDtypeStruct((B, S), f32)
    return batch


def state_specs(cfg: ModelConfig, lora: LoRAConfig, targets: Tuple[str, ...]
                ) -> Tuple[Any, Any, Any]:
    """(params, adapters, opt_state) ShapeDtypeStruct trees via eval_shape."""
    def build(key):
        params = T.init(cfg, key)
        adapters = init_lora(params, targets, lora.rank, lora.alpha, key,
                             dtype=jnp.float32)
        opt_state = adamw_init(adapters)
        return params, adapters, opt_state

    return jax.eval_shape(build, jax.random.PRNGKey(0))


def cache_specs(cfg: ModelConfig, shape: ShapeConfig, kv_dtype) -> Any:
    return jax.eval_shape(
        lambda: T.init_cache(cfg, shape.global_batch, shape.seq_len, kv_dtype))
