"""Training launcher: LoRA fine-tuning of any registered architecture on the
host devices (smoke/real) — the single-tenant (non-federated) path.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --smoke \
      --steps 20 [--batch 4] [--seq 64] [--use-kernels]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.io import save
from repro.common.config import LoRAConfig, OptimConfig
from repro.configs import get_config, get_smoke_config, lora_targets
from repro.data.synthetic import make_eval_data
from repro.models import transformer as T
from repro.optim.adamw import adamw_init
from repro.peft.lora import init_lora
from repro.train.step import make_eval_step, make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--rank", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--use-kernels", action="store_true")
    ap.add_argument("--ckpt", default="")
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    key = jax.random.PRNGKey(0)
    params = T.init(cfg, key)
    targets = lora_targets(cfg)
    adapters = init_lora(params, targets, args.rank, float(args.rank), key)
    opt_state = adamw_init(adapters)
    optim = OptimConfig(lr=args.lr)
    step = jax.jit(make_train_step(cfg, optim, remat=False,
                                   loss_chunk=min(args.seq, 512),
                                   use_kernels=args.use_kernels,
                                   grad_accum=args.grad_accum))
    eval_step = jax.jit(make_eval_step(cfg, loss_chunk=min(args.seq, 512)))

    rng = np.random.default_rng(0)
    ev = make_eval_data(num_samples=args.batch * 4, seq_len=args.seq,
                        vocab=cfg.vocab_size)

    def batch_at(i):
        lo = (i * args.batch) % (ev["tokens"].shape[0] - args.batch + 1)
        return {k: jnp.asarray(v[lo: lo + args.batch]) for k, v in ev.items()}

    print(f"training {cfg.name}: {cfg.param_count():,} params, LoRA rank "
          f"{args.rank} on {targets}")
    t0 = time.time()
    for i in range(args.steps):
        adapters, opt_state, metrics = step(params, adapters, opt_state,
                                            batch_at(i))
        if i % max(1, args.steps // 10) == 0 or i == args.steps - 1:
            print(f"step {i:4d} loss={float(metrics['loss']):.4f} "
                  f"acc={float(metrics['accuracy']):.3f} "
                  f"({time.time()-t0:.1f}s)")
    m = eval_step(params, adapters, batch_at(0))
    print(f"final eval: loss={float(m['loss']):.4f} acc={float(m['accuracy']):.3f}")
    if args.ckpt:
        save(args.ckpt, adapters, step=args.steps)
        print(f"adapters saved to {args.ckpt}")


if __name__ == "__main__":
    main()
