from repro.common.xla_env import force_host_devices
force_host_devices(512)

"""Multi-pod dry-run: lower + compile every (architecture × input shape) on
the production meshes, with 512 placeholder host devices.

For each combo this prints/records:
  * compiled.memory_analysis()  — bytes per device (proves it fits),
  * compiled.cost_analysis()    — FLOPs / bytes for the roofline,
  * collective bytes parsed from the optimized HLO (all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute result sizes),
and writes a JSON record under experiments/dryrun/.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-4b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
"""
import argparse
import json
import os
import sys
import time
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.common.config import INPUT_SHAPES, LoRAConfig, ModelConfig, OptimConfig, ShapeConfig
from repro.configs import ASSIGNED, get_config, long_context_variant, lora_targets
from repro.topology import (axis_size, batch_pspecs, cache_pspecs,
                            make_production_mesh, params_pspecs,
                            replicated_pspecs, to_shardings)
from repro.launch.specs import cache_specs, input_specs, state_specs
from repro.train.step import make_serve_step, make_train_step, make_prefill_step

# ---------------------------------------------------------------------------
# v5e hardware constants (roofline)
# ---------------------------------------------------------------------------
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

# HLO text parsing lives in the jax-free audit layer; re-exported here for
# the dry-run record writers and existing callers
from repro.analysis.hlo_audit import (  # noqa: E402,F401
    _COLLECTIVES,
    collective_bytes,
    shape_bytes as _shape_bytes,
)


# ---------------------------------------------------------------------------
# step construction
# ---------------------------------------------------------------------------

def default_grad_accum(cfg: ModelConfig, shape: ShapeConfig, mesh) -> int:
    """Microbatch count: keep the per-device residual-stream carry
    (tokens/µbatch × d_model × 2B × L) around ≤ 2 GiB, capped so the
    microbatch still spans the data axis."""
    if shape.mode != "train":
        return 1
    dp = axis_size(mesh, "data") * axis_size(mesh, "pod")
    carry = shape.global_batch * shape.seq_len // dp * cfg.d_model * 2 * cfg.num_layers
    micro = 1
    while carry / micro > 2e9 and micro < shape.global_batch // dp:
        micro *= 2
    return micro


def build_dryrun(cfg: ModelConfig, shape: ShapeConfig, mesh,
                 lora_rank: int = 16, kv_cache_dtype: str = "bfloat16",
                 use_kernels: bool = False, loss_chunk: int = 512,
                 grad_accum: int = 0):
    """Returns (jitted_fn, example_args as ShapeDtypeStructs)."""
    if grad_accum == 0:
        grad_accum = default_grad_accum(cfg, shape, mesh)
    targets = lora_targets(cfg)
    lora = LoRAConfig(rank=lora_rank, alpha=float(lora_rank), targets=targets)
    optim = OptimConfig()
    params_s, adapters_s, opt_s = state_specs(cfg, lora, targets)
    batch_s = input_specs(cfg, shape)

    params_ps = params_pspecs(mesh, cfg, params_s)
    adapters_ps = replicated_pspecs(adapters_s)
    opt_ps = replicated_pspecs(opt_s)
    batch_ps = batch_pspecs(mesh, cfg, batch_s)

    if shape.mode == "train":
        step = make_train_step(cfg, optim, remat=True, loss_chunk=loss_chunk,
                               use_kernels=use_kernels, grad_accum=grad_accum)
        fn = jax.jit(
            step,
            in_shardings=(to_shardings(mesh, params_ps),
                          to_shardings(mesh, adapters_ps),
                          to_shardings(mesh, opt_ps),
                          to_shardings(mesh, batch_ps)),
            out_shardings=(to_shardings(mesh, adapters_ps),
                           to_shardings(mesh, opt_ps),
                           NamedSharding(mesh, P())),
        )
        return fn, (params_s, adapters_s, opt_s, batch_s)

    vocab_ax = "model" if cfg.vocab_size % axis_size(mesh, "model") == 0 else None

    if shape.mode == "prefill":
        step = make_prefill_step(cfg, use_kernels=use_kernels)
        fn = jax.jit(
            step,
            in_shardings=(to_shardings(mesh, params_ps),
                          to_shardings(mesh, adapters_ps),
                          to_shardings(mesh, batch_ps)),
            out_shardings=NamedSharding(mesh, P(None, vocab_ax)),
        )
        return fn, (params_s, adapters_s, batch_s)

    # decode
    kv_dtype = jnp.int8 if kv_cache_dtype == "int8" else jnp.dtype(cfg.dtype)
    cache_s = cache_specs(cfg, shape, kv_dtype)
    cache_ps = cache_pspecs(mesh, cfg, cache_s)
    step = make_serve_step(cfg)
    fn = jax.jit(
        step,
        in_shardings=(to_shardings(mesh, params_ps),
                      to_shardings(mesh, adapters_ps),
                      to_shardings(mesh, cache_ps),
                      to_shardings(mesh, batch_ps)),
        out_shardings=(NamedSharding(mesh, P(None, vocab_ax)),
                       to_shardings(mesh, cache_ps)),
        donate_argnums=(2,),
    )
    return fn, (params_s, adapters_s, cache_s, batch_s)


def pick_kv_dtype(cfg: ModelConfig, shape: ShapeConfig) -> str:
    """int8 cache where bf16 would exceed v5e HBM (DESIGN.md §Shape-skips).
    The MHA archs (kv heads = heads) carry 2·d_model bytes/token/layer of
    bf16 cache — at 32k × batch 128 that is 21–33 GiB/device on a v5e-256."""
    if shape.mode != "decode":
        return "bfloat16"
    if shape.name == "decode_32k" and cfg.name in (
            "qwen1.5-32b", "phi-3-vision-4.2b", "musicgen-medium"):
        return "int8"
    return "bfloat16"


def arch_shape_config(arch: str, shape_name: str) -> ModelConfig:
    cfg = get_config(arch)
    if shape_name == "long_500k":
        cfg = long_context_variant(cfg)
    return cfg


def run_one(arch: str, shape_name: str, multi_pod: bool = False,
            use_kernels: bool = False, lora_rank: int = 16,
            loss_chunk: int = 512, save: bool = True,
            verbose: bool = True) -> Dict[str, Any]:
    from repro.configs import _ALIAS
    arch = _ALIAS.get(arch, arch)          # canonical record names
    shape = INPUT_SHAPES[shape_name]
    cfg = arch_shape_config(arch, shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    kv = pick_kv_dtype(cfg, shape)

    ga = default_grad_accum(cfg, shape, mesh)
    t0 = time.time()
    fn, args = build_dryrun(cfg, shape, mesh, lora_rank=lora_rank,
                            kv_cache_dtype=kv, use_kernels=use_kernels,
                            loss_chunk=loss_chunk, grad_accum=ga)
    from repro.common.pjit_utils import active_mesh
    with mesh, active_mesh(mesh):
        lowered = fn.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    coll = collective_bytes(compiled.as_text())

    flops = float(cost.get("flops", 0.0))
    bytes_acc = float(cost.get("bytes accessed", 0.0))
    coll_total = float(sum(coll.values()))

    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": int(n_chips),
        "mode": shape.mode,
        "kv_cache_dtype": kv,
        "grad_accum": ga,
        "sliding_window": cfg.sliding_window,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
            "code_bytes": int(getattr(mem, "generated_code_size_in_bytes", 0)),
        },
        "flops_per_device": flops,
        "bytes_per_device": bytes_acc,
        "collective_bytes_per_device": coll,
        "roofline": {
            # cost_analysis is per-device post-SPMD; global = per_device*chips
            "compute_s": flops / PEAK_FLOPS,
            "memory_s": bytes_acc / HBM_BW,
            "collective_s": coll_total / ICI_BW,
        },
        "model_params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
    }
    r = rec["roofline"]
    dom = max(r, key=r.get)
    rec["roofline"]["dominant"] = dom

    if verbose:
        hbm_gib = (rec["memory"]["argument_bytes"] + rec["memory"]["temp_bytes"]
                   + rec["memory"]["output_bytes"]) / 2**30
        print(f"[dryrun] {arch:22s} {shape_name:12s} {rec['mesh']:8s} "
              f"compile={t_compile:6.1f}s mem/dev={hbm_gib:7.2f}GiB "
              f"flops/dev={flops:.3e} bytes/dev={bytes_acc:.3e} "
              f"coll/dev={coll_total:.3e} dominant={dom}")
    if save:
        outdir = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                              "experiments", "dryrun")
        os.makedirs(outdir, exist_ok=True)
        fname = os.path.join(outdir,
                             f"{arch}_{shape_name}_{rec['mesh']}.json".replace("/", "_"))
        if os.path.exists(fname):     # preserve an existing analysis section
            with open(fname) as f:
                old = json.load(f)
            if "analysis" in old:
                rec["analysis"] = old["analysis"]
        with open(fname, "w") as f:
            json.dump(rec, f, indent=2)
    return rec


# ---------------------------------------------------------------------------
# roofline analysis lowering (exact FLOPs/bytes/collectives)
# ---------------------------------------------------------------------------
# XLA's cost_analysis counts a while-loop body ONCE, so the scanned fit-proof
# compile under-reports FLOPs by ~L×.  For the roofline we therefore lower
# *unrolled* variants at two reduced depths (L1, L2) on the same mesh and
# extrapolate linearly in depth:  F(L) ≈ F(L1) + (F(L2)-F(L1))/(L2-L1)·(L-L1).
# Known approximations (documented in EXPERIMENTS.md §Roofline):
#   * deepseek: the 2 extra dense layers are priced as MoE layers (≲3%);
#   * rwkv: the WKV time scan stays rolled (flops ≲2% of the block; its HBM
#     state traffic is a CPU-lowering artifact — the Pallas kernel keeps the
#     state in VMEM).

def _reduced_pair(cfg: ModelConfig):
    if cfg.family == "hybrid":
        l1, l2 = cfg.attn_every, 2 * cfg.attn_every
        return cfg.replace(num_layers=l1), cfg.replace(num_layers=l2), l1, l2
    kw = {}
    if cfg.first_dense_layers:
        kw["first_dense_layers"] = 1
    return (cfg.replace(num_layers=2, **kw), cfg.replace(num_layers=4, **kw),
            2, 4)


def run_analysis(arch: str, shape_name: str, multi_pod: bool = False,
                 lora_rank: int = 16, verbose: bool = True) -> Dict[str, Any]:
    from repro.common import flags
    from repro.configs import _ALIAS
    arch = _ALIAS.get(arch, arch)          # canonical record names
    shape = INPUT_SHAPES[shape_name]
    cfg = arch_shape_config(arch, shape_name)
    c1, c2, l1, l2 = _reduced_pair(cfg)
    mesh = make_production_mesh(multi_pod=multi_pod)
    kv = pick_kv_dtype(cfg, shape)

    def measure(c):
        from repro.common.pjit_utils import active_mesh
        fn, args = build_dryrun(c, shape, mesh, lora_rank=lora_rank,
                                kv_cache_dtype=kv, grad_accum=1)
        with mesh, active_mesh(mesh):
            compiled = fn.lower(*args).compile()
        cost = compiled.cost_analysis()
        if isinstance(cost, list):
            cost = cost[0]
        coll = collective_bytes(compiled.as_text())
        return (float(cost.get("flops", 0.0)),
                float(cost.get("bytes accessed", 0.0)),
                float(sum(coll.values())), coll)

    flags.set_analysis_unroll(True)
    try:
        t0 = time.time()
        f1, b1, cl1, _ = measure(c1)
        f2, b2, cl2, coll2 = measure(c2)
        dt = time.time() - t0
    finally:
        flags.set_analysis_unroll(False)

    L = cfg.num_layers

    def extrap(v1, v2):
        slope = (v2 - v1) / (l2 - l1)
        return max(v1 + slope * (L - l1), v1)

    flops = extrap(f1, f2)
    byts = extrap(b1, b2)
    coll = extrap(cl1, cl2)
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "layers_measured": [l1, l2],
        "flops_per_device": flops,
        "bytes_per_device": byts,
        "collective_bytes_per_device": coll,
        "collective_breakdown_L2": coll2,
        "analysis_wall_s": round(dt, 1),
        "roofline": {
            "compute_s": flops / PEAK_FLOPS,
            "memory_s": byts / HBM_BW,
            "collective_s": coll / ICI_BW,
        },
    }
    r = rec["roofline"]
    r["dominant"] = max(("compute_s", "memory_s", "collective_s"), key=r.get)

    # merge into the dry-run record if present
    outdir = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                          "experiments", "dryrun")
    fname = os.path.join(outdir, f"{arch}_{shape_name}_{rec['mesh']}.json")
    base = {}
    if os.path.exists(fname):
        with open(fname) as f:
            base = json.load(f)
    base["analysis"] = rec
    os.makedirs(outdir, exist_ok=True)
    with open(fname, "w") as f:
        json.dump(base, f, indent=2)
    if verbose:
        print(f"[analysis] {arch:22s} {shape_name:12s} "
              f"flops/dev={flops:.3e} bytes/dev={byts:.3e} coll/dev={coll:.3e} "
              f"compute={r['compute_s']:.4f}s memory={r['memory_s']:.4f}s "
              f"coll={r['collective_s']:.4f}s dom={r['dominant']} ({dt:.0f}s)")
    return rec


def run_aggregation_dryrun(multi_pod: bool = False, num_layers: int = 22,
                           num_proj: int = 2, m: int = 2048, n: int = 2048,
                           clients: int = 10, rank: int = 16,
                           tau: float = 0.9, verbose: bool = True):
    """Lower + compile the FLoRIST *server aggregation itself* as a sharded
    TPU program (layers × projections sharded over 'model', Gram-route thin
    SVDs) on the production mesh — the paper's Table-4 step as it would run
    on the pod.  TinyLlama geometry by default."""
    from repro.common.pjit_utils import active_mesh
    from repro.core.distributed import make_sharded_florist
    mesh = make_production_mesh(multi_pod=multi_pod)
    L = num_layers * num_proj
    r = clients * rank
    fn = make_sharded_florist(mesh, tau=tau, svd_method="gram")
    Bs = jax.ShapeDtypeStruct((L, m, r), jnp.float32)
    As = jax.ShapeDtypeStruct((L, r, n), jnp.float32)
    with mesh, active_mesh(mesh):
        compiled = fn.lower(Bs, As).compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    coll = collective_bytes(compiled.as_text())
    mem = compiled.memory_analysis()
    rec = {
        "kind": "florist_server_aggregation",
        "mesh": "2x16x16" if multi_pod else "16x16",
        "geometry": {"layers": L, "m": m, "n": n, "stacked_rank": r},
        "flops_per_device": float(cost.get("flops", 0.0)),
        "bytes_per_device": float(cost.get("bytes accessed", 0.0)),
        "collective_bytes_per_device": coll,
        "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
        "est_seconds_compute": float(cost.get("flops", 0.0)) / PEAK_FLOPS,
    }
    outdir = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                          "experiments", "dryrun")
    os.makedirs(outdir, exist_ok=True)
    with open(os.path.join(outdir, f"server_aggregation_{rec['mesh']}.json"),
              "w") as f:
        json.dump(rec, f, indent=2)
    if verbose:
        print(f"[aggregation] {rec['mesh']} flops/dev={rec['flops_per_device']:.3e} "
              f"coll/dev={sum(coll.values()):.3e} "
              f"est_compute={rec['est_seconds_compute']*1e6:.1f}us")
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(INPUT_SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--use-kernels", action="store_true")
    ap.add_argument("--analyze", action="store_true",
                    help="run the unrolled reduced-depth roofline lowering "
                         "instead of the fit-proof compile")
    ap.add_argument("--aggregation", action="store_true",
                    help="dry-run the sharded FLoRIST server aggregation")
    ap.add_argument("--lora-rank", type=int, default=16)
    args = ap.parse_args(argv)

    if args.aggregation:
        run_aggregation_dryrun(multi_pod=args.multi_pod)
        return

    combos = []
    if args.all:
        for a in ASSIGNED:
            for s in INPUT_SHAPES:
                combos.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        combos = [(args.arch, args.shape)]

    failures = []
    for a, s in combos:
        try:
            if args.analyze:
                run_analysis(a, s, multi_pod=args.multi_pod,
                             lora_rank=args.lora_rank)
            else:
                run_one(a, s, multi_pod=args.multi_pod,
                        use_kernels=args.use_kernels, lora_rank=args.lora_rank)
        except Exception as e:  # noqa: BLE001 — report, keep sweeping
            failures.append((a, s, repr(e)[:200]))
            print(f"[dryrun] FAIL {a} {s}: {e}", file=sys.stderr)
    if failures:
        print(f"\n{len(failures)} failures:", file=sys.stderr)
        for f in failures:
            print("  ", f, file=sys.stderr)
        sys.exit(1)
    print(f"\nall {len(combos)} combos lowered+compiled OK")


if __name__ == "__main__":
    main()
