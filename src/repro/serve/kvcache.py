"""KV caches for batched decode.

Supports:
  * full-context caches (capacity = context length),
  * sliding-window ring-buffer caches (capacity = window) — the documented
    sub-quadratic variant used for ``long_500k`` on full-attention archs,
  * int8-quantized storage (per-token, per-head absmax scales) — used where
    the bf16 cache exceeds HBM (qwen1.5-32b @ decode_32k),
  * MLA compressed-latent caches (DeepSeek-V3): only (c_kv, k_rope) stored.

All update ops are jit/pjit-friendly (dynamic_update_slice at ``pos % cap``).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.common.config import ModelConfig


# ---------------------------------------------------------------------------
# int8 quantization helpers
# ---------------------------------------------------------------------------

def quant(x: jnp.ndarray):
    """absmax int8 quantization over the last axis. Returns (q, scale)."""
    s = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True) / 127.0
    s = jnp.maximum(s, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / s), -127, 127).astype(jnp.int8)
    return q, s.astype(jnp.float32)


def dequant(q: jnp.ndarray, s: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * s


# ---------------------------------------------------------------------------
# GQA attention cache
# ---------------------------------------------------------------------------

def attn_cache(cfg: ModelConfig, batch: int, capacity: int, dtype=jnp.bfloat16) -> Dict:
    K, hd = cfg.num_kv_heads, cfg.head_dim
    int8 = dtype == jnp.int8
    store = jnp.int8 if int8 else dtype
    c = {
        "k": jnp.zeros((batch, capacity, K, hd), store),
        "v": jnp.zeros((batch, capacity, K, hd), store),
        "pos": jnp.zeros((), jnp.int32),       # absolute next position
        "length": jnp.zeros((), jnp.int32),    # tokens resident (<= capacity)
    }
    if int8:
        c["k_scale"] = jnp.zeros((batch, capacity, K, 1), jnp.float32)
        c["v_scale"] = jnp.zeros((batch, capacity, K, 1), jnp.float32)
    return c


def cache_update(cfg: ModelConfig, cache: Dict, k, v) -> Dict:
    """Insert one token's k,v (B,1,K,hd) at slot pos % capacity."""
    cap = cache["k"].shape[1]
    slot = cache["pos"] % cap
    c = dict(cache)
    if cache["k"].dtype == jnp.int8:
        kq, ks = quant(k)
        vq, vs = quant(v)
        c["k"] = jax.lax.dynamic_update_slice_in_dim(cache["k"], kq, slot, axis=1)
        c["v"] = jax.lax.dynamic_update_slice_in_dim(cache["v"], vq, slot, axis=1)
        c["k_scale"] = jax.lax.dynamic_update_slice_in_dim(cache["k_scale"], ks, slot, axis=1)
        c["v_scale"] = jax.lax.dynamic_update_slice_in_dim(cache["v_scale"], vs, slot, axis=1)
    else:
        c["k"] = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k.astype(cache["k"].dtype), slot, axis=1)
        c["v"] = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v.astype(cache["v"].dtype), slot, axis=1)
    c["pos"] = cache["pos"] + 1
    c["length"] = jnp.minimum(cache["length"] + 1, cap)
    return c


def cache_kv(cfg: ModelConfig, cache: Dict):
    """Return attendable (k, v) as fp tensors."""
    if cache["k"].dtype == jnp.int8:
        k = dequant(cache["k"], cache["k_scale"]).astype(jnp.bfloat16)
        v = dequant(cache["v"], cache["v_scale"]).astype(jnp.bfloat16)
        return k, v
    return cache["k"], cache["v"]


# ---------------------------------------------------------------------------
# MLA compressed cache (DeepSeek-V3)
# ---------------------------------------------------------------------------

def mla_cache(cfg: ModelConfig, batch: int, capacity: int, dtype=jnp.bfloat16) -> Dict:
    int8 = dtype == jnp.int8
    store = jnp.int8 if int8 else dtype
    c = {
        "c_kv": jnp.zeros((batch, capacity, cfg.kv_lora_rank), store),
        "k_rope": jnp.zeros((batch, capacity, cfg.qk_rope_head_dim), store),
        "pos": jnp.zeros((), jnp.int32),
        "length": jnp.zeros((), jnp.int32),
    }
    if int8:
        c["c_kv_scale"] = jnp.zeros((batch, capacity, 1), jnp.float32)
        c["k_rope_scale"] = jnp.zeros((batch, capacity, 1), jnp.float32)
    return c


def mla_cache_update(cache: Dict, c_kv_t, k_rope_t) -> Dict:
    """c_kv_t: (B,1,kvr), k_rope_t: (B,1,rope)."""
    cap = cache["c_kv"].shape[1]
    slot = cache["pos"] % cap
    c = dict(cache)
    if cache["c_kv"].dtype == jnp.int8:
        q1, s1 = quant(c_kv_t)
        q2, s2 = quant(k_rope_t)
        c["c_kv"] = jax.lax.dynamic_update_slice_in_dim(cache["c_kv"], q1, slot, axis=1)
        c["k_rope"] = jax.lax.dynamic_update_slice_in_dim(cache["k_rope"], q2, slot, axis=1)
        c["c_kv_scale"] = jax.lax.dynamic_update_slice_in_dim(cache["c_kv_scale"], s1, slot, axis=1)
        c["k_rope_scale"] = jax.lax.dynamic_update_slice_in_dim(cache["k_rope_scale"], s2, slot, axis=1)
    else:
        c["c_kv"] = jax.lax.dynamic_update_slice_in_dim(
            cache["c_kv"], c_kv_t.astype(cache["c_kv"].dtype), slot, axis=1)
        c["k_rope"] = jax.lax.dynamic_update_slice_in_dim(
            cache["k_rope"], k_rope_t.astype(cache["k_rope"].dtype), slot, axis=1)
    c["pos"] = cache["pos"] + 1
    c["length"] = jnp.minimum(cache["length"] + 1, cap)
    return c
