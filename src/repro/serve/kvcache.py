"""KV caches for batched decode.

Supports:
  * full-context caches (capacity = context length),
  * sliding-window ring-buffer caches (capacity = window) — the documented
    sub-quadratic variant used for ``long_500k`` on full-attention archs,
  * int8-quantized storage (per-token, per-head absmax scales) — used where
    the bf16 cache exceeds HBM (qwen1.5-32b @ decode_32k),
  * MLA compressed-latent caches (DeepSeek-V3): only (c_kv, k_rope) stored.

Positions are **per batch slot**: ``pos``/``length`` have shape ``(B,)`` so
every slot of a continuous-batching engine advances its own ring
independently — a freed slot is re-armed with :func:`reset_slot` and the new
occupant starts writing at its own position 0 instead of the previous
request's global offset (the cross-request contamination bug).

Update ops accept a whole token *chunk* ``(B, C, ...)`` with an optional
per-slot valid count ``n_tokens: (B,)`` (rows with ``n_tokens[b] == 0`` are
untouched), so chunked prefill and masked continuous batching are one jitted
write.  All ops are jit/pjit-friendly (per-row ring scatter at
``(pos + t) % cap``).
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.common.config import ModelConfig


# ---------------------------------------------------------------------------
# int8 quantization helpers
# ---------------------------------------------------------------------------

def quant(x: jnp.ndarray):
    """absmax int8 quantization over the last axis. Returns (q, scale)."""
    s = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True) / 127.0
    s = jnp.maximum(s, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / s), -127, 127).astype(jnp.int8)
    return q, s.astype(jnp.float32)


def dequant(q: jnp.ndarray, s: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * s


# ---------------------------------------------------------------------------
# per-slot ring write
# ---------------------------------------------------------------------------

def _ring_write(buf: jnp.ndarray, val: jnp.ndarray, pos: jnp.ndarray,
                n: jnp.ndarray) -> jnp.ndarray:
    """Write a token chunk into a per-slot ring buffer.

    buf: (B, cap, ...), val: (B, C, ...), pos/n: (B,).  Row ``b`` writes its
    first ``n[b]`` chunk tokens at ring slots ``(pos[b] + t) % cap``; when
    ``n[b] > cap`` only the last ``cap`` tokens land (last write wins, as in
    sequential single-token updates).  Dtype-preserving (int8 safe).
    """
    B, cap = buf.shape[:2]
    C = val.shape[1]
    t = jnp.arange(C)[None, :]
    wpos = (pos[:, None] + t) % cap                                # (B,C)
    valid = (t < n[:, None]) & (t >= n[:, None] - cap)             # (B,C)
    # O(C) per-row scatter: invalid lanes are pushed out of bounds and
    # dropped; valid lanes hit unique slots (only the last `cap` tokens of
    # a chunk write), so there are never duplicate scatter indices
    idx = jnp.where(valid, wpos, cap)
    return buf.at[jnp.arange(B)[:, None], idx].set(
        val.astype(buf.dtype), mode="drop")


def _advance(cache: Dict, c: Dict, n: jnp.ndarray, cap: int) -> Dict:
    c["pos"] = cache["pos"] + n
    c["length"] = jnp.minimum(cache["length"] + n, cap)
    return c


def _n_tokens(n: Optional[jnp.ndarray], B: int, C: int) -> jnp.ndarray:
    if n is None:
        return jnp.full((B,), C, jnp.int32)
    return n.astype(jnp.int32)


# ---------------------------------------------------------------------------
# GQA attention cache
# ---------------------------------------------------------------------------

def attn_cache(cfg: ModelConfig, batch: int, capacity: int, dtype=jnp.bfloat16) -> Dict:
    K, hd = cfg.num_kv_heads, cfg.head_dim
    int8 = dtype == jnp.int8
    store = jnp.int8 if int8 else dtype
    c = {
        "k": jnp.zeros((batch, capacity, K, hd), store),
        "v": jnp.zeros((batch, capacity, K, hd), store),
        "pos": jnp.zeros((batch,), jnp.int32),     # per-slot next position
        "length": jnp.zeros((batch,), jnp.int32),  # per-slot tokens resident
    }
    if int8:
        c["k_scale"] = jnp.zeros((batch, capacity, K, 1), jnp.float32)
        c["v_scale"] = jnp.zeros((batch, capacity, K, 1), jnp.float32)
    return c


def cache_update(cfg: ModelConfig, cache: Dict, k, v,
                 n_tokens: Optional[jnp.ndarray] = None) -> Dict:
    """Insert a token chunk's k,v (B,C,K,hd) at each row's own ring offset.

    ``n_tokens: (B,)`` marks how many of the C tokens are real per row
    (None = all C); rows with 0 are left untouched (inactive slots).
    """
    cap = cache["k"].shape[1]
    B = k.shape[0]
    n = _n_tokens(n_tokens, B, k.shape[1])
    pos = cache["pos"]
    c = dict(cache)
    if cache["k"].dtype == jnp.int8:
        kq, ks = quant(k)
        vq, vs = quant(v)
        c["k"] = _ring_write(cache["k"], kq, pos, n)
        c["v"] = _ring_write(cache["v"], vq, pos, n)
        c["k_scale"] = _ring_write(cache["k_scale"], ks, pos, n)
        c["v_scale"] = _ring_write(cache["v_scale"], vs, pos, n)
    else:
        c["k"] = _ring_write(cache["k"], k.astype(cache["k"].dtype), pos, n)
        c["v"] = _ring_write(cache["v"], v.astype(cache["v"].dtype), pos, n)
    return _advance(cache, c, n, cap)


def cache_kv(cfg: ModelConfig, cache: Dict):
    """Return attendable (k, v) as fp tensors."""
    if cache["k"].dtype == jnp.int8:
        k = dequant(cache["k"], cache["k_scale"]).astype(jnp.bfloat16)
        v = dequant(cache["v"], cache["v_scale"]).astype(jnp.bfloat16)
        return k, v
    return cache["k"], cache["v"]


# ---------------------------------------------------------------------------
# MLA compressed cache (DeepSeek-V3)
# ---------------------------------------------------------------------------

def mla_cache(cfg: ModelConfig, batch: int, capacity: int, dtype=jnp.bfloat16) -> Dict:
    int8 = dtype == jnp.int8
    store = jnp.int8 if int8 else dtype
    c = {
        "c_kv": jnp.zeros((batch, capacity, cfg.kv_lora_rank), store),
        "k_rope": jnp.zeros((batch, capacity, cfg.qk_rope_head_dim), store),
        "pos": jnp.zeros((batch,), jnp.int32),
        "length": jnp.zeros((batch,), jnp.int32),
    }
    if int8:
        c["c_kv_scale"] = jnp.zeros((batch, capacity, 1), jnp.float32)
        c["k_rope_scale"] = jnp.zeros((batch, capacity, 1), jnp.float32)
    return c


def mla_cache_update(cache: Dict, c_kv_t, k_rope_t,
                     n_tokens: Optional[jnp.ndarray] = None) -> Dict:
    """c_kv_t: (B,C,kvr), k_rope_t: (B,C,rope); per-row ring writes."""
    cap = cache["c_kv"].shape[1]
    B = c_kv_t.shape[0]
    n = _n_tokens(n_tokens, B, c_kv_t.shape[1])
    pos = cache["pos"]
    c = dict(cache)
    if cache["c_kv"].dtype == jnp.int8:
        q1, s1 = quant(c_kv_t)
        q2, s2 = quant(k_rope_t)
        c["c_kv"] = _ring_write(cache["c_kv"], q1, pos, n)
        c["k_rope"] = _ring_write(cache["k_rope"], q2, pos, n)
        c["c_kv_scale"] = _ring_write(cache["c_kv_scale"], s1, pos, n)
        c["k_rope_scale"] = _ring_write(cache["k_rope_scale"], s2, pos, n)
    else:
        c["c_kv"] = _ring_write(cache["c_kv"],
                                c_kv_t.astype(cache["c_kv"].dtype), pos, n)
        c["k_rope"] = _ring_write(cache["k_rope"],
                                  k_rope_t.astype(cache["k_rope"].dtype), pos, n)
    return _advance(cache, c, n, cap)


# ---------------------------------------------------------------------------
# slot reset (continuous batching)
# ---------------------------------------------------------------------------

# un-stacked rank of every known cache/state leaf: the batch axis of a leaf
# sits at ``ndim - rank`` (leaves may carry leading layer-stack axes).
# Defined in the shared topology layer so partition rules and these reset
# ops agree on one table.
from repro.topology.partitioning import CACHE_LEAF_RANKS  # noqa: E402


def _reset(cache: Any, row_mask_fn) -> Any:
    def fix(path, leaf):
        last = getattr(path[-1], "key", None) if path else None
        base = CACHE_LEAF_RANKS.get(last, leaf.ndim)
        bax = leaf.ndim - base
        if leaf.ndim == 0 or bax < 0 or bax >= leaf.ndim:
            return leaf
        m = row_mask_fn(leaf.shape[bax])
        m = m.reshape((1,) * bax + (leaf.shape[bax],) + (1,) * (leaf.ndim - bax - 1))
        return jnp.where(m, jnp.zeros_like(leaf), leaf)
    return jax.tree_util.tree_map_with_path(fix, cache)


def reset_slots(cache: Any, mask: jnp.ndarray) -> Any:
    """Zero the cache rows of every slot where ``mask: (B,)`` is True.

    Works on a single layer cache dict, a layer-stacked dict, or the whole
    cache tuple from :func:`repro.models.transformer.init_cache` (attention
    rings, MLA latents, SSM/RWKV recurrent states alike): per-slot
    ``pos``/``length`` restart at 0 and every stateful row is wiped, so the
    next occupant of the slot sees a fresh cache.
    """
    mask = jnp.asarray(mask, bool)
    return _reset(cache, lambda b: mask)


def reset_slot(cache: Any, i) -> Any:
    """Zero batch slot ``i``'s cache rows (jit-friendly, ``i`` may be traced)."""
    return _reset(cache, lambda b: jnp.arange(b) == i)
