"""Batched serving engine: slot-based continuous batching over the decode
step, with sampling strategies (greedy / temperature / top-k / top-p) and
per-sequence stop conditions.

The engine owns a fixed batch of B slots against one KV cache.  Requests
are admitted into free slots; every engine step decodes one token for every
active slot (inactive slots decode into a scratch position and are masked).
This is the single-host serving loop the decode_32k dry-run shape lowers —
here runnable end-to-end on CPU with the smoke configs.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.config import ModelConfig
from repro.models import transformer as T
from repro.train.step import make_serve_step


@dataclasses.dataclass
class SamplingParams:
    temperature: float = 0.0        # 0 => greedy
    top_k: int = 0                  # 0 => no top-k filter
    top_p: float = 1.0              # 1 => no nucleus filter
    max_tokens: int = 32
    stop_token: int = -1            # -1 => never


@dataclasses.dataclass
class Request:
    uid: int
    prompt: List[int]
    params: SamplingParams
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


def sample_logits(logits: jnp.ndarray, params: SamplingParams,
                  key: jax.Array) -> jnp.ndarray:
    """logits: (V,) -> token id. Pure-JAX single-sequence sampler."""
    if params.temperature <= 0.0:
        return jnp.argmax(logits)
    logits = logits / params.temperature
    if params.top_k:
        kth = jax.lax.top_k(logits, params.top_k)[0][-1]
        logits = jnp.where(logits < kth, -jnp.inf, logits)
    if params.top_p < 1.0:
        sorted_logits = jnp.sort(logits)[::-1]
        probs = jax.nn.softmax(sorted_logits)
        cum = jnp.cumsum(probs)
        # smallest set with cumulative prob >= top_p
        cutoff_idx = jnp.searchsorted(cum, params.top_p, side="left")
        cutoff = sorted_logits[jnp.minimum(cutoff_idx, logits.shape[0] - 1)]
        logits = jnp.where(logits < cutoff, -jnp.inf, logits)
    return jax.random.categorical(key, logits)


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params: Any, adapters: Any = None,
                 batch_slots: int = 4, capacity: int = 256,
                 kv_dtype=None, seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.adapters = adapters
        self.B = batch_slots
        self.capacity = capacity
        self.key = jax.random.PRNGKey(seed)
        kv_dtype = kv_dtype or jnp.dtype(cfg.dtype)
        self.cache = T.init_cache(cfg, batch_slots, capacity, kv_dtype)
        self._step = jax.jit(make_serve_step(cfg))
        self.slots: List[Optional[Request]] = [None] * batch_slots
        self._pending: List[Request] = []
        self._uid = 0
        self._last_tokens = np.zeros((batch_slots, 1), np.int32)
        self._prefill_left: Dict[int, List[int]] = {}

    # -- public API -----------------------------------------------------------
    def submit(self, prompt: List[int],
               params: Optional[SamplingParams] = None) -> int:
        self._uid += 1
        self._pending.append(Request(self._uid, list(prompt),
                                     params or SamplingParams()))
        return self._uid

    def run(self, max_steps: int = 1000) -> Dict[int, List[int]]:
        """Run until all submitted requests complete. Returns uid->tokens."""
        results: Dict[int, List[int]] = {}
        for _ in range(max_steps):
            self._admit()
            if all(s is None for s in self.slots) and not self._pending:
                break
            self._engine_step(results)
        # drain stragglers
        for s in self.slots:
            if s is not None:
                results[s.uid] = s.generated
        return results

    # -- internals -------------------------------------------------------------
    def _admit(self):
        for i in range(self.B):
            if self.slots[i] is None and self._pending:
                req = self._pending.pop(0)
                self.slots[i] = req
                # prompt tokens are fed through the decode path (cache fill)
                self._prefill_left[i] = list(req.prompt)
                if not req.prompt:
                    # empty prompt: seed generation from token 0 rather than
                    # whatever token the slot's previous occupant left behind
                    self._last_tokens[i, 0] = 0

    def _engine_step(self, results: Dict[int, List[int]]):
        toks = self._last_tokens.copy()
        feeding = [False] * self.B
        for i, req in enumerate(self.slots):
            if req is None:
                toks[i, 0] = 0
            elif self._prefill_left.get(i):
                toks[i, 0] = self._prefill_left[i].pop(0)
                feeding[i] = True
        logits, self.cache = self._step(self.params, self.adapters,
                                        self.cache, {"tokens": jnp.asarray(toks)})
        self.key, *keys = jax.random.split(self.key, self.B + 1)
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            if feeding[i] and self._prefill_left.get(i):
                continue                      # still consuming the prompt
            tok = int(sample_logits(logits[i], req.params, keys[i]))
            req.generated.append(tok)
            self._last_tokens[i, 0] = tok
            if (tok == req.params.stop_token
                    or len(req.generated) >= req.params.max_tokens):
                req.done = True
                results[req.uid] = req.generated
                self.slots[i] = None
                self._prefill_left.pop(i, None)
