"""Jit-compiled continuous-batching serving engine.

The engine owns a fixed batch of B slots against one KV cache with
**per-slot** ring positions (:mod:`repro.serve.kvcache`).  Requests are
admitted into free slots (the slot's cache rows are wiped on admission);
every engine step is ONE jitted on-device call that

  * feeds each active slot either a whole prompt chunk (chunked prefill,
    ``prefill_chunk`` tokens through the cached sequence path) or its last
    sampled token,
  * masks inactive slots (``n_tokens = 0`` — their cache rows never move),
  * samples the next token for every row that finished its prompt with
    branch-free masked math (greedy / temperature / top-k / top-p as
    ``where``-combined thresholds, no ``lax.cond``),
  * draws randomness from per-request PRNG streams keyed by
    ``fold_in(seed, uid)`` — outputs are invariant to slot placement and
    admission interleaving,
  * applies stop/max-token completion (the stop token is **excluded** from
    the emitted text) and scatters emitted tokens into an on-device output
    buffer.

The host loop only admits requests, picks the step shape (chunked while any
slot is prefilling, otherwise a ``lax.scan`` burst of width-1 steps — a
fixed set of compiled executables, no per-step retraces), and polls
completion flags once per burst.

``decode_impl`` selects the attention interior of every step (dense oracle
| streamed ring-flash-decode | Pallas kernel — see ``transformer.decode``);
the executable set and retrace guarantees are identical for all three.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.config import ModelConfig
from repro.common.pjit_utils import active_mesh
from repro.models import transformer as T
from repro.serve import kvcache as Kv
from repro.serve.adapters import AdapterRegistry, attach, is_device_state


@dataclasses.dataclass
class SamplingParams:
    temperature: float = 0.0        # 0 => greedy
    top_k: int = 0                  # 0 => no top-k filter
    top_p: float = 1.0              # 1 => no nucleus filter
    max_tokens: int = 32
    stop_token: int = -1            # -1 => never


@dataclasses.dataclass
class Request:
    uid: int
    prompt: List[int]
    params: SamplingParams
    adapter_id: int = 0             # 0 = base model, no adapter
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


def sample_token(logits: jnp.ndarray, key: jax.Array, temperature,
                 top_k, top_p) -> jnp.ndarray:
    """logits: (V,) -> token id.  Branch-free masked sampling: greedy,
    temperature, top-k and top-p all compile as one program (``temperature``
    etc. may be traced per-slot values) — vmap-able across batch rows."""
    V = logits.shape[0]
    lf = logits.astype(jnp.float32)
    greedy_tok = jnp.argmax(lf)
    lt = lf / jnp.maximum(temperature, 1e-6)
    sorted_lt = jnp.sort(lt)[::-1]
    # top-k: keep logits >= k-th largest (inactive when top_k == 0)
    kth = sorted_lt[jnp.clip(top_k - 1, 0, V - 1)]
    kth = jnp.where(top_k > 0, kth, -jnp.inf)
    # top-p on the top-k-FILTERED, renormalized distribution (filtering a
    # sorted array by a value threshold keeps it sorted): smallest prefix
    # with mass >= top_p
    sorted_f = jnp.where(sorted_lt < kth, -jnp.inf, sorted_lt)
    probs = jax.nn.softmax(sorted_f)
    cut = jnp.searchsorted(jnp.cumsum(probs), top_p, side="left")
    pth = sorted_f[jnp.minimum(cut, V - 1)]
    pth = jnp.where(top_p < 1.0, pth, -jnp.inf)
    masked = jnp.where(lt < jnp.maximum(kth, pth), -jnp.inf, lt)
    sampled = jax.random.categorical(key, masked)
    return jnp.where(temperature <= 0.0, greedy_tok, sampled).astype(jnp.int32)


def sample_logits(logits: jnp.ndarray, params: SamplingParams,
                  key: jax.Array) -> jnp.ndarray:
    """Back-compat wrapper: sample one token with host-side SamplingParams."""
    return sample_token(logits, key, params.temperature, params.top_k,
                        params.top_p)


def _build_engine_step(cfg: ModelConfig, width: int, stochastic: bool = True,
                       trace_counter: Optional[Dict[Any, int]] = None,
                       decode_impl: str = "dense", lora_impl: str = "xla"):
    """Pure engine step of fixed token ``width``: (params, adapters, cache,
    state) -> (cache, state, finished (B,) bool).  Jit this once per
    (width, stochastic).  ``stochastic=False`` compiles the greedy-only
    variant — plain argmax, no sort/softmax/categorical or key splitting —
    used whenever no outstanding request samples.  (Greedy rows' outputs
    never depend on their keys, and a sampled request keeps the engine in
    the stochastic variant for its whole lifetime, so mode switches cannot
    perturb sampled streams.)  ``decode_impl`` picks the attention interior
    (dense | streamed | kernel — see ``transformer.decode``).

    ``adapters`` may be a classic single-tenant adapter tree OR an
    :class:`AdapterRegistry` device state (paged pools + indirection
    tables): the latter is attached against the per-slot
    ``state["adapter_ids"]`` table so every batch row applies its own
    adapter (``lora_impl`` picks the bgmv Pallas kernel or its XLA twin).
    The branch is resolved at trace time from pytree structure; registry
    churn changes only array VALUES, so it never retraces."""
    C = width

    def step(params, adapters, cache, state):
        if trace_counter is not None:       # python side effect: counts traces
            key = (C, "sampled" if stochastic else "greedy")
            trace_counter[key] = trace_counter.get(key, 0) + 1
        if is_device_state(adapters):
            adapters = attach(adapters, state["adapter_ids"], impl=lora_impl)
        active = state["active"]
        t = jnp.arange(C)[None, :]
        consumed, plen = state["consumed"], state["prompt_len"]
        remaining = jnp.maximum(plen - consumed, 0)
        prefilling = active & (remaining > 0)
        n_pre = jnp.minimum(remaining, C)
        pcap = state["prompt_buf"].shape[1]
        gidx = jnp.clip(consumed[:, None] + t, 0, pcap - 1)
        pre_toks = jnp.take_along_axis(state["prompt_buf"], gidx, axis=1)
        dec_toks = jnp.pad(state["last_token"][:, None], ((0, 0), (0, C - 1)))
        toks = jnp.where(prefilling[:, None], pre_toks, dec_toks)
        n_tok = jnp.where(prefilling, n_pre,
                          jnp.where(active, 1, 0)).astype(jnp.int32)

        lg, cache = T.decode(cfg, params, cache, {"tokens": toks}, adapters,
                             n_tokens=n_tok, decode_impl=decode_impl)
        last = jnp.clip(n_tok - 1, 0, C - 1)
        logits = jnp.take_along_axis(lg, last[:, None, None], axis=1)[:, 0]

        consumed = consumed + jnp.where(prefilling, n_pre, 0)
        # a row samples once its whole prompt is in the cache (covers plain
        # decode rows and the step that consumed the final prompt chunk)
        do_sample = active & (consumed >= plen)

        if stochastic:
            split = jax.vmap(partial(jax.random.split, num=2))(state["keys"])
            keys = jnp.where(do_sample[:, None], split[:, 0], state["keys"])
            tok = jax.vmap(sample_token)(logits, split[:, 1],
                                         state["temperature"],
                                         state["top_k"], state["top_p"])
        else:
            keys = state["keys"]
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)

        hit_stop = tok == state["stop_token"]
        emit = do_sample & ~hit_stop            # stop token is never emitted
        gc = state["gen_count"]
        ocap = state["out_buf"].shape[1]
        sel = ((jnp.arange(ocap)[None, :] == jnp.clip(gc, 0, ocap - 1)[:, None])
               & emit[:, None])
        out_buf = jnp.where(sel, tok[:, None], state["out_buf"])
        gc = gc + emit.astype(jnp.int32)
        finished = do_sample & (hit_stop | (gc >= state["max_tokens"]))

        new_state = dict(state,
                         active=active & ~finished,
                         last_token=jnp.where(emit, tok, state["last_token"]),
                         consumed=consumed,
                         gen_count=gc,
                         out_buf=out_buf,
                         keys=keys)
        return cache, new_state, finished

    return step


class _MeshedFn:
    """A jitted engine fn bound to a mesh.

    Tracing happens on the first call (or an explicit ``lower``), so the
    wrapper re-enters the ambient-mesh context around both — that is what
    lets the trace-time ``constrain`` pins inside the model resolve against
    the engine's mesh."""

    def __init__(self, fn, mesh):
        self._fn, self._mesh = fn, mesh

    def __call__(self, *args):
        with active_mesh(self._mesh):
            return self._fn(*args)

    def lower(self, *args, **kw):
        with active_mesh(self._mesh):
            return self._fn.lower(*args, **kw)


def _build_engine_burst(cfg: ModelConfig, steps: int, stochastic: bool = True,
                        trace_counter: Optional[Dict[Any, int]] = None,
                        decode_impl: str = "dense", lora_impl: str = "xla"):
    """``steps`` width-1 engine steps as ONE jitted ``lax.scan`` — the
    decode hot loop with a single dispatch per burst.  Finished/inactive
    rows no-op inside the scan (n_tokens = 0), so a fixed burst length is
    safe even when a slot completes mid-burst."""
    step = _build_engine_step(cfg, 1, stochastic, decode_impl=decode_impl,
                              lora_impl=lora_impl)

    def burst(params, adapters, cache, state):
        if trace_counter is not None:
            key = (f"burst{steps}", "sampled" if stochastic else "greedy")
            trace_counter[key] = trace_counter.get(key, 0) + 1

        def body(carry, _):
            cache, state = carry
            cache, state, _ = step(params, adapters, cache, state)
            return (cache, state), None

        (cache, state), _ = jax.lax.scan(body, (cache, state), None,
                                         length=steps)
        return cache, state

    return burst


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params: Any, adapters: Any = None,
                 batch_slots: int = 4, capacity: int = 256,
                 kv_dtype=None, seed: int = 0, prefill_chunk: int = 8,
                 max_tokens_cap: int = 1024, decode_impl: str = "dense",
                 registry: Optional[AdapterRegistry] = None,
                 lora_impl: Optional[str] = None,
                 mesh: Optional[Any] = None):
        if decode_impl not in ("dense", "streamed", "kernel"):
            raise ValueError(f"unknown decode_impl {decode_impl!r}")
        if registry is not None and adapters is not None:
            raise ValueError("pass a single-tenant adapter tree OR a "
                             "multi-tenant registry, not both")
        self.cfg = cfg
        self.params = params
        self.adapters = adapters
        self.registry = registry
        # bgmv Pallas kernel alongside the kernel attention interior, the
        # XLA gather/einsum twin otherwise (overridable independently)
        self.lora_impl = lora_impl or (
            "kernel" if decode_impl == "kernel" else "xla")
        self.B = batch_slots
        self.capacity = capacity
        self.decode_impl = decode_impl
        kv_dtype = kv_dtype or jnp.dtype(cfg.dtype)
        # SSM/RWKV recurrences step one token at a time; attention families
        # take whole chunks through the cached sequence path
        ring_cap = min(capacity, cfg.sliding_window or capacity)
        self.chunk = (1 if cfg.family in ("ssm", "hybrid")
                      else max(1, min(prefill_chunk, ring_cap)))
        self.cache = T.init_cache(cfg, batch_slots, capacity, kv_dtype,
                                  prefill_chunk=self.chunk)
        self._base_key = jax.random.PRNGKey(seed)
        B = batch_slots
        self._state: Dict[str, jnp.ndarray] = {
            "active": jnp.zeros((B,), bool),
            "last_token": jnp.zeros((B,), jnp.int32),
            "consumed": jnp.zeros((B,), jnp.int32),
            "prompt_len": jnp.zeros((B,), jnp.int32),
            "prompt_buf": jnp.zeros((B, max(capacity, 1)), jnp.int32),
            "gen_count": jnp.zeros((B,), jnp.int32),
            "out_buf": jnp.zeros((B, max(max_tokens_cap, 1)), jnp.int32),
            "temperature": jnp.zeros((B,), jnp.float32),
            "top_k": jnp.zeros((B,), jnp.int32),
            "top_p": jnp.ones((B,), jnp.float32),
            "max_tokens": jnp.zeros((B,), jnp.int32),
            "stop_token": jnp.full((B,), -1, jnp.int32),
            "keys": jnp.zeros((B, 2), jnp.uint32),
            # slot -> adapter id (0 = base); the attach() gather key
            "adapter_ids": jnp.zeros((B,), jnp.int32),
        }
        self.slots: List[Optional[Request]] = [None] * batch_slots
        self._pending: List[Request] = []
        self._uid = 0
        self._host_left: Dict[int, int] = {}       # slot -> prompt tokens left
        self._step_fns: Dict[int, Any] = {}
        # (width, mode) / ("burstN", mode) -> #traces (bench + retrace tests)
        self.trace_counts: Dict[Any, int] = {}
        # mesh=None keeps today's single-device engine bit-for-bit; with a
        # mesh every engine-owned tree is committed to its serve sharding
        # and every executable gets explicit in_/out_shardings
        self.mesh = mesh
        self._shardings: Optional[Dict[str, Any]] = None
        if mesh is not None:
            self._install_mesh(mesh)

    # -- public API -----------------------------------------------------------
    def submit(self, prompt: List[int],
               params: Optional[SamplingParams] = None,
               adapter_id: int = 0) -> int:
        params = params or SamplingParams()
        if len(prompt) > int(self._state["prompt_buf"].shape[1]):
            raise ValueError(f"prompt of {len(prompt)} tokens exceeds the "
                             f"engine prompt capacity {self.capacity}")
        if params.max_tokens < 1:
            raise ValueError(f"max_tokens={params.max_tokens} must be >= 1")
        if params.max_tokens > int(self._state["out_buf"].shape[1]):
            raise ValueError(f"max_tokens={params.max_tokens} exceeds "
                             f"max_tokens_cap={self._state['out_buf'].shape[1]}")
        # validated at SUBMIT time: an unknown or evicted id is a loud host
        # error, never a silent base-model fallback
        if adapter_id != 0:
            if self.registry is None:
                raise ValueError(f"adapter_id={adapter_id} requires an "
                                 "engine constructed with a registry")
            if not self.registry.is_live(adapter_id):
                raise KeyError(f"adapter_id={adapter_id} is unknown or "
                               "evicted from the registry")
        self._uid += 1
        self._pending.append(Request(self._uid, list(prompt), params,
                                     adapter_id=adapter_id))
        return self._uid

    def reset_slot(self, i: int) -> None:
        """Abort slot ``i``'s request and re-arm the slot: the KV ring /
        recurrent rows are wiped AND the slot's adapter-table entry is
        cleared back to the base id, so the next occupant can never run
        against its predecessor's adapter (or a since-evicted one)."""
        if self.slots[i] is None:
            raise ValueError(f"slot {i} is not occupied")
        self.cache = Kv.reset_slot(self.cache, i)
        self._state = dict(
            self._state,
            active=self._state["active"].at[i].set(False),
            adapter_ids=self._state["adapter_ids"].at[i].set(0),
        )
        self.slots[i] = None
        self._host_left.pop(i, None)

    def run(self, max_steps: int = 1000,
            poll_every: int = 8) -> Dict[int, List[int]]:
        """Run until all submitted requests complete (or ``max_steps``
        engine steps elapse).  Returns uid -> generated tokens.  Requests
        still occupying a slot when the step budget runs out are reported
        with their partial output, marked done, and freed — a subsequent
        ``run()`` never re-decodes or double-reports them.

        ``poll_every`` bounds how many decode steps run back-to-back before
        the host syncs completion flags: pure-decode phases run whole
        ``poll_every``-step bursts as one dispatch (the device queue
        pipelines them) and poll only at burst boundaries, so a slot that
        finishes mid-burst — ``max_tokens`` exhaustion or an early
        stop-token exit — idles on-device for up to ``poll_every - 1``
        steps before the host collects it and re-admits from the queue
        (throughput over single-request latency)."""
        results: Dict[int, List[int]] = {}
        steps = 0
        while steps < max_steps:
            self._admit()
            if all(s is None for s in self.slots) and not self._pending:
                break
            prefilling = self._prefilling()
            if not prefilling and poll_every > 1 \
                    and max_steps - steps >= poll_every:
                # pure-decode phase: scan poll_every steps in ONE dispatch
                fn = self._get_burst(poll_every, self._stochastic())
                self.cache, self._state = fn(self.params, self._adapters_arg(),
                                             self.cache, self._state)
                steps += poll_every
                self._poll(results)
            else:
                width = self.chunk if prefilling else 1
                could_sample = any(
                    self.slots[i] is not None
                    and self._host_left.get(i, 0) <= width
                    for i in range(self.B))
                self._engine_step(width)
                steps += 1
                # skip the blocking flag sync on prefill steps where no row
                # consumed its final prompt chunk (nothing can finish)
                if could_sample:
                    self._poll(results)
        self._drain(results)
        return results

    def run_steps(self, steps: int) -> Dict[int, List[int]]:
        """Advance the engine exactly ``steps`` engine steps WITHOUT
        draining: in-flight requests stay resident in their slots (unlike
        :meth:`run`, which reports stragglers' partial output and frees
        them).  Pending requests are admitted as slots open; completed
        requests are collected and returned.  This is the host-controlled
        stepping mode the round→deploy loop uses to interleave serving with
        registry churn (register / swap / evict between steps)."""
        results: Dict[int, List[int]] = {}
        for _ in range(steps):
            self._admit()
            if all(s is None for s in self.slots) and not self._pending:
                break
            self._engine_step()
            self._poll(results)
        return results

    def lower_step(self, width: int = 1, stochastic: bool = False):
        """Lower (not run) one engine step against the engine's current
        trees — the inspection surface the HLO-collective assertions and the
        XLA flag-tuning harness compile."""
        fn = self._get_step(width, stochastic)
        return fn.lower(self.params, self._adapters_arg(), self.cache,
                        self._state)

    # -- internals -------------------------------------------------------------
    def _install_mesh(self, mesh):
        """Pin every engine-owned tree onto ``mesh``.

        Computes the serve pspecs (:mod:`repro.topology.serve`), then
        ``device_put``s params / cache / state (and the registry pools)
        ONCE with the target shardings.  Host-side ``.at[].set`` updates on
        committed arrays preserve their sharding, so admission and registry
        churn keep matching the executables' ``in_shardings`` (a drifted
        committed sharding would be a hard error there, never silent)."""
        from repro import topology
        specs = topology.serve_pspecs(
            mesh, self.cfg, self.params, self.cache, self._state,
            adapters=self._adapters_arg(), lora_impl=self.lora_impl)
        sh = {k: (None if s is None else topology.to_shardings(mesh, s))
              for k, s in specs.items()}
        self.params = jax.device_put(self.params, sh["params"])
        self.cache = jax.device_put(self.cache, sh["cache"])
        self._state = jax.device_put(self._state, sh["state"])
        if self.registry is not None:
            self.registry.place(sh["adapters"])
        elif self.adapters is not None:
            self.adapters = jax.device_put(self.adapters, sh["adapters"])
        self._shardings = sh

    def _jit_engine_fn(self, fn, n_out: int):
        if self._shardings is None:
            return jax.jit(fn)
        sh = self._shardings
        out = (sh["cache"], sh["state"]) + ((None,) if n_out == 3 else ())
        jf = jax.jit(fn, in_shardings=(sh["params"], sh["adapters"],
                                       sh["cache"], sh["state"]),
                     out_shardings=out)
        return _MeshedFn(jf, self.mesh)

    def _adapters_arg(self):
        """What the jitted step receives as ``adapters``: the registry's
        fixed-structure device state in multi-tenant mode (fresh VALUES
        every call — hot-swaps land here — same treedef, so never a
        retrace), else the engine's static adapter tree."""
        if self.registry is not None:
            return self.registry.device_state
        return self.adapters
    def _admit(self):
        admitted = []
        for i in range(self.B):
            if self.slots[i] is None and self._pending:
                req = self._pending.pop(0)
                self.slots[i] = req
                self._host_left[i] = len(req.prompt)
                admitted.append((i, req))
        if not admitted:
            return
        # one cache wipe + one update per state field for the whole cohort:
        # per-slot pos/length restart at 0, recurrent states are zeroed, so
        # no new occupant ever sees its predecessor's keys
        mask = np.zeros(self.B, bool)
        idx = np.asarray([i for i, _ in admitted])
        mask[idx] = True
        self.cache = Kv.reset_slots(self.cache, jnp.asarray(mask))
        st = self._state
        rows = np.zeros((len(admitted), st["prompt_buf"].shape[1]), np.int32)
        for r, (_, req) in enumerate(admitted):
            rows[r, :len(req.prompt)] = req.prompt
        reqs = [req for _, req in admitted]
        ix = jnp.asarray(idx)

        def put(name, vals, dtype):
            return st[name].at[ix].set(jnp.asarray(vals, dtype))

        self._state = dict(
            st,
            active=put("active", [True] * len(reqs), bool),
            # empty prompt: generation seeds from token 0, never from a
            # stale token the slot's previous occupant left behind
            last_token=put("last_token", [0] * len(reqs), jnp.int32),
            consumed=put("consumed", [0] * len(reqs), jnp.int32),
            prompt_len=put("prompt_len", [len(r.prompt) for r in reqs], jnp.int32),
            prompt_buf=st["prompt_buf"].at[ix].set(jnp.asarray(rows)),
            gen_count=put("gen_count", [0] * len(reqs), jnp.int32),
            out_buf=st["out_buf"].at[ix].set(0),
            temperature=put("temperature", [r.params.temperature for r in reqs],
                            jnp.float32),
            top_k=put("top_k", [r.params.top_k for r in reqs], jnp.int32),
            top_p=put("top_p", [r.params.top_p for r in reqs], jnp.float32),
            max_tokens=put("max_tokens", [r.params.max_tokens for r in reqs],
                           jnp.int32),
            stop_token=put("stop_token", [r.params.stop_token for r in reqs],
                           jnp.int32),
            adapter_ids=put("adapter_ids", [r.adapter_id for r in reqs],
                            jnp.int32),
            # per-request PRNG streams: a function of (seed, uid) only, so
            # sampling is invariant to slot placement
            keys=st["keys"].at[ix].set(
                jax.vmap(lambda u: jax.random.fold_in(self._base_key, u))(
                    jnp.asarray([r.uid for r in reqs]))),
        )

    def _stochastic(self) -> bool:
        """Whether any outstanding request samples (temperature > 0): if
        none does, the greedy-only step variant runs — no sort / categorical
        / key splitting in the hot loop."""
        outstanding = self._pending + [s for s in self.slots if s is not None]
        return any(r.params.temperature > 0.0 for r in outstanding)

    def _get_step(self, width: int, stochastic: bool):
        key = (width, stochastic)
        if key not in self._step_fns:
            self._step_fns[key] = self._jit_engine_fn(_build_engine_step(
                self.cfg, width, stochastic, self.trace_counts,
                self.decode_impl, self.lora_impl), n_out=3)
        return self._step_fns[key]

    def _get_burst(self, steps: int, stochastic: bool):
        key = ("burst", steps, stochastic)
        if key not in self._step_fns:
            self._step_fns[key] = self._jit_engine_fn(_build_engine_burst(
                self.cfg, steps, stochastic, self.trace_counts,
                self.decode_impl, self.lora_impl), n_out=2)
        return self._step_fns[key]

    def _prefilling(self) -> bool:
        """Whether any occupied slot is still consuming its prompt."""
        return any(self.slots[i] is not None and self._host_left.get(i, 0) > 0
                   for i in range(self.B))

    def _engine_step(self, width: Optional[int] = None):
        if width is None:
            width = self.chunk if self._prefilling() else 1
        step = self._get_step(width, self._stochastic())
        self.cache, self._state, _ = step(self.params, self._adapters_arg(),
                                          self.cache, self._state)
        for i in range(self.B):
            if self.slots[i] is None:
                continue
            if self._host_left.get(i, 0) > 0:
                self._host_left[i] = max(0, self._host_left[i] - width)

    def _poll(self, results: Dict[int, List[int]]):
        """Sync completion flags once per burst: an occupied slot whose
        device row went inactive has finished."""
        active = np.asarray(self._state["active"])
        done = [i for i, req in enumerate(self.slots)
                if req is not None and not active[i]]
        if done:
            self._collect(done, results)

    def _collect(self, slot_idx, results: Dict[int, List[int]]):
        gc = np.asarray(self._state["gen_count"])
        out = np.asarray(self._state["out_buf"])
        for i in slot_idx:
            i = int(i)
            req = self.slots[i]
            if req is None:
                continue
            req.generated = out[i, :gc[i]].tolist()
            req.done = True
            results[req.uid] = req.generated
            self.slots[i] = None
            self._host_left.pop(i, None)

    def _drain(self, results: Dict[int, List[int]]):
        """Timed-out slots: report partial output, mark done, free the slot
        (and deactivate it on device) so a later run() starts clean."""
        stragglers = [i for i, s in enumerate(self.slots) if s is not None]
        if not stragglers:
            return
        self._collect(stragglers, results)
        mask = self._state["active"].at[jnp.asarray(stragglers)].set(False)
        self._state = dict(self._state, active=mask)


# -- abstract contracts (checked by repro.analysis.contracts) -----------------

from repro.analysis.registry import ContractCase, check_contract  # noqa: E402


def _engine_contract(case, build):
    from repro.analysis import fixtures as FX
    from repro.topology import serve_pspecs
    cfg = FX.tiny_config(case.family)
    if cfg.family == "ssm" and case.decode_impl != "dense":
        return None          # recurrences have no attention interior to swap
    params = FX.abstract_params(cfg)
    cache = FX.abstract_cache(cfg)
    state = FX.engine_state()
    fn, out_check = build(FX, cfg, params, cache, state)
    mesh = FX.abstract_mesh(case.mesh)
    bundle = serve_pspecs(mesh, cfg, params, cache, state)
    tree = {"params": params, "cache": cache, "state": state}
    specs = {k: bundle[k] for k in tree}
    return ContractCase(fn, (params, None, cache, state),
                        out_check=out_check, pspec_tree=(tree, specs),
                        mesh=mesh)


@check_contract("serve.engine_step", families=("gqa", "mla", "moe", "ssm"),
                decode_impls=("dense", "streamed", "kernel"))
def _contract_engine_step(case):
    """The engine step's cache/state avals are a fixed point (this is what
    makes the continuous-batching hot loop retrace-free) and every
    engine-owned tree shards under the serve rules at the mesh width."""

    def build(FX, cfg, params, cache, state):
        step = _build_engine_step(cfg, FX.chunk_width(cfg), stochastic=True,
                                  decode_impl=case.decode_impl)

        def out_check(out, _case):
            c2, s2, finished = out
            assert FX.avals_equal(c2, cache), "cache avals drift"
            assert FX.avals_equal(s2, state), "state avals drift"
            assert finished.shape == (FX.BATCH_SLOTS,), finished.shape
            assert finished.dtype == jnp.bool_, finished.dtype

        return step, out_check

    return _engine_contract(case, build)


@check_contract("serve.decode_burst", families=("gqa", "mla", "moe", "ssm"),
                decode_impls=("dense", "streamed", "kernel"))
def _contract_decode_burst(case):
    """The scanned width-1 burst preserves (cache, state) avals — the
    single-dispatch decode loop admits a fixed burst length."""

    def build(FX, cfg, params, cache, state):
        burst = _build_engine_burst(cfg, steps=2, stochastic=True,
                                    decode_impl=case.decode_impl)

        def out_check(out, _case):
            c2, s2 = out
            assert FX.avals_equal(c2, cache), "cache avals drift"
            assert FX.avals_equal(s2, state), "state avals drift"

        return burst, out_check

    return _engine_contract(case, build)
