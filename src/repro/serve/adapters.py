"""Multi-tenant adapter serving: registry + paged on-device adapter store.

FLoRIST's server mints one compact global low-rank adapter per
cohort/task/round — at production scale many of them are live at once, and
heterogeneous ranks are intrinsic (FLoRA stacking and AFLoRA resource-aware
per-client ranks both produce adapters whose rank varies per tenant and per
round).  This module lets ONE :class:`repro.serve.engine.ServeEngine` serve
them all in one continuous batch:

* **Paged adapter store.**  Adapters live on-device in fixed-shape paged
  pools: one ``(L?, n_pages, page_rank, din)`` A-pool and one
  ``(L?, n_pages, dout, page_rank)`` B-pool per LoRA-bearing leaf (``L`` is
  the layer-stack axis of scanned leaves).  An adapter of rank ``r``
  occupies ``ceil(r / page_rank)`` pages via an indirection table, so
  registering / evicting / swapping an adapter of ANY rank never changes an
  array shape — zero retraces — and never touches pages held by other
  adapters, so in-flight requests (which pin their adapter *id*) are never
  perturbed.

* **:class:`AdapterRegistry`** — host-side bookkeeping (name → id,
  versions, free pages, per-adapter rank/scale metadata) over those pools.
  ``register(name, adapters) -> adapter_id``, ``evict(name_or_id)``,
  ``swap(name, adapters) -> new_id`` (atomic version bump: the new version
  gets fresh pages and a fresh id; the old id keeps serving in-flight rows
  until evicted).

* **:func:`attach`** — builds the adapter tree the model consumes: every
  pool leaf becomes a :class:`repro.peft.lora.PagedLoRA` carrying pools +
  indirection + the per-batch-row id table; scanned leaves get the layer
  axis broadcast onto the shared tables so ``lax.scan`` over layers
  unstacks every child cleanly.

Adapter id **0 is reserved** for "base model, no adapter": its rank entry
is pinned to 0, so every lane of its delta is masked to an exact zero in
both the XLA twin and the bgmv kernel.
"""
from __future__ import annotations

import math
from typing import Any, Callable, Dict, List, Optional, Union

import jax
import jax.numpy as jnp

from repro.peft.lora import PagedLoRA


def _is_adapter_leaf(node: Any) -> bool:
    return isinstance(node, dict) and "A" in node and "B" in node


def _map_adapter_leaves(fn: Callable, node: Any) -> Any:
    """Map ``fn`` over every ``{"A", "B", ...}`` leaf-dict of an adapter (or
    pool) tree, preserving the surrounding container structure."""
    if _is_adapter_leaf(node):
        return fn(node)
    if isinstance(node, dict):
        return {k: _map_adapter_leaves(fn, v) for k, v in node.items()}
    if isinstance(node, (tuple, list)):
        return type(node)(_map_adapter_leaves(fn, v) for v in node)
    return node


def _walk_adapter_leaves(node: Any, path=()):
    """Yield (path, leaf_dict) for every adapter leaf, in deterministic
    order: dict keys sorted (matching jax pytree key order, so trees that
    differ only in dict insertion order walk identically)."""
    if _is_adapter_leaf(node):
        yield path, node
        return
    if isinstance(node, dict):
        for k in sorted(node):
            yield from _walk_adapter_leaves(node[k], path + (k,))
    elif isinstance(node, (tuple, list)):
        for i, v in enumerate(node):
            yield from _walk_adapter_leaves(v, path + (i,))


def attach(device_state: Dict[str, Any], ids, impl: str = "xla"):
    """Build the adapter tree a decode step consumes from the registry's
    device state and the engine's per-slot ``ids: (B,)`` table.

    Every pool leaf becomes a :class:`PagedLoRA`; stacked leaves (A-pool of
    rank 4: ``(L, P, pr, din)``) get ``table`` / ``rank`` / ``ids``
    broadcast to a leading ``L`` axis so the model's ``lax.scan`` over
    layers unstacks them alongside the pools.  Pure tracing-time structure:
    the broadcasts are free under jit.
    """
    if impl not in ("xla", "kernel"):
        raise ValueError(f"unknown paged-LoRA impl {impl!r}")
    table, rank = device_state["table"], device_state["rank"]
    ids = jnp.asarray(ids, jnp.int32)

    def mk(leaf):
        a = leaf["A"]
        if a.ndim == 4:                                  # stacked (L,P,pr,din)
            L = a.shape[0]
            return PagedLoRA(
                a, leaf["B"], leaf["scale"],
                jnp.broadcast_to(table, (L,) + table.shape),
                jnp.broadcast_to(rank, (L,) + rank.shape),
                jnp.broadcast_to(ids, (L,) + ids.shape), impl=impl)
        return PagedLoRA(a, leaf["B"], leaf["scale"], table, rank, ids,
                         impl=impl)

    return _map_adapter_leaves(mk, device_state["pools"])


def is_device_state(adapters: Any) -> bool:
    """Whether ``adapters`` is a registry device-state dict (pools + tables)
    rather than a classic single-tenant adapter tree."""
    return (isinstance(adapters, dict) and "pools" in adapters
            and "table" in adapters and "rank" in adapters)


class AdapterRegistry:
    """Registry of live adapters over fixed-shape paged device pools.

    ``template`` is any adapter tree with the structure the engine will
    serve (e.g. a round's ``global_adapters``) — only its leaf *shapes*
    matter (din/dout per leaf and the layer-stack axis); its values are NOT
    registered.

    Parameters
    ----------
    page_rank:   ranks per page — an adapter of rank r spans
                 ``ceil(r / page_rank)`` pages.
    num_pages:   pool capacity in pages (shared by all adapters).
    max_adapters: id-table capacity, *including* the reserved base id 0.
    max_rank:    largest registrable rank; fixes the indirection-table width
                 ``Pmax = ceil(max_rank / page_rank)``.
    """

    def __init__(self, template: Any, *, page_rank: int = 4,
                 num_pages: int = 64, max_adapters: int = 16,
                 max_rank: int = 32):
        if page_rank < 1 or num_pages < 1 or max_adapters < 2:
            raise ValueError("page_rank/num_pages >= 1 and max_adapters >= 2"
                             " required")
        self.page_rank = page_rank
        self.num_pages = num_pages
        self.max_adapters = max_adapters
        self.max_rank = max_rank
        self.pages_max = max(1, math.ceil(max_rank / page_rank))

        def mk_pool(leaf):
            a, b = leaf["A"], leaf["B"]
            if a.ndim == 3:                              # stacked (L, r, din)
                L, _, din = a.shape
                dout = b.shape[1]
                return {
                    "A": jnp.zeros((L, num_pages, page_rank, din), a.dtype),
                    "B": jnp.zeros((L, num_pages, dout, page_rank), b.dtype),
                    "scale": jnp.zeros((L, max_adapters), jnp.float32),
                }
            din = a.shape[1]
            dout = b.shape[0]
            return {
                "A": jnp.zeros((num_pages, page_rank, din), a.dtype),
                "B": jnp.zeros((num_pages, dout, page_rank), b.dtype),
                "scale": jnp.zeros((max_adapters,), jnp.float32),
            }

        self._pools = _map_adapter_leaves(mk_pool, template)
        self._leaf_paths = [p for p, _ in _walk_adapter_leaves(template)]
        if not self._leaf_paths:
            raise ValueError("template adapter tree has no {'A','B'} leaves")
        self._table = jnp.zeros((max_adapters, self.pages_max), jnp.int32)
        self._rank = jnp.zeros((max_adapters,), jnp.int32)  # id 0 stays 0
        self._free_pages: List[int] = list(range(num_pages))
        self._free_ids: List[int] = list(range(1, max_adapters))
        # id -> {"name", "rank", "pages", "version", "retired"}
        self._meta: Dict[int, Dict[str, Any]] = {}
        self._names: Dict[str, int] = {}            # name -> current id
        self._versions: Dict[str, List[int]] = {}   # name -> id history

    # -- introspection ---------------------------------------------------------
    @property
    def device_state(self) -> Dict[str, Any]:
        """The pytree a serve step takes as its ``adapters`` argument:
        fixed structure and shapes across any register/evict/swap churn."""
        return {"pools": self._pools, "table": self._table, "rank": self._rank}

    def place(self, shardings: Optional[Dict[str, Any]]) -> None:
        """Commit the registry's device state onto a mesh: one-time
        ``device_put`` of pools / indirection table / rank table with
        ``shardings`` (a tree matching :attr:`device_state`, normally from
        ``topology.serve_adapter_pspecs``).  Every later ``register`` /
        ``swap`` / ``evict`` goes through ``.at[].set`` on the committed
        arrays, which preserves their sharding — so a placed registry keeps
        matching the engine executables' ``in_shardings`` across churn."""
        if shardings is None:
            return
        st = jax.device_put(self.device_state, shardings)
        self._pools, self._table, self._rank = (
            st["pools"], st["table"], st["rank"])

    @property
    def num_free_pages(self) -> int:
        return len(self._free_pages)

    @property
    def live_ids(self) -> List[int]:
        return sorted(self._meta)

    def resolve(self, name: str) -> int:
        """Current adapter id serving ``name`` (post-swap: the new version)."""
        return self._names[name]

    def is_live(self, adapter_id: int) -> bool:
        """Whether ``adapter_id`` is servable: the reserved base id 0, or a
        registered (possibly swap-retired, not yet evicted) adapter."""
        return adapter_id == 0 or adapter_id in self._meta

    def metadata(self, adapter_id: int) -> Dict[str, Any]:
        return dict(self._meta[adapter_id])

    # -- lifecycle -------------------------------------------------------------
    def register(self, name: str, adapters: Any) -> int:
        """Copy ``adapters`` into free pages and return its adapter id.

        Zero-retrace contract: only ``.at[].set`` updates of fixed-shape
        arrays — no engine executable ever re-specializes on registry churn.
        """
        if name in self._names:
            raise ValueError(f"adapter name {name!r} is already registered; "
                             "use swap() to publish a new version")
        return self._install(name, adapters)

    def swap(self, name: str, adapters: Any) -> int:
        """Atomic version bump for a live name: the new version lands in
        fresh pages under a NEW id, then the name is repointed.  The old id
        (and its pages) stays fully servable for rows already in flight —
        evict it once they drain."""
        if name not in self._names:
            raise KeyError(f"cannot swap unknown adapter name {name!r}")
        old = self._names[name]
        new = self._install(name, adapters)
        self._meta[old]["retired"] = True
        return new

    def evict(self, ref: Union[str, int]) -> None:
        """Free an adapter's pages and id.  ``ref`` is an adapter id, or a
        name (evicts EVERY live version of the name, retired ones included).
        The freed rank entry is zeroed on device, so a stale id in a slot
        degrades to the base model deterministically — but evicting an
        adapter that still has rows in flight is a caller error; the engine
        refuses NEW submissions against an evicted id."""
        if isinstance(ref, str):
            if ref not in self._versions:
                raise KeyError(f"unknown adapter name {ref!r}")
            for aid in [i for i in self._versions[ref] if i in self._meta]:
                self._evict_id(aid)
            return
        self._evict_id(ref)

    # -- internals -------------------------------------------------------------
    def _evict_id(self, aid: int) -> None:
        if aid not in self._meta:
            raise KeyError(f"unknown or already-evicted adapter id {aid}")
        meta = self._meta.pop(aid)
        self._free_pages.extend(meta["pages"])
        self._free_pages.sort()
        self._free_ids.append(aid)
        self._free_ids.sort()
        self._rank = self._rank.at[aid].set(0)
        name = meta["name"]
        if self._names.get(name) == aid:
            del self._names[name]
        vs = self._versions.get(name)
        if vs is not None:
            vs[:] = [i for i in vs if i != aid]
            if not vs:
                del self._versions[name]

    def _adapter_rank(self, adapters: Any) -> int:
        paths, ranks = [], []
        for path, leaf in _walk_adapter_leaves(adapters):
            paths.append(path)
            ranks.append(int(leaf["A"].shape[-2]))
        if paths != self._leaf_paths:
            raise ValueError("adapter tree structure does not match the "
                             f"registry template: got leaves {paths}, "
                             f"expected {self._leaf_paths}")
        return max(ranks)

    def _install(self, name: str, adapters: Any) -> int:
        r = self._adapter_rank(adapters)
        if r < 1:
            raise ValueError("cannot register a rank-0 adapter")
        if r > self.max_rank:
            raise ValueError(f"adapter rank {r} exceeds the registry "
                             f"max_rank {self.max_rank}")
        n_pg = math.ceil(r / self.page_rank)
        if len(self._free_pages) < n_pg:
            raise RuntimeError(f"out of adapter pages: need {n_pg}, "
                               f"{len(self._free_pages)} free "
                               f"(evict something or grow num_pages)")
        if not self._free_ids:
            raise RuntimeError("out of adapter ids (grow max_adapters)")
        pages = self._free_pages[:n_pg]          # smallest-first: determinism
        del self._free_pages[:n_pg]
        aid = self._free_ids.pop(0)

        rp = n_pg * self.page_rank               # padded rank (whole pages)
        pg = jnp.asarray(pages, jnp.int32)

        def write(pool, leaf):
            a = jnp.asarray(leaf["A"])
            b = jnp.asarray(leaf["B"])
            scale = jnp.asarray(leaf["scale"], jnp.float32)
            if a.ndim == 3:                      # stacked (L, r_leaf, din)
                L, rl, din = a.shape
                dout = b.shape[1]
                ap = jnp.zeros((L, rp, din), pool["A"].dtype).at[:, :rl].set(
                    a.astype(pool["A"].dtype))
                bp = jnp.zeros((L, dout, rp), pool["B"].dtype).at[..., :rl].set(
                    b.astype(pool["B"].dtype))
                return {
                    "A": pool["A"].at[:, pg].set(
                        ap.reshape(L, n_pg, self.page_rank, din)),
                    "B": pool["B"].at[:, pg].set(jnp.moveaxis(
                        bp.reshape(L, dout, n_pg, self.page_rank), 2, 1)),
                    "scale": pool["scale"].at[:, aid].set(
                        jnp.broadcast_to(scale, (L,))),
                }
            rl, din = a.shape
            dout = b.shape[0]
            ap = jnp.zeros((rp, din), pool["A"].dtype).at[:rl].set(
                a.astype(pool["A"].dtype))
            bp = jnp.zeros((dout, rp), pool["B"].dtype).at[:, :rl].set(
                b.astype(pool["B"].dtype))
            return {
                "A": pool["A"].at[pg].set(
                    ap.reshape(n_pg, self.page_rank, din)),
                "B": pool["B"].at[pg].set(jnp.moveaxis(
                    bp.reshape(dout, n_pg, self.page_rank), 1, 0)),
                "scale": pool["scale"].at[aid].set(
                    jnp.reshape(scale, ())),
            }

        # zip the pool tree against the incoming adapter tree leaf-by-leaf
        leaves = dict(_walk_adapter_leaves(adapters))

        def write_at(path):
            def go(pool_node, p=()):
                if _is_adapter_leaf(pool_node):
                    return write(pool_node, leaves[p])
                if isinstance(pool_node, dict):
                    return {k: go(v, p + (k,)) for k, v in pool_node.items()}
                if isinstance(pool_node, (tuple, list)):
                    return type(pool_node)(go(v, p + (i,))
                                           for i, v in enumerate(pool_node))
                return pool_node
            return go

        self._pools = write_at(None)(self._pools)
        row = jnp.zeros((self.pages_max,), jnp.int32).at[:n_pg].set(pg)
        self._table = self._table.at[aid].set(row)
        self._rank = self._rank.at[aid].set(r)

        version = len(self._versions.get(name, [])) + 1
        self._meta[aid] = {"name": name, "rank": r, "pages": pages,
                           "version": version, "retired": False}
        self._names[name] = aid
        self._versions.setdefault(name, []).append(aid)
        return aid
