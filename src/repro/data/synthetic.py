"""Synthetic instruction-tuning corpus + federated non-IID partitioning.

Offline stand-in for Dolly/Alpaca/Wizard: a *learnable* instruction-following
task so federated fine-tuning runs show real convergence differences between
aggregation methods.

Task family: sequence = [BOS, instr_1..instr_m, SEP, resp_1..resp_m, pad...]
where ``resp_i = (instr_i * mult_t + off_t) mod (vocab - 4) + 4`` for a
*task id* ``t``.  Clients draw tasks from Dirichlet(α) proportions over the
task pool (paper §4.1: α = 0.5), so clients are non-IID in task mixture —
the direct analogue of the paper's Dirichlet label-skew splits.

Loss is masked to response positions only (instruction tuning).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List

import numpy as np

BOS, SEP, EOS, PAD = 1, 2, 3, 0
SPECIAL = 4


@dataclass
class ClientDataset:
    tokens: np.ndarray       # (n, S) int32
    loss_mask: np.ndarray    # (n, S) float32 — 1 on response positions
    num_samples: int

    def batches(self, batch_size: int, rng: np.random.Generator):
        idx = rng.permutation(self.num_samples)
        for i in range(0, self.num_samples - batch_size + 1, batch_size):
            sel = idx[i: i + batch_size]
            yield {"tokens": self.tokens[sel], "loss_mask": self.loss_mask[sel]}


def _make_example(rng, task: int, seq_len: int, vocab: int, num_tasks: int):
    m = (seq_len - 3) // 2
    mult = 1 + 2 * (task % 7)
    off = 3 + 11 * task
    instr = rng.integers(SPECIAL, vocab, size=m)
    resp = (instr * mult + off) % (vocab - SPECIAL) + SPECIAL
    toks = np.full(seq_len, PAD, np.int32)
    toks[0] = BOS
    toks[1: 1 + m] = instr
    toks[1 + m] = SEP
    toks[2 + m: 2 + 2 * m] = resp
    toks[2 + 2 * m] = EOS
    mask = np.zeros(seq_len, np.float32)
    # next-token loss: predicting resp tokens (targets at positions 2+m..)
    mask[2 + m: 3 + 2 * m] = 1.0
    return toks, mask


def dirichlet_partition(num_clients: int, num_tasks: int, alpha: float,
                        rng: np.random.Generator) -> np.ndarray:
    """Per-client task mixture, Dirichlet(alpha) (paper: alpha=0.5)."""
    return rng.dirichlet([alpha] * num_tasks, size=num_clients)


def make_federated_data(num_clients: int = 100, mean_samples: int = 32,
                        seq_len: int = 64, vocab: int = 256,
                        num_tasks: int = 8, alpha: float = 0.5,
                        seed: int = 0) -> List[ClientDataset]:
    rng = np.random.default_rng(seed)
    mix = dirichlet_partition(num_clients, num_tasks, alpha, rng)
    out = []
    for c in range(num_clients):
        n = max(4, int(rng.lognormal(np.log(mean_samples), 0.4)))
        tasks = rng.choice(num_tasks, size=n, p=mix[c])
        toks = np.zeros((n, seq_len), np.int32)
        mask = np.zeros((n, seq_len), np.float32)
        for i, t in enumerate(tasks):
            toks[i], mask[i] = _make_example(rng, int(t), seq_len, vocab, num_tasks)
        out.append(ClientDataset(toks, mask, n))
    return out


def make_eval_data(num_samples: int = 128, seq_len: int = 64, vocab: int = 256,
                   num_tasks: int = 8, seed: int = 1234) -> Dict:
    """Held-out uniform-task eval set (the 'MMLU subset' analogue)."""
    rng = np.random.default_rng(seed)
    toks = np.zeros((num_samples, seq_len), np.int32)
    mask = np.zeros((num_samples, seq_len), np.float32)
    for i in range(num_samples):
        toks[i], mask[i] = _make_example(rng, int(rng.integers(num_tasks)),
                                         seq_len, vocab, num_tasks)
    return {"tokens": toks, "loss_mask": mask}
