"""Model assembly: segment plan + scan-over-layers for all assigned families.

A model is a sequence of *segments*; each segment is ``(kind, count)`` where
``count`` homogeneous layers are stacked and executed with ``lax.scan`` (so
HLO size / compile time is O(#segments), not O(depth)).  Zamba2's shared
attention block is stored once (``params["shared_blk"]``) and applied at each
``("shared", 1)`` plan entry; DeepSeek-V3's first dense layers form their own
segment.

Public API:
    layer_plan(cfg)                       -> [(kind, count), ...]
    init(cfg, key)                        -> params
    forward(cfg, params, batch, ...)      -> (hidden, aux)   [train / prefill]
    logits(cfg, params, hidden)           -> (B, S, V)
    init_cache(cfg, batch, capacity, ...) -> cache pytree (per-slot pos)
    decode(cfg, params, cache, batch, ..) -> (logits, cache) [token chunk]
    reset_cache_slots(cache, mask)        -> cache with masked slots wiped
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.common.config import ModelConfig
from repro.models import layers as Lyr
from repro.models import moe as Moe
from repro.models import rwkv as Rwkv
from repro.models import ssm as Ssm
from repro.serve import kvcache as Kv

Params = Dict[str, Any]

ATTN_KINDS = ("dense", "moe", "mla_dense", "mla_moe", "shared")


# ---------------------------------------------------------------------------
# plan
# ---------------------------------------------------------------------------

def layer_plan(cfg: ModelConfig) -> List[Tuple[str, int]]:
    L = cfg.num_layers
    if cfg.family == "ssm":
        return [("rwkv", L)]
    if cfg.family == "hybrid":
        plan, remaining = [], L
        while remaining > 0:
            n = min(cfg.attn_every, remaining)
            plan.append(("mamba", n))
            remaining -= n
            if n == cfg.attn_every:
                plan.append(("shared", 1))
        return plan
    if cfg.num_experts:
        kind = "mla_moe" if cfg.use_mla else "moe"
        dense_kind = "mla_dense" if cfg.use_mla else "dense"
        if cfg.first_dense_layers:
            return [(dense_kind, cfg.first_dense_layers),
                    (kind, L - cfg.first_dense_layers)]
        return [(kind, L)]
    return [("dense", L)]


def num_shared_applications(cfg: ModelConfig) -> int:
    return sum(1 for k, _ in layer_plan(cfg) if k == "shared")


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_block(cfg: ModelConfig, kind: str, key, dtype) -> Params:
    d = cfg.d_model
    k1, k2 = jax.random.split(key)
    if kind == "mamba":
        return {"ln": jnp.ones((d,), dtype), "mixer": Ssm.init_mamba2(cfg, k1, dtype)}
    if kind == "rwkv":
        return {"ln1": jnp.ones((d,), dtype), "ln2": jnp.ones((d,), dtype),
                "mix": Rwkv.init_rwkv6(cfg, k1, dtype)}
    attn_init = Lyr.init_mla if kind.startswith("mla") else Lyr.init_attention
    blk = {"ln1": jnp.ones((d,), dtype), "attn": attn_init(cfg, k1, dtype),
           "ln2": jnp.ones((d,), dtype)}
    if kind in ("moe", "mla_moe"):
        blk["moe"] = Moe.init_moe(cfg, k2, dtype)
    else:
        blk["mlp"] = Lyr.init_mlp(cfg, k2, dtype)
    return blk


def init(cfg: ModelConfig, key: jax.Array) -> Params:
    dtype = jnp.dtype(cfg.dtype)
    keys = jax.random.split(key, 8)
    d, V = cfg.d_model, cfg.vocab_size
    params: Params = {
        "embed": (jax.random.normal(keys[0], (V, d)) * 0.02).astype(dtype),
        "final_norm": jnp.ones((d,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = Lyr.dense_init(keys[1], (d, V), d, dtype)
    if cfg.frontend:
        fd = cfg.frontend_dim or d
        params["frontend_proj"] = Lyr.dense_init(keys[2], (fd, d), fd, dtype)
    blocks = []
    kb = keys[3]
    for i, (kind, count) in enumerate(layer_plan(cfg)):
        if kind == "shared":
            continue
        kb, ks = jax.random.split(kb)
        layer_keys = jax.random.split(ks, count)
        blocks.append(jax.vmap(lambda k: _init_block(cfg, kind, k, dtype))(layer_keys))
    params["blocks"] = tuple(blocks)
    if cfg.family == "hybrid":
        params["shared_blk"] = _init_block(cfg, "shared", keys[4], dtype)
    return params


# ---------------------------------------------------------------------------
# block application (full sequence)
# ---------------------------------------------------------------------------

def _block_fwd(cfg: ModelConfig, kind: str, p: Params, x, a: Dict,
               use_kernels: bool):
    """One layer, full sequence. Returns (x, aux)."""
    a = a or {}
    aux = jnp.zeros((), jnp.float32)
    if kind == "mamba":
        h = Ssm.mamba2_fwd(cfg, p["mixer"], Lyr.rmsnorm(x, p["ln"], cfg.norm_eps),
                           a.get("mixer"))
        return x + h, aux
    if kind == "rwkv":
        h, _ = Rwkv.time_mix(cfg, p["mix"], Lyr.rmsnorm(x, p["ln1"], cfg.norm_eps),
                             a.get("mix"), use_kernel=use_kernels)
        x = x + h
        h, _ = Rwkv.channel_mix(cfg, p["mix"], Lyr.rmsnorm(x, p["ln2"], cfg.norm_eps),
                                a.get("mix"))
        return x + h, aux
    attn_fn = Lyr.mla_fwd if kind.startswith("mla") else partial(
        Lyr.attention_fwd, use_kernel=use_kernels)
    h = attn_fn(cfg, p["attn"], Lyr.rmsnorm(x, p["ln1"], cfg.norm_eps), a.get("attn"))
    x = x + h
    xn = Lyr.rmsnorm(x, p["ln2"], cfg.norm_eps)
    if kind in ("moe", "mla_moe"):
        h, aux = Moe.moe_fwd(cfg, p["moe"], xn, a.get("moe"))
    else:
        h = Lyr.mlp_fwd(p["mlp"], xn, a.get("mlp"))
    return x + h, aux


def _seg_scan(cfg, kind, seg_p, seg_a, x, use_kernels, remat):
    """Scan `count` stacked layers of one kind."""
    body_fn = partial(_block_fwd, cfg, kind, use_kernels=use_kernels)
    if remat:
        body_fn = jax.checkpoint(body_fn, policy=jax.checkpoint_policies.nothing_saveable)

    def body(carry, xs):
        x, aux = carry
        p_l, a_l = xs
        x, aux_l = body_fn(p_l, x, a_l)
        return (x, aux + aux_l), None

    from repro.common import flags
    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                               (seg_p, seg_a), unroll=flags.scan_unroll())
    return x, aux


# ---------------------------------------------------------------------------
# embedding / head
# ---------------------------------------------------------------------------

def embed_inputs(cfg: ModelConfig, params: Params, batch: Dict) -> jnp.ndarray:
    dtype = jnp.dtype(cfg.dtype)
    parts = []
    if cfg.frontend == "vision" and "patch_embeds" in batch:
        parts.append((batch["patch_embeds"] @ params["frontend_proj"]).astype(dtype))
    if cfg.frontend == "audio" and "frame_embeds" in batch:
        parts.append((batch["frame_embeds"] @ params["frontend_proj"]).astype(dtype))
    if "tokens" in batch:
        parts.append(params["embed"][batch["tokens"]])
    x = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=1)
    return x


def logits(cfg: ModelConfig, params: Params, hidden: jnp.ndarray) -> jnp.ndarray:
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return hidden @ head


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------

def forward(cfg: ModelConfig, params: Params, batch: Dict,
            adapters: Optional[Dict] = None, remat: bool = False,
            use_kernels: bool = False) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full-sequence forward. Returns (final hidden (B,S,d), moe aux loss)."""
    x = embed_inputs(cfg, params, batch)
    a_blocks = (adapters or {}).get("blocks", ())
    aux = jnp.zeros((), jnp.float32)
    seg_i = 0
    for kind, count in layer_plan(cfg):
        if kind == "shared":
            sa = (adapters or {}).get("shared_blk", {})
            x, aux_l = _block_fwd(cfg, "shared", params["shared_blk"], x, sa, use_kernels)
            aux += aux_l
            continue
        seg_a = a_blocks[seg_i] if seg_i < len(a_blocks) and a_blocks[seg_i] else {}
        x, aux_l = _seg_scan(cfg, kind, params["blocks"][seg_i], seg_a, x,
                             use_kernels, remat)
        aux += aux_l
        seg_i += 1
    x = Lyr.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return x, aux


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def _stack_zeros(tree, n: int):
    return jax.tree.map(lambda t: jnp.zeros((n,) + t.shape, t.dtype), tree)


def init_cache(cfg: ModelConfig, batch: int, capacity: int,
               kv_dtype=jnp.bfloat16, prefill_chunk: int = 1) -> Tuple:
    """Cache pytree mirroring the segment plan.

    capacity: context length (or window size when cfg.sliding_window > 0).
    prefill_chunk: widest token chunk a single decode() call will write —
    sliding-window rings keep ``chunk - 1`` extra slots so a chunk's own
    writes never evict tokens its earliest in-chunk query still attends
    (the window mask in :func:`ring_attend_mask` trims the surplus).
    """
    if cfg.sliding_window:
        capacity = min(capacity,
                       cfg.sliding_window + max(prefill_chunk, 1) - 1)
    caches = []
    for kind, count in layer_plan(cfg):
        if kind == "shared":
            caches.append(Kv.attn_cache(cfg, batch, capacity, kv_dtype))
        elif kind == "mamba":
            caches.append(_stack_zeros(Ssm.mamba2_init_state(cfg, batch), count))
        elif kind == "rwkv":
            caches.append(_stack_zeros(Rwkv.rwkv6_init_state(cfg, batch), count))
        elif kind.startswith("mla"):
            caches.append(_stack_zeros(Kv.mla_cache(cfg, batch, capacity, kv_dtype), count))
        else:
            caches.append(_stack_zeros(Kv.attn_cache(cfg, batch, capacity, kv_dtype), count))
    return tuple(caches)


def _mask_state_rows(new_cache, old_cache, n_tokens):
    """Keep the old recurrent state for rows with n_tokens == 0 (the
    documented n_tokens contract: masked rows leave their cache untouched)."""
    if n_tokens is None:
        return new_cache
    keep = n_tokens > 0
    return jax.tree.map(
        lambda nw, old: jnp.where(keep.reshape((-1,) + (1,) * (nw.ndim - 1)),
                                  nw, old), new_cache, old_cache)


def _block_decode(cfg: ModelConfig, kind: str, p: Params, x, cache, a: Dict,
                  n_tokens=None, decode_impl: str = "dense"):
    """One layer, one token chunk. Returns (x, new_cache)."""
    a = a or {}
    if kind == "mamba":
        assert x.shape[1] == 1, "SSM decode is a single-token recurrence"
        h, new = Ssm.mamba2_decode(cfg, p["mixer"],
                                   Lyr.rmsnorm(x, p["ln"], cfg.norm_eps),
                                   cache, a.get("mixer"))
        return x + h, _mask_state_rows(new, cache, n_tokens)
    if kind == "rwkv":
        assert x.shape[1] == 1, "RWKV decode is a single-token recurrence"
        old = cache
        h, st = Rwkv.time_mix(cfg, p["mix"], Lyr.rmsnorm(x, p["ln1"], cfg.norm_eps),
                              a.get("mix"), state=cache)
        x = x + h
        cache = {**cache, **st}
        h, st = Rwkv.channel_mix(cfg, p["mix"], Lyr.rmsnorm(x, p["ln2"], cfg.norm_eps),
                                 a.get("mix"), state=cache)
        cache = {**cache, **st}
        return x + h, _mask_state_rows(cache, old, n_tokens)
    dec_fn = Lyr.mla_decode if kind.startswith("mla") else Lyr.attention_decode
    h, cache = dec_fn(cfg, p["attn"], Lyr.rmsnorm(x, p["ln1"], cfg.norm_eps),
                      cache, a.get("attn"), n_tokens=n_tokens,
                      decode_impl=decode_impl)
    x = x + h
    xn = Lyr.rmsnorm(x, p["ln2"], cfg.norm_eps)
    if kind in ("moe", "mla_moe"):
        h, _ = Moe.moe_fwd(cfg, p["moe"], xn, a.get("moe"))
    else:
        h = Lyr.mlp_fwd(p["mlp"], xn, a.get("mlp"))
    return x + h, cache


def decode(cfg: ModelConfig, params: Params, cache: Tuple, batch: Dict,
           adapters: Optional[Dict] = None,
           n_tokens: Optional[jnp.ndarray] = None,
           decode_impl: str = "dense") -> Tuple[jnp.ndarray, Tuple]:
    """One decode step over a token chunk. batch: {"tokens": (B,C)} (or
    frame/patch embeds); C=1 is classic single-token decode, C>1 feeds a
    whole prefill chunk through the cached path in one call.  Caches carry
    per-slot ``pos``/``length`` so every batch row rides its own ring
    offset.  ``n_tokens: (B,)`` optionally gives the real token count per
    row (None = all C; rows with 0 leave their cache untouched — inactive
    continuous-batching slots).  ``decode_impl`` picks the attention
    interior of every attention/MLA layer: ``"dense"`` oracle, ``"streamed"``
    XLA flash-decoding, or ``"kernel"`` Pallas ring-flash-decode (SSM/RWKV
    recurrences are unaffected).  Returns (logits (B,C,V), new_cache)."""
    x = embed_inputs(cfg, params, batch)
    a_blocks = (adapters or {}).get("blocks", ())
    new_caches = []
    seg_i = 0
    plan = layer_plan(cfg)
    for ci, (kind, count) in enumerate(plan):
        if kind == "shared":
            sa = (adapters or {}).get("shared_blk", {})
            x, c = _block_decode(cfg, "shared", params["shared_blk"], x, cache[ci],
                                 sa, n_tokens, decode_impl)
            new_caches.append(c)
            continue
        seg_a = a_blocks[seg_i] if seg_i < len(a_blocks) and a_blocks[seg_i] else {}

        def body(carry, xs, kind=kind):
            xc = carry
            p_l, a_l, c_l = xs
            xc, c_l = _block_decode(cfg, kind, p_l, xc, c_l, a_l, n_tokens,
                                    decode_impl)
            return xc, c_l

        from repro.common import flags
        x, c = jax.lax.scan(body, x, (params["blocks"][seg_i], seg_a, cache[ci]),
                            unroll=flags.scan_unroll())
        new_caches.append(c)
        seg_i += 1
    x = Lyr.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return logits(cfg, params, x), tuple(new_caches)


def reset_cache_slots(cache: Tuple, mask) -> Tuple:
    """Zero the per-slot state of every cache row where ``mask: (B,)`` is
    True — ring positions, KV rows, and SSM/RWKV recurrent states alike —
    so a freed continuous-batching slot hands its successor a fresh cache."""
    return Kv.reset_slots(cache, mask)
