"""Mamba2 (SSD) layer in pure JAX — chunked, MXU-friendly formulation.

Used standalone and as the backbone of the Zamba2 hybrid.  The training /
prefill path uses the chunkwise-parallel SSD algorithm (intra-chunk matmuls +
inter-chunk state scan); decode is the O(1) single-token recurrence.
n_groups = 1 (B/C shared across heads), as in Zamba2-1.2B.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.common.config import ModelConfig
from repro.models.layers import dense_init, rmsnorm
from repro.peft.lora import lora_proj

Params = Dict[str, Any]

CHUNK = 256


def init_mamba2(cfg: ModelConfig, key, dtype) -> Params:
    d, din, st, H = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.num_ssm_heads
    ks = jax.random.split(key, 4)
    proj_out = 2 * din + 2 * st + H
    return {
        "in_proj": dense_init(ks[0], (d, proj_out), d, dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv, din + 2 * st)) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((din + 2 * st,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm": jnp.ones((din,), dtype),
        "out_proj": dense_init(ks[2], (din, d), din, dtype),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv. x: (B,S,C), w: (K,C)."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(xp[:, i: i + x.shape[1], :] * w[i] for i in range(K))
    return jax.nn.silu((out + b).astype(jnp.float32)).astype(x.dtype)


def _split_proj(cfg: ModelConfig, zxbcdt):
    din, st, H = cfg.d_inner, cfg.ssm_state, cfg.num_ssm_heads
    z = zxbcdt[..., :din]
    xBC = zxbcdt[..., din: 2 * din + 2 * st]
    dt = zxbcdt[..., 2 * din + 2 * st:]
    return z, xBC, dt


def mamba2_fwd(cfg: ModelConfig, p: Params, x, adapters=None):
    """x: (B,S,d) -> (B,S,d)."""
    Bsz, S, d = x.shape
    din, st, H, hd = cfg.d_inner, cfg.ssm_state, cfg.num_ssm_heads, cfg.ssm_head_dim
    a = adapters or {}
    zxbcdt = lora_proj(x, p["in_proj"], a.get("in_proj"))
    z, xBC, dt = _split_proj(cfg, zxbcdt)
    xBC = _causal_conv(xBC, p["conv_w"], p["conv_b"])
    xs = xBC[..., :din].reshape(Bsz, S, H, hd)
    Bm = xBC[..., din: din + st]                      # (B,S,st)
    Cm = xBC[..., din + st:]                           # (B,S,st)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (B,S,H)
    A = -jnp.exp(p["A_log"])                                       # (H,)
    dA = dt * A                                                    # (B,S,H)

    y = _ssd_chunked(xs, dt, dA, Bm, Cm)
    y = y + p["D"][None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(Bsz, S, din).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype),
                p["norm"], cfg.norm_eps)
    return lora_proj(y, p["out_proj"], a.get("out_proj"))


def _ssd_chunked(xs, dt, dA, Bm, Cm, chunk: int = CHUNK):
    """Chunkwise-parallel SSD.

    xs: (B,S,H,hd), dt/dA: (B,S,H), Bm/Cm: (B,S,st). Returns fp32 (B,S,H,hd).
    """
    Bsz, S, H, hd = xs.shape
    st = Bm.shape[-1]
    c = min(chunk, S)
    assert S % c == 0, (S, c)
    nc = S // c

    def r(t, *shape):
        return t.reshape(Bsz, nc, c, *shape)
    xs_, dt_, dA_ = r(xs, H, hd), r(dt, H), r(dA, H)
    B_, C_ = r(Bm, st), r(Cm, st)

    cum = jnp.cumsum(dA_, axis=2)                       # (B,nc,c,H)
    # intra-chunk: y[t] = sum_{s<=t} exp(cum_t - cum_s) * (C_t . B_s) dt_s x_s
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,nc,c(t),c(s),H)
    tri = jnp.tril(jnp.ones((c, c), bool))[None, None, :, :, None]
    # mask BEFORE exp: masked (s>t) entries have seg>0 and can overflow, and
    # a where() after exp turns 0·inf into NaN in the backward pass
    seg = jnp.where(tri, seg, 0.0)
    decay = jnp.where(tri, jnp.exp(seg), 0.0)
    scores = jnp.einsum("bntk,bnsk->bnts",
                        C_.astype(jnp.float32), B_.astype(jnp.float32))
    M = scores[..., None] * decay                        # (B,nc,t,s,H)
    y_intra = jnp.einsum("bntsh,bnsh,bnshd->bnthd", M, dt_, xs_.astype(jnp.float32))

    # chunk-final states and inter-chunk scan
    dec_end = jnp.exp(cum[:, :, -1:, :] - cum)           # (B,nc,c,H)
    states = jnp.einsum("bnsh,bnsh,bnsk,bnshd->bnhkd",
                        dec_end, dt_, B_.astype(jnp.float32), xs_.astype(jnp.float32))
    total = jnp.exp(cum[:, :, -1, :])                    # (B,nc,H)

    def step(h, inp):
        stt, tot = inp                                   # (B,H,st,hd), (B,H)
        h_new = h * tot[..., None, None] + stt
        return h_new, h                                  # emit previous state

    from repro.common import flags
    h0 = jnp.zeros((Bsz, H, st, hd), jnp.float32)
    _, h_prev = jax.lax.scan(step,
                             h0,
                             (states.swapaxes(0, 1), total.swapaxes(0, 1)),
                             unroll=flags.scan_unroll())
    h_prev = h_prev.swapaxes(0, 1)                       # (B,nc,H,st,hd)
    y_inter = jnp.einsum("bntk,bnth,bnhkd->bnthd",
                         C_.astype(jnp.float32), jnp.exp(cum), h_prev)
    return (y_intra + y_inter).reshape(Bsz, S, H, hd)


# ---------------------------------------------------------------------------
# decode (single token, O(1) state)
# ---------------------------------------------------------------------------

def mamba2_init_state(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> Dict:
    din, st = cfg.d_inner, cfg.ssm_state
    H, hd = cfg.num_ssm_heads, cfg.ssm_head_dim
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, din + 2 * st), dtype),
        "ssm": jnp.zeros((batch, H, st, hd), jnp.float32),
    }


def mamba2_decode(cfg: ModelConfig, p: Params, x, state: Dict, adapters=None):
    """x: (B,1,d) -> (y, new_state)."""
    Bsz, S, d = x.shape
    din, st, H, hd = cfg.d_inner, cfg.ssm_state, cfg.num_ssm_heads, cfg.ssm_head_dim
    a = adapters or {}
    zxbcdt = lora_proj(x, p["in_proj"], a.get("in_proj"))
    z, xBC, dt = _split_proj(cfg, zxbcdt)
    hist = jnp.concatenate([state["conv"], xBC], axis=1)   # (B,K,cdim)
    new_conv = hist[:, 1:]
    K = p["conv_w"].shape[0]
    conv_out = jnp.einsum("bkc,kc->bc", hist, p["conv_w"]) + p["conv_b"]
    xBC = jax.nn.silu(conv_out.astype(jnp.float32)).astype(x.dtype)[:, None]
    xs = xBC[..., :din].reshape(Bsz, H, hd)
    Bm = xBC[:, 0, din: din + st]
    Cm = xBC[:, 0, din + st:]
    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,H)
    A = -jnp.exp(p["A_log"])
    dec = jnp.exp(dt * A)                                   # (B,H)
    h = state["ssm"] * dec[..., None, None] + jnp.einsum(
        "bh,bk,bhd->bhkd", dt, Bm.astype(jnp.float32), xs.astype(jnp.float32))
    y = jnp.einsum("bk,bhkd->bhd", Cm.astype(jnp.float32), h)
    y = y + p["D"][None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(Bsz, 1, din).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype),
                p["norm"], cfg.norm_eps)
    out = lora_proj(y, p["out_proj"], a.get("out_proj"))
    return out, {"conv": new_conv, "ssm": h}
