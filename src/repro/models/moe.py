"""Mixture-of-Experts layer (token-choice top-k, capacity-bounded, sort-based
dispatch) — covers granite-moe (32e top-8) and DeepSeek-V3 (1 shared + 256
routed top-8, sigmoid scoring).

Dispatch is the TPU-idiomatic sort/scatter formulation: tokens are sorted by
assigned expert, scattered into a dense ``(E, C, d)`` buffer (capacity-drop
beyond C), processed with a single grouped einsum (MXU-friendly, shardable
over the expert axis = expert parallelism on the ``model`` mesh axis), and
gathered back.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.common.pjit_utils import shard_map as _pjit_shard_map

from repro.common.config import ModelConfig
from repro.models.layers import dense_init, init_mlp, mlp_fwd

Params = Dict[str, Any]


def init_moe(cfg: ModelConfig, key, dtype) -> Params:
    d = cfg.d_model
    e_ff = cfg.moe_d_ff or cfg.d_ff
    E = cfg.num_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (d, E), d, jnp.float32),
        "w_gate": dense_init(ks[1], (E, d, e_ff), d, dtype),
        "w_up": dense_init(ks[2], (E, d, e_ff), d, dtype),
        "w_down": dense_init(ks[3], (E, e_ff, d), e_ff, dtype),
    }
    if cfg.num_shared_experts:
        shared_ff = e_ff * cfg.num_shared_experts
        p["shared"] = init_mlp(cfg, ks[4], dtype, d_ff=shared_ff)
    return p


def router_scores(cfg: ModelConfig, router_w, x) -> jnp.ndarray:
    """(tokens, E) routing probabilities."""
    logits = x.astype(jnp.float32) @ router_w
    if cfg.router_sigmoid:          # DeepSeek-V3 style
        return jax.nn.sigmoid(logits)
    return jax.nn.softmax(logits, axis=-1)


def _capacity(cfg: ModelConfig, num_tokens: int) -> int:
    k, E = cfg.experts_per_token, cfg.num_experts
    c = int(cfg.moe_capacity_factor * num_tokens * k / E)
    return max(8, (c + 7) // 8 * 8)   # 8-aligned, floor of 8


def _route_and_dispatch(cfg: ModelConfig, router_w, xt: jnp.ndarray, C: int):
    """Token-choice top-k + sort-based capacity dispatch for a local token
    slab xt (T, d).  Returns (buf (E, C, d), combine metadata, aux)."""
    T, d = xt.shape
    k, E = cfg.experts_per_token, cfg.num_experts
    probs = router_scores(cfg, router_w, xt)                      # (T, E)
    top_w, top_e = jax.lax.top_k(probs, k)                        # (T, k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # load-balance auxiliary loss (Switch-style)
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(top_e, E, dtype=jnp.float32).sum(1), axis=0)
    aux = E * jnp.sum(me * ce)

    flat_e = top_e.reshape(-1)                                    # (T*k,)
    flat_w = top_w.reshape(-1)
    tok_id = jnp.repeat(jnp.arange(T), k)
    order = jnp.argsort(flat_e, stable=True)
    se, st, sw = flat_e[order], tok_id[order], flat_w[order]
    seg_start = jnp.searchsorted(se, jnp.arange(E), side="left")
    pos = jnp.arange(T * k) - seg_start[se]
    keep = pos < C
    dst_e = jnp.where(keep, se, E)
    dst_c = jnp.where(keep, pos, 0)
    buf = jnp.zeros((E + 1, C, d), xt.dtype)
    buf = buf.at[dst_e, dst_c].set(xt[st], mode="drop")
    meta = (st, dst_e, dst_c, sw, keep)
    return buf[:E], meta, aux


def _combine(T: int, eo: jnp.ndarray, meta, dtype):
    st, dst_e, dst_c, sw, keep = meta
    E = eo.shape[0]
    gathered = eo[dst_e % E, dst_c]
    gathered = gathered * (sw * keep)[:, None].astype(dtype)
    return jnp.zeros((T, eo.shape[-1]), dtype).at[st].add(gathered)


def _experts(p: Params, ebuf: jnp.ndarray, dtype):
    g = jnp.einsum("ecd,edf->ecf", ebuf, p["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", ebuf, p["w_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(dtype) * u
    return jnp.einsum("ecf,efd->ecd", h, p["w_down"])


def moe_fwd(cfg: ModelConfig, p: Params, x: jnp.ndarray,
            adapters=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, d) -> (out, aux_loss).

    Mesh-aware: with an active mesh and divisible expert count, runs the
    shard_map expert-parallel path (local dispatch → all-to-all → local
    expert einsum → all-to-all back); otherwise the single-device path.
    The GSPMD global-sort formulation is NOT used on a mesh: data-dependent
    gather/scatter indices force it to replicate the (T·k, d) token gathers
    on every device (observed 78–106 GiB/device on the MoE archs).
    """
    from repro.common.pjit_utils import _ambient_mesh
    mesh = _ambient_mesh()
    if mesh is not None and cfg.num_experts % 2 == 0:
        out, aux = _moe_fwd_sharded(cfg, p, x, mesh)
        if out is not None:
            if cfg.num_shared_experts:
                out = out + mlp_fwd(p["shared"], x,
                                    adapters.get("shared") if adapters else None)
            return out, aux

    B, S, d = x.shape
    T = B * S
    xt = x.reshape(T, d)
    C = _capacity(cfg, T)
    ebuf, meta, aux = _route_and_dispatch(cfg, p["router"], xt, C)
    eo = _experts(p, ebuf, x.dtype)
    out = _combine(T, eo, meta, x.dtype)
    if cfg.num_shared_experts:
        out = out + mlp_fwd(p["shared"], xt,
                            adapters.get("shared") if adapters else None)
    return out.reshape(B, S, d), aux


def _moe_fwd_sharded(cfg: ModelConfig, p: Params, x: jnp.ndarray, mesh):
    """shard_map expert parallelism (DESIGN.md §5).

    Tokens are sharded (batch → data/pod, sequence → model); experts are
    sharded over 'model' (and additionally 'data' when E divides the full
    slice — DeepSeek's 256 experts → exactly one expert per chip on a
    16×16 pod).  Dispatch is local, the exchange is one all-to-all each
    way — the communication pattern the roofline's all-to-all term tracks.
    Returns (out, aux) or (None, None) if shapes don't permit.
    """
    from jax.sharding import PartitionSpec as P
    from repro.common.pjit_utils import batch_axes, mesh_axis_sizes

    B, S, d = x.shape
    E = cfg.num_experts
    sizes = mesh_axis_sizes()
    msize = sizes.get("model", 1)
    dax = batch_axes()
    d_sz = 1
    if dax is not None:
        for n in (dax if isinstance(dax, tuple) else (dax,)):
            d_sz *= sizes.get(n, 1)
    data_sz = sizes.get("data", 1)

    if msize <= 1 or S % msize or (dax is not None and B % d_sz):
        return None, None
    if E % (msize * data_sz) == 0 and E >= msize * data_sz:
        ep_axes = ("data", "model")
        ep = msize * data_sz
        w_spec = P(("data", "model"), None, None)
    elif E % msize == 0:
        ep_axes = ("model",)
        ep = msize
        w_spec = P("model", None, None)
    else:
        return None, None

    T_l = (B // d_sz) * (S // msize)
    C_l = _capacity(cfg, T_l)
    all_axes = tuple(mesh.axis_names)

    def body(x_l, router, wg, wu, wd):
        Bl, Sl, _ = x_l.shape
        xt = x_l.reshape(Bl * Sl, d)
        ebuf, meta, aux = _route_and_dispatch(cfg, router, xt, C_l)
        # -> expert owners
        ebuf = jax.lax.all_to_all(ebuf, ep_axes, split_axis=0, concat_axis=1,
                                  tiled=True)          # (E/ep, C_l*ep, d)
        eo = _experts({"w_gate": wg, "w_up": wu, "w_down": wd}, ebuf, x_l.dtype)
        eo = jax.lax.all_to_all(eo, ep_axes, split_axis=1, concat_axis=0,
                                tiled=True)            # (E, C_l, d)
        out = _combine(Bl * Sl, eo, meta, x_l.dtype)
        aux = jax.lax.pmean(aux, all_axes)
        return out.reshape(Bl, Sl, d), aux

    xs = P(dax, "model", None)
    out, aux = _pjit_shard_map(
        body, mesh=mesh,
        in_specs=(xs, P(None, None), w_spec, w_spec, w_spec),
        out_specs=(xs, P()),
        check_vma=False,
    )(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])
    return out, aux
