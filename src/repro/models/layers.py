"""Core transformer layers in pure JAX.

Everything is functional: ``init_*`` builds param subtrees, ``*_fwd`` applies
them.  Attention supports GQA (arbitrary kv groups), QKV bias (Qwen1.5/2/2.5),
per-head qk RMSNorm (Qwen3), RoPE, sliding windows, and a single-token cached
decode path.  MLA (DeepSeek-V3) lives in this module too.

LoRA adapters are threaded through every projection via
:func:`repro.peft.lora.lora_proj`: each projection takes an optional
``{"A": (r, in), "B": (out, r)}`` adapter leaf.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.common.config import ModelConfig
from repro.common.pjit_utils import (
    _ambient_mesh,
    constrain,
    mesh_axis_sizes,
    shard_map as _pjit_shard_map,
)
from repro.peft.lora import PagedLoRA, lora_proj, paged_delta_weight

Params = Dict[str, Any]

# spec of a (B, C, heads, per-head) decode activation under head-parallel
# tensor parallelism (repro.topology.serve)
_HEAD_SPEC = (None, None, "model", None)


def _model_par_heads(kv_heads: int, q_heads: int) -> bool:
    """Whether head-parallel decode applies under the ambient mesh: the
    ``model`` axis must divide both head counts so every GQA group stays on
    one shard (attention is then collective-free; the only communication is
    the all-reduce at the row-parallel output projections)."""
    m = mesh_axis_sizes().get("model", 1)
    return m > 1 and kv_heads % m == 0 and q_heads % m == 0


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def dense_init(key, shape, in_dim, dtype):
    scale = 1.0 / math.sqrt(in_dim)
    return (jax.random.normal(key, shape) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm(x: jnp.ndarray, weight: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * weight.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float, positions: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """cos/sin tables for given integer positions. positions: (...,)"""
    inv = 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))
    ang = positions.astype(jnp.float32)[..., None] * inv  # (..., hd/2)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """x: (..., S, H, hd) rotated pairwise-interleaved; cos/sin: (S, hd/2)."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    x1, x2 = jnp.split(x, 2, axis=-1)
    # broadcast cos/sin over head axis: (S, 1, hd/2)
    c = cos[..., :, None, :]
    s = sin[..., :, None, :]
    out = jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)
    return out.astype(dt)


# ---------------------------------------------------------------------------
# GQA attention
# ---------------------------------------------------------------------------

def init_attention(cfg: ModelConfig, key, dtype) -> Params:
    d, H, K, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, H * hd), d, dtype),
        "wk": dense_init(ks[1], (d, K * hd), d, dtype),
        "wv": dense_init(ks[2], (d, K * hd), d, dtype),
        "wo": dense_init(ks[3], (H * hd, d), H * hd, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), dtype)
        p["bk"] = jnp.zeros((K * hd,), dtype)
        p["bv"] = jnp.zeros((K * hd,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def _qkv(cfg: ModelConfig, p: Params, x, adapters, positions):
    """Project to q,k,v with all arch options. x: (B,S,d)."""
    B, S, _ = x.shape
    H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    a = adapters or {}
    q = lora_proj(x, p["wq"], a.get("wq"))
    k = lora_proj(x, p["wk"], a.get("wk"))
    v = lora_proj(x, p["wv"], a.get("wv"))
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, S, K, hd)
    v = v.reshape(B, S, K, hd)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    cos, sin = rope_freqs(hd, cfg.rope_theta, positions)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    return q, k, v


def gqa_scores_einsum(q, k):
    """Grouped attention scores. q: (B,S,H,hd), k: (B,T,K,hd) -> (B,H,S,T)."""
    B, S, H, hd = q.shape
    K = k.shape[2]
    g = H // K
    q = q.reshape(B, S, K, g, hd)
    s = jnp.einsum("bskgh,btkh->bkgst", q.astype(jnp.float32), k.astype(jnp.float32))
    return s.reshape(B, H, S, k.shape[1])


def gqa_out_einsum(w, v):
    """w: (B,H,S,T), v: (B,T,K,hd) -> (B,S,H,hd)."""
    B, H, S, T = w.shape
    K = v.shape[2]
    g = H // K
    w = w.reshape(B, K, g, S, T)
    o = jnp.einsum("bkgst,btkh->bskgh", w, v.astype(jnp.float32))
    return o.reshape(B, S, H, v.shape[3])


def causal_mask(S: int, T: int, q_offset: int = 0, window: int = 0):
    """(S,T) mask: True = attend. q position i attends kv position j iff
    j <= i+q_offset and (window == 0 or j > i+q_offset-window)."""
    qpos = jnp.arange(S)[:, None] + q_offset
    kpos = jnp.arange(T)[None, :]
    m = kpos <= qpos
    if window:
        m &= kpos > (qpos - window)
    return m


def attention_fwd(cfg: ModelConfig, p: Params, x, adapters=None, positions=None,
                  use_kernel: bool = False) -> jnp.ndarray:
    """Full-sequence causal attention (train / prefill). x: (B,S,d).

    Three paths: Pallas kernel (TPU fast path), chunked XLA-flash (default —
    memory-bounded, what dry-runs lower), einsum fallback (odd tiny shapes).
    """
    from repro.models.attention_core import dispatch_flash
    B, S, d = x.shape
    if positions is None:
        positions = jnp.arange(S)
    q, k, v = _qkv(cfg, p, x, adapters, positions)
    if use_kernel:
        # any S: ops.flash_attention pads to block multiples internally
        from repro.kernels import ops as kops
        o = kops.flash_attention(q, k, v, causal=True, window=cfg.sliding_window)
    elif S % min(S, 512) == 0:
        o = dispatch_flash(q, k, v, causal=True, window=cfg.sliding_window,
                           q_chunk=512, kv_chunk=1024)
    else:
        scale = 1.0 / math.sqrt(cfg.head_dim)
        s = gqa_scores_einsum(q, k) * scale
        mask = causal_mask(S, S, 0, cfg.sliding_window)
        s = jnp.where(mask[None, None], s, -1e30)
        w = jax.nn.softmax(s, axis=-1)
        o = gqa_out_einsum(w, v)
    o = o.reshape(B, S, cfg.num_heads * cfg.head_dim).astype(x.dtype)
    a = adapters or {}
    return lora_proj(o, p["wo"], a.get("wo"))


def attention_decode(cfg: ModelConfig, p: Params, x, cache: Dict, adapters=None,
                     n_tokens=None, decode_impl: str = "dense"):
    """Chunked cached decode with per-slot positions.

    x: (B,C,d) — one token (C=1) or a prefill chunk.  cache:
    {"k": (B,T,K,hd), "v": (B,T,K,hd), "pos": (B,), "length": (B,)} where T
    is the cache capacity (= context length, or window size when sliding);
    every batch slot rides its own ring offset.  ``n_tokens: (B,)``
    optionally marks how many of the C tokens are real per row (masked
    continuous batching; rows with 0 leave their cache untouched).
    Returns (out (B,C,d), new_cache).

    ``decode_impl`` selects the attention interior: ``"dense"`` (the tested
    oracle — full (B,H,C,T) scores + dense ring mask), ``"streamed"``
    (XLA flash-decoding: online softmax over kv blocks, in-loop ring
    masking + int8 dequant, O(block) live memory), or ``"kernel"`` (the
    Pallas ring-flash-decode kernel — same contract, fused on TPU).  All
    three agree on every VALID query position (``t < n_tokens[b]``); rows
    a chunk marks invalid hold unspecified values (their outputs are
    discarded by every caller).  int8 caveat: the dense path dequantizes
    to bf16 (``cache_kv``) while streamed/kernel fuse an fp32 dequant per
    block — strictly more precise, so int8 agreement is within bf16
    tolerance rather than bit-exact.
    """
    from repro.models.attention_core import ring_attend_mask
    from repro.serve.kvcache import cache_update, cache_kv
    B, C, _ = x.shape
    qpos = cache["pos"][:, None] + jnp.arange(C)[None, :]     # (B,C) absolute
    q, k, v = _qkv(cfg, p, x, adapters, qpos)
    headpar = _model_par_heads(cfg.num_kv_heads, cfg.num_heads)
    if headpar:
        q = constrain(q, _HEAD_SPEC)
        k = constrain(k, _HEAD_SPEC)
        v = constrain(v, _HEAD_SPEC)
    cache = cache_update(cfg, cache, k, v, n_tokens)
    if decode_impl == "dense":
        kc, vc = cache_kv(cfg, cache)
        T = kc.shape[1]
        scale = 1.0 / math.sqrt(cfg.head_dim)
        s = gqa_scores_einsum(q, kc) * scale            # (B,H,C,T)
        mask = ring_attend_mask(cache["pos"], cache["length"], T, qpos,
                                cfg.sliding_window)     # (B,C,T) per-row
        s = jnp.where(mask[:, None], s, -1e30)
        w = jax.nn.softmax(s, axis=-1)
        o = gqa_out_einsum(w, vc)
    else:
        n = (jnp.full((B,), C, jnp.int32) if n_tokens is None
             else n_tokens.astype(jnp.int32))
        int8 = cache["k"].dtype == jnp.int8
        kw = dict(window=cfg.sliding_window,
                  k_scale=cache["k_scale"] if int8 else None,
                  v_scale=cache["v_scale"] if int8 else None)
        if decode_impl == "kernel":
            o = _ring_decode_sharded(q, cache, n, cfg.sliding_window,
                                     headpar)
        elif decode_impl == "streamed":
            from repro.models.attention_core import ring_flash_decode
            o = ring_flash_decode(q, cache["k"], cache["v"], cache["pos"],
                                  cache["length"], n, **kw)
        else:
            raise ValueError(f"unknown decode_impl {decode_impl!r}")
    if headpar:
        o = constrain(o, _HEAD_SPEC)
    o = o.reshape(B, C, cfg.num_heads * cfg.head_dim).astype(x.dtype)
    a = adapters or {}
    return lora_proj(o, p["wo"], a.get("wo")), cache


def _ring_decode_sharded(q, cache: Dict, n, window, headpar: bool):
    """Pallas ring-flash-decode, head-parallel when possible: with an
    ambient mesh whose ``model`` axis divides the head counts, the kernel
    runs inside ``shard_map`` over the kv-head axis — each shard attends
    its own GQA groups against its own cache shard, no collectives.  The
    kernel is opaque to GSPMD, so without the manual mapping a sharded
    cache would be all-gathered around every call."""
    from repro.kernels import ops as kops
    int8 = cache["k"].dtype == jnp.int8
    args = [q, cache["k"], cache["v"], cache["pos"], cache["length"], n]
    if int8:
        args += [cache["k_scale"], cache["v_scale"]]

    def body(q_, k_, v_, pos_, len_, n_, *scales):
        ks_, vs_ = scales if scales else (None, None)
        return kops.ring_decode(q_, k_, v_, pos_, len_, n_, window=window,
                                k_scale=ks_, v_scale=vs_)

    mesh = _ambient_mesh()
    if not headpar or mesh is None:
        return body(*args)
    hspec = P(*_HEAD_SPEC)
    rep1 = P(None)
    specs = [hspec] * 3 + [rep1] * 3 + ([hspec] * 2 if int8 else [])
    return _pjit_shard_map(body, mesh=mesh, in_specs=tuple(specs),
                           out_specs=hspec, check_vma=False)(*args)


def _mla_ring_decode_sharded(q_eff, cache: Dict, n, scale, window,
                             headpar: bool):
    """MLA Pallas latent decode under head parallelism: query heads shard
    over ``model`` (``shard_map`` over axis 2 of ``q_eff``); the compressed
    latent cache is tiny and stays replicated, so each shard attends all
    positions with its own head slice — no collectives."""
    from repro.kernels import ops as kops
    int8 = cache["c_kv"].dtype == jnp.int8
    args = [q_eff, cache["c_kv"], cache["k_rope"], cache["pos"],
            cache["length"], n]
    if int8:
        args += [cache["c_kv_scale"], cache["k_rope_scale"]]

    def body(q_, ckv_, kr_, pos_, len_, n_, *scales):
        cs_, rs_ = scales if scales else (None, None)
        return kops.mla_ring_decode(q_, ckv_, kr_, pos_, len_, n_,
                                    scale=scale, window=window,
                                    c_kv_scale=cs_, k_rope_scale=rs_)

    mesh = _ambient_mesh()
    if not headpar or mesh is None:
        return body(*args)
    hspec = P(*_HEAD_SPEC)
    rep3 = P(None, None, None)
    rep1 = P(None)
    specs = [hspec, rep3, rep3, rep1, rep1, rep1]
    if int8:
        specs += [rep3, rep3]
    return _pjit_shard_map(body, mesh=mesh, in_specs=tuple(specs),
                           out_specs=hspec, check_vma=False)(*args)


# ---------------------------------------------------------------------------
# MLA — Multi-head Latent Attention (DeepSeek-V3)
# ---------------------------------------------------------------------------

def init_mla(cfg: ModelConfig, key, dtype) -> Params:
    d = cfg.d_model
    qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
    nope, rope, vd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    H = cfg.num_heads
    ks = jax.random.split(key, 5)
    return {
        "wq_a": dense_init(ks[0], (d, qr), d, dtype),
        "q_a_norm": jnp.ones((qr,), dtype),
        "wq_b": dense_init(ks[1], (qr, H * (nope + rope)), qr, dtype),
        "wkv_a": dense_init(ks[2], (d, kvr + rope), d, dtype),
        "kv_a_norm": jnp.ones((kvr,), dtype),
        "wkv_b": dense_init(ks[3], (kvr, H * (nope + vd)), kvr, dtype),
        "wo": dense_init(ks[4], (H * vd, d), H * vd, dtype),
    }


def _mla_qkv(cfg: ModelConfig, p: Params, x, adapters, positions):
    B, S, _ = x.shape
    H = cfg.num_heads
    nope, rope, vd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    a = adapters or {}
    q = lora_proj(x, p["wq_a"], a.get("wq_a"))
    q = rmsnorm(q, p["q_a_norm"], cfg.norm_eps)
    q = lora_proj(q, p["wq_b"], a.get("wq_b")).reshape(B, S, H, nope + rope)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    kv = lora_proj(x, p["wkv_a"], a.get("wkv_a"))
    c_kv, k_rope = kv[..., : cfg.kv_lora_rank], kv[..., cfg.kv_lora_rank:]
    cos, sin = rope_freqs(rope, cfg.rope_theta, positions)
    q_rope = apply_rope(q_rope, cos, sin)
    k_rope = apply_rope(k_rope[..., None, :], cos, sin)  # (B,S,1,rope)
    c_kv = rmsnorm(c_kv, p["kv_a_norm"], cfg.norm_eps)
    return q_nope, q_rope, c_kv, k_rope[..., 0, :]


def _mla_expand_kv(cfg: ModelConfig, p: Params, c_kv, adapters):
    """Expand compressed kv latent to per-head k_nope and v."""
    B, T, _ = c_kv.shape
    H = cfg.num_heads
    nope, vd = cfg.qk_nope_head_dim, cfg.v_head_dim
    a = adapters or {}
    kv = lora_proj(c_kv, p["wkv_b"], a.get("wkv_b")).reshape(B, T, H, nope + vd)
    return kv[..., :nope], kv[..., nope:]


def _mla_attend(cfg, q_nope, q_rope, k_nope, k_rope, v):
    """q_*: (B,S,H,*), k_nope/v: (B,T,H,*), k_rope: (B,T,rope) shared."""
    scale = 1.0 / math.sqrt(cfg.qk_nope_head_dim + cfg.qk_rope_head_dim)
    s = jnp.einsum("bshc,bthc->bhst", q_nope.astype(jnp.float32), k_nope.astype(jnp.float32))
    s += jnp.einsum("bshc,btc->bhst", q_rope.astype(jnp.float32), k_rope.astype(jnp.float32))
    return s * scale, v


def mla_fwd(cfg: ModelConfig, p: Params, x, adapters=None, positions=None):
    """MLA full-sequence attention — *absorbed* formulation: attention runs
    against the compressed latent stream (B,T,kvr); the per-head K/V
    expansion is never materialized (attention_core.mla_absorbed)."""
    from repro.models.attention_core import mla_absorbed
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S)
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(cfg, p, x, adapters, positions)
    if S % min(S, 512) == 0:
        w_kvb = p["wkv_b"]
        a_kvb = (adapters or {}).get("wkv_b")
        if isinstance(a_kvb, PagedLoRA):
            raise NotImplementedError(
                "paged multi-tenant adapters only run through the decode "
                "path (mla_decode); mla_fwd is the training/full-seq path")
        if a_kvb is not None:   # fold the LoRA delta into the absorbed weight
            w_kvb = w_kvb + ((a_kvb["B"] @ a_kvb["A"]).T
                             * a_kvb["scale"]).astype(w_kvb.dtype)
        o = mla_absorbed(q_nope, q_rope, c_kv.astype(jnp.float32),
                         k_rope.astype(jnp.float32), w_kvb,
                         num_heads=cfg.num_heads, nope_dim=cfg.qk_nope_head_dim,
                         v_dim=cfg.v_head_dim, causal=True,
                         window=cfg.sliding_window)
    else:
        k_nope, v = _mla_expand_kv(cfg, p, c_kv, adapters)
        s, v = _mla_attend(cfg, q_nope, q_rope, k_nope, k_rope, v)
        mask = causal_mask(S, S, 0, cfg.sliding_window)
        s = jnp.where(mask[None, None], s, -1e30)
        w = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhst,bthv->bshv", w, v.astype(jnp.float32))
    o = o.reshape(B, S, cfg.num_heads * cfg.v_head_dim).astype(x.dtype)
    a = adapters or {}
    return lora_proj(o, p["wo"], a.get("wo"))


def mla_decode(cfg: ModelConfig, p: Params, x, cache: Dict, adapters=None,
               n_tokens=None, decode_impl: str = "dense"):
    """MLA chunked decode — *absorbed* formulation: attention runs directly
    against the compressed latent cache (the paper-faithful MLA memory
    saving); the per-head K/V expansion ((B,T,H,·) — 17 GB/layer at
    32k×128h) is never materialized.  Scores: q_latᵀc_kv + q_ropeᵀk_rope;
    values: latent then per-head V-projection after the softmax.  x: (B,C,d)
    with per-slot cache positions; ``n_tokens: (B,)`` masks padded rows as in
    :func:`attention_decode`.

    ``decode_impl``: ``"dense"`` (oracle; int8 caches are dequantized WHOLE
    up front), ``"streamed"`` (XLA flash-decoding over latent kv blocks —
    int8 halves dequantized per block, so serving never holds a full fp32
    cache copy), or ``"kernel"`` (Pallas).  Agreement contract as in
    :func:`attention_decode`.
    """
    from repro.models.attention_core import ring_attend_mask
    from repro.serve.kvcache import mla_cache_update
    B, C, _ = x.shape
    H = cfg.num_heads
    nope, vd = cfg.qk_nope_head_dim, cfg.v_head_dim
    kvr = cfg.kv_lora_rank
    qpos = cache["pos"][:, None] + jnp.arange(C)[None, :]     # (B,C)
    q_nope, q_rope, c_kv_t, k_rope_t = _mla_qkv(cfg, p, x, adapters, qpos)
    cache = mla_cache_update(cache, c_kv_t, k_rope_t, n_tokens)

    a = adapters or {}
    w_kvb = p["wkv_b"]
    a_kvb = a.get("wkv_b")
    if isinstance(a_kvb, PagedLoRA):
        # multi-tenant: every batch row folds ITS OWN adapter's delta into
        # the absorbed weight, so the latent projections become per-row
        w = (w_kvb.astype(jnp.float32)[None] + paged_delta_weight(a_kvb)
             ).reshape(B, kvr, H, nope + vd)
        w_k, w_v = w[..., :nope], w[..., nope:]
        q_lat = jnp.einsum("bshn,bkhn->bshk", q_nope.astype(jnp.float32), w_k)
        v_ein = "bshk,bkhv->bshv"
        headpar = False       # per-row absorbed weights: keep heads whole
    else:
        if a_kvb is not None:    # fold LoRA delta into the absorbed weight
            w_kvb = w_kvb + ((a_kvb["B"] @ a_kvb["A"]).T
                             * a_kvb["scale"]).astype(w_kvb.dtype)
        w = w_kvb.reshape(kvr, H, nope + vd).astype(jnp.float32)
        w_k, w_v = w[..., :nope], w[..., nope:]
        q_lat = jnp.einsum("bshn,khn->bshk", q_nope.astype(jnp.float32), w_k)
        v_ein = "bshk,khv->bshv"
        headpar = _model_par_heads(H, H)
    if headpar:
        q_lat = constrain(q_lat, _HEAD_SPEC)
    scale = 1.0 / math.sqrt(nope + cfg.qk_rope_head_dim)
    int8 = cache["c_kv"].dtype == jnp.int8
    if decode_impl == "dense":
        c_kv, k_rope = cache["c_kv"], cache["k_rope"]
        if int8:
            from repro.serve.kvcache import dequant
            c_kv = dequant(c_kv, cache["c_kv_scale"])
            k_rope = dequant(k_rope, cache["k_rope_scale"])
        c_kv = c_kv.astype(jnp.float32)
        k_rope = k_rope.astype(jnp.float32)
        s = (jnp.einsum("bshk,btk->bhst", q_lat, c_kv)
             + jnp.einsum("bshr,btr->bhst", q_rope.astype(jnp.float32),
                          k_rope)) * scale
        T = s.shape[-1]
        mask = ring_attend_mask(cache["pos"], cache["length"], T, qpos,
                                cfg.sliding_window)            # (B,C,T)
        s = jnp.where(mask[:, None], s, -1e30)
        wts = jax.nn.softmax(s, axis=-1)
        out_lat = jnp.einsum("bhst,btk->bshk", wts, c_kv)      # (B,C,H,kvr)
    else:
        n = (jnp.full((B,), C, jnp.int32) if n_tokens is None
             else n_tokens.astype(jnp.int32))
        q_eff = jnp.concatenate(
            [q_lat, q_rope.astype(jnp.float32)], axis=-1)      # (B,C,H,kvr+r)
        kw = dict(scale=scale, window=cfg.sliding_window,
                  c_kv_scale=cache["c_kv_scale"] if int8 else None,
                  k_rope_scale=cache["k_rope_scale"] if int8 else None)
        if decode_impl == "kernel":
            out_lat = _mla_ring_decode_sharded(q_eff, cache, n, scale,
                                               cfg.sliding_window, headpar)
        elif decode_impl == "streamed":
            from repro.models.attention_core import mla_ring_flash_decode
            out_lat = mla_ring_flash_decode(q_eff, cache["c_kv"],
                                            cache["k_rope"], cache["pos"],
                                            cache["length"], n, **kw)
        else:
            raise ValueError(f"unknown decode_impl {decode_impl!r}")
    if headpar:
        out_lat = constrain(out_lat, _HEAD_SPEC)
    o = jnp.einsum(v_ein, out_lat, w_v)
    o = o.reshape(B, C, H * vd).astype(x.dtype)
    return lora_proj(o, p["wo"], a.get("wo")), cache


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------

def init_mlp(cfg: ModelConfig, key, dtype, d_ff: int = 0) -> Params:
    d, ff = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(ks[0], (d, ff), d, dtype),
        "w_up": dense_init(ks[1], (d, ff), d, dtype),
        "w_down": dense_init(ks[2], (ff, d), ff, dtype),
    }


def mlp_fwd(p: Params, x, adapters=None):
    a = adapters or {}
    g = lora_proj(x, p["w_gate"], a.get("w_gate"))
    u = lora_proj(x, p["w_up"], a.get("w_up"))
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return lora_proj(h, p["w_down"], a.get("w_down"))
