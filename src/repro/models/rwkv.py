"""RWKV6 "Finch" layer (data-dependent decay, attention-free) in pure JAX.

Time-mix with data-dependent token-shift (ddlerp) and data-dependent decay
(the defining Finch features, arXiv:2404.05892), per-head WKV state
recurrence, group-norm output, gated; plus the squared-ReLU channel-mix.

The WKV recurrence is sequential over time — the training/prefill path uses
``lax.scan`` (and optionally the Pallas ``wkv6`` kernel); decode is the O(1)
state update.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.common.config import ModelConfig
from repro.models.layers import dense_init
from repro.peft.lora import lora_proj

Params = Dict[str, Any]


def init_rwkv6(cfg: ModelConfig, key, dtype) -> Params:
    d, ff, dl = cfg.d_model, cfg.d_ff, cfg.rwkv_decay_lora
    H, hd = cfg.num_rwkv_heads, cfg.rwkv_head_dim
    ks = jax.random.split(key, 12)
    return {
        # --- time mix -------------------------------------------------------
        "mu_x": (jax.random.uniform(ks[0], (d,))).astype(dtype),
        "mu": (jax.random.uniform(ks[1], (5, d))).astype(dtype),   # r,k,v,w,g
        "dd_w1": dense_init(ks[2], (d, 5 * dl), d, dtype),
        "dd_w2": (jax.random.normal(ks[3], (5, dl, d)) * 0.02).astype(dtype),
        "wr": dense_init(ks[4], (d, d), d, dtype),
        "wk": dense_init(ks[5], (d, d), d, dtype),
        "wv": dense_init(ks[6], (d, d), d, dtype),
        "wg": dense_init(ks[7], (d, d), d, dtype),
        "wo": dense_init(ks[8], (d, d), d, dtype),
        "w0": jnp.full((d,), -6.0, jnp.float32),      # decay base (pre -exp)
        "wd1": dense_init(ks[9], (d, dl), d, dtype),
        "wd2": (jax.random.normal(ks[10], (dl, d)) * 0.02).astype(dtype),
        "u": jnp.zeros((H, hd), jnp.float32),          # time_first bonus
        "ln_x_w": jnp.ones((d,), dtype),
        "ln_x_b": jnp.zeros((d,), dtype),
        # --- channel mix ------------------------------------------------------
        "mu_ck": (jax.random.uniform(ks[11], (d,))).astype(dtype),
        "mu_cr": jnp.full((d,), 0.5, dtype),
        "wck": dense_init(ks[2], (d, ff), d, dtype),
        "wcv": dense_init(ks[3], (ff, d), ff, dtype),
        "wcr": dense_init(ks[4], (d, d), d, dtype),
    }


def _shift(x, last=None):
    """token shift: x_{t-1}; first position gets `last` (or zeros)."""
    prev = jnp.zeros_like(x[:, :1]) if last is None else last[:, None]
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def _ddlerp(p, x, xx):
    """Data-dependent lerp producing the 5 mixed inputs (r,k,v,w,g)."""
    B, S, d = x.shape
    base = x + xx * p["mu_x"]
    dl = p["dd_w1"].shape[1] // 5
    h = jnp.tanh((base @ p["dd_w1"]).astype(jnp.float32)).reshape(B, S, 5, dl)
    off = jnp.einsum("bsfl,fld->bsfd", h.astype(x.dtype), p["dd_w2"])  # (B,S,5,d)
    mixed = x[:, :, None] + xx[:, :, None] * (p["mu"][None, None] + off)
    return mixed                                                        # (B,S,5,d)


def _decay(p, xw):
    """Data-dependent per-channel decay; returns log-decay w < 0 (fp32)."""
    dd = jnp.tanh((xw @ p["wd1"]).astype(jnp.float32)) @ p["wd2"].astype(jnp.float32)
    return -jnp.exp(p["w0"] + dd)                                        # (B,S,d)


def wkv_scan(r, k, v, w, u):
    """Reference WKV recurrence.

    r,k,v: (B,S,H,hd); w: (B,S,H,hd) log-decay; u: (H,hd).
    y_t = r_t · (S_{t-1} + u ⊙ k_t ⊗ v_t);  S_t = e^{w_t} ⊙_k S_{t-1} + k_t ⊗ v_t.
    Returns (y: (B,S,H,hd), final state (B,H,hd,hd)) in fp32.
    """
    B, S, H, hd = r.shape
    rf, kf, vf, wf = (t.astype(jnp.float32) for t in (r, k, v, w))

    def step(state, inp):
        rt, kt, vt, wt = inp                    # (B,H,hd) each
        kv = kt[..., :, None] * vt[..., None, :]            # (B,H,hd,hd)
        y = jnp.einsum("bhk,bhkv->bhv", rt, state + u[..., None] * kv)
        state = jnp.exp(wt)[..., None] * state + kv
        return state, y

    s0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    xs = tuple(t.swapaxes(0, 1) for t in (rf, kf, vf, wf))
    final, ys = jax.lax.scan(step, s0, xs)
    return ys.swapaxes(0, 1), final


def _group_norm(x, w, b, H, eps):
    """Per-head layer norm. x: (B,S,d) fp32."""
    B, S, d = x.shape
    xh = x.reshape(B, S, H, d // H)
    mu = xh.mean(-1, keepdims=True)
    var = xh.var(-1, keepdims=True)
    xh = (xh - mu) * jax.lax.rsqrt(var + eps)
    return xh.reshape(B, S, d) * w + b


def time_mix(cfg: ModelConfig, p: Params, x, adapters=None, state=None,
             use_kernel: bool = False):
    """x: (B,S,d). state: None (train/prefill) or decode state dict."""
    B, S, d = x.shape
    H, hd = cfg.num_rwkv_heads, cfg.rwkv_head_dim
    a = adapters or {}
    last = state["tm_x"] if state is not None else None
    xx = _shift(x, last) - x
    mixed = _ddlerp(p, x, xx)
    xr, xk, xv, xw, xg = (mixed[:, :, i] for i in range(5))
    r = lora_proj(xr, p["wr"], a.get("wr")).reshape(B, S, H, hd)
    k = lora_proj(xk, p["wk"], a.get("wk")).reshape(B, S, H, hd)
    v = lora_proj(xv, p["wv"], a.get("wv")).reshape(B, S, H, hd)
    g = jax.nn.silu(lora_proj(xg, p["wg"], a.get("wg")).astype(jnp.float32))
    w = _decay(p, xw).reshape(B, S, H, hd)

    if state is not None:
        # O(1) decode update
        s = state["wkv"]                                    # (B,H,hd,hd) fp32
        rt, kt, vt = (t[:, 0].astype(jnp.float32) for t in (r, k, v))
        wt = w[:, 0]
        kv = kt[..., :, None] * vt[..., None, :]
        y = jnp.einsum("bhk,bhkv->bhv", rt, s + p["u"][..., None] * kv)[:, None]
        new_s = jnp.exp(wt)[..., None] * s + kv
        new_state = {"tm_x": x[:, -1], "wkv": new_s}
    elif use_kernel:
        from repro.kernels import ops as kops
        y = kops.wkv6(r, k, v, w, p["u"])
        new_state = None
    else:
        y, _ = wkv_scan(r, k, v, w, p["u"])
        new_state = None

    y = y.reshape(B, -1, d)
    y = _group_norm(y, p["ln_x_w"].astype(jnp.float32),
                    p["ln_x_b"].astype(jnp.float32), H, cfg.norm_eps)
    y = (y * g).astype(x.dtype)
    return lora_proj(y, p["wo"], a.get("wo")), new_state


def channel_mix(cfg: ModelConfig, p: Params, x, adapters=None, state=None):
    a = adapters or {}
    last = state["cm_x"] if state is not None else None
    xx = _shift(x, last) - x
    xk = x + xx * p["mu_ck"]
    xr = x + xx * p["mu_cr"]
    k = lora_proj(xk, p["wck"], a.get("wck"))
    k = jnp.square(jax.nn.relu(k.astype(jnp.float32))).astype(x.dtype)
    rgate = jax.nn.sigmoid(lora_proj(xr, p["wcr"], a.get("wcr")).astype(jnp.float32))
    out = (rgate * lora_proj(k, p["wcv"], a.get("wcv")).astype(jnp.float32)).astype(x.dtype)
    new_state = {"cm_x": x[:, -1]} if state is not None else None
    return out, new_state


def rwkv6_init_state(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> Dict:
    d, H, hd = cfg.d_model, cfg.num_rwkv_heads, cfg.rwkv_head_dim
    return {
        "tm_x": jnp.zeros((batch, d), dtype),
        "wkv": jnp.zeros((batch, H, hd, hd), jnp.float32),
        "cm_x": jnp.zeros((batch, d), dtype),
    }
