"""Memory-bounded chunked attention in pure JAX (XLA-flash).

``flash_jax`` is the lowering-path attention used for long sequences: a
double scan (query chunks × kv chunks) with online softmax, so peak live
memory is O(bq·bk) score tiles instead of O(S²).  GQA/MQA via head grouping
(k/v have K heads, q has H = g·K).  The Pallas kernel
(:mod:`repro.kernels.flash_attention`) is the TPU-target fast path behind
``use_kernels``; this module is what every dry-run lowers by default and the
oracle the kernel is tested against is the same math.

Also here: the *absorbed* MLA formulation (DeepSeek-V3 weight absorption) —
queries are projected into the compressed-KV latent space so attention runs
against the (B,T,kv_lora_rank) latent stream directly and the per-head
K/V expansion ((B,T,H,192/128) ≈ GiB-scale at 4k×128h) is never
materialized.  TPU adaptation note: this trades extra MXU FLOPs
(q·W_absorb) for HBM footprint — the right trade on v5e (DESIGN.md §3).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.common.pjit_utils import shard_map as _pjit_shard_map

NEG_INF = -1e30


def _chunk_mask(qpos, kpos, causal: bool, window: int):
    m = jnp.ones((qpos.shape[0], kpos.shape[0]), jnp.bool_)
    if causal:
        m &= kpos[None, :] <= qpos[:, None]
    if window:
        m &= kpos[None, :] > (qpos[:, None] - window)
    return m


def ring_slot_positions(pos, length, cap: int):
    """Absolute token position held by each ring-buffer slot, per batch row.

    pos/length: (B,) per-slot cache state AFTER the current write.  Returns
    (p_abs, resident), both (B, cap): ``p_abs[b, s]`` is the absolute
    position of the newest token ever written to slot ``s`` of row ``b``
    (negative if never written) and ``resident[b, s]`` marks slots whose
    token is still live (not yet evicted by the ring).
    """
    s = jnp.arange(cap)[None, :]
    last = pos[:, None] - 1                       # newest absolute position
    p_abs = last - jnp.mod(last - s, cap)         # (B, cap)
    resident = p_abs >= (pos - length)[:, None]
    return p_abs, resident


def ring_attend_mask(pos, length, cap: int, qpos, window: int = 0):
    """Decode attention mask over a per-slot ring-buffer cache.

    pos/length: (B,) cache state AFTER the query chunk was written;
    qpos: (B, C) absolute positions of the query tokens.  Returns a
    (B, C, cap) bool mask: row ``b``'s query ``t`` attends cache slot ``s``
    iff the slot is resident for THAT row, causally visible
    (``p_abs <= qpos``), and inside the sliding window when one is set.
    Masking is per-row, so batch slots at different positions (continuous
    batching) never see each other's — or a previous occupant's — keys.
    """
    p_abs, resident = ring_slot_positions(pos, length, cap)
    m = resident[:, None, :] & (p_abs[:, None, :] <= qpos[:, :, None])
    if window:
        m &= p_abs[:, None, :] > (qpos[:, :, None] - window)
    return m


def ring_block_mask(pos, length, n_tokens, cap: int, start, bk: int, C: int,
                    window: int = 0):
    """Ring attention mask for ONE kv block of ``bk`` slots at ``start``.

    The in-loop (streamed / in-kernel) form of :func:`ring_attend_mask`:
    pos/length: (B,) ring state AFTER the chunk write, ``n_tokens: (B,)``
    real query tokens per row (query positions are recovered as
    ``qpos = pos - n_tokens + t``).  Returns a (B, C, bk) bool mask for
    slots ``[start, start + bk)``; slots ``>= cap`` (block padding) are
    masked out.  Concatenating the blocks over ``start = 0, bk, 2bk, ...``
    reproduces ``ring_attend_mask(pos, length, cap, qpos, window)`` exactly
    (property-tested in tests/test_decode_kernels.py).
    """
    s = start + jnp.arange(bk)[None, :]                     # (1, bk)
    last = (pos - 1)[:, None]
    p_abs = last - jnp.mod(last - s, cap)                   # (B, bk)
    resident = (p_abs >= (pos - length)[:, None]) & (s < cap)
    qpos = (pos - n_tokens)[:, None] + jnp.arange(C)[None, :]   # (B, C)
    m = resident[:, None, :] & (p_abs[:, None, :] <= qpos[:, :, None])
    if window:
        m &= p_abs[:, None, :] > (qpos[:, :, None] - window)
    return m


def _streamed_ring_attend(qf, kv_block, pos, length, n_tokens, cap: int,
                          bk: int, nb: int, dv: int, window: int,
                          scale: float):
    """Online-softmax scan over ring-cache kv blocks.

    qf: (B,C,K,g,dq) fp32; ``kv_block(start) -> (k (B,bk,K,dq),
    v (B,bk,K,dv))`` fp32 (dequantization happens per block inside the
    callback, so an int8 cache is never expanded whole).  Live memory is
    O(B·H·C·bk) score tiles — never O(cap).  Returns (B,C,H,dv) fp32.
    """
    B, C, K, g, _ = qf.shape

    def body(carry, ib):
        m_run, l_run, acc = carry
        start = ib * bk
        kb, vb = kv_block(start)
        s = jnp.einsum("bckgd,bxkd->bkgcx", qf, kb) * scale     # (B,K,g,C,bk)
        msk = ring_block_mask(pos, length, n_tokens, cap, start, bk, C,
                              window)                           # (B,C,bk)
        s = jnp.where(msk[:, None, None], s, NEG_INF)
        m_new = jnp.maximum(m_run, s.max(axis=-1))
        alpha = jnp.exp(m_run - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l_run * alpha + p.sum(-1)
        acc = acc * alpha[..., None] + jnp.einsum("bkgcx,bxkd->bkgcd", p, vb)
        return (m_new, l_new, acc), None

    m0 = jnp.full((B, K, g, C), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, K, g, C), jnp.float32)
    a0 = jnp.zeros((B, K, g, C, dv), jnp.float32)
    (m_f, l_f, acc), _ = jax.lax.scan(body, (m0, l0, a0), jnp.arange(nb))
    out = acc / jnp.maximum(l_f, 1e-30)[..., None]              # (B,K,g,C,dv)
    return out.transpose(0, 3, 1, 2, 4).reshape(B, C, K * g, dv)


def _block_slice(x, start, bk):
    return jax.lax.dynamic_slice_in_dim(x, start, bk, axis=1)


def _pad_cap(arrs, cap: int, bk: int):
    """Pad the slot axis (axis 1) of every array to a bk multiple (dtype-
    preserving — an int8 cache stays int8; padded slots are masked by
    ``s < cap`` inside :func:`ring_block_mask`)."""
    pad = (-cap) % bk
    if pad == 0:
        return arrs
    return [None if a is None else
            jnp.pad(a, [(0, 0), (0, pad)] + [(0, 0)] * (a.ndim - 2))
            for a in arrs]


def ring_flash_decode(q, k, v, pos, length, n_tokens=None, *, window: int = 0,
                      scale: Optional[float] = None, block: int = 128,
                      k_scale=None, v_scale=None):
    """Streamed (XLA flash-decoding) attention over a GQA ring cache.

    q: (B,C,H,hd); k/v: (B,cap,K,hd) — RAW cache storage, possibly int8
    with per-token absmax scales (B,cap,K,1); pos/length: (B,) ring state
    AFTER the chunk write; n_tokens: (B,) real query tokens (None = C).
    The ring residency ∧ causal ∧ window mask is computed per kv block
    in-loop and int8 blocks are dequantized in-loop, so neither a dense
    (B,C,cap) mask, a (B,H,C,cap) score tensor, nor a full-precision cache
    copy is ever live.  Returns (B,C,H,hd) fp32 — the same math as the
    dense oracle in :func:`repro.kernels.ref.ring_decode_ref`.
    """
    B, C, H, dq = q.shape
    cap, K = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    g = H // K
    if scale is None:
        scale = 1.0 / math.sqrt(dq)
    n = (jnp.full((B,), C, jnp.int32) if n_tokens is None
         else n_tokens.astype(jnp.int32))
    bk = min(block, cap)
    nb = -(-cap // bk)
    k, v, k_scale, v_scale = _pad_cap([k, v, k_scale, v_scale], cap, bk)
    qf = q.astype(jnp.float32).reshape(B, C, K, g, dq)

    def kv_block(start):
        kb = _block_slice(k, start, bk).astype(jnp.float32)
        vb = _block_slice(v, start, bk).astype(jnp.float32)
        if k_scale is not None:
            kb = kb * _block_slice(k_scale, start, bk)
            vb = vb * _block_slice(v_scale, start, bk)
        return kb, vb

    return _streamed_ring_attend(qf, kv_block, pos, length, n, cap, bk, nb,
                                 dv, window, scale)


def mla_ring_flash_decode(q_eff, c_kv, k_rope, pos, length, n_tokens=None, *,
                          scale: float, window: int = 0, block: int = 128,
                          c_kv_scale=None, k_rope_scale=None):
    """Streamed absorbed-MLA decode over the compressed-latent ring cache.

    q_eff: (B,C,H,kvr+rope) absorbed queries ``[q_nope·W_k | q_rope]``;
    c_kv: (B,cap,kvr), k_rope: (B,cap,rope) — raw cache storage (int8 with
    (B,cap,1) per-half scales supported; each half is dequantized PER BLOCK
    in-loop, never as a whole).  Returns out_lat (B,C,H,kvr) fp32 — the
    caller applies the absorbed V-projection.  ``scale`` must be the
    un-absorbed 1/√(nope+rope).
    """
    B, C, H, dq = q_eff.shape
    cap, kvr = c_kv.shape[1], c_kv.shape[2]
    n = (jnp.full((B,), C, jnp.int32) if n_tokens is None
         else n_tokens.astype(jnp.int32))
    bk = min(block, cap)
    nb = -(-cap // bk)
    c_kv, k_rope, c_kv_scale, k_rope_scale = _pad_cap(
        [c_kv, k_rope, c_kv_scale, k_rope_scale], cap, bk)
    qf = q_eff.astype(jnp.float32).reshape(B, C, 1, H, dq)   # MQA: K=1, g=H

    def kv_block(start):
        ckv = _block_slice(c_kv, start, bk).astype(jnp.float32)
        kr = _block_slice(k_rope, start, bk).astype(jnp.float32)
        if c_kv_scale is not None:
            ckv = ckv * _block_slice(c_kv_scale, start, bk)
            kr = kr * _block_slice(k_rope_scale, start, bk)
        kb = jnp.concatenate([ckv, kr], axis=-1)[:, :, None, :]
        return kb, ckv[:, :, None, :]

    return _streamed_ring_attend(qf, kv_block, pos, length, n, cap, bk, nb,
                                 kvr, window, scale)


def flash_jax(q, k, v, *, causal: bool = True, window: int = 0,
              scale: Optional[float] = None, q_chunk: int = 512,
              kv_chunk: int = 1024, unroll: Optional[bool] = None,
              q_offset=0):
    """q: (B,S,H,dq), k: (B,T,K,dq), v: (B,T,K,dv) -> (B,S,H,dv) fp32.

    Double-scan online softmax; O(B·H·bq·bk) live scores.  ``q_offset`` is
    the global position of q row 0 (sequence-parallel shards pass their
    offset; the causal/window masks are in global coordinates).
    """
    from repro.common import flags
    if unroll is None:
        unroll = flags.scan_unroll()
    B, S, H, dq = q.shape
    T, K = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    g = H // K
    if scale is None:
        scale = 1.0 / math.sqrt(dq)
    if unroll:
        # analysis lowering: same FLOPs, far fewer (bigger) unrolled blocks —
        # the program is never executed, so tile memory is irrelevant
        q_chunk = max(q_chunk, S // 2)
        kv_chunk = max(kv_chunk, T // 2)
    bq = min(q_chunk, S)
    bk = min(kv_chunk, T)
    assert S % bq == 0 and T % bk == 0, (S, bq, T, bk)
    nq, nk = S // bq, T // bk

    qf = q.astype(jnp.float32).reshape(B, nq, bq, K, g, dq)
    kf = k.astype(jnp.float32).reshape(B, nk, bk, K, dq)
    vf = v.astype(jnp.float32).reshape(B, nk, bk, K, dv)

    def q_block(iq, q_blk):
        qpos = q_offset + iq * bq + jnp.arange(bq)

        # remat: score tiles are recomputed in backward — without this the
        # inner scan's linearization keeps every (bq×bk) p-tile alive
        @jax.checkpoint
        def kv_step(carry, inp):
            m_run, l_run, acc = carry
            ik, k_blk, v_blk = inp
            s = jnp.einsum("bqkgd,bxkd->bkgqx", q_blk, k_blk) * scale
            kpos = ik * bk + jnp.arange(bk)
            msk = _chunk_mask(qpos, kpos, causal, window)
            s = jnp.where(msk[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m_run, s.max(axis=-1))
            alpha = jnp.exp(m_run - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l_run * alpha + p.sum(-1)
            acc = acc * alpha[..., None] + jnp.einsum("bkgqx,bxkd->bkgqd", p, v_blk)
            return (m_new, l_new, acc), None

        m0 = jnp.full((B, K, g, bq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, K, g, bq), jnp.float32)
        a0 = jnp.zeros((B, K, g, bq, dv), jnp.float32)
        (m_f, l_f, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (jnp.arange(nk), kf.swapaxes(0, 1), vf.swapaxes(0, 1)),
            unroll=unroll)
        out = acc / jnp.maximum(l_f, 1e-30)[..., None]     # (B,K,g,bq,dv)
        return out.transpose(0, 3, 1, 2, 4)                 # (B,bq,K,g,dv)

    q_block = jax.checkpoint(q_block)
    outs = jax.lax.scan(
        lambda _, inp: (None, q_block(inp[0], inp[1])),
        None, (jnp.arange(nq), qf.swapaxes(0, 1)), unroll=unroll)[1]
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, S, H, dv)
    return out


def dispatch_flash(q, k, v, *, causal: bool = True, window: int = 0,
                   scale: Optional[float] = None, q_chunk: int = 512,
                   kv_chunk: int = 1024):
    """Mesh-aware attention dispatch (DESIGN.md §5):

      * no mesh / tests            -> plain flash_jax;
      * KV heads divide 'model'    -> head parallelism (sharding constraint,
        zero attention-interior collectives);
      * otherwise                  -> explicit shard_map SEQUENCE parallelism:
        q's sequence dim is split over 'model', K/V are broadcast to each
        shard, every device attends its own q rows.  The shard_map transpose
        turns dK/dV into psums (reduce-scatter-shaped), avoiding GSPMD's
        involuntary p-tile all-gathers in the flash backward.
    """
    from repro.common.pjit_utils import (_ambient_mesh, batch_axes, constrain,
                                         mesh_axis_sizes)
    from jax.sharding import PartitionSpec as P

    mesh = _ambient_mesh()
    if mesh is None:
        return flash_jax(q, k, v, causal=causal, window=window, scale=scale,
                         q_chunk=q_chunk, kv_chunk=kv_chunk)
    sizes = mesh_axis_sizes()
    msize = sizes.get("model", 1)
    dax = batch_axes()
    B, S, H, dq = q.shape
    K = k.shape[2]

    if msize == 1 or K % msize == 0:
        q = constrain(q, (dax, None, "model", None))
        k = constrain(k, (dax, None, "model", None))
        v = constrain(v, (dax, None, "model", None))
        return flash_jax(q, k, v, causal=causal, window=window, scale=scale,
                         q_chunk=q_chunk, kv_chunk=kv_chunk)

    local_S = S // msize
    d_sz = 1
    if dax is not None:
        names = dax if isinstance(dax, tuple) else (dax,)
        for n in names:
            d_sz *= sizes.get(n, 1)
    if S % msize or local_S < 1 or (dax is not None and B % d_sz):
        # fall back to batch parallelism (replicated over model)
        q = constrain(q, (dax, None, None, None))
        k = constrain(k, (dax, None, None, None))
        v = constrain(v, (dax, None, None, None))
        return flash_jax(q, k, v, causal=causal, window=window, scale=scale,
                         q_chunk=q_chunk, kv_chunk=kv_chunk)

    bq = min(q_chunk, local_S)

    def body(q_l, k_l, v_l):
        off = jax.lax.axis_index("model") * local_S
        return flash_jax(q_l, k_l, v_l, causal=causal, window=window,
                         scale=scale, q_chunk=bq, kv_chunk=kv_chunk,
                         q_offset=off)

    qs = P(dax, "model", None, None)
    kvs = P(dax, None, None, None)
    return _pjit_shard_map(body, mesh=mesh, in_specs=(qs, kvs, kvs),
                         out_specs=qs, check_vma=False)(q, k, v)


def mla_absorbed(q_nope, q_rope, c_kv, k_rope, w_kvb, *, num_heads: int,
                 nope_dim: int, v_dim: int, causal: bool = True,
                 window: int = 0, q_chunk: int = 512, kv_chunk: int = 1024):
    """Absorbed-MLA attention.

    q_nope: (B,S,H,nope), q_rope: (B,S,H,rope),
    c_kv: (B,T,kvr), k_rope: (B,T,rope),
    w_kvb: (kvr, H*(nope+v_dim)) — the kv up-projection whose K-part is
    absorbed into the query and V-part applied after attention.
    Returns (B,S,H,v_dim) fp32.
    """
    from repro.common.pjit_utils import (_ambient_mesh, batch_axes,
                                         mesh_axis_sizes)
    from jax.sharding import PartitionSpec as P

    B, S, H, _ = q_nope.shape
    T = c_kv.shape[1]
    kvr = c_kv.shape[-1]
    scale = 1.0 / math.sqrt(nope_dim + q_rope.shape[-1])

    def absorbed(qn, qr, ckv, kr, w_kvb_, q_offset=0, q_ck=q_chunk):
        w = w_kvb_.reshape(kvr, H, nope_dim + v_dim).astype(jnp.float32)
        w_k, w_v = w[..., :nope_dim], w[..., nope_dim:]
        # absorb K-projection into the query: (b,s,H,kvr)
        q_lat = jnp.einsum("bshn,khn->bshk", qn.astype(jnp.float32), w_k)
        # single "kv head" (MQA): key = [c_kv | k_rope], query = [q_lat | q_rope]
        q_eff = jnp.concatenate([q_lat, qr.astype(jnp.float32)], axis=-1)
        k_eff = jnp.concatenate([ckv, kr], axis=-1)[:, :, None, :].astype(jnp.float32)
        v_eff = ckv[:, :, None, :].astype(jnp.float32)
        out_lat = flash_jax(q_eff, k_eff, v_eff, causal=causal, window=window,
                            scale=scale, q_chunk=q_ck, kv_chunk=kv_chunk,
                            q_offset=q_offset)          # (b,s,H,kvr)
        return jnp.einsum("bshk,khv->bshv", out_lat, w_v)

    mesh = _ambient_mesh()
    if mesh is not None:
        sizes = mesh_axis_sizes()
        msize = sizes.get("model", 1)
        dax = batch_axes()
        d_sz = 1
        if dax is not None:
            for n in (dax if isinstance(dax, tuple) else (dax,)):
                d_sz *= sizes.get(n, 1)
        if msize > 1 and S % msize == 0 and (dax is None or B % d_sz == 0):
            # sequence-parallel: q stream (and its latent projection, the
            # memory hot spot) sharded over 'model'; compressed KV stream is
            # tiny and broadcast
            local_S = S // msize
            bq = min(q_chunk, local_S)

            def body(qn_l, qr_l, ckv_l, kr_l, w_l):
                off = jax.lax.axis_index("model") * local_S
                return absorbed(qn_l, qr_l, ckv_l, kr_l, w_l,
                                q_offset=off, q_ck=bq)

            qs = P(dax, "model", None, None)
            kvs = P(dax, None, None)
            return _pjit_shard_map(
                body, mesh=mesh,
                in_specs=(qs, qs, kvs, kvs, P(None, None)),
                out_specs=qs, check_vma=False,
            )(q_nope, q_rope, c_kv, k_rope, w_kvb)

    return absorbed(q_nope, q_rope, c_kv, k_rope, w_kvb)
