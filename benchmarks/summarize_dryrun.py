"""Summarize experiments/dryrun/*.json as the roofline table."""
import glob
import json
import os
import sys


def rows(dirpath="experiments/dryrun"):
    out = []
    for f in sorted(glob.glob(os.path.join(dirpath, "*.json"))):
        d = json.load(open(f))
        if "memory" not in d:       # e.g. server_aggregation records
            continue
        m = d["memory"]
        tot = (m["argument_bytes"] + m["temp_bytes"] + m["output_bytes"]) / 2**30
        r = d["roofline"]
        # MODEL_FLOPS: 6·N_active·D for training (fwd+bwd), 2·N_active·D for
        # inference; D = tokens processed this step
        mult = 6 if d["mode"] == "train" else 2
        model_flops = mult * d["active_params"] * _tokens(d)
        hlo_global = d["flops_per_device"] * d["chips"]
        out.append({
            "arch": d["arch"], "shape": d["shape"], "mesh": d["mesh"],
            "mem_gib": tot, "compile_s": d["compile_s"],
            "compute_s": r["compute_s"], "memory_s": r["memory_s"],
            "collective_s": r["collective_s"], "dominant": r["dominant"],
            "grad_accum": d.get("grad_accum", 1),
            "kv": d.get("kv_cache_dtype", "-"),
            "model_flops": model_flops,
            "useful_frac": model_flops / hlo_global if hlo_global else 0.0,
        })
    return out


def _tokens(d):
    # tokens processed per step (decode: one new token per sequence)
    from_shape = {"train_4k": 256 * 4096, "prefill_32k": 32 * 32768,
                  "decode_32k": 128, "long_500k": 1}
    return from_shape[d["shape"]]


def main():
    dirpath = sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun"
    rs = rows(dirpath)
    hdr = (f"{'arch':22s} {'shape':12s} {'mesh':8s} {'mem/dev':>9s} {'cmpl(s)':>8s} "
           f"{'compute_s':>10s} {'memory_s':>10s} {'coll_s':>10s} {'dominant':>12s} "
           f"{'ga':>3s} {'useful%':>8s}")
    print(hdr)
    print("-" * len(hdr))
    for r in rs:
        print(f"{r['arch']:22s} {r['shape']:12s} {r['mesh']:8s} "
              f"{r['mem_gib']:8.2f}G {r['compile_s']:8.1f} "
              f"{r['compute_s']:10.4f} {r['memory_s']:10.4f} "
              f"{r['collective_s']:10.4f} {r['dominant']:>12s} "
              f"{r['grad_accum']:3d} {100*r['useful_frac']:7.1f}%")


if __name__ == "__main__":
    main()
