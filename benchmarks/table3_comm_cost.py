"""Table 3: per-round communication cost (MB, FP16) on TinyLlama geometry
(22 layers, q/v projections, rank 16, 10 sampled clients) — exact analytic
parameter counts from our accounting, plus Full-FT reference.

Claims validated: download(FLoRIST) ≪ download(FLoRA) (paper: ~70×) and
≪ Full FT (paper: ~400×); upload identical for all two-adapter methods.

Each analytic figure is cross-checked against the bytes the measured wire
transport (bf16 codec = the paper's 2-byte accounting) actually serializes
for the same trees; the ``wire_matches_analytic`` flag in the output must
be True for every method."""
from __future__ import annotations

import jax.numpy as jnp

from benchmarks.common import emit
from repro.configs import get_config
from repro.core import costs as C
from repro.core.aggregators import leaf_dims, make_aggregator

L, D, R, K = 22, 2048, 16, 10       # TinyLlama: layers, d_model, rank, clients


def _client_tree(r):
    leaf = lambda: {"A": jnp.zeros((L, r, D)), "B": jnp.zeros((L, D, r)),
                    "scale": jnp.ones((L,))}
    return {"blocks": {0: {"attn": {"wq": leaf(), "wv": leaf()}}}}


def run(florist_p: int = 7):
    """florist_p: per-layer kept rank (paper's τ=0.9 implies ~7 avg on
    TinyLlama-Wizard: 5.15 MB / (2 proj · 22 L · 2·2048 · 2 B))."""
    cfg = get_config("tinyllama-1.1b")
    full_ft_mb = C.mb(cfg.param_count())
    trees = [_client_tree(R) for _ in range(K)]
    w = [1.0 / K] * K
    dims = leaf_dims(trees[0])

    rows = [{"name": "table3/full_ft", "us_per_call": "",
             "derived": f"upload_mb={full_ft_mb:.2f};download_mb={full_ft_mb:.2f}"}]
    out = {}
    for method, cfg_kw in [("fedit", {}), ("flora", {}),
                           ("flexlora", {}),
                           ("ffa", dict(A_init=trees[0])),
                           ("florist", dict(tau=1.0, max_rank=florist_p))]:
        # streaming server lifecycle: one client in memory at a time
        strat = make_aggregator(method, **cfg_kw)
        strat.begin_round(dims)
        for tree, wk in zip(trees, w):
            strat.add_client(tree, wk, rank=R)
        agg = strat.finalize()
        up = C.mb(strat.round_upload_params) / K               # per client
        down = C.mb(strat.download_params(agg, dims, 1, [R] * K))
        # measured wire bytes (bf16 = 2 B/param) must match the analytic
        # FP16 accounting exactly for the same trees
        wire_up = C.wire_mb(C.wire_upload_bytes(method, trees)) / K
        # flexlora's per-client wire sum equals its analytic K-tree total
        wire_down = C.wire_mb(C.wire_download_bytes(method, agg, 1))
        wire_ok = (abs(wire_up - up) < 1e-9 and abs(wire_down - down) < 1e-9)
        assert wire_ok, (method, wire_up, up, wire_down, down)
        out[method] = down
        rows.append({"name": f"table3/{method}", "us_per_call": "",
                     "derived": (f"upload_mb={up:.2f};download_mb={down:.2f};"
                                 f"wire_matches_analytic={wire_ok}")})
    rows.append({
        "name": "table3/ratios", "us_per_call": "",
        "derived": (f"flora_over_florist={out['flora']/out['florist']:.1f}x;"
                    f"fullft_over_florist={full_ft_mb/out['florist']:.1f}x"),
    })
    return rows


if __name__ == "__main__":
    emit(run())
