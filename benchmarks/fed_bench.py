"""Per-round federated runtime wall-clock: client runners × round schedulers.

The cohort runner is the client-side analogue of the batched server
pipeline: instead of K·steps jitted train-step dispatches per round (one
per client per batch, each with its own host→device transfer), every
equal-rank cohort trains in ONE compiled ``vmap``-of-``scan`` call.  This
measures what that dispatch collapse buys on the CPU smoke config, across
the sync and async schedulers.

Emits JSON for CI artifacts (the ``BENCH_fed.json`` trajectory)::

    PYTHONPATH=src python benchmarks/fed_bench.py --smoke --json BENCH_fed.json
"""
from __future__ import annotations

import argparse
import json
import statistics
import time

import jax

from repro.common.config import FedConfig, LoRAConfig, ModelConfig, OptimConfig
from repro.core.federated import FederatedTrainer
from repro.data.synthetic import make_eval_data

SMOKE_MODEL = ModelConfig(name="fedbench-tiny", family="dense", num_layers=2,
                          d_model=32, num_heads=2, num_kv_heads=1, head_dim=16,
                          d_ff=64, vocab_size=128, dtype="float32")
FULL_MODEL = ModelConfig(name="fedbench-small", family="dense", num_layers=4,
                         d_model=128, num_heads=4, num_kv_heads=2, head_dim=32,
                         d_ff=256, vocab_size=512, dtype="float32")


def make_trainer(cfg: ModelConfig, runner: str, scheduler: str, *,
                 clients: int, sample: int, local_steps: int,
                 batch_size: int, seq_len: int) -> FederatedTrainer:
    fed = FedConfig(num_clients=clients, clients_per_round=sample,
                    method="florist", tau=0.9, homogeneous_rank=8, seed=0)
    return FederatedTrainer(cfg, fed, LoRAConfig(rank=8, alpha=8.0),
                            OptimConfig(lr=3e-3), batch_size=batch_size,
                            local_steps=local_steps, seq_len=seq_len,
                            eval_data=make_eval_data(num_samples=32,
                                                     seq_len=seq_len,
                                                     vocab=cfg.vocab_size),
                            runner=runner, scheduler=scheduler)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small config + few iters (CI)")
    ap.add_argument("--json", default="", help="write results to this path")
    ap.add_argument("--iters", type=int, default=0)
    args = ap.parse_args()

    cfg = SMOKE_MODEL if args.smoke else FULL_MODEL
    clients, sample = (32, 16)
    # smoke: dispatch-dominated shapes — per-step compute is tiny, so the
    # sequential runner's 192 per-batch dispatches dominate and the cohort
    # collapse of them into one call per round shows its full effect
    local_steps = 12 if args.smoke else 8
    batch_size, seq_len = (2, 16) if args.smoke else (8, 32)
    iters = args.iters or 5
    warmup = 2          # round 1 compiles, round 2 hits any late shapes

    combos = [(runner, scheduler)
              for runner in ("sequential", "cohort")
              for scheduler in ("sync", "async")]
    trainers = {c: make_trainer(cfg, *c, clients=clients, sample=sample,
                                local_steps=local_steps,
                                batch_size=batch_size, seq_len=seq_len)
                for c in combos}
    rounds = {c: 0 for c in combos}
    for c in combos:
        for _ in range(warmup):
            trainers[c].run_round(rounds[c])
            rounds[c] += 1
    # interleave the combos round-robin so slow drift of the host (CI
    # machines throttle) hits every arm equally instead of biasing one
    samples = {c: [] for c in combos}
    for _ in range(iters):
        for c in combos:
            t0 = time.perf_counter()
            trainers[c].run_round(rounds[c])
            rounds[c] += 1
            samples[c].append((time.perf_counter() - t0) * 1e3)

    results = []
    for (runner, scheduler) in combos:
        ms = float(statistics.median(samples[(runner, scheduler)]))
        results.append({"runner": runner, "scheduler": scheduler,
                        "ms_per_round": round(ms, 3)})
        print(f"{runner:10s} {scheduler:7s} {ms:9.2f} ms/round")

    def best(runner):
        return min(r["ms_per_round"] for r in results
                   if r["runner"] == runner and r["scheduler"] == "sync")

    speedup = best("sequential") / best("cohort")
    print(f"speedup (cohort vs sequential, sync): {speedup:.2f}x")

    report = {
        "config": {"model": cfg.name, "num_clients": clients,
                   "clients_per_round": sample, "local_steps": local_steps,
                   "iters": iters, "smoke": bool(args.smoke),
                   "backend": jax.default_backend()},
        "results": results,
        "speedup_cohort_vs_sequential": round(speedup, 2),
    }
    if args.json:
        with open(args.json, "w") as f:
            json.dump(report, f, indent=2)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
