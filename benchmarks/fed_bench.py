"""Per-round federated runtime wall-clock: client runners × round schedulers.

The cohort runner is the client-side analogue of the batched server
pipeline: instead of K·steps jitted train-step dispatches per round (one
per client per batch, each with its own host→device transfer), every
equal-rank cohort trains in ONE compiled ``vmap``-of-``scan`` call.  This
measures what that dispatch collapse buys on the CPU smoke config, across
the sync and async schedulers, plus two extra axes:

* ``--smoke`` also sweeps the **utility-vs-ε DP curve**: the same smoke
  round with the transport's DP stage at decreasing privacy budgets
  (σ calibrated per ε by the classical Gaussian-mechanism bound), so the
  accuracy cost of DP-on-the-wire is a watched trajectory, not folklore;
* ``--scale`` runs the **population-scale arm**: 1024 clients, a sampled
  participation fraction, and the ``sharded_cohort`` runner against the
  single-device ``cohort`` and legacy ``sequential`` runners.  Run it
  under ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` to measure
  the mesh-sharded round (the ``sharded_vs_cohort`` ratio only shows real
  speedup when the virtual devices map to real cores).

* ``--faults`` measures the **fault-tolerance arm**: the full hardened
  path (per-block checksums + the screening validation gate) against the
  same rounds with both disabled — the ``overhead_hardened_vs_off`` ratio
  is what the ``fed_faults`` bench-gate suite holds to ≤5% — plus a
  poison-containment probe (20% NaN/scale clients against the ``full``
  gate) whose quarantine recall is watched too.

Emits JSON for CI artifacts (the ``BENCH_fed.json`` /
``BENCH_fed_scale.json`` / ``BENCH_fed_faults.json`` trajectories)::

    PYTHONPATH=src python benchmarks/fed_bench.py --smoke --json BENCH_fed.json
    PYTHONPATH=src python benchmarks/fed_bench.py --scale --json BENCH_fed_scale.json
    PYTHONPATH=src python benchmarks/fed_bench.py --faults --json BENCH_fed_faults.json
"""
from __future__ import annotations

import argparse
import json
import statistics
import time

import jax

from repro.common.config import FedConfig, LoRAConfig, ModelConfig, OptimConfig
from repro.core.aggregators.florist import FloristAggregator
from repro.core.federated import FederatedTrainer
from repro.core.privacy import noise_multiplier_for_epsilon
from repro.core.runtime import (FaultPlan, SampledScheduler,
                                ShardedCohortRunner, Transport,
                                ValidationGate)
from repro.data.synthetic import make_eval_data, make_federated_data

SMOKE_MODEL = ModelConfig(name="fedbench-tiny", family="dense", num_layers=2,
                          d_model=32, num_heads=2, num_kv_heads=1, head_dim=16,
                          d_ff=64, vocab_size=128, dtype="float32")
FULL_MODEL = ModelConfig(name="fedbench-small", family="dense", num_layers=4,
                         d_model=128, num_heads=4, num_kv_heads=2, head_dim=32,
                         d_ff=256, vocab_size=512, dtype="float32")


def make_trainer(cfg: ModelConfig, runner, scheduler, *,
                 clients: int, sample: int, local_steps: int,
                 batch_size: int, seq_len: int, **kw) -> FederatedTrainer:
    fed = FedConfig(num_clients=clients, clients_per_round=sample,
                    method="florist", tau=0.9, homogeneous_rank=8, seed=0)
    data = kw.pop("clients_data", None)    # pre-built population, if shared
    return FederatedTrainer(cfg, fed, LoRAConfig(rank=8, alpha=8.0),
                            OptimConfig(lr=3e-3), clients=data,
                            batch_size=batch_size,
                            local_steps=local_steps, seq_len=seq_len,
                            eval_data=make_eval_data(num_samples=32,
                                                     seq_len=seq_len,
                                                     vocab=cfg.vocab_size),
                            runner=runner, scheduler=scheduler, **kw)


def dp_axis(cfg: ModelConfig, *, clients: int, sample: int, local_steps: int,
            batch_size: int, seq_len: int, rounds: int = 3) -> dict:
    """Utility-vs-ε: final smoke eval loss as the per-round privacy budget
    tightens (σ = classical Gaussian calibration for ε at δ=1e-5)."""
    curve = []
    for eps in (None, 8.0, 2.0, 0.5):
        sigma = 0.0 if eps is None else noise_multiplier_for_epsilon(eps)
        tr = make_trainer(cfg, "cohort", "sync", clients=clients,
                          sample=sample, local_steps=local_steps,
                          batch_size=batch_size, seq_len=seq_len,
                          dp_clip=0.0 if eps is None else 1.0,
                          dp_sigma=sigma)
        loss = tr.run(rounds)[-1].eval_loss
        curve.append({"epsilon": eps, "sigma": round(sigma, 4),
                      "eval_loss": round(loss, 5)})
        tag = "inf" if eps is None else f"{eps:g}"
        print(f"dp eps={tag:>4s} sigma={sigma:6.3f} loss={loss:.4f}")
    ref = curve[0]["eval_loss"]
    tightest = curve[-1]["eval_loss"]
    return {"curve": curve,
            # utility cost of the tightest budget, as a machine-invariant
            # ratio (deterministic seeds: shifts mean the CODE changed)
            "loss_ratio_tightest_eps": round(tightest / ref, 4)}


def scale_axis(iters: int) -> dict:
    """1024-client rounds: sampled participation + the three runners.

    ``sharded_cohort`` shards the cohort's client axis over the fed mesh's
    ``data`` axis; with N real devices each compiled call trains 1/N of the
    cohort per device.  ``peak_live_clients`` / ``peak_pending_blocks``
    assert the O(cohort) memory claim on both sides of the wire.
    """
    cfg = ModelConfig(name="fedbench-nano", family="dense", num_layers=1,
                      d_model=32, num_heads=2, num_kv_heads=1, head_dim=16,
                      d_ff=64, vocab_size=128, dtype="float32")
    clients, participants, local_steps = 1024, 64, 2
    batch_size, seq_len = 2, 16
    data = make_federated_data(num_clients=clients, mean_samples=6,
                               seq_len=seq_len, vocab=cfg.vocab_size, seed=0)
    arms = {"sequential": "sequential", "cohort": "cohort",
            "sharded_cohort": ShardedCohortRunner(block=participants)}
    results, trainers = [], {}
    for name, runner in arms.items():
        agg = FloristAggregator(tau=0.9, svd_method="svd", stream="auto",
                                flush_every=participants)
        tr = make_trainer(cfg, runner,
                          SampledScheduler(fraction=participants / clients),
                          clients=clients, sample=participants,
                          local_steps=local_steps, batch_size=batch_size,
                          seq_len=seq_len, aggregator=agg, clients_data=data)
        trainers[name] = tr
        rnd = 0
        tr.run_round(rnd)                      # warmup/compile round
        rnd += 1
        samples = []
        for _ in range(iters):
            t0 = time.perf_counter()
            tr.run_round(rnd)
            rnd += 1
            samples.append(time.perf_counter() - t0)
        sec = float(statistics.median(samples))
        results.append({"runner": name, "ms_per_round": round(sec * 1e3, 3),
                        "rounds_per_sec": round(1.0 / sec, 4)})
        print(f"scale {name:15s} {sec * 1e3:9.2f} ms/round "
              f"({1.0 / sec:.3f} rounds/s)")

    by = {r["runner"]: r["ms_per_round"] for r in results}
    sharded = trainers["sharded_cohort"]
    return {
        "config": {"model": cfg.name, "num_clients": clients,
                   "participants": participants, "local_steps": local_steps,
                   "mesh_devices": jax.device_count()},
        "results": results,
        "speedup_sharded_vs_sequential":
            round(by["sequential"] / by["sharded_cohort"], 2),
        "speedup_sharded_vs_cohort":
            round(by["cohort"] / by["sharded_cohort"], 2),
        "peak_live_clients": sharded.runner.peak_live_clients,
        "peak_pending_blocks": sharded.aggregator.peak_pending_blocks,
    }


def faults_axis(iters: int) -> dict:
    """Fault-tolerance overhead + containment on the smoke config.

    *Overhead*: identical clean rounds through (a) the fully hardened path
    — per-block CRC-32 checksums verified at unpack plus the streaming
    ``screen`` validation gate — and (b) both disabled (the pre-PR-10
    path).  Interleaved round-robin timing, median ratio; the ``fed_faults``
    gate holds the ratio to ≤5% overhead.

    *Containment*: 20% of clients poisoned (NaN/Inf or 100×-scaled deltas)
    against the buffering ``full`` gate; recall = caught / injected over
    the measured rounds.
    """
    cfg = SMOKE_MODEL
    clients, sample, local_steps = 32, 16, 12
    batch_size, seq_len = 2, 16
    arms = {
        "off": dict(validation="off",
                    transport=Transport("fp32", checksums=False)),
        "hardened": dict(validation="screen"),
    }
    trainers = {name: make_trainer(cfg, "cohort", "sync", clients=clients,
                                   sample=sample, local_steps=local_steps,
                                   batch_size=batch_size, seq_len=seq_len,
                                   **kw)
                for name, kw in arms.items()}
    rounds = {name: 0 for name in arms}
    # long warmup: FLoRIST's global rank drifts over the first rounds and
    # each new rank recompiles the eval step — time only the steady state
    for name in arms:
        for _ in range(5):
            trainers[name].run_round(rounds[name])
            rounds[name] += 1
    samples = {name: [] for name in arms}
    order = list(arms)
    for it in range(iters):
        # alternate which arm goes first: the leading arm of a pair absorbs
        # any deferred host work from the previous pair
        for name in (order if it % 2 == 0 else order[::-1]):
            t0 = time.perf_counter()
            trainers[name].run_round(rounds[name])
            rounds[name] += 1
            samples[name].append((time.perf_counter() - t0) * 1e3)
    ms = {name: float(statistics.median(s)) for name, s in samples.items()}
    overhead = ms["hardened"] / ms["off"]
    for name in arms:
        print(f"faults {name:9s} {ms[name]:9.2f} ms/round")
    print(f"hardened/off overhead: {overhead:.3f}x")

    # containment probe: poisoned clients must be caught by the full gate
    plan = FaultPlan(seed=7, nan=0.1, scale=0.1)
    tr = make_trainer(cfg, "cohort", "sync", clients=clients, sample=sample,
                      local_steps=2, batch_size=batch_size, seq_len=seq_len,
                      faults=plan, validation=ValidationGate("full"))
    plans = []
    orig_plan = tr.scheduler.plan
    tr.scheduler.plan = lambda rnd, ctx: plans.append(orig_plan(rnd, ctx)) \
        or plans[-1]
    probe_rounds = 3
    hist = tr.run(probe_rounds)
    injected = sum(1 for p in plans for t in p.tasks
                   if plan.client_fault(p.round, t.client_id).kind
                   in ("nan", "scale"))
    caught = sum(r.rejected + r.quarantined for r in hist)
    recall = (caught / injected) if injected else 1.0
    print(f"poison containment: {caught}/{injected} caught "
          f"(recall {recall:.2f})")
    return {
        "config": {"model": cfg.name, "num_clients": clients,
                   "clients_per_round": sample, "local_steps": local_steps,
                   "iters": iters, "backend": jax.default_backend()},
        "results": [{"arm": name, "ms_per_round": round(v, 3)}
                    for name, v in ms.items()],
        "overhead_hardened_vs_off": round(overhead, 4),
        "poison_injected": injected,
        "poison_caught": caught,
        "poison_quarantine_recall": round(recall, 4),
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small config + few iters (CI)")
    ap.add_argument("--scale", action="store_true",
                    help="1024-client sampled + sharded_cohort arm only")
    ap.add_argument("--faults", action="store_true",
                    help="hardened-path overhead + poison containment arm")
    ap.add_argument("--json", default="", help="write results to this path")
    ap.add_argument("--iters", type=int, default=0)
    args = ap.parse_args()

    if args.faults:
        report = faults_axis(args.iters or 5)
        if args.json:
            with open(args.json, "w") as f:
                json.dump(report, f, indent=2)
            print(f"wrote {args.json}")
        return

    if args.scale:
        report = scale_axis(args.iters or 3)
        report["config"]["backend"] = jax.default_backend()
        print(f"speedup (sharded_cohort vs sequential): "
              f"{report['speedup_sharded_vs_sequential']:.2f}x")
        print(f"speedup (sharded_cohort vs cohort, mesh "
              f"{report['config']['mesh_devices']}): "
              f"{report['speedup_sharded_vs_cohort']:.2f}x")
        if args.json:
            with open(args.json, "w") as f:
                json.dump(report, f, indent=2)
            print(f"wrote {args.json}")
        return

    cfg = SMOKE_MODEL if args.smoke else FULL_MODEL
    clients, sample = (32, 16)
    # smoke: dispatch-dominated shapes — per-step compute is tiny, so the
    # sequential runner's 192 per-batch dispatches dominate and the cohort
    # collapse of them into one call per round shows its full effect
    local_steps = 12 if args.smoke else 8
    batch_size, seq_len = (2, 16) if args.smoke else (8, 32)
    iters = args.iters or 5
    warmup = 2          # round 1 compiles, round 2 hits any late shapes

    combos = [(runner, scheduler)
              for runner in ("sequential", "cohort")
              for scheduler in ("sync", "async")]
    trainers = {c: make_trainer(cfg, *c, clients=clients, sample=sample,
                                local_steps=local_steps,
                                batch_size=batch_size, seq_len=seq_len)
                for c in combos}
    rounds = {c: 0 for c in combos}
    for c in combos:
        for _ in range(warmup):
            trainers[c].run_round(rounds[c])
            rounds[c] += 1
    # interleave the combos round-robin so slow drift of the host (CI
    # machines throttle) hits every arm equally instead of biasing one
    samples = {c: [] for c in combos}
    for _ in range(iters):
        for c in combos:
            t0 = time.perf_counter()
            trainers[c].run_round(rounds[c])
            rounds[c] += 1
            samples[c].append((time.perf_counter() - t0) * 1e3)

    results = []
    for (runner, scheduler) in combos:
        ms = float(statistics.median(samples[(runner, scheduler)]))
        results.append({"runner": runner, "scheduler": scheduler,
                        "ms_per_round": round(ms, 3)})
        print(f"{runner:10s} {scheduler:7s} {ms:9.2f} ms/round")

    def best(runner):
        return min(r["ms_per_round"] for r in results
                   if r["runner"] == runner and r["scheduler"] == "sync")

    speedup = best("sequential") / best("cohort")
    print(f"speedup (cohort vs sequential, sync): {speedup:.2f}x")

    report = {
        "config": {"model": cfg.name, "num_clients": clients,
                   "clients_per_round": sample, "local_steps": local_steps,
                   "iters": iters, "smoke": bool(args.smoke),
                   "backend": jax.default_backend()},
        "results": results,
        "speedup_cohort_vs_sequential": round(speedup, 2),
        "dp_axis": dp_axis(cfg, clients=clients, sample=sample,
                           local_steps=local_steps, batch_size=batch_size,
                           seq_len=seq_len),
    }
    if args.json:
        with open(args.json, "w") as f:
            json.dump(report, f, indent=2)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
