"""Bench-regression gate: fresh BENCH_*.json vs the committed trajectory.

Three benchmark suites emit JSON reports in CI; this gate is what finally
watches them.  It compares a freshly produced report against the committed
baseline under ``benchmarks/baselines/`` and FAILS (exit 1) when a watched
metric regresses by more than ``--tol`` (default 15%).

Watched metrics are machine-speed-invariant RATIOS (speedups, arm-to-arm
slowdowns) rather than absolute tok/s or wall seconds — a slower CI runner
scales both arms of a ratio equally, so a >15% ratio regression means the
CODE got slower (a tok/s or round-time regression of the optimized arm
relative to its in-run baseline arm), not the machine.  Trace counts are
compared exactly: a single extra compile in the serving hot loop is a
regression no tolerance should absorb.

Regenerate baselines intentionally (after an accepted perf change)::

    PYTHONPATH=src python benchmarks/serve_bench.py --smoke \
        --json benchmarks/baselines/BENCH_serve.json

Usage (CI)::

    python benchmarks/bench_gate.py --suite serve --fresh BENCH_serve.json
"""
from __future__ import annotations

import argparse
import json
import os
import sys

# suite -> [(dotted metric path, direction[, tol])]; "higher" = bigger is
# better; an entry's own tol (fraction) overrides the CLI --tol for metrics
# whose run-to-run noise is wider than the suite default
WATCHED = {
    "serve": [
        ("speedup_jit_vs_eager", "higher"),
        ("speedup_chunked_vs_width1", "higher"),
        ("decode_impl_axis.speedup_streamed_vs_dense", "higher"),
        ("multi_adapter_axis.slowdown_32_vs_1", "lower"),
        ("mesh_axis.slowdown_sharded_vs_single", "lower"),
    ],
    "fed": [
        ("speedup_cohort_vs_sequential", "higher"),
        # DP utility cost is deterministic (seeded noise keys): a drift in
        # the ratio means the mechanism or the training path changed, but
        # small code-level reorderings legitimately move it, hence the
        # wide tol
        ("dp_axis.loss_ratio_tightest_eps", "lower", 0.5),
    ],
    # 1024-client arm (CI runs it at 8 forced host devices).  The
    # sharded-vs-sequential ratio inherits the dispatch collapse and is
    # robust on any machine; sharded-vs-cohort only shows real speedup when
    # the mesh devices map to real cores, so its wide tol puts the floor
    # below 1.0 — the gate then catches a missing metric or a broken
    # sharded path, never a core-starved runner
    "fed_scale": [
        ("speedup_sharded_vs_sequential", "higher"),
        ("speedup_sharded_vs_cohort", "higher", 0.5),
        ("peak_live_clients", "lower", 0.0),
        ("peak_pending_blocks", "lower", 0.0),
    ],
    # fault-tolerance arm: the hardened/off round-time ratio is ~1.0 by
    # construction, so the per-entry 5% tol IS the ISSUE's overhead bound
    # (checksums + validation must stay within 5% of a round); quarantine
    # recall is deterministic (seeded fault plan) and must not drop
    "fed_faults": [
        ("overhead_hardened_vs_off", "lower", 0.05),
        ("poison_quarantine_recall", "higher", 0.0),
    ],
    "kernels": [
        ("decode.speedup_streamed_vs_dense_fp32", "higher"),
        ("decode.speedup_streamed_vs_dense_int8", "higher"),
    ],
    "agg": [
        ("speedup_batched_vs_loop", "higher"),
    ],
    # winner-vs-BASE speedups are >= 1 by construction (BASE is in the swept
    # set) but their magnitude is timing-noise on CPU runners, so the wide
    # tol puts the floor below 1.0: the gate then catches a missing metric
    # or a broken sweep, never a noisy margin
    "xla_flags": [
        ("topologies.mesh_1.speedup_winner_vs_base", "higher", 0.5),
        ("topologies.mesh_2.speedup_winner_vs_base", "higher", 0.5),
    ],
}

# suite -> dotted paths of {arm: {trace_key: count}} dicts compared exactly
TRACE_PATHS = {
    "serve": ["trace_counts",
              "multi_adapter_axis.adapters_1.trace_counts",
              "multi_adapter_axis.adapters_8.trace_counts",
              "multi_adapter_axis.adapters_32.trace_counts",
              "mesh_axis.sharded.trace_counts"],
}

DEFAULT_BASELINE = {
    "serve": "BENCH_serve.json",
    "fed": "BENCH_fed.json",
    "fed_scale": "BENCH_fed_scale.json",
    "fed_faults": "BENCH_fed_faults.json",
    "kernels": "BENCH_kernels.json",
    "agg": "agg_bench.json",
    "xla_flags": "BENCH_xla_flags.json",
}


def _get(report, dotted):
    node = report
    for k in dotted.split("."):
        if not isinstance(node, dict) or k not in node:
            return None
        node = node[k]
    return node


def _trace_total(node):
    """Sum of all integer trace counts in an {arm: {key: n}} subtree."""
    if isinstance(node, bool):
        return 0
    if isinstance(node, int):
        return node
    if isinstance(node, dict):
        return sum(_trace_total(v) for v in node.values())
    return 0


def check(suite: str, fresh: dict, baseline: dict, cli_tol: float):
    failures, checked = [], 0
    for entry in WATCHED[suite]:
        path, direction = entry[0], entry[1]
        tol = entry[2] if len(entry) > 2 else cli_tol
        base = _get(baseline, path)
        new = _get(fresh, path)
        if base is None:
            print(f"  ~ {path}: not in baseline, skipped "
                  "(regenerate baselines to start watching it)")
            continue
        if new is None:
            failures.append(f"{path}: present in baseline but MISSING from "
                            "the fresh report")
            continue
        checked += 1
        if direction == "higher":
            ok = new >= base * (1.0 - tol)
            verdict = f"{new} vs baseline {base} (floor {base * (1 - tol):.3f})"
        else:
            ok = new <= base * (1.0 + tol)
            verdict = f"{new} vs baseline {base} (ceiling {base * (1 + tol):.3f})"
        mark = "ok" if ok else "REGRESSED"
        print(f"  {'+' if ok else '!'} {path} [{direction}]: {verdict} -> {mark}")
        if not ok:
            failures.append(f"{path}: {verdict}")

    for path in TRACE_PATHS.get(suite, []):
        base = _trace_total(_get(baseline, path))
        new = _trace_total(_get(fresh, path))
        if base == 0 and new == 0:
            continue
        checked += 1
        ok = new <= base
        print(f"  {'+' if ok else '!'} {path} trace total: {new} vs "
              f"baseline {base} -> {'ok' if ok else 'RETRACE REGRESSION'}")
        if not ok:
            failures.append(f"{path}: trace count grew {base} -> {new} "
                            "(a new compile in the hot loop)")
    return failures, checked


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--suite", required=True, choices=sorted(WATCHED),
                    help="which benchmark report to gate")
    ap.add_argument("--fresh", required=True,
                    help="freshly produced report JSON")
    ap.add_argument("--baseline", default="",
                    help="committed baseline JSON (default: "
                         "benchmarks/baselines/<suite file>)")
    ap.add_argument("--tol", type=float, default=0.15,
                    help="allowed fractional regression on ratio metrics")
    args = ap.parse_args()

    baseline_path = args.baseline or os.path.join(
        os.path.dirname(os.path.abspath(__file__)), "baselines",
        DEFAULT_BASELINE[args.suite])
    if not os.path.exists(baseline_path):
        print(f"bench_gate: no committed baseline at {baseline_path} — "
              "commit one (see module docstring) so the trajectory is watched")
        return 1
    with open(args.fresh) as f:
        fresh = json.load(f)
    with open(baseline_path) as f:
        baseline = json.load(f)

    print(f"bench_gate[{args.suite}]: {args.fresh} vs {baseline_path} "
          f"(tol {args.tol:.0%})")
    failures, checked = check(args.suite, fresh, baseline, args.tol)
    if failures:
        print(f"bench_gate[{args.suite}]: {len(failures)} regression(s):")
        for msg in failures:
            print(f"  - {msg}")
        return 1
    print(f"bench_gate[{args.suite}]: {checked} metric(s) within tolerance")
    return 0


if __name__ == "__main__":
    sys.exit(main())
