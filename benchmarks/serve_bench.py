"""Serving-engine throughput: eager per-token loop vs the jitted engine step.

Arms over the same continuous-batching workload:

  * ``eager``      — the seed ServeEngine loop: one token per engine step,
                     per-row host-side sampling (eager argmax + int() sync),
                     a B+1-way key split every step;
  * ``jit_chunk1`` — the jitted engine step, chunked prefill OFF (width 1);
  * ``jit_chunkN`` — the jitted engine step with chunked prefill (whole
                     prompt chunks through the cached sequence path);
  * ``jit_chunkN_streamed`` — the same engine with ``decode_impl=
                     "streamed"`` (ring-flash-decode: online softmax over kv
                     blocks, no dense (B,H,C,cap) scores / (B,C,cap) mask).

The report's ``decode_impl`` axis compares the streamed hot loop against
the dense oracle (``speedup_streamed_vs_dense`` — must not regress).  Also
verifies every jitted arm compiles ONCE per executable (no per-step
retraces after warmup).

The ``multi_adapter`` axis serves the same workload through the
multi-tenant registry (``repro.serve.adapters``) with 1 / 8 / 32 live
adapters of mixed ranks, requests round-robining across them; it reports
per-arm tok/s, the 32-vs-1 slowdown ratio, and the trace counts — with a
registry hot-swap between the warmup and timed passes to prove adapter
churn causes zero retraces.  Emits JSON for CI artifacts::

    PYTHONPATH=src python benchmarks/serve_bench.py --smoke --json BENCH_serve.json
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.config import ModelConfig
from repro.models import transformer as T
from repro.serve.engine import SamplingParams, ServeEngine
from repro.train.step import make_serve_step

SMOKE_MODEL = ModelConfig(name="servebench-tiny", family="dense", num_layers=2,
                          d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
                          d_ff=128, vocab_size=256, dtype="float32")
FULL_MODEL = ModelConfig(name="servebench-small", family="dense", num_layers=4,
                         d_model=128, num_heads=8, num_kv_heads=4, head_dim=16,
                         d_ff=256, vocab_size=512, dtype="float32")


def _seed_sample_logits(logits, params, key):
    """The seed engine's per-row sampler, verbatim: python-branching eager
    ops (each one a separate dispatch) per slot per token."""
    if params.temperature <= 0.0:
        return jnp.argmax(logits)
    logits = logits / params.temperature
    if params.top_k:
        kth = jax.lax.top_k(logits, params.top_k)[0][-1]
        logits = jnp.where(logits < kth, -jnp.inf, logits)
    if params.top_p < 1.0:
        sorted_logits = jnp.sort(logits)[::-1]
        probs = jax.nn.softmax(sorted_logits)
        cum = jnp.cumsum(probs)
        cutoff_idx = jnp.searchsorted(cum, params.top_p, side="left")
        cutoff = sorted_logits[jnp.minimum(cutoff_idx, logits.shape[0] - 1)]
        logits = jnp.where(logits < cutoff, -jnp.inf, logits)
    return jax.random.categorical(key, logits)


class EagerLoop:
    """The seed engine's hot loop, kept as the measured baseline: single
    jitted model step per TOKEN, host-side per-row sampling, eager key
    splits — everything the jitted engine step collapses on-device."""

    def __init__(self, cfg, params, batch_slots, capacity, seed=0):
        self.cfg, self.params = cfg, params
        self.B = batch_slots
        self.key = jax.random.PRNGKey(seed)
        self.cache = T.init_cache(cfg, batch_slots, capacity, jnp.dtype(cfg.dtype))
        self._step = jax.jit(make_serve_step(cfg))
        self.slots = [None] * batch_slots
        self._pending = []
        self._last = np.zeros((batch_slots, 1), np.int32)
        self._left = {}

    def submit(self, prompt, params):
        self._pending.append([len(self._pending) + 1, list(prompt), params, []])
        return self._pending[-1][0]

    def run(self, max_steps=10000):
        results = {}
        for _ in range(max_steps):
            for i in range(self.B):
                if self.slots[i] is None and self._pending:
                    req = self._pending.pop(0)
                    self.slots[i] = req
                    self._left[i] = list(req[1])
            if all(s is None for s in self.slots) and not self._pending:
                break
            toks = self._last.copy()
            feeding = [False] * self.B
            for i, req in enumerate(self.slots):
                if req is None:
                    toks[i, 0] = 0
                elif self._left.get(i):
                    toks[i, 0] = self._left[i].pop(0)
                    feeding[i] = True
            logits, self.cache = self._step(self.params, None, self.cache,
                                            {"tokens": jnp.asarray(toks)})
            self.key, *keys = jax.random.split(self.key, self.B + 1)
            for i, req in enumerate(self.slots):
                if req is None or (feeding[i] and self._left.get(i)):
                    continue
                tok = int(_seed_sample_logits(logits[i], req[2], keys[i]))
                req[3].append(tok)
                self._last[i, 0] = tok
                if len(req[3]) >= req[2].max_tokens:
                    results[req[0]] = req[3]
                    self.slots[i] = None
        return results


def workload(engine, n_req, prompt_len, gen, rng, adapter_ids=None):
    # temperature sampling: the production path (the seed loop pays ~8 eager
    # dispatches + a host sync per slot per token here; the jitted step pays
    # zero extra — sampling compiles into the engine step)
    sp = SamplingParams(temperature=0.8, top_k=20, top_p=0.95, max_tokens=gen)
    uids = []
    for r in range(n_req):
        p = rng.integers(1, engine.cfg.vocab_size, prompt_len).tolist()
        if adapter_ids:
            uids.append(engine.submit(p, sp,
                                      adapter_id=adapter_ids[r % len(adapter_ids)]))
        else:
            uids.append(engine.submit(p, sp))
    t0 = time.perf_counter()
    out = engine.run()
    dt = time.perf_counter() - t0
    total = sum(len(out[u]) for u in uids)
    return dt, total


def multi_adapter_axis(cfg, params, args, gen, capacity, rng):
    """1 / 8 / 32 live mixed-rank adapters through ONE engine each: tok/s
    per arm + trace counts, with a hot-swap between warmup and the timed
    pass to prove registry churn never retraces."""
    from repro.configs import lora_targets
    from repro.peft.lora import init_lora
    from repro.serve.adapters import AdapterRegistry

    key = jax.random.PRNGKey(7)
    template = init_lora(params, lora_targets(cfg), 4, 8.0, key)
    ranks = [4, 8, 2, 6]
    axis = {}
    for n_ad in (1, 8, 32):
        reg = AdapterRegistry(template, page_rank=4, num_pages=2 * n_ad + 6,
                              max_adapters=n_ad + 3, max_rank=8)
        ids = [reg.register(
            f"t{j}", init_lora(params, lora_targets(cfg), ranks[j % len(ranks)],
                               8.0, jax.random.fold_in(key, j)))
            for j in range(n_ad)]
        eng = ServeEngine(cfg, params, batch_slots=args.slots,
                          capacity=capacity, prefill_chunk=args.chunk,
                          registry=reg)
        dt, total = workload(eng, args.requests, args.prompt_len, gen, rng,
                             adapter_ids=ids)
        warm_traces = dict(eng.trace_counts)
        # registry churn between passes: the timed pass runs against swapped
        # pool contents with the SAME executables
        ids[0] = reg.swap("t0", init_lora(params, lora_targets(cfg), 8, 8.0,
                                          jax.random.fold_in(key, 999)))
        dt2, _ = workload(eng, args.requests, args.prompt_len, gen, rng,
                          adapter_ids=ids)
        assert dict(eng.trace_counts) == warm_traces, (
            f"multi_adapter[{n_ad}]: registry churn retraced "
            f"({warm_traces} -> {dict(eng.trace_counts)})")
        dt = min(dt, dt2)
        axis[f"adapters_{n_ad}"] = {
            "wall_s": round(dt, 4), "tokens": total,
            "tok_per_s": round(total / dt, 2),
            "live_adapters": n_ad,
            "ranks": [ranks[j % len(ranks)] for j in range(min(n_ad, 4))],
            "trace_counts": {str(k): v for k, v in warm_traces.items()},
        }
        print(f"multi_adapter[{n_ad:2d}]     {total:5d} tokens in {dt:7.3f}s "
              f"({total / dt:8.1f} tok/s)")
    t1 = axis["adapters_1"]["tok_per_s"]
    t32 = axis["adapters_32"]["tok_per_s"]
    axis["slowdown_32_vs_1"] = round(t1 / t32, 2)
    axis["retraces_stable_under_churn"] = True
    print(f"multi-adapter slowdown (32 vs 1 live): {t1 / t32:.2f}x")
    return axis


def _mesh_worker(args, cfg, gen, capacity, rng) -> None:
    """One mesh-sharded measurement: this process was started with
    ``--xla_force_host_platform_device_count`` already in its env (XLA
    reads it at backend init, so it cannot be set in-process here)."""
    from repro.launch.dryrun import collective_bytes
    from repro.topology import make_serve_mesh

    params = T.init(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, batch_slots=args.slots, capacity=capacity,
                      prefill_chunk=args.chunk, decode_impl="streamed",
                      mesh=make_serve_mesh(args.mesh_worker))
    dt, total = workload(eng, args.requests, args.prompt_len, gen, rng)
    before = dict(eng.trace_counts)
    dt2, _ = workload(eng, args.requests, args.prompt_len, gen, rng)
    assert dict(eng.trace_counts) == before, (
        f"mesh_axis[{args.mesh_worker}]: retraced after warmup "
        f"({before} -> {dict(eng.trace_counts)})")
    dt = min(dt, dt2)
    totals = collective_bytes(eng.lower_step(width=1).compile().as_text())
    print(json.dumps({
        "devices": len(jax.devices()),
        "wall_s": round(dt, 4), "tokens": total,
        "tok_per_s": round(total / dt, 2),
        "trace_counts": {str(k): v for k, v in before.items()},
        "collective_bytes_per_step": {k: v for k, v in totals.items() if v},
    }))


def mesh_axis(args, gen):
    """Same streamed workload on a (data=1, model=N) mesh, 1 vs 2 forced
    host devices, each in a fresh subprocess: tok/s, per-step collective
    bytes from the compiled step, and trace counts for the gate."""
    from repro.common.xla_env import merge_flags

    axis = {}
    for name, n in (("single", 1), ("sharded", 2)):
        env = dict(os.environ)
        env["XLA_FLAGS"] = merge_flags(
            os.environ.get("XLA_FLAGS", ""),
            f"--xla_force_host_platform_device_count={n}")
        env.setdefault("JAX_PLATFORMS", "cpu")
        cmd = [sys.executable, os.path.abspath(__file__),
               "--mesh-worker", str(n), "--slots", str(args.slots),
               "--requests", str(args.requests),
               "--prompt-len", str(args.prompt_len),
               "--gen", str(gen), "--chunk", str(args.chunk)]
        if args.smoke:
            cmd.append("--smoke")
        proc = subprocess.run(cmd, env=env, capture_output=True, text=True,
                              timeout=900)
        if proc.returncode != 0:
            print(proc.stdout, proc.stderr, file=sys.stderr)
            raise RuntimeError(f"mesh_axis worker (devices={n}) failed")
        axis[name] = json.loads(proc.stdout.strip().splitlines()[-1])
        print(f"mesh_axis[{name:7s}] {axis[name]['tokens']:5d} tokens in "
              f"{axis[name]['wall_s']:7.3f}s ({axis[name]['tok_per_s']:8.1f} "
              f"tok/s) collectives={axis[name]['collective_bytes_per_step']}")
    axis["model_axis"] = 2
    axis["slowdown_sharded_vs_single"] = round(
        axis["single"]["tok_per_s"] / axis["sharded"]["tok_per_s"], 2)
    print(f"mesh-axis slowdown (2-device model-sharded vs 1): "
          f"{axis['slowdown_sharded_vs_single']:.2f}x")
    return axis


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small config + few iters (CI)")
    ap.add_argument("--json", default="", help="write results to this path")
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=64,
                    help="serving-realistic prompts: prefill dominates the "
                         "step count unless it is chunked")
    ap.add_argument("--gen", type=int, default=0)
    ap.add_argument("--chunk", type=int, default=16)
    ap.add_argument("--mesh-worker", type=int, default=0,
                    help=argparse.SUPPRESS)
    args = ap.parse_args()

    cfg = SMOKE_MODEL if args.smoke else FULL_MODEL
    gen = args.gen or (32 if args.smoke else 48)
    capacity = args.prompt_len + gen + 8
    rng = np.random.default_rng(0)

    if args.mesh_worker:
        _mesh_worker(args, cfg, gen, capacity, rng)
        return

    def mk(kind):
        if kind == "eager":
            return EagerLoop(cfg, params, args.slots, capacity)
        chunk = 1 if kind == "jit_chunk1" else args.chunk
        impl = "streamed" if kind.endswith("_streamed") else "dense"
        return ServeEngine(cfg, params, batch_slots=args.slots,
                           capacity=capacity, prefill_chunk=chunk,
                           decode_impl=impl)

    params = T.init(cfg, jax.random.PRNGKey(0))
    arms = ["eager", "jit_chunk1", f"jit_chunk{args.chunk}",
            f"jit_chunk{args.chunk}_streamed"]

    results = {}
    trace_counts = {}
    for kind in arms:
        e = mk(kind)
        # first pass compiles this instance's executables, second is warm;
        # report the warm (min) timing for every arm
        dt, total = workload(e, args.requests, args.prompt_len, gen, rng)
        if isinstance(e, ServeEngine):
            before = dict(e.trace_counts)
        dt2, _ = workload(e, args.requests, args.prompt_len, gen, rng)
        dt = min(dt, dt2)
        if isinstance(e, ServeEngine):
            assert e.trace_counts == before, \
                f"{kind}: retraced after warmup ({before} -> {e.trace_counts})"
            trace_counts[kind] = before
        results[kind] = {"wall_s": round(dt, 4),
                         "tokens": total,
                         "tok_per_s": round(total / dt, 2),
                         "decode_impl": ("streamed" if kind.endswith("_streamed")
                                         else "dense")}
        print(f"{kind:20s} {total:5d} tokens in {dt:7.3f}s "
              f"({total / dt:8.1f} tok/s)")

    jit1 = results["jit_chunk1"]["tok_per_s"]
    jitN = results[f"jit_chunk{args.chunk}"]["tok_per_s"]
    jitS = results[f"jit_chunk{args.chunk}_streamed"]["tok_per_s"]
    eager = results["eager"]["tok_per_s"]
    speedup = jitN / eager
    print(f"speedup (jitted+chunked vs eager loop): {speedup:.2f}x")
    print(f"chunked prefill vs width-1: {jitN / jit1:.2f}x")
    print(f"streamed decode vs dense: {jitS / jitN:.2f}x")
    print(f"trace counts (stable across runs): {trace_counts}")

    multi_axis = multi_adapter_axis(cfg, params, args, gen, capacity, rng)
    m_axis = mesh_axis(args, gen)

    report = {
        "config": {"model": cfg.name, "batch_slots": args.slots,
                   "requests": args.requests, "prompt_len": args.prompt_len,
                   "gen": gen, "prefill_chunk": args.chunk,
                   "smoke": bool(args.smoke),
                   "backend": jax.default_backend()},
        "results": results,
        "decode_impl_axis": {
            "dense": jitN, "streamed": jitS,
            "speedup_streamed_vs_dense": round(jitS / jitN, 2)},
        "multi_adapter_axis": multi_axis,
        "mesh_axis": m_axis,
        "speedup_jit_vs_eager": round(speedup, 2),
        "speedup_chunked_vs_width1": round(jitN / jit1, 2),
        "trace_counts": {arm: {str(k): v for k, v in c.items()}
                         for arm, c in trace_counts.items()},
    }
    if args.json:
        with open(args.json, "w") as f:
            json.dump(report, f, indent=2)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
