"""Figure 5: total global-adapter rank (across layers) vs threshold τ —
lower τ → aggressive rank compression → higher download efficiency."""
from __future__ import annotations

from benchmarks.common import bench_fed, emit

TAUS = (0.7, 0.8, 0.9, 0.95, 0.99)


def run():
    rows = []
    prev = None
    monotone = True
    for tau in TAUS:
        hist, tr = bench_fed("florist", tau=tau, rounds=2)
        total = hist[-1].global_rank_total
        if prev is not None and total < prev - 1e-9:
            pass
        if prev is not None and total + 1e-9 < prev:
            monotone = monotone and False
        rows.append({"name": f"fig5/tau={tau}", "us_per_call": "",
                     "derived": f"total_rank={total};eff={1.0/max(total,1):.2e}"})
        prev = total
    rows.append({"name": "fig5/monotone_nondecreasing", "us_per_call": "",
                 "derived": str(monotone)})
    return rows


if __name__ == "__main__":
    emit(run())
