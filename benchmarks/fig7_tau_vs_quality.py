"""Figure 7: energy threshold τ vs model quality — the SVT-regularization
curve (quality peaks below τ=1, degrades when τ is too aggressive)."""
from __future__ import annotations

from benchmarks.common import FAST, bench_fed, emit

TAUS = (0.6, 0.8, 0.9, 0.99, "auto")


def run():
    rows = []
    accs = {}
    for tau in TAUS:
        # "auto" = beyond-paper knee-point rank selection (paper §5 future
        # work (i)) — no tunable threshold at all
        hist, _ = bench_fed("florist", tau=tau,
                            rounds=3 if FAST else 8)
        accs[tau] = hist[-1].eval_acc
        rows.append({"name": f"fig7/tau={tau}",
                     "us_per_call": f"{hist[-1].eval_loss:.4f}",
                     "derived": f"acc={hist[-1].eval_acc:.3f};"
                               f"rank={hist[-1].global_rank_total}"})
    best = max(accs, key=accs.get)
    rows.append({"name": "fig7/best_tau", "us_per_call": "",
                 "derived": f"{best}"})
    return rows


if __name__ == "__main__":
    emit(run())
