"""saxml-style XLA inference-flag tuning for the sharded serve hot loop.

XLA reads ``XLA_FLAGS`` once at backend init, so every (flag set × mesh
topology) cell runs in a fresh subprocess: the worker builds a mesh-sharded
``ServeEngine`` on ``--xla_force_host_platform_device_count=N`` host
devices, compiles the decode burst, times it, and prints one JSON line.
The parent sweeps the named flag sets for the current backend, picks the
winner per topology, and records everything (winner + full per-set
timings) in a bench artifact:

  PYTHONPATH=src python benchmarks/xla_flags_tune.py --smoke --json BENCH_xla_flags.json

Flag sets follow the saxml serving playbook: a BASE set, an MBLO set
(memory-bound-loop optimizer) and a CM set (windowed-einsum /
async-collective-permute communication/compute overlap) on TPU; on CPU the
sweep covers the documented cpu-backend levers (fast-math, thunk runtime,
concurrency-optimized scheduler) so the harness exercises end to end in CI.
``append_xla_flags`` semantics: a flag the user already set in the
environment is never overridden by a set below.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.common.xla_env import merge_flags, render_flags  # noqa: E402

# named flag sets per backend.  TPU sets are from the saxml serving recipe;
# CPU sets cover that backend's documented performance levers.
FLAG_SETS = {
    "tpu": {
        "BASE": {
            "xla_tpu_enable_data_parallel_all_reduce_opt": True,
            "xla_tpu_data_parallel_opt_different_sized_ops": True,
            "xla_tpu_enable_async_collective_fusion": True,
            "xla_tpu_enable_async_collective_fusion_fuse_all_gather": True,
            "xla_tpu_enable_async_collective_fusion_multiple_steps": True,
            "xla_tpu_overlap_compute_collective_tc": True,
            "xla_enable_async_all_gather": True,
        },
        "MBLO": {
            "xla_tpu_enforce_prefetch_fifo_order": True,
            "xla_tpu_memory_bound_loop_optimizer_options": "enabled:true",
        },
        "CM": {
            "xla_jf_spmd_threshold_for_windowed_einsum_mib": 0,
            "xla_enable_async_collective_permute": True,
            "xla_tpu_spmd_unroll_windowed_einsum": True,
        },
    },
    "cpu": {
        "BASE": {},
        "FASTMATH": {"xla_cpu_enable_fast_math": True},
        "NOTHUNKS": {"xla_cpu_use_thunk_runtime": False},
        "CONCSCHED": {"xla_cpu_enable_concurrency_optimized_scheduler": True},
    },
}
# non-BASE sets apply ON TOP of BASE (saxml composes them the same way)
_COMPOSE_WITH_BASE = True

BURST = 8


def _worker(args) -> int:
    """One measurement cell; env (XLA_FLAGS) was fixed by the parent."""
    import jax

    from repro.common.config import ModelConfig
    from repro.models import transformer as T
    from repro.serve.engine import SamplingParams, ServeEngine
    from repro.topology import make_serve_mesh

    cfg = ModelConfig(name="flagtune-tiny", family="dense", num_layers=2,
                      d_model=64, num_heads=8, num_kv_heads=4, head_dim=16,
                      d_ff=128, vocab_size=256, dtype="float32")
    params = T.init(cfg, jax.random.PRNGKey(0))
    B = 4
    eng = ServeEngine(cfg, params, batch_slots=B, capacity=128,
                      prefill_chunk=8, decode_impl="streamed",
                      mesh=make_serve_mesh(args.mesh))
    for i in range(B):
        eng.submit([1 + i, 2, 3, 4], SamplingParams(max_tokens=512))
    eng.run_steps(1)                      # prefill; slots now pure-decode

    fn = eng._get_burst(BURST, False)
    fargs = (eng.params, eng._adapters_arg(), eng.cache, eng._state)
    t0 = time.perf_counter()
    jax.block_until_ready(fn(*fargs))     # trace + compile + first run
    compile_s = time.perf_counter() - t0
    times = []
    for _ in range(args.iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*fargs))
        times.append(time.perf_counter() - t0)
    best = min(times)
    us_per_step = best / BURST * 1e6
    print(json.dumps({
        "us_per_step": us_per_step,
        "tok_per_s": B * BURST / best,
        "compile_s": compile_s,
        "devices": len(jax.devices()),
    }))
    return 0


def _run_cell(set_name: str, flags: dict, mesh: int, args) -> dict:
    env = dict(os.environ)
    # merge_flags: a flag the user set in the parent env keeps its value
    env["XLA_FLAGS"] = merge_flags(
        os.environ.get("XLA_FLAGS", ""),
        f"--xla_force_host_platform_device_count={mesh}",
        *render_flags(flags).split())
    env.setdefault("JAX_PLATFORMS", "cpu")
    cmd = [sys.executable, os.path.abspath(__file__), "--worker",
           "--mesh", str(mesh), "--iters", str(args.iters)]
    proc = subprocess.run(cmd, env=env, capture_output=True, text=True,
                          timeout=600)
    if proc.returncode != 0:
        print(proc.stdout, proc.stderr, file=sys.stderr)
        raise RuntimeError(f"worker failed: set={set_name} mesh={mesh}")
    return json.loads(proc.stdout.strip().splitlines()[-1])


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--worker", action="store_true", help=argparse.SUPPRESS)
    ap.add_argument("--mesh", type=int, default=1, help=argparse.SUPPRESS)
    ap.add_argument("--iters", type=int, default=5)
    ap.add_argument("--smoke", action="store_true",
                    help="topologies {1,2} instead of {1,2,4,8}")
    ap.add_argument("--backend", default="",
                    help="flag-set family (default: detect, cpu off-TPU)")
    ap.add_argument("--json", default="", help="write the report here")
    args = ap.parse_args()

    if args.worker:
        return _worker(args)

    backend = args.backend
    if not backend:
        backend = "tpu" if os.environ.get("JAX_PLATFORMS", "") == "tpu" \
            else "cpu"
    sets = FLAG_SETS[backend]
    base = sets.get("BASE", {})
    topologies = (1, 2) if args.smoke else (1, 2, 4, 8)

    report = {"suite": "xla_flags", "backend": backend,
              "burst": BURST,
              "flag_sets": {k: render_flags(v) for k, v in sets.items()},
              "topologies": {}}
    for mesh in topologies:
        results = {}
        for name, flags in sets.items():
            merged = dict(base, **flags) if _COMPOSE_WITH_BASE else flags
            results[name] = _run_cell(name, merged, mesh, args)
            print(f"mesh={mesh} {name:10s} "
                  f"{results[name]['us_per_step']:9.1f} us/step "
                  f"(compile {results[name]['compile_s']:.1f}s)")
        winner = min(results, key=lambda n: results[n]["us_per_step"])
        entry = {
            "results": results,
            "winner": winner,
            "winning_flags": render_flags(dict(base, **sets[winner])
                                          if _COMPOSE_WITH_BASE
                                          else sets[winner]),
            "speedup_winner_vs_base": (results["BASE"]["us_per_step"]
                                       / results[winner]["us_per_step"]),
        }
        report["topologies"][f"mesh_{mesh}"] = entry
        print(f"mesh={mesh}: winner={winner} "
              f"(x{entry['speedup_winner_vs_base']:.3f} vs BASE)")

    if args.json:
        with open(args.json, "w") as f:
            json.dump(report, f, indent=2, sort_keys=True)
        print(f"wrote {args.json}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
