"""Diagnostic: dump the largest collective ops from an (optionally unrolled,
reduced-depth) dry-run compile.  Usage:

  PYTHONPATH=src python benchmarks/hlo_collectives.py <arch> <shape> [L] [--unroll]
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import collections
import re
import sys

import jax  # noqa: E402

from repro.common import flags
from repro.common.config import INPUT_SHAPES
from repro.common.pjit_utils import active_mesh
from repro.configs import get_config, long_context_variant
from repro.launch.dryrun import _COLLECTIVES, _shape_bytes, build_dryrun, pick_kv_dtype
from repro.launch.mesh import make_production_mesh


def main():
    arch, shape_name = sys.argv[1], sys.argv[2]
    L = int(sys.argv[3]) if len(sys.argv) > 3 and sys.argv[3].isdigit() else 2
    unroll = "--unroll" in sys.argv
    shape = INPUT_SHAPES[shape_name]
    cfg = get_config(arch)
    if shape_name == "long_500k":
        cfg = long_context_variant(cfg)
    kw = {"num_layers": L}
    if cfg.first_dense_layers:
        kw["first_dense_layers"] = 1
    cfg = cfg.replace(**kw)
    mesh = make_production_mesh()
    flags.set_analysis_unroll(unroll)
    fn, args = build_dryrun(cfg, shape, mesh, grad_accum=1,
                            kv_cache_dtype=pick_kv_dtype(cfg, shape))
    with mesh, active_mesh(mesh):
        compiled = fn.lower(*args).compile()
    txt = compiled.as_text()
    per_line = []
    totals = collections.Counter()
    for line in txt.splitlines():
        ls = line.strip()
        m = re.match(r"^(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.+?)\s+([a-z\-]+)\(", ls)
        if not m:
            continue
        op = m.group(2)
        for c in _COLLECTIVES:
            if op.startswith(c):
                b = _shape_bytes(m.group(1))
                totals[c] += b
                per_line.append((b, c, ls[:150]))
                break
    print("totals:", {k: f"{v/2**30:.2f}GiB" for k, v in totals.items()})
    print(f"\ntop collectives (of {len(per_line)}):")
    for b, c, l in sorted(per_line, reverse=True)[:12]:
        print(f"  {b/2**20:9.1f}MiB {c:18s} {l[:120]}")


if __name__ == "__main__":
    main()
