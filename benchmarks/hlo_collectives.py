"""Inspect the collective schedule of compiled programs.

Two modes:

*Dry-run mode* (default) — dump the largest collective ops from an
(optionally unrolled, reduced-depth) training dry-run compile on the
production mesh:

  PYTHONPATH=src python benchmarks/hlo_collectives.py <arch> <shape> [L] [--unroll]

*Serve mode* (``--serve``) — compile ONE sharded engine decode step on a
(data=1, model=N) host mesh and ASSERT its collective schedule: attention
is head-parallel so the only expected collective is the all-reduce at the
row-parallel output projections (+ the small vocab-sharded logit
reduction); all-to-all must not appear; total collective bytes stay under
an analytic per-step bound; and with the streamed interior no dense
``(B, H, C, cap)`` score/mask buffer may rematerialize.  Exits non-zero on
any violation — CI-friendly.

  PYTHONPATH=src python benchmarks/hlo_collectives.py --serve \\
      [--mesh 8] [--decode-impl streamed] [--width 1]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.common.xla_env import force_host_devices  # noqa: E402 (jax-free)


def parse_args(argv):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("arch", nargs="?", help="architecture (dry-run mode)")
    ap.add_argument("shape", nargs="?", help="input shape name (dry-run mode)")
    ap.add_argument("layers", nargs="?", type=int, default=2,
                    help="reduced layer count (dry-run mode)")
    ap.add_argument("--unroll", action="store_true")
    ap.add_argument("--serve", action="store_true",
                    help="assert the sharded serve-step collective schedule")
    ap.add_argument("--mesh", type=int, default=8,
                    help="model-axis size for --serve (forced host devices)")
    ap.add_argument("--decode-impl", default="streamed",
                    choices=("dense", "streamed", "kernel"))
    ap.add_argument("--width", type=int, default=1,
                    help="step token width for --serve (1 = decode)")
    args = ap.parse_args(argv)
    if not args.serve and (args.arch is None or args.shape is None):
        ap.error("dry-run mode needs <arch> <shape> (or pass --serve)")
    return args


def main_dryrun(args):
    import collections
    import re

    from repro.common import flags
    from repro.common.config import INPUT_SHAPES
    from repro.common.pjit_utils import active_mesh
    from repro.configs import get_config, long_context_variant
    from repro.launch.dryrun import (_COLLECTIVES, _shape_bytes, build_dryrun,
                                     pick_kv_dtype)
    from repro.topology import make_production_mesh

    shape = INPUT_SHAPES[args.shape]
    cfg = get_config(args.arch)
    if args.shape == "long_500k":
        cfg = long_context_variant(cfg)
    kw = {"num_layers": args.layers}
    if cfg.first_dense_layers:
        kw["first_dense_layers"] = 1
    cfg = cfg.replace(**kw)
    mesh = make_production_mesh()
    flags.set_analysis_unroll(args.unroll)
    fn, fargs = build_dryrun(cfg, shape, mesh, grad_accum=1,
                             kv_cache_dtype=pick_kv_dtype(cfg, shape))
    with mesh, active_mesh(mesh):
        compiled = fn.lower(*fargs).compile()
    txt = compiled.as_text()
    per_line = []
    totals = collections.Counter()
    for line in txt.splitlines():
        ls = line.strip()
        m = re.match(r"^(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.+?)\s+([a-z\-]+)\(", ls)
        if not m:
            continue
        op = m.group(2)
        for c in _COLLECTIVES:
            if op.startswith(c):
                b = _shape_bytes(m.group(1))
                totals[c] += b
                per_line.append((b, c, ls[:150]))
                break
    print("totals:", {k: f"{v/2**30:.2f}GiB" for k, v in totals.items()})
    print(f"\ntop collectives (of {len(per_line)}):")
    for b, c, l in sorted(per_line, reverse=True)[:12]:
        print(f"  {b/2**20:9.1f}MiB {c:18s} {l[:120]}")
    return 0


def _serve_config():
    """Tiny fp32 config for the serve-step schedule check: head counts
    divide every mesh size in {1, 2, 4, 8}."""
    from repro.common.config import ModelConfig
    return ModelConfig(name="hlo-serve-tiny", family="dense", num_layers=2,
                       d_model=64, num_heads=8, num_kv_heads=8, head_dim=16,
                       d_ff=128, vocab_size=256, dtype="float32")


def main_serve(args):
    import jax

    from repro.analysis.hlo_audit import collective_bytes, run_audit
    from repro.models import transformer as T
    from repro.serve.engine import ServeEngine
    from repro.topology import make_serve_mesh

    cfg = _serve_config()
    msize = args.mesh
    mesh = make_serve_mesh(msize)
    params = T.init(cfg, jax.random.PRNGKey(0))
    # cap must exceed the streamed block size (128): the live-memory claim
    # is that score tiles stay O(block), never O(cap)
    B, cap = 4, 512
    eng = ServeEngine(cfg, params, batch_slots=B, capacity=cap,
                      prefill_chunk=8, decode_impl=args.decode_impl,
                      mesh=mesh)
    compiled = eng.lower_step(width=args.width, stochastic=False).compile()
    txt = compiled.as_text()

    totals = collective_bytes(txt)
    print(f"serve step: impl={args.decode_impl} width={args.width} "
          f"mesh=(1,{msize}) B={B} cap={cap}")
    print("collective bytes/step:", {k: v for k, v in totals.items() if v})

    # the declarative schedule assertions live in repro.analysis.hlo_audit
    # ("serve.decode_step"); CI regression tests run the same audit
    failures = run_audit("serve.decode_step", txt, {
        "cfg": cfg, "mesh": msize, "batch": B, "capacity": cap,
        "width": args.width, "decode_impl": args.decode_impl,
    })

    if failures:
        for f in failures:
            print("FAIL:", f)
        return 1
    print("PASS: collective schedule as expected")
    return 0


if __name__ == "__main__":
    args = parse_args(sys.argv[1:])
    # append (never clobber) the forced device count BEFORE backend init
    force_host_devices(max(args.mesh, 1) if args.serve else 512)
    sys.exit(main_serve(args) if args.serve else main_dryrun(args))
