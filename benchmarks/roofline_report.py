"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from
experiments/dryrun/*.json.  Usage:

  python benchmarks/roofline_report.py > experiments/ROOFLINE.md
"""
import glob
import json
import os
import sys

SHAPE_TOKENS = {"train_4k": 256 * 4096, "prefill_32k": 32 * 32768,
                "decode_32k": 128, "long_500k": 1}
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
ARCH_ORDER = ["phi3_vision_4p2b", "zamba2_1p2b", "rwkv6_1p6b", "qwen1p5_32b",
              "granite_moe_1b_a400m", "qwen3_4b", "qwen2p5_14b", "qwen2_0p5b",
              "deepseek_v3_671b", "musicgen_medium"]


def _sentence(dom: str, mode: str, arch: str) -> str:
    moe = "moe" in arch or "deepseek" in arch
    if dom == "compute_s":
        return ("raise arithmetic intensity: larger per-chip microbatch and "
                "fused LoRA matmul (Pallas lora_matmul) to keep the MXU fed")
    if dom == "memory_s":
        if mode == "decode":
            return ("KV-cache bytes dominate: int8 cache (done where needed) "
                    "→ next lever is grouped/paged reads or MQA distillation")
        return ("bytes-accessed is a fusion upper bound; real levers: bf16 "
                "flash score tiles, fewer remat recomputes, fusing the "
                "adapter matmul into the base projection")
    if moe:
        return ("overlap the expert all-to-all with the shared-expert "
                "matmul; cap capacity factor; int8 dispatch payloads")
    if "rwkv" in arch:
        return ("sequence-shard the residual stream (Megatron-SP) so the "
                "per-layer projection all-reduces become RS+AG halves")
    return ("turn tensor-parallel all-reduces into reduce-scatter + "
            "all-gather pairs around the MLP (sequence parallelism) and "
            "overlap with compute")


def load(dirpath):
    recs = {}
    for f in glob.glob(os.path.join(dirpath, "*.json")):
        d = json.load(open(f))
        if d.get("kind"):
            continue
        recs[(d["arch"], d["shape"], d["mesh"])] = d
    return recs


def fitproof_table(recs, mesh):
    lines = [
        f"| arch | shape | ga | kv | mem/dev (GiB) | compile (s) |",
        f"|---|---|---|---|---|---|",
    ]
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            d = recs.get((a, s, mesh))
            if not d:
                continue
            m = d["memory"]
            tot = (m["argument_bytes"] + m["temp_bytes"] + m["output_bytes"]) / 2**30
            flag = " ⚠" if tot > 16 else ""
            lines.append(
                f"| {a} | {s} | {d.get('grad_accum', 1)} | "
                f"{d.get('kv_cache_dtype', '-')} | {tot:.2f}{flag} | "
                f"{d['compile_s']:.1f} |")
    return "\n".join(lines)


def roofline_table(recs):
    lines = [
        "| arch | shape | compute (s) | memory (s) | collective (s) | "
        "dominant | MODEL_FLOPS | useful | next lever |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            d = recs.get((a, s, "16x16"))
            if not d or "analysis" not in d:
                continue
            an = d["analysis"]
            r = an["roofline"]
            mult = 6 if d["mode"] == "train" else 2
            model_flops = mult * d["active_params"] * SHAPE_TOKENS[s]
            hlo_global = an["flops_per_device"] * d["chips"]
            useful = model_flops / hlo_global if hlo_global else 0.0
            dom = r["dominant"]
            lines.append(
                f"| {a} | {s} | {r['compute_s']:.4f} | {r['memory_s']:.4f} | "
                f"{r['collective_s']:.4f} | {dom.replace('_s','')} | "
                f"{model_flops:.2e} | {min(useful,9.99)*100:.0f}% | "
                f"{_sentence(dom, d['mode'], a)} |")
    return "\n".join(lines)


def main():
    dirpath = sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun"
    recs = load(dirpath)
    print("### Fit-proof (16×16, 256 chips)\n")
    print(fitproof_table(recs, "16x16"))
    print("\n### Fit-proof (2×16×16, 512 chips)\n")
    print(fitproof_table(recs, "2x16x16"))
    print("\n### Roofline (single pod; unrolled-analysis numbers)\n")
    print(roofline_table(recs))


if __name__ == "__main__":
    main()
