"""Table 4: server-side computational cost per aggregation method at
TinyLlama shapes (m = n = 2048, K = 10 clients, rank 16 → stacked r = 160).

Two measurements:
  * XLA-measured FLOPs of the jit-compiled aggregation math (cost_analysis
    of florist's stacked-SVD pipeline vs FlexLoRA's dense-ΔW SVD);
  * wall-clock µs on this host (CPU) for the same ops.

Claim validated: FLoRIST ≪ FlexLoRA server cost (paper: 7.5×; 466.95M vs
3516.01M FLOPs)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit
from repro.core.aggregators import make_aggregator
from repro.core.svd import florist_core_padded, thin_svd

M = N = 2048
K, R = 10, 16
r = K * R


def _flops(fn, *args):
    c = jax.jit(fn).lower(*args).compile().cost_analysis()
    if isinstance(c, list):
        c = c[0]
    return float(c.get("flops", 0.0))


def run():
    rng = np.random.default_rng(0)
    B_stack = jnp.asarray(rng.normal(size=(M, r)), jnp.float32)
    A_stack = jnp.asarray(rng.normal(size=(r, N)), jnp.float32)

    def florist(bs, as_):
        return florist_core_padded(bs, as_, tau=0.9)

    def flexlora(bs, as_):
        dw = bs @ as_                       # forms the dense ΔW
        u, s, vt = jnp.linalg.svd(dw, full_matrices=False)
        return u[:, :R] * s[:R], vt[:R]

    def fedit(bs, as_):                      # weighted averaging only
        b = bs.reshape(M, K, R).mean(1)
        a = as_.reshape(K, R, N).mean(0)
        return b, a

    fl_f = _flops(florist, B_stack, A_stack)
    fx_f = _flops(flexlora, B_stack, A_stack)
    fi_f = _flops(fedit, B_stack, A_stack)
    fl_t = timeit(jax.jit(florist), B_stack, A_stack)
    fx_t = timeit(jax.jit(flexlora), B_stack, A_stack)
    fi_t = timeit(jax.jit(fedit), B_stack, A_stack)

    # analytic table (per layer-pair, full model = ×2 proj ×22 layers)
    dims = {("blocks", 0, "attn", "wq"): (22, N, M),
            ("blocks", 0, "attn", "wv"): (22, N, M)}
    ranks = {k: [7] * 22 for k in dims}
    ana = {m: make_aggregator(m).server_flops(dims, [R] * K, ranks)
           for m in ("fedit", "ffa", "flora", "flexlora", "florist")}

    rows = [
        {"name": "table4/florist_measured", "us_per_call": f"{fl_t:.0f}",
         "derived": f"flops={fl_f:.3e}"},
        {"name": "table4/flexlora_measured", "us_per_call": f"{fx_t:.0f}",
         "derived": f"flops={fx_f:.3e}"},
        {"name": "table4/fedit_measured", "us_per_call": f"{fi_t:.0f}",
         "derived": f"flops={fi_f:.3e}"},
        {"name": "table4/speedup", "us_per_call": f"{fx_t/max(fl_t,1e-9):.2f}",
         "derived": f"flops_ratio_flex_over_florist={fx_f/max(fl_f,1):.2f}"},
    ]
    for m, f in ana.items():
        rows.append({"name": f"table4/analytic/{m}", "us_per_call": "",
                     "derived": f"flops={f:.3e}"})
    return rows


if __name__ == "__main__":
    emit(run())
