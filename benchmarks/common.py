"""Shared benchmark utilities."""
from __future__ import annotations

import os
import time
from typing import Callable, Dict, List

import jax
import numpy as np

from repro.common.config import FedConfig, LoRAConfig, ModelConfig, OptimConfig

FAST = os.environ.get("BENCH_FAST", "0") == "1"

# tiny federated benchmark model (CPU-sized)
BENCH_MODEL = ModelConfig(name="bench-tiny", family="dense", num_layers=4,
                          d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
                          d_ff=128, vocab_size=256, dtype="float32")
BENCH_LORA = LoRAConfig(rank=8, alpha=8.0)
BENCH_OPT = OptimConfig(lr=3e-3)


def bench_fed(method: str, *, heterogeneous: bool = False, tau: float = 0.9,
              rounds: int = 0, seed: int = 0, num_clients: int = 20,
              clients_per_round: int = 5):
    from repro.core.federated import FederatedTrainer
    rounds = rounds or (3 if FAST else 10)
    fed = FedConfig(
        num_clients=num_clients, clients_per_round=clients_per_round,
        method=method, tau=tau, homogeneous_rank=8,
        heterogeneous=heterogeneous,
        rank_distribution=((4, 8), (8, 4), (16, 4), (32, 2), (64, 2)),
        zero_padding=heterogeneous and method in ("fedit", "ffa"),
        seed=seed)
    tr = FederatedTrainer(BENCH_MODEL, fed, BENCH_LORA, BENCH_OPT,
                          batch_size=8, local_steps=4, seq_len=32)
    hist = tr.run(rounds)
    return hist, tr


def timeit(fn: Callable, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall-time in µs (jit-compiled callables; blocks on result)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(ts))


def emit(rows: List[Dict]) -> None:
    for r in rows:
        print(f"{r['name']},{r.get('us_per_call', '')},{r.get('derived', '')}")
