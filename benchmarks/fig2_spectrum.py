"""Figure 2 analogue: singular-value spectrum of the aggregated update in a
heterogeneous round — demonstrates the low intrinsic dimensionality that
motivates thresholding (most energy within the first ~6-10 components even
when Σ r_k is large)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import bench_fed, emit
from repro.core.svd import energy_rank


def run():
    hist, tr = bench_fed("florist", heterogeneous=True, tau=1.0, rounds=1)
    rows = []
    agg = tr.global_state
    eff_ranks = []
    stack_ranks = []
    for path, spectra in agg.spectra.items():
        for l, s in enumerate(spectra):
            import jax.numpy as jnp
            p90 = energy_rank(jnp.asarray(s), 0.90)
            p99 = energy_rank(jnp.asarray(s), 0.99)
            eff_ranks.append(p90)
            stack_ranks.append(len(s))
            if l < 2:
                rows.append({
                    "name": f"fig2/{'/'.join(map(str, path))}/layer{l}",
                    "us_per_call": "",
                    "derived": f"p90={p90};p99={p99};stack_rank={len(s)};"
                               f"sigma1={s[0]:.3f};sigma_last={s[-1]:.2e}",
                })
    rows.append({
        "name": "fig2/summary", "us_per_call": "",
        "derived": (f"mean_p90={np.mean(eff_ranks):.1f};"
                    f"mean_stack_rank={np.mean(stack_ranks):.0f};"
                    f"compression={np.mean(stack_ranks)/max(np.mean(eff_ranks),1):.1f}x"),
    })
    return rows


if __name__ == "__main__":
    emit(run())
